//! Finetuning scenario (paper §6.1 analogue): adapt a pretrained
//! checkpoint to the synthetic task suite under each training method,
//! reporting answer-span loss/accuracy — the Table 2 workflow.
//!
//!     cargo run --release --example finetune_sim -- \
//!         [--profile tiny] [--steps 80] [--task arith] [--seeds 2]
//!
//! The paper's headline instability (Block diverging on GSM8K for some
//! seeds while Fallback stays stable, Fig 8a) is what the multi-seed
//! loop surfaces.

use anyhow::Result;

use dbfq::coordinator::{TrainConfig, Trainer};
use dbfq::data::{answer_span_loss, Task};
use dbfq::model::Method;
use dbfq::runtime::{artifacts_dir, Runtime};
use dbfq::util::bench::Table;
use dbfq::util::cli::Args;
use dbfq::util::rng::Pcg64;

fn task_by_name(name: &str) -> Task {
    match name {
        "span" => Task::SpanCopy,
        "choice" => Task::Choice,
        "cont" => Task::Continuation,
        _ => Task::Arithmetic,
    }
}

fn finetune(
    rt: &Runtime,
    profile: &str,
    method: Method,
    task: Task,
    steps: usize,
    seed: u64,
) -> Result<(f64, f64)> {
    let prof = rt.profile(profile)?.clone();
    let mut cfg = TrainConfig::new(profile, method, seed, steps);
    cfg.lr.peak = 3e-4; // finetune-ish: smaller LR, short warmup
    cfg.lr.warmup = steps / 7 + 1;
    let mut trainer = Trainer::new(rt, cfg)?;
    let mut rng = Pcg64::new(seed ^ 0xF1E7);
    let mut final_train = f64::NAN;
    for _ in 0..steps {
        let (toks, _) = task.batch(prof.batch, prof.seq_len, prof.vocab,
                                   &mut rng);
        let st = trainer.step_on(&toks)?;
        final_train = st.loss;
    }
    // held-out answer-span loss
    let mut eval_rng = Pcg64::new(0xE7A1);
    let mut span_tot = 0.0;
    let n_eval = 8;
    for _ in 0..n_eval {
        let (toks, spans) = task.batch(prof.batch, prof.seq_len,
                                       prof.vocab, &mut eval_rng);
        let per_tok = trainer.eval_per_token(&toks)?;
        span_tot +=
            answer_span_loss(&per_tok, prof.batch, prof.seq_len, &spans);
    }
    Ok((final_train, span_tot / n_eval as f64))
}

fn main() -> Result<()> {
    let args = Args::from_env(&[]).map_err(anyhow::Error::msg)?;
    let profile = args.get_or("profile", "tiny").to_string();
    let steps = args.get_usize("steps", 80);
    let seeds = args.get_u64("seeds", 2);
    let task = task_by_name(args.get_or("task", "arith"));

    let rt = Runtime::open(&artifacts_dir())?;
    println!("finetune_sim: {} on {}  steps={steps}", profile,
             task.name());

    let mut table = Table::new(&["method", "seed", "train-loss",
                                 "answer-span-loss"]);
    for method in [Method::Bf16, Method::Block, Method::Jetfire,
                   Method::Fallback] {
        for seed in 0..seeds {
            let (tl, sl) =
                finetune(&rt, &profile, method, task, steps, seed)?;
            table.row(&[
                method.tag().into(),
                seed.to_string(),
                format!("{tl:.4}"),
                format!("{sl:.4}"),
            ]);
        }
    }
    table.print();
    println!("\n(lower answer-span loss = better task accuracy; the \
              paper's Table 2 pattern: Ours ≈ BF16, Block can diverge \
              on hard seeds)");
    Ok(())
}
