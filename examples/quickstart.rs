//! Quickstart: train a tiny GLU transformer with dynamic block-level
//! fallback INT8 quantization, entirely from Rust.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Walks through the public API surface: open the artifact runtime,
//! build a trainer, stream synthetic data, watch the delay-threshold
//! controller (Algorithm 2) keep the fallback rate inside [0.1, 0.3],
//! then evaluate.

use anyhow::Result;

use dbfq::coordinator::{TrainConfig, Trainer};
use dbfq::data::Corpus;
use dbfq::model::Method;
use dbfq::runtime::{artifacts_dir, Runtime};
use dbfq::util::rng::Pcg64;

fn main() -> Result<()> {
    // 1. Open the AOT artifact registry (HLO text + manifest).
    let rt = Runtime::open(&artifacts_dir())?;
    let prof = rt.profile("tiny")?.clone();
    println!(
        "model: d={} layers={} params={}  platform={}",
        prof.d_model, prof.n_layers, prof.n_params, rt.platform()
    );

    // 2. Configure fallback-quantized training (paper defaults:
    //    INT8 blocks, SR for gradients, rate band [0.1, 0.3], alpha 1.3).
    let steps = 60;
    let cfg = TrainConfig::new("tiny", Method::Fallback, 42, steps);

    // 3. Data: synthetic Zipfian byte corpus.
    let corpus = Corpus::synthetic(100_000, prof.vocab, 7);
    let mut rng = Pcg64::new(42);

    // 4. Train.
    let mut trainer = Trainer::new(&rt, cfg)?;
    for s in 0..steps {
        let tokens = corpus.sample_batch(prof.batch, prof.seq_len, &mut rng);
        let st = trainer.step_on(&tokens)?;
        if s % 10 == 0 || s + 1 == steps {
            println!(
                "step {:3}  loss {:.4}  fallback-rate {:.3}  θ̄ {:.3}",
                st.step, st.loss, st.mean_fallback_rate, st.mean_theta
            );
        }
    }

    // 5. Evaluate.
    let eval = corpus.eval_batches(prof.batch, prof.seq_len, 8);
    let loss = trainer.eval_on(&eval)?;
    println!("eval: loss {loss:.4}  ppl {:.2}", loss.exp());

    // 6. The same numeric format, natively in Rust (no PJRT):
    let mut mrng = Pcg64::new(1);
    let x = dbfq::util::Mat::randn(256, 256, 1.0, &mut mrng);
    let w = dbfq::util::Mat::randn(256, 256, 1.0, &mut mrng);
    let exact = dbfq::gemm::matmul(&x, &w, 1);
    let (c, rate) = dbfq::gemm::fallback_matmul(&x, &w, 4.0, 128, 1);
    println!(
        "rust fallback GEMM: rate {:.3}, rel-err {:.5}",
        rate,
        dbfq::quant::metrics::rel_err(&c.data, &exact.data)
    );
    Ok(())
}
