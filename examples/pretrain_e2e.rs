//! End-to-end pretraining driver — the repo's headline validation run.
//!
//!     cargo run --release --example pretrain_e2e -- \
//!         [--profile small|e2e] [--steps N] [--method fallback] \
//!         [--compare] [--seed N] [--out runs/]
//!
//! Trains a GLU transformer on the synthetic corpus and logs the loss
//! curve + fallback-rate trace to a JSON lines file. With `--compare`
//! it interleaves BF16 and Fallback runs on identical data order so the
//! curves are directly overlayable (paper Fig 7b's claim: they match).
//!
//! Profiles: `small` = 14M params (default; full multi-hundred-step run
//! is tractable on this single-core CPU testbed), `e2e` = 113M params
//! (~the paper-prompt's 100M; use fewer steps). Results land in
//! EXPERIMENTS.md.

use std::io::Write;

use anyhow::Result;

use dbfq::coordinator::{TrainConfig, Trainer};
use dbfq::data::Corpus;
use dbfq::model::Method;
use dbfq::runtime::{artifacts_dir, Runtime};
use dbfq::util::cli::Args;
use dbfq::util::json::{obj, Json};
use dbfq::util::rng::Pcg64;

fn run_one(
    rt: &Runtime,
    profile: &str,
    method: Method,
    steps: usize,
    seed: u64,
    eval_every: usize,
    log: &mut std::fs::File,
) -> Result<Vec<(usize, f64)>> {
    let prof = rt.profile(profile)?.clone();
    let mut cfg = TrainConfig::new(profile, method, seed, steps);
    cfg.lr.peak = 3e-4;
    cfg.lr.warmup = (steps / 10).max(5);
    let corpus = Corpus::synthetic(400_000, prof.vocab, 1234);
    let eval_batches = corpus.eval_batches(prof.batch, prof.seq_len, 4);
    // identical data order across methods: seed depends only on `seed`
    let mut rng = Pcg64::new(seed.wrapping_mul(977));
    let mut trainer = Trainer::new(rt, cfg)?;
    let mut curve = Vec::new();
    let t0 = std::time::Instant::now();
    for s in 0..steps {
        let toks = corpus.sample_batch(prof.batch, prof.seq_len, &mut rng);
        let st = trainer.step_on(&toks)?;
        let mut rec = vec![
            ("run", Json::Str(format!("{profile}/{}", method.tag()))),
            ("step", Json::Num(st.step as f64)),
            ("loss", Json::Num(st.loss)),
            ("rate", Json::Num(st.mean_fallback_rate)),
            ("theta", Json::Num(st.mean_theta)),
        ];
        if (s + 1) % eval_every == 0 || s + 1 == steps {
            let vl = trainer.eval_on(&eval_batches)?;
            curve.push((st.step, vl));
            rec.push(("val_loss", Json::Num(vl)));
            println!(
                "[{}] step {:4}  train {:.4}  val {:.4}  rate {:.3}  \
                 ({:.2}s/step)",
                method.tag(), st.step, st.loss, vl,
                st.mean_fallback_rate,
                t0.elapsed().as_secs_f64() / (s + 1) as f64
            );
        }
        writeln!(log, "{}", obj(rec).to_string())?;
    }
    Ok(curve)
}

fn main() -> Result<()> {
    let args = Args::from_env(&["compare"]).map_err(anyhow::Error::msg)?;
    let profile = args.get_or("profile", "small").to_string();
    let steps = args.get_usize("steps", 300);
    let seed = args.get_u64("seed", 0);
    let eval_every = args.get_usize("eval-every", 25);
    let outdir = args.get_or("out", "runs").to_string();
    std::fs::create_dir_all(&outdir)?;

    let rt = Runtime::open(&artifacts_dir())?;
    let prof = rt.profile(&profile)?.clone();
    println!(
        "pretrain_e2e: {} params={} seq={} batch={} steps={steps}",
        profile, prof.n_params, prof.seq_len, prof.batch
    );

    let methods: Vec<Method> = if args.has_flag("compare") {
        vec![Method::Bf16, Method::Fallback]
    } else {
        vec![match args.get_or("method", "fallback") {
            "bf16" => Method::Bf16,
            "block" => Method::Block,
            "jetfire" => Method::Jetfire,
            _ => Method::Fallback,
        }]
    };

    let mut log = std::fs::File::create(format!(
        "{outdir}/pretrain_{profile}_{seed}.jsonl"
    ))?;
    let mut summaries = Vec::new();
    for method in methods {
        let curve = run_one(&rt, &profile, method, steps, seed,
                            eval_every, &mut log)?;
        summaries.push((method, curve));
    }

    println!("\n== final validation losses ==");
    for (m, curve) in &summaries {
        if let Some((step, vl)) = curve.last() {
            println!("{:9} step {step:4}  val loss {vl:.4}  ppl {:.2}",
                     m.tag(), vl.exp());
        }
    }
    if summaries.len() == 2 {
        let b = summaries[0].1.last().unwrap().1;
        let f = summaries[1].1.last().unwrap().1;
        println!(
            "fallback - bf16 val-loss gap: {:+.4} (paper: curves overlap)",
            f - b
        );
    }
    Ok(())
}
