//! Numeric-format tour: inspect what dynamic block-level fallback does
//! to a GLU activation tensor, entirely in the Rust core library.
//!
//!     cargo run --release --example fallback_inspect
//!
//! Prints the paper's §4.1 outlier anatomy (Table 1-style stats), the
//! block fallback map (Fig 4a), the RMSE story (Fig 3b: fallback vs
//! INT8 vs INT16), and the underflow rates that motivate the method.

use dbfq::outlier::{column_concentration, fallback_map, outlier_stats,
                    ActivationModel};
use dbfq::quant::{self, metrics, Criterion, Rounding, INT8_LEVELS};
use dbfq::util::bench::Table;

fn main() {
    // 1. A GLU activation with the paper's outlier structure.
    let act = ActivationModel::glu_llm(512, 1024).sample(7);
    let s = outlier_stats(&act);
    println!("== outlier anatomy (paper §4.1 / Table 1) ==");
    println!("token-wise max |x|  : {:8.1}", s.token_wise);
    println!("channel-wise max |x|: {:8.1}", s.channel_wise);
    println!("others max |x|      : {:8.1}   (P2: unstructured)",
             s.others);
    println!("fraction < 1% of max: {:8.3}   (P3: sparsity)\n",
             s.sparsity_99);

    // 2. Quantization error of the candidate formats (Fig 3b).
    let mut t = Table::new(&["format", "rmse", "underflow"]);
    let bq = quant::block_quant(&act, 128, INT8_LEVELS, Rounding::Nearest);
    t.row(&[
        "INT8 128x128".into(),
        format!("{:.5}", metrics::rmse(&bq.dequant().data, &act.data)),
        format!("{:.3}", metrics::underflow_rate(&act.data, &bq.q)),
    ]);
    let i16 = quant::int16_block_quant(&act, 128);
    t.row(&[
        "INT16 128x128".into(),
        format!("{:.5}", metrics::rmse(&i16.dequant().data, &act.data)),
        "-".into(),
    ]);
    for rate in [0.1, 0.2, 0.5, 1.0] {
        let probe = quant::fallback_quant(&act, f32::INFINITY, 128,
                                          INT8_LEVELS, Criterion::AbsMax);
        let theta = quant::theta_for_rate(&probe.metric, rate);
        let fq = quant::fallback_quant(&act, theta, 128, INT8_LEVELS,
                                       Criterion::AbsMax);
        t.row(&[
            format!("Fallback {:.0}%", 100.0 * fq.fallback_rate()),
            format!("{:.5}", metrics::rmse(&fq.dequant().data, &act.data)),
            "-".into(),
        ]);
    }
    println!("== representation error (Fig 3b story) ==");
    t.print();

    // 3. The fallback map (Fig 4a): which blocks fall back at 20%?
    let (u, rb, cb) = fallback_map(&act, 128, 0.2);
    println!("\n== fallback block map (Fig 4a, {rb}x{cb} blocks, \
              20% rate) ==");
    for r in 0..rb {
        let row: String = (0..cb)
            .map(|c| if u[r * cb + c] { '#' } else { '.' })
            .collect();
        println!("  {row}");
    }
    println!(
        "column concentration (top-2 cols): {:.2} — channel-wise \
         pattern with occasional scatter",
        column_concentration(&u, rb, cb, 2)
    );

    // 4. ACT-MEM math (paper §5.2): INT10 1x128 context = 5/8 of BF16.
    let gq = quant::group_quant(&act, 128, 10);
    let bf16 = act.data.len() * 2;
    println!(
        "\nnon-linear context: INT10 1x128 = {} bytes vs BF16 {} \
         ({:.0}%)",
        gq.bytes(),
        bf16,
        100.0 * gq.bytes() as f64 / bf16 as f64
    );
}
