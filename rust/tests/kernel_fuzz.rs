//! Randomized differential fuzzer: every microkernel backend on the
//! host vs the exact i64 oracles, over hostile shapes.
//!
//! The per-commit property suites (`engine_prop.rs` etc.) run a fixed
//! number of cases; this binary instead runs **as many random cases
//! as fit a wall-clock budget**, libLISA-style: generate a random
//! configuration, run it through every backend (`kernels::available()`
//! — the same set `PALLAS_KERNEL` can force), and demand bit-identity
//! with the exact integer reference. Disagreement of any backend with
//! the oracle — or of two backends with each other — is a bug by the
//! engine's contract.
//!
//! Deliberately hostile inputs:
//! * prime K / width / N (every SIMD j-tail and K-remainder path),
//! * block size at the `I8_EXACT_MAX_BS` exactness boundary,
//! * saturated ±127 codes (the worst case for the sse2/avx2 i16-pair
//!   scheme and the avx512vnni unsigned-offset correction),
//! * zero-heavy codes and all-fallback u-masks,
//! * nibble-packed i4 panels against full-range i8 codes on the A
//!   side (the staged ladder's residual contract), odd widths (the
//!   half-byte tail of the pack), and block size at the
//!   `I4_EXACT_MAX_BS` nibble exactness boundary.
//!
//! Knobs (env):
//! * `DBFQ_FUZZ_SEED` — base seed (default fixed); every failure
//!   message carries the case seed for replay.
//! * `DBFQ_FUZZ_SECS` — wall-clock budget per fuzz test (default 1.5,
//!   so the suite stays cheap in PR CI; the nightly workflow sets
//!   300).

use std::time::{Duration, Instant};

use dbfq::gemm::kernels::{self, Kernels};
use dbfq::gemm::{
    block_gemm_reference, fallback_gemm_reference, int4_gemm_reference,
    staged_gemm_reference, DataPath, GemmPlan, I4_EXACT_MAX_BS,
    I8_EXACT_MAX_BS,
};
use dbfq::quant::{block_quant, fallback_quant, staged_quant,
                  Criterion, Rounding, INT4_LEVELS, INT8_LEVELS};
use dbfq::util::rng::Pcg64;
use dbfq::util::Mat;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn budget() -> Duration {
    Duration::from_secs_f64(env_f64("DBFQ_FUZZ_SECS", 1.5))
}

fn base_seed() -> u64 {
    env_u64("DBFQ_FUZZ_SEED", 0xF0_22_5EED_2026)
}

/// Code-generation regime for one case.
#[derive(Clone, Copy, Debug)]
enum Regime {
    /// uniform codes in [-127, 127]
    Uniform,
    /// every code ±127 (saturation / offset-correction worst case)
    Saturated,
    /// mostly zero, a few ±127 spikes
    Sparse,
}

fn pick_regime(rng: &mut Pcg64) -> Regime {
    match rng.below(4) {
        0 => Regime::Saturated,
        1 => Regime::Sparse,
        _ => Regime::Uniform,
    }
}

fn rand_codes(n: usize, regime: Regime, rng: &mut Pcg64) -> Vec<i8> {
    (0..n)
        .map(|_| match regime {
            Regime::Uniform => (rng.below(255) as i32 - 127) as i8,
            Regime::Saturated => {
                if rng.below(2) == 0 { 127 } else { -127 }
            }
            Regime::Sparse => match rng.below(8) {
                0 => 127,
                1 => -127,
                _ => 0,
            },
        })
        .collect()
}

/// f32 data built from raw codes. When a block contains a ±127
/// element its absmax is 127, the scale is 1, and every code
/// round-trips exactly (the Saturated regime guarantees this);
/// otherwise quantization re-derives codes — equally fine for a
/// differential test, which only needs *some* valid quantization.
fn mat_from_codes(rows: usize, cols: usize, codes: &[i8]) -> Mat {
    Mat::from_vec(rows, cols,
                  codes.iter().map(|&c| c as f32).collect())
}

/// Exact i64 reference for a `rows`-row dot tile, mirroring the
/// kernel calling convention (`panel[(k0 + k) * width + j]`).
#[allow(clippy::too_many_arguments)]
fn ref_dot(
    qa: &[i8], a_stride: usize, r: usize, k0: usize, bs: usize,
    panel: &[i8], width: usize, rows: usize,
) -> Vec<i64> {
    let mut out = vec![0i64; rows * width];
    for t in 0..rows {
        let arow = &qa[(r + t) * a_stride + k0..];
        for j in 0..width {
            let mut s = 0i64;
            for k in 0..bs {
                s += arow[k] as i64
                    * panel[(k0 + k) * width + j] as i64;
            }
            out[t * width + j] = s;
        }
    }
    out
}

/// One random kernel-level case: raw codes through every backend's
/// dot1/dot2/dot4 tiles vs the i64 reference.
fn fuzz_dot_case(case_seed: u64, backends: &[&'static Kernels]) {
    let mut rng = Pcg64::new(case_seed);
    // hostile block sizes: tiny, prime, SIMD-misaligned, large
    let bs = [1usize, 2, 3, 4, 5, 7, 8, 12, 13, 16, 17, 31, 37, 61,
              64, 101, 128, 251][rng.below(18)];
    // width ≤ bs is the engine contract; primes + SIMD tails
    let width = 1 + rng.below(bs.min(67));
    let k0 = bs * rng.below(3);
    let a_stride = k0 + bs + rng.below(5);
    let rows = 4; // dot4 needs 4 rows; reuse for all tiles
    let r = rng.below(2);
    let regime = pick_regime(&mut rng);
    let qa = rand_codes((r + rows) * a_stride, regime, &mut rng);
    let panel = rand_codes((k0 + bs) * width, regime, &mut rng);
    let want = ref_dot(&qa, a_stride, r, k0, bs, &panel, width, rows);

    for &kn in backends {
        for (tile_rows, dot) in
            [(1usize, kn.dot_i8), (2, kn.dot2_i8), (4, kn.dot4_i8)]
        {
            // row t's results land bs apart in both workspaces
            let mut acci = vec![0i32; tile_rows * bs];
            let mut acc = vec![0.0f32; tile_rows * bs];
            dot(&qa, a_stride, r, k0, bs, &panel, width, &mut acci,
                &mut acc);
            for t in 0..tile_rows {
                for j in 0..width {
                    let w = want[t * width + j];
                    let got = acci[t * bs + j];
                    assert_eq!(
                        got as i64, w,
                        "backend {} dot{tile_rows} acci \
                         seed={case_seed:#x} bs={bs} width={width} \
                         k0={k0} regime={regime:?} t={t} j={j}",
                        kn.name
                    );
                    let gotf = acc[t * bs + j];
                    assert_eq!(
                        gotf.to_bits(), (w as f32).to_bits(),
                        "backend {} dot{tile_rows} widen \
                         seed={case_seed:#x} bs={bs} width={width} \
                         t={t} j={j}",
                        kn.name
                    );
                }
            }
        }
    }
}

#[test]
fn fuzz_dot_tiles_vs_i64_reference() {
    let backends = kernels::available();
    let seed = base_seed();
    let deadline = Instant::now() + budget();
    let mut cases = 0u64;
    while Instant::now() < deadline {
        fuzz_dot_case(seed.wrapping_add(cases), &backends);
        cases += 1;
    }
    println!(
        "kernel_fuzz dot tiles: {cases} cases, seed {seed:#x}, \
         backends {:?}",
        backends.iter().map(|k| k.name).collect::<Vec<_>>()
    );
    assert!(cases > 0);
}

/// One random engine-level case: quantized matrices through
/// `GemmPlan` (both int8 and fallback precisions, true-i8 path) on
/// every backend vs the exact references.
fn fuzz_engine_case(case_seed: u64, backends: &[&'static Kernels]) {
    let mut rng = Pcg64::new(case_seed);
    let bs = [3usize, 5, 7, 13, 16, 17, 31][rng.below(7)];
    // prime-heavy dims with occasional exact multiples
    let dim = |rng: &mut Pcg64, bs: usize| match rng.below(4) {
        0 => [7usize, 13, 23, 41, 53][rng.below(5)],
        1 => bs * (1 + rng.below(3)),
        _ => 1 + rng.below(3 * bs),
    };
    let (m, k, n) = (dim(&mut rng, bs), dim(&mut rng, bs),
                    dim(&mut rng, bs));
    let regime = pick_regime(&mut rng);
    let a = mat_from_codes(m, k, &rand_codes(m * k, regime, &mut rng));
    let b = mat_from_codes(k, n, &rand_codes(k * n, regime, &mut rng));
    let qa = block_quant(&a, bs, INT8_LEVELS, Rounding::Nearest);
    let qb = block_quant(&b, bs, INT8_LEVELS, Rounding::Nearest);
    let c_ref = block_gemm_reference(&qa, &qb);
    // all-fallback vs no-fallback vs random masks
    let theta = match rng.below(3) {
        0 => -1.0,
        1 => f32::INFINITY,
        _ => 0.0, // AbsMax metric > 0 wherever the block is nonzero
    };
    let fa = fallback_quant(&a, theta, bs, INT8_LEVELS,
                            Criterion::AbsMax);
    let f_ref = fallback_gemm_reference(&fa, &qb, &fa.u);
    let threads = 1 + rng.below(4);
    for &kn in backends {
        let c = GemmPlan::new_int8_path(&qa, &qb, threads,
                                        DataPath::Int8)
            .with_kernels(kn)
            .execute();
        assert_eq!(
            c.data, c_ref.data,
            "backend {} int8 vs i64 oracle seed={case_seed:#x} \
             ({m},{k},{n}) bs={bs} regime={regime:?} \
             threads={threads}",
            kn.name
        );
        let f = GemmPlan::new_fallback_path(&fa, &qb, &fa.u, threads,
                                            DataPath::Int8)
            .with_kernels(kn)
            .execute();
        assert_eq!(
            f.data, f_ref.data,
            "backend {} fallback vs i64 oracle seed={case_seed:#x} \
             ({m},{k},{n}) bs={bs} theta={theta} regime={regime:?} \
             threads={threads}",
            kn.name
        );
    }
}

#[test]
fn fuzz_engine_paths_vs_i64_oracle() {
    let backends = kernels::available();
    let seed = base_seed() ^ 0x5EC0_0DD;
    let deadline = Instant::now() + budget();
    let mut cases = 0u64;
    while Instant::now() < deadline {
        fuzz_engine_case(seed.wrapping_add(cases), &backends);
        cases += 1;
    }
    println!(
        "kernel_fuzz engine paths: {cases} cases, seed {seed:#x}"
    );
    assert!(cases > 0);
}

#[test]
fn fuzz_boundary_block_size_saturated() {
    // The exactness cliff edge: bs = I8_EXACT_MAX_BS with every code
    // saturated puts each block dot at 1040 · 127² = 16 774 160, just
    // under 2²⁴ — one more element would break f32 exactness, so any
    // backend widening or correction error shows up here first. Run a
    // small fixed number of cases (the matrices are K = 1040 wide).
    let backends = kernels::available();
    let bs = I8_EXACT_MAX_BS;
    let seed = base_seed() ^ 0xB0_0D;
    for case in 0..3u64 {
        let mut rng = Pcg64::new(seed.wrapping_add(case));
        let (m, n) = (1 + rng.below(4), 1 + rng.below(6));
        let k = bs;
        let a = mat_from_codes(
            m, k, &rand_codes(m * k, Regime::Saturated, &mut rng));
        let b = mat_from_codes(
            k, n, &rand_codes(k * n, Regime::Saturated, &mut rng));
        let qa = block_quant(&a, bs, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, bs, INT8_LEVELS, Rounding::Nearest);
        let c_ref = block_gemm_reference(&qa, &qb);
        for &kn in &backends {
            for threads in [1usize, 3] {
                let c = GemmPlan::new_int8_path(&qa, &qb, threads,
                                                DataPath::Int8)
                    .with_kernels(kn)
                    .execute();
                assert_eq!(
                    c.data, c_ref.data,
                    "backend {} at bs={bs} saturated case={case} \
                     threads={threads}",
                    kn.name
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// INT4 (nibble-packed) fuzzing
// ---------------------------------------------------------------------

/// Nibble codes in [-7, 7] per regime.
fn rand_nibbles(n: usize, regime: Regime, rng: &mut Pcg64) -> Vec<i8> {
    (0..n)
        .map(|_| match regime {
            Regime::Uniform => (rng.below(15) as i32 - 7) as i8,
            Regime::Saturated => {
                if rng.below(2) == 0 { 7 } else { -7 }
            }
            Regime::Sparse => match rng.below(8) {
                0 => 7,
                1 => -7,
                _ => 0,
            },
        })
        .collect()
}

/// Pack per-`(k, j)` codes (`codes[k * width + j]`) into the nibble
/// panel layout the `dot*_i4` kernels read: row stride
/// `width.div_ceil(2)`, low nibble = even column.
fn pack_nibble_panel(codes: &[i8], k_rows: usize,
                     width: usize) -> Vec<u8> {
    let rw = width.div_ceil(2);
    let mut out = vec![0u8; k_rows * rw];
    for k in 0..k_rows {
        for j in 0..width {
            let c = (codes[k * width + j] as u8) & 0x0f;
            let b = &mut out[k * rw + (j >> 1)];
            *b |= if j & 1 == 0 { c } else { c << 4 };
        }
    }
    out
}

/// One random i4 kernel-level case: a nibble-packed panel against
/// **full i8-range** A codes (the staged ladder runs residual codes
/// up to ±127 through the same tiles) on every backend's
/// dot1/dot2/dot4 i4 slots vs the i64 reference over the unpacked
/// codes.
fn fuzz_i4_dot_case(case_seed: u64, backends: &[&'static Kernels]) {
    let mut rng = Pcg64::new(case_seed);
    let bs = [1usize, 2, 3, 4, 5, 7, 8, 12, 13, 16, 17, 31, 37, 61,
              64, 101, 128, 251][rng.below(18)];
    // odd widths matter here: they leave a half-empty tail byte
    let width = 1 + rng.below(bs.min(67));
    let k0 = bs * rng.below(3);
    let a_stride = k0 + bs + rng.below(5);
    let rows = 4;
    let r = rng.below(2);
    let regime = pick_regime(&mut rng);
    let qa = rand_codes((r + rows) * a_stride, regime, &mut rng);
    let codes = rand_nibbles((k0 + bs) * width, regime, &mut rng);
    let panel = pack_nibble_panel(&codes, k0 + bs, width);
    let want = ref_dot(&qa, a_stride, r, k0, bs, &codes, width, rows);

    for &kn in backends {
        for (tile_rows, dot) in
            [(1usize, kn.dot_i4), (2, kn.dot2_i4), (4, kn.dot4_i4)]
        {
            let mut acci = vec![0i32; tile_rows * bs];
            let mut acc = vec![0.0f32; tile_rows * bs];
            dot(&qa, a_stride, r, k0, bs, &panel, width, &mut acci,
                &mut acc);
            for t in 0..tile_rows {
                for j in 0..width {
                    let w = want[t * width + j];
                    assert_eq!(
                        acci[t * bs + j] as i64, w,
                        "backend {} i4 dot{tile_rows} acci \
                         seed={case_seed:#x} bs={bs} width={width} \
                         k0={k0} regime={regime:?} t={t} j={j}",
                        kn.name
                    );
                    assert_eq!(
                        acc[t * bs + j].to_bits(),
                        (w as f32).to_bits(),
                        "backend {} i4 dot{tile_rows} widen \
                         seed={case_seed:#x} bs={bs} width={width} \
                         t={t} j={j}",
                        kn.name
                    );
                }
            }
        }
    }
}

#[test]
fn fuzz_i4_dot_tiles_vs_i64_reference() {
    let backends = kernels::available();
    let seed = base_seed() ^ 0x14_14;
    let deadline = Instant::now() + budget();
    let mut cases = 0u64;
    while Instant::now() < deadline {
        fuzz_i4_dot_case(seed.wrapping_add(cases), &backends);
        cases += 1;
    }
    println!(
        "kernel_fuzz i4 dot tiles: {cases} cases, seed {seed:#x}"
    );
    assert!(cases > 0);
}

/// One random i4 engine-level case: quantized matrices through the
/// `DataPath::Int4` plan and the staged Int4→Int8→f32 ladder on
/// every backend vs the exact i64 nibble references.
fn fuzz_i4_engine_case(case_seed: u64, backends: &[&'static Kernels]) {
    let mut rng = Pcg64::new(case_seed);
    let bs = [3usize, 5, 7, 13, 16, 17, 31][rng.below(7)];
    let dim = |rng: &mut Pcg64, bs: usize| match rng.below(4) {
        0 => [7usize, 13, 23, 41, 53][rng.below(5)],
        1 => bs * (1 + rng.below(3)),
        _ => 1 + rng.below(3 * bs),
    };
    let (m, k, n) = (dim(&mut rng, bs), dim(&mut rng, bs),
                    dim(&mut rng, bs));
    let regime = pick_regime(&mut rng);
    let a = mat_from_codes(m, k,
                           &rand_nibbles(m * k, regime, &mut rng));
    let b = mat_from_codes(k, n,
                           &rand_nibbles(k * n, regime, &mut rng));
    let qa = block_quant(&a, bs, INT4_LEVELS, Rounding::Nearest);
    let qb = block_quant(&b, bs, INT4_LEVELS, Rounding::Nearest);
    let c_ref = int4_gemm_reference(&qa, &qb);
    // all-I4, mixed tiers, all-f32
    let theta = match rng.below(3) {
        0 => f32::INFINITY,
        1 => -1.0,
        _ => 5.0, // nibble-valued data: absmax ≤ 7, so ladder mixes
    };
    let sa = staged_quant(&a, theta, bs);
    let s_ref = staged_gemm_reference(&sa, &qb);
    let threads = 1 + rng.below(4);
    for &kn in backends {
        let c = GemmPlan::new_int8_path(&qa, &qb, threads,
                                        DataPath::Int4)
            .with_kernels(kn)
            .execute();
        assert_eq!(
            c.data, c_ref.data,
            "backend {} int4 vs i64 oracle seed={case_seed:#x} \
             ({m},{k},{n}) bs={bs} regime={regime:?} \
             threads={threads}",
            kn.name
        );
        let s = GemmPlan::new_staged(&sa, &qb, threads)
            .with_kernels(kn)
            .execute();
        assert_eq!(
            s.data, s_ref.data,
            "backend {} staged vs i64 oracle seed={case_seed:#x} \
             ({m},{k},{n}) bs={bs} theta={theta} regime={regime:?} \
             threads={threads}",
            kn.name
        );
    }
}

#[test]
fn fuzz_i4_engine_paths_vs_i64_oracle() {
    let backends = kernels::available();
    let seed = base_seed() ^ 0x57A6_ED;
    let deadline = Instant::now() + budget();
    let mut cases = 0u64;
    while Instant::now() < deadline {
        fuzz_i4_engine_case(seed.wrapping_add(cases), &backends);
        cases += 1;
    }
    println!(
        "kernel_fuzz i4 engine paths: {cases} cases, seed {seed:#x}"
    );
    assert!(cases > 0);
}

#[test]
fn fuzz_i4_boundary_block_size_saturated() {
    // The nibble exactness cliff edge: bs = I4_EXACT_MAX_BS with
    // ±127 A codes (the staged residual worst case) against ±7
    // panel codes puts each block dot at 18 872 · 127 · 7 =
    // 16 777 208, just under 2²⁴ — one more element would break f32
    // exactness in the widen. Kernel-level (a K that wide never
    // appears as an engine block in the suites), few fixed cases.
    let backends = kernels::available();
    let bs = I4_EXACT_MAX_BS;
    let seed = base_seed() ^ 0x14_B0_0D;
    for case in 0..2u64 {
        let mut rng = Pcg64::new(seed.wrapping_add(case));
        let width = 1 + rng.below(6);
        let qa = rand_codes(4 * bs, Regime::Saturated, &mut rng);
        let codes = rand_nibbles(bs * width, Regime::Saturated,
                                 &mut rng);
        let panel = pack_nibble_panel(&codes, bs, width);
        let want = ref_dot(&qa, bs, 0, 0, bs, &codes, width, 4);
        for &kn in &backends {
            let mut acci = vec![0i32; 4 * bs];
            let mut acc = vec![0.0f32; 4 * bs];
            (kn.dot4_i4)(&qa, bs, 0, 0, bs, &panel, width, &mut acci,
                         &mut acc);
            for t in 0..4 {
                for j in 0..width {
                    let w = want[t * width + j];
                    assert_eq!(
                        acci[t * bs + j] as i64, w,
                        "backend {} i4 boundary acci case={case} \
                         t={t} j={j}",
                        kn.name
                    );
                    assert_eq!(
                        acc[t * bs + j].to_bits(),
                        (w as f32).to_bits(),
                        "backend {} i4 boundary widen case={case} \
                         t={t} j={j}",
                        kn.name
                    );
                }
            }
        }
    }
}
