//! Property tests for the plan/execute GEMM engine: across thread
//! counts (1/2/4), **both data paths** (SimF32 f32-code simulation and
//! the true-i8/i32 path), all three `Placement` scenarios, and
//! non-multiple-of-block shapes, the engine must be **bit-identical**
//! to the retained pre-engine baselines (`matmul_baseline`,
//! `block_gemm_baseline`, `fallback_gemm_baseline`) *and* to the
//! exact-i64 reference oracles (`block_gemm_reference`,
//! `fallback_gemm_reference`).
//!
//! Bitwise equality (not approximate) is the contract: for block
//! sizes within `I8_EXACT_MAX_BS` every K-block dot is an integer
//! below 2²⁴, so layout, scheduling, and even integer-vs-float
//! accumulation must not change a single bit.
//!
//! The i8-path assertions additionally run on **every microkernel
//! backend available on the host** (`kernels::available()`, the same
//! set the `PALLAS_KERNEL` override can force), over block sizes that
//! are not multiples of any SIMD width and shapes with odd column
//! tails — so scalar, sse2, avx2, avx512vnni and neon all face the
//! i64 oracles directly. (Longer, hostile-shape sweeps live in the
//! nightly `kernel_fuzz` differential fuzzer.)

use dbfq::gemm::kernels;
use dbfq::gemm::{
    block_gemm, block_gemm_baseline, block_gemm_path,
    block_gemm_reference, fallback_gemm, fallback_gemm_baseline,
    fallback_gemm_path, fallback_gemm_reference, matmul,
    matmul_baseline, remap_placement, DataPath, GemmPlan, Placement,
    Precision,
};
use dbfq::prop_assert;
use dbfq::quant::{block_quant, fallback_quant, theta_for_rate,
                  Criterion, Rounding, INT8_LEVELS};
use dbfq::util::testing::forall;
use dbfq::util::Mat;

const THREADS: [usize; 3] = [1, 2, 4];
const BLOCK: usize = 16;

#[test]
fn prop_dense_engine_bit_identical() {
    forall("engine-dense-vs-baseline", 12, |g| {
        // deliberately awkward shapes (primes, 1-row, tails)
        let m = g.usize_in(1, 40);
        let k = g.usize_in(1, 40);
        let n = g.usize_in(1, 40);
        let a = Mat::from_vec(m, k, g.vec_normal(m * k, 1.0));
        let b = Mat::from_vec(k, n, g.vec_normal(k * n, 1.0));
        for threads in THREADS {
            let c_eng = matmul(&a, &b, threads);
            let c_seed = matmul_baseline(&a, &b, threads);
            prop_assert!(
                c_eng.data == c_seed.data,
                "dense mismatch ({m},{k},{n}) threads={threads}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_int8_engine_bit_identical() {
    forall("engine-int8-vs-baseline", 12, |g| {
        // non-multiple-of-block shapes included (+7 offsets)
        let m = BLOCK * g.usize_in(1, 3) + g.usize_in(0, 7);
        let k = BLOCK * g.usize_in(1, 3) + g.usize_in(0, 7);
        let n = BLOCK * g.usize_in(1, 3) + g.usize_in(0, 7);
        let a =
            Mat::from_vec(m, k, g.vec_outliers(m * k, 1.0, 4, 120.0));
        let b = Mat::from_vec(k, n, g.vec_normal(k * n, 1.0));
        let qa = block_quant(&a, BLOCK, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, BLOCK, INT8_LEVELS, Rounding::Nearest);
        // the exact-i64 oracle anchors both data paths and the seed
        let c_ref = block_gemm_reference(&qa, &qb);
        for threads in THREADS {
            let c_eng = block_gemm(&qa, &qb, threads);
            let c_seed = block_gemm_baseline(&qa, &qb, threads);
            prop_assert!(
                c_eng.data == c_seed.data,
                "int8 mismatch ({m},{k},{n}) threads={threads}"
            );
            for path in [DataPath::SimF32, DataPath::Int8] {
                let c_path = block_gemm_path(&qa, &qb, threads, path);
                prop_assert!(
                    c_path.data == c_ref.data,
                    "int8 {path:?} vs i64 oracle ({m},{k},{n}) \
                     threads={threads}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fallback_engine_bit_identical_all_placements() {
    forall("engine-fallback-vs-baseline", 10, |g| {
        let m = BLOCK * g.usize_in(1, 3) + g.usize_in(0, 7);
        let k = BLOCK * g.usize_in(1, 3) + g.usize_in(0, 7);
        let n = BLOCK * g.usize_in(1, 3) + g.usize_in(0, 7);
        let a =
            Mat::from_vec(m, k, g.vec_outliers(m * k, 1.0, 6, 150.0));
        let b = Mat::from_vec(k, n, g.vec_normal(k * n, 1.0));
        let probe = fallback_quant(&a, f32::INFINITY, BLOCK,
                                   INT8_LEVELS, Criterion::AbsMax);
        // a mid-range rate so all placements differ meaningfully
        let theta = theta_for_rate(&probe.metric, 0.3);
        let fa = fallback_quant(&a, theta, BLOCK, INT8_LEVELS,
                                Criterion::AbsMax);
        let qb = block_quant(&b, BLOCK, INT8_LEVELS, Rounding::Nearest);
        for placement in [Placement::Natural, Placement::Random(11),
                          Placement::Sequential] {
            let u = remap_placement(&fa, placement);
            let c_ref = fallback_gemm_reference(&fa, &qb, &u);
            for threads in THREADS {
                let c_eng = fallback_gemm(&fa, &qb, &u, threads);
                let c_seed =
                    fallback_gemm_baseline(&fa, &qb, &u, threads);
                prop_assert!(
                    c_eng.data == c_seed.data,
                    "fallback mismatch ({m},{k},{n}) \
                     threads={threads} placement={placement:?}"
                );
                for path in [DataPath::SimF32, DataPath::Int8] {
                    let c_path =
                        fallback_gemm_path(&fa, &qb, &u, threads, path);
                    prop_assert!(
                        c_path.data == c_ref.data,
                        "fallback {path:?} vs i64 oracle ({m},{k},{n}) \
                         threads={threads} placement={placement:?}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_int8_all_backends_bit_identical() {
    // Backend sweep against the exact i64 oracle: block sizes chosen
    // to be indivisible by every vector width in the tree (8 for
    // sse2/neon, 16 for avx2) so the SIMD j-tails and odd K-pairs are
    // always exercised, plus shape offsets for odd output tails.
    let backends = kernels::available();
    forall("engine-int8-backends-vs-oracle", 10, |g| {
        let bs = [12usize, 20, 24, 17][g.usize_in(0, 3)];
        let m = bs * g.usize_in(1, 2) + g.usize_in(0, 7);
        let k = bs * g.usize_in(1, 2) + g.usize_in(0, 7);
        let n = bs * g.usize_in(1, 2) + g.usize_in(0, 7);
        let a =
            Mat::from_vec(m, k, g.vec_outliers(m * k, 1.0, 4, 120.0));
        let b = Mat::from_vec(k, n, g.vec_normal(k * n, 1.0));
        let qa = block_quant(&a, bs, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, bs, INT8_LEVELS, Rounding::Nearest);
        let c_ref = block_gemm_reference(&qa, &qb);
        for &kn in &backends {
            for threads in [1usize, 3] {
                let c = GemmPlan::new_int8_path(&qa, &qb, threads,
                                                DataPath::Int8)
                    .with_kernels(kn)
                    .execute();
                prop_assert!(
                    c.data == c_ref.data,
                    "backend {} vs i64 oracle ({m},{k},{n}) bs={bs} \
                     threads={threads}",
                    kn.name
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fallback_all_backends_bit_identical() {
    // The residual (Algorithm 1) path rides the same backend kernels;
    // sweep it too, across placements, against the i64 oracle.
    let backends = kernels::available();
    forall("engine-fallback-backends-vs-oracle", 6, |g| {
        let bs = [12usize, 20, 24][g.usize_in(0, 2)];
        let m = bs * g.usize_in(1, 2) + g.usize_in(0, 7);
        let k = bs * g.usize_in(1, 2) + g.usize_in(0, 7);
        let n = bs * g.usize_in(1, 2) + g.usize_in(0, 7);
        let a =
            Mat::from_vec(m, k, g.vec_outliers(m * k, 1.0, 6, 150.0));
        let b = Mat::from_vec(k, n, g.vec_normal(k * n, 1.0));
        let probe = fallback_quant(&a, f32::INFINITY, bs, INT8_LEVELS,
                                   Criterion::AbsMax);
        let theta = theta_for_rate(&probe.metric, 0.3);
        let fa = fallback_quant(&a, theta, bs, INT8_LEVELS,
                                Criterion::AbsMax);
        let qb = block_quant(&b, bs, INT8_LEVELS, Rounding::Nearest);
        for placement in [Placement::Natural, Placement::Sequential] {
            let u = remap_placement(&fa, placement);
            let c_ref = fallback_gemm_reference(&fa, &qb, &u);
            for &kn in &backends {
                let c = GemmPlan::new_fallback_path(&fa, &qb, &u, 2,
                                                    DataPath::Int8)
                    .with_kernels(kn)
                    .execute();
                prop_assert!(
                    c.data == c_ref.data,
                    "backend {} fallback vs i64 oracle ({m},{k},{n}) \
                     bs={bs} placement={placement:?}",
                    kn.name
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_plan_reuse_matches_fresh_plans() {
    // A plan executed twice and two plans over the same (cached)
    // operands must agree bitwise — the packed-view caches on the
    // quant structs must not change results.
    forall("engine-plan-reuse", 8, |g| {
        let m = BLOCK * g.usize_in(1, 2) + g.usize_in(0, 7);
        let k = BLOCK * g.usize_in(1, 2);
        let n = BLOCK * g.usize_in(1, 2) + g.usize_in(0, 7);
        let a =
            Mat::from_vec(m, k, g.vec_outliers(m * k, 1.0, 3, 100.0));
        let b = Mat::from_vec(k, n, g.vec_normal(k * n, 1.0));
        let qa = block_quant(&a, BLOCK, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, BLOCK, INT8_LEVELS, Rounding::Nearest);
        let plan = GemmPlan::new_int8(&qa, &qb, 2);
        prop_assert!(plan.precision() == Precision::Int8Block,
                     "precision");
        let c1 = plan.execute();
        let c2 = plan.execute();
        let c3 = GemmPlan::new_int8(&qa, &qb, 3).execute();
        prop_assert!(c1.data == c2.data, "re-execute differs");
        prop_assert!(c1.data == c3.data, "fresh plan differs");
        Ok(())
    });
}
