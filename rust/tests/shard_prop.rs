//! Cross-layer properties of sharded GEMM execution (`PALLAS_SHARDS`,
//! `WeightPlan::with_shards`, per-shard LPT scheduling).
//!
//! The contract under test: sharding is **bit-neutral**. Splitting a
//! plan's column panels into S contiguous shards — each with its own
//! LPT bucket schedule and worker-affinity hints — must produce output
//! bitwise identical to the flat S=1 engine for every
//! S × backend × thread-count × data-path combination, at every layer
//! of the stack (direct engine plans, `LayerStep`, `ModelStep`), and
//! across a warm-state save/restore at S>1. The sharded paths also
//! stay pinned to the exact i64 oracles where those already apply.
//! The deterministic fixed-shape widening reduction
//! (`kernels::widen_reduce_i32`, the hook future K-splits will sum
//! partials through) is checked against exact i64 accumulation.

use dbfq::gemm::{block_gemm_reference, fallback_gemm_reference,
                 kernels, synth_microbatch, DataPath, GemmPlan,
                 LayerStep, LayerStepConfig, ModelStep,
                 ModelStepConfig, WeightPlan};
use dbfq::quant::{block_quant, fallback_quant, Criterion, Rounding,
                  INT8_LEVELS};
use dbfq::util::rng::Pcg64;
use dbfq::util::Mat;

const BLOCK: usize = 16;
const THREADS: [usize; 3] = [1, 2, 4];
const SHARDS: [usize; 4] = [1, 2, 3, 4];

/// Outlier-bearing operands: `a` carries planted spikes so the
/// fallback plan has residual blocks to schedule, and the panel
/// count (40 cols / 16 block = 3 panels) exercises uneven shard
/// splits at S ∈ {2, 3} and clamping at S = 4.
fn operands(seed: u64) -> (Mat, Mat) {
    let mut rng = Pcg64::new(seed);
    let mut a = Mat::randn(48, 33, 1.0, &mut rng);
    for i in 0..10 {
        let n = a.data.len();
        a.data[i * 131 % n] = 260.0;
    }
    let b = Mat::randn(33, 40, 1.0, &mut rng);
    (a, b)
}

#[test]
fn sharded_engine_matches_flat_and_exact_oracles() {
    let (a, b) = operands(0x5A4D);
    let qa = block_quant(&a, BLOCK, INT8_LEVELS, Rounding::Nearest);
    let qb = block_quant(&b, BLOCK, INT8_LEVELS, Rounding::Nearest);
    let fa = fallback_quant(&a, 40.0, BLOCK, INT8_LEVELS,
                            Criterion::AbsMax);
    assert!(fa.fallback_rate() > 0.0, "outliers must trigger fallback");
    // exact i64 oracles (bs = 16 ≤ I8_EXACT_MAX_BS)
    let exact_i8 = block_gemm_reference(&qa, &qb);
    let exact_fb = fallback_gemm_reference(&fa, &qb, &fa.u);
    // flat engine reference: one thread, one shard
    let flat_i8 = GemmPlan::new_int8_path(&qa, &qb, 1, DataPath::Int8)
        .with_shards(1)
        .execute();
    let flat_fb = GemmPlan::new_fallback_path(
        &fa, &qb, &fa.u, 1, DataPath::Int8)
        .with_shards(1)
        .execute();
    assert_eq!(flat_i8.data, exact_i8.data, "flat int8 vs i64 oracle");
    assert_eq!(flat_fb.data, exact_fb.data,
               "flat fallback vs i64 oracle");
    for kn in kernels::available() {
        for path in [DataPath::Int8, DataPath::SimF32] {
            for threads in THREADS {
                for shards in SHARDS {
                    let ci = GemmPlan::new_int8_path(
                        &qa, &qb, threads, path)
                        .with_kernels(kn)
                        .with_shards(shards)
                        .execute();
                    let cf = GemmPlan::new_fallback_path(
                        &fa, &qb, &fa.u, threads, path)
                        .with_kernels(kn)
                        .with_shards(shards)
                        .execute();
                    let tag = format!(
                        "backend {} path {} threads {threads} \
                         shards {shards}",
                        kn.name, path.tag());
                    assert_eq!(ci.data, flat_i8.data, "int8 {tag}");
                    assert_eq!(cf.data, flat_fb.data,
                               "fallback {tag}");
                }
            }
        }
    }
}

#[test]
fn sharded_weight_plans_match_flat_at_engine_level() {
    // The cached-weight entry point: sharding configured on the
    // WeightPlan must flow into every derived GemmPlan and stay
    // bit-neutral on both the int8 and fallback halves.
    let (a, b) = operands(0x77E1);
    let qa = block_quant(&a, BLOCK, INT8_LEVELS, Rounding::Nearest);
    let qb = std::sync::Arc::new(
        block_quant(&b, BLOCK, INT8_LEVELS, Rounding::Nearest));
    let fa = fallback_quant(&a, 40.0, BLOCK, INT8_LEVELS,
                            Criterion::AbsMax);
    for kn in kernels::available() {
        for path in [DataPath::Int8, DataPath::SimF32] {
            let wp_flat = WeightPlan::new(qb.clone(), path)
                .with_kernels(kn)
                .with_shards(1);
            let ref_i8 = wp_flat.plan_int8(&qa, 1).execute();
            let ref_fb =
                wp_flat.plan_fallback(&fa, &fa.u, 1).execute();
            for threads in THREADS {
                for shards in SHARDS {
                    let wp = WeightPlan::new(qb.clone(), path)
                        .with_kernels(kn)
                        .with_shards(shards);
                    assert_eq!(wp.shard_count(), shards);
                    let ci = wp.plan_int8(&qa, threads).execute();
                    let cf = wp.plan_fallback(&fa, &fa.u, threads)
                        .execute();
                    let tag = format!(
                        "backend {} path {} threads {threads} \
                         shards {shards}",
                        kn.name, path.tag());
                    assert_eq!(ci.data, ref_i8.data, "int8 {tag}");
                    assert_eq!(cf.data, ref_fb.data,
                               "fallback {tag}");
                }
            }
        }
    }
}

#[test]
fn sharded_layer_step_matches_flat() {
    for kn in kernels::available() {
        for path in [DataPath::Int8, DataPath::SimF32] {
            // flat reference driver: threads 1, shards 1
            let mut cfg = LayerStepConfig::new(16, 32, 16, BLOCK);
            cfg.glu = false;
            cfg.threads = 1;
            cfg.shards = 1;
            cfg.path = path;
            let mut rf = LayerStep::with_random_weights(cfg, 0x1A7)
                .with_kernels(kn);
            let (acts, grads) = synth_microbatch(rf.sites(), 19,
                                                 180.0);
            let (ref_outs, _) = rf.microstep(&acts, &grads);
            for threads in THREADS {
                for shards in SHARDS {
                    let mut cfg =
                        LayerStepConfig::new(16, 32, 16, BLOCK);
                    cfg.glu = false;
                    cfg.threads = threads;
                    cfg.shards = shards;
                    cfg.path = path;
                    let mut ls =
                        LayerStep::with_random_weights(cfg, 0x1A7)
                            .with_kernels(kn);
                    let (outs, _) = ls.microstep(&acts, &grads);
                    for (s, (x, y)) in
                        outs.iter().zip(&ref_outs).enumerate()
                    {
                        let tag = format!(
                            "site {s} backend {} path {} threads \
                             {threads} shards {shards}",
                            kn.name, path.tag());
                        assert_eq!(x.y.data, y.y.data, "y {tag}");
                        assert_eq!(x.dx.data, y.dx.data, "dx {tag}");
                        assert_eq!(x.dw.data, y.dw.data, "dw {tag}");
                    }
                }
            }
        }
    }
}

#[test]
fn sharded_model_step_matches_flat() {
    for kn in kernels::available() {
        for path in [DataPath::Int8, DataPath::SimF32] {
            let mut cfg = ModelStepConfig::new(1, 16, 32, 40, 16,
                                               BLOCK);
            cfg.glu = false;
            cfg.threads = 1;
            cfg.shards = 1;
            cfg.path = path;
            let mut rf = ModelStep::with_random_weights(cfg, 0x99)
                .with_kernels(kn);
            let (acts, grads) = synth_microbatch(rf.sites(), 17,
                                                 180.0);
            // two microsteps: cold build + warm cache-hit path
            let mut ref_outs = Vec::new();
            for _ in 0..2 {
                let (o, _) = rf.microstep(&acts, &grads);
                ref_outs.push(o);
            }
            for threads in THREADS {
                for shards in SHARDS {
                    let mut cfg = ModelStepConfig::new(1, 16, 32, 40,
                                                       16, BLOCK);
                    cfg.glu = false;
                    cfg.threads = threads;
                    cfg.shards = shards;
                    cfg.path = path;
                    let mut ms =
                        ModelStep::with_random_weights(cfg, 0x99)
                            .with_kernels(kn);
                    for (t, refs) in ref_outs.iter().enumerate() {
                        let (outs, _) = ms.microstep(&acts, &grads);
                        for (s, (x, y)) in
                            outs.iter().zip(refs).enumerate()
                        {
                            let tag = format!(
                                "site {s} microstep {t} backend {} \
                                 path {} threads {threads} shards \
                                 {shards}",
                                kn.name, path.tag());
                            assert_eq!(x.y.data, y.y.data,
                                       "y {tag}");
                            assert_eq!(x.dx.data, y.dx.data,
                                       "dx {tag}");
                            assert_eq!(x.dw.data, y.dw.data,
                                       "dw {tag}");
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn warm_state_round_trips_at_multiple_shards() {
    // Save under S=2, restore under S=2: the restored process's next
    // microstep must hit on every lookup and reproduce the exact
    // bits the saved process would have produced. A restore under a
    // different S must fail loudly (not silently mis-shard).
    let mut cfg = ModelStepConfig::new(1, 16, 32, 40, 16, BLOCK);
    cfg.glu = false;
    cfg.threads = 2;
    cfg.shards = 2;
    let shapes = ModelStep::with_random_weights(cfg.clone(), 0xD0);
    let weights: Vec<Mat> = shapes
        .sites()
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let mut rng = Pcg64::new(0xD0 ^ (i as u64) << 17);
            Mat::randn(l.k, l.n, 0.05, &mut rng)
        })
        .collect();
    // the randn driver only supplied the site shapes; drive a step
    // from the known weights
    let mut ms = ModelStep::new(cfg.clone(), weights.clone());
    let (acts, grads) = synth_microbatch(ms.sites(), 13, 180.0);
    ms.microstep(&acts, &grads);
    let state = ms.warm_state(None);
    let (mut restored, _) =
        ModelStep::from_warm_state(cfg.clone(), weights.clone(),
                                   &state)
            .expect("same-shard restore must succeed");
    assert_eq!(restored.microsteps(), 1);
    let (cont, rep_c) = ms.microstep(&acts, &grads);
    let (rest, rep_r) = restored.microstep(&acts, &grads);
    assert_eq!(rep_r.cache_misses, 0,
               "restored process must start at steady state");
    assert_eq!(rep_c.cache_misses, 0);
    for (s, (x, y)) in cont.iter().zip(&rest).enumerate() {
        assert_eq!(x.y.data, y.y.data, "y site {s}");
        assert_eq!(x.dx.data, y.dx.data, "dx site {s}");
        assert_eq!(x.dw.data, y.dw.data, "dw site {s}");
    }
    // and the restored bits equal the flat S=1 engine's
    let mut flat_cfg = cfg.clone();
    flat_cfg.shards = 1;
    flat_cfg.threads = 1;
    let mut flat = ModelStep::new(flat_cfg, weights.clone());
    flat.microstep(&acts, &grads);
    let (flat_outs, _) = flat.microstep(&acts, &grads);
    for (s, (x, y)) in rest.iter().zip(&flat_outs).enumerate() {
        assert_eq!(x.y.data, y.y.data, "restored vs flat y site {s}");
    }
    // shard-count mismatch: loud error mentioning the shard config
    let mut other = cfg.clone();
    other.shards = 3;
    let err =
        ModelStep::from_warm_state(other, weights, &state)
            .unwrap_err();
    assert!(err.contains("shard"), "{err}");
}

#[test]
fn widen_reduce_is_exact_and_shape_deterministic() {
    // The deterministic widening reduction: bit-identical to exact
    // i64 accumulation (within the f32-exact range) regardless of
    // how many partials feed it, and a single partial reduces to the
    // plain widen of that partial.
    let mut rng = Pcg64::new(0x5EED);
    let width = 37usize;
    let stride = 40usize; // padded rows, like real accumulators
    let parts: Vec<Vec<i32>> = (0..5)
        .map(|_| {
            (0..stride)
                .map(|_| (rng.next_u64() % 20001) as i32 - 10000)
                .collect()
        })
        .collect();
    let views: Vec<&[i32]> =
        parts.iter().map(|p| p.as_slice()).collect();
    let mut acc = vec![0.0f32; stride];
    kernels::widen_reduce_i32(&views, &mut acc, width);
    for j in 0..width {
        let exact: i64 =
            parts.iter().map(|p| p[j] as i64).sum();
        assert_eq!(acc[j].to_bits(), (exact as f32).to_bits(),
                   "lane {j}");
    }
    // lanes past `width` untouched
    for (j, &v) in acc.iter().enumerate().skip(width) {
        assert_eq!(v, 0.0, "lane {j} must be untouched");
    }
    // one partial == plain widen
    let mut one = vec![0.0f32; stride];
    kernels::widen_reduce_i32(&views[..1], &mut one, width);
    for j in 0..width {
        assert_eq!(one[j].to_bits(),
                   (parts[0][j] as f32).to_bits(),
                   "single-partial lane {j}");
    }
    // every prefix count produces the same bits as exact i64 —
    // the tree shape is fixed by the partial count alone, so any
    // future K-split fan-in stays deterministic
    for n in 2..=5usize {
        let mut accn = vec![0.0f32; stride];
        kernels::widen_reduce_i32(&views[..n], &mut accn, width);
        for j in 0..width {
            let exact: i64 =
                parts[..n].iter().map(|p| p[j] as i64).sum();
            assert_eq!(accn[j].to_bits(),
                       (exact as f32).to_bits(),
                       "n {n} lane {j}");
        }
    }
}

#[test]
fn widen_simd_toggle_is_bit_neutral() {
    // The vectorized widen vtable slot must produce the scalar
    // floor's exact bits through a real sharded plan on every
    // backend (release builds take the SIMD path; debug builds route
    // to scalar either way — same bits by construction).
    let (a, b) = operands(0xF00D);
    let qa = block_quant(&a, BLOCK, INT8_LEVELS, Rounding::Nearest);
    let qb = block_quant(&b, BLOCK, INT8_LEVELS, Rounding::Nearest);
    let prev = kernels::widen_simd_enabled();
    for kn in kernels::available() {
        for shards in [1usize, 2] {
            let plan = GemmPlan::new_int8_path(&qa, &qb, 2,
                                               DataPath::Int8)
                .with_kernels(kn)
                .with_shards(shards);
            kernels::set_widen_simd_enabled(true);
            let on = plan.execute();
            kernels::set_widen_simd_enabled(false);
            let off = plan.execute();
            kernels::set_widen_simd_enabled(prev);
            assert_eq!(on.data, off.data,
                       "widen SIMD toggle backend {} shards {shards}",
                       kn.name);
        }
    }
}
