//! Cross-layer properties of the persistent worker-pool runtime
//! (`util::pool`).
//!
//! The contract under test: pooled dispatch must be **bit-identical**
//! to the scoped-thread fallback at every layer — direct engine
//! plans, the pipeline site runner, and the multi-layer `ModelStep`
//! driver — for every microkernel backend available on the host,
//! both data paths, and 1/2/4 threads. The runtime must also survive
//! nested submits (engine calls issued from inside pool workers run
//! inline instead of deadlocking) and oversubscription (more
//! concurrent plans than workers), and a warm `ModelStep` microstep
//! must be allocation-free: zero thread spawns and zero engine
//! workspace/output growths, observed through
//! `util::pool::work_counters`.
//!
//! Tests that flip the process-global pool flag serialize on one
//! mutex (and restore the previous value on drop), so `cargo test`'s
//! concurrent test threads never observe a half-toggled runtime.

use std::sync::{Mutex, MutexGuard, OnceLock};

use dbfq::gemm::{kernels, site_reference, synth_microbatch,
                 DataPath, GemmPlan, ModelStep, ModelStepConfig};
use dbfq::model::layer_linears;
use dbfq::quant::{block_quant, fallback_quant, Criterion, Rounding,
                  INT8_LEVELS};
use dbfq::util::pool;
use dbfq::util::rng::Pcg64;
use dbfq::util::threadpool::parallel_map;
use dbfq::util::Mat;

const BLOCK: usize = 16;
const THREADS: [usize; 3] = [1, 2, 4];

/// Serializes every test that reads-and-toggles the process-global
/// pool flag; restores the entry value on drop (also on panic).
struct PoolFlagGuard {
    _lock: MutexGuard<'static, ()>,
    prev: bool,
}

impl PoolFlagGuard {
    fn hold() -> PoolFlagGuard {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        let lock = LOCK
            .get_or_init(|| Mutex::new(()))
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        PoolFlagGuard { _lock: lock, prev: pool::pool_enabled() }
    }
}

impl Drop for PoolFlagGuard {
    fn drop(&mut self) {
        pool::set_pool_enabled(self.prev);
    }
}

/// Outlier-bearing operands: `a` carries planted spikes so the
/// fallback plan really has residual blocks to schedule.
fn operands(seed: u64) -> (Mat, Mat) {
    let mut rng = Pcg64::new(seed);
    let mut a = Mat::randn(48, 33, 1.0, &mut rng);
    for i in 0..10 {
        let n = a.data.len();
        a.data[i * 131 % n] = 260.0;
    }
    let b = Mat::randn(33, 40, 1.0, &mut rng);
    (a, b)
}

#[test]
fn pool_vs_scoped_bit_identity_engine() {
    let _guard = PoolFlagGuard::hold();
    let (a, b) = operands(0xB00);
    let qa = block_quant(&a, BLOCK, INT8_LEVELS, Rounding::Nearest);
    let qb = block_quant(&b, BLOCK, INT8_LEVELS, Rounding::Nearest);
    let fa = fallback_quant(&a, 40.0, BLOCK, INT8_LEVELS,
                            Criterion::AbsMax);
    assert!(fa.fallback_rate() > 0.0, "outliers must trigger fallback");
    for kn in kernels::available() {
        for path in [DataPath::Int8, DataPath::SimF32] {
            for threads in THREADS {
                let int8 =
                    GemmPlan::new_int8_path(&qa, &qb, threads, path)
                        .with_kernels(kn);
                let fb = GemmPlan::new_fallback_path(
                    &fa, &qb, &fa.u, threads, path)
                    .with_kernels(kn);
                pool::set_pool_enabled(true);
                let ci_pool = int8.execute();
                let cf_pool = fb.execute();
                pool::set_pool_enabled(false);
                let ci_scope = int8.execute();
                let cf_scope = fb.execute();
                let tag = format!("backend {} path {} threads \
                                   {threads}",
                                  kn.name, path.tag());
                assert_eq!(ci_pool.data, ci_scope.data, "int8 {tag}");
                assert_eq!(cf_pool.data, cf_scope.data,
                           "fallback {tag}");
            }
        }
    }
}

#[test]
fn pool_vs_scoped_bit_identity_site() {
    let _guard = PoolFlagGuard::hold();
    let sites = layer_linears(16, 32, false, 16);
    let l = &sites[0];
    let mut rng = Pcg64::new(0x517E);
    let w = Mat::randn(l.k, l.n, 0.05, &mut rng);
    let (acts, grads) = synth_microbatch(&sites[..1], 23, 180.0);
    let sr = Rounding::Stochastic(0xDECAF);
    for kn in kernels::available() {
        for path in [DataPath::Int8, DataPath::SimF32] {
            for threads in THREADS {
                pool::set_pool_enabled(true);
                let on = site_reference(
                    l, &w, &acts[0], &grads[0], 8.0, sr, BLOCK,
                    threads, path, kn,
                );
                pool::set_pool_enabled(false);
                let off = site_reference(
                    l, &w, &acts[0], &grads[0], 8.0, sr, BLOCK,
                    threads, path, kn,
                );
                let tag = format!("backend {} path {} threads \
                                   {threads}",
                                  kn.name, path.tag());
                assert_eq!(on.y.data, off.y.data, "y {tag}");
                assert_eq!(on.dx.data, off.dx.data, "dx {tag}");
                assert_eq!(on.dw.data, off.dw.data, "dw {tag}");
            }
        }
    }
}

#[test]
fn pool_vs_scoped_bit_identity_model_step() {
    let _guard = PoolFlagGuard::hold();
    for kn in kernels::available() {
        for path in [DataPath::Int8, DataPath::SimF32] {
            for threads in THREADS {
                let mut cfg =
                    ModelStepConfig::new(1, 16, 32, 40, 16, BLOCK);
                cfg.glu = false;
                cfg.threads = threads;
                cfg.path = path;
                let mut on =
                    ModelStep::with_random_weights(cfg.clone(), 0x99)
                        .with_kernels(kn);
                let mut off =
                    ModelStep::with_random_weights(cfg, 0x99)
                        .with_kernels(kn);
                let (acts, grads) =
                    synth_microbatch(on.sites(), 17, 180.0);
                for t in 0..2usize {
                    pool::set_pool_enabled(true);
                    let (mo, _) = on.microstep(&acts, &grads);
                    pool::set_pool_enabled(false);
                    let (so, _) = off.microstep(&acts, &grads);
                    for (s, (x, y)) in
                        mo.iter().zip(&so).enumerate()
                    {
                        let tag = format!(
                            "site {s} microstep {t} backend {} path \
                             {} threads {threads}",
                            kn.name,
                            path.tag()
                        );
                        assert_eq!(x.y.data, y.y.data, "y {tag}");
                        assert_eq!(x.dx.data, y.dx.data, "dx {tag}");
                        assert_eq!(x.dw.data, y.dw.data, "dw {tag}");
                    }
                }
            }
        }
    }
}

#[test]
fn nested_engine_calls_inside_pool_jobs_run_inline() {
    // Plans executed from inside pool workers (nested submits) must
    // run inline — no deadlock even when every worker is busy — and
    // still produce the canonical bits.
    let (a, b) = operands(0x4E57);
    let qa = block_quant(&a, BLOCK, INT8_LEVELS, Rounding::Nearest);
    let qb = block_quant(&b, BLOCK, INT8_LEVELS, Rounding::Nearest);
    let reference = GemmPlan::new_int8(&qa, &qb, 1).execute();
    let plan = GemmPlan::new_int8(&qa, &qb, 4);
    let outs: Vec<Vec<f32>> =
        parallel_map(8, 8, |_| plan.execute().data);
    for (i, o) in outs.iter().enumerate() {
        assert_eq!(o, &reference.data, "nested execute {i}");
    }
}

#[test]
fn oversubscription_smoke() {
    // More concurrent submitters than pool workers: eight OS threads
    // each repeatedly execute a 4-way plan against the one global
    // pool. Everything must complete (queueing, no lost jobs) with
    // the canonical bits.
    let (a, b) = operands(0x0BE5);
    let qa = block_quant(&a, BLOCK, INT8_LEVELS, Rounding::Nearest);
    let qb = block_quant(&b, BLOCK, INT8_LEVELS, Rounding::Nearest);
    let reference = GemmPlan::new_int8(&qa, &qb, 1).execute();
    std::thread::scope(|s| {
        for _ in 0..8 {
            s.spawn(|| {
                for _ in 0..4 {
                    let plan = GemmPlan::new_int8(&qa, &qb, 4);
                    assert_eq!(plan.execute().data, reference.data);
                }
            });
        }
    });
}

#[test]
fn model_step_steady_state_is_allocation_free() {
    let _guard = PoolFlagGuard::hold();
    if !pool::pool_enabled() {
        // PALLAS_POOL=off leg: scoped dispatch legitimately spawns
        // per call — the zero-alloc guarantee is pool-only.
        return;
    }
    let mut cfg = ModelStepConfig::new(1, 16, 32, 40, 16, BLOCK);
    cfg.glu = false;
    let mut ms = ModelStep::with_random_weights(cfg, 0xAB);
    let (acts, grads) = synth_microbatch(ms.sites(), 11, 180.0);
    // Warm until quiescent: the pool's task→worker assignment is
    // nondeterministic, so a worker may meet its first i8 panel (and
    // grow its thread-local workspace) several microsteps in.
    let mut quiet = false;
    for _ in 0..12 {
        let (s0, w0) = pool::work_counters();
        ms.microstep_in_place(&acts, &grads);
        let (s1, w1) = pool::work_counters();
        if s1 == s0 && w1 == w0 {
            quiet = true;
            break;
        }
    }
    assert!(quiet, "never reached the allocation-free steady state");
    for step in 0..2 {
        let (s0, w0) = pool::work_counters();
        let rep = ms.microstep_in_place(&acts, &grads);
        let (s1, w1) = pool::work_counters();
        assert_eq!(rep.cache_misses, 0,
                   "steady-state microstep must hit (step {step})");
        assert_eq!(s1 - s0, 0,
                   "steady-state thread spawns (step {step})");
        assert_eq!(w1 - w0, 0,
                   "steady-state workspace/output allocs \
                    (step {step})");
    }
}
