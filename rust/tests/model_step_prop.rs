//! Property tests for the multi-layer `ModelStep` driver
//! (`gemm::pipeline`).
//!
//! Two contracts under test:
//!
//! * **Composition**: a `ModelStep` over N layers + LM head sharing
//!   one `PlanCache` must be *bit-identical* to N standalone
//!   `LayerStep`s (built from `ModelStepConfig::layer_config`, which
//!   namespaces the gradient SR streams per layer) plus a direct
//!   engine computation of the head — on every microkernel backend
//!   available on the host and across thread counts. Site
//!   namespacing, the shared cache, and the per-site θ routing must
//!   not change a single bit.
//! * **Warm state**: serialize → restore must put a fresh process at
//!   steady state — every lookup of its *first* microstep hits, the
//!   restored θ vector and microstep counter match the saved
//!   process, and the next microstep's outputs are bit-identical to
//!   the ones the saved process produces.

use dbfq::costmodel::SubstrateCalibration;
use dbfq::gemm::{grad_sr_seed, kernels, layer_sr_seed,
                 site_reference, synth_microbatch, LayerStep,
                 ModelStep, ModelStepConfig};
use dbfq::model::model_linears;
use dbfq::quant::Rounding;
use dbfq::util::json::Json;
use dbfq::util::rng::Pcg64;
use dbfq::util::Mat;

/// 2 layers + head, vocab distinct from every layer dimension so the
/// head is a genuinely different shape in the shared cache.
fn model_cfg(threads: usize) -> ModelStepConfig {
    let mut cfg = ModelStepConfig::new(2, 16, 32, 56, 16, 16);
    cfg.glu = false;
    cfg.threads = threads;
    cfg
}

fn site_weights(cfg: &ModelStepConfig, seed: u64) -> Vec<Mat> {
    let sites = model_linears(cfg.layers, cfg.d_model, cfg.d_ff,
                              cfg.glu, cfg.vocab, cfg.tokens);
    let mut rng = Pcg64::new(seed);
    sites
        .iter()
        .map(|l| Mat::randn(l.k, l.n, 0.05, &mut rng))
        .collect()
}

#[test]
fn model_step_bit_identical_to_composed_layer_steps_per_backend() {
    for kn in kernels::available() {
        for threads in [1usize, 2, 4] {
            let cfg = model_cfg(threads);
            let n_sites = cfg.n_sites();
            let weights = site_weights(&cfg, 0x77);
            let mut ms = ModelStep::new(cfg.clone(), weights.clone())
                .with_kernels(kn);
            // distinct θ per site so any site conflation would be
            // visible in the fallback masks
            let thetas: Vec<f32> =
                (0..n_sites).map(|s| 4.0 + s as f32).collect();
            ms.controller_mut()
                .thresholds
                .copy_from_slice(&thetas);
            let (acts, grads) =
                synth_microbatch(ms.sites(), 21, 180.0);
            let mut layer_steps: Vec<LayerStep> = (0..cfg.layers)
                .map(|l| {
                    let mut ls = LayerStep::new(
                        cfg.layer_config(l),
                        weights[4 * l..4 * l + 4].to_vec(),
                    )
                    .with_kernels(kn);
                    ls.controller_mut()
                        .thresholds
                        .copy_from_slice(&thetas[4 * l..4 * l + 4]);
                    ls
                })
                .collect();
            for t in 0..2usize {
                let (mo, rep) = ms.microstep(&acts, &grads);
                if t == 0 {
                    assert_eq!(rep.cache_misses as usize,
                               2 * n_sites);
                } else {
                    assert_eq!(rep.cache_misses, 0,
                               "warm model microstep must hit \
                                (backend {}, {threads} threads)",
                               kn.name);
                    assert_eq!(rep.cache_hits as usize, 2 * n_sites);
                }
                // layers vs standalone LayerSteps
                for (l, ls) in layer_steps.iter_mut().enumerate() {
                    let (lo, _) = ls.microstep(
                        &acts[4 * l..4 * l + 4],
                        &grads[4 * l..4 * l + 4],
                    );
                    for (i, b) in lo.iter().enumerate() {
                        let a = &mo[4 * l + i];
                        let tag = format!(
                            "layer {l} site {i} microstep {t} \
                             backend {} threads {threads}",
                            kn.name
                        );
                        assert_eq!(a.y.data, b.y.data, "y {tag}");
                        assert_eq!(a.dx.data, b.dx.data, "dx {tag}");
                        assert_eq!(a.dw.data, b.dw.data, "dw {tag}");
                    }
                }
                // LM head vs the shared cache-free site reference
                // (its SR stream is "layer `layers`", site 0; the
                // math's independence is pinned by the direct-engine
                // and i64-oracle tests in the crate)
                let h = n_sites - 1;
                let sr = Rounding::Stochastic(grad_sr_seed(
                    layer_sr_seed(cfg.sr_seed, cfg.layers), t, 0));
                let ho = site_reference(
                    &ms.sites()[h], &weights[h], &acts[h],
                    &grads[h], thetas[h], sr, cfg.block, threads,
                    cfg.path, kn,
                );
                let tag = format!(
                    "lm_head microstep {t} backend {} threads \
                     {threads}",
                    kn.name
                );
                assert_eq!(mo[h].y.data, ho.y.data, "y {tag}");
                assert_eq!(mo[h].dx.data, ho.dx.data, "dx {tag}");
                assert_eq!(mo[h].dw.data, ho.dw.data, "dw {tag}");
            }
        }
    }
}

#[test]
fn warm_state_restore_reaches_steady_state_on_first_microstep() {
    let cfg = model_cfg(2);
    let n_sites = cfg.n_sites();
    let weights = site_weights(&cfg, 0x99);
    let mut ms = ModelStep::new(cfg.clone(), weights.clone());
    let (acts, grads) = synth_microbatch(ms.sites(), 31, 180.0);
    // run one step so the warm state carries *adapted* θ, a non-zero
    // microstep counter, and a fully resident cache
    ms.microstep(&acts, &grads);
    let applied = ms.end_step();
    assert_eq!(applied.len(), n_sites);

    let cal = SubstrateCalibration {
        dims: (96, 96, 96),
        block: 16,
        threads: 2,
        dense_gops: 4.0,
        int8_gops: 9.0,
        int8_sim_gops: 5.0,
        fallback: vec![(0.0, 9.0), (0.25, 7.5)],
        backend: "scalar",
        per_backend: vec![("scalar", 9.0)],
    };
    // full text round trip — what an actual process restart sees
    let text = ms.warm_state(Some(&cal)).to_string();
    let parsed = Json::parse(&text).unwrap();
    let (mut ms2, cal2) = ModelStep::from_warm_state(
        cfg.clone(), weights.clone(), &parsed)
        .unwrap();
    let cal2 = cal2.expect("embedded calibration must survive");
    assert_eq!(cal2.int8_gops, cal.int8_gops);
    assert_eq!(cal2.fallback, cal.fallback);
    assert_eq!(ms2.controller().thresholds,
               ms.controller().thresholds,
               "adapted θ must ride the warm state");
    assert_eq!(ms2.microsteps(), ms.microsteps(),
               "SR streams must continue, not repeat");
    assert_eq!(ms2.kernel_backend(), ms.kernel_backend());

    // both processes run "the next microstep": the restored one must
    // hit on every lookup of its FIRST microstep and agree bitwise
    // with the saved process
    let (oa, ra) = ms.microstep(&acts, &grads);
    let (ob, rb) = ms2.microstep(&acts, &grads);
    assert_eq!(ra.cache_misses, 0);
    assert_eq!(rb.cache_misses, 0,
               "restored process must start at steady state");
    assert_eq!(rb.cache_hits as usize, 2 * n_sites);
    for (s, (a, b)) in oa.iter().zip(&ob).enumerate() {
        assert_eq!(a.y.data, b.y.data, "y[{s}] restored differs");
        assert_eq!(a.dx.data, b.dx.data, "dx[{s}] restored differs");
        assert_eq!(a.dw.data, b.dw.data, "dw[{s}] restored differs");
    }
}

#[test]
fn warm_state_restored_plans_bit_identical_to_cold_built() {
    // A restored (prewarmed) plan and a cold-built one over the same
    // weights must produce the same bits — per host backend.
    for kn in kernels::available() {
        let cfg = model_cfg(1);
        let weights = site_weights(&cfg, 0x55);
        let mut saved = ModelStep::new(cfg.clone(), weights.clone())
            .with_kernels(kn);
        let (acts, grads) = synth_microbatch(saved.sites(), 41, 180.0);
        saved.microstep(&acts, &grads);
        let state =
            Json::parse(&saved.warm_state(None).to_string()).unwrap();
        // restore — from_warm_state re-pins the *recorded* backend,
        // except that a PALLAS_KERNEL env override (the scalar-forced
        // CI leg) always wins over the recorded pin
        let (mut restored, _) = ModelStep::from_warm_state(
            cfg.clone(), weights.clone(), &state)
            .unwrap();
        let expect = kernels::env_override()
            .map(|k| k.name)
            .unwrap_or(kn.name);
        assert_eq!(restored.kernel_backend(), expect);
        // cold-built driver advanced to the same microstep index
        let mut cold = ModelStep::new(cfg.clone(), weights.clone())
            .with_kernels(kn);
        cold.microstep(&acts, &grads);
        cold.clear_cache();
        let (oc, rc) = cold.microstep(&acts, &grads);
        let (or_, rr) = restored.microstep(&acts, &grads);
        assert!(rc.cache_misses > 0 && rr.cache_misses == 0,
                "cold rebuilds, restored hits");
        for (s, (a, b)) in oc.iter().zip(&or_).enumerate() {
            assert_eq!(a.y.data, b.y.data,
                       "y[{s}] {} warm vs cold", kn.name);
            assert_eq!(a.dx.data, b.dx.data,
                       "dx[{s}] {} warm vs cold", kn.name);
            assert_eq!(a.dw.data, b.dw.data,
                       "dw[{s}] {} warm vs cold", kn.name);
        }
    }
}
