//! Cross-layer properties of the precision lattice
//! (`DataPath::Int4`, the staged Int4→Int8→f32 fallback ladder, and
//! the GLU activation sites).
//!
//! The contract under test: every rung of the lattice is **exact**
//! against its i64 integer oracle within the paper block sizes
//! (`bs ≤ I4_EXACT_MAX_BS` for nibble codes), and the staged ladder
//! is **bit-neutral** to execution geometry — the same bits come out
//! of every backend × thread-count × shard-count combination, at
//! every layer of the stack (direct engine plans, cached
//! `WeightPlan`s, `LayerStep`, `ModelStep`, and a full `TrainLoop`
//! over the GLU surrogate), and across a warm-state save/restore.
//! Every config here pins `cfg.path` explicitly, so the suite is
//! stable under any `PALLAS_PATH` override (the CI int4 leg runs
//! exactly this file under `PALLAS_PATH=int4`).

use std::sync::Arc;

use dbfq::data::Corpus;
use dbfq::gemm::{grad_sr_seed, int4_gemm_reference, kernels,
                 layer_sr_seed, site_reference,
                 staged_gemm_reference, synth_microbatch, DataPath,
                 GemmPlan, LayerStep, LayerStepConfig, ModelStep,
                 ModelStepConfig, WeightPlan, I4_EXACT_MAX_BS};
use dbfq::model::sites_per_layer;
use dbfq::quant::{block_quant, staged_quant, Rounding, INT4_LEVELS};
use dbfq::train::{Loader, TrainLoop, TrainLoopConfig};
use dbfq::util::rng::Pcg64;
use dbfq::util::Mat;

const BLOCK: usize = 16;
const THREADS: [usize; 3] = [1, 2, 4];
const SHARDS: [usize; 2] = [1, 2];
const PATHS: [DataPath; 3] =
    [DataPath::Int4, DataPath::Int8, DataPath::SimF32];

/// Outlier-bearing operands for the plain Int4 data path: spikes so
/// nibble saturation is exercised, 40 cols / 16 block = 3 panels so
/// S = 2 splits unevenly.
fn operands(seed: u64) -> (Mat, Mat) {
    let mut rng = Pcg64::new(seed);
    let mut a = Mat::randn(48, 33, 1.0, &mut rng);
    for i in 0..10 {
        let n = a.data.len();
        a.data[i * 131 % n] = 260.0;
    }
    let b = Mat::randn(33, 40, 1.0, &mut rng);
    (a, b)
}

/// Operands for the staged ladder: two spike magnitudes so a single
/// θ = 40 pins blocks on all three tiers (quiet → I4, 60-spikes →
/// I8, 260-spikes → f32 via the κ = 4 promotion rule).
fn staged_operands(seed: u64) -> (Mat, Mat) {
    let mut rng = Pcg64::new(seed);
    let mut a = Mat::randn(48, 33, 1.0, &mut rng);
    let n = a.data.len();
    for i in 0..10 {
        a.data[i * 131 % n] = 260.0;
        a.data[(i * 197 + 5) % n] = 60.0;
    }
    let b = Mat::randn(33, 40, 1.0, &mut rng);
    (a, b)
}

#[test]
fn int4_engine_matches_i64_oracle_everywhere() {
    assert!(BLOCK <= I4_EXACT_MAX_BS,
            "fixture block must sit inside the exactness bound");
    let (a, b) = operands(0x14A7);
    let qa = block_quant(&a, BLOCK, INT4_LEVELS, Rounding::Nearest);
    let qb = block_quant(&b, BLOCK, INT4_LEVELS, Rounding::Nearest);
    let exact = int4_gemm_reference(&qa, &qb);
    for kn in kernels::available() {
        for threads in THREADS {
            for shards in SHARDS {
                let c = GemmPlan::new_int8_path(&qa, &qb, threads,
                                                DataPath::Int4)
                    .with_kernels(kn)
                    .with_shards(shards)
                    .execute();
                assert_eq!(
                    c.data, exact.data,
                    "int4 backend {} threads {threads} shards \
                     {shards}",
                    kn.name);
            }
        }
    }
}

#[test]
fn staged_ladder_matches_i64_oracle_everywhere() {
    let (a, b) = staged_operands(0x57A6);
    let qb = block_quant(&b, BLOCK, INT4_LEVELS, Rounding::Nearest);
    // θ sweep: all-I4, genuinely mixed, all-f32
    for theta in [f32::INFINITY, 40.0, -1.0] {
        let sa = staged_quant(&a, theta, BLOCK);
        if theta == 40.0 {
            // the fixture must exercise all three tiers at once
            assert!(sa.rate_i8() > 0.0, "no promoted blocks");
            assert!(sa.rate_i8() < 1.0, "no I4-tier blocks");
            assert!(sa.rate_f32() > 0.0, "no f32-tier blocks");
            assert!(sa.rate_i8() > sa.rate_f32(),
                    "no I8-tier blocks (all promotions went to f32)");
        }
        if theta.is_infinite() {
            assert_eq!(sa.rate_i8(), 0.0, "∞ must pin everything I4");
        }
        if theta < 0.0 {
            assert_eq!(sa.rate_f32(), 1.0,
                       "negative θ must pin everything f32");
        }
        let exact = staged_gemm_reference(&sa, &qb);
        let qb_arc = Arc::new(qb.clone());
        for kn in kernels::available() {
            for threads in THREADS {
                for shards in SHARDS {
                    let tag = format!(
                        "theta {theta} backend {} threads {threads} \
                         shards {shards}",
                        kn.name);
                    let c = GemmPlan::new_staged(&sa, &qb, threads)
                        .with_kernels(kn)
                        .with_shards(shards)
                        .execute();
                    assert_eq!(c.data, exact.data, "staged {tag}");
                    // same bits through the cached-weight entry point
                    let wp =
                        WeightPlan::new(qb_arc.clone(),
                                        DataPath::Int4)
                            .with_kernels(kn)
                            .with_shards(shards);
                    let cw = wp.plan_staged(&sa, threads).execute();
                    assert_eq!(cw.data, exact.data,
                               "weight-plan staged {tag}");
                }
            }
        }
    }
}

#[test]
fn transposed_staged_ladder_matches_i64_oracle() {
    // The dW orientation: `StagedQuant::transposed` is a pure
    // permutation (no re-quantization), so the transposed ladder must
    // stay pinned to the oracle on its own operand shapes.
    let (a, _) = staged_operands(0x7D0A);
    let mut rng = Pcg64::new(0x7D0B);
    let bt = Mat::randn(48, 24, 1.0, &mut rng);
    let qbt = block_quant(&bt, BLOCK, INT4_LEVELS, Rounding::Nearest);
    let sa = staged_quant(&a, 40.0, BLOCK);
    let sat = sa.transposed();
    assert!(sat.rate_i8() > 0.0 && sat.rate_f32() > 0.0,
            "transpose must preserve the tier mix");
    let exact = staged_gemm_reference(&sat, &qbt);
    for kn in kernels::available() {
        for threads in THREADS {
            for shards in SHARDS {
                let c = GemmPlan::new_staged(&sat, &qbt, threads)
                    .with_kernels(kn)
                    .with_shards(shards)
                    .execute();
                assert_eq!(
                    c.data, exact.data,
                    "staged-T backend {} threads {threads} shards \
                     {shards}",
                    kn.name);
            }
        }
    }
}

#[test]
fn lattice_layer_step_bit_identical_across_configs() {
    for path in PATHS {
        for glu in [false, true] {
            // flat reference driver: threads 1, shards 1
            let mut cfg = LayerStepConfig::new(16, 32, 16, BLOCK);
            cfg.glu = glu;
            cfg.threads = 1;
            cfg.shards = 1;
            cfg.path = path;
            let mut rf = LayerStep::with_random_weights(cfg, 0x4A7);
            let (acts, grads) =
                synth_microbatch(rf.sites(), 29, 180.0);
            let (ref_outs, ref_rep) = rf.microstep(&acts, &grads);
            assert_eq!(ref_outs.len(), sites_per_layer(glu));
            for kn in kernels::available() {
                for threads in THREADS {
                    for shards in SHARDS {
                        let mut cfg =
                            LayerStepConfig::new(16, 32, 16, BLOCK);
                        cfg.glu = glu;
                        cfg.threads = threads;
                        cfg.shards = shards;
                        cfg.path = path;
                        let mut ls =
                            LayerStep::with_random_weights(cfg,
                                                           0x4A7)
                                .with_kernels(kn);
                        let (outs, rep) = ls.microstep(&acts,
                                                       &grads);
                        for (s, (x, y)) in
                            outs.iter().zip(&ref_outs).enumerate()
                        {
                            let tag = format!(
                                "site {s} path {} glu {glu} backend \
                                 {} threads {threads} shards \
                                 {shards}",
                                path.tag(), kn.name);
                            assert_eq!(x.y.data, y.y.data,
                                       "y {tag}");
                            assert_eq!(x.dx.data, y.dx.data,
                                       "dx {tag}");
                            assert_eq!(x.dw.data, y.dw.data,
                                       "dw {tag}");
                        }
                        // the Algorithm-2-visible rates feed the
                        // controller — they must be geometry-blind
                        // too, or thresholds would drift apart
                        for (s, (x, y)) in rep
                            .sites
                            .iter()
                            .zip(&ref_rep.sites)
                            .enumerate()
                        {
                            assert_eq!(
                                x.fallback_rate.to_bits(),
                                y.fallback_rate.to_bits(),
                                "rate site {s} path {} glu {glu}",
                                path.tag());
                            assert_eq!(
                                x.fallback_rate_f32.to_bits(),
                                y.fallback_rate_f32.to_bits(),
                                "f32 rate site {s} path {} glu \
                                 {glu}",
                                path.tag());
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn int4_model_step_and_warm_state_bit_identical() {
    // ModelStep on the lattice floor with the GLU split: flat
    // reference vs the geometry sweep, then a warm-state round trip
    // at S = 2 that must resume bit-exactly.
    let make_cfg = |threads: usize, shards: usize| {
        let mut cfg = ModelStepConfig::new(1, 16, 32, 40, 16, BLOCK);
        cfg.glu = true;
        cfg.threads = threads;
        cfg.shards = shards;
        cfg.path = DataPath::Int4;
        cfg
    };
    let mut rf = ModelStep::with_random_weights(make_cfg(1, 1), 0xB4);
    let (acts, grads) = synth_microbatch(rf.sites(), 31, 180.0);
    let mut ref_outs = Vec::new();
    for _ in 0..2 {
        let (o, _) = rf.microstep(&acts, &grads);
        ref_outs.push(o);
    }
    for kn in kernels::available() {
        for threads in [1usize, 2] {
            for shards in SHARDS {
                let mut ms = ModelStep::with_random_weights(
                    make_cfg(threads, shards), 0xB4)
                    .with_kernels(kn);
                for (t, refs) in ref_outs.iter().enumerate() {
                    let (outs, _) = ms.microstep(&acts, &grads);
                    for (s, (x, y)) in
                        outs.iter().zip(refs).enumerate()
                    {
                        let tag = format!(
                            "site {s} microstep {t} backend {} \
                             threads {threads} shards {shards}",
                            kn.name);
                        assert_eq!(x.y.data, y.y.data, "y {tag}");
                        assert_eq!(x.dx.data, y.dx.data, "dx {tag}");
                        assert_eq!(x.dw.data, y.dw.data, "dw {tag}");
                    }
                }
            }
        }
    }
    // warm-state round trip on the Int4 lattice (schema v2 records
    // the precision format — same-format restore must succeed and
    // resume at steady state with the exact continued bits)
    let cfg = make_cfg(2, 2);
    let shapes = ModelStep::with_random_weights(cfg.clone(), 0xB5);
    let weights: Vec<Mat> = shapes
        .sites()
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let mut rng = Pcg64::new(0xB5 ^ (i as u64) << 17);
            Mat::randn(l.k, l.n, 0.05, &mut rng)
        })
        .collect();
    let mut ms = ModelStep::new(cfg.clone(), weights.clone());
    ms.microstep(&acts, &grads);
    let state = ms.warm_state(None);
    let (mut restored, _) =
        ModelStep::from_warm_state(cfg, weights, &state)
            .expect("same-format Int4 restore must succeed");
    let (cont, _) = ms.microstep(&acts, &grads);
    let (rest, rep) = restored.microstep(&acts, &grads);
    assert_eq!(rep.cache_misses, 0,
               "restored Int4 process must start at steady state");
    for (s, (x, y)) in cont.iter().zip(&rest).enumerate() {
        assert_eq!(x.y.data, y.y.data, "restored y site {s}");
        assert_eq!(x.dx.data, y.dx.data, "restored dx site {s}");
        assert_eq!(x.dw.data, y.dw.data, "restored dw site {s}");
    }
}

#[test]
fn glu_model_step_matches_composed_site_references() {
    // The GLU gate/up sites are ordinary linear sites to the engine:
    // one glu=true ModelStep microstep must decompose exactly into
    // per-site `site_reference` calls with the model's layer-
    // namespaced SR seeds and the θ in effect at the microstep.
    const THETA: f32 = 3.0;
    for path in [DataPath::Int4, DataPath::Int8] {
        let mut cfg = ModelStepConfig::new(2, 16, 32, 40, 16, BLOCK);
        cfg.glu = true;
        cfg.threads = 2;
        cfg.path = path;
        let spl = sites_per_layer(true);
        let shapes =
            ModelStep::with_random_weights(cfg.clone(), 0x61A);
        let weights: Vec<Mat> = shapes
            .sites()
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let mut rng = Pcg64::new(0x61A ^ (i as u64) << 9);
                Mat::randn(l.k, l.n, 0.05, &mut rng)
            })
            .collect();
        for kn in kernels::available() {
            let mut ms =
                ModelStep::new(cfg.clone(), weights.clone())
                    .with_kernels(kn);
            ms.controller_mut().thresholds.fill(THETA);
            let (acts, grads) =
                synth_microbatch(ms.sites(), 37, 180.0);
            let (outs, _) = ms.microstep(&acts, &grads);
            assert_eq!(outs.len(), spl * cfg.layers + 1);
            for (i, l) in ms.sites().iter().enumerate() {
                // site i's SR stream: layer-namespaced, with the LM
                // head as "layer" `layers`, site 0 of its stream
                let (layer, local) = if i < spl * cfg.layers {
                    (i / spl, i % spl)
                } else {
                    (cfg.layers, 0)
                };
                let sr = Rounding::Stochastic(grad_sr_seed(
                    layer_sr_seed(cfg.sr_seed, layer), 0, local));
                let r = site_reference(l, &weights[i], &acts[i],
                                       &grads[i], THETA, sr, BLOCK,
                                       1, path, kn);
                let tag = format!("site {i} path {} backend {}",
                                  path.tag(), kn.name);
                assert_eq!(outs[i].y.data, r.y.data, "y {tag}");
                assert_eq!(outs[i].dx.data, r.dx.data, "dx {tag}");
                assert_eq!(outs[i].dw.data, r.dw.data, "dw {tag}");
            }
        }
    }
}

#[test]
fn glu_train_loop_loss_curve_bit_identical_across_configs() {
    // End-to-end acceptance: the GLU surrogate trains through
    // TrainLoop on the lattice, and the whole loss curve (plus the
    // controller-visible tier rates) is bit-identical across
    // backend × thread × shard geometry.
    const STEPS: usize = 2;
    let corpus = Corpus::synthetic(400, 40, 11);
    for path in [DataPath::Int4, DataPath::Int8] {
        let make_cfg = |threads: usize, shards: usize| {
            let mut cfg =
                TrainLoopConfig::new(1, 16, 32, 40, 2, 4, BLOCK);
            cfg.glu = true;
            cfg.telemetry = true;
            cfg.threads = threads;
            cfg.shards = shards;
            cfg.path = path;
            cfg
        };
        let mut rf = TrainLoop::new(
            make_cfg(1, 1),
            Loader::pretrain(corpus.clone(), 2, 4, 77));
        let ref_stats = rf.run(STEPS);
        assert!(ref_stats[0].loss.is_finite());
        let hist = ref_stats[0]
            .outlier_hist
            .as_ref()
            .expect("telemetry must attach histograms");
        assert!(hist.iter().sum::<u64>() > 0,
                "histogram must count every block");
        for kn in kernels::available() {
            for threads in [1usize, 2] {
                for shards in SHARDS {
                    let mut tl = TrainLoop::new(
                        make_cfg(threads, shards),
                        Loader::pretrain(corpus.clone(), 2, 4, 77))
                        .with_kernels(kn);
                    let stats = tl.run(STEPS);
                    for (t, (s, r)) in
                        stats.iter().zip(&ref_stats).enumerate()
                    {
                        let tag = format!(
                            "step {t} path {} backend {} threads \
                             {threads} shards {shards}",
                            path.tag(), kn.name);
                        assert_eq!(s.loss.to_bits(),
                                   r.loss.to_bits(),
                                   "loss {tag}");
                        assert_eq!(s.grad_norm.to_bits(),
                                   r.grad_norm.to_bits(),
                                   "grad_norm {tag}");
                        assert_eq!(s.fallback_rate.to_bits(),
                                   r.fallback_rate.to_bits(),
                                   "rate {tag}");
                        assert_eq!(s.fallback_rate_f32.to_bits(),
                                   r.fallback_rate_f32.to_bits(),
                                   "f32 rate {tag}");
                        assert_eq!(s.outlier_hist, r.outlier_hist,
                                   "hist {tag}");
                    }
                }
            }
        }
    }
}
