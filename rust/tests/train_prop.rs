//! End-to-end training-loop properties: bit-reproducibility of the
//! loss curve across every kernel backend, thread count, and shard
//! config; checkpoint restore continuing bit-identically; plan-cache
//! behavior under full per-step weight mutation; and the
//! paper-trend convergence of the quantized run against the exact
//! dense-f32 reference.

use dbfq::coordinator::LrSchedule;
use dbfq::data::Corpus;
use dbfq::gemm::{kernels, DataPath};
use dbfq::train::{Loader, TrainLoop, TrainLoopConfig};
use dbfq::util::json::Json;

const VOCAB: usize = 64;
const BATCH: usize = 2;
const SEQ: usize = 8;

fn small_cfg() -> TrainLoopConfig {
    let mut cfg =
        TrainLoopConfig::new(1, 32, 48, VOCAB, BATCH, SEQ, 16);
    cfg.threads = 1;
    cfg.shards = 1;
    cfg
}

fn small_loader(seed: u64) -> Loader {
    Loader::pretrain(Corpus::synthetic(600, VOCAB, 13), BATCH, SEQ,
                     seed)
}

fn loss_bits(tl: &mut TrainLoop, steps: usize) -> Vec<u64> {
    tl.run(steps).iter().map(|s| s.loss.to_bits()).collect()
}

fn weight_bits(tl: &TrainLoop) -> Vec<u32> {
    tl.weights()
        .iter()
        .flat_map(|w| w.data.iter().map(|v| v.to_bits()))
        .collect()
}

/// The tentpole determinism claim: the whole training trajectory —
/// not just one GEMM — is byte-identical across every available
/// kernel backend, thread count, and shard count.
#[test]
fn loss_curve_bit_identical_across_backends_threads_shards() {
    let steps = 6;
    let mut reference: Option<(Vec<u64>, Vec<u32>)> = None;
    for kn in kernels::available() {
        for threads in [1usize, 2, 4] {
            for shards in [1usize, 2] {
                let mut cfg = small_cfg();
                cfg.threads = threads;
                cfg.shards = shards;
                let mut tl =
                    TrainLoop::new(cfg, small_loader(17))
                        .with_kernels(kn);
                let curve = loss_bits(&mut tl, steps);
                let weights = weight_bits(&tl);
                match &reference {
                    None => {
                        reference = Some((curve, weights));
                    }
                    Some((c0, w0)) => {
                        assert_eq!(&curve, c0,
                                   "loss curve diverged: backend \
                                    {} threads {threads} shards \
                                    {shards}", kn.name);
                        assert_eq!(&weights, w0,
                                   "weights diverged: backend {} \
                                    threads {threads} shards \
                                    {shards}", kn.name);
                    }
                }
            }
        }
    }
}

/// The true-int8 data path is bit-identical to its f32 simulation
/// for the entire training run — the gap the ISSUE bounds is
/// exactly zero by the engine's exactness argument (block sizes ≤
/// 1040 keep every i8 partial sum in f32's exact-integer range).
#[test]
fn int8_and_simf32_training_runs_are_bitwise_equal() {
    let mk = |path: DataPath| {
        let mut cfg = small_cfg();
        cfg.path = path;
        let mut tl = TrainLoop::new(cfg, small_loader(23));
        (loss_bits(&mut tl, 6), weight_bits(&tl))
    };
    let (ci, wi) = mk(DataPath::Int8);
    let (cs, ws) = mk(DataPath::SimF32);
    assert_eq!(ci, cs, "Int8 vs SimF32 loss curves");
    assert_eq!(wi, ws, "Int8 vs SimF32 final weights");
}

/// Save at step 10, restore into a fresh process-alike, run 10 more:
/// every loss bit and weight bit matches the uninterrupted 20-step
/// run. (Cache *stats* differ — restore prewarms where the original
/// missed — but plans rebuilt from the same weights are
/// byte-identical, so outputs cannot.)
#[test]
fn checkpoint_restore_resumes_bit_identical() {
    let mut straight = TrainLoop::new(small_cfg(), small_loader(31));
    let full: Vec<u64> = loss_bits(&mut straight, 20);

    let mut first = TrainLoop::new(small_cfg(), small_loader(31));
    let head: Vec<u64> = loss_bits(&mut first, 10);
    let state = first.checkpoint();
    // Through text, as a real save/load would go.
    let parsed = Json::parse(&state.to_string()).unwrap();
    let mut resumed = TrainLoop::from_checkpoint(
        small_cfg(), small_loader(31), &parsed)
        .unwrap();
    assert_eq!(resumed.step(), 10);
    let tail: Vec<u64> = loss_bits(&mut resumed, 10);

    let mut rejoined = head;
    rejoined.extend(tail);
    assert_eq!(rejoined, full, "restored run diverged");
    assert_eq!(weight_bits(&resumed), weight_bits(&straight));
    let (a, b) = (resumed.model().unwrap(),
                  straight.model().unwrap());
    assert_eq!(a.microsteps(), b.microsteps());
    assert_eq!(a.controller().thresholds, b.controller().thresholds);
}

/// Plan-cache behavior under the training loop's full per-step
/// weight mutation, with gradient accumulation making the cache
/// earn its keep: every step's first microbatch rebuilds both
/// weight halves of every site (2S misses), the second hits all of
/// them (2S hits), and the quant/pack counters account for exactly
/// that — no stale plans, no thrashing, no silent extra work.
#[test]
fn cache_under_per_step_weight_mutation() {
    let mut cfg = small_cfg();
    cfg.accum = 2;
    cfg.threads = 1; // counters are per-thread exact only inline
    let s = cfg.n_sites() as u64;
    let mut tl = TrainLoop::new(cfg.clone(), small_loader(41));
    let mut twin = TrainLoop::new(cfg, small_loader(41));
    for step in 0..8 {
        // The twin rebuilds every plan from scratch each step: if a
        // stale plan ever survived `set_weight`, the curves would
        // split here.
        twin.model_mut().unwrap().clear_cache();
        let st = tl.step_once();
        let sw = twin.step_once();
        assert_eq!(st.loss.to_bits(), sw.loss.to_bits(),
                   "cached vs cache-cleared run at step {step}");
        assert_eq!(st.cache_misses, 2 * s, "step {step} misses");
        assert_eq!(st.cache_hits, 2 * s, "step {step} hits");
        // Cold microbatch: 4 quants (X, dY, W, Wᵀ) + 3 packs per
        // site; warm: 2 quants + 1 pack.
        assert_eq!(st.quants, 6 * s, "step {step} quant calls");
        assert_eq!(st.packs, 4 * s, "step {step} panel packs");
        let cache = tl.model().unwrap().cache();
        assert!(cache.len() <= cache.capacity());
        assert!(!cache.stats().thrashing(),
                "cache thrashing at step {step}");
    }
    assert_eq!(weight_bits(&tl), weight_bits(&twin));
}

/// The convergence harness (Fig-7b trend at CPU toy scale): 200
/// synthetic-pretrain steps must actually learn — final loss well
/// under the ~ln(64) ≈ 4.16 random-init loss — on both engines, and
/// the quantized run must land within a bounded gap of the exact
/// dense-f32 reference.
#[test]
fn pretrain_converges_and_tracks_exact_reference() {
    let steps = 200;
    let run = |exact: bool| {
        let mut cfg = TrainLoopConfig::new(
            1, 32, 48, VOCAB, 4, SEQ, 16);
        cfg.threads = 1;
        cfg.exact = exact;
        cfg.lr = LrSchedule { peak: 5e-3, warmup: 10,
                              total: steps };
        let loader = Loader::pretrain(
            Corpus::synthetic(2000, VOCAB, 13), 4, SEQ, 71);
        let mut tl = TrainLoop::new(cfg, loader);
        let stats = tl.run(steps);
        let first: f64 = stats[..10]
            .iter()
            .map(|s| s.loss)
            .sum::<f64>() / 10.0;
        let last: f64 = stats[steps - 10..]
            .iter()
            .map(|s| s.loss)
            .sum::<f64>() / 10.0;
        (first, last)
    };
    let (q_first, q_last) = run(false);
    let (e_first, e_last) = run(true);
    assert!(q_last < q_first - 0.3,
            "quantized run failed to converge: {q_first} -> \
             {q_last}");
    assert!(e_last < e_first - 0.3,
            "exact run failed to converge: {e_first} -> {e_last}");
    assert!((q_last - e_last).abs() < 0.75,
            "quantized final loss {q_last} strayed from exact \
             {e_last}");
}
