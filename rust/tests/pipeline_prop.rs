//! Property tests for the layer-step pipeline (`gemm::pipeline`).
//!
//! The contract under test is the acceptance bar of the plan cache:
//! a **cached** weight half must produce byte-identical C to a
//! **freshly built** plan over the same operands —
//!
//! * on every microkernel backend available on the host
//!   (`kernels::available()`, the `PALLAS_KERNEL` choices),
//! * at both int8 precisions (`Int8Block` and `Fallback`),
//! * on both data paths (`Int8` and the `SimF32` oracle),
//! * across 1/2/4 threads,
//!
//! and the `LayerStep` driver must be bitwise invariant to cache
//! state (hit vs miss) and thread count.

use std::sync::Arc;

use dbfq::gemm::{
    kernels, synth_microbatch, DataPath, GemmPlan, LayerStep,
    LayerStepConfig, WeightPlan,
};
use dbfq::prop_assert;
use dbfq::quant::{block_quant, fallback_quant, theta_for_rate,
                  Criterion, Rounding, INT8_LEVELS};
use dbfq::util::testing::forall;
use dbfq::util::Mat;

const THREADS: [usize; 3] = [1, 2, 4];
const BLOCK: usize = 16;

#[test]
fn prop_cached_weight_plan_bit_identical_per_backend() {
    let backends = kernels::available();
    forall("pipeline-cached-vs-fresh", 8, |g| {
        let m = BLOCK * g.usize_in(1, 3) + g.usize_in(0, 7);
        let k = BLOCK * g.usize_in(1, 3) + g.usize_in(0, 7);
        let n = BLOCK * g.usize_in(1, 3) + g.usize_in(0, 7);
        let a =
            Mat::from_vec(m, k, g.vec_outliers(m * k, 1.0, 5, 140.0));
        let w = Mat::from_vec(k, n, g.vec_normal(k * n, 1.0));
        let qa = block_quant(&a, BLOCK, INT8_LEVELS, Rounding::Nearest);
        let probe = fallback_quant(&a, f32::INFINITY, BLOCK,
                                   INT8_LEVELS, Criterion::AbsMax);
        let theta = theta_for_rate(&probe.metric, 0.3);
        let fa = fallback_quant(&a, theta, BLOCK, INT8_LEVELS,
                                Criterion::AbsMax);
        for path in [DataPath::Int8, DataPath::SimF32] {
            for &kn in &backends {
                // the cached half: built once, reused for every
                // thread count below
                let qw = Arc::new(block_quant(&w, BLOCK, INT8_LEVELS,
                                              Rounding::Nearest));
                let wp =
                    WeightPlan::new(qw, path).with_kernels(kn);
                for threads in THREADS {
                    // fresh operand per comparison so no caches are
                    // shared with the cached half
                    let qw_fresh = block_quant(&w, BLOCK, INT8_LEVELS,
                                               Rounding::Nearest);
                    let c_cached =
                        wp.plan_int8(&qa, threads).execute();
                    let c_fresh = GemmPlan::new_int8_path(
                        &qa, &qw_fresh, threads, path)
                        .with_kernels(kn)
                        .execute();
                    prop_assert!(
                        c_cached.data == c_fresh.data,
                        "int8 cached vs fresh ({m},{k},{n}) \
                         backend={} path={path:?} threads={threads}",
                        kn.name
                    );
                    let f_cached = wp
                        .plan_fallback(&fa, &fa.u, threads)
                        .execute();
                    let f_fresh = GemmPlan::new_fallback_path(
                        &fa, &qw_fresh, &fa.u, threads, path)
                        .with_kernels(kn)
                        .execute();
                    prop_assert!(
                        f_cached.data == f_fresh.data,
                        "fallback cached vs fresh ({m},{k},{n}) \
                         backend={} path={path:?} threads={threads}",
                        kn.name
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_layer_step_cache_and_thread_invariant() {
    forall("pipeline-layerstep-invariance", 5, |g| {
        let d_model = 16 * g.usize_in(1, 2);
        let d_ff = 16 * g.usize_in(2, 3);
        let tokens = 16 * g.usize_in(1, 2) + g.usize_in(0, 5);
        let mut cfg = LayerStepConfig::new(d_model, d_ff, tokens, 16);
        cfg.glu = g.usize_in(0, 1) == 1;
        cfg.threads = 1;
        let seed = 0xA11CE;
        let mut ls = LayerStep::with_random_weights(cfg.clone(), seed);
        let (acts, grads) = synth_microbatch(ls.sites(), 7, 150.0);
        let (o1, r1) = ls.microstep(&acts, &grads);
        // identical inputs again: every weight lookup must hit, and
        // the cache hit must not change a single bit
        let (o2, r2) = ls.microstep(&acts, &grads);
        prop_assert!(r1.cache_misses == 8 && r1.cache_hits == 0,
                     "cold lookups: {r1:?}");
        prop_assert!(r2.cache_misses == 0 && r2.cache_hits == 8,
                     "warm lookups: {r2:?}");
        for (i, (a, b)) in o1.iter().zip(&o2).enumerate() {
            prop_assert!(a.y.data == b.y.data, "y[{i}] hit differs");
            prop_assert!(a.dx.data == b.dx.data,
                         "dx[{i}] hit differs");
            prop_assert!(a.dw.data == b.dw.data,
                         "dw[{i}] hit differs");
        }
        // thread-count invariance: quantization and the engine are
        // both bitwise thread-invariant, so the whole pipeline is
        for threads in [2usize, 4] {
            let mut cfg_t = cfg.clone();
            cfg_t.threads = threads;
            let mut ls_t =
                LayerStep::with_random_weights(cfg_t, seed);
            let (ot, _) = ls_t.microstep(&acts, &grads);
            for (i, (a, b)) in o1.iter().zip(&ot).enumerate() {
                prop_assert!(a.y.data == b.y.data,
                             "y[{i}] threads={threads}");
                prop_assert!(a.dx.data == b.dx.data,
                             "dx[{i}] threads={threads}");
                prop_assert!(a.dw.data == b.dw.data,
                             "dw[{i}] threads={threads}");
            }
        }
        Ok(())
    });
}
