//! Property tests for the layer-step pipeline (`gemm::pipeline`).
//!
//! The contract under test is the acceptance bar of the plan cache:
//! a **cached** weight half must produce byte-identical C to a
//! **freshly built** plan over the same operands —
//!
//! * on every microkernel backend available on the host
//!   (`kernels::available()`, the `PALLAS_KERNEL` choices),
//! * at both int8 precisions (`Int8Block` and `Fallback`),
//! * on both data paths (`Int8` and the `SimF32` oracle),
//! * across 1/2/4 threads,
//!
//! and the `LayerStep` driver must be bitwise invariant to cache
//! state (hit vs miss) and thread count.

use std::sync::Arc;

use dbfq::gemm::{
    fallback_gemm_reference, grad_sr_seed, kernels, synth_microbatch,
    DataPath, GemmPlan, LayerStep, LayerStepConfig, WeightPlan,
};
use dbfq::prop_assert;
use dbfq::quant::{block_quant, fallback_quant, theta_for_rate,
                  Criterion, Rounding, INT8_LEVELS};
use dbfq::util::testing::forall;
use dbfq::util::Mat;

const THREADS: [usize; 3] = [1, 2, 4];
const BLOCK: usize = 16;

#[test]
fn prop_cached_weight_plan_bit_identical_per_backend() {
    let backends = kernels::available();
    forall("pipeline-cached-vs-fresh", 8, |g| {
        let m = BLOCK * g.usize_in(1, 3) + g.usize_in(0, 7);
        let k = BLOCK * g.usize_in(1, 3) + g.usize_in(0, 7);
        let n = BLOCK * g.usize_in(1, 3) + g.usize_in(0, 7);
        let a =
            Mat::from_vec(m, k, g.vec_outliers(m * k, 1.0, 5, 140.0));
        let w = Mat::from_vec(k, n, g.vec_normal(k * n, 1.0));
        let qa = block_quant(&a, BLOCK, INT8_LEVELS, Rounding::Nearest);
        let probe = fallback_quant(&a, f32::INFINITY, BLOCK,
                                   INT8_LEVELS, Criterion::AbsMax);
        let theta = theta_for_rate(&probe.metric, 0.3);
        let fa = fallback_quant(&a, theta, BLOCK, INT8_LEVELS,
                                Criterion::AbsMax);
        for path in [DataPath::Int8, DataPath::SimF32] {
            for &kn in &backends {
                // the cached half: built once, reused for every
                // thread count below
                let qw = Arc::new(block_quant(&w, BLOCK, INT8_LEVELS,
                                              Rounding::Nearest));
                let wp =
                    WeightPlan::new(qw, path).with_kernels(kn);
                for threads in THREADS {
                    // fresh operand per comparison so no caches are
                    // shared with the cached half
                    let qw_fresh = block_quant(&w, BLOCK, INT8_LEVELS,
                                               Rounding::Nearest);
                    let c_cached =
                        wp.plan_int8(&qa, threads).execute();
                    let c_fresh = GemmPlan::new_int8_path(
                        &qa, &qw_fresh, threads, path)
                        .with_kernels(kn)
                        .execute();
                    prop_assert!(
                        c_cached.data == c_fresh.data,
                        "int8 cached vs fresh ({m},{k},{n}) \
                         backend={} path={path:?} threads={threads}",
                        kn.name
                    );
                    let f_cached = wp
                        .plan_fallback(&fa, &fa.u, threads)
                        .execute();
                    let f_fresh = GemmPlan::new_fallback_path(
                        &fa, &qw_fresh, &fa.u, threads, path)
                        .with_kernels(kn)
                        .execute();
                    prop_assert!(
                        f_cached.data == f_fresh.data,
                        "fallback cached vs fresh ({m},{k},{n}) \
                         backend={} path={path:?} threads={threads}",
                        kn.name
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_layer_step_cache_and_thread_invariant() {
    forall("pipeline-layerstep-invariance", 5, |g| {
        let d_model = 16 * g.usize_in(1, 2);
        let d_ff = 16 * g.usize_in(2, 3);
        let tokens = 16 * g.usize_in(1, 2) + g.usize_in(0, 5);
        let mut cfg = LayerStepConfig::new(d_model, d_ff, tokens, 16);
        cfg.glu = g.usize_in(0, 1) == 1;
        cfg.threads = 1;
        let seed = 0xA11CE;
        let mut ls = LayerStep::with_random_weights(cfg.clone(), seed);
        let (acts, grads) = synth_microbatch(ls.sites(), 7, 150.0);
        let (o1, r1) = ls.microstep(&acts, &grads);
        // identical inputs again: every weight lookup must hit. The
        // gradient SR streams are seeded per microstep, so the warm
        // microstep is compared against a *cold rebuild at the same
        // microstep index* (hit vs miss must not change a single
        // bit), not against the previous microstep.
        let (o2, r2) = ls.microstep(&acts, &grads);
        prop_assert!(r1.cache_misses == 8 && r1.cache_hits == 0,
                     "cold lookups: {r1:?}");
        prop_assert!(r2.cache_misses == 0 && r2.cache_hits == 8,
                     "warm lookups: {r2:?}");
        let mut ls_cold =
            LayerStep::with_random_weights(cfg.clone(), seed);
        ls_cold.microstep(&acts, &grads);
        ls_cold.clear_cache();
        let (o2_cold, r2_cold) = ls_cold.microstep(&acts, &grads);
        prop_assert!(r2_cold.cache_misses == 8,
                     "cleared cache must rebuild: {r2_cold:?}");
        for (i, (a, b)) in o2.iter().zip(&o2_cold).enumerate() {
            prop_assert!(a.y.data == b.y.data, "y[{i}] hit differs");
            prop_assert!(a.dx.data == b.dx.data,
                         "dx[{i}] hit differs");
            prop_assert!(a.dw.data == b.dw.data,
                         "dw[{i}] hit differs");
        }
        // fresh SR draws per microstep: the warm gradient outputs
        // must not repeat the cold microstep's bits
        prop_assert!(o1.iter().zip(&o2).any(|(a, b)| {
            a.dx.data != b.dx.data
        }), "gradient SR must advance between microsteps");
        // thread-count invariance: quantization (per-block SR
        // streams), the engine, and the pipeline glue are all
        // bitwise thread-invariant — per microstep index
        for threads in [2usize, 4] {
            let mut cfg_t = cfg.clone();
            cfg_t.threads = threads;
            let mut ls_t =
                LayerStep::with_random_weights(cfg_t, seed);
            let (ot1, _) = ls_t.microstep(&acts, &grads);
            let (ot2, _) = ls_t.microstep(&acts, &grads);
            for (i, ((a1, a2), (b1, b2))) in o1
                .iter()
                .zip(&o2)
                .zip(ot1.iter().zip(&ot2))
                .enumerate()
            {
                prop_assert!(a1.y.data == b1.y.data,
                             "y[{i}] threads={threads}");
                prop_assert!(a1.dx.data == b1.dx.data,
                             "dx[{i}] threads={threads}");
                prop_assert!(a1.dw.data == b1.dw.data,
                             "dw[{i}] threads={threads}");
                prop_assert!(a2.dx.data == b2.dx.data,
                             "dx[{i}] microstep 2 threads={threads}");
                prop_assert!(a2.dw.data == b2.dw.data,
                             "dw[{i}] microstep 2 threads={threads}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dw_matches_exact_i64_fallback_oracle() {
    // The dW bugfix contract: Xᵀ·dY runs Algorithm 1 with Xᵀ's
    // fallback representation at the site's θ — bit-identical to the
    // exact i64 reference, at every thread count, with the backward
    // rate reported per site.
    forall("pipeline-dw-oracle", 6, |g| {
        let d_model = 16 * g.usize_in(1, 2);
        let d_ff = 16 * g.usize_in(2, 3);
        let tokens = 16 * g.usize_in(1, 2) + g.usize_in(0, 5);
        let mut cfg = LayerStepConfig::new(d_model, d_ff, tokens, 16);
        cfg.glu = false;
        cfg.threads = g.usize_in(1, 4);
        let mut ls =
            LayerStep::with_random_weights(cfg.clone(), 0xD0_0E);
        let (acts, grads) = synth_microbatch(ls.sites(), 13, 220.0);
        let thetas: Vec<f32> = acts
            .iter()
            .map(|x| {
                let probe = fallback_quant(x, f32::INFINITY, BLOCK,
                                           INT8_LEVELS,
                                           Criterion::AbsMax);
                theta_for_rate(&probe.metric, 0.3)
            })
            .collect();
        ls.controller_mut().thresholds.copy_from_slice(&thetas);
        let (outs, rep) = ls.microstep(&acts, &grads);
        for (i, l) in ls.sites().iter().enumerate() {
            let fxt = fallback_quant(&acts[i].transpose(), thetas[i],
                                     BLOCK, INT8_LEVELS,
                                     Criterion::AbsMax);
            let qdy = block_quant(&grads[i], BLOCK, INT8_LEVELS,
                                  Rounding::Stochastic(grad_sr_seed(
                                      cfg.sr_seed, 0, i)));
            let oracle =
                fallback_gemm_reference(&fxt, &qdy, &fxt.u);
            prop_assert!(outs[i].dw.data == oracle.data,
                         "dW vs i64 oracle at {} ({} threads)",
                         l.name, cfg.threads);
            prop_assert!(
                (rep.sites[i].bwd_fallback_rate
                 - fxt.fallback_rate()).abs() < 1e-12,
                "bwd rate report at {}", l.name
            );
        }
        Ok(())
    });
}
