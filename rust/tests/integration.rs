//! L3 integration tests: PJRT runtime + AOT artifacts + coordinator.
//!
//! Require `make artifacts` (tiny profile at minimum). They prove the
//! full L1→L2→L3 composition: the Rust quant/gemm implementations agree
//! bitwise with the Pallas-kernel artifacts executed through PJRT, and
//! the training coordinator drives the AOT train step end to end.
//!
//! From a clean checkout (no compiled artifacts) every test here
//! **skips** — `require_artifacts!()` passes trivially with a message —
//! so `cargo test -q` stays green without the Python/JAX toolchain.
//! The pure-Rust substrate is covered by the unit tests and
//! `tests/engine_prop.rs` regardless.

use dbfq::coordinator::{QScalars, TrainConfig, Trainer};
use dbfq::data::Corpus;
use dbfq::model::Method;
use dbfq::quant::{self, Criterion, Rounding, INT8_LEVELS};
use dbfq::runtime::{artifacts_dir, Runtime, Value};
use dbfq::util::rng::Pcg64;
use dbfq::util::Mat;

/// Skip (return early, passing) when `artifacts/manifest.json` is
/// absent; the runtime tests cannot run without AOT artifacts.
macro_rules! require_artifacts {
    () => {
        if !std::path::Path::new(&artifacts_dir())
            .join("manifest.json")
            .exists()
        {
            eprintln!(
                "skipping {}: artifacts/manifest.json not found — run \
                 `make artifacts` to enable the PJRT integration tests",
                module_path!()
            );
            return;
        }
    };
}

fn runtime() -> Runtime {
    Runtime::open(&artifacts_dir()).expect("run `make artifacts` first")
}

fn outlier_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    let mut m = Mat::randn(rows, cols, 1.0, &mut rng);
    for _ in 0..6 {
        let i = rng.below(m.data.len());
        m.data[i] = 150.0 * (1.0 + rng.uniform_f32());
    }
    m
}

#[test]
fn manifest_lists_expected_artifacts() {
    require_artifacts!();
    let rt = runtime();
    for a in ["init_tiny", "train_tiny_fallback", "eval_tiny_fallback",
              "op_block_gemm", "op_fallback_gemm", "op_fallback_quant",
              "op_group_quant"] {
        assert!(rt.has_artifact(a), "missing artifact {a}");
    }
    let prof = rt.profile("tiny").unwrap();
    assert_eq!(prof.n_sites, 4 * prof.n_layers + 1);
}

#[test]
fn init_artifact_deterministic_and_sized() {
    require_artifacts!();
    let rt = runtime();
    let p1 = rt.call("init_tiny", &[Value::scalar_i32(3)]).unwrap();
    let p2 = rt.call("init_tiny", &[Value::scalar_i32(3)]).unwrap();
    let p3 = rt.call("init_tiny", &[Value::scalar_i32(4)]).unwrap();
    assert_eq!(p1[0].as_f32().unwrap(), p2[0].as_f32().unwrap());
    assert_ne!(p1[0].as_f32().unwrap(), p3[0].as_f32().unwrap());
    assert_eq!(p1[0].len(), rt.profile("tiny").unwrap().n_params);
}

/// The core cross-validation: the Rust block GEMM must agree with the
/// Pallas block-GEMM kernel (lowered to HLO, executed via PJRT) bitwise
/// on the integer path, within f32 accumulation noise on scales.
#[test]
fn rust_gemm_matches_pallas_kernel_artifact() {
    require_artifacts!();
    let rt = runtime();
    // op_block_gemm: m=64 n=48 k=80, block=16 (see aot.emit_kernel_ops)
    let (m, n, k, b) = (64, 48, 80, 16);
    let a_mat = outlier_mat(m, k, 11);
    let b_mat = outlier_mat(k, n, 12);
    let qa = quant::block_quant(&a_mat, b, INT8_LEVELS, Rounding::Nearest);
    let qb = quant::block_quant(&b_mat, b, INT8_LEVELS, Rounding::Nearest);

    let qa_f: Vec<f32> = qa.q.iter().map(|&v| v as f32).collect();
    let qb_f: Vec<f32> = qb.q.iter().map(|&v| v as f32).collect();
    let out = rt
        .call(
            "op_block_gemm",
            &[
                Value::mat_f32(qa_f, m, k),
                Value::mat_f32(qa.scale.clone(), m / b, k / b),
                Value::mat_f32(qb_f, k, n),
                Value::mat_f32(qb.scale.clone(), k / b, n / b),
            ],
        )
        .unwrap();
    let c_pallas = out[0].as_f32().unwrap();
    let c_rust = dbfq::gemm::block_gemm(&qa, &qb, 1);
    let mut max_rel = 0.0f64;
    for (x, y) in c_rust.data.iter().zip(c_pallas) {
        let rel = ((x - y).abs() / y.abs().max(1.0)) as f64;
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 1e-5, "rust vs pallas GEMM max rel {max_rel}");
}

#[test]
fn rust_fallback_quant_matches_pallas_kernel_artifact() {
    require_artifacts!();
    let rt = runtime();
    let (m, k, b) = (64, 80, 16);
    let x = outlier_mat(m, k, 21);
    let theta = 10.0f32;
    let out = rt
        .call(
            "op_fallback_quant",
            &[Value::mat_f32(x.data.clone(), m, k),
              Value::scalar_f32(theta)],
        )
        .unwrap();
    // outputs (dict sorted): absmax, q, rq, rscale, scale, u
    let q_pallas = out[1].as_f32().unwrap();
    let u_pallas = out[5].as_f32().unwrap();
    let fq = quant::fallback_quant(&x, theta, b, INT8_LEVELS,
                                   Criterion::AbsMax);
    // integer codes must match exactly
    for (i, (&qp, &qr)) in
        q_pallas.iter().zip(fq.base.q.iter()).enumerate()
    {
        assert_eq!(qp, qr as f32, "q mismatch at {i}");
    }
    for (i, (&up, &ur)) in u_pallas.iter().zip(fq.u.iter()).enumerate() {
        assert_eq!(up, ur as u8 as f32, "u mismatch at {i}");
    }
    // residual codes: FMA contraction may shift the residual by 1 ulp of
    // the first-step scale; allow |Δcode| <= 1.
    let rq_pallas = out[2].as_f32().unwrap();
    let mut diff1 = 0usize;
    for (&rp, &rr) in rq_pallas.iter().zip(fq.rq.iter()) {
        let d = (rp - rr as f32).abs();
        assert!(d <= 1.0, "rq diff {d}");
        if d > 0.0 {
            diff1 += 1;
        }
    }
    assert!(diff1 < rq_pallas.len() / 20,
            "too many 1-code residual diffs: {diff1}");
}

#[test]
fn rust_group_quant_matches_pallas_kernel_artifact() {
    require_artifacts!();
    let rt = runtime();
    let (m, k) = (64, 80);
    let x = outlier_mat(m, k, 31);
    let out = rt
        .call("op_group_quant",
              &[Value::mat_f32(x.data.clone(), m, k),
                Value::scalar_f32(10.0)])
        .unwrap();
    let q_pallas = out[0].as_f32().unwrap();
    let gq = quant::group_quant(&x, 16, 10);
    for (i, (&qp, &qr)) in q_pallas.iter().zip(gq.q.iter()).enumerate() {
        assert_eq!(qp, qr as f32, "group code mismatch at {i}");
    }
}

#[test]
fn fallback_gemm_artifact_consistent_with_rust() {
    require_artifacts!();
    let rt = runtime();
    let (m, n, k, b) = (64, 48, 80, 16);
    let a_mat = outlier_mat(m, k, 41);
    let b_mat = outlier_mat(k, n, 42);
    let fa = quant::fallback_quant(&a_mat, 20.0, b, INT8_LEVELS,
                                   Criterion::AbsMax);
    let qb = quant::block_quant(&b_mat, b, INT8_LEVELS, Rounding::Nearest);
    let u_f: Vec<f32> = fa.u.iter().map(|&u| u as u8 as f32).collect();
    let out = rt
        .call(
            "op_fallback_gemm",
            &[
                Value::mat_f32(
                    fa.base.q.iter().map(|&v| v as f32).collect(), m, k),
                Value::mat_f32(fa.base.scale.clone(), m / b, k / b),
                Value::mat_f32(
                    fa.rq.iter().map(|&v| v as f32).collect(), m, k),
                Value::mat_f32(fa.rscale.clone(), m / b, k / b),
                Value::mat_f32(u_f, m / b, k / b),
                Value::mat_f32(
                    qb.q.iter().map(|&v| v as f32).collect(), k, n),
                Value::mat_f32(qb.scale.clone(), k / b, n / b),
            ],
        )
        .unwrap();
    let c_pallas = out[0].as_f32().unwrap();
    let c_rust = dbfq::gemm::fallback_gemm(&fa, &qb, &fa.u, 1);
    let mut max_rel = 0.0f64;
    for (x, y) in c_rust.data.iter().zip(c_pallas) {
        max_rel = max_rel.max(((x - y).abs() / y.abs().max(1.0)) as f64);
    }
    assert!(max_rel < 1e-5, "fallback GEMM max rel {max_rel}");
}

#[test]
fn trainer_reduces_loss_and_controls_rate() {
    require_artifacts!();
    let rt = runtime();
    let cfg = TrainConfig::new("tiny", Method::Fallback, 7, 40);
    let prof = rt.profile("tiny").unwrap().clone();
    let corpus = Corpus::synthetic(50_000, prof.vocab, 1);
    let mut rng = Pcg64::new(2);
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let mut first = 0.0;
    let mut last = 0.0;
    for s in 0..40 {
        let toks = corpus.sample_batch(prof.batch, prof.seq_len, &mut rng);
        let st = tr.step_on(&toks).unwrap();
        if s == 0 {
            first = st.loss;
        }
        last = st.loss;
    }
    assert!(last < first - 0.3, "loss {first} -> {last}");
    // Delay controller must have pulled the rate toward [0.1, 0.3].
    let tail: Vec<f64> = tr.history[30..]
        .iter()
        .map(|s| s.mean_fallback_rate)
        .collect();
    let mean_tail = tail.iter().sum::<f64>() / tail.len() as f64;
    assert!(mean_tail > 0.02 && mean_tail < 0.55,
            "tail fallback rate {mean_tail}");
}

#[test]
fn trainer_all_methods_run() {
    require_artifacts!();
    let rt = runtime();
    let prof = rt.profile("tiny").unwrap().clone();
    let corpus = Corpus::synthetic(20_000, prof.vocab, 3);
    for method in Method::all() {
        let cfg = TrainConfig::new("tiny", method, 1, 5);
        let mut rng = Pcg64::new(4);
        let mut tr = Trainer::new(&rt, cfg).unwrap();
        for _ in 0..3 {
            let toks =
                corpus.sample_batch(prof.batch, prof.seq_len, &mut rng);
            let st = tr.step_on(&toks).unwrap();
            assert!(st.loss.is_finite(), "{method:?}");
        }
    }
}

#[test]
fn eval_deterministic_and_prefix_eval_blocks_leakage() {
    require_artifacts!();
    let rt = runtime();
    let prof = rt.profile("tiny").unwrap().clone();
    let cfg = TrainConfig::new("tiny", Method::Fallback, 5, 0);
    let tr = Trainer::new(&rt, cfg).unwrap();
    let corpus = Corpus::synthetic(20_000, prof.vocab, 5);
    let batches = corpus.eval_batches(prof.batch, prof.seq_len, 2);
    let l1 = tr.eval_on(&batches).unwrap();
    let l2 = tr.eval_on(&batches).unwrap();
    assert_eq!(l1, l2);

    // evalp: per-token losses before the prefix must ignore tail edits
    let mut t1: Vec<i32> = (0..prof.seq_len as i32 + 1)
        .map(|i| i % prof.vocab as i32)
        .collect();
    let out1 = rt
        .call(
            "evalp_tiny_fallback",
            &[
                Value::vec_f32(tr.params.clone()),
                Value::mat_i32(t1.clone(), 1, prof.seq_len + 1),
                Value::vec_f32(tr.controller.thresholds.clone()),
                Value::vec_f32(QScalars::default().to_vec()),
                Value::scalar_i32(16),
            ],
        )
        .unwrap();
    for v in t1.iter_mut().skip(20) {
        *v = (*v + 3) % prof.vocab as i32;
    }
    let out2 = rt
        .call(
            "evalp_tiny_fallback",
            &[
                Value::vec_f32(tr.params.clone()),
                Value::mat_i32(t1, 1, prof.seq_len + 1),
                Value::vec_f32(tr.controller.thresholds.clone()),
                Value::vec_f32(QScalars::default().to_vec()),
                Value::scalar_i32(16),
            ],
        )
        .unwrap();
    let p1 = out1[1].as_f32().unwrap();
    let p2 = out2[1].as_f32().unwrap();
    for i in 0..14 {
        let d = (p1[i] - p2[i]).abs();
        assert!(d < 1e-4, "leakage at pos {i}: {d}");
    }
}

#[test]
fn checkpoint_roundtrip() {
    require_artifacts!();
    let rt = runtime();
    let cfg = TrainConfig::new("tiny", Method::Fallback, 9, 5);
    let prof = rt.profile("tiny").unwrap().clone();
    let corpus = Corpus::synthetic(20_000, prof.vocab, 6);
    let mut rng = Pcg64::new(7);
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    for _ in 0..2 {
        let toks = corpus.sample_batch(prof.batch, prof.seq_len, &mut rng);
        tr.step_on(&toks).unwrap();
    }
    let dir = std::env::temp_dir().join("dbfq_ckpt_test");
    let path = dir.to_str().unwrap().to_string();
    tr.save_checkpoint(&path).unwrap();
    let saved = tr.params.clone();
    let cfg2 = TrainConfig::new("tiny", Method::Fallback, 10, 5);
    let mut tr2 = Trainer::new(&rt, cfg2).unwrap();
    assert_ne!(tr2.params, saved);
    tr2.load_checkpoint(&path).unwrap();
    assert_eq!(tr2.params, saved);
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    require_artifacts!();
    let rt = runtime();
    let err = rt.call("init_tiny", &[Value::vec_f32(vec![1.0, 2.0])]);
    assert!(err.is_err());
    let err2 = rt.call("init_tiny", &[]);
    assert!(err2.is_err());
    assert!(rt.call("no_such_artifact", &[]).is_err());
}
