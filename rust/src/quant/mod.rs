//! The DBFQ numeric-format core library (Rust side).
//!
//! Mirrors `python/compile/kernels/ref.py` with identical numerics
//! (ties-to-even rounding, `absmax * (1/L)` scales, exact int32 block
//! accumulation downstream in `gemm`). Cross-validated against the JAX
//! oracles through the op-level HLO artifacts in the runtime tests.

pub mod block;
pub mod fallback;
pub mod granularity;
pub mod group;
pub mod metrics;
pub mod staged;

pub use block::{block_quant, block_quant_threads, int16_block_quant,
                quant_work_counters, BlockQuant, PanelPack,
                PanelPackI4, PanelPackI8, Rounding, INT4_LEVELS,
                INT8_LEVELS};
pub use fallback::{fallback_quant, fallback_quant_threads,
                   theta_for_rate, Criterion, FallbackQuant};
pub use staged::{staged_quant, staged_quant_threads, StagedQuant,
                 Tier, STAGED_F32_KAPPA};
pub use granularity::{granular_quant, switchback_matmul, Granularity};
pub use group::{group_quant, levels_for_bits, GroupQuant};
