//! Per-block (B x B) INT8 quantization — Rust mirror of
//! `python/compile/kernels/ref.py` (paper §3.1).
//!
//! Numerics discipline (kept bit-compatible with the JAX side, asserted
//! by integration tests through the PJRT runtime):
//!   * scale = absmax * (1.0f32 / levels); zero blocks get scale 1.0
//!   * round-to-nearest uses ties-to-even (jnp.round semantics)
//!   * stochastic rounding is floor(x/scale + u), u ~ U[0,1)
//! Values are stored as `i8` here (the real packed format) plus f32
//! scales per block.

use std::cell::Cell;
use std::sync::{Arc, OnceLock};

use crate::util::rng::{Pcg64, SplitMix64};
use crate::util::threadpool::{default_threads, parallel_items};
use crate::util::Mat;

pub const INT8_LEVELS: f32 = 127.0;

/// Symmetric 4-bit code range: codes live in `[-7, 7]` (the nibble
/// value `-8` is deliberately unused so the range stays symmetric,
/// mirroring the i8 convention of `[-127, 127]`). Quantizing with
/// these levels through [`block_quant`] produces a [`BlockQuant`]
/// whose stored `i8` codes are all 4-bit-representable — the
/// `DataPath::Int4` engine path streams them through nibble-packed
/// panels ([`PanelPackI4`]).
pub const INT4_LEVELS: f32 = 7.0;

thread_local! {
    static QUANT_CALLS: Cell<u64> = const { Cell::new(0) };
    static PANEL_PACKS: Cell<u64> = const { Cell::new(0) };
}

/// Thread-local work counters: `(block-quantization calls,
/// column-panel packs built)` on the calling thread since it started.
///
/// `block_quant*` bumps the first (a `fallback_quant` bumps it once,
/// via its base quantization); building either panel pack
/// ([`BlockQuant::col_panels`] / [`BlockQuant::col_panels_i8`]) bumps
/// the second. Both count *invocations on the calling thread* — the
/// worker threads inside a parallel quantization don't touch them —
/// so a test observes exactly the work its own calls triggered even
/// when the test harness runs other tests concurrently. Used by the
/// plan-cache regression tests and `benches/layer_step.rs` to prove
/// that a cache hit skips weight re-quantization and re-packing.
pub fn quant_work_counters() -> (u64, u64) {
    (QUANT_CALLS.with(|c| c.get()), PANEL_PACKS.with(|c| c.get()))
}

/// Column-panel-contiguous f32 view of the int8 codes — the B-operand
/// layout of the GEMM engine's `DataPath::SimF32` *simulation/oracle*
/// path only (see `gemm::engine` docs). The default `Int8` path
/// streams the 4x-smaller [`PanelPackI8`] instead and never builds
/// this view.
///
/// Panel `bj` covers logical columns `bj*block .. min((bj+1)*block,
/// cols)` and stores all `prows` padded rows of that column strip
/// contiguously (row-major within the panel, stride = panel width). The
/// inner GEMM kernel then streams one contiguous panel instead of
/// striding across the full matrix width.
#[derive(Debug, Clone)]
pub struct PanelPack {
    /// panel (block) size the pack was built for
    pub block: usize,
    /// logical (unpadded) column count
    pub cols: usize,
    /// padded row count — rows stored per panel
    pub prows: usize,
    /// offset of panel `bj` in `data`
    pub starts: Vec<usize>,
    /// logical width of panel `bj` (last panel may be narrower)
    pub widths: Vec<usize>,
    /// f32 codes, panel-major
    pub data: Vec<f32>,
}

impl PanelPack {
    /// The contiguous rows of panel `bj` (`prows * widths[bj]` floats).
    #[inline]
    pub fn panel(&self, bj: usize) -> &[f32] {
        let w = self.widths[bj];
        &self.data[self.starts[bj]..self.starts[bj] + self.prows * w]
    }

    /// Resident bytes of the packed codes (4 per element).
    pub fn bytes(&self) -> usize {
        4 * self.data.len()
    }
}

/// Column-panel-contiguous **i8** view of the codes — the true INT8
/// B-operand layout of the GEMM engine's `DataPath::Int8` path. Same
/// panel geometry as [`PanelPack`], but the codes stay 1 byte each, so
/// the packed operand moves 4x fewer bytes than the f32 simulation.
///
/// SIMD contract (the `gemm::kernels` backends stream this layout
/// directly): panel rows are *unpadded* — a vector load at
/// `(k, j)` reads `panel[k*width + j .. +L]`, which the kernels keep
/// in bounds by chunking `j` to full vector widths and finishing the
/// remainder scalar, so no alignment or tail padding is required
/// (`loadu`/`vld1` loads are unaligned-tolerant on every supported
/// ISA and the panel is contiguous, so wide loads never cross into
/// unmapped memory). Padding rows to the vector width was considered
/// and rejected: it would desync `widths[bj]` from the data stride
/// for every consumer of the f32 twin.
#[derive(Debug, Clone)]
pub struct PanelPackI8 {
    /// panel (block) size the pack was built for
    pub block: usize,
    /// logical (unpadded) column count
    pub cols: usize,
    /// padded row count — rows stored per panel
    pub prows: usize,
    /// offset of panel `bj` in `data`
    pub starts: Vec<usize>,
    /// logical width of panel `bj` (last panel may be narrower)
    pub widths: Vec<usize>,
    /// i8 codes, panel-major
    pub data: Vec<i8>,
}

impl PanelPackI8 {
    /// The contiguous rows of panel `bj` (`prows * widths[bj]` codes).
    #[inline]
    pub fn panel(&self, bj: usize) -> &[i8] {
        let w = self.widths[bj];
        &self.data[self.starts[bj]..self.starts[bj] + self.prows * w]
    }

    /// Resident bytes of the packed codes (1 per element).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// Column-panel-contiguous **nibble-packed** view of 4-bit codes —
/// the B-operand layout of the GEMM engine's `DataPath::Int4` path.
/// Same panel geometry as [`PanelPackI8`], but each panel *row* of
/// `width` codes is packed into `width.div_ceil(2)` bytes: byte `j`
/// of a row holds code `2j` in its **low** nibble and code `2j+1` in
/// its **high** nibble (two's-complement 4-bit; an odd row width
/// leaves the final high nibble zero). Rows therefore stay
/// byte-aligned for every panel width, and a packed row is decoded
/// with two shifts per byte: `lo = ((b << 4) as i8) >> 4`,
/// `hi = (b as i8) >> 4`.
///
/// Codes must come from an [`INT4_LEVELS`] quantization (range
/// `[-7, 7]`); packing debug-asserts the range, because a silent
/// nibble truncation of an 8-bit code would corrupt results without
/// any error.
#[derive(Debug, Clone)]
pub struct PanelPackI4 {
    /// panel (block) size the pack was built for
    pub block: usize,
    /// logical (unpadded) column count
    pub cols: usize,
    /// padded row count — rows stored per panel
    pub prows: usize,
    /// offset of panel `bj` in `data` (bytes)
    pub starts: Vec<usize>,
    /// logical width of panel `bj` (codes, not bytes)
    pub widths: Vec<usize>,
    /// packed nibbles, panel-major; row `k` of panel `bj` occupies
    /// `widths[bj].div_ceil(2)` bytes
    pub data: Vec<u8>,
}

impl PanelPackI4 {
    /// Bytes per packed row of panel `bj`.
    #[inline]
    pub fn row_bytes(&self, bj: usize) -> usize {
        self.widths[bj].div_ceil(2)
    }

    /// The contiguous packed rows of panel `bj`
    /// (`prows * row_bytes(bj)` bytes).
    #[inline]
    pub fn panel(&self, bj: usize) -> &[u8] {
        let rw = self.row_bytes(bj);
        &self.data[self.starts[bj]..self.starts[bj] + self.prows * rw]
    }

    /// Resident bytes of the packed codes (two codes per byte).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// Pack two 4-bit codes into one byte (`lo` in the low nibble).
#[inline]
fn pack_nibbles(lo: i8, hi: i8) -> u8 {
    debug_assert!(
        (-7..=7).contains(&lo) && (-7..=7).contains(&hi),
        "nibble-packing codes outside [-7, 7] (lo={lo} hi={hi}) — \
         operand was not quantized with INT4_LEVELS"
    );
    (lo as u8 & 0x0F) | ((hi as u8 & 0x0F) << 4)
}

/// Column-panel packing shared by the f32 and i8 views: walk panels
/// left to right, copy each panel's `prows` rows contiguously, apply
/// `conv` per code. Returns `(starts, widths, data)`.
fn pack_col_panels<T: Copy, U>(
    q: &[T], prows: usize, pcols: usize, cols: usize, bs: usize,
    conv: impl Fn(T) -> U,
) -> (Vec<usize>, Vec<usize>, Vec<U>) {
    PANEL_PACKS.with(|c| c.set(c.get() + 1));
    let cb = pcols / bs;
    let mut starts = Vec::with_capacity(cb);
    let mut widths = Vec::with_capacity(cb);
    let mut data = Vec::with_capacity(prows * cols);
    for bj in 0..cb {
        let c_lo = bj * bs;
        let c_hi = ((bj + 1) * bs).min(cols);
        let width = c_hi - c_lo;
        starts.push(data.len());
        widths.push(width);
        for k in 0..prows {
            let row = &q[k * pcols + c_lo..k * pcols + c_hi];
            data.extend(row.iter().map(|&v| conv(v)));
        }
    }
    (starts, widths, data)
}

/// Block-quantized matrix: q holds int8 codes in row-major order of the
/// *padded* (block-aligned) matrix; scales/absmax are (rb x cb).
///
/// Caching invariant: the packed views handed out by [`codes_f32`],
/// [`col_panels`] and [`col_panels_i8`] are computed once and reused
/// by every subsequent GEMM over the same operand (weights in
/// particular — the plan cache in `gemm::pipeline` keeps them alive
/// across training steps), so `q` must not be mutated after the first
/// GEMM — treat a `BlockQuant` as frozen once built. On the engine's
/// default `DataPath::Int8` path only the i8 panel pack is ever
/// materialized; the f32 views serve the `SimF32` oracle path and are
/// built lazily on first demand.
///
/// [`codes_f32`]: BlockQuant::codes_f32
/// [`col_panels`]: BlockQuant::col_panels
/// [`col_panels_i8`]: BlockQuant::col_panels_i8
#[derive(Debug, Clone)]
pub struct BlockQuant {
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    /// padded dims
    pub prows: usize,
    pub pcols: usize,
    pub q: Vec<i8>,
    pub scale: Vec<f32>,
    pub absmax: Vec<f32>,
    /// lazily cached row-major f32 copy of `q` (SimF32 path only)
    f32_cache: OnceLock<Arc<Vec<f32>>>,
    /// lazily cached f32 column-panel pack of `q` (SimF32 path only)
    panel_cache: OnceLock<Arc<PanelPack>>,
    /// lazily cached i8 column-panel pack of `q` (Int8 path)
    i8_panel_cache: OnceLock<Arc<PanelPackI8>>,
    /// lazily cached nibble-packed column panels of `q` (Int4 path;
    /// only valid for INT4_LEVELS quantizations)
    i4_panel_cache: OnceLock<Arc<PanelPackI4>>,
}

impl BlockQuant {
    pub fn rb(&self) -> usize {
        self.prows / self.block
    }

    pub fn cb(&self) -> usize {
        self.pcols / self.block
    }

    #[inline]
    pub fn scale_at(&self, br: usize, bc: usize) -> f32 {
        self.scale[br * self.cb() + bc]
    }

    #[inline]
    pub fn q_at(&self, r: usize, c: usize) -> i8 {
        self.q[r * self.pcols + c]
    }

    /// Dequantize back to the original (cropped) shape.
    pub fn dequant(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let br = r / self.block;
            for c in 0..self.cols {
                let bc = c / self.block;
                m.data[r * self.cols + c] =
                    self.q[r * self.pcols + c] as f32 * self.scale_at(br, bc);
            }
        }
        m
    }

    /// Stored size in bytes (int8 codes + f32 scales) — ACT-MEM accounting.
    pub fn bytes(&self) -> usize {
        self.q.len() + 4 * self.scale.len()
    }

    /// Cached f32 copy of the int8 codes (same padded row-major
    /// layout) — the A-operand view of the engine's
    /// `DataPath::SimF32` oracle path only. The default `Int8` path
    /// streams `q` zero-copy and never materializes this copy.
    ///
    /// Products and in-block sums of int8 codes stay below 2^24, so
    /// f32 kernels over this view are bit-exact to int32 accumulation.
    /// The copy is made on first use and shared by every later SimF32
    /// GEMM over the same operand.
    pub fn codes_f32(&self) -> Arc<Vec<f32>> {
        self.f32_cache
            .get_or_init(|| {
                Arc::new(self.q.iter().map(|&v| v as f32).collect())
            })
            .clone()
    }

    /// Cached f32 column-panel pack of the codes — the B-operand layout
    /// of the engine's `DataPath::SimF32` path (see [`PanelPack`]).
    /// Built on first use.
    pub fn col_panels(&self) -> Arc<PanelPack> {
        self.panel_cache
            .get_or_init(|| {
                let (starts, widths, data) = pack_col_panels(
                    &self.q, self.prows, self.pcols, self.cols,
                    self.block, |v| v as f32,
                );
                Arc::new(PanelPack {
                    block: self.block,
                    cols: self.cols,
                    prows: self.prows,
                    starts,
                    widths,
                    data,
                })
            })
            .clone()
    }

    /// Cached **i8** column-panel pack of the codes — the B-operand
    /// layout of the engine's `DataPath::Int8` path (see
    /// [`PanelPackI8`]). Built on first use; a quarter the bytes of
    /// [`col_panels`](BlockQuant::col_panels).
    pub fn col_panels_i8(&self) -> Arc<PanelPackI8> {
        self.i8_panel_cache
            .get_or_init(|| {
                let (starts, widths, data) = pack_col_panels(
                    &self.q, self.prows, self.pcols, self.cols,
                    self.block, |v| v,
                );
                Arc::new(PanelPackI8 {
                    block: self.block,
                    cols: self.cols,
                    prows: self.prows,
                    starts,
                    widths,
                    data,
                })
            })
            .clone()
    }

    /// Cached **nibble-packed** column panels of the codes — the
    /// B-operand layout of the engine's `DataPath::Int4` path (see
    /// [`PanelPackI4`]). Built on first use; half the bytes of
    /// [`col_panels_i8`](BlockQuant::col_panels_i8). Valid only when
    /// the operand was quantized with [`INT4_LEVELS`] (codes in
    /// `[-7, 7]`) — packing debug-asserts the range.
    pub fn col_panels_i4(&self) -> Arc<PanelPackI4> {
        self.i4_panel_cache
            .get_or_init(|| {
                PANEL_PACKS.with(|c| c.set(c.get() + 1));
                let cb = self.pcols / self.block;
                let mut starts = Vec::with_capacity(cb);
                let mut widths = Vec::with_capacity(cb);
                let mut data: Vec<u8> = Vec::new();
                for bj in 0..cb {
                    let c_lo = bj * self.block;
                    let c_hi = ((bj + 1) * self.block).min(self.cols);
                    let width = c_hi - c_lo;
                    let rw = width.div_ceil(2);
                    starts.push(data.len());
                    widths.push(width);
                    for k in 0..self.prows {
                        let row =
                            &self.q[k * self.pcols + c_lo..k * self.pcols + c_hi];
                        for b in 0..rw {
                            let lo = row[2 * b];
                            let hi =
                                if 2 * b + 1 < width { row[2 * b + 1] } else { 0 };
                            data.push(pack_nibbles(lo, hi));
                        }
                    }
                }
                Arc::new(PanelPackI4 {
                    block: self.block,
                    cols: self.cols,
                    prows: self.prows,
                    starts,
                    widths,
                    data,
                })
            })
            .clone()
    }

    /// The transposed quantization, built by **permuting** the stored
    /// codes and per-block grids instead of re-running quantization on
    /// `xᵀ`.
    ///
    /// For [`Rounding::Nearest`] this is *bit-identical* to
    /// `block_quant(&x.transpose(), ..)`: per-block absmax (a max over
    /// the same elements) and scale are symmetric under transposition,
    /// padding is symmetric (`prows`/`pcols` swap), and nearest
    /// rounding is elementwise-deterministic. Stochastically-rounded
    /// quantizations do **not** transpose this way (per-block RNG
    /// streams are indexed by block position), so callers on the SR
    /// path must re-quantize.
    ///
    /// Deliberately does *not* bump the quantization work counter —
    /// this is a permutation, not a quantization pass — which is what
    /// makes the saving visible to the plan-cache counter tests. The
    /// packed-view caches start empty (panel layouts do not permute).
    pub fn transposed(&self) -> BlockQuant {
        let (tprows, tpcols) = (self.pcols, self.prows);
        let mut q = vec![0i8; self.q.len()];
        for r in 0..self.prows {
            let row = &self.q[r * self.pcols..(r + 1) * self.pcols];
            for (c, &v) in row.iter().enumerate() {
                q[c * tpcols + r] = v;
            }
        }
        let (rb, cb) = (self.rb(), self.cb());
        let mut scale = vec![1.0f32; rb * cb];
        let mut absmax = vec![0.0f32; rb * cb];
        for br in 0..rb {
            for bc in 0..cb {
                scale[bc * rb + br] = self.scale[br * cb + bc];
                absmax[bc * rb + br] = self.absmax[br * cb + bc];
            }
        }
        BlockQuant {
            rows: self.cols,
            cols: self.rows,
            block: self.block,
            prows: tprows,
            pcols: tpcols,
            q,
            scale,
            absmax,
            f32_cache: OnceLock::new(),
            panel_cache: OnceLock::new(),
            i8_panel_cache: OnceLock::new(),
            i4_panel_cache: OnceLock::new(),
        }
    }

    /// Whether the f32 code copy has been materialized. The Int8 data
    /// path must leave this `false` (the 4x resident-set saving); the
    /// SimF32 oracles build it lazily on demand.
    pub fn f32_codes_built(&self) -> bool {
        self.f32_cache.get().is_some()
    }

    /// Whether the f32 column-panel pack has been materialized.
    pub fn f32_panels_built(&self) -> bool {
        self.panel_cache.get().is_some()
    }

    /// Whether the i8 column-panel pack has been materialized.
    pub fn i8_panels_built(&self) -> bool {
        self.i8_panel_cache.get().is_some()
    }

    /// Whether the nibble-packed column panels have been materialized.
    pub fn i4_panels_built(&self) -> bool {
        self.i4_panel_cache.get().is_some()
    }
}

fn pad_up(n: usize, b: usize) -> usize {
    n.div_ceil(b) * b
}

#[inline]
pub fn safe_scale(absmax: f32, levels: f32) -> f32 {
    if absmax > 0.0 {
        absmax * (1.0f32 / levels)
    } else {
        1.0
    }
}

/// Rounding mode for the quantization step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Rounding {
    /// Round-to-nearest, ties to even (matches `jnp.round`).
    Nearest,
    /// Stochastic rounding with the given RNG seed.
    Stochastic(u64),
}

/// Deterministic per-block RNG stream for stochastic rounding: the
/// user seed is SplitMix64-expanded once, then xor-folded with a
/// golden-ratio multiple of the block index. Every block draws from
/// its own stream, so quantization results are identical for every
/// worker count and block visit order.
fn block_stream(seed: u64, bi: u64) -> Pcg64 {
    let mut sm = SplitMix64(seed);
    let base = sm.next();
    Pcg64::new(base ^ bi.wrapping_add(1).wrapping_mul(0x9e3779b97f4a7c15))
}

/// Quantize one block row (`block` matrix rows, all column blocks):
/// absmax sweep, scale, rounding. `qrow` is the block row's slice of
/// the padded code matrix; `srow`/`arow` its row of the scale/absmax
/// grids.
#[allow(clippy::too_many_arguments)]
fn quant_block_row(
    x: &Mat, block: usize, levels: f32, rounding: Rounding, br: usize,
    pcols: usize, qrow: &mut [i8], srow: &mut [f32], arow: &mut [f32],
) {
    let cb = srow.len();
    let r0 = br * block;
    let r1 = (r0 + block).min(x.rows);
    for bc in 0..cb {
        let c0 = bc * block;
        let c1 = (c0 + block).min(x.cols);
        let mut am = 0.0f32;
        for r in r0..r1 {
            for c in c0..c1 {
                am = am.max(x.at(r, c).abs());
            }
        }
        let s = safe_scale(am, levels);
        arow[bc] = am;
        srow[bc] = s;
        let inv = 1.0 / s;
        let mut rng = match rounding {
            Rounding::Stochastic(seed) => {
                Some(block_stream(seed, (br * cb + bc) as u64))
            }
            Rounding::Nearest => None,
        };
        for r in r0..r1 {
            for c in c0..c1 {
                let v = x.at(r, c) * inv;
                let rounded = match &mut rng {
                    None => v.round_ties_even(),
                    Some(rng) => (v + rng.uniform_f32()).floor(),
                };
                qrow[(r - r0) * pcols + c] =
                    rounded.clamp(-levels, levels) as i8;
            }
        }
    }
}

/// Quantize with per-(B x B)-block absmax scaling. Runs on
/// [`default_threads`] workers dispatched through the persistent
/// runtime ([`crate::util::pool`] via [`parallel_items`] — no
/// per-call thread spawns); see [`block_quant_threads`] for explicit
/// control. Results are bitwise thread-count-independent: each block
/// row owns disjoint output slices and stochastic rounding draws
/// from per-block RNG streams.
pub fn block_quant(x: &Mat, block: usize, levels: f32,
                   rounding: Rounding) -> BlockQuant {
    block_quant_threads(x, block, levels, rounding, default_threads())
}

/// [`block_quant`] with an explicit worker count (block rows are the
/// parallel unit).
pub fn block_quant_threads(x: &Mat, block: usize, levels: f32,
                           rounding: Rounding, threads: usize)
                           -> BlockQuant {
    QUANT_CALLS.with(|c| c.set(c.get() + 1));
    let prows = pad_up(x.rows, block);
    let pcols = pad_up(x.cols, block);
    let rb = prows / block;
    let cb = pcols / block;
    let mut q = vec![0i8; prows * pcols];
    let mut scale = vec![1.0f32; rb * cb];
    let mut absmax = vec![0.0f32; rb * cb];

    if rb > 0 && cb > 0 {
        // One work item per block row: its `block` rows of `q` plus
        // its row of the scale/absmax grids — disjoint by construction.
        let items: Vec<_> = q
            .chunks_mut(block * pcols)
            .zip(scale.chunks_mut(cb).zip(absmax.chunks_mut(cb)))
            .collect();
        parallel_items(items, threads, |br, (qrow, (srow, arow))| {
            quant_block_row(
                x, block, levels, rounding, br, pcols, qrow, srow, arow,
            );
        });
    }
    BlockQuant {
        rows: x.rows,
        cols: x.cols,
        block,
        prows,
        pcols,
        q,
        scale,
        absmax,
        f32_cache: OnceLock::new(),
        panel_cache: OnceLock::new(),
        i8_panel_cache: OnceLock::new(),
        i4_panel_cache: OnceLock::new(),
    }
}

/// INT16-style "double-bit" quantization comparator (Fig 3b): a single
/// scale with 2^15-1 levels; codes stored as i16.
pub struct Int16Quant {
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    pub prows: usize,
    pub pcols: usize,
    pub q: Vec<i16>,
    pub scale: Vec<f32>,
}

pub fn int16_block_quant(x: &Mat, block: usize) -> Int16Quant {
    let levels = 32767.0f32;
    let prows = pad_up(x.rows, block);
    let pcols = pad_up(x.cols, block);
    let rb = prows / block;
    let cb = pcols / block;
    let mut q = vec![0i16; prows * pcols];
    let mut scale = vec![1.0f32; rb * cb];
    for br in 0..rb {
        for bc in 0..cb {
            let (r0, c0) = (br * block, bc * block);
            let mut am = 0.0f32;
            for r in r0..(r0 + block).min(x.rows) {
                for c in c0..(c0 + block).min(x.cols) {
                    am = am.max(x.at(r, c).abs());
                }
            }
            let s = safe_scale(am, levels);
            scale[br * cb + bc] = s;
            for r in r0..(r0 + block).min(x.rows) {
                for c in c0..(c0 + block).min(x.cols) {
                    let v = (x.at(r, c) / s).round_ties_even();
                    q[r * pcols + c] = v.clamp(-levels, levels) as i16;
                }
            }
        }
    }
    Int16Quant { rows: x.rows, cols: x.cols, block, prows, pcols, q, scale }
}

impl Int16Quant {
    pub fn dequant(&self) -> Mat {
        let cb = self.pcols / self.block;
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let s = self.scale[(r / self.block) * cb + c / self.block];
                m.data[r * self.cols + c] =
                    self.q[r * self.pcols + c] as f32 * s;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn randmat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        Mat::randn(rows, cols, 3.0, &mut rng)
    }

    #[test]
    fn roundtrip_error_bound() {
        let x = randmat(40, 24, 1);
        let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
        let d = bq.dequant();
        for r in 0..x.rows {
            for c in 0..x.cols {
                let s = bq.scale_at(r / 16, c / 16);
                assert!((d.at(r, c) - x.at(r, c)).abs() <= s / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn zero_block_exact() {
        let x = Mat::zeros(16, 16);
        let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
        assert!(bq.q.iter().all(|&q| q == 0));
        assert_eq!(bq.scale[0], 1.0);
        assert_eq!(bq.dequant().data, x.data);
    }

    #[test]
    fn codes_in_range() {
        let x = randmat(32, 32, 2);
        let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
        assert!(bq.q.iter().all(|&q| (-127..=127).contains(&(q as i32))));
    }

    #[test]
    fn padding_crops_correctly() {
        let x = randmat(33, 17, 3);
        let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
        assert_eq!(bq.prows, 48);
        assert_eq!(bq.pcols, 32);
        let d = bq.dequant();
        assert_eq!(d.rows, 33);
        assert_eq!(d.cols, 17);
    }

    #[test]
    fn ties_to_even() {
        // 2.5 rounds to 2, 3.5 rounds to 4 under ties-even.
        assert_eq!(2.5f32.round_ties_even(), 2.0);
        assert_eq!(3.5f32.round_ties_even(), 4.0);
        // Build a block whose absmax=127 so scale=1 and codes equal values.
        let mut x = Mat::zeros(16, 16);
        x.data[0] = 127.0;
        x.data[1] = 2.5;
        x.data[2] = 3.5;
        let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
        assert_eq!(bq.q[0], 127);
        assert_eq!(bq.q[1], 2);
        assert_eq!(bq.q[2], 4);
    }

    #[test]
    fn stochastic_rounding_unbiased() {
        let x = randmat(16, 16, 5);
        let mut acc = vec![0.0f64; 256];
        let trials = 400;
        for t in 0..trials {
            let bq = block_quant(&x, 16, INT8_LEVELS,
                                 Rounding::Stochastic(1000 + t));
            let d = bq.dequant();
            for (a, v) in acc.iter_mut().zip(&d.data) {
                *a += *v as f64;
            }
        }
        let scale = x.abs_max() / 127.0;
        let tol = 5.0 * scale as f64 / (trials as f64).sqrt();
        for (a, v) in acc.iter().zip(&x.data) {
            assert!((a / trials as f64 - *v as f64).abs() < tol + 1e-6);
        }
    }

    #[test]
    fn packed_views_match_codes() {
        let x = randmat(40, 41, 9); // non-multiple-of-block shape
        let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
        let f = bq.codes_f32();
        assert_eq!(f.len(), bq.q.len());
        for (a, &b) in f.iter().zip(bq.q.iter()) {
            assert_eq!(*a, b as f32);
        }
        // cache: same allocation returned on the second call
        assert!(Arc::ptr_eq(&f, &bq.codes_f32()));

        let p = bq.col_panels();
        assert_eq!(p.widths.len(), bq.cb());
        assert_eq!(p.widths.iter().sum::<usize>(), bq.cols);
        for bj in 0..bq.cb() {
            let panel = p.panel(bj);
            let (c_lo, w) = (bj * bq.block, p.widths[bj]);
            for k in 0..bq.prows {
                for j in 0..w {
                    assert_eq!(panel[k * w + j],
                               bq.q[k * bq.pcols + c_lo + j] as f32,
                               "panel {bj} row {k} col {j}");
                }
            }
        }
        assert!(Arc::ptr_eq(&p, &bq.col_panels()));

        // i8 pack mirrors the f32 pack exactly, at 1/4 the bytes
        let pi = bq.col_panels_i8();
        assert_eq!(pi.starts, p.starts);
        assert_eq!(pi.widths, p.widths);
        assert_eq!(pi.data.len(), p.data.len());
        for (a, &b) in p.data.iter().zip(pi.data.iter()) {
            assert_eq!(*a, b as f32);
        }
        assert_eq!(4 * pi.bytes(), p.bytes());
        assert!(Arc::ptr_eq(&pi, &bq.col_panels_i8()));
    }

    #[test]
    fn int4_codes_in_range_and_nibble_pack_roundtrips() {
        // Odd widths included so the zero-filled final high nibble and
        // the byte-aligned row stride are both exercised.
        for (rows, cols) in [(32usize, 32usize), (40, 41), (17, 23)] {
            let x = randmat(rows, cols, 77 + cols as u64);
            let bq = block_quant(&x, 16, INT4_LEVELS, Rounding::Nearest);
            assert!(bq.q.iter().all(|&q| (-7..=7).contains(&(q as i32))));
            let p4 = bq.col_panels_i4();
            assert_eq!(p4.widths.len(), bq.cb());
            assert_eq!(p4.widths.iter().sum::<usize>(), bq.cols);
            for bj in 0..bq.cb() {
                let panel = p4.panel(bj);
                let (c_lo, w) = (bj * bq.block, p4.widths[bj]);
                let rw = p4.row_bytes(bj);
                assert_eq!(panel.len(), bq.prows * rw);
                for k in 0..bq.prows {
                    for j in 0..w {
                        let byte = panel[k * rw + j / 2];
                        let code = if j % 2 == 0 {
                            ((byte << 4) as i8) >> 4
                        } else {
                            (byte as i8) >> 4
                        };
                        assert_eq!(code,
                                   bq.q[k * bq.pcols + c_lo + j],
                                   "panel {bj} row {k} col {j}");
                    }
                    if w % 2 == 1 {
                        // odd width: final high nibble must be zero
                        assert_eq!(panel[k * rw + rw - 1] >> 4, 0);
                    }
                }
            }
            // cached — same allocation, and exactly one pack counted
            let (_, p0) = quant_work_counters();
            assert!(Arc::ptr_eq(&p4, &bq.col_panels_i4()));
            let (_, p1) = quant_work_counters();
            assert_eq!(p1 - p0, 0);
            // half the i8 pack's bytes (up to odd-width rounding)
            let pi8 = bq.col_panels_i8();
            assert!(p4.bytes() <= pi8.bytes() / 2 + bq.prows * bq.cb());
        }
    }

    #[test]
    fn int4_stochastic_rounding_unbiased() {
        let x = randmat(16, 16, 21);
        let mut acc = vec![0.0f64; 256];
        let trials = 400;
        for t in 0..trials {
            let bq = block_quant(&x, 16, INT4_LEVELS,
                                 Rounding::Stochastic(2000 + t));
            let d = bq.dequant();
            for (a, v) in acc.iter_mut().zip(&d.data) {
                *a += *v as f64;
            }
        }
        let scale = x.abs_max() / 7.0;
        let tol = 5.0 * scale as f64 / (trials as f64).sqrt();
        for (a, v) in acc.iter().zip(&x.data) {
            assert!((a / trials as f64 - *v as f64).abs() < tol + 1e-6);
        }
    }

    #[test]
    fn transposed_bit_identical_to_requantized_transpose() {
        // Pin the permutation against the ground truth: a fresh
        // Nearest quantization of xᵀ — including a non-multiple-of-
        // block shape so the padding swap is exercised.
        for (rows, cols) in [(32usize, 32usize), (40, 23), (17, 49)] {
            let x = randmat(rows, cols, 31 + rows as u64);
            let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
            let (q0, p0) = quant_work_counters();
            let bt = bq.transposed();
            let (q1, p1) = quant_work_counters();
            assert_eq!((q1 - q0, p1 - p0), (0, 0),
                       "a permutation must not count as quant work");
            let fresh = block_quant(&x.transpose(), 16, INT8_LEVELS,
                                    Rounding::Nearest);
            assert_eq!(bt.rows, fresh.rows);
            assert_eq!(bt.cols, fresh.cols);
            assert_eq!(bt.prows, fresh.prows);
            assert_eq!(bt.pcols, fresh.pcols);
            assert_eq!(bt.q, fresh.q, "({rows},{cols}) codes");
            assert_eq!(bt.scale, fresh.scale);
            assert_eq!(bt.absmax, fresh.absmax);
        }
    }

    #[test]
    fn work_counters_track_this_threads_calls() {
        // Counters are thread-local, so this test's deltas are exact
        // even under a concurrent test harness.
        let x = randmat(32, 32, 13);
        let (q0, p0) = quant_work_counters();
        let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
        let (q1, p1) = quant_work_counters();
        assert_eq!(q1 - q0, 1);
        assert_eq!(p1 - p0, 0);
        bq.col_panels_i8();
        bq.col_panels_i8(); // cached — no second pack
        bq.col_panels();
        let (q2, p2) = quant_work_counters();
        assert_eq!(q2 - q1, 0);
        assert_eq!(p2 - p1, 2);
    }

    #[test]
    fn cache_introspection_tracks_materialization() {
        let x = randmat(32, 32, 12);
        let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
        assert!(!bq.f32_codes_built());
        assert!(!bq.f32_panels_built());
        assert!(!bq.i8_panels_built());
        bq.col_panels_i8();
        assert!(bq.i8_panels_built());
        assert!(!bq.f32_codes_built() && !bq.f32_panels_built());
        bq.codes_f32();
        bq.col_panels();
        assert!(bq.f32_codes_built() && bq.f32_panels_built());
    }

    #[test]
    fn parallel_quant_thread_count_invariant() {
        // Regression: block rows quantize in parallel with per-block
        // stochastic-rounding streams — results must be bitwise
        // identical for every worker count.
        let x = randmat(70, 50, 11); // non-multiple-of-block shape
        for rounding in [Rounding::Nearest, Rounding::Stochastic(42)] {
            let q1 = block_quant_threads(&x, 16, INT8_LEVELS,
                                         rounding, 1);
            for threads in [2usize, 4, 7] {
                let qt = block_quant_threads(&x, 16, INT8_LEVELS,
                                             rounding, threads);
                assert_eq!(q1.q, qt.q, "{rounding:?} x{threads}");
                assert_eq!(q1.scale, qt.scale);
                assert_eq!(q1.absmax, qt.absmax);
            }
        }
    }

    #[test]
    fn stochastic_streams_differ_per_block() {
        // Same sub-block values in different blocks must not share an
        // RNG stream (independence across blocks).
        let mut x = Mat::zeros(32, 16);
        for (i, v) in x.data.iter_mut().enumerate() {
            // identical 16x16 pattern in both block rows
            *v = ((i % 256) as f32) / 51.0 + 0.37;
        }
        let bq = block_quant(&x, 16, INT8_LEVELS,
                             Rounding::Stochastic(9));
        let top = &bq.q[..16 * bq.pcols];
        let bot = &bq.q[16 * bq.pcols..32 * bq.pcols];
        assert_ne!(top, bot, "per-block streams collapsed");
    }

    #[test]
    fn int16_more_accurate_than_int8_without_outliers() {
        let x = randmat(32, 32, 7);
        let e8 = {
            let d = block_quant(&x, 16, INT8_LEVELS,
                                Rounding::Nearest).dequant();
            crate::quant::metrics::rmse(&d.data, &x.data)
        };
        let e16 = {
            let d = int16_block_quant(&x, 16).dequant();
            crate::quant::metrics::rmse(&d.data, &x.data)
        };
        assert!(e16 < e8 / 100.0, "e16={e16} e8={e8}");
    }
}
