//! Per-block (B x B) INT8 quantization — Rust mirror of
//! `python/compile/kernels/ref.py` (paper §3.1).
//!
//! Numerics discipline (kept bit-compatible with the JAX side, asserted
//! by integration tests through the PJRT runtime):
//!   * scale = absmax * (1.0f32 / levels); zero blocks get scale 1.0
//!   * round-to-nearest uses ties-to-even (jnp.round semantics)
//!   * stochastic rounding is floor(x/scale + u), u ~ U[0,1)
//! Values are stored as `i8` here (the real packed format) plus f32
//! scales per block.

use std::sync::{Arc, OnceLock};

use crate::util::rng::Pcg64;
use crate::util::Mat;

pub const INT8_LEVELS: f32 = 127.0;

/// Column-panel-contiguous f32 view of the int8 codes, the layout the
/// GEMM engine consumes for its **B** operand (see `gemm::engine` docs).
///
/// Panel `bj` covers logical columns `bj*block .. min((bj+1)*block,
/// cols)` and stores all `prows` padded rows of that column strip
/// contiguously (row-major within the panel, stride = panel width). The
/// inner GEMM kernel then streams one contiguous panel instead of
/// striding across the full matrix width.
#[derive(Debug, Clone)]
pub struct PanelPack {
    /// panel (block) size the pack was built for
    pub block: usize,
    /// logical (unpadded) column count
    pub cols: usize,
    /// padded row count — rows stored per panel
    pub prows: usize,
    /// offset of panel `bj` in `data`
    pub starts: Vec<usize>,
    /// logical width of panel `bj` (last panel may be narrower)
    pub widths: Vec<usize>,
    /// f32 codes, panel-major
    pub data: Vec<f32>,
}

impl PanelPack {
    /// The contiguous rows of panel `bj` (`prows * widths[bj]` floats).
    #[inline]
    pub fn panel(&self, bj: usize) -> &[f32] {
        let w = self.widths[bj];
        &self.data[self.starts[bj]..self.starts[bj] + self.prows * w]
    }
}

/// Block-quantized matrix: q holds int8 codes in row-major order of the
/// *padded* (block-aligned) matrix; scales/absmax are (rb x cb).
///
/// Caching invariant: the packed-f32 views handed out by [`codes_f32`]
/// and [`col_panels`] are computed once and reused for every subsequent
/// GEMM over the same operand (weights in particular), so `q` must not
/// be mutated after the first GEMM — treat a `BlockQuant` as frozen
/// once built.
///
/// [`codes_f32`]: BlockQuant::codes_f32
/// [`col_panels`]: BlockQuant::col_panels
#[derive(Debug, Clone)]
pub struct BlockQuant {
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    /// padded dims
    pub prows: usize,
    pub pcols: usize,
    pub q: Vec<i8>,
    pub scale: Vec<f32>,
    pub absmax: Vec<f32>,
    /// lazily cached row-major f32 copy of `q`
    f32_cache: OnceLock<Arc<Vec<f32>>>,
    /// lazily cached column-panel pack of `q`
    panel_cache: OnceLock<Arc<PanelPack>>,
}

impl BlockQuant {
    pub fn rb(&self) -> usize {
        self.prows / self.block
    }

    pub fn cb(&self) -> usize {
        self.pcols / self.block
    }

    #[inline]
    pub fn scale_at(&self, br: usize, bc: usize) -> f32 {
        self.scale[br * self.cb() + bc]
    }

    #[inline]
    pub fn q_at(&self, r: usize, c: usize) -> i8 {
        self.q[r * self.pcols + c]
    }

    /// Dequantize back to the original (cropped) shape.
    pub fn dequant(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let br = r / self.block;
            for c in 0..self.cols {
                let bc = c / self.block;
                m.data[r * self.cols + c] =
                    self.q[r * self.pcols + c] as f32 * self.scale_at(br, bc);
            }
        }
        m
    }

    /// Stored size in bytes (int8 codes + f32 scales) — ACT-MEM accounting.
    pub fn bytes(&self) -> usize {
        self.q.len() + 4 * self.scale.len()
    }

    /// Cached f32 copy of the int8 codes (same padded row-major layout).
    ///
    /// Products and in-block sums of int8 codes stay below 2^24, so f32
    /// kernels over this view are bit-exact to int32 accumulation while
    /// vectorizing far better on CPUs without an int8 dot ISA. The copy
    /// is made on first use and shared by every later GEMM — repeated
    /// GEMMs over the same operand (e.g. weights) skip re-conversion.
    pub fn codes_f32(&self) -> Arc<Vec<f32>> {
        self.f32_cache
            .get_or_init(|| {
                Arc::new(self.q.iter().map(|&v| v as f32).collect())
            })
            .clone()
    }

    /// Cached column-panel pack of the codes — the B-operand layout of
    /// `gemm::engine` (see [`PanelPack`]). Built on first use.
    pub fn col_panels(&self) -> Arc<PanelPack> {
        self.panel_cache
            .get_or_init(|| {
                let bs = self.block;
                let cb = self.cb();
                let mut starts = Vec::with_capacity(cb);
                let mut widths = Vec::with_capacity(cb);
                let mut data = Vec::with_capacity(self.prows * self.cols);
                for bj in 0..cb {
                    let c_lo = bj * bs;
                    let c_hi = ((bj + 1) * bs).min(self.cols);
                    let width = c_hi - c_lo;
                    starts.push(data.len());
                    widths.push(width);
                    for k in 0..self.prows {
                        let row = &self.q[k * self.pcols + c_lo
                                          ..k * self.pcols + c_hi];
                        data.extend(row.iter().map(|&v| v as f32));
                    }
                }
                Arc::new(PanelPack {
                    block: bs,
                    cols: self.cols,
                    prows: self.prows,
                    starts,
                    widths,
                    data,
                })
            })
            .clone()
    }
}

fn pad_up(n: usize, b: usize) -> usize {
    n.div_ceil(b) * b
}

#[inline]
pub fn safe_scale(absmax: f32, levels: f32) -> f32 {
    if absmax > 0.0 {
        absmax * (1.0f32 / levels)
    } else {
        1.0
    }
}

/// Rounding mode for the quantization step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Rounding {
    /// Round-to-nearest, ties to even (matches `jnp.round`).
    Nearest,
    /// Stochastic rounding with the given RNG seed.
    Stochastic(u64),
}

/// Quantize with per-(B x B)-block absmax scaling.
pub fn block_quant(x: &Mat, block: usize, levels: f32,
                   rounding: Rounding) -> BlockQuant {
    let prows = pad_up(x.rows, block);
    let pcols = pad_up(x.cols, block);
    let rb = prows / block;
    let cb = pcols / block;
    let mut q = vec![0i8; prows * pcols];
    let mut scale = vec![1.0f32; rb * cb];
    let mut absmax = vec![0.0f32; rb * cb];
    let mut rng = match rounding {
        Rounding::Stochastic(seed) => Some(Pcg64::new(seed)),
        Rounding::Nearest => None,
    };

    for br in 0..rb {
        for bc in 0..cb {
            let r0 = br * block;
            let c0 = bc * block;
            let mut am = 0.0f32;
            for r in r0..(r0 + block).min(x.rows) {
                for c in c0..(c0 + block).min(x.cols) {
                    am = am.max(x.at(r, c).abs());
                }
            }
            let s = safe_scale(am, levels);
            absmax[br * cb + bc] = am;
            scale[br * cb + bc] = s;
            let inv = 1.0 / s;
            for r in r0..(r0 + block).min(x.rows) {
                for c in c0..(c0 + block).min(x.cols) {
                    let v = x.at(r, c) * inv;
                    let rounded = match &mut rng {
                        None => v.round_ties_even(),
                        Some(rng) => (v + rng.uniform_f32()).floor(),
                    };
                    q[r * pcols + c] =
                        rounded.clamp(-levels, levels) as i8;
                }
            }
        }
    }
    BlockQuant {
        rows: x.rows,
        cols: x.cols,
        block,
        prows,
        pcols,
        q,
        scale,
        absmax,
        f32_cache: OnceLock::new(),
        panel_cache: OnceLock::new(),
    }
}

/// INT16-style "double-bit" quantization comparator (Fig 3b): a single
/// scale with 2^15-1 levels; codes stored as i16.
pub struct Int16Quant {
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    pub prows: usize,
    pub pcols: usize,
    pub q: Vec<i16>,
    pub scale: Vec<f32>,
}

pub fn int16_block_quant(x: &Mat, block: usize) -> Int16Quant {
    let levels = 32767.0f32;
    let prows = pad_up(x.rows, block);
    let pcols = pad_up(x.cols, block);
    let rb = prows / block;
    let cb = pcols / block;
    let mut q = vec![0i16; prows * pcols];
    let mut scale = vec![1.0f32; rb * cb];
    for br in 0..rb {
        for bc in 0..cb {
            let (r0, c0) = (br * block, bc * block);
            let mut am = 0.0f32;
            for r in r0..(r0 + block).min(x.rows) {
                for c in c0..(c0 + block).min(x.cols) {
                    am = am.max(x.at(r, c).abs());
                }
            }
            let s = safe_scale(am, levels);
            scale[br * cb + bc] = s;
            for r in r0..(r0 + block).min(x.rows) {
                for c in c0..(c0 + block).min(x.cols) {
                    let v = (x.at(r, c) / s).round_ties_even();
                    q[r * pcols + c] = v.clamp(-levels, levels) as i16;
                }
            }
        }
    }
    Int16Quant { rows: x.rows, cols: x.cols, block, prows, pcols, q, scale }
}

impl Int16Quant {
    pub fn dequant(&self) -> Mat {
        let cb = self.pcols / self.block;
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let s = self.scale[(r / self.block) * cb + c / self.block];
                m.data[r * self.cols + c] =
                    self.q[r * self.pcols + c] as f32 * s;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn randmat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        Mat::randn(rows, cols, 3.0, &mut rng)
    }

    #[test]
    fn roundtrip_error_bound() {
        let x = randmat(40, 24, 1);
        let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
        let d = bq.dequant();
        for r in 0..x.rows {
            for c in 0..x.cols {
                let s = bq.scale_at(r / 16, c / 16);
                assert!((d.at(r, c) - x.at(r, c)).abs() <= s / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn zero_block_exact() {
        let x = Mat::zeros(16, 16);
        let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
        assert!(bq.q.iter().all(|&q| q == 0));
        assert_eq!(bq.scale[0], 1.0);
        assert_eq!(bq.dequant().data, x.data);
    }

    #[test]
    fn codes_in_range() {
        let x = randmat(32, 32, 2);
        let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
        assert!(bq.q.iter().all(|&q| (-127..=127).contains(&(q as i32))));
    }

    #[test]
    fn padding_crops_correctly() {
        let x = randmat(33, 17, 3);
        let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
        assert_eq!(bq.prows, 48);
        assert_eq!(bq.pcols, 32);
        let d = bq.dequant();
        assert_eq!(d.rows, 33);
        assert_eq!(d.cols, 17);
    }

    #[test]
    fn ties_to_even() {
        // 2.5 rounds to 2, 3.5 rounds to 4 under ties-even.
        assert_eq!(2.5f32.round_ties_even(), 2.0);
        assert_eq!(3.5f32.round_ties_even(), 4.0);
        // Build a block whose absmax=127 so scale=1 and codes equal values.
        let mut x = Mat::zeros(16, 16);
        x.data[0] = 127.0;
        x.data[1] = 2.5;
        x.data[2] = 3.5;
        let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
        assert_eq!(bq.q[0], 127);
        assert_eq!(bq.q[1], 2);
        assert_eq!(bq.q[2], 4);
    }

    #[test]
    fn stochastic_rounding_unbiased() {
        let x = randmat(16, 16, 5);
        let mut acc = vec![0.0f64; 256];
        let trials = 400;
        for t in 0..trials {
            let bq = block_quant(&x, 16, INT8_LEVELS,
                                 Rounding::Stochastic(1000 + t));
            let d = bq.dequant();
            for (a, v) in acc.iter_mut().zip(&d.data) {
                *a += *v as f64;
            }
        }
        let scale = x.abs_max() / 127.0;
        let tol = 5.0 * scale as f64 / (trials as f64).sqrt();
        for (a, v) in acc.iter().zip(&x.data) {
            assert!((a / trials as f64 - *v as f64).abs() < tol + 1e-6);
        }
    }

    #[test]
    fn packed_views_match_codes() {
        let x = randmat(40, 41, 9); // non-multiple-of-block shape
        let bq = block_quant(&x, 16, INT8_LEVELS, Rounding::Nearest);
        let f = bq.codes_f32();
        assert_eq!(f.len(), bq.q.len());
        for (a, &b) in f.iter().zip(bq.q.iter()) {
            assert_eq!(*a, b as f32);
        }
        // cache: same allocation returned on the second call
        assert!(Arc::ptr_eq(&f, &bq.codes_f32()));

        let p = bq.col_panels();
        assert_eq!(p.widths.len(), bq.cb());
        assert_eq!(p.widths.iter().sum::<usize>(), bq.cols);
        for bj in 0..bq.cb() {
            let panel = p.panel(bj);
            let (c_lo, w) = (bj * bq.block, p.widths[bj]);
            for k in 0..bq.prows {
                for j in 0..w {
                    assert_eq!(panel[k * w + j],
                               bq.q[k * bq.pcols + c_lo + j] as f32,
                               "panel {bj} row {k} col {j}");
                }
            }
        }
        assert!(Arc::ptr_eq(&p, &bq.col_panels()));
    }

    #[test]
    fn int16_more_accurate_than_int8_without_outliers() {
        let x = randmat(32, 32, 7);
        let e8 = {
            let d = block_quant(&x, 16, INT8_LEVELS,
                                Rounding::Nearest).dequant();
            crate::quant::metrics::rmse(&d.data, &x.data)
        };
        let e16 = {
            let d = int16_block_quant(&x, 16).dequant();
            crate::quant::metrics::rmse(&d.data, &x.data)
        };
        assert!(e16 < e8 / 100.0, "e16={e16} e8={e8}");
    }
}
