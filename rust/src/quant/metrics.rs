//! Error metrics shared by experiments: RMSE, cosine similarity,
//! underflow rate, relative error — the quantities the paper's figures
//! report (Fig 3b RMSE, Fig 3c/5/7a CosSim, §4.1 underflow).

/// Root-mean-square error between two equal-length slices.
pub fn rmse(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let s: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum();
    (s / a.len() as f64).sqrt() as f32
}

/// Cosine similarity; returns 1.0 for two zero vectors.
pub fn cosine_similarity(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        dot += *x as f64 * *y as f64;
        na += *x as f64 * *x as f64;
        nb += *y as f64 * *y as f64;
    }
    if na == 0.0 && nb == 0.0 {
        return 1.0;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Fraction of nonzero entries that quantize to zero (paper §4.1:
/// "underflow" — non-outlier values lost when the scale is outlier-set).
pub fn underflow_rate(original: &[f32], quant_codes: &[i8]) -> f64 {
    assert_eq!(original.len(), quant_codes.len());
    let mut nonzero = 0usize;
    let mut under = 0usize;
    for (x, q) in original.iter().zip(quant_codes) {
        if *x != 0.0 {
            nonzero += 1;
            if *q == 0 {
                under += 1;
            }
        }
    }
    if nonzero == 0 {
        0.0
    } else {
        under as f64 / nonzero as f64
    }
}

/// ||a - b|| / ||b||.
pub fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        num += d * d;
        den += *y as f64 * *y as f64;
    }
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (num / den).sqrt()
}

/// Perplexity from a mean cross-entropy loss (nats).
pub fn ppl(mean_loss: f64) -> f64 {
    mean_loss.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmse_basics() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f32).sqrt()).abs()
                < 1e-6);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine_similarity(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs()
                < 1e-12);
        assert!(cosine_similarity(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine_similarity(&[1.0, 1.0], &[-1.0, -1.0]) + 1.0).abs()
                < 1e-12);
        assert_eq!(cosine_similarity(&[0.0], &[0.0]), 1.0);
        assert_eq!(cosine_similarity(&[0.0], &[1.0]), 0.0);
    }

    #[test]
    fn underflow() {
        let x = [5.0f32, 0.001, 0.0, -0.002];
        let q = [5i8, 0, 0, 0];
        // 3 nonzero entries, 2 quantized to zero
        assert!((underflow_rate(&x, &q) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn rel_err_basics() {
        assert_eq!(rel_err(&[1.0], &[1.0]), 0.0);
        assert!((rel_err(&[2.0], &[1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ppl_is_exp() {
        assert!((ppl(0.0) - 1.0).abs() < 1e-12);
        assert!((ppl(1.0) - std::f64::consts::E).abs() < 1e-12);
    }
}
