//! Fallback (residual) quantization — paper §4.3/§4.4.
//!
//! An outlier block G is represented as [Q(G), Q(G − Q(G))]: two INT8
//! blocks with independent scales. The fallback indicator u(i,k) is
//! decided per block by a selectable criterion (AbsMax / L1 / L1-Rel)
//! against a threshold θ maintained by the delay-threshold controller.

use std::sync::{Arc, OnceLock};

use crate::util::threadpool::{default_threads, parallel_items};
use crate::util::Mat;

use super::block::{block_quant_threads, safe_scale, BlockQuant,
                   Rounding};

/// Fallback selection criterion (§4.4, Fig 3c).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Criterion {
    /// max |G| of the block (paper default — free from step 1).
    AbsMax,
    /// absolute quantization error sum |G − Q(G)|.
    L1,
    /// relative error sum|G − Q(G)| / sum|G|.
    L1Rel,
}

/// Caching invariant: like [`BlockQuant`], the cached residual view
/// from [`residual_f32`](FallbackQuant::residual_f32) is built once —
/// treat the struct as frozen after construction. That f32 view
/// serves only the engine's `SimF32` oracle path; the default
/// `DataPath::Int8` path streams the stored `rq` codes zero-copy and
/// never materializes it.
#[derive(Debug, Clone)]
pub struct FallbackQuant {
    pub base: BlockQuant,
    /// residual INT8 codes (same padded layout as base.q)
    pub rq: Vec<i8>,
    pub rscale: Vec<f32>,
    /// per-block fallback indicator
    pub u: Vec<bool>,
    /// value of the selection metric per block
    pub metric: Vec<f32>,
    /// lazily cached row-major f32 copy of `rq`
    rf32_cache: OnceLock<Arc<Vec<f32>>>,
}

impl FallbackQuant {
    pub fn fallback_rate(&self) -> f64 {
        if self.u.is_empty() {
            return 0.0;
        }
        self.u.iter().filter(|&&b| b).count() as f64 / self.u.len() as f64
    }

    /// Dequantize: Q + u * ΔQ.
    pub fn dequant(&self) -> Mat {
        let b = self.base.block;
        let cb = self.base.cb();
        let mut m = self.base.dequant();
        for r in 0..m.rows {
            for c in 0..m.cols {
                let bi = (r / b) * cb + c / b;
                if self.u[bi] {
                    m.data[r * m.cols + c] +=
                        self.rq[r * self.base.pcols + c] as f32
                            * self.rscale[bi];
                }
            }
        }
        m
    }

    /// Stored bytes: INT8 base everywhere + residual only where u=1.
    pub fn bytes(&self) -> usize {
        let b2 = self.base.block * self.base.block;
        let fb_blocks = self.u.iter().filter(|&&x| x).count();
        self.base.bytes() + fb_blocks * (b2 + 4)
    }

    /// Cached f32 copy of the residual codes (same padded row-major
    /// layout as `base.q`); built once, shared by every later SimF32
    /// GEMM. The Int8 data path reads `rq` directly and never
    /// materializes this.
    pub fn residual_f32(&self) -> Arc<Vec<f32>> {
        self.rf32_cache
            .get_or_init(|| {
                Arc::new(self.rq.iter().map(|&v| v as f32).collect())
            })
            .clone()
    }

    /// Whether the f32 residual copy has been materialized (must stay
    /// `false` while only the Int8 data path runs).
    pub fn residual_f32_built(&self) -> bool {
        self.rf32_cache.get().is_some()
    }

    /// The transposed fallback quantization, built by **permuting**
    /// the stored codes and per-block grids instead of re-running
    /// Algorithm 1 on `xᵀ`.
    ///
    /// Under [`Criterion::AbsMax`] (the pipeline's criterion) this is
    /// *bit-identical* to `fallback_quant(&x.transpose(), ..)`: the
    /// base quantization transposes exactly (see
    /// [`BlockQuant::transposed`]), the residual `rmax` is a max over
    /// the same elements, `safe_scale` and the elementwise
    /// nearest-rounded residual codes are deterministic, and the
    /// AbsMax metric *is* the base absmax — order-independent. The
    /// `L1`/`L1Rel` metrics are f64 sums whose accumulation order
    /// follows the element sweep, so a transposed re-quantization can
    /// differ from the permuted metric in the last bits there (the
    /// `u` decision can then flip only for blocks sitting exactly on
    /// θ); callers needing bit-identity on those criteria must
    /// re-quantize.
    ///
    /// Like `BlockQuant::transposed`, this bumps **no** quantization
    /// work counter — it is a permutation, not a quantization pass —
    /// which is how the pipeline's counter tests see the saving. The
    /// residual f32 cache starts empty.
    pub fn transposed(&self) -> FallbackQuant {
        let base = self.base.transposed();
        // Residual codes share base.q's padded layout; permute them
        // with the same loop.
        let (prows, pcols) = (self.base.prows, self.base.pcols);
        let tpcols = prows;
        let mut rq = vec![0i8; self.rq.len()];
        for r in 0..prows {
            let row = &self.rq[r * pcols..(r + 1) * pcols];
            for (c, &v) in row.iter().enumerate() {
                rq[c * tpcols + r] = v;
            }
        }
        let (rb, cb) = (self.base.rb(), self.base.cb());
        let mut rscale = vec![1.0f32; rb * cb];
        let mut u = vec![false; rb * cb];
        let mut metric = vec![0.0f32; rb * cb];
        for br in 0..rb {
            for bc in 0..cb {
                rscale[bc * rb + br] = self.rscale[br * cb + bc];
                u[bc * rb + br] = self.u[br * cb + bc];
                metric[bc * rb + br] = self.metric[br * cb + bc];
            }
        }
        FallbackQuant {
            base,
            rq,
            rscale,
            u,
            metric,
            rf32_cache: OnceLock::new(),
        }
    }
}

/// Residual-quantize one block row: metric sweep, fallback decision,
/// residual codes. `rqrow` is the block row's slice of the padded
/// residual code matrix; `srow`/`urow`/`mrow` its rows of the
/// per-block grids.
#[allow(clippy::too_many_arguments)]
fn fallback_block_row(
    x: &Mat, base: &BlockQuant, theta: f32, block: usize, levels: f32,
    criterion: Criterion, br: usize, rqrow: &mut [i8],
    srow: &mut [f32], urow: &mut [bool], mrow: &mut [f32],
) {
    let cb = srow.len();
    let r0 = br * block;
    let r1 = (r0 + block).min(x.rows);
    for bc in 0..cb {
        let bi = br * cb + bc;
        let c0 = bc * block;
        let c1 = (c0 + block).min(x.cols);
        let s = base.scale[bi];
        // residual + metric accumulation in one sweep
        let mut rmax = 0.0f32;
        let mut l1 = 0.0f64;
        let mut tot = 0.0f64;
        for r in r0..r1 {
            for c in c0..c1 {
                let v = x.at(r, c);
                let deq = base.q[r * base.pcols + c] as f32 * s;
                let resid = v - deq;
                rmax = rmax.max(resid.abs());
                l1 += resid.abs() as f64;
                tot += v.abs() as f64;
            }
        }
        mrow[bc] = match criterion {
            Criterion::AbsMax => base.absmax[bi],
            Criterion::L1 => l1 as f32,
            Criterion::L1Rel => {
                if tot > 0.0 {
                    (l1 / tot) as f32
                } else {
                    0.0
                }
            }
        };
        urow[bc] = mrow[bc] > theta;
        let rs = safe_scale(rmax, levels);
        srow[bc] = rs;
        let inv = 1.0 / rs;
        for r in r0..r1 {
            for c in c0..c1 {
                let deq = base.q[r * base.pcols + c] as f32 * s;
                let resid = x.at(r, c) - deq;
                rqrow[(r - r0) * base.pcols + c] = (resid * inv)
                    .round_ties_even()
                    .clamp(-levels, levels) as i8;
            }
        }
    }
}

/// Two-step fallback quantization of `x` with threshold `theta`. Runs
/// on [`default_threads`] workers dispatched through the persistent
/// runtime ([`crate::util::pool`] via [`parallel_items`] — no
/// per-call thread spawns); see [`fallback_quant_threads`]. Bitwise
/// thread-count-independent (no RNG; disjoint block-row outputs).
pub fn fallback_quant(x: &Mat, theta: f32, block: usize, levels: f32,
                      criterion: Criterion) -> FallbackQuant {
    fallback_quant_threads(x, theta, block, levels, criterion,
                           default_threads())
}

/// [`fallback_quant`] with an explicit worker count (block rows are
/// the parallel unit, for both the base quantization and the residual
/// pass).
pub fn fallback_quant_threads(x: &Mat, theta: f32, block: usize,
                              levels: f32, criterion: Criterion,
                              threads: usize) -> FallbackQuant {
    let base =
        block_quant_threads(x, block, levels, Rounding::Nearest, threads);
    let (rb, cb) = (base.rb(), base.cb());
    let mut rq = vec![0i8; base.q.len()];
    let mut rscale = vec![1.0f32; rb * cb];
    let mut u = vec![false; rb * cb];
    let mut metric = vec![0.0f32; rb * cb];

    if rb > 0 && cb > 0 {
        let items: Vec<_> = rq
            .chunks_mut(block * base.pcols)
            .zip(rscale.chunks_mut(cb))
            .zip(u.chunks_mut(cb))
            .zip(metric.chunks_mut(cb))
            .collect();
        parallel_items(items, threads,
                       |br, (((rqrow, srow), urow), mrow)| {
            fallback_block_row(
                x, &base, theta, block, levels, criterion, br, rqrow,
                srow, urow, mrow,
            );
        });
    }
    FallbackQuant {
        base,
        rq,
        rscale,
        u,
        metric,
        rf32_cache: OnceLock::new(),
    }
}

/// θ that yields (as closely as achievable) the requested fallback
/// rate under the strictly-greater selection rule `u = metric > θ`.
/// Used by benches to pin rates exactly; training uses the delay
/// controller instead (Alg 2).
///
/// Because selection is a scalar threshold, blocks sharing one metric
/// value fall back (or not) together — with duplicated values no θ can
/// split a tie group, and the old (1-rate)-quantile pick could land a
/// whole group on the wrong side of θ, overshooting the request. This
/// version enumerates every achievable rate (one per distinct metric
/// value, plus 0 and 1) and returns the θ whose achieved rate is
/// closest to `rate`; exact-distance ties break deterministically
/// toward the *lower* achieved rate (fallback work is the cost being
/// budgeted, so when in doubt spend less).
pub fn theta_for_rate(metrics: &[f32], rate: f64) -> f32 {
    if metrics.is_empty() {
        return f32::INFINITY;
    }
    let n = metrics.len();
    let mut sorted = metrics.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());

    // θ = +inf achieves rate 0; θ = last occurrence of value v achieves
    // (elements strictly greater than v) / n; θ = -inf achieves rate 1.
    // Walking values in ascending order visits achieved rates in
    // *descending* order, so track the best candidate seen.
    let mut best_theta = f32::INFINITY;
    let mut best_err = rate; // achieved 0 at θ = +inf
    let mut best_rate = 0.0f64;
    let mut consider = |theta: f32, achieved: f64| {
        let err = (achieved - rate).abs();
        if err < best_err || (err == best_err && achieved < best_rate) {
            best_theta = theta;
            best_err = err;
            best_rate = achieved;
        }
    };
    let mut i = 0;
    while i < n {
        let v = sorted[i];
        let mut j = i;
        while j + 1 < n && sorted[j + 1] == v {
            j += 1;
        }
        consider(v, (n - j - 1) as f64 / n as f64);
        i = j + 1;
    }
    consider(f32::NEG_INFINITY, 1.0);
    best_theta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::block::INT8_LEVELS;
    use crate::quant::metrics::rmse;
    use crate::util::rng::Pcg64;

    fn outlier_mat(rows: usize, cols: usize, seed: u64, n_out: usize,
                   mag: f32) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut m = Mat::randn(rows, cols, 1.0, &mut rng);
        for _ in 0..n_out {
            let i = rng.below(m.data.len());
            let jitter = 1.0 + rng.uniform_f32(); // distinct magnitudes
            m.data[i] = mag * jitter
                * if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        }
        m
    }

    #[test]
    fn all_fallback_reduces_error() {
        let x = outlier_mat(64, 64, 1, 10, 300.0);
        let fq = fallback_quant(&x, -1.0, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        assert!((fq.fallback_rate() - 1.0).abs() < 1e-9);
        let plain = fq.base.dequant();
        let fb = fq.dequant();
        let e_plain = rmse(&plain.data, &x.data);
        let e_fb = rmse(&fb.data, &x.data);
        assert!(e_fb < e_plain * 0.05, "e_fb={e_fb} e_plain={e_plain}");
    }

    #[test]
    fn no_fallback_at_huge_theta() {
        let x = outlier_mat(64, 64, 2, 10, 300.0);
        let fq = fallback_quant(&x, f32::INFINITY, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        assert_eq!(fq.fallback_rate(), 0.0);
        assert_eq!(fq.dequant().data, fq.base.dequant().data);
    }

    #[test]
    fn fallback_beats_int16_with_extreme_outliers() {
        // Paper Fig 3(b): a 20000-magnitude outlier ruins INT16's single
        // scale but not the two-step representation.
        let x = outlier_mat(128, 128, 3, 8, 20000.0);
        let fq = fallback_quant(&x, -1.0, 128, INT8_LEVELS,
                                Criterion::AbsMax);
        let e_fb = rmse(&fq.dequant().data, &x.data);
        let i16q = crate::quant::block::int16_block_quant(&x, 128);
        let e_16 = rmse(&i16q.dequant().data, &x.data);
        assert!(e_fb < e_16, "fallback {e_fb} vs int16 {e_16}");
    }

    #[test]
    fn criteria_agree_on_extreme_blocks() {
        // A block with a huge outlier should rank top under all criteria.
        let x = outlier_mat(64, 64, 4, 1, 1000.0);
        for crit in [Criterion::AbsMax, Criterion::L1, Criterion::L1Rel] {
            let fq = fallback_quant(&x, f32::INFINITY, 16, INT8_LEVELS, crit);
            let hot = fq
                .metric
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            // locate the outlier block
            let pos = x.data.iter().position(|v| v.abs() > 500.0).unwrap();
            let (r, c) = (pos / x.cols, pos % x.cols);
            let want = (r / 16) * fq.base.cb() + c / 16;
            assert_eq!(hot, want, "criterion {crit:?}");
        }
    }

    #[test]
    fn theta_for_rate_hits_target() {
        let x = outlier_mat(128, 128, 5, 24, 100.0);
        let fq = fallback_quant(&x, f32::INFINITY, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        for rate in [0.1, 0.25, 0.5] {
            let theta = theta_for_rate(&fq.metric, rate);
            let fq2 = fallback_quant(&x, theta, 16, INT8_LEVELS,
                                     Criterion::AbsMax);
            let got = fq2.fallback_rate();
            assert!((got - rate).abs() <= 1.0 / 64.0 + 1e-9,
                    "rate {rate} got {got}");
        }
    }

    #[test]
    fn theta_for_rate_ties_never_overshoot_nearest() {
        // Three tie groups: 1.0 x3, 2.0 x3, 3.0 x2. Achievable fallback
        // rates under `metric > theta` are only {0, 2/8, 5/8, 1}.
        let metrics = [1.0f32, 1.0, 1.0, 2.0, 2.0, 2.0, 3.0, 3.0];
        let achieved = |theta: f32| {
            metrics.iter().filter(|&&m| m > theta).count() as f64
                / metrics.len() as f64
        };
        // exact hits
        assert_eq!(achieved(theta_for_rate(&metrics, 0.25)), 0.25);
        assert_eq!(achieved(theta_for_rate(&metrics, 0.625)), 0.625);
        assert_eq!(achieved(theta_for_rate(&metrics, 0.0)), 0.0);
        assert_eq!(achieved(theta_for_rate(&metrics, 1.0)), 1.0);
        // between achievable rates: picks the nearest, never a whole
        // tie-group past it (0.3 is nearer 2/8=0.25 than 5/8)
        assert_eq!(achieved(theta_for_rate(&metrics, 0.3)), 0.25);
        // equidistant from 0.25 and 0.625 at 0.4375: lower rate wins
        assert_eq!(achieved(theta_for_rate(&metrics, 0.4375)), 0.25);
        // all-equal metrics: only rates 0 and 1 are achievable
        let flat = [5.0f32; 6];
        let t = theta_for_rate(&flat, 0.4);
        assert_eq!(flat.iter().filter(|&&m| m > t).count(), 0);
        let t1 = theta_for_rate(&flat, 0.9);
        assert_eq!(flat.iter().filter(|&&m| m > t1).count(), 6);
        // determinism
        assert_eq!(theta_for_rate(&metrics, 0.3).to_bits(),
                   theta_for_rate(&metrics, 0.3).to_bits());
    }

    #[test]
    fn transposed_bit_identical_to_requantized_transpose() {
        // Pin for the pipeline's dW optimization: permuting the
        // forward activation quantization must equal re-running
        // Algorithm 1 on xᵀ bit for bit (AbsMax criterion), without
        // registering any quantization work.
        use crate::quant::block::quant_work_counters;
        for (rows, cols, theta) in
            [(32usize, 32usize, 30.0f32), (40, 23, 20.0), (17, 49, -1.0)]
        {
            let x = outlier_mat(rows, cols, 0xF1, 6, 200.0);
            let fx = fallback_quant(&x, theta, 16, INT8_LEVELS,
                                    Criterion::AbsMax);
            let before = quant_work_counters();
            let ft = fx.transposed();
            let after = quant_work_counters();
            assert_eq!(before, after,
                       "transposed() must not count as quant work");
            let fresh = fallback_quant(&x.transpose(), theta, 16,
                                       INT8_LEVELS, Criterion::AbsMax);
            assert_eq!(ft.base.rows, fresh.base.rows);
            assert_eq!(ft.base.cols, fresh.base.cols);
            assert_eq!(ft.base.q, fresh.base.q, "({rows},{cols})");
            assert_eq!(ft.base.scale, fresh.base.scale);
            assert_eq!(ft.base.absmax, fresh.base.absmax);
            assert_eq!(ft.rq, fresh.rq, "({rows},{cols})");
            assert_eq!(ft.rscale, fresh.rscale);
            assert_eq!(ft.u, fresh.u);
            assert_eq!(ft.metric, fresh.metric);
            assert!(!ft.residual_f32_built());
        }
    }

    #[test]
    fn parallel_fallback_thread_count_invariant() {
        // Regression: residual quantization parallelized over block
        // rows must be bitwise identical for every worker count.
        let x = outlier_mat(70, 55, 8, 12, 250.0);
        let f1 = fallback_quant_threads(&x, 30.0, 16, INT8_LEVELS,
                                        Criterion::AbsMax, 1);
        for threads in [2usize, 4, 7] {
            let ft = fallback_quant_threads(&x, 30.0, 16, INT8_LEVELS,
                                            Criterion::AbsMax, threads);
            assert_eq!(f1.base.q, ft.base.q, "threads={threads}");
            assert_eq!(f1.rq, ft.rq);
            assert_eq!(f1.rscale, ft.rscale);
            assert_eq!(f1.u, ft.u);
            assert_eq!(f1.metric, ft.metric);
        }
    }

    #[test]
    fn bytes_accounting() {
        let x = outlier_mat(32, 32, 6, 4, 200.0);
        let fq_none = fallback_quant(&x, f32::INFINITY, 16, INT8_LEVELS,
                                     Criterion::AbsMax);
        let fq_all = fallback_quant(&x, -1.0, 16, INT8_LEVELS,
                                    Criterion::AbsMax);
        assert!(fq_all.bytes() > fq_none.bytes());
        // full fallback doubles code bytes (+ scale word per block)
        assert_eq!(fq_all.bytes() - fq_none.bytes(), 4 * (256 + 4));
    }

    #[test]
    fn prop_dequant_error_bounded_by_residual_scale() {
        crate::util::testing::forall("fb-residual-bound", 25, |g| {
            let rows = 16 * g.usize_in(1, 3);
            let cols = 16 * g.usize_in(1, 3);
            let data = g.vec_outliers(rows * cols, 1.0, 5, 150.0);
            let x = Mat::from_vec(rows, cols, data);
            let fq = fallback_quant(&x, -1.0, 16, INT8_LEVELS,
                                    Criterion::AbsMax);
            let d = fq.dequant();
            let cb = fq.base.cb();
            for r in 0..rows {
                for c in 0..cols {
                    let bi = (r / 16) * cb + c / 16;
                    let bound = fq.rscale[bi] / 2.0 + 1e-5;
                    let err = (d.at(r, c) - x.at(r, c)).abs();
                    crate::prop_assert!(
                        err <= bound,
                        "err {err} > bound {bound} at ({r},{c})"
                    );
                }
            }
            Ok(())
        });
    }
}
