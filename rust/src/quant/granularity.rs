//! Quantization granularities of §3.1 — per-tensor, per-token,
//! per-channel — plus the SwitchBack recipe (per-token X × per-channel
//! W, Wortsman et al. 2023), the baseline family the paper's block
//! fallback is measured against.
//!
//! These exist to *quantify why they fail* on GLU activations: a single
//! outlier poisons an entire row/column/tensor scale (underflow), while
//! 128×128 blocks + fallback isolate it (§4.1 discussion, Fig 1a).

use crate::util::Mat;

use super::block::safe_scale;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// one scale for the whole matrix
    PerTensor,
    /// one scale per row (token)
    PerToken,
    /// one scale per column (channel)
    PerChannel,
}

/// Quantized matrix under a §3.1 granularity.
#[derive(Debug, Clone)]
pub struct GranularQuant {
    pub rows: usize,
    pub cols: usize,
    pub granularity: Granularity,
    pub q: Vec<i8>,
    /// 1 (tensor), rows (token) or cols (channel) scales
    pub scale: Vec<f32>,
}

pub fn granular_quant(x: &Mat, g: Granularity, levels: f32)
                      -> GranularQuant {
    let (rows, cols) = (x.rows, x.cols);
    let mut q = vec![0i8; rows * cols];
    let scale = match g {
        Granularity::PerTensor => {
            let s = safe_scale(x.abs_max(), levels);
            let inv = 1.0 / s;
            for (qi, &v) in q.iter_mut().zip(&x.data) {
                *qi = (v * inv).round_ties_even()
                    .clamp(-levels, levels) as i8;
            }
            vec![s]
        }
        Granularity::PerToken => {
            let mut scales = vec![1.0f32; rows];
            for r in 0..rows {
                let row = x.row(r);
                let am = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let s = safe_scale(am, levels);
                scales[r] = s;
                let inv = 1.0 / s;
                for (c, &v) in row.iter().enumerate() {
                    q[r * cols + c] = (v * inv).round_ties_even()
                        .clamp(-levels, levels) as i8;
                }
            }
            scales
        }
        Granularity::PerChannel => {
            let mut scales = vec![1.0f32; cols];
            for c in 0..cols {
                let mut am = 0.0f32;
                for r in 0..rows {
                    am = am.max(x.at(r, c).abs());
                }
                scales[c] = safe_scale(am, levels);
            }
            for r in 0..rows {
                for c in 0..cols {
                    q[r * cols + c] = (x.at(r, c) / scales[c])
                        .round_ties_even()
                        .clamp(-levels, levels) as i8;
                }
            }
            scales
        }
    };
    GranularQuant { rows, cols, granularity: g, q, scale }
}

impl GranularQuant {
    pub fn dequant(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let s = match self.granularity {
                    Granularity::PerTensor => self.scale[0],
                    Granularity::PerToken => self.scale[r],
                    Granularity::PerChannel => self.scale[c],
                };
                m.data[r * self.cols + c] =
                    self.q[r * self.cols + c] as f32 * s;
            }
        }
        m
    }
}

/// SwitchBack matmul: per-token X (M×K rows) × per-channel W^T columns
/// — i.e. W (N×K) quantized per output row. Returns C ≈ X·Wᵀ.
pub fn switchback_matmul(x: &Mat, w: &Mat, levels: f32) -> Mat {
    assert_eq!(x.cols, w.cols, "X (T,K) x W (N,K)");
    let qx = granular_quant(x, Granularity::PerToken, levels);
    let qw = granular_quant(w, Granularity::PerToken, levels); // rows of W = out channels
    let (t, k, n) = (x.rows, x.cols, w.rows);
    let mut c = Mat::zeros(t, n);
    for r in 0..t {
        let sx = qx.scale[r];
        for j in 0..n {
            let mut acc = 0i32;
            for kk in 0..k {
                acc += qx.q[r * k + kk] as i32 * qw.q[j * k + kk] as i32;
            }
            c.data[r * n + j] = acc as f32 * (sx * qw.scale[j]);
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::metrics::{rel_err, rmse, underflow_rate};
    use crate::quant::INT8_LEVELS;
    use crate::util::rng::Pcg64;

    fn gaussian(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        Mat::randn(rows, cols, 1.0, &mut rng)
    }

    #[test]
    fn all_granularities_bounded_error_without_outliers() {
        let x = gaussian(64, 64, 1);
        for g in [Granularity::PerTensor, Granularity::PerToken,
                  Granularity::PerChannel] {
            let q = granular_quant(&x, g, INT8_LEVELS);
            let e = rmse(&q.dequant().data, &x.data);
            assert!(e < 0.03, "{g:?}: {e}");
        }
    }

    #[test]
    fn occasional_outlier_poisons_tensor_and_row_scales() {
        // P2's point: one occasional outlier in a random position ruins
        // per-tensor entirely and its own token row; block quantization
        // (16x16) confines the damage to one block.
        let mut x = gaussian(64, 64, 2);
        x.data[40 * 64 + 13] = 5000.0;
        let per_tensor = granular_quant(&x, Granularity::PerTensor,
                                        INT8_LEVELS);
        let uf_tensor = underflow_rate(&x.data, &per_tensor.q);
        assert!(uf_tensor > 0.9, "tensor underflow {uf_tensor}");

        let per_token = granular_quant(&x, Granularity::PerToken,
                                       INT8_LEVELS);
        // only the poisoned row underflows
        let row = &x.data[40 * 64..41 * 64];
        let qrow = &per_token.q[40 * 64..41 * 64];
        let uf_row = underflow_rate(row, qrow);
        assert!(uf_row > 0.9, "row underflow {uf_row}");
        let uf_all = underflow_rate(&x.data, &per_token.q);
        assert!(uf_all < 0.05, "global underflow {uf_all}");

        // block quant: damage confined to one 16x16 block
        let bq = crate::quant::block_quant(&x, 16, INT8_LEVELS,
                                           crate::quant::Rounding::Nearest);
        let uf_block = underflow_rate(&x.data, &bq.q[..x.data.len()]);
        assert!(uf_block < uf_tensor / 10.0,
                "block {uf_block} vs tensor {uf_tensor}");
    }

    #[test]
    fn channel_outliers_defeat_per_token_but_not_per_channel() {
        // SwitchBack's known weakness (§3.2): channel-wise outliers make
        // *every* token row carry a huge scale.
        let mut x = gaussian(128, 128, 3);
        for r in 0..128 {
            x.data[r * 128 + 7] = 800.0; // hot channel
        }
        let pt = granular_quant(&x, Granularity::PerToken, INT8_LEVELS);
        let pc = granular_quant(&x, Granularity::PerChannel, INT8_LEVELS);
        let e_tok = rmse(&pt.dequant().data, &x.data);
        let e_ch = rmse(&pc.dequant().data, &x.data);
        assert!(e_ch < e_tok / 5.0, "token {e_tok} vs channel {e_ch}");
    }

    #[test]
    fn switchback_ok_without_outliers_bad_with() {
        let x = gaussian(32, 64, 4);
        let w = gaussian(48, 64, 5);
        let exact = {
            let wt = w.transpose();
            crate::gemm::matmul(&x, &wt, 1)
        };
        let c = switchback_matmul(&x, &w, INT8_LEVELS);
        assert!(rel_err(&c.data, &exact.data) < 0.02);

        // occasional activation outliers break it; block fallback holds
        let mut xo = x.clone();
        for i in [5usize, 600, 1500] {
            xo.data[i] = 400.0;
        }
        let exact_o = {
            let wt = w.transpose();
            crate::gemm::matmul(&xo, &wt, 1)
        };
        let c_sb = switchback_matmul(&xo, &w, INT8_LEVELS);
        let wt = w.transpose();
        let (c_fb, _) =
            crate::gemm::fallback_matmul(&xo, &wt, 10.0, 16, 1);
        let e_sb = rel_err(&c_sb.data, &exact_o.data);
        let e_fb = rel_err(&c_fb.data, &exact_o.data);
        assert!(e_fb < e_sb, "fallback {e_fb} !< switchback {e_sb}");
    }

    #[test]
    fn scale_counts() {
        let x = gaussian(8, 16, 6);
        assert_eq!(granular_quant(&x, Granularity::PerTensor,
                                  INT8_LEVELS).scale.len(), 1);
        assert_eq!(granular_quant(&x, Granularity::PerToken,
                                  INT8_LEVELS).scale.len(), 8);
        assert_eq!(granular_quant(&x, Granularity::PerChannel,
                                  INT8_LEVELS).scale.len(), 16);
    }
}
