//! Staged Int4 → Int8 → f32 fallback quantization — the precision
//! lattice's activation-side representation (`DataPath::Int4`).
//!
//! The paper's Algorithm 1 picks, per block, between one INT8 pass and
//! a two-pass INT8 fallback. On the INT4 data path the same machinery
//! drives a three-tier ladder instead:
//!
//! * **Tier I4** (metric ≤ θ): the block is represented by its INT4
//!   base codes alone.
//! * **Tier I8** (metric > θ): an INT8 residual `Q8(G − Q4(G))` rides
//!   along — the block's effective precision is INT4 + INT8, i.e. the
//!   Jetfire-style INT8 tier.
//! * **Tier F32** (metric > κ·θ, `κ =` [`STAGED_F32_KAPPA`]): the
//!   exact f32 remainder `G − Q4(G) − Q8(…)` is *also* carried, so the
//!   block participates at (f32) full precision — the "fall all the
//!   way back" rung for the extreme GLU-activation outliers the paper
//!   is about.
//!
//! The selection metric is the per-block **AbsMax** (the paper-default
//! criterion — free from the base quantization's first sweep, and the
//! only criterion whose transposed quantization is an exact
//! permutation; see [`FallbackQuant::transposed`] for the argument).
//! θ comes from the same Algorithm-2 delay controller that drives the
//! binary fallback: the executed **I8-tier rate** (`metric > θ`) is
//! what the pipeline reports back, so the controller's band semantics
//! are unchanged; κ is a fixed multiplier, not a second control loop.
//!
//! Residual and remainder grids are computed for *every* block (like
//! [`FallbackQuant`], whose `rq` also spans all blocks) — the tier
//! masks gate *execution*, not construction, which keeps construction
//! bitwise thread-count-invariant and makes
//! [`transposed`](StagedQuant::transposed) a pure permutation.
//!
//! [`FallbackQuant`]: super::fallback::FallbackQuant
//! [`FallbackQuant::transposed`]: super::fallback::FallbackQuant::transposed

use crate::util::threadpool::{default_threads, parallel_items};
use crate::util::Mat;

use super::block::{block_quant_threads, safe_scale, BlockQuant,
                   Rounding, INT4_LEVELS, INT8_LEVELS};

/// Fixed multiplier on θ for the f32 tier: a block whose AbsMax
/// exceeds `κ·θ` is too hot even for the INT8 residual and carries
/// its exact f32 remainder instead. One knob (θ) stays under
/// Algorithm-2 control; κ is deliberately constant so the staged
/// ladder adds no second feedback loop.
pub const STAGED_F32_KAPPA: f32 = 4.0;

/// Per-block precision tier of a staged quantization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// INT4 base codes only.
    I4,
    /// base + INT8 residual.
    I8,
    /// base + INT8 residual + exact f32 remainder.
    F32,
}

/// Staged three-tier quantization of an activation operand (A side of
/// the GEMM). The base is an [`INT4_LEVELS`] [`BlockQuant`]; `rq`
/// holds the INT8 residual codes, `r2` the f32 second remainder, both
/// in the base's padded row-major layout. `u8_mask` / `uf_mask` gate
/// the residual / remainder terms per block at execution time.
///
/// Like [`BlockQuant`], treat the struct as frozen after construction
/// — the engine borrows the grids zero-copy across plan executions.
#[derive(Debug, Clone)]
pub struct StagedQuant {
    pub base: BlockQuant,
    /// INT8 residual codes of `x − dequant(base)` (all blocks)
    pub rq: Vec<i8>,
    pub rscale: Vec<f32>,
    /// exact f32 remainder `x − dequant(base) − rq·rscale` (all
    /// blocks, padded layout; zero in the padding)
    pub r2: Vec<f32>,
    /// per-block tier (row-block-major grid, like `base.scale`)
    pub tier: Vec<Tier>,
    /// AbsMax selection metric per block (= `base.absmax`)
    pub metric: Vec<f32>,
    /// tier ≥ I8 (the Algorithm-2-visible fallback decision)
    pub u8_mask: Vec<bool>,
    /// tier = F32
    pub uf_mask: Vec<bool>,
}

impl StagedQuant {
    /// Fraction of blocks promoted past the INT4 base (tier ≥ I8) —
    /// the rate the delay controller sees.
    pub fn rate_i8(&self) -> f64 {
        if self.u8_mask.is_empty() {
            return 0.0;
        }
        self.u8_mask.iter().filter(|&&b| b).count() as f64
            / self.u8_mask.len() as f64
    }

    /// Fraction of blocks promoted all the way to f32 (tier = F32).
    pub fn rate_f32(&self) -> f64 {
        if self.uf_mask.is_empty() {
            return 0.0;
        }
        self.uf_mask.iter().filter(|&&b| b).count() as f64
            / self.uf_mask.len() as f64
    }

    /// Dequantize: base + u8·residual + uf·remainder.
    pub fn dequant(&self) -> Mat {
        let b = self.base.block;
        let cb = self.base.cb();
        let mut m = self.base.dequant();
        for r in 0..m.rows {
            for c in 0..m.cols {
                let bi = (r / b) * cb + c / b;
                let pc = r * self.base.pcols + c;
                if self.u8_mask[bi] {
                    m.data[r * m.cols + c] +=
                        self.rq[pc] as f32 * self.rscale[bi];
                }
                if self.uf_mask[bi] {
                    m.data[r * m.cols + c] += self.r2[pc];
                }
            }
        }
        m
    }

    /// The transposed staged quantization, built by **permuting** the
    /// stored grids instead of re-running the ladder on `xᵀ` — the dW
    /// path's zero-cost reuse, mirroring
    /// [`FallbackQuant::transposed`](super::fallback::FallbackQuant::transposed).
    ///
    /// Bit-identical to `staged_quant(&x.transpose(), ..)` because
    /// every per-block quantity here is either an elementwise map or a
    /// max over the same elements (the base is Nearest-rounded, the
    /// AbsMax metric is the base absmax, and both tier comparisons are
    /// per-block scalars) — there is no order-sensitive accumulation
    /// anywhere in the ladder. Bumps no quantization work counter.
    pub fn transposed(&self) -> StagedQuant {
        let base = self.base.transposed();
        let (prows, pcols) = (self.base.prows, self.base.pcols);
        let tpcols = prows;
        let mut rq = vec![0i8; self.rq.len()];
        let mut r2 = vec![0.0f32; self.r2.len()];
        for r in 0..prows {
            for c in 0..pcols {
                rq[c * tpcols + r] = self.rq[r * pcols + c];
                r2[c * tpcols + r] = self.r2[r * pcols + c];
            }
        }
        let (rb, cb) = (self.base.rb(), self.base.cb());
        let mut rscale = vec![1.0f32; rb * cb];
        let mut tier = vec![Tier::I4; rb * cb];
        let mut metric = vec![0.0f32; rb * cb];
        let mut u8_mask = vec![false; rb * cb];
        let mut uf_mask = vec![false; rb * cb];
        for br in 0..rb {
            for bc in 0..cb {
                let (src, dst) = (br * cb + bc, bc * rb + br);
                rscale[dst] = self.rscale[src];
                tier[dst] = self.tier[src];
                metric[dst] = self.metric[src];
                u8_mask[dst] = self.u8_mask[src];
                uf_mask[dst] = self.uf_mask[src];
            }
        }
        StagedQuant { base, rq, rscale, r2, tier, metric, u8_mask, uf_mask }
    }
}

/// Residual-ladder pass for one block row: tier decision from the base
/// AbsMax, INT8 residual codes, exact f32 remainder.
#[allow(clippy::too_many_arguments)]
fn staged_block_row(
    x: &Mat, base: &BlockQuant, theta: f32, block: usize, br: usize,
    rqrow: &mut [i8], srow: &mut [f32], r2row: &mut [f32],
    trow: &mut [Tier], mrow: &mut [f32], u8row: &mut [bool],
    ufrow: &mut [bool],
) {
    let cb = srow.len();
    let r0 = br * block;
    let r1 = (r0 + block).min(x.rows);
    for bc in 0..cb {
        let bi = br * cb + bc;
        let c0 = bc * block;
        let c1 = (c0 + block).min(x.cols);
        let s = base.scale[bi];
        let am = base.absmax[bi];
        mrow[bc] = am;
        // κ·θ with θ = +∞ must stay +∞ (fallback disabled), and any
        // finite θ scales; NaN never arises from the controller.
        let t = if am > theta * STAGED_F32_KAPPA {
            Tier::F32
        } else if am > theta {
            Tier::I8
        } else {
            Tier::I4
        };
        trow[bc] = t;
        u8row[bc] = t != Tier::I4;
        ufrow[bc] = t == Tier::F32;
        // INT8 residual of the INT4 base (one sweep for rmax)
        let mut rmax = 0.0f32;
        for r in r0..r1 {
            for c in c0..c1 {
                let deq = base.q[r * base.pcols + c] as f32 * s;
                rmax = rmax.max((x.at(r, c) - deq).abs());
            }
        }
        let rs = safe_scale(rmax, INT8_LEVELS);
        srow[bc] = rs;
        let inv = 1.0 / rs;
        for r in r0..r1 {
            for c in c0..c1 {
                let deq = base.q[r * base.pcols + c] as f32 * s;
                let resid = x.at(r, c) - deq;
                let code = (resid * inv)
                    .round_ties_even()
                    .clamp(-INT8_LEVELS, INT8_LEVELS)
                    as i8;
                rqrow[(r - r0) * base.pcols + c] = code;
                // exact f32 remainder after both integer tiers
                r2row[(r - r0) * base.pcols + c] =
                    resid - code as f32 * rs;
            }
        }
    }
}

/// Staged three-tier quantization of `x` with threshold `theta` (the
/// INT8 promotion threshold; the f32 tier triggers at
/// `theta ·`[`STAGED_F32_KAPPA`]). The INT4 base is Nearest-rounded —
/// like [`fallback_quant`](super::fallback::fallback_quant)'s base —
/// so the dW path can reuse the forward's quantization by permutation.
/// Runs on [`default_threads`] workers.
pub fn staged_quant(x: &Mat, theta: f32, block: usize) -> StagedQuant {
    staged_quant_threads(x, theta, block, default_threads())
}

/// [`staged_quant`] with an explicit worker count (block rows are the
/// parallel unit). Bitwise thread-count-invariant: no RNG, disjoint
/// block-row outputs.
pub fn staged_quant_threads(x: &Mat, theta: f32, block: usize,
                            threads: usize) -> StagedQuant {
    let base = block_quant_threads(x, block, INT4_LEVELS,
                                   Rounding::Nearest, threads);
    let (rb, cb) = (base.rb(), base.cb());
    let mut rq = vec![0i8; base.q.len()];
    let mut rscale = vec![1.0f32; rb * cb];
    let mut r2 = vec![0.0f32; base.q.len()];
    let mut tier = vec![Tier::I4; rb * cb];
    let mut metric = vec![0.0f32; rb * cb];
    let mut u8_mask = vec![false; rb * cb];
    let mut uf_mask = vec![false; rb * cb];

    if rb > 0 && cb > 0 {
        let items: Vec<_> = rq
            .chunks_mut(block * base.pcols)
            .zip(rscale.chunks_mut(cb))
            .zip(r2.chunks_mut(block * base.pcols))
            .zip(tier.chunks_mut(cb))
            .zip(metric.chunks_mut(cb))
            .zip(u8_mask.chunks_mut(cb))
            .zip(uf_mask.chunks_mut(cb))
            .collect();
        parallel_items(
            items, threads,
            |br, ((((((rqrow, srow), r2row), trow), mrow), u8row),
                  ufrow)| {
                staged_block_row(
                    x, &base, theta, block, br, rqrow, srow, r2row,
                    trow, mrow, u8row, ufrow,
                );
            },
        );
    }
    StagedQuant { base, rq, rscale, r2, tier, metric, u8_mask, uf_mask }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::metrics::rmse;
    use crate::util::rng::Pcg64;

    fn outlier_mat(rows: usize, cols: usize, seed: u64, n_out: usize,
                   mag: f32) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut m = Mat::randn(rows, cols, 1.0, &mut rng);
        for _ in 0..n_out {
            let i = rng.below(m.data.len());
            let jitter = 1.0 + rng.uniform_f32();
            m.data[i] = mag * jitter
                * if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        }
        m
    }

    #[test]
    fn tiers_follow_theta_and_kappa() {
        let x = outlier_mat(64, 64, 1, 6, 100.0);
        let sq = staged_quant(&x, 3.0, 16);
        for (bi, &t) in sq.tier.iter().enumerate() {
            let am = sq.metric[bi];
            let want = if am > 3.0 * STAGED_F32_KAPPA {
                Tier::F32
            } else if am > 3.0 {
                Tier::I8
            } else {
                Tier::I4
            };
            assert_eq!(t, want, "block {bi} absmax {am}");
            assert_eq!(sq.u8_mask[bi], t != Tier::I4);
            assert_eq!(sq.uf_mask[bi], t == Tier::F32);
        }
        // θ = +∞ disables every promotion (κ·∞ = ∞)
        let off = staged_quant(&x, f32::INFINITY, 16);
        assert_eq!(off.rate_i8(), 0.0);
        assert_eq!(off.rate_f32(), 0.0);
        // θ < 0 promotes everything to F32
        let all = staged_quant(&x, -1.0, 16);
        assert_eq!(all.rate_f32(), 1.0);
    }

    #[test]
    fn each_tier_tightens_the_error() {
        let x = outlier_mat(64, 64, 2, 8, 300.0);
        // all-I4 vs all-I8 vs all-F32 representations of the same data
        let i4 = staged_quant(&x, f32::INFINITY, 16);
        let e4 = rmse(&i4.dequant().data, &x.data);
        let mut i8t = staged_quant(&x, f32::INFINITY, 16);
        i8t.u8_mask.iter_mut().for_each(|u| *u = true);
        let e8 = rmse(&i8t.dequant().data, &x.data);
        let f32t = staged_quant(&x, -1.0, 16);
        let ef = rmse(&f32t.dequant().data, &x.data);
        assert!(e8 < e4 * 0.2, "e8={e8} e4={e4}");
        assert!(ef < e8 * 0.2, "ef={ef} e8={e8}");
        // the f32 tier is the exact remainder: near-lossless
        assert!(ef < 1e-4, "ef={ef}");
    }

    #[test]
    fn transposed_bit_identical_to_requantized_transpose() {
        use crate::quant::block::quant_work_counters;
        for (rows, cols, theta) in
            [(32usize, 32usize, 30.0f32), (40, 23, 3.0), (17, 49, -1.0)]
        {
            let x = outlier_mat(rows, cols, 0xA7, 6, 200.0);
            let sq = staged_quant(&x, theta, 16);
            let before = quant_work_counters();
            let st = sq.transposed();
            let after = quant_work_counters();
            assert_eq!(before, after,
                       "transposed() must not count as quant work");
            let fresh = staged_quant(&x.transpose(), theta, 16);
            assert_eq!(st.base.q, fresh.base.q, "({rows},{cols})");
            assert_eq!(st.base.scale, fresh.base.scale);
            assert_eq!(st.rq, fresh.rq);
            assert_eq!(st.rscale, fresh.rscale);
            assert_eq!(
                st.r2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                fresh.r2.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(st.tier, fresh.tier);
            assert_eq!(st.u8_mask, fresh.u8_mask);
            assert_eq!(st.uf_mask, fresh.uf_mask);
        }
    }

    #[test]
    fn parallel_staged_thread_count_invariant() {
        let x = outlier_mat(70, 55, 8, 12, 250.0);
        let s1 = staged_quant_threads(&x, 3.0, 16, 1);
        for threads in [2usize, 4, 7] {
            let st = staged_quant_threads(&x, 3.0, 16, threads);
            assert_eq!(s1.base.q, st.base.q, "threads={threads}");
            assert_eq!(s1.rq, st.rq);
            assert_eq!(s1.rscale, st.rscale);
            assert_eq!(
                s1.r2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                st.r2.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(s1.tier, st.tier);
        }
    }

    #[test]
    fn base_codes_are_nibble_range() {
        let x = outlier_mat(48, 48, 9, 5, 150.0);
        let sq = staged_quant(&x, 2.0, 16);
        assert!(sq.base.q.iter()
            .all(|&q| (-7..=7).contains(&(q as i32))));
        // and the nibble pack of the base is buildable
        let p4 = sq.base.col_panels_i4();
        assert_eq!(p4.widths.iter().sum::<usize>(), sq.base.cols);
    }
}
