//! 1 x G per-group n-bit quantization for non-linear activation contexts
//! (paper §5.2): INT10 with 1x128 groups stores norm/activation inputs at
//! 5/8 of BF16 memory while keeping gradients near-lossless (Fig 7a).

use crate::util::Mat;

use super::block::safe_scale;

#[derive(Debug, Clone)]
pub struct GroupQuant {
    pub rows: usize,
    pub cols: usize,
    pub group: usize,
    pub bits: u32,
    /// codes, row-major (i16 holds up to 15-bit magnitudes)
    pub q: Vec<i16>,
    /// (rows x cols/group) scales
    pub scale: Vec<f32>,
}

pub fn levels_for_bits(bits: u32) -> f32 {
    (1u32 << (bits - 1)) as f32 - 1.0
}

/// Quantize each 1 x group row-segment with its own absmax scale.
pub fn group_quant(x: &Mat, group: usize, bits: u32) -> GroupQuant {
    assert!(x.cols % group == 0, "cols must divide group size");
    assert!((2..=15).contains(&bits));
    let levels = levels_for_bits(bits);
    let gpr = x.cols / group;
    let mut q = vec![0i16; x.rows * x.cols];
    let mut scale = vec![1.0f32; x.rows * gpr];
    for r in 0..x.rows {
        for g in 0..gpr {
            let c0 = g * group;
            let seg = &x.data[r * x.cols + c0..r * x.cols + c0 + group];
            let am = seg.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let s = safe_scale(am, levels);
            scale[r * gpr + g] = s;
            let inv = 1.0 / s;
            for (i, &v) in seg.iter().enumerate() {
                q[r * x.cols + c0 + i] = (v * inv)
                    .round_ties_even()
                    .clamp(-levels, levels) as i16;
            }
        }
    }
    GroupQuant { rows: x.rows, cols: x.cols, group, bits, q, scale }
}

impl GroupQuant {
    pub fn dequant(&self) -> Mat {
        let gpr = self.cols / self.group;
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let s = self.scale[r * gpr + c / self.group];
                m.data[r * self.cols + c] =
                    self.q[r * self.cols + c] as f32 * s;
            }
        }
        m
    }

    /// Packed size in bytes: n-bit codes (bit-packed) + f32 scale/group.
    /// This is what the paper's ACT-MEM column counts.
    pub fn bytes(&self) -> usize {
        let code_bits = self.rows * self.cols * self.bits as usize;
        code_bits.div_ceil(8) + 4 * self.scale.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::metrics::rmse;
    use crate::util::rng::Pcg64;

    fn randmat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        Mat::randn(rows, cols, 2.0, &mut rng)
    }

    #[test]
    fn roundtrip_error_bound() {
        let x = randmat(8, 256, 1);
        let gq = group_quant(&x, 128, 10);
        let d = gq.dequant();
        let gpr = x.cols / 128;
        for r in 0..x.rows {
            for c in 0..x.cols {
                let s = gq.scale[r * gpr + c / 128];
                assert!((d.at(r, c) - x.at(r, c)).abs() <= s / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn error_decreases_with_bits() {
        let x = randmat(8, 256, 2);
        let mut last = f32::INFINITY;
        for bits in [4, 6, 8, 10, 12] {
            let e = rmse(&group_quant(&x, 128, bits).dequant().data,
                         &x.data);
            assert!(e < last, "bits={bits}: {e} !< {last}");
            last = e;
        }
    }

    #[test]
    fn int10_memory_is_5_8_of_bf16() {
        // paper §5.2: 10-bit codes = 10/16 = 5/8 of BF16, plus scales.
        let x = randmat(128, 1024, 3);
        let gq = group_quant(&x, 128, 10);
        let bf16_bytes = x.data.len() * 2;
        let code_bytes = (x.data.len() * 10) / 8;
        assert_eq!(gq.bytes(), code_bytes + 4 * (128 * 8));
        let ratio = code_bytes as f64 / bf16_bytes as f64;
        assert!((ratio - 0.625).abs() < 1e-9);
    }

    #[test]
    fn prop_codes_in_range() {
        crate::util::testing::forall("group-range", 30, |g| {
            let rows = g.usize_in(1, 8);
            let groups = g.usize_in(1, 4);
            let bits = g.usize_in(2, 12) as u32;
            let cols = groups * 32;
            let x = Mat::from_vec(rows, cols,
                                  g.vec_outliers(rows * cols, 1.0, 3, 90.0));
            let gq = group_quant(&x, 32, bits);
            let l = levels_for_bits(bits) as i32;
            for &q in &gq.q {
                crate::prop_assert!((-l..=l).contains(&(q as i32)),
                                    "code {q} out of {l}-level range");
            }
            Ok(())
        });
    }
}
