//! `dbfq` — launcher CLI for the DBFQ training framework.
//!
//! Subcommands:
//!   train  --profile tiny --method fallback --steps 50 [--seed N]
//!          [--lr X] [--rmin/--rmax/--alpha ...] [--out ckpt]
//!   eval   --profile tiny --method fallback --ckpt path [--batches N]
//!   info   [--profile NAME]        show artifact/profile inventory
//!   gemm   --m --n --k [--block] [--theta]   run the CPU GEMM substrate

use anyhow::{bail, Result};

use dbfq::coordinator::{TrainConfig, Trainer};
use dbfq::data::Corpus;
use dbfq::model::Method;
use dbfq::runtime::{artifacts_dir, Runtime};
use dbfq::util::cli::Args;
use dbfq::util::rng::Pcg64;

use dbfq::config::{load_train_config, parse_method};

fn cmd_train(args: &Args) -> Result<()> {
    let rt = Runtime::open(&artifacts_dir())?;
    // --config file.json < CLI flags (see config module)
    let (mut cfg, steps) = load_train_config(args, 50)?;
    cfg.lr.peak = args.get_f64("lr", cfg.lr.peak);
    let profile = cfg.profile.clone();
    let method = cfg.method;
    let seed = cfg.seed;

    let prof = rt.profile(&profile)?.clone();
    println!(
        "dbfq train: profile={profile} ({} params, {} layers) \
         method={} steps={steps} platform={}",
        prof.n_params, prof.n_layers, method.tag(), rt.platform()
    );
    let corpus = Corpus::synthetic(200_000, prof.vocab, seed ^ 0xC0);
    let mut rng = Pcg64::new(seed);
    let mut trainer = Trainer::new(&rt, cfg)?;

    let t0 = std::time::Instant::now();
    for s in 0..steps {
        let tokens = corpus.sample_batch(prof.batch, prof.seq_len, &mut rng);
        let st = trainer.step_on(&tokens)?;
        if s < 3 || (s + 1) % 10 == 0 || s + 1 == steps {
            println!(
                "step {:4}  loss {:.4}  |g| {:.3}  fb-rate {:.3}  \
                 theta {:.3}  lr {:.2e}",
                st.step, st.loss, st.grad_norm, st.mean_fallback_rate,
                st.mean_theta, st.lr
            );
        }
    }
    println!(
        "trained {steps} steps in {:.1}s ({:.2} s/step)",
        t0.elapsed().as_secs_f64(),
        t0.elapsed().as_secs_f64() / steps as f64
    );
    if let Some(out) = args.get("out") {
        trainer.save_checkpoint(out)?;
        println!("checkpoint -> {out}.json / {out}.f32");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let rt = Runtime::open(&artifacts_dir())?;
    let profile = args.get_or("profile", "tiny").to_string();
    let method = parse_method(args.get_or("method", "fallback"))?;
    let prof = rt.profile(&profile)?.clone();
    let cfg = TrainConfig::new(&profile, method, 0, 0);
    let mut trainer = Trainer::new(&rt, cfg)?;
    if let Some(ckpt) = args.get("ckpt") {
        trainer.load_checkpoint(ckpt)?;
    }
    let corpus = Corpus::synthetic(100_000, prof.vocab, 0xE7A1);
    let batches =
        corpus.eval_batches(prof.batch, prof.seq_len,
                            args.get_usize("batches", 8));
    let loss = trainer.eval_on(&batches)?;
    println!("eval: mean loss {loss:.4}  ppl {:.2}", loss.exp());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = Runtime::open(&artifacts_dir())?;
    println!("platform: {}", rt.platform());
    if let Some(p) = args.get("profile") {
        let prof = rt.profile(p)?;
        println!("{prof:#?}");
        return Ok(());
    }
    let mut profs: Vec<_> = rt.profiles.keys().collect();
    profs.sort();
    println!("profiles:");
    for p in profs {
        let m = &rt.profiles[p];
        println!(
            "  {p:16} d={} L={} ff={} seq={} params={}",
            m.d_model, m.n_layers, m.d_ff, m.seq_len, m.n_params
        );
    }
    let mut arts: Vec<_> = rt.artifacts.keys().collect();
    arts.sort();
    println!("artifacts ({}):", arts.len());
    for a in arts {
        println!("  {a}");
    }
    Ok(())
}

fn cmd_gemm(args: &Args) -> Result<()> {
    use dbfq::gemm;
    use dbfq::util::Mat;
    let m = args.get_usize("m", 1024);
    let n = args.get_usize("n", 1024);
    let k = args.get_usize("k", 1024);
    let block = args.get_usize("block", 128);
    let theta = args.get_f64("theta", f64::INFINITY) as f32;
    let threads = args.get_usize("threads",
                                 dbfq::util::threadpool::default_threads());
    let mut rng = Pcg64::new(1);
    let a = Mat::randn(m, k, 1.0, &mut rng);
    let b = Mat::randn(k, n, 1.0, &mut rng);

    let t0 = std::time::Instant::now();
    let c = gemm::matmul(&a, &b, threads);
    let t_f32 = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let cq = gemm::quantized_matmul(&a, &b, block, threads);
    let t_i8 = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let (cf, rate) = gemm::fallback_matmul(&a, &b, theta, block, threads);
    let t_fb = t0.elapsed().as_secs_f64();

    let gops = |t: f64| 2.0 * (m * n * k) as f64 / t / 1e9;
    println!("f32      : {:8.2} Gops ({t_f32:.3}s)", gops(t_f32));
    println!("int8-blk : {:8.2} Gops ({t_i8:.3}s)", gops(t_i8));
    println!("fallback : {:8.2} Gops ({t_fb:.3}s) rate={rate:.3}",
             gops(t_fb));
    println!(
        "int8 rel-err {:.4}  fallback rel-err {:.4}",
        dbfq::quant::metrics::rel_err(&cq.data, &c.data),
        dbfq::quant::metrics::rel_err(&cf.data, &c.data)
    );
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env(&["fast"]).map_err(anyhow::Error::msg)?;
    match args.positional().first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("info") | None => cmd_info(&args),
        Some("gemm") => cmd_gemm(&args),
        Some(other) => bail!(
            "unknown command '{other}' (train | eval | info | gemm)"
        ),
    }
}
