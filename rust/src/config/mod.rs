//! Config-file layer: load/validate/merge `TrainConfig` from JSON.
//!
//! A launcher config file looks like:
//!
//! ```json
//! {
//!   "profile": "small",
//!   "method": "fallback",
//!   "seed": 0,
//!   "steps": 300,
//!   "lr": {"peak": 1e-3, "warmup": 30},
//!   "weight_decay": 1e-3,
//!   "grad_clip": 1.0,
//!   "fallback": {"r_min": 0.1, "r_max": 0.3, "alpha": 1.3},
//!   "quant": {"x_bits": 8, "w_bits": 8, "dy_bits": 8,
//!             "ctx_bits": 10, "sr_dy": true, "sr_ctx": true,
//!             "criterion": "absmax"}
//! }
//! ```
//!
//! CLI flags override file values (`Args` wins over JSON wins over
//! paper defaults) — the usual launcher precedence.

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{LrSchedule, QScalars, TrainConfig};
use crate::model::Method;
use crate::util::cli::Args;
use crate::util::json::Json;

pub fn parse_method(s: &str) -> Result<Method> {
    Ok(match s {
        "bf16" => Method::Bf16,
        "block" => Method::Block,
        "jetfire" => Method::Jetfire,
        "fallback" => Method::Fallback,
        other => bail!("unknown method '{other}' \
                        (bf16|block|jetfire|fallback)"),
    })
}

fn bits_to_levels(bits: f64) -> Result<f32> {
    if !(2.0..=23.0).contains(&bits) {
        bail!("bit-width {bits} out of range [2, 23]");
    }
    Ok((2f64.powi(bits as i32 - 1) - 1.0) as f32)
}

/// Build a TrainConfig from an optional JSON file + CLI overrides.
pub fn load_train_config(args: &Args, default_steps: usize)
                         -> Result<(TrainConfig, usize)> {
    let mut j = Json::Obj(Default::default());
    if let Some(path) = args.get("config") {
        j = Json::parse_file(path).map_err(|e| anyhow!(e))?;
    }
    let gs = |key: &str| j.get(key).and_then(|v| v.as_str().map(String::from));
    let gn = |key: &str| j.get(key).and_then(|v| v.as_f64());

    let profile = args
        .get("profile")
        .map(String::from)
        .or_else(|| gs("profile"))
        .unwrap_or_else(|| "tiny".into());
    let method = parse_method(
        args.get("method")
            .map(String::from)
            .or_else(|| gs("method"))
            .as_deref()
            .unwrap_or("fallback"),
    )?;
    let steps = args.get("steps").map(|s| s.parse().unwrap()).or_else(
        || gn("steps").map(|n| n as usize)).unwrap_or(default_steps);
    let seed = args
        .get("seed")
        .map(|s| s.parse().unwrap())
        .or_else(|| gn("seed").map(|n| n as u64))
        .unwrap_or(0);

    let mut cfg = TrainConfig::new(&profile, method, seed, steps);

    if let Some(lr) = j.get("lr") {
        cfg.lr = LrSchedule {
            peak: lr.get("peak").and_then(|v| v.as_f64())
                .unwrap_or(cfg.lr.peak),
            warmup: lr.get("warmup").and_then(|v| v.as_usize())
                .unwrap_or(cfg.lr.warmup),
            total: steps,
        };
    }
    if let Some(v) = args.get("lr") {
        cfg.lr.peak = v.parse()?;
    }
    if let Some(v) = gn("weight_decay") {
        cfg.weight_decay = v;
    }
    if let Some(v) = gn("grad_clip") {
        cfg.grad_clip = v;
    }
    if let Some(fb) = j.get("fallback") {
        if let Some(v) = fb.get("r_min").and_then(|v| v.as_f64()) {
            cfg.r_min = v;
        }
        if let Some(v) = fb.get("r_max").and_then(|v| v.as_f64()) {
            cfg.r_max = v;
        }
        if let Some(v) = fb.get("alpha").and_then(|v| v.as_f64()) {
            cfg.alpha = v as f32;
        }
    }
    cfg.r_min = args.get_f64("rmin", cfg.r_min);
    cfg.r_max = args.get_f64("rmax", cfg.r_max);
    cfg.alpha = args.get_f64("alpha", cfg.alpha as f64) as f32;

    if let Some(q) = j.get("quant") {
        let mut qs = QScalars::default();
        if let Some(b) = q.get("x_bits").and_then(|v| v.as_f64()) {
            qs.levels_x = bits_to_levels(b)?;
        }
        if let Some(b) = q.get("w_bits").and_then(|v| v.as_f64()) {
            qs.levels_w = bits_to_levels(b)?;
        }
        if let Some(b) = q.get("dy_bits").and_then(|v| v.as_f64()) {
            qs.levels_dy = bits_to_levels(b)?;
        }
        if let Some(b) = q.get("ctx_bits").and_then(|v| v.as_f64()) {
            qs.ctx_bits = b as f32;
        }
        if let Some(b) = q.get("sr_dy").and_then(|v| v.as_bool()) {
            qs.sr_dy = b as u8 as f32;
        }
        if let Some(b) = q.get("sr_ctx").and_then(|v| v.as_bool()) {
            qs.sr_ctx = b as u8 as f32;
        }
        if let Some(c) = q.get("criterion").and_then(|v| v.as_str()) {
            qs.crit = match c {
                "absmax" => [1.0, 0.0, 0.0],
                "l1" => [0.0, 1.0, 0.0],
                "l1rel" => [0.0, 0.0, 1.0],
                other => bail!("unknown criterion '{other}'"),
            };
        }
        cfg.qscalars = qs;
    }

    // validation
    if !(0.0..=1.0).contains(&cfg.r_min) || !(0.0..=1.0).contains(&cfg.r_max)
        || cfg.r_min > cfg.r_max
    {
        bail!("invalid fallback band [{}, {}]", cfg.r_min, cfg.r_max);
    }
    if cfg.alpha <= 1.0 {
        bail!("adjustment factor alpha must exceed 1, got {}", cfg.alpha);
    }
    Ok((cfg, steps))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Args {
        let v: Vec<String> = xs.iter().map(|s| s.to_string()).collect();
        Args::parse(&v, &[]).unwrap()
    }

    #[test]
    fn defaults_without_file() {
        let (cfg, steps) = load_train_config(&args(&[]), 50).unwrap();
        assert_eq!(cfg.profile, "tiny");
        assert_eq!(cfg.method, Method::Fallback);
        assert_eq!(steps, 50);
        assert_eq!(cfg.r_min, 0.1);
        assert_eq!(cfg.alpha, 1.3);
    }

    #[test]
    fn file_then_cli_precedence() {
        let dir = std::env::temp_dir().join("dbfq_cfg_test.json");
        std::fs::write(&dir, r#"{
            "profile": "small", "method": "block", "steps": 100,
            "lr": {"peak": 0.01, "warmup": 5},
            "fallback": {"r_min": 0.05, "r_max": 0.4, "alpha": 2.0},
            "quant": {"x_bits": 6, "criterion": "l1", "sr_dy": false}
        }"#).unwrap();
        let a = args(&["--config", dir.to_str().unwrap(),
                       "--method", "fallback", "--rmin", "0.2"]);
        let (cfg, steps) = load_train_config(&a, 50).unwrap();
        assert_eq!(cfg.profile, "small"); // from file
        assert_eq!(cfg.method, Method::Fallback); // CLI override
        assert_eq!(steps, 100);
        assert_eq!(cfg.lr.peak, 0.01);
        assert_eq!(cfg.r_min, 0.2); // CLI override
        assert_eq!(cfg.r_max, 0.4); // file
        assert_eq!(cfg.qscalars.levels_x, 31.0); // 6 bits
        assert_eq!(cfg.qscalars.crit, [0.0, 1.0, 0.0]);
        assert_eq!(cfg.qscalars.sr_dy, 0.0);
    }

    #[test]
    fn rejects_invalid() {
        let dir = std::env::temp_dir().join("dbfq_cfg_bad.json");
        std::fs::write(&dir, r#"{"fallback": {"r_min": 0.5, "r_max": 0.1}}"#)
            .unwrap();
        let a = args(&["--config", dir.to_str().unwrap()]);
        assert!(load_train_config(&a, 10).is_err());

        std::fs::write(&dir, r#"{"quant": {"x_bits": 99}}"#).unwrap();
        let a = args(&["--config", dir.to_str().unwrap()]);
        assert!(load_train_config(&a, 10).is_err());

        std::fs::write(&dir, r#"{"method": "fp4"}"#).unwrap();
        let a = args(&["--config", dir.to_str().unwrap()]);
        assert!(load_train_config(&a, 10).is_err());
    }
}
