//! Training coordinator: drives the AOT train/eval artifacts through the
//! PJRT runtime, owns optimizer state, runs the delay-threshold
//! controller (Algorithm 2) between steps, and streams metrics.
//!
//! This is the L3 "framework" a user launches: configure a profile +
//! method, hand it a data source, call `step()` in a loop. Python is
//! never on this path.

pub mod metrics_log;
pub mod threshold;

use anyhow::{anyhow, Result};

use crate::model::Method;
use crate::runtime::{Runtime, Value};
use crate::util::rng::Pcg64;

pub use metrics_log::MetricsLog;
pub use threshold::{RateAccumulator, ThresholdController};

/// Runtime quantization scalars fed to every artifact call
/// (see `trainstep.QSCALAR_NAMES`).
#[derive(Debug, Clone)]
pub struct QScalars {
    pub levels_x: f32,
    pub levels_w: f32,
    pub levels_dy: f32,
    pub sr_dy: f32,
    pub sr_ctx: f32,
    pub fallback_bwd: f32,
    /// one-hot [absmax, l1, l1rel]
    pub crit: [f32; 3],
    pub ctx_bits: f32,
    /// forward non-linear *input* bits (Fig 6a); >= 15 disables (BF16)
    pub nl_in_bits: f32,
}

impl Default for QScalars {
    fn default() -> Self {
        QScalars {
            levels_x: 127.0,
            levels_w: 127.0,
            levels_dy: 127.0,
            sr_dy: 1.0,
            sr_ctx: 1.0,
            fallback_bwd: 0.0,
            crit: [1.0, 0.0, 0.0],
            ctx_bits: 10.0,
            nl_in_bits: 15.0,
        }
    }
}

impl QScalars {
    /// Effectively-lossless settings (the high-precision reference used
    /// by gradient-cosine ablations).
    pub fn lossless() -> QScalars {
        QScalars {
            levels_x: 4_194_303.0, // 2^23-ish: f32-exact "no quantization"
            levels_w: 4_194_303.0,
            levels_dy: 4_194_303.0,
            sr_dy: 0.0,
            sr_ctx: 0.0,
            fallback_bwd: 0.0,
            crit: [1.0, 0.0, 0.0],
            ctx_bits: 15.0,
            nl_in_bits: 15.0,
        }
    }

    pub fn bits(x_bits: u32, w_bits: u32, dy_bits: u32) -> QScalars {
        QScalars {
            levels_x: (1u32 << (x_bits - 1)) as f32 - 1.0,
            levels_w: (1u32 << (w_bits - 1)) as f32 - 1.0,
            levels_dy: (1u32 << (dy_bits - 1)) as f32 - 1.0,
            ..QScalars::default()
        }
    }

    pub fn to_vec(&self) -> Vec<f32> {
        vec![
            self.levels_x,
            self.levels_w,
            self.levels_dy,
            self.sr_dy,
            self.sr_ctx,
            self.fallback_bwd,
            self.crit[0],
            self.crit[1],
            self.crit[2],
            self.ctx_bits,
            self.nl_in_bits,
        ]
    }
}

/// Learning-rate schedule: linear warmup then linear decay (paper
/// Appendix A uses exactly this shape).
#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub peak: f64,
    pub warmup: usize,
    pub total: usize,
}

impl LrSchedule {
    pub fn lr_at(&self, step: usize) -> f64 {
        if self.total == 0 {
            return self.peak;
        }
        if step < self.warmup {
            return self.peak * (step + 1) as f64 / self.warmup as f64;
        }
        let rest = (self.total - step.min(self.total)) as f64
            / (self.total - self.warmup).max(1) as f64;
        self.peak * rest.max(0.0)
    }
}

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub profile: String,
    pub method: Method,
    pub seed: u64,
    pub lr: LrSchedule,
    pub weight_decay: f64,
    pub grad_clip: f64,
    pub qscalars: QScalars,
    /// fallback-rate band + adjustment factor (Algorithm 2)
    pub r_min: f64,
    pub r_max: f64,
    pub alpha: f32,
    /// pin θ forever (constant-rate ablation, Fig 8b) — skips Alg 2
    pub freeze_thresholds: bool,
}

impl TrainConfig {
    pub fn new(profile: &str, method: Method, seed: u64,
               total_steps: usize) -> TrainConfig {
        TrainConfig {
            profile: profile.to_string(),
            method,
            seed,
            lr: LrSchedule { peak: 1e-3, warmup: total_steps / 10 + 1,
                             total: total_steps },
            weight_decay: 1e-3,
            grad_clip: 1.0,
            qscalars: QScalars::default(),
            r_min: 0.1,
            r_max: 0.3,
            alpha: 1.3,
            freeze_thresholds: false,
        }
    }
}

/// Per-step statistics.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub step: usize,
    pub loss: f64,
    pub grad_norm: f64,
    pub mean_fallback_rate: f64,
    pub mean_theta: f64,
    pub lr: f64,
}

/// The training coordinator.
pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: TrainConfig,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: usize,
    pub controller: ThresholdController,
    pub history: Vec<StepStats>,
    rng: Pcg64,
    train_artifact: String,
    eval_artifact: String,
}

impl<'rt> Trainer<'rt> {
    /// Initialize parameters via the profile's `init` artifact.
    pub fn new(rt: &'rt Runtime, cfg: TrainConfig) -> Result<Trainer<'rt>> {
        let prof = rt.profile(&cfg.profile)?.clone();
        let train_artifact =
            format!("train_{}_{}", cfg.profile, cfg.method.tag());
        let eval_artifact =
            format!("eval_{}_{}", cfg.profile, cfg.method.tag());
        if !rt.has_artifact(&train_artifact) {
            return Err(anyhow!(
                "artifact '{train_artifact}' missing — re-run `make \
                 artifacts` with this profile/mode"
            ));
        }
        let out = rt.call(
            &format!("init_{}", cfg.profile),
            &[Value::scalar_i32(cfg.seed as i32)],
        )?;
        let params = out.into_iter().next().unwrap().into_f32()?;
        assert_eq!(params.len(), prof.n_params);

        let controller = if cfg.method == Method::Fallback {
            let mut c = ThresholdController::paper_default(prof.n_sites);
            c.r_min = cfg.r_min;
            c.r_max = cfg.r_max;
            c.alpha = cfg.alpha;
            c
        } else {
            ThresholdController::disabled(prof.n_sites)
        };

        Ok(Trainer {
            rt,
            m: vec![0.0; params.len()],
            v: vec![0.0; params.len()],
            params,
            step: 0,
            controller,
            history: Vec::new(),
            rng: Pcg64::new(cfg.seed ^ 0xD8F9),
            cfg,
            train_artifact,
            eval_artifact,
        })
    }

    /// Pin all thresholds to a fixed value (constant-rate ablations).
    pub fn set_thresholds(&mut self, theta: f32) {
        for t in self.controller.thresholds.iter_mut() {
            *t = theta;
        }
    }

    /// One optimizer step on a (batch, seq+1) token window.
    pub fn step_on(&mut self, tokens: &[i32]) -> Result<StepStats> {
        let prof = self.rt.profile(&self.cfg.profile)?;
        let lr = self.cfg.lr.lr_at(self.step);
        let seed = self.rng.next_u32() as i32;

        let inputs = vec![
            Value::vec_f32(std::mem::take(&mut self.params)),
            Value::vec_f32(std::mem::take(&mut self.m)),
            Value::vec_f32(std::mem::take(&mut self.v)),
            Value::scalar_f32(self.step as f32),
            Value::mat_i32(tokens.to_vec(), prof.batch, prof.seq_len + 1),
            Value::scalar_i32(seed),
            Value::vec_f32(self.controller.thresholds.clone()),
            Value::vec_f32(self.cfg.qscalars.to_vec()),
            Value::F32(
                vec![lr as f32, self.cfg.weight_decay as f32,
                     self.cfg.grad_clip as f32],
                vec![3],
            ),
        ];
        let mut out = self.rt.call(&self.train_artifact, &inputs)?;
        // outputs: params, m, v, loss, rates, grad_norm
        let grad_norm = out.pop().unwrap().scalar()? as f64;
        let rates = out.pop().unwrap().into_f32()?;
        let loss = out.pop().unwrap().scalar()? as f64;
        self.v = out.pop().unwrap().into_f32()?;
        self.m = out.pop().unwrap().into_f32()?;
        self.params = out.pop().unwrap().into_f32()?;

        let mean_rate = rates.iter().map(|&r| r as f64).sum::<f64>()
            / rates.len().max(1) as f64;
        if self.cfg.method == Method::Fallback
            && !self.cfg.freeze_thresholds
        {
            self.controller.update(&rates);
        }
        self.step += 1;
        let stats = StepStats {
            step: self.step,
            loss,
            grad_norm,
            mean_fallback_rate: mean_rate,
            mean_theta: self.controller.mean_theta(),
            lr,
        };
        self.history.push(stats.clone());
        Ok(stats)
    }

    /// Mean eval loss over token windows (deterministic, no SR).
    pub fn eval_on(&self, batches: &[Vec<i32>]) -> Result<f64> {
        let prof = self.rt.profile(&self.cfg.profile)?;
        let mut tot = 0.0f64;
        for tokens in batches {
            let out = self.rt.call(
                &self.eval_artifact,
                &[
                    Value::vec_f32(self.params.clone()),
                    Value::mat_i32(tokens.clone(), prof.batch,
                                   prof.seq_len + 1),
                    Value::vec_f32(self.controller.thresholds.clone()),
                    Value::vec_f32(self.cfg.qscalars.to_vec()),
                ],
            )?;
            tot += out[0].scalar()? as f64;
        }
        Ok(tot / batches.len().max(1) as f64)
    }

    /// Per-token eval losses for one window (answer-span scoring).
    pub fn eval_per_token(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let prof = self.rt.profile(&self.cfg.profile)?;
        let out = self.rt.call(
            &self.eval_artifact,
            &[
                Value::vec_f32(self.params.clone()),
                Value::mat_i32(tokens.to_vec(), prof.batch,
                               prof.seq_len + 1),
                Value::vec_f32(self.controller.thresholds.clone()),
                Value::vec_f32(self.cfg.qscalars.to_vec()),
            ],
        )?;
        out[1].clone().into_f32()
    }

    /// Save a JSON checkpoint (params as base-less f32 list is huge; we
    /// store raw little-endian f32 alongside a JSON header).
    pub fn save_checkpoint(&self, path: &str) -> Result<()> {
        let hdr = crate::util::json::obj(vec![
            ("profile", crate::util::json::Json::Str(
                self.cfg.profile.clone())),
            ("method", crate::util::json::Json::Str(
                self.cfg.method.tag().into())),
            ("step", crate::util::json::Json::Num(self.step as f64)),
            ("n_params", crate::util::json::Json::Num(
                self.params.len() as f64)),
            // full Algorithm 2 state (band, α, counters, θ vector) so
            // a resumed run skips the threshold re-adaptation
            // transient. This replaces the old bare `thresholds`
            // array, which emitted invalid JSON (`inf`) for the
            // disabled-controller baselines.
            ("controller", self.controller.to_json()),
        ]);
        std::fs::write(format!("{path}.json"), hdr.to_string())?;
        let mut raw = Vec::with_capacity(self.params.len() * 4);
        for p in &self.params {
            raw.extend_from_slice(&p.to_le_bytes());
        }
        std::fs::write(format!("{path}.f32"), raw)?;
        Ok(())
    }

    /// Load parameters from a checkpoint written by `save_checkpoint`,
    /// restoring the Algorithm 2 controller when the JSON header
    /// carries it (checkpoints predating the field still load — the
    /// controller then keeps its current state and re-adapts).
    pub fn load_checkpoint(&mut self, path: &str) -> Result<()> {
        let raw = std::fs::read(format!("{path}.f32"))?;
        if raw.len() != self.params.len() * 4 {
            return Err(anyhow!(
                "checkpoint size {} != expected {}",
                raw.len() / 4,
                self.params.len()
            ));
        }
        // Validate the header fully BEFORE touching self — an error
        // return must leave the trainer exactly as it was, never with
        // the rejected checkpoint's params half-applied.
        let controller = load_checkpoint_controller(
            &format!("{path}.json"),
            self.controller.thresholds.len(),
        )?;
        for (i, chunk) in raw.chunks_exact(4).enumerate() {
            self.params[i] =
                f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        if let Some(c) = controller {
            self.controller = c;
        }
        Ok(())
    }
}

/// Parse a checkpoint JSON header and extract its Algorithm 2
/// controller, applying the legacy-degradation policy
/// `Trainer::load_checkpoint` has carried since the controller field
/// was introduced (factored out so the policy is unit-testable
/// without a live runtime):
///
/// * missing header file → `Ok(None)` — raw-params checkpoints are
///   fine, the live controller keeps its state and re-adapts;
/// * unreadable header → `Ok(None)` **with a loud warning**:
///   checkpoints from before the controller field wrote bare `inf`
///   tokens for disabled-controller baselines (invalid JSON) and
///   their params are perfectly intact — aborting the resume would
///   turn a recoverable situation into a hard stop, while losing the
///   controller only costs the re-adaptation transient. A parseable
///   header *without* the field likewise predates it → `Ok(None)`;
/// * a controller that is present but malformed, or sized for a
///   different model than `expected_sites` → `Err` before any state
///   is touched — that is corruption, not legacy.
pub fn load_checkpoint_controller(hdr_path: &str,
                                  expected_sites: usize)
                                  -> Result<Option<ThresholdController>>
{
    if !std::path::Path::new(hdr_path).exists() {
        return Ok(None);
    }
    match crate::util::json::Json::parse_file(hdr_path) {
        Ok(hdr) => match hdr.get("controller") {
            Some(cj) => {
                let c = ThresholdController::from_json(cj)
                    .map_err(|e| anyhow!("checkpoint controller: {e}"))?;
                if c.thresholds.len() != expected_sites {
                    return Err(anyhow!(
                        "checkpoint controller has {} sites, model \
                         has {expected_sites}",
                        c.thresholds.len()
                    ));
                }
                Ok(Some(c))
            }
            None => Ok(None),
        },
        Err(e) => {
            eprintln!(
                "warning: checkpoint header {hdr_path} is unreadable \
                 ({e}); loading params only — the threshold \
                 controller re-adapts"
            );
            Ok(None)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let s = LrSchedule { peak: 1.0, warmup: 10, total: 100 };
        assert!(s.lr_at(0) < s.lr_at(9));
        assert!((s.lr_at(9) - 1.0).abs() < 0.11);
        assert!(s.lr_at(50) < 1.0);
        assert!(s.lr_at(99) < s.lr_at(50));
        assert_eq!(s.lr_at(100), 0.0);
    }

    #[test]
    fn qscalars_vec_layout() {
        let q = QScalars::default();
        let v = q.to_vec();
        assert_eq!(v.len(), 11);
        assert_eq!(v[0], 127.0);
        assert_eq!(v[3], 1.0); // sr_dy
        assert_eq!(v[6], 1.0); // crit absmax
        assert_eq!(v[9], 10.0); // ctx bits
    }

    #[test]
    fn qscalars_bits() {
        let q = QScalars::bits(8, 8, 4);
        assert_eq!(q.levels_x, 127.0);
        assert_eq!(q.levels_dy, 7.0);
    }

    fn tmp_hdr(tag: &str, contents: &str) -> String {
        let p = std::env::temp_dir().join(format!(
            "dbfq_ckpt_hdr_{tag}_{}.json",
            std::process::id()
        ));
        std::fs::write(&p, contents).unwrap();
        p.to_str().unwrap().to_string()
    }

    #[test]
    fn checkpoint_header_degradation_policy() {
        // Missing file: params-only load, no error.
        let missing = std::env::temp_dir()
            .join("dbfq_no_such_header.json");
        assert!(load_checkpoint_controller(
            missing.to_str().unwrap(), 4)
            .unwrap()
            .is_none());

        // Valid header with a matching controller: restored.
        let c = ThresholdController::paper_default(4);
        let hdr = crate::util::json::obj(vec![
            ("step", crate::util::json::Json::Num(7.0)),
            ("controller", c.to_json()),
        ]);
        let p = tmp_hdr("valid", &hdr.to_string());
        let got = load_checkpoint_controller(&p, 4).unwrap().unwrap();
        assert_eq!(got.thresholds, c.thresholds);
        // ...but sized for a different model: a loud error, never a
        // silently mismatched controller.
        let err = load_checkpoint_controller(&p, 9).unwrap_err();
        assert!(err.to_string().contains("sites"), "{err}");
        std::fs::remove_file(&p).ok();

        // Legacy pre-controller headers wrote bare `inf` tokens —
        // invalid JSON. Policy (since the controller field landed):
        // warn + params-only, NOT an error.
        let p = tmp_hdr("legacy", r#"{"thresholds": [inf, inf]}"#);
        assert!(load_checkpoint_controller(&p, 4)
            .unwrap()
            .is_none());
        std::fs::remove_file(&p).ok();

        // A parseable header without the field predates it: None.
        let p = tmp_hdr("nofield", r#"{"step": 3}"#);
        assert!(load_checkpoint_controller(&p, 4)
            .unwrap()
            .is_none());
        std::fs::remove_file(&p).ok();

        // A malformed controller value is corruption, not legacy.
        let p = tmp_hdr("malformed", r#"{"controller": "oops"}"#);
        let err = load_checkpoint_controller(&p, 4).unwrap_err();
        assert!(err.to_string().contains("controller"), "{err}");
        std::fs::remove_file(&p).ok();
    }
}
