//! Delay-threshold controller — paper Algorithm 2 (§4.4, Appendix D).
//!
//! Per linear-layer fallback thresholds θ are adjusted *between* steps
//! from the previous step's observed fallback rates: divide by α when
//! the rate falls below r_min, multiply by α when it exceeds r_max.
//! This avoids the tensor-wide TopK reduction a direct threshold would
//! need, at the cost of one-step delay (hence the name).
//!
//! θ values are runtime inputs to the AOT train-step graph, so the
//! controller needs no recompilation to act.
//!
//! Controller state serializes to JSON
//! ([`to_json`](ThresholdController::to_json) /
//! [`from_json`](ThresholdController::from_json)) so a training
//! process can persist its adapted θ vector and resume without
//! re-walking the Algorithm 2 transient — `gemm::pipeline`'s
//! warm-state files embed exactly this.

use crate::util::json::{obj, Json};

/// Controller state for all quantization sites of a model.
#[derive(Debug, Clone)]
pub struct ThresholdController {
    pub thresholds: Vec<f32>,
    pub r_min: f64,
    pub r_max: f64,
    pub alpha: f32,
    /// adjustment counters (diagnostics)
    pub n_up: usize,
    pub n_down: usize,
}

impl ThresholdController {
    /// Paper defaults: range [0.1, 0.3], α = 1.3, θ₀ = 1.
    pub fn paper_default(n_sites: usize) -> ThresholdController {
        ThresholdController::new(n_sites, 1.0, 0.1, 0.3, 1.3)
    }

    pub fn new(n_sites: usize, theta0: f32, r_min: f64, r_max: f64,
               alpha: f32) -> ThresholdController {
        assert!(alpha > 1.0, "adjustment factor must exceed 1");
        assert!(0.0 <= r_min && r_min <= r_max && r_max <= 1.0);
        ThresholdController {
            thresholds: vec![theta0; n_sites],
            r_min,
            r_max,
            alpha,
            n_up: 0,
            n_down: 0,
        }
    }

    /// Disable fallback entirely (Block / Jetfire / BF16 baselines).
    pub fn disabled(n_sites: usize) -> ThresholdController {
        ThresholdController {
            thresholds: vec![f32::INFINITY; n_sites],
            r_min: 0.0,
            r_max: 1.0,
            alpha: 2.0,
            n_up: 0,
            n_down: 0,
        }
    }

    /// Algorithm 2 lines 13-19: one post-step adjustment from observed
    /// per-site fallback rates.
    pub fn update(&mut self, rates: &[f32]) {
        assert_eq!(rates.len(), self.thresholds.len());
        for (theta, &rate) in self.thresholds.iter_mut().zip(rates) {
            if !theta.is_finite() {
                continue; // disabled site
            }
            if (rate as f64) < self.r_min {
                *theta /= self.alpha;
                self.n_down += 1;
            } else if (rate as f64) > self.r_max {
                *theta *= self.alpha;
                self.n_up += 1;
            }
        }
    }

    /// Serialize the full controller state (θ vector, band, α,
    /// adjustment counters). Disabled sites carry θ = +∞, which JSON
    /// numbers cannot express — they serialize as the string `"inf"`.
    pub fn to_json(&self) -> Json {
        let thresholds = Json::Arr(
            self.thresholds
                .iter()
                .map(|&t| {
                    if t.is_finite() {
                        Json::Num(t as f64)
                    } else {
                        Json::Str("inf".into())
                    }
                })
                .collect(),
        );
        obj(vec![
            ("thresholds", thresholds),
            ("r_min", Json::Num(self.r_min)),
            ("r_max", Json::Num(self.r_max)),
            ("alpha", Json::Num(self.alpha as f64)),
            ("n_up", Json::Num(self.n_up as f64)),
            ("n_down", Json::Num(self.n_down as f64)),
        ])
    }

    /// Restore a controller serialized by
    /// [`to_json`](ThresholdController::to_json). Enforces the same
    /// invariants as [`new`](ThresholdController::new) — a corrupted
    /// or hand-edited file with `alpha ≤ 1` or a malformed band
    /// would otherwise run Algorithm 2 *inverted* (adjusting θ away
    /// from the band), so external input fails here instead.
    pub fn from_json(j: &Json) -> Result<ThresholdController, String> {
        let f = |k: &str| {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("controller: missing '{k}'"))
        };
        let thresholds = j
            .get("thresholds")
            .and_then(|v| v.as_arr())
            .ok_or("controller: missing 'thresholds'")?
            .iter()
            .map(|v| match v {
                Json::Num(n) => Ok(*n as f32),
                Json::Str(s) if s == "inf" => Ok(f32::INFINITY),
                other => Err(format!("controller: bad θ {other:?}")),
            })
            .collect::<Result<Vec<f32>, String>>()?;
        let (r_min, r_max) = (f("r_min")?, f("r_max")?);
        let alpha = f("alpha")? as f32;
        let valid = alpha > 1.0
            && 0.0 <= r_min
            && r_min <= r_max
            && r_max <= 1.0;
        if !valid {
            return Err(format!(
                "controller: invalid state (alpha={alpha} must \
                 exceed 1, band [{r_min}, {r_max}] must satisfy \
                 0 <= r_min <= r_max <= 1)"
            ));
        }
        Ok(ThresholdController {
            thresholds,
            r_min,
            r_max,
            alpha,
            n_up: f("n_up")? as usize,
            n_down: f("n_down")? as usize,
        })
    }

    pub fn mean_theta(&self) -> f64 {
        let finite: Vec<f64> = self
            .thresholds
            .iter()
            .filter(|t| t.is_finite())
            .map(|&t| t as f64)
            .collect();
        if finite.is_empty() {
            return f64::INFINITY;
        }
        finite.iter().sum::<f64>() / finite.len() as f64
    }
}

/// Per-site fallback rates accumulated across the microsteps of one
/// optimizer step — the execution-side feedback hook of the
/// layer-step pipeline (`gemm::pipeline`).
///
/// Each microstep the pipeline records the rates its fallback GEMMs
/// *actually ran with* (one per linear site); at the step boundary
/// [`flush_into`](RateAccumulator::flush_into) hands the per-site
/// means to [`ThresholdController::update`] and resets. θ therefore
/// adapts from real execution, with Algorithm 2's one-step delay,
/// instead of from offline tensor statistics.
#[derive(Debug, Clone)]
pub struct RateAccumulator {
    sums: Vec<f64>,
    microsteps: usize,
}

impl RateAccumulator {
    pub fn new(n_sites: usize) -> RateAccumulator {
        RateAccumulator { sums: vec![0.0; n_sites], microsteps: 0 }
    }

    pub fn n_sites(&self) -> usize {
        self.sums.len()
    }

    /// Microsteps recorded since the last flush.
    pub fn microsteps(&self) -> usize {
        self.microsteps
    }

    pub fn is_empty(&self) -> bool {
        self.microsteps == 0
    }

    /// Record one microstep's observed per-site fallback rates.
    pub fn record(&mut self, rates: &[f64]) {
        assert_eq!(rates.len(), self.sums.len(), "site count");
        for (s, &r) in self.sums.iter_mut().zip(rates) {
            *s += r;
        }
        self.microsteps += 1;
    }

    /// Mean per-site rates over the recorded microsteps (all zeros
    /// when nothing was recorded).
    pub fn mean_rates(&self) -> Vec<f32> {
        let n = self.microsteps.max(1) as f64;
        self.sums.iter().map(|&s| (s / n) as f32).collect()
    }

    /// Apply Algorithm 2 with the accumulated means and reset for the
    /// next step, returning the means that were applied. No-op
    /// returning an empty vec when no microstep was recorded (a
    /// controller update from fabricated zero rates would drive every
    /// θ down).
    pub fn flush_into(&mut self,
                      c: &mut ThresholdController) -> Vec<f32> {
        if self.microsteps == 0 {
            return Vec::new();
        }
        let means = self.mean_rates();
        c.update(&means);
        self.sums.iter_mut().for_each(|s| *s = 0.0);
        self.microsteps = 0;
        means
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_accumulator_means_and_flushes() {
        let mut acc = RateAccumulator::new(2);
        assert!(acc.is_empty());
        acc.record(&[0.4, 0.0]);
        acc.record(&[0.6, 0.2]);
        assert_eq!(acc.microsteps(), 2);
        let means = acc.mean_rates();
        assert!((means[0] - 0.5).abs() < 1e-6);
        assert!((means[1] - 0.1).abs() < 1e-6);
        let mut c = ThresholdController::new(2, 1.0, 0.1, 0.3, 1.3);
        let applied = acc.flush_into(&mut c);
        assert_eq!(applied, means);
        // site 0 above the band -> theta up; site 1 inside -> steady
        assert!(c.thresholds[0] > 1.0);
        assert_eq!(c.thresholds[1], 1.0);
        assert!(acc.is_empty());
        // flushing an empty accumulator must not move thresholds
        let before = c.thresholds.clone();
        assert!(acc.flush_into(&mut c).is_empty());
        assert_eq!(c.thresholds, before);
    }

    #[test]
    fn moves_toward_band() {
        let mut c = ThresholdController::new(2, 1.0, 0.1, 0.3, 1.3);
        c.update(&[0.0, 0.9]); // site0 too low -> theta down; site1 up
        assert!(c.thresholds[0] < 1.0);
        assert!(c.thresholds[1] > 1.0);
        assert_eq!(c.n_down, 1);
        assert_eq!(c.n_up, 1);
    }

    #[test]
    fn stays_inside_band() {
        let mut c = ThresholdController::new(1, 2.0, 0.1, 0.3, 1.3);
        c.update(&[0.2]);
        assert_eq!(c.thresholds[0], 2.0);
    }

    #[test]
    fn disabled_sites_never_move() {
        let mut c = ThresholdController::disabled(3);
        c.update(&[0.0, 0.5, 1.0]);
        assert!(c.thresholds.iter().all(|t| t.is_infinite()));
    }

    #[test]
    fn converges_on_simulated_plant() {
        // Plant: rate = fraction of block absmaxes (lognormal) > theta.
        // The controller must pull the rate into [0.1, 0.3] and keep it
        // there — the closed-loop property Algorithm 2 claims.
        let mut rng = crate::util::rng::Pcg64::new(1);
        let mut absmaxes = vec![0.0f32; 4096];
        for a in absmaxes.iter_mut() {
            *a = (rng.normal() * 1.2).exp() as f32;
        }
        let rate_for = |theta: f32| {
            absmaxes.iter().filter(|&&a| a > theta).count() as f32
                / absmaxes.len() as f32
        };
        let mut c = ThresholdController::new(1, 1000.0, 0.1, 0.3, 1.3);
        let mut in_band_streak = 0;
        for _ in 0..200 {
            let r = rate_for(c.thresholds[0]);
            c.update(&[r]);
            let r_now = rate_for(c.thresholds[0]);
            if (0.1..=0.3).contains(&(r_now as f64)) {
                in_band_streak += 1;
            } else {
                in_band_streak = 0;
            }
        }
        assert!(in_band_streak >= 50,
                "controller failed to settle (streak {in_band_streak})");
    }

    #[test]
    fn controller_json_roundtrip_including_disabled_sites() {
        let mut c = ThresholdController::new(3, 2.0, 0.05, 0.4, 1.5);
        c.thresholds[1] = f32::INFINITY; // disabled site
        c.update(&[0.9, 0.9, 0.0]); // moves θ0 up, θ2 down, counters set
        let j = c.to_json();
        // the serialized form must be valid JSON text (∞ cannot ride
        // as a bare number)
        let reparsed =
            crate::util::json::Json::parse(&j.to_string()).unwrap();
        let r = ThresholdController::from_json(&reparsed).unwrap();
        assert_eq!(r.thresholds, c.thresholds);
        assert_eq!((r.r_min, r.r_max, r.alpha),
                   (c.r_min, c.r_max, c.alpha));
        assert_eq!((r.n_up, r.n_down), (c.n_up, c.n_down));
        // malformed input errors instead of panicking
        assert!(ThresholdController::from_json(
            &crate::util::json::Json::Null).is_err());
        // inverted-feedback states are rejected at the boundary: an
        // alpha ≤ 1 would make update() adjust θ *away* from the band
        let mut bad = c.to_json();
        if let crate::util::json::Json::Obj(m) = &mut bad {
            m.insert("alpha".into(),
                     crate::util::json::Json::Num(0.5));
        }
        let err = ThresholdController::from_json(&bad).unwrap_err();
        assert!(err.contains("invalid state"), "{err}");
    }

    #[test]
    fn prop_update_is_bounded_multiplicative() {
        crate::util::testing::forall("thresh-bounded", 30, |g| {
            let n = g.usize_in(1, 16);
            let mut c = ThresholdController::new(
                n, g.f32_in(0.01, 100.0), 0.1, 0.3, 1.3);
            let before = c.thresholds.clone();
            let rates: Vec<f32> =
                (0..n).map(|_| g.f32_in(0.0, 1.0)).collect();
            c.update(&rates);
            for (b, a) in before.iter().zip(&c.thresholds) {
                let ratio = a / b;
                crate::prop_assert!(
                    (ratio - 1.0).abs() < 1e-6
                        || (ratio - 1.3).abs() < 1e-3
                        || (ratio - 1.0 / 1.3).abs() < 1e-3,
                    "ratio {ratio}"
                );
            }
            Ok(())
        });
    }
}
