//! Structured metrics stream: JSON-lines writer + in-memory summaries.
//!
//! Every training/benchmark driver funnels its per-step statistics
//! through here, giving runs a uniform on-disk format
//! (`runs/*.jsonl`) that the fig7b bench and external tooling can
//! consume, plus cheap running summaries (mean/min/max/last, EMA).

use std::io::Write;

use crate::util::json::{obj, Json};

/// Running summary of one scalar series.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub count: usize,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    pub last: f64,
    pub ema: f64,
    pub ema_alpha: f64,
}

impl Series {
    pub fn new(name: &str) -> Series {
        Series {
            name: name.to_string(),
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            last: f64::NAN,
            ema: f64::NAN,
            ema_alpha: 0.1,
        }
    }

    pub fn push(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.last = v;
        self.ema = if self.ema.is_nan() {
            v
        } else {
            self.ema + self.ema_alpha * (v - self.ema)
        };
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }
}

/// JSON-lines metrics logger with per-key running summaries.
pub struct MetricsLog {
    file: Option<std::fs::File>,
    pub series: std::collections::BTreeMap<String, Series>,
    pub run: String,
}

impl MetricsLog {
    /// `path = None` keeps summaries in memory only.
    pub fn new(run: &str, path: Option<&str>) -> std::io::Result<MetricsLog> {
        let file = match path {
            Some(p) => {
                if let Some(dir) = std::path::Path::new(p).parent() {
                    std::fs::create_dir_all(dir)?;
                }
                Some(std::fs::File::create(p)?)
            }
            None => None,
        };
        Ok(MetricsLog {
            file,
            series: Default::default(),
            run: run.to_string(),
        })
    }

    /// Record one step's scalars; writes one JSON line if file-backed.
    pub fn log(&mut self, step: usize, kv: &[(&str, f64)])
               -> std::io::Result<()> {
        for (k, v) in kv {
            self.series
                .entry(k.to_string())
                .or_insert_with(|| Series::new(k))
                .push(*v);
        }
        if let Some(f) = &mut self.file {
            let mut rec = vec![
                ("run", Json::Str(self.run.clone())),
                ("step", Json::Num(step as f64)),
            ];
            for (k, v) in kv {
                rec.push((k, Json::Num(*v)));
            }
            writeln!(f, "{}", obj(rec).to_string())?;
        }
        Ok(())
    }

    pub fn summary(&self) -> String {
        let mut s = String::new();
        for (k, series) in &self.series {
            s.push_str(&format!(
                "{k}: last {:.4}  mean {:.4}  min {:.4}  max {:.4}  \
                 (n={})\n",
                series.last,
                series.mean(),
                series.min,
                series.max,
                series.count
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_stats() {
        let mut s = Series::new("loss");
        for v in [3.0, 2.0, 4.0, 1.0] {
            s.push(v);
        }
        assert_eq!(s.count, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.last, 1.0);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!(s.ema > 1.0 && s.ema < 3.0);
    }

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join("dbfq_mlog_test.jsonl");
        let path = dir.to_str().unwrap();
        let mut log = MetricsLog::new("test", Some(path)).unwrap();
        log.log(1, &[("loss", 2.5), ("rate", 0.2)]).unwrap();
        log.log(2, &[("loss", 2.0), ("rate", 0.25)]).unwrap();
        drop(log);
        let text = std::fs::read_to_string(path).unwrap();
        let lines: Vec<&str> = text.trim().lines().collect();
        assert_eq!(lines.len(), 2);
        let j = Json::parse(lines[1]).unwrap();
        assert_eq!(j.req("step").as_f64(), Some(2.0));
        assert_eq!(j.req("loss").as_f64(), Some(2.0));
        assert_eq!(j.req("run").as_str(), Some("test"));
    }

    #[test]
    fn memory_only_mode() {
        let mut log = MetricsLog::new("mem", None).unwrap();
        log.log(0, &[("x", 1.0)]).unwrap();
        assert!(log.summary().contains("x: last 1.0000"));
    }
}
