//! Model configuration mirror + resource accounting (CAL-FLOPS, ACT-MEM).
//!
//! The Rust side never re-implements the transformer math (that is the
//! AOT graph's job); it reasons *about* the model: parameter counts,
//! per-step matmul FLOPs (the paper's CAL-FLOPS denominator), and the
//! activation-context memory of each training method (the ACT-MEM
//! column of Table 2 and the 38% reduction headline).

use crate::runtime::ProfileMeta;

/// Training method, matching the L2 artifact modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Bf16,
    Block,
    Jetfire,
    Fallback,
}

impl Method {
    pub fn tag(&self) -> &'static str {
        match self {
            Method::Bf16 => "bf16",
            Method::Block => "block",
            Method::Jetfire => "jetfire",
            Method::Fallback => "fallback",
        }
    }

    pub fn all() -> [Method; 4] {
        [Method::Bf16, Method::Block, Method::Jetfire, Method::Fallback]
    }
}

/// Shape summary of one transformer-layer linear site.
#[derive(Debug, Clone)]
pub struct LinearShape {
    pub name: &'static str,
    /// tokens per microstep (rows of X)
    pub m: usize,
    /// output features
    pub n: usize,
    /// input features
    pub k: usize,
}

/// GEMMs one linear site runs per microstep: forward `Y = X·W`, plus
/// the two backward GEMMs `dX = dY·Wᵀ` and `dW = Xᵀ·dY` — the 1:2
/// fwd:bwd ratio of the CAL-FLOPS accounting. The layer-step pipeline
/// (`gemm::pipeline`) runs exactly these three per site.
pub const GEMMS_PER_SITE: usize = 3;

impl LinearShape {
    /// FLOPs of one forward GEMM at this site (2·M·N·K).
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// FLOPs of one full microstep at this site:
    /// [`GEMMS_PER_SITE`] GEMMs of equal volume (dX and dW move the
    /// same M·N·K as the forward).
    pub fn microstep_flops(&self) -> f64 {
        GEMMS_PER_SITE as f64 * self.flops()
    }
}

/// Quantized linear sites per transformer layer: 4 for the plain MLP
/// (`qkv`, `attn_out`, `mlp_in`, `mlp_down`), 5 for the GLU MLP —
/// the gate and up projections of `silu(X·W_gate) ⊙ (X·W_up)` are
/// separate GEMM sites with their own fallback thresholds, because
/// the gate activation is where the paper's extreme GLU outliers
/// live (§4.1) and a shared θ would conflate two very different
/// magnitude distributions.
pub fn sites_per_layer(glu: bool) -> usize {
    if glu { 5 } else { 4 }
}

/// The linear sites of one layer (+ LM head handled separately):
/// [`sites_per_layer`] entries. With `glu` the MLP input projection
/// splits into `mlp_gate` and `mlp_up` (each `d_model → d_ff`, same
/// total parameters as the fused `2·d_ff` projection) so each half
/// carries its own Algorithm-2 threshold.
pub fn layer_linears(d_model: usize, d_ff: usize, glu: bool,
                     tokens: usize) -> Vec<LinearShape> {
    let mut v = vec![
        LinearShape { name: "qkv", m: tokens, n: 3 * d_model, k: d_model },
        LinearShape { name: "attn_out", m: tokens, n: d_model, k: d_model },
    ];
    if glu {
        v.push(LinearShape {
            name: "mlp_gate", m: tokens, n: d_ff, k: d_model,
        });
        v.push(LinearShape {
            name: "mlp_up", m: tokens, n: d_ff, k: d_model,
        });
    } else {
        v.push(LinearShape {
            name: "mlp_in", m: tokens, n: d_ff, k: d_model,
        });
    }
    v.push(LinearShape {
        name: "mlp_down", m: tokens, n: d_model, k: d_ff,
    });
    v
}

/// The LM-head linear: `(tokens × d_model) · (d_model × vocab)` — the
/// largest (and only vocab-shaped) GEMM of a training step, which is
/// what makes it the multi-shape pressure case for the shared plan
/// cache in `gemm::pipeline::ModelStep`.
pub fn lm_head_linear(d_model: usize, vocab: usize,
                      tokens: usize) -> LinearShape {
    LinearShape { name: "lm_head", m: tokens, n: vocab, k: d_model }
}

/// Every linear site of an `n_layers` transformer plus the LM head,
/// flattened layer-major (layer 0's qkv…mlp_down, …, head last) —
/// the global site order of `gemm::pipeline::ModelStep`, its
/// threshold controller, and its rate accumulator.
pub fn model_linears(n_layers: usize, d_model: usize, d_ff: usize,
                     glu: bool, vocab: usize,
                     tokens: usize) -> Vec<LinearShape> {
    let mut v =
        Vec::with_capacity(sites_per_layer(glu) * n_layers + 1);
    for _ in 0..n_layers {
        v.extend(layer_linears(d_model, d_ff, glu, tokens));
    }
    v.push(lm_head_linear(d_model, vocab, tokens));
    v
}

/// Trainable parameters of the quantized-linear sites of an
/// `n_layers` model + LM head — exactly the weights an optimizer
/// updates through `gemm::pipeline::ModelStep::set_weight` (embedding
/// and norms are not quantized sites and are excluded). The cost
/// model's `substrate_train_step_secs` prices the optimizer's
/// elementwise update over this count.
pub fn model_param_count(n_layers: usize, d_model: usize, d_ff: usize,
                         glu: bool, vocab: usize) -> usize {
    model_linears(n_layers, d_model, d_ff, glu, vocab, 1)
        .iter()
        .map(|l| l.k * l.n)
        .sum()
}

/// Matmul FLOPs for one microstep (fwd + bwd = 3 GEMMs per linear site,
/// 2*M*N*K each), the paper's CAL-FLOPS denominator ("only computation
/// time is measured"). Attention matmuls are included; softmax/norms are
/// not (they are not GEMMs).
pub fn train_step_gemm_flops(p: &ProfileMeta) -> f64 {
    let tokens = p.batch * p.seq_len;
    let mut fwd = 0.0f64;
    for l in layer_linears(p.d_model, p.d_ff, p.glu, tokens) {
        fwd += l.flops();
    }
    fwd *= p.n_layers as f64;
    // attention score + value matmuls: 2 * (T^2 * D) per batch elem
    let attn = 2.0
        * 2.0
        * p.batch as f64
        * p.seq_len as f64
        * p.seq_len as f64
        * p.d_model as f64;
    fwd += attn * p.n_layers as f64;
    // LM head
    fwd += 2.0 * tokens as f64 * p.vocab as f64 * p.d_model as f64;
    // fwd:bwd GEMM ratio is 1:2 for linears (dX and dW)
    3.0 * fwd
}

/// Activation-context bytes stored by one training method for one
/// microstep (paper Table 2 ACT-MEM, §5 memory design).
///
/// Per layer the contexts are:
///   * 4 linear X contexts (sizes K of each site x tokens)
///   * attention context (q,k,v,probs kept BF16 in all methods)
///   * 2 norm inputs + GLU (g,u) or GELU input
pub fn act_mem_bytes(p: &ProfileMeta, m: Method) -> f64 {
    let t = (p.batch * p.seq_len) as f64;
    let d = p.d_model as f64;
    let f = p.d_ff as f64;
    let heads_bytes = 2.0; // bf16 baseline element size

    // elements entering linear layers per layer: qkv(d) + attn_out(d)
    // + mlp_in(d) + mlp_down(f)
    let linear_elems = t * (3.0 * d + f);
    // non-linear contexts per layer: ln1(d) + ln2(d) + glu(g,u: 2f) or
    // gelu(f)
    let nl_elems = t * (2.0 * d + if p.glu { 2.0 * f } else { f });
    // attention tensors kept bf16 in every method: q,k,v rope'd (3d) +
    // attn weights are recomputed — count 3d + output d
    let attn_elems = t * 4.0 * d;

    let (lin_bytes_per_elem, nl_bytes_per_elem) = match m {
        // bf16 stores everything at 2 bytes
        Method::Bf16 => (2.0, 2.0),
        // block: INT8 linear contexts (+f32 scale per 128^2 block ~ eps),
        // non-linear stays bf16
        Method::Block => (1.0, 2.0),
        // jetfire: INT8 everywhere (32x32 blocks: scale overhead
        // 4/(32*32) per elem)
        Method::Jetfire => (1.0 + 4.0 / 1024.0, 1.0 + 4.0 / 1024.0),
        // ours: INT8 linear contexts, INT10 1x128 non-linear contexts
        Method::Fallback => {
            (1.0 + 4.0 / (p.block * p.block) as f64,
             10.0 / 8.0 + 4.0 / p.group as f64)
        }
    };

    let per_layer = linear_elems * lin_bytes_per_elem
        + nl_elems * nl_bytes_per_elem
        + attn_elems * heads_bytes;
    let head = t * d * lin_bytes_per_elem + t * d * nl_bytes_per_elem;
    per_layer * p.n_layers as f64 + head
}

/// Fraction of forward compute spent in linear layers (Fig 6b): GEMM
/// flops vs GEMM + non-linear elementwise work, as hidden size grows.
pub fn linear_time_fraction(d_model: usize, d_ff: usize, seq: usize,
                            glu: bool) -> f64 {
    let t = seq as f64;
    let d = d_model as f64;
    let f = d_ff as f64;
    let lin: f64 = layer_linears(d_model, d_ff, glu, seq)
        .iter()
        .map(|l| l.flops())
        .sum();
    let attn = 2.0 * 2.0 * t * t * d;
    // non-linear elementwise cost ~ c * elements (norms, silu, residual);
    // c≈8 ops/elem with bandwidth-bound execution
    let nl = 8.0 * t * (4.0 * d + if glu { 3.0 * f } else { 2.0 * f });
    lin / (lin + attn + nl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ProfileMeta;

    fn prof(d: usize, layers: usize, ff: usize) -> ProfileMeta {
        ProfileMeta {
            name: "t".into(),
            vocab: 256,
            d_model: d,
            n_layers: layers,
            n_heads: d / 64,
            d_ff: ff,
            seq_len: 256,
            glu: true,
            batch: 2,
            block: 128,
            group: 128,
            n_params: 0,
            n_sites: 4 * layers + 1,
            param_layout: vec![],
        }
    }

    #[test]
    fn linear_shape_flops_accounting() {
        let l = LinearShape { name: "qkv", m: 8, n: 6, k: 4 };
        assert_eq!(l.flops(), 2.0 * 8.0 * 6.0 * 4.0);
        assert_eq!(l.microstep_flops(), 3.0 * l.flops());
        assert_eq!(GEMMS_PER_SITE, 3);
    }

    #[test]
    fn model_linears_order_and_accounting() {
        let (layers, d, ff, vocab, toks) = (3usize, 32, 64, 256, 16);
        let sites = model_linears(layers, d, ff, false, vocab, toks);
        assert_eq!(sites.len(), 4 * layers + 1);
        for l in 0..layers {
            let names: Vec<_> =
                sites[4 * l..4 * l + 4].iter().map(|s| s.name).collect();
            assert_eq!(names,
                       ["qkv", "attn_out", "mlp_in", "mlp_down"]);
        }
        let head = sites.last().unwrap();
        assert_eq!((head.name, head.m, head.n, head.k),
                   ("lm_head", toks, vocab, d));
        // flattened flops = layers × per-layer flops + head flops
        let per_layer: f64 = layer_linears(d, ff, false, toks)
            .iter()
            .map(|l| l.microstep_flops())
            .sum();
        let total: f64 =
            sites.iter().map(|l| l.microstep_flops()).sum();
        let expect = layers as f64 * per_layer
            + lm_head_linear(d, vocab, toks).microstep_flops();
        assert!((total - expect).abs() < 1e-6);
    }

    #[test]
    fn glu_layers_split_the_mlp_input_into_gate_and_up() {
        let (d, ff, toks) = (32usize, 48, 16);
        assert_eq!(sites_per_layer(false), 4);
        assert_eq!(sites_per_layer(true), 5);
        let sites = layer_linears(d, ff, true, toks);
        assert_eq!(sites.len(), sites_per_layer(true));
        let names: Vec<_> = sites.iter().map(|s| s.name).collect();
        assert_eq!(names, ["qkv", "attn_out", "mlp_gate", "mlp_up",
                           "mlp_down"]);
        for l in &sites[2..4] {
            assert_eq!((l.m, l.n, l.k), (toks, ff, d),
                       "{} shape", l.name);
        }
        // the split conserves parameters and GEMM flops vs the fused
        // 2·d_ff projection (gate + up = one d→2ff matrix, halved)
        let plain = layer_linears(d, ff, false, toks);
        let pg: usize = sites.iter().map(|l| l.k * l.n).sum();
        let pp: usize = plain.iter().map(|l| l.k * l.n).sum();
        assert_eq!(pg, pp + d * ff,
                   "glu adds exactly one d_model x d_ff projection");
        let fg: f64 = sites.iter().map(|l| l.flops()).sum();
        let fused = 2.0 * toks as f64 * (2 * ff) as f64 * d as f64;
        let fp: f64 = plain.iter().map(|l| l.flops()).sum::<f64>()
            - 2.0 * toks as f64 * ff as f64 * d as f64
            + fused;
        assert!((fg - fp).abs() < 1e-9,
                "gate+up flops must equal the fused projection");
        // the global layout follows: 5·layers + 1 sites under glu
        let m = model_linears(2, d, ff, true, 80, toks);
        assert_eq!(m.len(), 2 * sites_per_layer(true) + 1);
        assert_eq!(m[7].name, "mlp_gate");
        assert_eq!(m.last().unwrap().name, "lm_head");
    }

    #[test]
    fn param_count_matches_site_shapes() {
        let (layers, d, ff, vocab) = (2usize, 32, 48, 80);
        // per layer: qkv d·3d + attn_out d·d + mlp_in d·ff +
        // mlp_down ff·d; head d·vocab
        let per_layer = d * 3 * d + d * d + d * ff + ff * d;
        assert_eq!(model_param_count(layers, d, ff, false, vocab),
                   layers * per_layer + d * vocab);
        // glu doubles the mlp_in output dim
        assert_eq!(model_param_count(1, d, ff, true, vocab),
                   d * 3 * d + d * d + d * 2 * ff + ff * d + d * vocab);
        // independent of tokens by construction (m never enters)
        let a = model_linears(2, d, ff, false, vocab, 1);
        let b = model_linears(2, d, ff, false, vocab, 999);
        let pa: usize = a.iter().map(|l| l.k * l.n).sum();
        let pb: usize = b.iter().map(|l| l.k * l.n).sum();
        assert_eq!(pa, pb);
    }

    #[test]
    fn flops_scale_quadratically_in_d() {
        let f1 = train_step_gemm_flops(&prof(512, 8, 2048));
        let f2 = train_step_gemm_flops(&prof(1024, 8, 4096));
        let ratio = f2 / f1;
        assert!(ratio > 3.5 && ratio < 4.5, "ratio {ratio}");
    }

    #[test]
    fn act_mem_ordering_matches_paper() {
        // Table 2: Jetfire < Ours < Block < BF16
        let p = prof(2048, 20, 8192);
        let bf16 = act_mem_bytes(&p, Method::Bf16);
        let block = act_mem_bytes(&p, Method::Block);
        let ours = act_mem_bytes(&p, Method::Fallback);
        let jet = act_mem_bytes(&p, Method::Jetfire);
        assert!(jet < ours && ours < block && block < bf16);
        // paper: ours ≈ 61% of bf16
        let frac = ours / bf16;
        assert!(frac > 0.5 && frac < 0.75, "ours/bf16 = {frac}");
    }

    #[test]
    fn linear_fraction_grows_with_model_size() {
        let small = linear_time_fraction(512, 2048, 1024, true);
        let large = linear_time_fraction(8192, 28672, 1024, true);
        assert!(large > small);
        assert!(small > 0.3 && large > 0.8);
    }
}
