//! Synthetic data pipeline: corpus generation, byte tokenizer, batching,
//! and finetune task generators.
//!
//! Substitutes the paper's OpenWebText / GSM8K / DROP workloads (see
//! DESIGN.md §Substitutions): a Zipfian n-gram byte language gives the
//! pretraining corpus a learnable structure with a non-trivial loss
//! floor; the finetune tasks are sequence-to-sequence templates
//! (arithmetic chains, span extraction) exercising the same quantized
//! fwd/bwd code paths as the paper's benchmarks.

use crate::util::rng::Pcg64;

/// Token stream + sampler for fixed-length training windows.
#[derive(Clone)]
pub struct Corpus {
    pub tokens: Vec<u8>,
    pub vocab: usize,
}

impl Corpus {
    /// Zipfian order-2 Markov byte corpus. Word-like segments drawn from
    /// a power-law vocabulary with spaces — enough structure that a
    /// small LM's loss falls well below ln(vocab) but stays above zero.
    pub fn synthetic(n_tokens: usize, vocab: usize, seed: u64) -> Corpus {
        assert!(vocab >= 16);
        let mut rng = Pcg64::new(seed);
        // Build a random "lexicon" of words over bytes [1, vocab).
        let n_words = 512;
        let words: Vec<Vec<u8>> = (0..n_words)
            .map(|_| {
                let len = 2 + rng.below(8);
                (0..len)
                    .map(|_| 1 + rng.below(vocab - 1) as u8)
                    .collect()
            })
            .collect();
        // Zipf weights ~ 1/rank.
        let mut tokens = Vec::with_capacity(n_tokens + 16);
        let harmonic: f64 = (1..=n_words).map(|r| 1.0 / r as f64).sum();
        while tokens.len() < n_tokens {
            let mut u = rng.uniform() * harmonic;
            let mut idx = 0;
            for r in 1..=n_words {
                u -= 1.0 / r as f64;
                if u <= 0.0 {
                    idx = r - 1;
                    break;
                }
            }
            tokens.extend_from_slice(&words[idx]);
            tokens.push(0); // separator byte
        }
        tokens.truncate(n_tokens);
        Corpus { tokens, vocab }
    }

    /// Sample a (batch, seq+1) window batch as i32 (AOT input format).
    ///
    /// Every window of `seq + 1` tokens is reachable: valid starts
    /// are `0 ..= len - (seq + 1)`, i.e. `below(len - seq)` — an
    /// earlier off-by-one (`below(len - seq - 1)`) could never serve
    /// the final window and panicked on a corpus of exactly one
    /// window (`sample_batch_covers_last_window` pins both).
    pub fn sample_batch(&self, batch: usize, seq: usize,
                        rng: &mut Pcg64) -> Vec<i32> {
        assert!(self.tokens.len() > seq,
                "corpus shorter than one window");
        let mut out = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            let start = rng.below(self.tokens.len() - seq);
            out.extend(
                self.tokens[start..start + seq + 1]
                    .iter()
                    .map(|&b| b as i32),
            );
        }
        out
    }

    /// Deterministic evaluation windows (non-overlapping).
    pub fn eval_batches(&self, batch: usize, seq: usize,
                        n_batches: usize) -> Vec<Vec<i32>> {
        let mut out = Vec::new();
        let mut pos = 0;
        for _ in 0..n_batches {
            let mut b = Vec::with_capacity(batch * (seq + 1));
            for _ in 0..batch {
                // `>` not `>=`: a window ending exactly at len is
                // still in bounds (wrapping it early silently dropped
                // the corpus tail from evaluation).
                if pos + seq + 1 > self.tokens.len() {
                    pos = 0;
                }
                b.extend(
                    self.tokens[pos..pos + seq + 1]
                        .iter()
                        .map(|&t| t as i32),
                );
                pos += seq + 1;
            }
            out.push(b);
        }
        out
    }
}

/// Synthetic finetune tasks (Table 2 / Fig 8 substitutions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// "GSM8K-like": multi-step arithmetic chains, answer after '='.
    Arithmetic,
    /// "DROP-like": copy the span between markers.
    SpanCopy,
    /// "MMLU-like": 4-way classification by parity/majority rules.
    Choice,
    /// "HellaSwag-like": pick the continuation matching the pattern.
    Continuation,
}

impl Task {
    pub fn all() -> [Task; 4] {
        [Task::Arithmetic, Task::SpanCopy, Task::Choice,
         Task::Continuation]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::Arithmetic => "arith(GSM8K-like)",
            Task::SpanCopy => "span(DROP-like)",
            Task::Choice => "choice(MMLU-like)",
            Task::Continuation => "cont(HELLASWAG-like)",
        }
    }

    /// Generate one example as a token sequence of exactly `seq+1`
    /// tokens (padded with 0). Returns (tokens, answer_span) where the
    /// answer occupies `answer_span` positions at the end before padding
    /// — accuracy is measured by exact-match greedy decoding over that
    /// span (the paper reports Acc/F1; exact-match is our analogue).
    pub fn example(&self, seq: usize, vocab: usize, rng: &mut Pcg64)
                   -> (Vec<i32>, std::ops::Range<usize>) {
        let v = vocab as i32;
        // Reserved bytes: 0 pad, 1 '=', 2 '[', 3 ']', 4 sep.
        let digit = |rng: &mut Pcg64| 5 + rng.below(10) as i32;
        let mut t: Vec<i32> = Vec::new();
        let ans: Vec<i32> = match self {
            Task::Arithmetic => {
                // a + b + c mod 10 chains: "d d d = r"
                let n = 3 + rng.below(3);
                let mut sum = 0i32;
                for _ in 0..n {
                    let d = digit(rng);
                    sum = (sum + (d - 5)) % 10;
                    t.push(d);
                }
                t.push(1);
                vec![5 + sum]
            }
            Task::SpanCopy => {
                let pre = 3 + rng.below(6);
                let span = 2 + rng.below(4);
                for _ in 0..pre {
                    t.push(digit(rng));
                }
                t.push(2);
                let s: Vec<i32> = (0..span).map(|_| digit(rng)).collect();
                t.extend(&s);
                t.push(3);
                for _ in 0..rng.below(4) {
                    t.push(digit(rng));
                }
                t.push(1);
                s
            }
            Task::Choice => {
                let n = 5;
                let mut ones = 0;
                for _ in 0..n {
                    let b = rng.below(2) as i32;
                    ones += b;
                    t.push(5 + b);
                }
                t.push(1);
                vec![if ones > (n as i32) / 2 { 5 + 1 } else { 5 }]
            }
            Task::Continuation => {
                // repeat a short motif twice, answer = its next element
                let len = 3 + rng.below(3);
                let motif: Vec<i32> = (0..len).map(|_| digit(rng)).collect();
                t.extend(&motif);
                t.extend(&motif);
                t.push(1);
                vec![motif[0]]
            }
        };
        t.extend(&ans);
        let ans_end = t.len();
        let ans_start = ans_end - ans.len();
        assert!(t.len() <= seq + 1, "example longer than window");
        t.resize(seq + 1, 0);
        for x in &mut t {
            *x = (*x).min(v - 1);
        }
        (t, ans_start..ans_end)
    }

    /// A batch of examples: (flat tokens (batch x (seq+1)), spans).
    pub fn batch(&self, batch: usize, seq: usize, vocab: usize,
                 rng: &mut Pcg64)
                 -> (Vec<i32>, Vec<std::ops::Range<usize>>) {
        let mut flat = Vec::with_capacity(batch * (seq + 1));
        let mut spans = Vec::with_capacity(batch);
        for _ in 0..batch {
            let (t, s) = self.example(seq, vocab, rng);
            flat.extend(t);
            spans.push(s);
        }
        (flat, spans)
    }
}

/// Exact-match accuracy of greedy predictions over answer spans.
///
/// `per_token_loss` is unused for accuracy but mean answer-span loss is
/// returned as a convergence proxy alongside.
pub fn answer_span_loss(per_token_loss: &[f32], batch: usize, seq: usize,
                        spans: &[std::ops::Range<usize>]) -> f64 {
    // per_token_loss is (batch, seq): loss of predicting token t+1 at t.
    let mut tot = 0.0f64;
    let mut cnt = 0usize;
    for (b, span) in spans.iter().enumerate() {
        for pos in span.clone() {
            if pos == 0 {
                continue;
            }
            let idx = b * seq + (pos - 1); // predicting `pos` from pos-1
            if idx < per_token_loss.len() && (pos - 1) < seq {
                tot += per_token_loss[idx] as f64;
                cnt += 1;
            }
        }
    }
    let _ = batch;
    if cnt == 0 {
        0.0
    } else {
        tot / cnt as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_has_structure() {
        let c = Corpus::synthetic(50_000, 64, 1);
        assert_eq!(c.tokens.len(), 50_000);
        assert!(c.tokens.iter().all(|&t| (t as usize) < 64));
        // separator must be frequent (word structure)
        let zeros = c.tokens.iter().filter(|&&t| t == 0).count();
        assert!(zeros > 1_000, "zeros {zeros}");
        // Zipf: most common non-zero byte much more frequent than median
        let mut counts = vec![0usize; 64];
        for &t in &c.tokens {
            counts[t as usize] += 1;
        }
        let mut nz: Vec<usize> =
            counts[1..].iter().copied().filter(|&c| c > 0).collect();
        nz.sort_unstable();
        assert!(nz[nz.len() - 1] > 4 * nz[nz.len() / 2]);
    }

    #[test]
    fn corpus_deterministic_per_seed() {
        let a = Corpus::synthetic(1000, 64, 7).tokens;
        let b = Corpus::synthetic(1000, 64, 7).tokens;
        let c = Corpus::synthetic(1000, 64, 8).tokens;
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn batch_shapes() {
        let c = Corpus::synthetic(10_000, 64, 2);
        let mut rng = Pcg64::new(3);
        let b = c.sample_batch(4, 32, &mut rng);
        assert_eq!(b.len(), 4 * 33);
        assert!(b.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn eval_batches_deterministic() {
        let c = Corpus::synthetic(10_000, 64, 2);
        assert_eq!(c.eval_batches(2, 32, 3), c.eval_batches(2, 32, 3));
        assert_eq!(c.eval_batches(2, 32, 3).len(), 3);
    }

    #[test]
    fn tasks_produce_valid_examples() {
        let mut rng = Pcg64::new(5);
        for task in Task::all() {
            for _ in 0..50 {
                let (t, span) = task.example(32, 64, &mut rng);
                assert_eq!(t.len(), 33);
                assert!(span.end <= 33);
                assert!(span.start < span.end);
                assert!(t.iter().all(|&x| (0..64).contains(&x)),
                        "{task:?}");
            }
        }
    }

    #[test]
    fn arithmetic_answers_consistent() {
        // same RNG state -> same example; answer = sum of digits mod 10
        let mut rng = Pcg64::new(9);
        let (t, span) = Task::Arithmetic.example(32, 64, &mut rng);
        let eq_pos = t.iter().position(|&x| x == 1).unwrap();
        let sum: i32 = t[..eq_pos].iter().map(|&d| d - 5).sum();
        assert_eq!(t[span.start], 5 + sum.rem_euclid(10));
    }

    #[test]
    fn span_loss_indexing() {
        let batch = 2;
        let seq = 8;
        let losses = vec![1.0f32; batch * seq];
        let spans = vec![3..5, 2..4];
        let l = answer_span_loss(&losses, batch, seq, &spans);
        assert!((l - 1.0).abs() < 1e-9);
    }

    #[test]
    fn span_loss_boundary_spans() {
        let batch = 2;
        let seq = 8;
        // distinct values so we can tell *which* positions counted
        let losses: Vec<f32> =
            (0..batch * seq).map(|i| i as f32).collect();
        // Empty spans contribute nothing — and an all-empty batch is
        // 0.0, not NaN from a 0/0.
        assert_eq!(answer_span_loss(&losses, batch, seq, &[0..0, 5..5]),
                   0.0);
        // A span at the far edge: answer token at position `seq`
        // (the last token of the seq+1 window) is predicted from
        // position seq-1 — the final per-token-loss slot of that row.
        let l = answer_span_loss(&losses, batch, seq, &[seq..seq + 1,
                                                        0..0]);
        assert_eq!(l, (seq - 1) as f64);
        // Position 0 can never be predicted (no preceding token):
        // a span starting at 0 only counts its tail.
        let l0 = answer_span_loss(&losses, batch, seq, &[0..2, 0..0]);
        assert_eq!(l0, 0.0); // predicting pos 1 from pos 0 → slot 0
        // Out-of-window positions (> seq) are skipped, not indexed.
        let lo = answer_span_loss(&losses, batch, seq,
                                  &[seq + 1..seq + 3, 0..0]);
        assert_eq!(lo, 0.0);
    }

    #[test]
    fn sample_batch_covers_last_window() {
        // A corpus of exactly one window has exactly one valid start;
        // the pre-fix bound `below(len - seq - 1)` hit below(0) here.
        let seq = 8;
        let c = Corpus {
            tokens: (0..=seq as u8).collect(),
            vocab: 64,
        };
        let mut rng = Pcg64::new(11);
        let b = c.sample_batch(3, seq, &mut rng);
        let want: Vec<i32> = (0..=seq as i32).collect();
        assert_eq!(b, [want.clone(), want.clone(), want].concat());
        // And on a real corpus the final window is reachable.
        let c = Corpus::synthetic(1_000, 64, 3);
        let last_start = c.tokens.len() - (seq + 1);
        let mut hit_last = false;
        let mut rng = Pcg64::new(1);
        for _ in 0..4_000 {
            let start = rng.below(c.tokens.len() - seq);
            hit_last |= start == last_start;
        }
        assert!(hit_last, "final window unreachable");
    }

    #[test]
    fn eval_batches_cover_exact_tail() {
        // 3 windows of seq+1 = 9 tokens over a 27-token corpus tile
        // exactly; the pre-fix `>=` wrapped before the third window,
        // evaluating the head twice and the tail never.
        let seq = 8;
        let c = Corpus {
            tokens: (0..27u8).collect(),
            vocab: 64,
        };
        let batches = c.eval_batches(1, seq, 3);
        assert_eq!(batches[2],
                   (18..27).map(|t| t as i32).collect::<Vec<i32>>());
    }

    #[test]
    fn sample_batch_deterministic_per_seed() {
        let c = Corpus::synthetic(10_000, 64, 2);
        let mut r1 = Pcg64::new(42);
        let mut r2 = Pcg64::new(42);
        let mut r3 = Pcg64::new(43);
        let a = c.sample_batch(4, 32, &mut r1);
        let b = c.sample_batch(4, 32, &mut r2);
        let d = c.sample_batch(4, 32, &mut r3);
        assert_eq!(a, b);
        assert_ne!(a, d);
        // the stream continues, not repeats
        assert_ne!(a, c.sample_batch(4, 32, &mut r1));
    }

    #[test]
    fn task_batch_deterministic_per_seed() {
        for task in Task::all() {
            let mut r1 = Pcg64::new(77);
            let mut r2 = Pcg64::new(77);
            let mut r3 = Pcg64::new(78);
            let (t1, s1) = task.batch(4, 32, 64, &mut r1);
            let (t2, s2) = task.batch(4, 32, 64, &mut r2);
            let (t3, _) = task.batch(4, 32, 64, &mut r3);
            assert_eq!((t1.clone(), s1), (t2, s2), "{task:?}");
            assert_ne!(t1, t3, "{task:?}");
        }
    }
}
