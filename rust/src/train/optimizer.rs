//! Optimizers updating [`ModelStep`] weights through the
//! `set_weight` → `invalidate_weight` cadence.
//!
//! Both rules run **sequential f32 elementwise** math — no threading,
//! no reduction-order freedom — so a training step is bit-identical
//! across kernel backends, thread counts, and shard configs by
//! construction (the GEMM engine already guarantees it for the
//! gradients coming in). State serializes losslessly: every f32
//! roundtrips exactly through the JSON `f64` numbers, which is what
//! makes a restored run continue bit-for-bit
//! (`tests/train_prop.rs::checkpoint_restore_resumes_bit_identical`).
//!
//! [`ModelStep`]: crate::gemm::ModelStep

use crate::util::json::{arr_f64, obj, Json};
use crate::util::Mat;

/// One weight-update rule over the per-site weight matrices of a
/// model. Implementations lazily size their per-site state on first
/// update (sites have different shapes) and must be deterministic and
/// sequential — see the module docs.
pub trait Optimizer {
    /// Serialization tag (`kind` field) and display name.
    fn name(&self) -> &'static str;

    /// Called once at the start of each optimizer step, before the
    /// per-site updates — Adam's bias-correction clock. Default:
    /// no-op.
    fn begin_step(&mut self) {}

    /// Apply one update for site `site`: `w` is the (k × n) master
    /// weight, `dw` the same-shaped gradient, `lr` this step's
    /// learning rate.
    fn update(&mut self, site: usize, w: &mut Mat, dw: &Mat, lr: f32);

    /// f32 ops per parameter per update — the cost model's price tag
    /// (`SubstrateCalibration::substrate_train_step_secs`).
    fn flops_per_param(&self) -> f64;

    /// Full state (kind tag + hyperparameters + per-site buffers),
    /// losslessly restorable via [`optimizer_from_json`].
    fn to_json(&self) -> Json;
}

fn state_to_json(state: &[Vec<f32>]) -> Json {
    Json::Arr(
        state
            .iter()
            .map(|s| {
                let v: Vec<f64> =
                    s.iter().map(|&x| x as f64).collect();
                arr_f64(&v)
            })
            .collect(),
    )
}

fn state_from_json(j: &Json, n_sites: usize, what: &str)
                   -> Result<Vec<Vec<f32>>, String> {
    let arr = j
        .as_arr()
        .ok_or_else(|| format!("optimizer: malformed '{what}'"))?;
    if arr.len() != n_sites {
        return Err(format!(
            "optimizer: '{what}' has {} sites, model has {n_sites}",
            arr.len()
        ));
    }
    arr.iter()
        .map(|s| {
            s.to_f64_vec()
                .map(|v| v.iter().map(|&x| x as f32).collect())
                .ok_or_else(|| {
                    format!("optimizer: malformed '{what}' entry")
                })
        })
        .collect()
}

/// SGD with classical momentum: `v ← μ·v + g`, `w ← w − lr·v`.
pub struct SgdMomentum {
    pub momentum: f32,
    /// per-site velocity, sized lazily on first update
    vel: Vec<Vec<f32>>,
}

impl SgdMomentum {
    pub fn new(n_sites: usize, momentum: f32) -> SgdMomentum {
        SgdMomentum { momentum, vel: vec![Vec::new(); n_sites] }
    }
}

impl Optimizer for SgdMomentum {
    fn name(&self) -> &'static str {
        "sgd_momentum"
    }

    fn update(&mut self, site: usize, w: &mut Mat, dw: &Mat,
              lr: f32) {
        assert_eq!((w.rows, w.cols), (dw.rows, dw.cols),
                   "gradient shape for site {site}");
        let mu = self.momentum;
        let v = &mut self.vel[site];
        if v.is_empty() {
            v.resize(w.data.len(), 0.0);
        }
        assert_eq!(v.len(), w.data.len(),
                   "velocity shape for site {site}");
        for ((wi, vi), &g) in
            w.data.iter_mut().zip(v.iter_mut()).zip(&dw.data)
        {
            *vi = mu * *vi + g;
            *wi -= lr * *vi;
        }
    }

    fn flops_per_param(&self) -> f64 {
        4.0
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("kind", Json::Str(self.name().into())),
            ("momentum", Json::Num(self.momentum as f64)),
            ("vel", state_to_json(&self.vel)),
        ])
    }
}

/// Adam (Kingma & Ba) with bias correction. The timestep advances in
/// [`begin_step`](Optimizer::begin_step) — once per optimizer step,
/// not once per site — so every site of a step shares one
/// bias-correction factor.
pub struct Adam {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    /// Standard hyperparameters: β₁ = 0.9, β₂ = 0.999, ε = 1e-8.
    pub fn new(n_sites: usize) -> Adam {
        Adam::with_hyper(n_sites, 0.9, 0.999, 1e-8)
    }

    pub fn with_hyper(n_sites: usize, beta1: f32, beta2: f32,
                      eps: f32) -> Adam {
        Adam {
            beta1,
            beta2,
            eps,
            t: 0,
            m: vec![Vec::new(); n_sites],
            v: vec![Vec::new(); n_sites],
        }
    }

    /// Optimizer steps taken (the bias-correction clock).
    pub fn timestep(&self) -> u64 {
        self.t
    }
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }

    fn begin_step(&mut self) {
        self.t += 1;
    }

    fn update(&mut self, site: usize, w: &mut Mat, dw: &Mat,
              lr: f32) {
        assert!(self.t > 0, "Adam::update before begin_step");
        assert_eq!((w.rows, w.cols), (dw.rows, dw.cols),
                   "gradient shape for site {site}");
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let m = &mut self.m[site];
        let v = &mut self.v[site];
        if m.is_empty() {
            m.resize(w.data.len(), 0.0);
            v.resize(w.data.len(), 0.0);
        }
        assert_eq!(m.len(), w.data.len(),
                   "moment shape for site {site}");
        for (((wi, mi), vi), &g) in w
            .data
            .iter_mut()
            .zip(m.iter_mut())
            .zip(v.iter_mut())
            .zip(&dw.data)
        {
            *mi = b1 * *mi + (1.0 - b1) * g;
            *vi = b2 * *vi + (1.0 - b2) * g * g;
            let mhat = *mi / bc1;
            let vhat = *vi / bc2;
            *wi -= lr * mhat / (vhat.sqrt() + eps);
        }
    }

    fn flops_per_param(&self) -> f64 {
        12.0
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("kind", Json::Str(self.name().into())),
            ("beta1", Json::Num(self.beta1 as f64)),
            ("beta2", Json::Num(self.beta2 as f64)),
            ("eps", Json::Num(self.eps as f64)),
            ("t", Json::Num(self.t as f64)),
            ("m", state_to_json(&self.m)),
            ("v", state_to_json(&self.v)),
        ])
    }
}

/// Rebuild an optimizer from its [`Optimizer::to_json`] state. The
/// per-site buffer count must match `n_sites`; every scalar restores
/// bit-exactly (f32 → JSON f64 → f32 is lossless).
pub fn optimizer_from_json(j: &Json, n_sites: usize)
                           -> Result<Box<dyn Optimizer>, String> {
    let num = |k: &str| {
        j.get(k)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("optimizer: missing '{k}'"))
    };
    match j.get("kind").and_then(|v| v.as_str()) {
        Some("sgd_momentum") => {
            let momentum = num("momentum")? as f32;
            let vel = state_from_json(
                j.get("vel").ok_or("optimizer: missing 'vel'")?,
                n_sites, "vel")?;
            Ok(Box::new(SgdMomentum { momentum, vel }))
        }
        Some("adam") => {
            let (beta1, beta2, eps) = (num("beta1")? as f32,
                                       num("beta2")? as f32,
                                       num("eps")? as f32);
            let t = num("t")? as u64;
            let m = state_from_json(
                j.get("m").ok_or("optimizer: missing 'm'")?,
                n_sites, "m")?;
            let v = state_from_json(
                j.get("v").ok_or("optimizer: missing 'v'")?,
                n_sites, "v")?;
            Ok(Box::new(Adam { beta1, beta2, eps, t, m, v }))
        }
        Some(k) => Err(format!("optimizer: unknown kind '{k}'")),
        None => Err("optimizer: missing 'kind'".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, vals: &[f32]) -> Mat {
        Mat::from_vec(rows, cols, vals.to_vec())
    }

    #[test]
    fn sgd_momentum_matches_hand_computation() {
        let mut opt = SgdMomentum::new(1, 0.5);
        let mut w = mat(1, 2, &[1.0, -1.0]);
        let g = mat(1, 2, &[0.5, 0.25]);
        opt.begin_step();
        opt.update(0, &mut w, &g, 0.1);
        // v = g, w -= 0.1*v
        assert_eq!(w.data, vec![1.0 - 0.05, -1.0 - 0.025]);
        opt.begin_step();
        opt.update(0, &mut w, &g, 0.1);
        // v = 0.5*g + g = 1.5g
        assert_eq!(w.data[0], 1.0 - 0.05 - 0.1 * 0.75);
    }

    #[test]
    fn adam_first_step_is_signed_lr() {
        // With bias correction, step 1 moves each weight by
        // ~lr·sign(g) regardless of gradient magnitude.
        let mut opt = Adam::new(1);
        let mut w = mat(1, 3, &[0.0, 0.0, 0.0]);
        let g = mat(1, 3, &[0.3, -7.0, 1e-3]);
        opt.begin_step();
        opt.update(0, &mut w, &g, 0.01);
        assert_eq!(opt.timestep(), 1);
        for (wi, gi) in w.data.iter().zip(&g.data) {
            let step = wi / -gi.signum();
            assert!((step - 0.01).abs() < 1e-3,
                    "step {step} for g {gi}");
        }
    }

    #[test]
    #[should_panic(expected = "before begin_step")]
    fn adam_update_requires_begin_step() {
        let mut opt = Adam::new(1);
        let mut w = mat(1, 1, &[0.0]);
        let g = mat(1, 1, &[1.0]);
        opt.update(0, &mut w, &g, 0.01);
    }

    /// Serialize mid-run, restore, and require the restored optimizer
    /// to produce bit-identical weight trajectories from there on —
    /// the property checkpointing leans on.
    #[test]
    fn json_roundtrip_continues_bit_identical() {
        let makes: [fn() -> Box<dyn Optimizer>; 2] = [
            || Box::new(Adam::new(2)),
            || Box::new(SgdMomentum::new(2, 0.9)),
        ];
        for make in makes {
            let mut a = make();
            let mut w1 = mat(2, 2, &[0.1, -0.2, 0.3, -0.4]);
            let mut w2 = w1.clone();
            let g = mat(2, 2, &[0.01, 0.02, -0.03, 0.04]);
            for _ in 0..3 {
                a.begin_step();
                a.update(0, &mut w1, &g, 0.05);
                a.update(1, &mut w2, &g, 0.05);
            }
            let state = a.to_json();
            let text = state.to_string();
            let parsed =
                crate::util::json::Json::parse(&text).unwrap();
            let mut b = optimizer_from_json(&parsed, 2).unwrap();
            assert_eq!(b.name(), a.name());
            let (mut wa1, mut wb1) = (w1.clone(), w1.clone());
            let (mut wa2, mut wb2) = (w2.clone(), w2.clone());
            for _ in 0..3 {
                a.begin_step();
                b.begin_step();
                a.update(0, &mut wa1, &g, 0.05);
                b.update(0, &mut wb1, &g, 0.05);
                a.update(1, &mut wa2, &g, 0.05);
                b.update(1, &mut wb2, &g, 0.05);
            }
            assert_eq!(wa1.data, wb1.data);
            assert_eq!(wa2.data, wb2.data);
        }
    }

    #[test]
    fn from_json_rejects_malformed_state() {
        use crate::util::json::{obj, Json};
        // unknown kind
        let j = obj(vec![("kind", Json::Str("lion".into()))]);
        assert!(optimizer_from_json(&j, 1)
            .unwrap_err()
            .contains("unknown kind"));
        // missing kind
        assert!(optimizer_from_json(&Json::Null, 1)
            .unwrap_err()
            .contains("kind"));
        // site-count mismatch
        let mut opt = Adam::new(3);
        let mut w = mat(1, 1, &[0.0]);
        opt.begin_step();
        opt.update(0, &mut w, &mat(1, 1, &[1.0]), 0.1);
        let err =
            optimizer_from_json(&opt.to_json(), 5).unwrap_err();
        assert!(err.contains("sites"), "{err}");
    }
}
