//! Streaming tokenized batch loader over the [`data`] corpus/task
//! generators, with O(1) deterministic seeking.
//!
//! Every batch is a pure function of `(seed, index)`: batch `i` is
//! drawn from a fresh [`Pcg64`] whose seed mixes the loader seed with
//! the batch index through a [`SplitMix64`] round. Consequences:
//!
//! * reading batches in any order gives the same content per index,
//! * [`Loader::seek`] is O(1) — no replaying of skipped batches,
//! * a checkpoint only needs `(seed, cursor)` to resume the stream
//!   bit-exactly.
//!
//! [`data`]: crate::data

use std::ops::Range;

use crate::data::{Corpus, Task};
use crate::util::rng::{Pcg64, SplitMix64};

/// Where batches come from.
pub enum BatchSource {
    /// Language-model pretraining windows from a [`Corpus`].
    Pretrain(Corpus),
    /// Supervised finetune examples from a synthetic [`Task`];
    /// `vocab` caps the emitted token ids.
    Finetune { task: Task, vocab: usize },
}

/// One `(batch, seq + 1)` window batch: `tokens[b*(seq+1) + t]`,
/// inputs `..seq`, next-token targets `1..`. Finetune batches carry
/// the per-example answer spans for
/// [`answer_span_loss`](crate::data::answer_span_loss).
pub struct TokenBatch {
    pub tokens: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
    pub spans: Option<Vec<Range<usize>>>,
}

/// Deterministic batch stream; see the module docs for the seeking
/// contract.
pub struct Loader {
    source: BatchSource,
    batch: usize,
    seq: usize,
    seed: u64,
    cursor: u64,
}

impl Loader {
    pub fn pretrain(corpus: Corpus, batch: usize, seq: usize,
                    seed: u64) -> Loader {
        assert!(corpus.tokens.len() > seq,
                "corpus shorter than one window");
        Loader {
            source: BatchSource::Pretrain(corpus),
            batch,
            seq,
            seed,
            cursor: 0,
        }
    }

    pub fn finetune(task: Task, vocab: usize, batch: usize,
                    seq: usize, seed: u64) -> Loader {
        Loader {
            source: BatchSource::Finetune { task, vocab },
            batch,
            seq,
            seed,
            cursor: 0,
        }
    }

    /// The batch at stream position `index`, independent of the
    /// cursor and of any other `batch_at` calls.
    pub fn batch_at(&self, index: u64) -> TokenBatch {
        let mix = index.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng =
            Pcg64::new(SplitMix64(self.seed ^ mix).next());
        match &self.source {
            BatchSource::Pretrain(corpus) => TokenBatch {
                tokens: corpus
                    .sample_batch(self.batch, self.seq, &mut rng),
                batch: self.batch,
                seq: self.seq,
                spans: None,
            },
            BatchSource::Finetune { task, vocab } => {
                let (tokens, spans) = task.batch(
                    self.batch, self.seq, *vocab, &mut rng);
                TokenBatch {
                    tokens,
                    batch: self.batch,
                    seq: self.seq,
                    spans: Some(spans),
                }
            }
        }
    }

    /// The batch at the cursor; advances the cursor.
    pub fn next_batch(&mut self) -> TokenBatch {
        let b = self.batch_at(self.cursor);
        self.cursor += 1;
        b
    }

    /// Jump the stream to position `index` (O(1)).
    pub fn seek(&mut self, index: u64) {
        self.cursor = index;
    }

    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    /// Token-id space of emitted batches.
    pub fn vocab(&self) -> usize {
        match &self.source {
            BatchSource::Pretrain(c) => c.vocab,
            BatchSource::Finetune { vocab, .. } => *vocab,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_loader(seed: u64) -> Loader {
        let corpus = Corpus::synthetic(512, 64, 7);
        Loader::pretrain(corpus, 3, 8, seed)
    }

    #[test]
    fn batches_are_a_pure_function_of_seed_and_index() {
        let a = small_loader(42);
        let b = small_loader(42);
        // Read out of order on `b`; indices must still agree.
        for i in [3u64, 0, 2, 1] {
            assert_eq!(a.batch_at(i).tokens, b.batch_at(i).tokens,
                       "index {i}");
        }
        let c = small_loader(43);
        assert_ne!(a.batch_at(0).tokens, c.batch_at(0).tokens,
                   "different seeds should differ");
        // Consecutive indices must differ (SplitMix64 decorrelates
        // the raw xor pattern).
        assert_ne!(a.batch_at(0).tokens, a.batch_at(1).tokens);
    }

    #[test]
    fn seek_matches_sequential_reads() {
        let mut a = small_loader(9);
        let mut b = small_loader(9);
        let mut seq = Vec::new();
        for _ in 0..5 {
            seq.push(a.next_batch().tokens);
        }
        assert_eq!(a.cursor(), 5);
        b.seek(3);
        assert_eq!(b.next_batch().tokens, seq[3]);
        assert_eq!(b.next_batch().tokens, seq[4]);
        b.seek(0);
        assert_eq!(b.next_batch().tokens, seq[0]);
    }

    #[test]
    fn finetune_batches_carry_spans() {
        let mut l = Loader::finetune(Task::Arithmetic, 64, 2, 24, 5);
        let tb = l.next_batch();
        assert_eq!(tb.tokens.len(), 2 * 25);
        let spans = tb.spans.expect("finetune spans");
        assert_eq!(spans.len(), 2);
        for s in &spans {
            assert!(s.end <= 25, "span {s:?} within window");
        }
        assert!(tb.tokens.iter().all(|&t| (0..64).contains(&t)));
        // Deterministic per (seed, index) here too.
        let l2 = Loader::finetune(Task::Arithmetic, 64, 2, 24, 5);
        assert_eq!(l2.batch_at(0).tokens, l.batch_at(0).tokens);
        assert_eq!(l2.batch_at(0).spans, l.batch_at(0).spans);
    }
}
