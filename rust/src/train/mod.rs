//! End-to-end training loop over the quantized GEMM substrate:
//! optimizer + data loader + loss, driving [`ModelStep`] through its
//! split-microstep API.
//!
//! The model is a deliberately small surrogate transformer whose
//! *every* matmul runs through the quantized engine while everything
//! else stays exactly reproducible elementwise f32:
//!
//! * fixed (untrained) token embedding, regenerated from
//!   `init_seed` on restore rather than checkpointed,
//! * identity attention — the "attention output" is the V third of
//!   the qkv projection, so the site shapes and data flow match a
//!   real block without a softmax in the training path,
//! * ReLU MLP by default, or a SwiGLU MLP under `glu = true` —
//!   gate ⊙ up through the separate `mlp_gate` / `mlp_up` sites,
//!   each with its own fallback threshold — and plain residual
//!   adds,
//! * masked stable softmax cross-entropy at the LM head; finetune
//!   batches mask the loss to their answer spans
//!   ([`answer_span_loss`] convention: the loss of predicting the
//!   token at `pos` lives at `pos - 1`).
//!
//! Backward seeds the chain with `dLogits` at the `lm_head` site and
//! walks the layers in reverse, merging residual gradients — each
//! site's three GEMMs (Y, dX, dW) are the engine's, so a training
//! step is bit-identical across kernel backends, thread counts, and
//! shard configs, and the `Int8` data path is bit-identical to its
//! `SimF32` simulation (`tests/train_prop.rs` pins all of it).
//!
//! [`Engine::Exact`] swaps every site GEMM for the dense f32 engine
//! ([`crate::gemm::matmul`], also thread-invariant) — the reference
//! run the convergence-gap assertions and the evaluation path use.
//!
//! ## Checkpoints
//!
//! [`TrainLoop::checkpoint`] is format [`TRAIN_STATE_VERSION`] = 3
//! (kind [`TRAIN_STATE_KIND`]): master weights (f32-lossless f64
//! arrays), optimizer state, loader `(seed, cursor)`, the run's
//! precision-format record (`format`, the [`DataPath`] tag), and the
//! embedded [`ModelStep::warm_state`]. Version 1 was the bare
//! optimizer-less warm state of the pre-train-loop era; version 2
//! predates the precision lattice and carries no format record —
//! both are pre-lattice snapshots, so restore rejects anything but
//! an exact kind + version + format match with a loud error. A
//! resumed run continues bit-identically to the uninterrupted one.
//!
//! [`answer_span_loss`]: crate::data::answer_span_loss
//! [`ModelStep`]: crate::gemm::ModelStep

mod loader;
mod optimizer;

pub use loader::{BatchSource, Loader, TokenBatch};
pub use optimizer::{optimizer_from_json, Adam, Optimizer,
                    SgdMomentum};

use crate::coordinator::{LrSchedule, MetricsLog};
use crate::gemm::kernels::Kernels;
use crate::gemm::{matmul, DataPath, ModelStep, ModelStepConfig,
                  StepReport, OUTLIER_HIST_BINS};
use crate::model::{model_linears, sites_per_layer, LinearShape};
use crate::quant::quant_work_counters;
use crate::util::json::{arr_f64, obj, Json};
use crate::util::rng::Pcg64;
use crate::util::Mat;

/// `kind` tag of the training checkpoint format.
pub const TRAIN_STATE_KIND: &str = "dbfq_train_checkpoint";

/// Current training checkpoint version. History: **1** — bare
/// [`ModelStep::warm_state`] with no optimizer/loader section
/// (pre-train-loop); **2** — adds optimizer state, loader cursor,
/// and master weights; **3** — adds the precision-format record
/// (`format`) and the `glu` fingerprint field. v1 files cannot
/// resume an optimizer run and v2 files cannot say which rung of
/// the precision lattice produced them, so
/// [`TrainLoop::from_checkpoint`] rejects both loudly instead of
/// resuming onto silently different arithmetic.
pub const TRAIN_STATE_VERSION: f64 = 3.0;

/// Metric-log key per outlier-histogram bin: bin `b` counts blocks
/// whose AbsMax metric has f32 exponent `b − 8` (see
/// [`crate::gemm::metric_histogram`]).
const HIST_KEYS: [&str; OUTLIER_HIST_BINS] = [
    "outlier_hist_00", "outlier_hist_01", "outlier_hist_02",
    "outlier_hist_03", "outlier_hist_04", "outlier_hist_05",
    "outlier_hist_06", "outlier_hist_07", "outlier_hist_08",
    "outlier_hist_09", "outlier_hist_10", "outlier_hist_11",
    "outlier_hist_12", "outlier_hist_13", "outlier_hist_14",
    "outlier_hist_15",
];

/// Configuration of a [`TrainLoop`].
#[derive(Debug, Clone)]
pub struct TrainLoopConfig {
    pub layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub vocab: usize,
    /// sequences per microbatch
    pub batch: usize,
    /// tokens per sequence (each window carries `seq + 1` tokens)
    pub seq: usize,
    pub block: usize,
    pub threads: usize,
    pub shards: usize,
    pub path: DataPath,
    /// SwiGLU MLP through the split `mlp_gate` / `mlp_up` sites
    /// (5 quantized sites per layer) instead of the ReLU MLP (4)
    pub glu: bool,
    /// opt-in outlier telemetry: per-block activation-magnitude
    /// histograms per site per step, streamed through the metrics
    /// log and summed into [`StepStats::outlier_hist`]
    pub telemetry: bool,
    pub lr: LrSchedule,
    /// global-norm gradient clip; `0` disables
    pub grad_clip: f64,
    /// microbatches accumulated per optimizer step (≥ 1). With > 1,
    /// microsteps 2.. of a step re-run against unchanged weights, so
    /// the plan cache hits — the steady-state regime the cache
    /// exists for even under full per-step weight mutation.
    pub accum: usize,
    pub sr_seed: u64,
    /// seeds the fixed embedding and the weight init
    pub init_seed: u64,
    /// run the exact dense-f32 reference engine instead of the
    /// quantized substrate
    pub exact: bool,
}

impl TrainLoopConfig {
    pub fn new(layers: usize, d_model: usize, d_ff: usize,
               vocab: usize, batch: usize, seq: usize,
               block: usize) -> TrainLoopConfig {
        let ms = ModelStepConfig::new(layers, d_model, d_ff, vocab,
                                      batch * seq, block);
        TrainLoopConfig {
            layers,
            d_model,
            d_ff,
            vocab,
            batch,
            seq,
            block,
            threads: ms.threads,
            shards: ms.shards,
            path: ms.path,
            glu: false,
            telemetry: false,
            lr: LrSchedule { peak: 5e-3, warmup: 10, total: 0 },
            grad_clip: 1.0,
            accum: 1,
            sr_seed: ms.sr_seed,
            init_seed: 0x7A11,
            exact: false,
        }
    }

    /// Activation rows per microstep.
    pub fn tokens(&self) -> usize {
        self.batch * self.seq
    }

    pub fn n_sites(&self) -> usize {
        sites_per_layer(self.glu) * self.layers + 1
    }

    /// The [`ModelStepConfig`] of the quantized engine, mirroring
    /// this config's MLP flavor, data path, and telemetry knobs.
    pub fn model_config(&self) -> ModelStepConfig {
        let mut ms = ModelStepConfig::new(
            self.layers, self.d_model, self.d_ff, self.vocab,
            self.tokens(), self.block);
        ms.glu = self.glu;
        ms.telemetry = self.telemetry;
        ms.threads = self.threads;
        ms.shards = self.shards;
        ms.path = self.path;
        ms.sr_seed = self.sr_seed;
        ms
    }
}

/// Which GEMM substrate a [`TrainLoop`] runs on.
pub enum Engine {
    /// the quantized plan/execute engine with dynamic fallback
    Quantized(ModelStep),
    /// dense f32 reference ([`crate::gemm::matmul`])
    Exact,
}

/// One optimizer step's telemetry.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub step: usize,
    /// masked mean loss, averaged over the step's microbatches
    pub loss: f64,
    /// pre-clip global gradient norm
    pub grad_norm: f64,
    pub lr: f64,
    /// mean executed forward fallback rate across sites and
    /// microbatches (0 on the exact engine). On the Int4 lattice
    /// this is the tier ≥ Int8 promotion rate.
    pub fallback_rate: f64,
    /// mean f32-tier promotion rate (0 off the Int4 lattice)
    pub fallback_rate_f32: f64,
    /// per-block activation-magnitude histogram, summed over sites
    /// and microbatches ([`crate::gemm::metric_histogram`] bins);
    /// present only when the config's `telemetry` knob is on
    pub outlier_hist: Option<Vec<u64>>,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// thread-global quantization-call / panel-pack deltas over the
    /// step ([`quant_work_counters`]); exact only at `threads = 1`,
    /// where all quantization runs on the calling thread
    pub quants: u64,
    pub packs: u64,
}

/// Deterministic end-to-end training driver; see the module docs.
pub struct TrainLoop {
    cfg: TrainLoopConfig,
    sites: Vec<LinearShape>,
    /// master weights, mirrored into the engine via `set_weight`
    weights: Vec<Mat>,
    /// fixed token embedding (vocab × d_model), never trained
    embed: Mat,
    engine: Engine,
    opt: Box<dyn Optimizer>,
    loader: Loader,
    step: usize,
    history: Vec<StepStats>,
    log: Option<MetricsLog>,
}

/// Forward intermediates one microbatch's backward needs.
struct Trace {
    /// per-site input activation (for the exact engine's dW; the
    /// quantized engine keeps its own quantized copy internally)
    xs: Vec<Mat>,
    /// per-layer pre-ReLU MLP activation (for the ReLU mask;
    /// empty under `glu`)
    hs: Vec<Mat>,
    /// per-layer pre-activation gate projection (SwiGLU only)
    gs: Vec<Mat>,
    /// per-layer up projection (SwiGLU only)
    us: Vec<Mat>,
    logits: Mat,
}

fn add_into(a: &mut Mat, b: &Mat) {
    assert_eq!(a.data.len(), b.data.len());
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

/// Columns `c0..c1` of `m` as a fresh matrix.
fn take_cols(m: &Mat, c0: usize, c1: usize) -> Mat {
    Mat::from_fn(m.rows, c1 - c0, |r, c| m.row(r)[c0 + c])
}

/// `src` placed at column offset `c0` of a (rows × cols) zero
/// matrix.
fn scatter_cols(src: &Mat, cols: usize, c0: usize) -> Mat {
    let mut out = Mat::zeros(src.rows, cols);
    for r in 0..src.rows {
        let dst = &mut out.data[r * cols + c0..];
        dst[..src.cols].copy_from_slice(src.row(r));
    }
    out
}

fn relu(m: &Mat) -> Mat {
    let mut out = m.clone();
    for v in &mut out.data {
        *v = v.max(0.0);
    }
    out
}

fn relu_bwd(d: &Mat, pre: &Mat) -> Mat {
    let mut out = d.clone();
    for (v, &h) in out.data.iter_mut().zip(&pre.data) {
        if h <= 0.0 {
            *v = 0.0;
        }
    }
    out
}

fn sigmoid(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

/// SwiGLU mix: `silu(g) ⊙ u`, elementwise over same-shape matrices.
fn glu_mix(g: &Mat, u: &Mat) -> Mat {
    assert_eq!((g.rows, g.cols), (u.rows, u.cols));
    let mut out = g.clone();
    for (v, &uu) in out.data.iter_mut().zip(&u.data) {
        *v = *v * sigmoid(*v) * uu;
    }
    out
}

/// Backward of [`glu_mix`]: `(dGate, dUp)` from the downstream
/// gradient `da` and the saved pre-activation gate / up projections.
/// `silu'(g) = σ(g)·(1 + g·(1 − σ(g)))`.
fn glu_bwd(da: &Mat, g: &Mat, u: &Mat) -> (Mat, Mat) {
    let mut dgate = da.clone();
    let mut dup = da.clone();
    for i in 0..da.data.len() {
        let gv = g.data[i];
        let s = sigmoid(gv);
        dup.data[i] = da.data[i] * gv * s;
        dgate.data[i] =
            da.data[i] * u.data[i] * (s * (1.0 + gv * (1.0 - s)));
    }
    (dgate, dup)
}

/// Split a `(batch, seq + 1)` window batch into inputs (positions
/// `..seq`) and next-token targets (positions `1..`).
fn split_window(tb: &TokenBatch) -> (Vec<i32>, Vec<i32>) {
    let (b, s) = (tb.batch, tb.seq);
    assert_eq!(tb.tokens.len(), b * (s + 1));
    let mut inputs = Vec::with_capacity(b * s);
    let mut targets = Vec::with_capacity(b * s);
    for row in tb.tokens.chunks_exact(s + 1) {
        inputs.extend_from_slice(&row[..s]);
        targets.extend_from_slice(&row[1..]);
    }
    (inputs, targets)
}

/// Per-position loss weights (batch·seq, aligned with the flattened
/// activation rows): all-ones for pretrain batches, answer-span
/// indicator for finetune batches — span position `pos` marks slot
/// `pos - 1`, matching [`crate::data::answer_span_loss`].
fn loss_mask(tb: &TokenBatch) -> Vec<f32> {
    let (b, s) = (tb.batch, tb.seq);
    match &tb.spans {
        None => vec![1.0; b * s],
        Some(spans) => {
            let mut mask = vec![0.0; b * s];
            for (i, span) in spans.iter().enumerate().take(b) {
                for pos in span.clone() {
                    if (1..=s).contains(&pos) {
                        mask[i * s + (pos - 1)] = 1.0;
                    }
                }
            }
            mask
        }
    }
}

/// Stable masked softmax cross-entropy.
///
/// Returns the weighted mean loss, the unmasked per-position losses
/// (the [`crate::data::answer_span_loss`] input), and `dLoss/dLogits`
/// with the mask and `1/Σmask` folded in. All-zero mask → loss 0 and
/// zero gradient (a finetune batch whose spans all fell out of the
/// window must be a no-op, not a NaN).
fn softmax_ce(logits: &Mat, targets: &[i32], mask: &[f32])
              -> (f64, Vec<f32>, Mat) {
    let (rows, vocab) = (logits.rows, logits.cols);
    assert_eq!(targets.len(), rows);
    assert_eq!(mask.len(), rows);
    let wsum: f64 = mask.iter().map(|&w| w as f64).sum();
    let mut per_token = Vec::with_capacity(rows);
    let mut dlogits = Mat::zeros(rows, vocab);
    let mut loss = 0.0f64;
    for r in 0..rows {
        let z = logits.row(r);
        let t = targets[r] as usize;
        assert!(t < vocab, "target {t} outside vocab {vocab}");
        let zmax = z.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let sumexp: f64 =
            z.iter().map(|&v| ((v - zmax) as f64).exp()).sum();
        let lse = sumexp.ln();
        let l = (lse - (z[t] - zmax) as f64) as f32;
        per_token.push(l);
        if wsum > 0.0 && mask[r] > 0.0 {
            let w = mask[r] as f64 / wsum;
            loss += w * l as f64;
            for (c, &v) in z.iter().enumerate() {
                let p = ((v - zmax) as f64).exp() / sumexp;
                let onehot = if c == t { 1.0 } else { 0.0 };
                dlogits.data[r * vocab + c] =
                    (w * (p - onehot)) as f32;
            }
        }
    }
    (loss, per_token, dlogits)
}

impl TrainLoop {
    /// Build a fresh run: embedding first (σ = 1), then per-site
    /// weights at σ = 1/√k, all from one `init_seed` stream — the
    /// draw order is part of the checkpoint contract (restore
    /// regenerates the embedding from the same stream).
    pub fn new(cfg: TrainLoopConfig, loader: Loader) -> TrainLoop {
        assert_eq!(loader.batch_size(), cfg.batch,
                   "loader batch size vs config");
        assert_eq!(loader.seq(), cfg.seq, "loader seq vs config");
        assert_eq!(loader.vocab(), cfg.vocab,
                   "loader vocab vs config");
        assert!(cfg.accum >= 1, "accum must be >= 1");
        let sites = model_linears(cfg.layers, cfg.d_model, cfg.d_ff,
                                  cfg.glu, cfg.vocab, cfg.tokens());
        let mut rng = Pcg64::new(cfg.init_seed);
        let embed =
            Mat::randn(cfg.vocab, cfg.d_model, 1.0, &mut rng);
        let weights: Vec<Mat> = sites
            .iter()
            .map(|l| {
                let sigma = 1.0 / (l.k as f32).sqrt();
                Mat::randn(l.k, l.n, sigma, &mut rng)
            })
            .collect();
        let engine = if cfg.exact {
            Engine::Exact
        } else {
            Engine::Quantized(ModelStep::new(cfg.model_config(),
                                             weights.clone()))
        };
        let opt = Box::new(Adam::new(sites.len()));
        TrainLoop {
            cfg,
            sites,
            weights,
            embed,
            engine,
            opt,
            loader,
            step: 0,
            history: Vec::new(),
            log: None,
        }
    }

    /// Replace the optimizer (before any steps were taken).
    pub fn with_optimizer(mut self,
                          opt: Box<dyn Optimizer>) -> TrainLoop {
        assert_eq!(self.step, 0,
                   "with_optimizer after training started");
        self.opt = opt;
        self
    }

    /// Pin a specific kernel backend on the quantized engine
    /// (no-op on [`Engine::Exact`]).
    pub fn with_kernels(mut self, k: &'static Kernels) -> TrainLoop {
        self.engine = match self.engine {
            Engine::Quantized(ms) => {
                Engine::Quantized(ms.with_kernels(k))
            }
            e => e,
        };
        self
    }

    /// Attach a [`MetricsLog`]; every step logs loss, grad norm,
    /// lr, fallback rate, and cache stats. A write failure warns
    /// once and detaches the log (training never aborts on
    /// telemetry).
    pub fn attach_log(&mut self, log: MetricsLog) {
        self.log = Some(log);
    }

    pub fn config(&self) -> &TrainLoopConfig {
        &self.cfg
    }

    pub fn sites(&self) -> &[LinearShape] {
        &self.sites
    }

    pub fn weights(&self) -> &[Mat] {
        &self.weights
    }

    pub fn step(&self) -> usize {
        self.step
    }

    pub fn history(&self) -> &[StepStats] {
        &self.history
    }

    pub fn loader(&self) -> &Loader {
        &self.loader
    }

    pub fn optimizer(&self) -> &dyn Optimizer {
        self.opt.as_ref()
    }

    /// The quantized engine, when this run has one.
    pub fn model(&self) -> Option<&ModelStep> {
        match &self.engine {
            Engine::Quantized(ms) => Some(ms),
            Engine::Exact => None,
        }
    }

    pub fn model_mut(&mut self) -> Option<&mut ModelStep> {
        match &mut self.engine {
            Engine::Quantized(ms) => Some(ms),
            Engine::Exact => None,
        }
    }

    /// Exact-f32 forward over one window batch using the master
    /// weights (never the quantized engine — evaluation must not
    /// touch engine state mid-step): per-position losses, the
    /// [`crate::data::answer_span_loss`] input.
    pub fn eval_per_token(&self, tb: &TokenBatch) -> Vec<f32> {
        let (inputs, targets) = split_window(tb);
        let trace = self.exact_forward(&inputs);
        let mask = vec![1.0; targets.len()];
        let (_, per_token, _) =
            softmax_ce(&trace.logits, &targets, &mask);
        per_token
    }

    /// Masked mean evaluation loss of one window batch (exact-f32
    /// forward; finetune batches mask to their answer spans).
    pub fn eval_loss(&self, tb: &TokenBatch) -> f64 {
        let (inputs, targets) = split_window(tb);
        let trace = self.exact_forward(&inputs);
        let mask = loss_mask(tb);
        let (loss, _, _) =
            softmax_ce(&trace.logits, &targets, &mask);
        loss
    }

    /// Embedding lookup: one activation row per flattened position.
    fn embed_rows(&self, inputs: &[i32]) -> Mat {
        Mat::from_fn(inputs.len(), self.cfg.d_model, |r, c| {
            let t = inputs[r] as usize;
            assert!(t < self.cfg.vocab,
                    "token {t} outside vocab {}", self.cfg.vocab);
            self.embed.row(t)[c]
        })
    }

    /// One exact dense-f32 forward pass, tracing what backward
    /// needs.
    fn exact_forward(&self, inputs: &[i32]) -> Trace {
        let d = self.cfg.d_model;
        let th = self.cfg.threads;
        let spl = sites_per_layer(self.cfg.glu);
        let mut xs = Vec::with_capacity(self.sites.len());
        let mut hs = Vec::with_capacity(self.cfg.layers);
        let mut gs = Vec::with_capacity(self.cfg.layers);
        let mut us = Vec::with_capacity(self.cfg.layers);
        let mut x = self.embed_rows(inputs);
        for layer in 0..self.cfg.layers {
            let base = spl * layer;
            xs.push(x.clone());
            let qkv = matmul(&x, &self.weights[base], th);
            let v = take_cols(&qkv, 2 * d, 3 * d);
            xs.push(v.clone());
            let attn = matmul(&v, &self.weights[base + 1], th);
            add_into(&mut x, &attn);
            if self.cfg.glu {
                // mlp_gate and mlp_up both read the post-attention
                // residual stream
                xs.push(x.clone());
                xs.push(x.clone());
                let g = matmul(&x, &self.weights[base + 2], th);
                let u = matmul(&x, &self.weights[base + 3], th);
                let a = glu_mix(&g, &u);
                gs.push(g);
                us.push(u);
                xs.push(a.clone());
                let m = matmul(&a, &self.weights[base + 4], th);
                add_into(&mut x, &m);
            } else {
                xs.push(x.clone());
                let h = matmul(&x, &self.weights[base + 2], th);
                let a = relu(&h);
                hs.push(h);
                xs.push(a.clone());
                let m = matmul(&a, &self.weights[base + 3], th);
                add_into(&mut x, &m);
            }
        }
        xs.push(x.clone());
        let logits = matmul(&x, &self.weights[spl * self.cfg.layers],
                            th);
        Trace { xs, hs, gs, us, logits }
    }

    /// Exact backward matching [`exact_forward`](Self::exact_forward)
    /// — accumulates per-site `dW = Xᵀ·dY` into `dws`.
    fn exact_backward(&self, trace: &Trace, dlogits: &Mat,
                      dws: &mut [Mat]) {
        let d = self.cfg.d_model;
        let th = self.cfg.threads;
        let spl = sites_per_layer(self.cfg.glu);
        let head = spl * self.cfg.layers;
        let site_bwd = |site: usize, dy: &Mat, dws: &mut [Mat]| {
            add_into(&mut dws[site],
                     &matmul(&trace.xs[site].transpose(), dy, th));
            matmul(dy, &self.weights[site].transpose(), th)
        };
        let mut dx = site_bwd(head, dlogits, dws);
        for layer in (0..self.cfg.layers).rev() {
            let base = spl * layer;
            if self.cfg.glu {
                let da = site_bwd(base + 4, &dx, dws);
                let (dgate, dup) =
                    glu_bwd(&da, &trace.gs[layer], &trace.us[layer]);
                add_into(&mut dx, &site_bwd(base + 3, &dup, dws));
                add_into(&mut dx, &site_bwd(base + 2, &dgate, dws));
            } else {
                let da = site_bwd(base + 3, &dx, dws);
                let dh = relu_bwd(&da, &trace.hs[layer]);
                add_into(&mut dx, &site_bwd(base + 2, &dh, dws));
            }
            let dv = site_bwd(base + 1, &dx, dws);
            let dqkv = scatter_cols(&dv, 3 * d, 2 * d);
            add_into(&mut dx, &site_bwd(base, &dqkv, dws));
        }
    }

    /// One microbatch through whichever engine this run has:
    /// forward, loss, backward, `dW` accumulation into `dws`.
    /// Returns the masked loss and (on the quantized engine) the
    /// microstep report.
    fn microbatch(&mut self, tb: &TokenBatch, dws: &mut [Mat])
                  -> (f64, Option<StepReport>) {
        let (inputs, targets) = split_window(tb);
        let mask = loss_mask(tb);
        if matches!(self.engine, Engine::Exact) {
            let trace = self.exact_forward(&inputs);
            let (loss, _, dlogits) =
                softmax_ce(&trace.logits, &targets, &mask);
            self.exact_backward(&trace, &dlogits, dws);
            (loss, None)
        } else {
            let (loss, report) = self
                .quantized_microbatch(&inputs, &targets, &mask,
                                      dws);
            (loss, Some(report))
        }
    }

    /// The quantized twin of exact forward/backward, through
    /// [`ModelStep`]'s split-microstep API: interleaved
    /// `forward_site` calls, the loss at the head, then
    /// `backward_site` in reverse with residual merging, closed by
    /// `finish_microstep`.
    fn quantized_microbatch(&mut self, inputs: &[i32],
                            targets: &[i32], mask: &[f32],
                            dws: &mut [Mat])
                            -> (f64, StepReport) {
        let d = self.cfg.d_model;
        let layers = self.cfg.layers;
        let glu = self.cfg.glu;
        let spl = sites_per_layer(glu);
        let head = spl * layers;
        let mut x = self.embed_rows(inputs);
        let ms = match &mut self.engine {
            Engine::Quantized(ms) => ms,
            Engine::Exact => unreachable!("quantized microbatch"),
        };
        let mut hs = Vec::with_capacity(layers);
        let mut gus = Vec::with_capacity(layers);
        for layer in 0..layers {
            let base = spl * layer;
            let qkv = ms.forward_site(base, &x);
            let v = take_cols(&qkv, 2 * d, 3 * d);
            let attn = ms.forward_site(base + 1, &v);
            add_into(&mut x, &attn);
            if glu {
                let g = ms.forward_site(base + 2, &x);
                let u = ms.forward_site(base + 3, &x);
                let a = glu_mix(&g, &u);
                gus.push((g, u));
                let m = ms.forward_site(base + 4, &a);
                add_into(&mut x, &m);
            } else {
                let h = ms.forward_site(base + 2, &x);
                let a = relu(&h);
                hs.push(h);
                let m = ms.forward_site(base + 3, &a);
                add_into(&mut x, &m);
            }
        }
        let logits = ms.forward_site(head, &x);
        let (loss, _, dlogits) = softmax_ce(&logits, targets, mask);
        let mut dx = ms.backward_site(head, &dlogits);
        for layer in (0..layers).rev() {
            let base = spl * layer;
            if glu {
                let da = ms.backward_site(base + 4, &dx);
                let (g, u) = &gus[layer];
                let (dgate, dup) = glu_bwd(&da, g, u);
                add_into(&mut dx, &ms.backward_site(base + 3, &dup));
                add_into(&mut dx,
                         &ms.backward_site(base + 2, &dgate));
            } else {
                let da = ms.backward_site(base + 3, &dx);
                let dh = relu_bwd(&da, &hs[layer]);
                add_into(&mut dx, &ms.backward_site(base + 2, &dh));
            }
            let dv = ms.backward_site(base + 1, &dx);
            let dqkv = scatter_cols(&dv, 3 * d, 2 * d);
            add_into(&mut dx, &ms.backward_site(base, &dqkv));
        }
        let report = ms.finish_microstep();
        for (acc, out) in dws.iter_mut().zip(ms.outputs()) {
            add_into(acc, &out.dw);
        }
        (loss, report)
    }

    /// One optimizer step: `accum` microbatches, gradient
    /// averaging, global-norm clip, threshold-controller step,
    /// optimizer update, weight write-back into the engine.
    pub fn step_once(&mut self) -> StepStats {
        let lr = self.cfg.lr.lr_at(self.step);
        let (q0, p0) = quant_work_counters();
        let mut dws: Vec<Mat> = self
            .sites
            .iter()
            .map(|l| Mat::zeros(l.k, l.n))
            .collect();
        let mut loss_sum = 0.0f64;
        let mut fb_sum = 0.0f64;
        let mut fb32_sum = 0.0f64;
        let mut fb_n = 0usize;
        let mut hist: Option<Vec<u64>> = None;
        let (mut hits, mut misses) = (0u64, 0u64);
        for _ in 0..self.cfg.accum {
            let tb = self.loader.next_batch();
            let (loss, report) = self.microbatch(&tb, &mut dws);
            loss_sum += loss;
            if let Some(rep) = report {
                hits += rep.cache_hits;
                misses += rep.cache_misses;
                for s in &rep.sites {
                    fb_sum += s.fallback_rate;
                    fb32_sum += s.fallback_rate_f32;
                    fb_n += 1;
                    if let Some(h) = &s.outlier_hist {
                        let acc = hist.get_or_insert_with(|| {
                            vec![0u64; h.len()]
                        });
                        for (a, &v) in acc.iter_mut().zip(h) {
                            *a += v;
                        }
                    }
                }
            }
        }
        let inv = 1.0 / self.cfg.accum as f32;
        let mut sq = 0.0f64;
        for dw in &mut dws {
            for v in &mut dw.data {
                *v *= inv;
                sq += (*v as f64) * (*v as f64);
            }
        }
        let grad_norm = sq.sqrt();
        if self.cfg.grad_clip > 0.0 && grad_norm > self.cfg.grad_clip
        {
            let scale = (self.cfg.grad_clip / grad_norm) as f32;
            for dw in &mut dws {
                for v in &mut dw.data {
                    *v *= scale;
                }
            }
        }
        if let Engine::Quantized(ms) = &mut self.engine {
            ms.end_step();
        }
        self.opt.begin_step();
        for (s, dw) in dws.iter().enumerate() {
            self.opt.update(s, &mut self.weights[s], dw, lr as f32);
            if let Engine::Quantized(ms) = &mut self.engine {
                ms.set_weight(s, self.weights[s].clone());
            }
        }
        let (q1, p1) = quant_work_counters();
        let stats = StepStats {
            step: self.step,
            loss: loss_sum / self.cfg.accum as f64,
            grad_norm,
            lr,
            fallback_rate: if fb_n == 0 {
                0.0
            } else {
                fb_sum / fb_n as f64
            },
            fallback_rate_f32: if fb_n == 0 {
                0.0
            } else {
                fb32_sum / fb_n as f64
            },
            outlier_hist: hist,
            cache_hits: hits,
            cache_misses: misses,
            quants: q1.wrapping_sub(q0),
            packs: p1.wrapping_sub(p0),
        };
        let mut log_failed = false;
        if let Some(log) = &mut self.log {
            let mut kv = vec![
                ("loss", stats.loss),
                ("grad_norm", stats.grad_norm),
                ("lr", stats.lr),
                ("fallback_rate", stats.fallback_rate),
                ("fallback_rate_f32", stats.fallback_rate_f32),
                ("cache_hits", stats.cache_hits as f64),
                ("cache_misses", stats.cache_misses as f64),
            ];
            if let Some(h) = &stats.outlier_hist {
                for (i, &v) in h.iter().enumerate() {
                    kv.push((HIST_KEYS[i], v as f64));
                }
            }
            log_failed = log.log(stats.step, &kv).is_err();
        }
        if log_failed {
            eprintln!("train: metrics log write failed — \
                       detaching the log");
            self.log = None;
        }
        self.step += 1;
        self.history.push(stats.clone());
        stats
    }

    /// Run `steps` optimizer steps; returns their stats.
    pub fn run(&mut self, steps: usize) -> Vec<StepStats> {
        (0..steps).map(|_| self.step_once()).collect()
    }

    /// Serialize the full resumable state — see the module docs for
    /// the format. The corpus/task itself is not serialized: the
    /// caller rebuilds the [`Loader`] and
    /// [`from_checkpoint`](Self::from_checkpoint) checks its seed.
    pub fn checkpoint(&self) -> Json {
        let weights = Json::Arr(
            self.weights
                .iter()
                .map(|w| {
                    let v: Vec<f64> = w
                        .data
                        .iter()
                        .map(|&x| x as f64)
                        .collect();
                    arr_f64(&v)
                })
                .collect(),
        );
        obj(vec![
            ("kind", Json::Str(TRAIN_STATE_KIND.into())),
            ("version", Json::Num(TRAIN_STATE_VERSION)),
            // the precision-format record: which rung of the lattice
            // produced this run's arithmetic
            ("format", Json::Str(self.cfg.path.tag().into())),
            ("step", Json::Num(self.step as f64)),
            ("config", obj(vec![
                ("layers", Json::Num(self.cfg.layers as f64)),
                ("d_model", Json::Num(self.cfg.d_model as f64)),
                ("d_ff", Json::Num(self.cfg.d_ff as f64)),
                ("vocab", Json::Num(self.cfg.vocab as f64)),
                ("batch", Json::Num(self.cfg.batch as f64)),
                ("seq", Json::Num(self.cfg.seq as f64)),
                ("block", Json::Num(self.cfg.block as f64)),
                ("glu", Json::Bool(self.cfg.glu)),
                ("accum", Json::Num(self.cfg.accum as f64)),
                ("init_seed",
                 Json::Str(format!("{:016x}", self.cfg.init_seed))),
                ("exact", Json::Bool(self.cfg.exact)),
            ])),
            ("loader", obj(vec![
                ("seed",
                 Json::Str(format!("{:016x}", self.loader.seed()))),
                ("cursor", Json::Num(self.loader.cursor() as f64)),
            ])),
            ("optimizer", self.opt.to_json()),
            ("weights", weights),
            ("warm_state", match &self.engine {
                Engine::Quantized(ms) => ms.warm_state(None),
                Engine::Exact => Json::Null,
            }),
        ])
    }

    /// [`checkpoint`](Self::checkpoint) straight to a file.
    pub fn save_checkpoint(&self, path: &str)
                           -> Result<(), String> {
        self.checkpoint().to_file(path)
    }

    /// Restore a run. Strict on purpose: wrong `kind`, any version
    /// other than [`TRAIN_STATE_VERSION`] (older files are
    /// pre-lattice snapshots — v1 additionally has no optimizer
    /// state to resume from), a missing / unknown / mismatched
    /// precision-format record, a config fingerprint mismatch, or a
    /// loader whose seed differs from the saved one all fail
    /// loudly. The resumed run continues bit-identically to the
    /// uninterrupted original.
    pub fn from_checkpoint(cfg: TrainLoopConfig, mut loader: Loader,
                           state: &Json)
                           -> Result<TrainLoop, String> {
        if state.get("kind").and_then(|v| v.as_str())
            != Some(TRAIN_STATE_KIND)
        {
            return Err(
                "train checkpoint: wrong or missing 'kind'".into());
        }
        let version =
            state.get("version").and_then(|v| v.as_f64());
        match version {
            Some(v) if v == TRAIN_STATE_VERSION => {}
            Some(v) if v < TRAIN_STATE_VERSION => {
                return Err(format!(
                    "train checkpoint: version {v} is a pre-lattice \
                     snapshot (no precision-format record; v1 also \
                     predates optimizer state) — this build reads \
                     only version {TRAIN_STATE_VERSION}; re-save \
                     the checkpoint with this build"
                ));
            }
            _ => {
                return Err(format!(
                    "train checkpoint: unsupported version \
                     {version:?} (this build reads only version \
                     {TRAIN_STATE_VERSION})"
                ));
            }
        }
        let fmt = match state.get("format").and_then(|v| v.as_str())
        {
            None => {
                return Err(
                    "train checkpoint: missing 'format' — a \
                     pre-lattice snapshot cannot say which rung of \
                     the precision lattice produced it; re-save the \
                     checkpoint with this build"
                        .into(),
                );
            }
            Some(s) => DataPath::from_tag(s).ok_or_else(|| {
                format!(
                    "train checkpoint: unknown precision format \
                     {s:?}"
                )
            })?,
        };
        if fmt != cfg.path {
            return Err(format!(
                "train checkpoint: recorded precision format '{}' \
                 differs from the live config's '{}' (set \
                 PALLAS_PATH to match or re-save the checkpoint)",
                fmt.tag(),
                cfg.path.tag()
            ));
        }
        let sc = state
            .get("config")
            .ok_or("train checkpoint: missing 'config'")?;
        let field = |k: &str| {
            sc.get(k).and_then(|v| v.as_usize()).ok_or_else(|| {
                format!("train checkpoint: missing '{k}'")
            })
        };
        let saved_init = sc
            .get("init_seed")
            .and_then(|v| v.as_str())
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or("train checkpoint: missing 'init_seed'")?;
        let fingerprint_ok = field("layers")? == cfg.layers
            && field("d_model")? == cfg.d_model
            && field("d_ff")? == cfg.d_ff
            && field("vocab")? == cfg.vocab
            && field("batch")? == cfg.batch
            && field("seq")? == cfg.seq
            && field("block")? == cfg.block
            && sc.get("glu").and_then(|v| v.as_bool())
                == Some(cfg.glu)
            && field("accum")? == cfg.accum
            && saved_init == cfg.init_seed
            && sc.get("exact").and_then(|v| v.as_bool())
                == Some(cfg.exact);
        if !fingerprint_ok {
            return Err("train checkpoint: config fingerprint \
                        mismatch (saved for a different run)"
                .into());
        }
        let lc = state
            .get("loader")
            .ok_or("train checkpoint: missing 'loader'")?;
        let saved_seed = lc
            .get("seed")
            .and_then(|v| v.as_str())
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or("train checkpoint: missing loader 'seed'")?;
        if loader.seed() != saved_seed {
            return Err(format!(
                "train checkpoint: loader seed {:016x} differs \
                 from the saved stream's {saved_seed:016x}",
                loader.seed()
            ));
        }
        let cursor = lc
            .get("cursor")
            .and_then(|v| v.as_usize())
            .ok_or("train checkpoint: missing loader 'cursor'")?;
        loader.seek(cursor as u64);
        let sites = model_linears(cfg.layers, cfg.d_model, cfg.d_ff,
                                  cfg.glu, cfg.vocab, cfg.tokens());
        let warr = state
            .get("weights")
            .and_then(|v| v.as_arr())
            .ok_or("train checkpoint: missing 'weights'")?;
        if warr.len() != sites.len() {
            return Err(format!(
                "train checkpoint: {} weight matrices for {} sites",
                warr.len(),
                sites.len()
            ));
        }
        let mut weights = Vec::with_capacity(sites.len());
        for (l, wj) in sites.iter().zip(warr) {
            let v = wj.to_f64_vec().ok_or(
                "train checkpoint: malformed weight matrix")?;
            if v.len() != l.k * l.n {
                return Err(format!(
                    "train checkpoint: site {} weight has {} \
                     values, expected {}",
                    l.name,
                    v.len(),
                    l.k * l.n
                ));
            }
            weights.push(Mat::from_vec(
                l.k, l.n,
                v.iter().map(|&x| x as f32).collect()));
        }
        let opt = optimizer_from_json(
            state
                .get("optimizer")
                .ok_or("train checkpoint: missing 'optimizer'")?,
            sites.len())?;
        let engine = if cfg.exact {
            Engine::Exact
        } else {
            let ws = state
                .get("warm_state")
                .ok_or("train checkpoint: missing 'warm_state'")?;
            let (ms, _) = ModelStep::from_warm_state(
                cfg.model_config(), weights.clone(), ws)?;
            Engine::Quantized(ms)
        };
        let step = state
            .get("step")
            .and_then(|v| v.as_usize())
            .ok_or("train checkpoint: missing 'step'")?;
        // The embedding is derived data: regenerate it from the
        // init stream exactly as `new` drew it.
        let mut rng = Pcg64::new(cfg.init_seed);
        let embed =
            Mat::randn(cfg.vocab, cfg.d_model, 1.0, &mut rng);
        Ok(TrainLoop {
            cfg,
            sites,
            weights,
            embed,
            engine,
            opt,
            loader,
            step,
            history: Vec::new(),
            log: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Corpus;

    fn tiny_cfg() -> TrainLoopConfig {
        let mut cfg = TrainLoopConfig::new(1, 32, 48, 64, 2, 8, 16);
        cfg.threads = 1;
        cfg
    }

    fn tiny_loader(seed: u64) -> Loader {
        Loader::pretrain(Corpus::synthetic(400, 64, 11), 2, 8, seed)
    }

    #[test]
    fn loss_starts_near_uniform_and_steps_run() {
        let mut tl = TrainLoop::new(tiny_cfg(), tiny_loader(3));
        let tb = tl.loader().batch_at(0);
        let l0 = tl.eval_loss(&tb);
        // Random weights ≈ uniform predictions: ln(64) ≈ 4.16.
        assert!((l0 - (64.0f64).ln()).abs() < 1.0, "initial {l0}");
        let stats = tl.run(2);
        assert_eq!(stats.len(), 2);
        assert_eq!(tl.step(), 2);
        assert!(stats[0].loss.is_finite());
        assert!(stats[0].grad_norm > 0.0);
        assert_eq!(tl.loader().cursor(), 2);
        assert_eq!(tl.history().len(), 2);
    }

    #[test]
    fn exact_and_quantized_agree_on_first_loss_scale() {
        // Not bit-equal (different arithmetic) but the same model:
        // microbatch losses must be close at init where quantization
        // error is the only difference.
        let mut cfg = tiny_cfg();
        let mut q = TrainLoop::new(cfg.clone(), tiny_loader(5));
        cfg.exact = true;
        let mut e = TrainLoop::new(cfg, tiny_loader(5));
        let sq = q.step_once();
        let se = e.step_once();
        assert!((sq.loss - se.loss).abs() < 0.5,
                "quantized {} vs exact {}", sq.loss, se.loss);
        assert_eq!(se.fallback_rate, 0.0);
        assert_eq!(se.cache_hits + se.cache_misses, 0);
    }

    #[test]
    fn finetune_masked_loss_ignores_context_positions() {
        let cfg = tiny_cfg();
        let loader = Loader::finetune(crate::data::Task::Arithmetic,
                                      64, 2, 8, 9);
        let tl = TrainLoop::new(cfg, loader);
        let tb = tl.loader().batch_at(0);
        let mask = loss_mask(&tb);
        assert_eq!(mask.len(), 2 * 8);
        let spans = tb.spans.as_ref().unwrap();
        let marked: f32 = mask.iter().sum();
        let expect: usize = spans
            .iter()
            .map(|s| {
                s.clone().filter(|p| (1..=8).contains(p)).count()
            })
            .sum();
        assert_eq!(marked as usize, expect);
        assert!(tl.eval_loss(&tb).is_finite());
    }

    #[test]
    fn softmax_ce_gradient_matches_finite_difference() {
        let mut rng = Pcg64::new(77);
        let logits = Mat::randn(3, 5, 1.0, &mut rng);
        let targets = [1i32, 4, 0];
        let mask = [1.0f32, 0.0, 1.0];
        let (l0, per_token, d) =
            softmax_ce(&logits, &targets, &mask);
        assert_eq!(per_token.len(), 3);
        // Masked row contributes no gradient.
        assert!(d.row(1).iter().all(|&v| v == 0.0));
        let eps = 1e-3f32;
        for (r, c) in [(0usize, 1usize), (0, 3), (2, 0), (2, 4)] {
            let mut bumped = logits.clone();
            bumped.data[r * 5 + c] += eps;
            let (l1, _, _) = softmax_ce(&bumped, &targets, &mask);
            let fd = (l1 - l0) / eps as f64;
            let an = d.data[r * 5 + c] as f64;
            assert!((fd - an).abs() < 1e-3,
                    "d[{r}][{c}]: fd {fd} vs {an}");
        }
        // Degenerate all-zero mask: loss 0, gradient 0.
        let (lz, _, dz) =
            softmax_ce(&logits, &targets, &[0.0, 0.0, 0.0]);
        assert_eq!(lz, 0.0);
        assert!(dz.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn checkpoint_roundtrips_through_json_text() {
        let mut tl = TrainLoop::new(tiny_cfg(), tiny_loader(21));
        tl.run(3);
        let ck = tl.checkpoint();
        let parsed = Json::parse(&ck.to_string()).unwrap();
        let tr = TrainLoop::from_checkpoint(
            tiny_cfg(), tiny_loader(21), &parsed)
            .unwrap();
        assert_eq!(tr.step(), 3);
        assert_eq!(tr.loader().cursor(), 3);
        for (a, b) in tl.weights().iter().zip(tr.weights()) {
            assert_eq!(a.data, b.data);
        }
        assert_eq!(tl.embed.data, tr.embed.data);
    }

    #[test]
    fn from_checkpoint_rejects_wrong_kind_and_version() {
        let mut tl = TrainLoop::new(tiny_cfg(), tiny_loader(2));
        tl.run(1);
        let ck = tl.checkpoint();
        // Wrong kind: a bare v1 warm-state file is not a training
        // checkpoint.
        let warm = tl.model().unwrap().warm_state(None);
        let err = TrainLoop::from_checkpoint(
            tiny_cfg(), tiny_loader(2), &warm)
            .unwrap_err();
        assert!(err.contains("kind"), "{err}");
        // Version 1 of the train format: rejected with a message
        // that names the version problem.
        let mut fields = match ck.clone() {
            Json::Obj(f) => f,
            _ => unreachable!(),
        };
        fields.insert("version".to_string(), Json::Num(1.0));
        let err = TrainLoop::from_checkpoint(
            tiny_cfg(), tiny_loader(2), &Json::Obj(fields))
            .unwrap_err();
        assert!(err.contains("version"), "{err}");
        // Loader seed mismatch is loud, not a silently different
        // data stream.
        let err = TrainLoop::from_checkpoint(
            tiny_cfg(), tiny_loader(99), &ck)
            .unwrap_err();
        assert!(err.contains("seed"), "{err}");
        // Config fingerprint mismatch.
        let mut other = tiny_cfg();
        other.d_ff = 32;
        let err = TrainLoop::from_checkpoint(
            other, tiny_loader(2), &ck)
            .unwrap_err();
        assert!(err.contains("fingerprint"), "{err}");
    }

    #[test]
    fn metrics_log_collects_series() {
        let mut tl = TrainLoop::new(tiny_cfg(), tiny_loader(4));
        tl.attach_log(MetricsLog::new("train_test", None).unwrap());
        tl.run(2);
        let log = tl.log.as_ref().unwrap();
        assert_eq!(log.series["loss"].count, 2);
        assert_eq!(log.series["grad_norm"].count, 2);
    }

    #[test]
    fn glu_quantized_and_exact_agree_at_init() {
        // The SwiGLU surrogate trains through both engines with the
        // same model: losses must be close at init, where
        // quantization error is the only difference, and the GLU
        // checkpoint must round-trip but reject a plain-MLP config.
        let mut cfg = tiny_cfg();
        cfg.glu = true;
        assert_eq!(cfg.n_sites(), 6);
        let mut q = TrainLoop::new(cfg.clone(), tiny_loader(5));
        let mut ecfg = cfg.clone();
        ecfg.exact = true;
        let mut e = TrainLoop::new(ecfg, tiny_loader(5));
        let sq = q.step_once();
        let se = e.step_once();
        assert!((sq.loss - se.loss).abs() < 0.5,
                "quantized {} vs exact {}", sq.loss, se.loss);
        assert!(sq.grad_norm > 0.0 && se.grad_norm > 0.0);
        q.run(1);
        let ck = q.checkpoint();
        let tr = TrainLoop::from_checkpoint(
            cfg.clone(), tiny_loader(5), &ck)
            .unwrap();
        assert_eq!(tr.step(), 2);
        for (a, b) in q.weights().iter().zip(tr.weights()) {
            assert_eq!(a.data, b.data);
        }
        let err = TrainLoop::from_checkpoint(
            tiny_cfg(), tiny_loader(5), &ck)
            .unwrap_err();
        assert!(err.contains("fingerprint"), "{err}");
    }

    #[test]
    fn telemetry_streams_tier_rates_and_histograms() {
        let mut cfg = tiny_cfg();
        cfg.telemetry = true;
        let path = cfg.path;
        let mut tl = TrainLoop::new(cfg, tiny_loader(4));
        tl.attach_log(
            MetricsLog::new("train_telemetry", None).unwrap());
        let stats = tl.step_once();
        let h = stats.outlier_hist.as_ref()
            .expect("telemetry on => histogram present");
        assert_eq!(h.len(), OUTLIER_HIST_BINS);
        assert!(h.iter().sum::<u64>() > 0);
        if path != DataPath::Int4 {
            assert_eq!(stats.fallback_rate_f32, 0.0,
                       "binary fallback has no f32 tier");
        }
        let log = tl.log.as_ref().unwrap();
        assert_eq!(log.series["fallback_rate_f32"].count, 1);
        let bins = (0..OUTLIER_HIST_BINS)
            .filter(|&i| log.series.contains_key(HIST_KEYS[i]))
            .count();
        assert_eq!(bins, OUTLIER_HIST_BINS,
                   "every histogram bin streams through the log");
        // off by default: no histogram, no per-bin series
        let mut plain = TrainLoop::new(tiny_cfg(), tiny_loader(4));
        assert!(plain.step_once().outlier_hist.is_none());
    }

    #[test]
    fn from_checkpoint_rejects_format_mismatch_and_pre_lattice() {
        // Satellite: the training checkpoint carries the
        // precision-format record; wrong rung, unknown tag, and
        // pre-lattice files (missing record / old version) all fail
        // loudly.
        let mut tl = TrainLoop::new(tiny_cfg(), tiny_loader(8));
        tl.run(1);
        let ck = tl.checkpoint();
        let cfg = tiny_cfg();
        let restore = |st: &Json| {
            TrainLoop::from_checkpoint(cfg.clone(), tiny_loader(8),
                                       st)
        };
        let other = if cfg.path == DataPath::Int4 { "int8" }
                    else { "int4" };
        let mut wrong = ck.clone();
        if let Json::Obj(f) = &mut wrong {
            f.insert("format".into(), Json::Str(other.into()));
        }
        let err = restore(&wrong).unwrap_err();
        assert!(err.contains("precision format")
                && err.contains("PALLAS_PATH"), "{err}");
        let mut junk = ck.clone();
        if let Json::Obj(f) = &mut junk {
            f.insert("format".into(), Json::Str("int2".into()));
        }
        let err = restore(&junk).unwrap_err();
        assert!(err.contains("unknown precision format"), "{err}");
        let mut missing = ck.clone();
        if let Json::Obj(f) = &mut missing {
            f.remove("format");
        }
        let err = restore(&missing).unwrap_err();
        assert!(err.contains("pre-lattice"), "{err}");
        let mut old = ck.clone();
        if let Json::Obj(f) = &mut old {
            f.insert("version".into(), Json::Num(2.0));
        }
        let err = restore(&old).unwrap_err();
        assert!(err.contains("pre-lattice"), "{err}");
        // the untouched checkpoint still restores
        assert!(restore(&ck).is_ok());
    }
}
