//! # Persistent execution runtime
//!
//! A lazily-initialized, process-wide pool of parked worker threads
//! with a **scoped-borrow** submit API — the replacement for the
//! per-call `std::thread::scope` dispatch the GEMM engine and the
//! quant constructors used to pay on every call (spawn + join of
//! fresh OS threads, ≈3·(4L+1) times per `ModelStep` microstep).
//! Callers hand [`run_scoped`] a batch of closures that may borrow
//! stack data; the call blocks until every closure has run, so the
//! borrows never outlive the submitting frame — the same lifetime
//! contract as `thread::scope`, without the thread churn.
//!
//! ## Scoped-borrow safety argument
//!
//! Jobs are lifetime-erased (`'env` → `'static`) before they enter
//! the shared queue — the one `unsafe` in this module. Soundness
//! rests on three properties, each enforced structurally:
//!
//! 1. **Submission always joins.** [`run_scoped`] blocks on a
//!    completion latch counting down to zero; the private
//!    `ScopeHandle` also waits in `Drop`, so even a panic on the
//!    submitting thread cannot unwind past live borrows.
//! 2. **Workers always count down.** Each job runs under
//!    `catch_unwind`; panic or not, the latch decrements, so the
//!    submitter cannot deadlock on a panicked job (the payload is
//!    re-raised on the submitting thread after the join).
//! 3. **Queued jobs always run.** The global pool never shuts down,
//!    and dedicated pools drain their queue before their workers
//!    exit (and `Drop` can only run once no `scope` borrow is live).
//!
//! ## Bit-identity
//!
//! The pool changes *where* closures run, never *what* they compute:
//! callers keep the exact same work partition (the engine's LPT
//! bucket → job mapping, the helpers' chunk boundaries) and each job
//! processes its units in the same order as the scoped-thread path.
//! Every output range is written by exactly one job with the same
//! deterministic instruction stream, so pool-vs-scoped outputs are
//! bit-identical by construction (`tests/pool_prop.rs` pins this per
//! backend, data path, and thread count).
//!
//! ## Control surface
//!
//! * `PALLAS_THREADS=<n>` — overrides
//!   [`default_threads`](crate::util::threadpool::default_threads)
//!   (plan/driver worker counts and the pool size). Invalid values
//!   are a hard error, mirroring `PALLAS_KERNEL`.
//! * `PALLAS_POOL=off` — escape hatch: [`run_scoped`] falls back to
//!   the historical `thread::scope` spawn-per-call path (`on` and
//!   unset mean pooled; anything else is a hard error).
//!   [`set_pool_enabled`] toggles the same flag at runtime for
//!   A/B benches and the pool-vs-scoped identity tests.
//! * `PALLAS_SHARDS=<n>` — default shard count for sharded GEMM
//!   execution ([`default_shards`]); the engine splits each plan's
//!   column panels into `n` contiguous shards and schedules each
//!   shard on a stable subset of workers via [`run_scoped_hinted`].
//!   Invalid values are a hard error; unset/empty means 1 (flat).
//!
//! Re-entrancy: a job that submits again (nested data parallelism)
//! runs the nested batch **inline** on its worker instead of queueing
//! and waiting — a worker waiting on its own pool would deadlock a
//! single-worker pool. Concurrent submitters (e.g. `cargo test`'s
//! parallel test threads) interleave safely: jobs carry their own
//! latch, so scopes never observe each other.
//!
//! ## Work counters
//!
//! [`work_counters`] extends the `quant_work_counters` pattern to the
//! runtime: per-thread counts of OS threads spawned and engine
//! workspace/output allocations, attributed to the *submitting*
//! thread (worker-side workspace growth is summed per scope through
//! the jobs' `u64` return values and booked on the caller). The
//! steady-state regression in `tests/pool_prop.rs` asserts both stay
//! at zero across warm `ModelStep` microsteps.

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// One schedulable unit of a scoped submit: runs to completion and
/// returns a metric (the engine reports workspace growths; plain
/// data-parallel helpers return 0). Metrics are summed per scope and
/// returned to the submitter.
pub type ScopeJob<'env> = Box<dyn FnOnce() -> u64 + Send + 'env>;

type StaticJob = Box<dyn FnOnce() -> u64 + Send + 'static>;

thread_local! {
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
    static THREAD_SPAWNS: Cell<u64> = const { Cell::new(0) };
    static WS_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// `(thread_spawns, workspace_allocs)` attributed to the calling
/// thread — the runtime's `quant_work_counters` twin. Spawns count
/// OS threads created on this thread's behalf (pool construction,
/// `PALLAS_POOL=off` scoped fallbacks); workspace allocs count
/// engine `acc`/`acci` growths and GEMM output-buffer growths (see
/// `GemmPlan::execute_into`). Monotonic; diff around a region to
/// measure it. Steady-state microsteps must add zero to both.
pub fn work_counters() -> (u64, u64) {
    (THREAD_SPAWNS.with(|c| c.get()), WS_ALLOCS.with(|c| c.get()))
}

pub(crate) fn note_spawns(n: u64) {
    if n > 0 {
        THREAD_SPAWNS.with(|c| c.set(c.get() + n));
    }
}

pub(crate) fn note_ws_allocs(n: u64) {
    if n > 0 {
        WS_ALLOCS.with(|c| c.set(c.get() + n));
    }
}

/// Whether the current thread is a pool worker (nested submits from
/// here run inline — see the module docs on re-entrancy).
pub fn in_worker() -> bool {
    IN_WORKER.with(|w| w.get())
}

/// Parse a `PALLAS_THREADS` value: `None`/empty → no override, a
/// positive integer → that worker count. Anything else is a hard
/// error (same contract as `kernels::parse_override` — a typo must
/// not silently fall back and invalidate a pinned run).
pub fn parse_threads_override(val: Option<&str>) -> Option<usize> {
    match val {
        None | Some("") => None,
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => Some(n),
            _ => panic!(
                "PALLAS_THREADS={s:?} is not a positive worker-thread \
                 count"
            ),
        },
    }
}

static ENV_THREADS: OnceLock<Option<usize>> = OnceLock::new();

/// The `PALLAS_THREADS` override, read once per process.
pub fn env_threads() -> Option<usize> {
    *ENV_THREADS.get_or_init(|| {
        parse_threads_override(
            std::env::var("PALLAS_THREADS").ok().as_deref(),
        )
    })
}

/// Parse a `PALLAS_SHARDS` value: `None`/empty → no override, a
/// positive integer → that shard count. Anything else is a hard
/// error (same contract as [`parse_threads_override`] — a typo must
/// not silently fall back and invalidate a pinned run).
pub fn parse_shards_override(val: Option<&str>) -> Option<usize> {
    match val {
        None | Some("") => None,
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => Some(n),
            _ => panic!(
                "PALLAS_SHARDS={s:?} is not a positive shard count"
            ),
        },
    }
}

static ENV_SHARDS: OnceLock<Option<usize>> = OnceLock::new();

/// The `PALLAS_SHARDS` override, read once per process.
pub fn env_shards() -> Option<usize> {
    *ENV_SHARDS.get_or_init(|| {
        parse_shards_override(
            std::env::var("PALLAS_SHARDS").ok().as_deref(),
        )
    })
}

/// The shard count new plans and drivers default to: the
/// `PALLAS_SHARDS` override, else 1 (auto). There is no portable
/// offline socket/CCD topology probe, so "auto" is the flat
/// single-shard schedule until an explicit override asks for more —
/// sharded and unsharded execution are bit-identical either way
/// (`tests/shard_prop.rs`), so the knob is purely a locality lever.
pub fn default_shards() -> usize {
    env_shards().unwrap_or(1)
}

/// Parse a `PALLAS_POOL` value: `None`/empty → no override (pooled),
/// `"on"`/`"off"` → forced. Anything else is a hard error.
pub fn parse_pool_override(val: Option<&str>) -> Option<bool> {
    match val {
        None | Some("") => None,
        Some("on") => Some(true),
        Some("off") => Some(false),
        Some(s) => panic!(
            "PALLAS_POOL={s:?} is not a valid pool mode (expected \
             \"on\" or \"off\")"
        ),
    }
}

static POOL_ENABLED: OnceLock<AtomicBool> = OnceLock::new();

fn enabled_flag() -> &'static AtomicBool {
    POOL_ENABLED.get_or_init(|| {
        let on = parse_pool_override(
            std::env::var("PALLAS_POOL").ok().as_deref(),
        )
        .unwrap_or(true);
        AtomicBool::new(on)
    })
}

/// Whether [`run_scoped`] routes through the persistent pool
/// (default) or the `thread::scope` fallback (`PALLAS_POOL=off` or
/// [`set_pool_enabled`]`(false)`).
pub fn pool_enabled() -> bool {
    enabled_flag().load(Ordering::Relaxed)
}

/// Runtime toggle of the pooled path — the A/B knob behind the
/// dispatch-overhead benches and the pool-vs-scoped identity tests.
/// Both paths are bit-identical; this only changes dispatch cost.
/// Tests toggling it must serialize on their own lock and restore
/// the previous value.
pub fn set_pool_enabled(on: bool) {
    enabled_flag().store(on, Ordering::Relaxed);
}

struct ScopeState {
    left: Mutex<usize>,
    done: Condvar,
    metric: AtomicU64,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl ScopeState {
    fn new(n: usize) -> ScopeState {
        ScopeState {
            left: Mutex::new(n),
            done: Condvar::new(),
            metric: AtomicU64::new(0),
            panic: Mutex::new(None),
        }
    }

    fn finish_one(&self) {
        let mut left = self.left.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut left = self.left.lock().unwrap();
        while *left > 0 {
            left = self.done.wait(left).unwrap();
        }
    }
}

struct Task {
    job: StaticJob,
    scope: Arc<ScopeState>,
    /// Preferred worker (reduced modulo the pool size): the sharded
    /// engine tags each shard's jobs with a stable worker index so a
    /// shard's packed panels are touched by the same threads every
    /// microstep (cache/NUMA locality). Purely best-effort — any
    /// worker may take any task, so placement never gates progress
    /// and correctness never depends on it.
    hint: Option<usize>,
}

struct PoolState {
    queue: VecDeque<Task>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    work: Condvar,
}

/// An in-flight scoped submit. Joining returns the summed job
/// metrics and re-raises the first job panic; dropping without
/// joining still blocks until every job finished (the lifetime
/// erasure's backstop — see the module docs).
struct ScopeHandle {
    state: Arc<ScopeState>,
}

impl ScopeHandle {
    fn join(self) -> u64 {
        self.state.wait();
        if let Some(p) = self.state.panic.lock().unwrap().take() {
            resume_unwind(p);
        }
        self.state.metric.load(Ordering::Relaxed)
    }
}

impl Drop for ScopeHandle {
    fn drop(&mut self) {
        // Idempotent: join() already waited by the time it drops.
        self.state.wait();
    }
}

/// A fixed set of parked worker threads executing scoped job
/// batches. One process-wide instance serves all callers (see
/// [`global`]); dedicated instances exist for tests
/// (oversubscription, shutdown).
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers.max(1)` parked threads (counted into
    /// [`work_counters`] on the calling thread).
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                queue: VecDeque::new(),
                shutdown: false,
            }),
            work: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("dbfq-pool-{i}"))
                    .spawn(move || worker_main(sh, i, workers))
                    .expect("spawn pool worker")
            })
            .collect();
        note_spawns(workers as u64);
        WorkerPool { shared, workers, handles }
    }

    /// Worker thread count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run every job and block until all completed; returns the
    /// summed metrics and re-raises the first job panic. Jobs may
    /// borrow the submitting frame — this call outlives them by
    /// construction. More jobs than workers is fine (they queue).
    pub fn scope(&self, tasks: Vec<ScopeJob<'_>>) -> u64 {
        if tasks.is_empty() {
            return 0;
        }
        self.submit(tasks.into_iter().map(|j| (None, j)).collect())
            .join()
    }

    /// [`scope`](WorkerPool::scope) with a preferred-worker hint per
    /// job (see [`Task::hint`]): a worker takes the first queued task
    /// hinted at it before falling back to FIFO order. Best-effort
    /// only — results and completion are identical to `scope`.
    pub fn scope_hinted(
        &self, tasks: Vec<(usize, ScopeJob<'_>)>,
    ) -> u64 {
        if tasks.is_empty() {
            return 0;
        }
        self.submit(
            tasks.into_iter().map(|(h, j)| (Some(h), j)).collect(),
        )
        .join()
    }

    /// Enqueue the batch and return its latch. Private: a leaked
    /// handle would be unsound-by-leak, so only the joining wrappers
    /// in this module may hold one.
    fn submit<'env>(
        &self, tasks: Vec<(Option<usize>, ScopeJob<'env>)>,
    ) -> ScopeHandle {
        let state = Arc::new(ScopeState::new(tasks.len()));
        {
            let mut st = self.shared.state.lock().unwrap();
            for (hint, job) in tasks {
                // SAFETY: the job's `'env` borrows stay valid until
                // the scope latch reaches zero, and every path out of
                // this module (join, handle drop, run_scoped unwind)
                // waits on that latch first; workers always decrement
                // it, panic or not. See the module-level safety
                // argument.
                let job: StaticJob = unsafe {
                    std::mem::transmute::<ScopeJob<'env>, StaticJob>(
                        job,
                    )
                };
                st.queue.push_back(Task {
                    job,
                    scope: Arc::clone(&state),
                    hint,
                });
            }
        }
        self.shared.work.notify_all();
        ScopeHandle { state }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Pull the next task for worker `me` of `nworkers`: the first task
/// hinted at this worker if one is queued, else plain FIFO. A task
/// hinted elsewhere is still taken when nothing matches — hints bias
/// placement, they never park a worker while work is queued.
fn pick_task(
    queue: &mut VecDeque<Task>, me: usize, nworkers: usize,
) -> Option<Task> {
    let mine = queue.iter().position(|t| {
        t.hint.is_some_and(|h| h % nworkers == me)
    });
    match mine {
        Some(i) => queue.remove(i),
        None => queue.pop_front(),
    }
}

fn worker_main(shared: Arc<Shared>, me: usize, nworkers: usize) {
    IN_WORKER.with(|w| w.set(true));
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(t) = pick_task(&mut st.queue, me, nworkers)
                {
                    break t;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        let Task { job, scope, hint: _ } = task;
        match catch_unwind(AssertUnwindSafe(job)) {
            Ok(m) => {
                scope.metric.fetch_add(m, Ordering::Relaxed);
            }
            Err(p) => {
                let mut slot = scope.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
        }
        scope.finish_one();
    }
}

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide pool, created on first pooled submit. Sized
/// `default_threads() - 1` because the submitting thread always runs
/// one job of its batch inline (see [`run_scoped`]) — a `W`-job
/// scope gets exactly `W`-way parallelism with no oversubscription.
/// Lives until process exit; under `cargo test` the parked workers
/// are shared by every concurrently running test.
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| {
        let n = crate::util::threadpool::default_threads();
        WorkerPool::new(n.saturating_sub(1))
    })
}

/// Run a batch of scoped jobs to completion and return their summed
/// metrics — the one dispatch point behind `parallel_chunks` /
/// `parallel_items` / `parallel_map` and `GemmPlan::execute`.
///
/// Dispatch policy, in order:
/// * 0 or 1 jobs → inline on the caller (no dispatch at all);
/// * on a pool worker → all inline (nested-submit re-entrancy);
/// * pool disabled → `thread::scope`, one spawned thread per job
///   (bit-identical; the historical path, kept as the
///   `PALLAS_POOL=off` escape hatch);
/// * otherwise → the last job runs inline on the caller while the
///   [`global`] pool executes the rest.
pub fn run_scoped(mut tasks: Vec<ScopeJob<'_>>) -> u64 {
    match tasks.len() {
        0 => 0,
        1 => tasks.pop().unwrap()(),
        _ if in_worker() => tasks.into_iter().map(|j| j()).sum(),
        _ if !pool_enabled() => scoped_fallback(tasks),
        _ => {
            let local_job = tasks.pop().unwrap();
            let handle = global()
                .submit(tasks.into_iter().map(|j| (None, j)).collect());
            // The local job must not unwind before the join — its
            // panic is held until the pooled jobs (which may borrow
            // the same frame) are done.
            let local = catch_unwind(AssertUnwindSafe(local_job));
            let pooled =
                catch_unwind(AssertUnwindSafe(|| handle.join()));
            match (local, pooled) {
                (Ok(a), Ok(b)) => a + b,
                (Err(p), _) | (Ok(_), Err(p)) => resume_unwind(p),
            }
        }
    }
}

/// [`run_scoped`] with a preferred-worker hint per job — the sharded
/// engine's dispatch point. Identical dispatch policy and results;
/// hints only bias which parked worker picks which job (and are
/// dropped entirely on the inline / `thread::scope` fallback paths,
/// where there are no persistent workers to pin to).
pub fn run_scoped_hinted(
    mut tasks: Vec<(usize, ScopeJob<'_>)>,
) -> u64 {
    match tasks.len() {
        0 => 0,
        1 => tasks.pop().unwrap().1(),
        _ if in_worker() => {
            tasks.into_iter().map(|(_, j)| j()).sum()
        }
        _ if !pool_enabled() => scoped_fallback(
            tasks.into_iter().map(|(_, j)| j).collect(),
        ),
        _ => {
            let (_, local_job) = tasks.pop().unwrap();
            let handle = global().submit(
                tasks
                    .into_iter()
                    .map(|(h, j)| (Some(h), j))
                    .collect(),
            );
            let local = catch_unwind(AssertUnwindSafe(local_job));
            let pooled =
                catch_unwind(AssertUnwindSafe(|| handle.join()));
            match (local, pooled) {
                (Ok(a), Ok(b)) => a + b,
                (Err(p), _) | (Ok(_), Err(p)) => resume_unwind(p),
            }
        }
    }
}

/// The pre-pool dispatch path: one fresh OS thread per job via
/// `std::thread::scope` (spawns are counted). Panics propagate on
/// the scope join, exactly as before.
fn scoped_fallback(tasks: Vec<ScopeJob<'_>>) -> u64 {
    note_spawns(tasks.len() as u64);
    let metric = AtomicU64::new(0);
    std::thread::scope(|s| {
        for job in tasks {
            let m = &metric;
            s.spawn(move || {
                m.fetch_add(job(), Ordering::Relaxed);
            });
        }
    });
    metric.into_inner()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn jobs_marking(
        flags: &[AtomicUsize],
    ) -> Vec<ScopeJob<'_>> {
        flags
            .iter()
            .map(|f| {
                Box::new(move || {
                    f.fetch_add(1, Ordering::Relaxed);
                    1u64
                }) as ScopeJob<'_>
            })
            .collect()
    }

    #[test]
    fn scope_runs_every_job_and_sums_metrics() {
        let pool = WorkerPool::new(2);
        let flags: Vec<AtomicUsize> =
            (0..16).map(|_| AtomicUsize::new(0)).collect();
        // 16 jobs on 2 workers: oversubscribed batches queue and
        // drain; each runs exactly once.
        let sum = pool.scope(jobs_marking(&flags));
        assert_eq!(sum, 16);
        assert!(flags
            .iter()
            .all(|f| f.load(Ordering::Relaxed) == 1));
        assert_eq!(pool.workers(), 2);
        // empty scope is a no-op
        assert_eq!(pool.scope(Vec::new()), 0);
    }

    #[test]
    fn scope_jobs_borrow_stack_data() {
        let pool = WorkerPool::new(3);
        let mut out = vec![0u32; 60];
        {
            let jobs: Vec<ScopeJob<'_>> = out
                .chunks_mut(20)
                .enumerate()
                .map(|(i, chunk)| {
                    Box::new(move || {
                        for (j, v) in chunk.iter_mut().enumerate() {
                            *v = (i * 20 + j) as u32;
                        }
                        0u64
                    }) as ScopeJob<'_>
                })
                .collect();
            pool.scope(jobs);
        }
        assert_eq!(out, (0u32..60).collect::<Vec<u32>>());
    }

    #[test]
    fn run_scoped_single_job_runs_inline() {
        let (spawns0, _) = work_counters();
        let here = std::thread::current().id();
        let ran = AtomicUsize::new(0);
        run_scoped(vec![Box::new(|| {
            assert_eq!(std::thread::current().id(), here);
            ran.fetch_add(1, Ordering::Relaxed);
            0
        })]);
        assert_eq!(ran.load(Ordering::Relaxed), 1);
        let (spawns1, _) = work_counters();
        assert_eq!(spawns1, spawns0, "single job must not dispatch");
    }

    #[test]
    fn nested_submit_runs_inline_on_workers() {
        let pool = WorkerPool::new(1);
        let hits = AtomicUsize::new(0);
        let jobs: Vec<ScopeJob<'_>> = (0..2)
            .map(|_| {
                let h = &hits;
                Box::new(move || {
                    assert!(in_worker());
                    // A nested run_scoped on a 1-worker pool would
                    // deadlock if it queued; it must run inline.
                    let nested: Vec<ScopeJob<'_>> = (0..3)
                        .map(|_| {
                            Box::new(move || {
                                h.fetch_add(1, Ordering::Relaxed);
                                0u64
                            }) as ScopeJob<'_>
                        })
                        .collect();
                    run_scoped(nested);
                    0u64
                }) as ScopeJob<'_>
            })
            .collect();
        pool.scope(jobs);
        assert_eq!(hits.load(Ordering::Relaxed), 6);
        assert!(!in_worker(), "flag is worker-local");
    }

    #[test]
    fn job_panic_propagates_to_submitter() {
        let pool = WorkerPool::new(2);
        let err = catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<ScopeJob<'_>> = vec![
                Box::new(|| 0u64),
                Box::new(|| panic!("job boom")),
                Box::new(|| 0u64),
            ];
            pool.scope(jobs);
        }))
        .expect_err("panic must cross the scope");
        let msg = err
            .downcast_ref::<&str>()
            .copied()
            .unwrap_or_default();
        assert_eq!(msg, "job boom");
        // the pool survives a panicked job
        assert_eq!(pool.scope(vec![Box::new(|| 7u64)]), 7);
    }

    #[test]
    fn scoped_fallback_counts_spawns_and_sums() {
        let (spawns0, _) = work_counters();
        let sum = scoped_fallback(vec![
            Box::new(|| 2u64),
            Box::new(|| 3u64),
        ]);
        assert_eq!(sum, 5);
        let (spawns1, _) = work_counters();
        assert_eq!(spawns1 - spawns0, 2);
    }

    #[test]
    fn drop_joins_workers_after_draining() {
        let flags: Vec<AtomicUsize> =
            (0..32).map(|_| AtomicUsize::new(0)).collect();
        {
            let pool = WorkerPool::new(2);
            pool.scope(jobs_marking(&flags));
        } // Drop: shutdown + join must not lose queued work
        assert!(flags
            .iter()
            .all(|f| f.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn threads_override_parses_or_panics() {
        assert_eq!(parse_threads_override(None), None);
        assert_eq!(parse_threads_override(Some("")), None);
        assert_eq!(parse_threads_override(Some("4")), Some(4));
        for bad in ["0", "-1", "lots", "4.5"] {
            let r = catch_unwind(|| parse_threads_override(Some(bad)));
            assert!(r.is_err(), "{bad:?} must hard-error");
        }
    }

    #[test]
    fn shards_override_parses_or_panics() {
        assert_eq!(parse_shards_override(None), None);
        assert_eq!(parse_shards_override(Some("")), None);
        assert_eq!(parse_shards_override(Some("2")), Some(2));
        for bad in ["0", "-1", "many", "1.5"] {
            let r = catch_unwind(|| parse_shards_override(Some(bad)));
            assert!(r.is_err(), "{bad:?} must hard-error");
        }
        // default_shards is env-driven; absent an override it is 1
        if std::env::var("PALLAS_SHARDS").map_or(true, |v| v.is_empty())
        {
            assert_eq!(default_shards(), 1);
        } else {
            assert_eq!(default_shards(), env_shards().unwrap());
        }
    }

    #[test]
    fn pick_task_prefers_hinted_then_fifo() {
        fn task(hint: Option<usize>) -> Task {
            Task {
                job: Box::new(|| 0u64),
                scope: Arc::new(ScopeState::new(1)),
                hint,
            }
        }
        // hinted-to-me (modulo pool size) beats FIFO order
        let mut q: VecDeque<Task> = VecDeque::new();
        q.push_back(task(Some(0)));
        q.push_back(task(Some(5))); // 5 % 4 == 1
        q.push_back(task(None));
        let t = pick_task(&mut q, 1, 4).unwrap();
        assert_eq!(t.hint, Some(5));
        // nothing hinted at me: plain FIFO, hints never strand work
        let t = pick_task(&mut q, 1, 4).unwrap();
        assert_eq!(t.hint, Some(0));
        let t = pick_task(&mut q, 1, 4).unwrap();
        assert_eq!(t.hint, None);
        assert!(pick_task(&mut q, 1, 4).is_none());
    }

    #[test]
    fn hinted_scope_runs_every_job_and_sums_metrics() {
        let pool = WorkerPool::new(2);
        let flags: Vec<AtomicUsize> =
            (0..16).map(|_| AtomicUsize::new(0)).collect();
        // hints far beyond the worker count reduce modulo pool size;
        // every job still runs exactly once
        let jobs: Vec<(usize, ScopeJob<'_>)> = flags
            .iter()
            .enumerate()
            .map(|(i, f)| {
                (
                    i * 7,
                    Box::new(move || {
                        f.fetch_add(1, Ordering::Relaxed);
                        1u64
                    }) as ScopeJob<'_>,
                )
            })
            .collect();
        assert_eq!(pool.scope_hinted(jobs), 16);
        assert!(flags
            .iter()
            .all(|f| f.load(Ordering::Relaxed) == 1));
        assert_eq!(pool.scope_hinted(Vec::new()), 0);
    }

    #[test]
    fn run_scoped_hinted_matches_run_scoped_on_every_path() {
        // single job: inline
        let here = std::thread::current().id();
        let (spawns0, _) = work_counters();
        let got = run_scoped_hinted(vec![(3, Box::new(|| {
            assert_eq!(std::thread::current().id(), here);
            5u64
        }))]);
        assert_eq!(got, 5);
        let (spawns1, _) = work_counters();
        assert_eq!(spawns1, spawns0, "single job must not dispatch");
        // multi-job: sums metrics like run_scoped (pooled or not)
        let sum = run_scoped_hinted(
            (0..6)
                .map(|i| {
                    (i, Box::new(move || i as u64) as ScopeJob<'_>)
                })
                .collect(),
        );
        assert_eq!(sum, 15);
    }

    #[test]
    fn pool_override_parses_or_panics() {
        assert_eq!(parse_pool_override(None), None);
        assert_eq!(parse_pool_override(Some("")), None);
        assert_eq!(parse_pool_override(Some("on")), Some(true));
        assert_eq!(parse_pool_override(Some("off")), Some(false));
        let r = catch_unwind(|| parse_pool_override(Some("maybe")));
        assert!(r.is_err());
    }

    #[test]
    fn ws_alloc_counter_is_thread_local_and_monotone() {
        let (_, ws0) = work_counters();
        note_ws_allocs(3);
        note_ws_allocs(0);
        let (_, ws1) = work_counters();
        assert_eq!(ws1 - ws0, 3);
        std::thread::spawn(|| {
            let (_, ws) = work_counters();
            assert_eq!(ws, 0, "fresh thread starts at zero");
        })
        .join()
        .unwrap();
    }
}
