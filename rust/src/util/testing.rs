//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `forall` runs a property over N seeded random cases and, on failure,
//! performs a bounded shrink search over the failing seed's generator
//! "size" parameter, reporting the smallest reproduction it finds.
//! Generators draw from a `Pcg64` handed to user closures, so arbitrary
//! structured inputs are easy to build.

use super::rng::Pcg64;

pub struct Gen<'a> {
    pub rng: &'a mut Pcg64,
    /// Size hint (shrinks toward 1 on failure).
    pub size: usize,
}

impl<'a> Gen<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let span = (hi - lo).min(self.size.max(1));
        lo + self.rng.below(span + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.uniform_f32()
    }

    pub fn vec_normal(&mut self, len: usize, sigma: f32) -> Vec<f32> {
        let mut v = vec![0.0; len];
        self.rng.fill_normal(&mut v, sigma);
        v
    }

    /// Heavy-tailed vector: normal body + sparse large outliers, the
    /// activation shape the paper targets (§4.1 P1–P3).
    pub fn vec_outliers(&mut self, len: usize, sigma: f32,
                        n_outliers: usize, magnitude: f32) -> Vec<f32> {
        let mut v = self.vec_normal(len, sigma);
        for _ in 0..n_outliers.min(len) {
            let i = self.rng.below(len);
            let sign = if self.rng.uniform() < 0.5 { -1.0 } else { 1.0 };
            v[i] = sign * magnitude * (0.5 + self.rng.uniform_f32());
        }
        v
    }
}

/// Run `prop` over `cases` random inputs. Panics with the failing seed
/// and smallest failing size on error.
pub fn forall<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5eed_0000 + case as u64;
        let mut rng = Pcg64::new(seed);
        let mut g = Gen { rng: &mut rng, size: 64 };
        if let Err(msg) = prop(&mut g) {
            // Shrink: retry the same seed with smaller size hints.
            let mut best: Option<(usize, String)> = None;
            for size in [1usize, 2, 4, 8, 16, 32] {
                let mut rng = Pcg64::new(seed);
                let mut g = Gen { rng: &mut rng, size };
                if let Err(m) = prop(&mut g) {
                    best = Some((size, m));
                    break;
                }
            }
            match best {
                Some((size, m)) => panic!(
                    "property '{name}' failed (seed={seed:#x}, \
                     shrunk size={size}): {m}"
                ),
                None => panic!(
                    "property '{name}' failed (seed={seed:#x}, size=64): \
                     {msg}"
                ),
            }
        }
    }
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

pub fn approx_eq(a: f32, b: f32, rtol: f32, atol: f32) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        forall("add-commutes", 50, |g| {
            let a = g.f32_in(-100.0, 100.0);
            let b = g.f32_in(-100.0, 100.0);
            prop_assert!(a + b == b + a, "{a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics() {
        forall("always-fails", 5, |g| {
            let n = g.usize_in(1, 100);
            prop_assert!(n == usize::MAX, "n={n}");
            Ok(())
        });
    }

    #[test]
    fn outlier_generator_has_outliers() {
        let mut rng = Pcg64::new(3);
        let mut g = Gen { rng: &mut rng, size: 64 };
        let v = g.vec_outliers(1024, 1.0, 8, 500.0);
        let big = v.iter().filter(|x| x.abs() > 100.0).count();
        assert!(big >= 4, "expected injected outliers, got {big}");
    }

    #[test]
    fn approx_eq_behaviour() {
        assert!(approx_eq(1.0, 1.0 + 1e-7, 1e-6, 0.0));
        assert!(!approx_eq(1.0, 1.1, 1e-6, 0.0));
        assert!(approx_eq(0.0, 1e-9, 0.0, 1e-8));
    }
}
