//! Deterministic RNG: PCG64 (XSL-RR) + SplitMix64 seeding.
//!
//! Built from scratch (no `rand` crate offline). Used for synthetic data,
//! stochastic rounding on the Rust side, and the property-test harness.
//! Streams are splittable so experiments are reproducible per-seed.

/// PCG-XSL-RR 128/64 — 128-bit state, 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into state/stream.
        let mut sm = SplitMix64(seed);
        let s = ((sm.next() as u128) << 64) | sm.next() as u128;
        let i = ((sm.next() as u128) << 64) | sm.next() as u128;
        let mut rng = Pcg64 { state: 0, inc: (i << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(s);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent stream (for per-layer / per-step splits).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a buffer with N(0, sigma).
    pub fn fill_normal(&mut self, buf: &mut [f32], sigma: f32) {
        for v in buf.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }
}

/// SplitMix64 — seeding helper and cheap standalone generator.
#[derive(Clone, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Pcg64::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut rng = Pcg64::new(13);
        for _ in 0..1000 {
            assert!(rng.below(10) < 10);
        }
        // all values reachable
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[rng.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(17);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::new(23);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
