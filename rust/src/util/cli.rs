//! Tiny CLI argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generates usage text from registered options.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
}

impl Args {
    /// Parse argv (after the program name). `known_flags` lists options
    /// that take no value.
    pub fn parse(argv: &[String], known_flags: &[&str]) -> Result<Args, String> {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&rest) {
                    a.flags.push(rest.to_string());
                } else if i + 1 < argv.len() {
                    a.opts.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    return Err(format!("option --{rest} needs a value"));
                }
            } else {
                a.pos.push(arg.clone());
            }
            i += 1;
        }
        Ok(a)
    }

    pub fn from_env(known_flags: &[&str]) -> Result<Args, String> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, known_flags)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: bad usize '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: bad u64 '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: bad f64 '{v}'")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = Args::parse(
            &sv(&["run", "--steps", "10", "--fast", "--lr=0.5", "out.json"]),
            &["fast"],
        )
        .unwrap();
        assert_eq!(a.positional(), &["run", "out.json"]);
        assert_eq!(a.get_usize("steps", 0), 10);
        assert_eq!(a.get_f64("lr", 0.0), 0.5);
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&[]), &[]).unwrap();
        assert_eq!(a.get_or("mode", "fallback"), "fallback");
        assert_eq!(a.get_usize("steps", 7), 7);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["--steps"]), &[]).is_err());
    }
}
