//! Scoped data-parallel helpers (no `rayon` offline).
//!
//! `parallel_chunks` splits an index range across worker threads using
//! `std::thread::scope`. On single-core hosts (like this testbed) it
//! degrades to a serial loop with zero thread overhead; the GEMM hot
//! paths call through here so multi-core machines scale transparently.

/// Number of worker threads to use (cores, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Run `f(start, end)` over disjoint chunks of `0..n` in parallel.
///
/// `f` must be `Sync` and side-effect-free across chunks (each chunk
/// owns its output range; callers split mutable buffers beforehand).
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads <= 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let fr = &f;
            s.spawn(move || fr(start, end));
        }
    });
}

/// Map `f` over `0..n`, collecting results in index order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_chunks(n, threads, |a, b| {
            for i in a..b {
                **slots[i].lock().unwrap() = f(i);
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_serial() {
        let hits = AtomicUsize::new(0);
        parallel_chunks(100, 1, |a, b| {
            hits.fetch_add(b - a, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn covers_all_indices_parallel() {
        let flags: Vec<AtomicUsize> =
            (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(1000, 8, |a, b| {
            for i in a..b {
                flags[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(flags.iter().all(|f| f.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_range_ok() {
        parallel_chunks(0, 4, |_, _| panic!("must not run"));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(64, 4, |i| i * i);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }
}
