//! Scoped data-parallel helpers (no `rayon` offline).
//!
//! `parallel_chunks` splits an index range across worker threads and
//! dispatches through the persistent runtime in [`crate::util::pool`]
//! (parked workers, `thread::scope` only as the `PALLAS_POOL=off`
//! fallback). On single-core hosts (like this testbed) it degrades to
//! a serial loop with zero dispatch overhead; the GEMM hot paths call
//! through here so multi-core machines scale transparently. Chunk
//! boundaries are `n.div_ceil(threads)`-sized regardless of dispatch
//! path, so results never depend on where the chunks run.

use crate::util::pool::{self, ScopeJob};

/// Number of worker threads to use: the `PALLAS_THREADS` override
/// when set (hard error on invalid values — see
/// [`pool::parse_threads_override`]), else cores, capped.
pub fn default_threads() -> usize {
    if let Some(n) = pool::env_threads() {
        return n;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Run `f(start, end)` over disjoint chunks of `0..n` in parallel.
///
/// `f` must be `Sync` and side-effect-free across chunks (each chunk
/// owns its output range; callers split mutable buffers beforehand).
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads <= 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    let fr = &f;
    let tasks: Vec<ScopeJob<'_>> = (0..threads)
        .map(|t| (t * chunk, ((t + 1) * chunk).min(n)))
        .take_while(|&(start, end)| start < end)
        .map(|(start, end)| {
            Box::new(move || {
                fr(start, end);
                0u64
            }) as ScopeJob<'_>
        })
        .collect();
    pool::run_scoped(tasks);
}

/// Partition `weights.len()` items into at most `threads` buckets with
/// balanced total weight, using greedy LPT (longest-processing-time)
/// assignment: items are visited heaviest-first and each goes to the
/// currently lightest bucket.
///
/// Deterministic: weight ties visit the lower index first, and load
/// ties pick the lower bucket index; each bucket's item list is
/// returned sorted ascending (cache-friendly sweep order). The GEMM
/// engine uses this to balance fallback-heavy C row panels (paper
/// Fig 8c, Sequential placement) across workers. Under sharded
/// execution (`PALLAS_SHARDS`) the engine calls this once *per shard*
/// with that shard's slice of the thread budget — the weights are
/// column-independent, so every shard balances the same row-chunk
/// costs over its own worker subset (`costmodel::sharded_makespan`
/// projects the resulting makespan without building a plan).
pub fn weighted_buckets(weights: &[f64], threads: usize) -> Vec<Vec<usize>> {
    let n = weights.len();
    let threads = threads.clamp(1, n.max(1));
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); threads];
    if n == 0 {
        return buckets;
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        weights[b]
            .partial_cmp(&weights[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut load = vec![0.0f64; threads];
    for i in order {
        let mut t = 0usize;
        for (j, &l) in load.iter().enumerate().skip(1) {
            if l < load[t] {
                t = j;
            }
        }
        buckets[t].push(i);
        load[t] += weights[i].max(0.0);
    }
    for b in &mut buckets {
        b.sort_unstable();
    }
    buckets
}

/// Distribute owned work items across threads: `f(i, item)` runs
/// exactly once per item, in ascending index order within each chunk,
/// with the index range split exactly like [`parallel_chunks`]. Each
/// worker receives a contiguous **owned run** of items (the input is
/// split with `Vec::split_off` and the runs moved into the jobs) —
/// no per-item locking, no aliasing, no `unsafe`. Items are handed
/// out *by value*, which lets callers pre-split disjoint `&mut`
/// output regions (e.g. with `chunks_mut`) and move each into its
/// worker. The quant constructors use this to parallelize block-row
/// quantization.
pub fn parallel_items<T, F>(items: Vec<T>, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads <= 1 {
        for (i, item) in items.into_iter().enumerate() {
            f(i, item);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    let fr = &f;
    let mut tasks: Vec<ScopeJob<'_>> = Vec::with_capacity(threads);
    let mut rest = items;
    let mut base = 0usize;
    while !rest.is_empty() {
        let take = chunk.min(rest.len());
        let tail = rest.split_off(take);
        let run = std::mem::replace(&mut rest, tail);
        tasks.push(Box::new(move || {
            for (j, item) in run.into_iter().enumerate() {
                fr(base + j, item);
            }
            0u64
        }));
        base += take;
    }
    pool::run_scoped(tasks);
}

/// Map `f` over `0..n`, collecting results in index order. Built on
/// [`parallel_items`] over disjoint `chunks_mut` runs of the output
/// — lock-free like the other helpers.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    if n == 0 {
        return out;
    }
    let chunk = n.div_ceil(threads.clamp(1, n));
    {
        let items: Vec<(usize, &mut [T])> =
            out.chunks_mut(chunk).enumerate().collect();
        parallel_items(items, threads, |_, (ci, run)| {
            for (j, v) in run.iter_mut().enumerate() {
                *v = f(ci * chunk + j);
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_serial() {
        let hits = AtomicUsize::new(0);
        parallel_chunks(100, 1, |a, b| {
            hits.fetch_add(b - a, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn covers_all_indices_parallel() {
        let flags: Vec<AtomicUsize> =
            (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(1000, 8, |a, b| {
            for i in a..b {
                flags[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(flags.iter().all(|f| f.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_range_ok() {
        parallel_chunks(0, 4, |_, _| panic!("must not run"));
    }

    #[test]
    fn items_consumed_exactly_once() {
        let data: Vec<usize> = (0..100).collect();
        let hits: Vec<AtomicUsize> =
            (0..100).map(|_| AtomicUsize::new(0)).collect();
        parallel_items(data, 4, |i, v| {
            assert_eq!(i, v, "index/item pairing");
            hits[v].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // empty input is a no-op
        parallel_items(Vec::<usize>::new(), 4, |_, _| {
            panic!("must not run")
        });
    }

    #[test]
    fn items_carry_disjoint_mut_slices() {
        let mut buf = vec![0u32; 64];
        {
            let items: Vec<_> = buf.chunks_mut(16).collect();
            parallel_items(items, 3, |i, chunk| {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (i * 16 + j) as u32;
                }
            });
        }
        assert_eq!(buf, (0u32..64).collect::<Vec<u32>>());
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(64, 4, |i| i * i);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_buckets_cover_and_balance() {
        // Sequential-placement shape: two heavy panels up front.
        let w = [2.0, 2.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let buckets = weighted_buckets(&w, 2);
        let mut all: Vec<usize> = buckets.concat();
        all.sort_unstable();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
        let loads: Vec<f64> = buckets
            .iter()
            .map(|b| b.iter().map(|&i| w[i]).sum())
            .collect();
        // LPT splits 10.0 of work into 5.0 + 5.0; contiguous halves
        // would give 7.0 + 3.0.
        assert_eq!(loads, vec![5.0, 5.0]);
    }

    #[test]
    fn weighted_buckets_deterministic_and_clamped() {
        let w = [1.0; 5];
        assert_eq!(weighted_buckets(&w, 2), weighted_buckets(&w, 2));
        // more threads than items: each bucket holds at most one item
        let b = weighted_buckets(&w, 16);
        assert_eq!(b.len(), 5);
        assert!(b.iter().all(|x| x.len() == 1));
        assert!(weighted_buckets(&[], 4).iter().all(|x| x.is_empty()));
    }

    #[test]
    fn weighted_buckets_partition_any_thread_count() {
        let w: Vec<f64> = (0..100).map(|i| 1.0 + (i % 7) as f64).collect();
        for threads in [1, 2, 4, 13] {
            let buckets = weighted_buckets(&w, threads);
            let mut all: Vec<usize> = buckets.concat();
            all.sort_unstable();
            assert_eq!(all, (0..100).collect::<Vec<_>>(),
                       "threads={threads}");
        }
    }
}
