//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Measures wall-clock with warmup, adaptive iteration count, and robust
//! statistics (median + MAD). Bench binaries are registered in
//! `Cargo.toml` with `harness = false` and print the paper's
//! table/figure rows directly.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub mad_ns: f64,
}

impl Stats {
    pub fn median_secs(&self) -> f64 {
        self.median_ns / 1e9
    }
}

/// Time `f`, autoscaling iterations to hit ~`target_ms` of total runtime.
pub fn bench<F: FnMut()>(mut f: F, target_ms: u64) -> Stats {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let budget = (target_ms as f64) * 1e6;
    let iters = ((budget / once) as usize).clamp(3, 1000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples[0];
    let mut devs: Vec<f64> =
        samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    Stats { iters, median_ns: median, mean_ns: mean, min_ns: min, mad_ns: mad }
}

/// GEMM throughput in Gops (2*M*N*K ops per multiply-accumulate pair).
pub fn gops(m: usize, n: usize, k: usize, secs: f64) -> f64 {
    (2.0 * m as f64 * n as f64 * k as f64) / secs / 1e9
}

/// Simple fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>()
                                  + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let s = bench(
            || {
                for i in 0..10_000u64 {
                    x = x.wrapping_add(i * i);
                }
            },
            20,
        );
        assert!(s.median_ns > 0.0);
        assert!(s.iters >= 3);
        assert!(s.min_ns <= s.median_ns);
        std::hint::black_box(x);
    }

    #[test]
    fn gops_math() {
        let g = gops(1000, 1000, 1000, 1.0);
        assert!((g - 2.0).abs() < 1e-9);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // smoke: no panic
    }
}
