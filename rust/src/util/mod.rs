//! From-scratch substrates: JSON, CLI, RNG, thread pool, bench harness,
//! property-testing. Only `xla` and `anyhow` are available offline, so
//! everything else the framework needs lives here.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod rng;
pub mod testing;
pub mod threadpool;

/// Matrix in row-major order — the shared tensor currency of the
/// quant/gemm/outlier modules.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Reshape to `rows × cols`, zero-filled, reusing the existing
    /// backing buffer when its capacity allows. Returns whether the
    /// buffer had to grow (i.e. whether this call allocated) — the
    /// engine's `execute_into` reports that through the runtime's
    /// workspace-allocation counter, so steady-state reuse is
    /// observable (`util::pool::work_counters`).
    pub fn reset_to(&mut self, rows: usize, cols: usize) -> bool {
        let need = rows * cols;
        let grew = self.data.capacity() < need;
        self.data.clear();
        self.data.resize(need, 0.0);
        self.rows = rows;
        self.cols = cols;
        grew
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize,
                   mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    pub fn randn(rows: usize, cols: usize, sigma: f32,
                 rng: &mut rng::Pcg64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, sigma);
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>()
            .sqrt() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_basics() {
        let m = Mat::from_fn(2, 3, |r, c| (r * 3 + c) as f32);
        assert_eq!(m.at(1, 2), 5.0);
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.at(2, 1), 5.0);
        assert_eq!(m.abs_max(), 5.0);
    }

    #[test]
    fn frob_norm() {
        let m = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((m.frob_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn reset_to_reuses_capacity_and_zeroes() {
        let mut m = Mat::from_vec(2, 3, vec![1.0; 6]);
        assert!(!m.reset_to(3, 2), "same size must not grow");
        assert_eq!((m.rows, m.cols), (3, 2));
        assert!(m.data.iter().all(|&x| x == 0.0));
        let mut small = Mat::zeros(0, 0);
        assert!(small.reset_to(2, 2), "growing is an allocation");
        assert!(!small.reset_to(1, 1), "shrinking reuses");
        assert_eq!(small.data.len(), 1);
    }
}
