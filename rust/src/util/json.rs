//! Minimal JSON parser and writer.
//!
//! Built from scratch because the offline crate set has no `serde`
//! facade. Parses the artifact manifest and config files; writes metrics
//! and experiment reports. Supports the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, bools, null); numbers are kept
//! as f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn parse_file(path: &str) -> Result<Json, String> {
        let s = std::fs::read_to_string(path)
            .map_err(|e| format!("read {path}: {e}"))?;
        Json::parse(&s).map_err(|e| format!("parse {path}: {e}"))
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that panics with a useful message — for required fields.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing JSON key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers → owned `Vec<f64>`; `None` when `self` is not
    /// an array or any element is not a number.
    pub fn to_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64())
            .collect()
    }

    /// Write the serialized value to `path` (warm-state files, bench
    /// reports).
    pub fn to_file(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.to_string())
            .map_err(|e| format!("write {path}: {e}"))
    }

    // -- writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.i, other
                    ))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.i, other
                    ))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            // Surrogate pairs are rare in our data; map
                            // lone surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => {
                            return Err(format!("bad escape {:?}", other))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| "invalid utf-8 in string")?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{txt}': {e}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(),
                   Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#)
            .unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.req("a").as_arr().unwrap()[2].req("b").as_str(),
                   Some("x"));
        assert_eq!(j.req("c").as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",null,true],"n":{"x":-1}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn writer_escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn number_array_accessor() {
        let j = arr_f64(&[1.0, -2.5, 0.0]);
        assert_eq!(j.to_f64_vec(), Some(vec![1.0, -2.5, 0.0]));
        // non-arrays and mixed arrays refuse
        assert_eq!(Json::Num(1.0).to_f64_vec(), None);
        let mixed = Json::parse(r#"[1, "x"]"#).unwrap();
        assert_eq!(mixed.to_f64_vec(), None);
    }
}
