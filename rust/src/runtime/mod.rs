//! PJRT runtime: load AOT HLO-text artifacts and execute them from Rust.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`). Artifacts
//! and their I/O signatures come from `artifacts/manifest.json` written
//! by `python/compile/aot.py`; executables are compiled lazily and
//! cached. The hot training loop keeps large state (params, Adam
//! moments) resident as `PjRtBuffer`s and feeds outputs straight back as
//! inputs, so per-step host↔device copies are limited to the small
//! tensors (tokens, θ, scalars) — see EXPERIMENTS.md §Perf.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

pub mod value;

pub use value::Value;

/// Signature of one artifact, from the manifest.
#[derive(Debug, Clone)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// One leaf of a model profile's flat parameter vector.
#[derive(Debug, Clone)]
pub struct ParamLeaf {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Model profile metadata (mirrors `aot.PROFILES`).
#[derive(Debug, Clone)]
pub struct ProfileMeta {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub glu: bool,
    pub batch: usize,
    pub block: usize,
    pub group: usize,
    pub n_params: usize,
    pub n_sites: usize,
    pub param_layout: Vec<ParamLeaf>,
}

fn tensor_sigs(j: &Json) -> Result<Vec<TensorSig>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("sig list"))?
        .iter()
        .map(|t| {
            Ok(TensorSig {
                name: t.req("name").as_str().unwrap_or("").to_string(),
                shape: t
                    .req("shape")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                dtype: t.req("dtype").as_str().unwrap_or("").to_string(),
            })
        })
        .collect()
}

/// The artifact registry + PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub artifacts: HashMap<String, ArtifactSig>,
    pub profiles: HashMap<String, ProfileMeta>,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open `dir` (usually `artifacts/`) and parse its manifest.
    pub fn open(dir: &str) -> Result<Runtime> {
        let dir = PathBuf::from(dir);
        let manifest = Json::parse_file(
            dir.join("manifest.json").to_str().unwrap(),
        )
        .map_err(|e| anyhow!("manifest: {e}"))?;

        let mut artifacts = HashMap::new();
        for (name, a) in manifest
            .req("artifacts")
            .as_obj()
            .ok_or_else(|| anyhow!("artifacts obj"))?
        {
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    file: a.req("file").as_str().unwrap().to_string(),
                    inputs: tensor_sigs(a.req("inputs"))?,
                    outputs: tensor_sigs(a.req("outputs"))?,
                },
            );
        }

        let mut profiles = HashMap::new();
        for (name, p) in manifest
            .req("profiles")
            .as_obj()
            .ok_or_else(|| anyhow!("profiles obj"))?
        {
            let m = p.req("model");
            let layout = p
                .req("param_layout")
                .as_arr()
                .unwrap()
                .iter()
                .map(|l| ParamLeaf {
                    name: l.req("name").as_str().unwrap().to_string(),
                    shape: l
                        .req("shape")
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|d| d.as_usize().unwrap())
                        .collect(),
                    offset: l.req("offset").as_usize().unwrap(),
                    size: l.req("size").as_usize().unwrap(),
                })
                .collect();
            profiles.insert(
                name.clone(),
                ProfileMeta {
                    name: name.clone(),
                    vocab: m.req("vocab").as_usize().unwrap(),
                    d_model: m.req("d_model").as_usize().unwrap(),
                    n_layers: m.req("n_layers").as_usize().unwrap(),
                    n_heads: m.req("n_heads").as_usize().unwrap(),
                    d_ff: m.req("d_ff").as_usize().unwrap(),
                    seq_len: m.req("seq_len").as_usize().unwrap(),
                    glu: m.req("glu").as_bool().unwrap_or(true),
                    batch: p.req("batch").as_usize().unwrap(),
                    block: p.req("block").as_usize().unwrap(),
                    group: p.req("group").as_usize().unwrap(),
                    n_params: p.req("n_params").as_usize().unwrap(),
                    n_sites: p.req("n_sites").as_usize().unwrap(),
                    param_layout: layout,
                },
            );
        }

        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            artifacts,
            profiles,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn profile(&self, name: &str) -> Result<&ProfileMeta> {
        self.profiles
            .get(name)
            .ok_or_else(|| anyhow!("unknown profile '{name}'"))
    }

    pub fn signature(&self, name: &str) -> Result<&ArtifactSig> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn load(&self, name: &str)
                -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let sig = self.signature(name)?;
        let path = self.dir.join(&sig.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().unwrap(),
        )
        .map_err(|e| anyhow!("parse HLO {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let rc = std::rc::Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Execute by artifact name with host values; returns host values.
    ///
    /// Inputs are validated against the manifest signature. The lowered
    /// modules return a single tuple (return_tuple=True), which is
    /// unpacked into one `Value` per declared output.
    pub fn call(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let sig = self.signature(name)?.clone();
        if inputs.len() != sig.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                sig.inputs.len(),
                inputs.len()
            );
        }
        for (v, s) in inputs.iter().zip(&sig.inputs) {
            if v.shape() != s.shape.as_slice() {
                bail!(
                    "{name}: input '{}' shape {:?} != expected {:?}",
                    s.name,
                    v.shape(),
                    s.shape
                );
            }
        }
        let exe = self.load(name)?;
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        let out = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if parts.len() != sig.outputs.len() {
            bail!(
                "{name}: {} outputs returned, manifest says {}",
                parts.len(),
                sig.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&sig.outputs)
            .map(|(lit, s)| Value::from_literal(&lit, &s.shape, &s.dtype))
            .collect()
    }
}

/// Locate the artifacts directory: `$DBFQ_ARTIFACTS`, `./artifacts`, or
/// relative to the crate root (tests run from the workspace root).
pub fn artifacts_dir() -> String {
    if let Ok(d) = std::env::var("DBFQ_ARTIFACTS") {
        return d;
    }
    for cand in ["artifacts", "../artifacts"] {
        if Path::new(cand).join("manifest.json").exists() {
            return cand.to_string();
        }
    }
    "artifacts".to_string()
}

#[cfg(test)]
mod tests {
    // Runtime tests that need real artifacts live in rust/tests/ (they
    // require `make artifacts` first); pure manifest parsing is here.
    use super::*;

    #[test]
    fn tensor_sig_parse() {
        let j = Json::parse(
            r#"[{"name":"x","shape":[2,3],"dtype":"float32"}]"#,
        )
        .unwrap();
        let sigs = tensor_sigs(&j).unwrap();
        assert_eq!(sigs[0].name, "x");
        assert_eq!(sigs[0].shape, vec![2, 3]);
        assert_eq!(sigs[0].dtype, "float32");
    }
}
