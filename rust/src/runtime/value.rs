//! Host-side tensor values marshalled to/from `xla::Literal`.

use anyhow::{anyhow, bail, Result};

/// A host tensor: f32 or i32, with explicit shape (row-major).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Value {
    pub fn scalar_f32(v: f32) -> Value {
        Value::F32(vec![v], vec![])
    }

    pub fn scalar_i32(v: i32) -> Value {
        Value::I32(vec![v], vec![])
    }

    pub fn vec_f32(v: Vec<f32>) -> Value {
        let n = v.len();
        Value::F32(v, vec![n])
    }

    pub fn mat_f32(v: Vec<f32>, rows: usize, cols: usize) -> Value {
        assert_eq!(v.len(), rows * cols);
        Value::F32(v, vec![rows, cols])
    }

    pub fn mat_i32(v: Vec<i32>, rows: usize, cols: usize) -> Value {
        assert_eq!(v.len(), rows * cols);
        Value::I32(v, vec![rows, cols])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(_, s) | Value::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Value::F32(d, _) => d.len(),
            Value::I32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(d, _) => Ok(d),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32(d, _) => Ok(d),
            _ => bail!("expected i32 value"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Value::F32(d, _) => Ok(d),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got {} elements", d.len());
        }
        Ok(d[0])
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64>;
        let lit = match self {
            Value::F32(d, s) => {
                dims = s.iter().map(|&x| x as i64).collect();
                xla::Literal::vec1(d.as_slice())
            }
            Value::I32(d, s) => {
                dims = s.iter().map(|&x| x as i64).collect();
                xla::Literal::vec1(d.as_slice())
            }
        };
        lit.reshape(&dims)
            .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))
    }

    pub fn from_literal(lit: &xla::Literal, shape: &[usize],
                        dtype: &str) -> Result<Value> {
        match dtype {
            "float32" => {
                let v = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("literal->f32: {e:?}"))?;
                Ok(Value::F32(v, shape.to_vec()))
            }
            "int32" => {
                let v = lit
                    .to_vec::<i32>()
                    .map_err(|e| anyhow!("literal->i32: {e:?}"))?;
                Ok(Value::I32(v, shape.to_vec()))
            }
            other => bail!("unsupported artifact dtype '{other}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let v = Value::mat_f32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        let lit = v.to_literal().unwrap();
        let back = Value::from_literal(&lit, &[2, 3], "float32").unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let v = Value::mat_i32(vec![1, -2, 3, 4], 2, 2);
        let lit = v.to_literal().unwrap();
        let back = Value::from_literal(&lit, &[2, 2], "int32").unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn scalar_helpers() {
        let s = Value::scalar_f32(2.5);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(s.scalar().unwrap(), 2.5);
        assert!(Value::vec_f32(vec![1.0, 2.0]).scalar().is_err());
        assert!(Value::scalar_i32(1).as_f32().is_err());
    }
}
