//! Exact references for the INT4 data path and the staged
//! Int4→Int8→f32 precision lattice.
//!
//! Same role as the `*_reference` oracles in [`super::int8`]: i64
//! block dots (exact for any block size), widened to f32 once per
//! K-block, then the engine's per-block scale-FMA order — so within
//! [`engine::I4_EXACT_MAX_BS`](super::I4_EXACT_MAX_BS) the engine
//! must match these **bitwise** on every backend, thread count, and
//! shard count. The f32-tier term of the staged reference replays the
//! v2 kernel contract (one `mul_add` per K step, ascending, over the
//! full padded block range) so even that term is bit-identical to the
//! `panel_dot` kernels.

use crate::quant::{BlockQuant, StagedQuant};
use crate::util::Mat;

/// Exact-integer reference for an INT4 block GEMM: both operands
/// carry codes in [-7, 7] (quantized at `INT4_LEVELS`), accumulated
/// in i64 per K-block. Bit-identical to
/// `GemmPlan::new_int8_path(.., DataPath::Int4)` — the engine reads
/// the same codes through the nibble panels — and to the SimF32 path
/// over the same operands.
pub fn int4_gemm_reference(a: &BlockQuant, b: &BlockQuant) -> Mat {
    let bs = a.block;
    let (m, n) = (a.rows, b.cols);
    let kb = a.cb();
    let nbk = b.cb();
    let mut c = Mat::zeros(m, n);
    for r in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for bk in 0..kb {
                let mut iacc = 0i64;
                for k in bk * bs..((bk + 1) * bs).min(a.cols) {
                    iacc += a.q[r * a.pcols + k] as i64
                        * b.q[k * b.pcols + j] as i64;
                }
                acc += iacc as f32
                    * (a.scale[(r / bs) * kb + bk]
                       * b.scale[bk * nbk + j / bs]);
            }
            c.data[r * n + j] = acc;
        }
    }
    c
}

/// Exact reference for the staged lattice GEMM
/// (`GemmPlan::new_staged`): per K-block, the INT4 base dot, then the
/// INT8 residual dot where `u8_mask` promotes, then the f32 remainder
/// where `uf_mask` promotes — the engine's exact term order. The two
/// integer dots accumulate in i64; the f32 term chains one `mul_add`
/// per K step over the **full padded** block range, exactly as
/// `panel_dot` streams the zero-padded panels, so the bits agree even
/// through the padding.
pub fn staged_gemm_reference(sa: &StagedQuant, b: &BlockQuant) -> Mat {
    let a = &sa.base;
    let bs = a.block;
    let (m, n) = (a.rows, b.cols);
    let kb = a.cb();
    let nbk = b.cb();
    let mut c = Mat::zeros(m, n);
    for r in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for bk in 0..kb {
                let bi = (r / bs) * kb + bk;
                let sb = b.scale[bk * nbk + j / bs];
                let mut base_i = 0i64;
                let mut res_i = 0i64;
                for k in bk * bs..((bk + 1) * bs).min(a.cols) {
                    let bq = b.q[k * b.pcols + j] as i64;
                    base_i += a.q[r * a.pcols + k] as i64 * bq;
                    res_i += sa.rq[r * a.pcols + k] as i64 * bq;
                }
                acc += base_i as f32 * (a.scale[bi] * sb);
                if sa.u8_mask[bi] {
                    acc += res_i as f32 * (sa.rscale[bi] * sb);
                }
                if sa.uf_mask[bi] {
                    let mut s = 0.0f32;
                    for k in bk * bs..(bk + 1) * bs {
                        s = sa.r2[r * a.pcols + k].mul_add(
                            b.q[k * b.pcols + j] as f32, s);
                    }
                    acc += s * sb;
                }
            }
            c.data[r * n + j] = acc;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::metrics::rel_err;
    use crate::quant::{block_quant, staged_quant, Rounding,
                       INT4_LEVELS};
    use crate::util::rng::Pcg64;

    fn mats(m: usize, k: usize, n: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Pcg64::new(seed);
        (Mat::randn(m, k, 1.0, &mut rng),
         Mat::randn(k, n, 1.0, &mut rng))
    }

    #[test]
    fn int4_reference_approximates_dense() {
        // sanity anchor: 4-bit quantization is coarse but not broken
        let (a, b) = mats(32, 48, 24, 7);
        let qa = block_quant(&a, 16, INT4_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 16, INT4_LEVELS, Rounding::Nearest);
        let c = int4_gemm_reference(&qa, &qb);
        let exact = crate::gemm::dense::matmul_naive(&a, &b);
        let re = rel_err(&exact.data, &c.data);
        assert!(re < 0.2, "rel err {re}");
    }

    #[test]
    fn staged_reference_tracks_dequant_product() {
        // The staged ladder's reference must agree with the dense
        // product of the dequantized operands to f32 roundoff: every
        // term it adds is exactly a block of dequant(A)·dequant(B).
        let mut rng = Pcg64::new(11);
        let mut a = Mat::randn(32, 48, 1.0, &mut rng);
        for i in 0..9 {
            a.data[i * 131 % a.data.len()] = 40.0 * (i as f32 - 4.0);
        }
        let b = Mat::randn(48, 24, 1.0, &mut rng);
        let sa = staged_quant(&a, 2.0, 16);
        assert!(sa.rate_i8() > 0.0, "no promoted blocks");
        let qb = block_quant(&b, 16, INT4_LEVELS, Rounding::Nearest);
        let c = staged_gemm_reference(&sa, &qb);
        let da = sa.dequant();
        let db = qb.dequant();
        let exact = crate::gemm::dense::matmul_naive(&da, &db);
        let re = rel_err(&exact.data, &c.data);
        // residual tiers shrink the error far below the pure-i4 level
        let pure = int4_gemm_reference(&sa.base, &qb);
        let re4 = rel_err(&exact.data, &pure.data);
        assert!(re < re4, "staged {re} not better than pure i4 {re4}");
        assert!(re < 0.05, "rel err {re}");
    }
}
