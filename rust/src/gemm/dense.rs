//! Dense f32 reference GEMM — the "BF16 baseline" of the kernel benches.
//!
//! `matmul` is a thin wrapper over the plan/execute engine
//! (`gemm::engine`, `Precision::Dense`). The pre-engine row-parallel
//! kernel is retained verbatim as [`matmul_baseline`]: it is the
//! before/after comparison point of `benches/gemm_engine.rs` and the
//! bit-identity oracle of `tests/engine_prop.rs`.

use crate::gemm::engine::GemmPlan;
use crate::gemm::kernels::{fma1_into, fma4_into};
use crate::util::threadpool::parallel_chunks;
use crate::util::Mat;

/// C = A (M x K) * B (K x N), f32, cache-blocked with 4-wide unroll.
/// Plans and executes through the engine; output is bit-identical to
/// [`matmul_baseline`] for every thread count.
pub fn matmul(a: &Mat, b: &Mat, threads: usize) -> Mat {
    GemmPlan::new_dense(a, b, threads).execute()
}

/// Retained pre-engine implementation: row panels distributed by
/// contiguous chunking, output rows written through a raw pointer.
/// Kept as the honest baseline the engine is measured against. Its
/// inner kernel follows the **v2 f32 op-order contract** (per-lane
/// sequential FMA, ascending K — see `gemm::kernels`); the v1 seed
/// order is retained under test as the bridge oracle.
pub fn matmul_baseline(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    let cdata = std::sync::atomic::AtomicPtr::new(c.data.as_mut_ptr());

    parallel_chunks(m, threads, |r0, r1| {
        let cptr = cdata.load(std::sync::atomic::Ordering::Relaxed);
        for r in r0..r1 {
            let arow = &a.data[r * k..(r + 1) * k];
            // SAFETY: each thread writes disjoint rows of C.
            let crow = unsafe {
                std::slice::from_raw_parts_mut(cptr.add(r * n), n)
            };
            matvec_row(arow, b, crow);
        }
    });
    c
}

/// crow += arow * B under the v2 f32 op-order contract (per-lane
/// sequential FMA over ascending K, vectorized through the shared
/// `gemm::kernels` primitives). Shared by the baseline above and the
/// engine's dense single-row path — one authoritative kernel keeps
/// them bit-identical by construction.
#[inline]
pub(crate) fn matvec_row(arow: &[f32], b: &Mat, crow: &mut [f32]) {
    let n = b.cols;
    let k = b.rows;
    let crow = &mut crow[..n];
    let kk = k & !3;
    for kb in (0..kk).step_by(4) {
        fma4_into(
            [arow[kb], arow[kb + 1], arow[kb + 2], arow[kb + 3]],
            &b.data[kb * n..(kb + 1) * n],
            &b.data[(kb + 1) * n..(kb + 2) * n],
            &b.data[(kb + 2) * n..(kb + 3) * n],
            &b.data[(kb + 3) * n..(kb + 4) * n],
            crow,
        );
    }
    for kb in kk..k {
        fma1_into(arow[kb], &b.data[kb * n..(kb + 1) * n], crow);
    }
}

/// Naive triple loop — correctness oracle for the optimized paths.
pub fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    let mut c = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for kk in 0..a.cols {
            let av = a.at(i, kk);
            for j in 0..b.cols {
                c.data[i * b.cols + j] += av * b.at(kk, j);
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::util::testing::max_abs_diff;

    #[test]
    fn matches_naive() {
        let mut rng = Pcg64::new(1);
        for (m, k, n) in [(7, 9, 5), (16, 16, 16), (33, 65, 17)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let c1 = matmul(&a, &b, 1);
            let c2 = matmul_naive(&a, &b);
            assert!(max_abs_diff(&c1.data, &c2.data) < 1e-3,
                    "({m},{k},{n})");
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Pcg64::new(2);
        let a = Mat::randn(64, 48, 1.0, &mut rng);
        let b = Mat::randn(48, 32, 1.0, &mut rng);
        let c1 = matmul(&a, &b, 1);
        let c4 = matmul(&a, &b, 4);
        assert_eq!(c1.data, c4.data);
    }

    #[test]
    fn identity() {
        let mut rng = Pcg64::new(3);
        let a = Mat::randn(8, 8, 1.0, &mut rng);
        let eye = Mat::from_fn(8, 8, |r, c| (r == c) as u32 as f32);
        let c = matmul(&a, &eye, 1);
        assert_eq!(c.data, a.data);
    }

    /// The v1 (seed) dense row kernel, retained verbatim as the
    /// bridge oracle for the v2 re-anchor.
    fn matvec_row_v1(arow: &[f32], b: &Mat, crow: &mut [f32]) {
        let n = b.cols;
        let k = b.rows;
        let kk = k & !3;
        for kb in (0..kk).step_by(4) {
            let a0 = arow[kb];
            let a1 = arow[kb + 1];
            let a2 = arow[kb + 2];
            let a3 = arow[kb + 3];
            let b0 = &b.data[kb * n..(kb + 1) * n];
            let b1 = &b.data[(kb + 1) * n..(kb + 2) * n];
            let b2 = &b.data[(kb + 2) * n..(kb + 3) * n];
            let b3 = &b.data[(kb + 3) * n..(kb + 4) * n];
            for j in 0..n {
                crow[j] +=
                    a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
        }
        for kb in kk..k {
            let av = arow[kb];
            let brow = &b.data[kb * n..(kb + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }

    #[test]
    fn v2_bridge_bounds_drift_from_v1_order() {
        // The dense path is the one place the re-anchor genuinely
        // changes bits (real f32 data leaves the exact-integer
        // range); the bridge bounds the rounding drift between the
        // orders.
        let mut rng = Pcg64::new(0xD2);
        for (k, n) in [(9usize, 5usize), (16, 16), (65, 17)] {
            let a = Mat::randn(1, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let mut v2 = vec![0.0f32; n];
            let mut v1 = vec![0.0f32; n];
            matvec_row(&a.data, &b, &mut v2);
            matvec_row_v1(&a.data, &b, &mut v1);
            for j in 0..n {
                let rel = (v2[j] - v1[j]).abs()
                    / v1[j].abs().max(1.0);
                assert!(rel < 1e-5, "drift {rel} at j={j} ({k},{n})");
            }
        }
    }

    #[test]
    fn wrapper_bit_identical_to_baseline() {
        let mut rng = Pcg64::new(4);
        for (m, k, n) in [(7, 9, 5), (16, 16, 16), (33, 65, 17),
                          (64, 48, 32)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            for threads in [1, 2, 4] {
                let c_eng = matmul(&a, &b, threads);
                let c_seed = matmul_baseline(&a, &b, threads);
                assert_eq!(c_eng.data, c_seed.data,
                           "({m},{k},{n}) threads={threads}");
            }
        }
    }
}
