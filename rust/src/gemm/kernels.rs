//! # Microkernel backend layer
//!
//! Every inner loop of the GEMM engine lives here, behind one
//! [`Kernels`] vtable selected **once per [`GemmPlan`] build** by
//! runtime CPU-feature detection:
//!
//! * `scalar` — the portable floor: the 4-unrolled loops inherited
//!   from the seed kernels, available on every target.
//! * `sse2` — x86_64 baseline SIMD: 8-wide i16 multiplies with exact
//!   i32 widening (SSE2 is unconditionally present on x86_64).
//! * `avx2` — 16-wide i16 multiplies (`_mm256_mullo_epi16` +
//!   sign-extending widens), gated on `is_x86_feature_detected!`.
//! * `avx512vnni` — 16-column dword dot tiles: `_mm512_dpbusd_epi32`
//!   consumes **four K codes per lane per instruction** using the
//!   unsigned-A offset trick (`Σ(a+128)·b = Σa·b + 128·Σb`, with the
//!   `128·Σb` column sums subtracted once per block). Gated on
//!   runtime `avx512f` + `avx512bw` + `avx512vnni` detection.
//! * `neon` — aarch64 baseline SIMD: 8-wide `vmlal_s16`
//!   multiply-accumulate-long (NEON is unconditionally present on
//!   aarch64).
//!
//! Selection order in [`select`]: the `PALLAS_KERNEL` env override
//! (`scalar|sse2|avx2|avx512vnni|neon`, read once per process) → a
//! backend installed by calibration ([`set_preferred`], wired up by
//! `SubstrateCalibration::install_fastest_backend`) → the statically
//! fastest detected backend ([`detect_best`]). A calibrated
//! preference that is **not available** on the running CPU (a warm
//! state or calibration file can outlive the host that measured it)
//! is skipped with a one-shot warning; the env override stays a hard
//! error on unavailable names, because a forced backend that silently
//! fell back would invalidate calibration runs and the CI matrix.
//!
//! ## Why every backend is bit-identical
//!
//! The i8 kernels accumulate **integers**: each backend computes the
//! exact mathematical dot `Σ_k a[k]·b[k]` of i8 codes in i32 (integer
//! addition is associative, so lane order and blocking cannot change
//! the value), then hands the same integer to the shared
//! [`widen_i32`]. The SSE2/AVX2/NEON backends use a narrower
//! intermediate — two i16 products summed in i16 — which is still
//! exact because `|a·b| ≤ 127² = 16129` and `2·16129 = 32258 < 2¹⁵`.
//! The VNNI backend offsets A into unsigned range and computes
//! `Σ(a+128)·b`: each u8×i8 product fits i16 (`|255·128| = 32640 <
//! 2¹⁵`), `VPDPBUSD` sums four of them into i32 **without
//! intermediate saturation** (that is the `VPDPBUSDS` variant), and
//! subtracting the `128·Σb` column-sum correction restores the exact
//! signed dot — still pure integer arithmetic, so the associativity
//! argument applies unchanged. Overflow of the i32 accumulator needs
//! `bs ≈ 6.6e4` even on the offset path, far past the f32-exactness
//! bound `I8_EXACT_MAX_BS` that gates the i8 data path. Hence all
//! backends agree bitwise with each other, with the `SimF32` f32
//! simulation, with the `*_baseline` oracles, and with the exact
//! i64 references — asserted per backend by `tests/engine_prop.rs`,
//! `tests/kernel_fuzz.rs`, and the kernel-level tests below.
//!
//! ## The v2 f32 kernel contract
//!
//! The **f32** kernels ([`panel_dot`], [`panel_dot2`], the dense slot
//! of the vtable, and their twins in `gemm::dense` / `gemm::int8`)
//! follow the **v2 op-order contract**: every output lane `j`
//! accumulates `acc[j] = fma(a[k], b[k][j], acc[j])` as one fused
//! multiply-add per K step, in ascending K, with no zero-code skip.
//! Because the order is *per lane* and every step is a
//! correctly-rounded IEEE FMA, the same bits fall out of scalar
//! `f32::mul_add`, AVX2 `_mm256_fmadd_ps`, and NEON `vfmaq_f32` —
//! vectorization across lanes cannot change a lane's operation
//! sequence. All f32 kernels route through the shared [`fma4_into`] /
//! [`fma1_into`] primitives, which dispatch to the widest FMA unit
//! detected at runtime ([`set_f32_simd_enabled`] forces the scalar
//! path for benchmarking). This is a deliberate re-anchor of the v1
//! seed order (4-wide grouped unfused sums with a zero-skip in the K
//! remainder); the bridge tests in this file and `gemm::dense` bound
//! the drift, and `docs/ARCHITECTURE.md` § "The f32 baseline
//! contract" documents the change. On the quantized paths (SimF32,
//! fallback residuals) all operands are integers and every partial
//! sum stays below 2²⁴ for `bs ≤ I8_EXACT_MAX_BS`, so v1 and v2
//! produce identical bits there — only the *dense* f32 path and
//! oversized-block simulations actually moved.
//!
//! ## Zero-code convention
//!
//! All kernels — i8 and f32 — process **every** code unconditionally;
//! no `a == 0` skip anywhere. (The seed skipped zero codes in some
//! scalar K remainders; for the integer kernels that was semantically
//! irrelevant and was dropped first, and the v2 re-anchor dropped the
//! last f32 instance, so the SIMD lanes stay branch-free everywhere.)
//!
//! ## Adding a backend
//!
//! Implement the three `DotI8` row tiles so they produce the exact
//! integer block dot in `acci` (any lane order), point the three
//! `DotI4` slots at `unpack_i4_entry!`-style delegates (or a native
//! nibble kernel — any exact-integer scheme is bit-identical by
//! construction), register the `static` in [`available`] behind its
//! feature gate — ordered by static speed, fastest last — and the
//! per-backend test/bench sweeps pick it up automatically. The generic recipe (with AMX as the next
//! worked example) lives in `docs/ARCHITECTURE.md` § "Adding a kernel
//! backend"; the landed `avx512vnni` backend in this file is the
//! reference implementation of an offset-trick ISA.
//!
//! [`GemmPlan`]: crate::gemm::engine::GemmPlan

use std::sync::atomic::{AtomicPtr, Ordering};
use std::sync::OnceLock;

use crate::util::Mat;

/// One-row i8 block dot: fills `acci[..width]` with the exact integer
/// dot of A row `r` (K-slice `[k0, k0+bs)`) against a contiguous i8
/// panel, then widens into `acc[..width]`.
pub type DotI8 = fn(
    qa: &[i8],
    a_stride: usize,
    r: usize,
    k0: usize,
    bs: usize,
    panel: &[i8],
    width: usize,
    acci: &mut [i32],
    acc: &mut [f32],
);

/// One-row block dot against a **nibble-packed INT4 panel**
/// ([`PanelPackI4`]): same contract as [`DotI8`], but `panel` holds
/// `width` codes per K row packed two per byte (`width.div_ceil(2)`
/// bytes per row, low nibble = even column, sign-extended two's
/// complement). The A side stays plain i8 — that is what lets the
/// staged fallback stream i8 *residual* codes through the same
/// kernels against the same packed B half.
///
/// [`PanelPackI4`]: crate::quant::PanelPackI4
pub type DotI4 = fn(
    qa: &[i8],
    a_stride: usize,
    r: usize,
    k0: usize,
    bs: usize,
    panel: &[u8],
    width: usize,
    acci: &mut [i32],
    acc: &mut [f32],
);

/// Dense two-row f32 kernel (rows share each loaded B row).
pub type Dense2 =
    fn(arow0: &[f32], arow1: &[f32], b: &Mat, crow0: &mut [f32], crow1: &mut [f32]);

/// i32 → f32 block-dot widening (one call per row per K-block).
pub type Widen = fn(acci: &[i32], acc: &mut [f32], width: usize);

/// A microkernel backend: the engine calls these and nothing else in
/// its hot loop. `dot2_i8`/`dot4_i8` compute 2/4 adjacent A rows
/// against one panel (row `t`'s results land at `acci[t*bs..]` /
/// `acc[t*bs..]`), sharing each loaded B row across the row tile —
/// the register-blocking axis where the ISAs differ.
pub struct Kernels {
    pub name: &'static str,
    pub dot_i8: DotI8,
    pub dot2_i8: DotI8,
    pub dot4_i8: DotI8,
    /// INT4 row tiles ([`DotI4`]): the scalar backend decodes nibbles
    /// in place; every SIMD backend unpacks the packed block into a
    /// thread-local i8 scratch once per (tile, K-block) and delegates
    /// to its own `dot*_i8` — exact integers either way, so the
    /// bit-identity argument above carries over unchanged.
    pub dot_i4: DotI4,
    pub dot2_i4: DotI4,
    pub dot4_i4: DotI4,
    pub dense2: Dense2,
    /// i32 → f32 widening the backend's dot kernels funnel through.
    /// `scalar`/`sse2` install the checked [`widen_i32`]; the
    /// AVX2/VNNI and NEON backends install vectorized variants that
    /// are bit-identical because the hardware i32→f32 conversion is a
    /// per-lane correctly-rounded unary op — the same rounding as
    /// `v as f32` — so lane count cannot change any output
    /// ([`set_widen_simd_enabled`] forces the scalar floor for the
    /// `widen_simd_vs_scalar` bench criterion; debug builds always
    /// take the scalar floor so its overflow guard keeps firing).
    pub widen: Widen,
}

impl std::fmt::Debug for Kernels {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Kernels").field("name", &self.name).finish()
    }
}

pub static SCALAR: Kernels = Kernels {
    name: "scalar",
    dot_i8: dot_i8_scalar,
    dot2_i8: dot2_i8_scalar,
    dot4_i8: dot4_i8_scalar,
    dot_i4: dot_i4_scalar,
    dot2_i4: dot2_i4_scalar,
    dot4_i4: dot4_i4_scalar,
    dense2: dense_rows2,
    widen: widen_i32,
};

#[cfg(target_arch = "x86_64")]
pub static SSE2: Kernels = Kernels {
    name: "sse2",
    dot_i8: x86::dot_i8_sse2,
    dot2_i8: x86::dot2_i8_sse2,
    dot4_i8: x86::dot4_i8_sse2,
    dot_i4: dot_i4_sse2,
    dot2_i4: dot2_i4_sse2,
    dot4_i4: dot4_i4_sse2,
    dense2: dense_rows2,
    widen: widen_i32,
};

#[cfg(target_arch = "x86_64")]
pub static AVX2: Kernels = Kernels {
    name: "avx2",
    dot_i8: x86::dot_i8_avx2,
    dot2_i8: x86::dot2_i8_avx2,
    dot4_i8: x86::dot4_i8_avx2,
    dot_i4: dot_i4_avx2,
    dot2_i4: dot2_i4_avx2,
    dot4_i4: dot4_i4_avx2,
    dense2: dense_rows2,
    widen: widen_i32_avx2,
};

#[cfg(target_arch = "x86_64")]
pub static AVX512VNNI: Kernels = Kernels {
    name: "avx512vnni",
    dot_i8: x86::dot_i8_avx512vnni,
    dot2_i8: x86::dot2_i8_avx512vnni,
    dot4_i8: x86::dot4_i8_avx512vnni,
    dot_i4: dot_i4_avx512vnni,
    dot2_i4: dot2_i4_avx512vnni,
    dot4_i4: dot4_i4_avx512vnni,
    dense2: dense_rows2,
    widen: widen_i32_avx2,
};

#[cfg(target_arch = "aarch64")]
pub static NEON: Kernels = Kernels {
    name: "neon",
    dot_i8: arm::dot_i8_neon,
    dot2_i8: arm::dot2_i8_neon,
    dot4_i8: arm::dot4_i8_neon,
    dot_i4: dot_i4_neon,
    dot2_i4: dot2_i4_neon,
    dot4_i4: dot4_i4_neon,
    dense2: dense_rows2,
    widen: widen_i32_neon,
};

/// Backends usable on this host, ordered slowest → statically
/// fastest. `scalar` is always present; SIMD entries appear when the
/// architecture (and, for AVX2 / AVX-512 VNNI, the runtime CPUID
/// checks) provides their instructions.
pub fn available() -> Vec<&'static Kernels> {
    let mut v: Vec<&'static Kernels> = vec![&SCALAR];
    push_arch_backends(&mut v);
    v
}

#[cfg(target_arch = "x86_64")]
fn push_arch_backends(v: &mut Vec<&'static Kernels>) {
    // SSE2 is part of the x86_64 baseline — no detection needed.
    v.push(&SSE2);
    if is_x86_feature_detected!("avx2") {
        v.push(&AVX2);
    }
    if is_x86_feature_detected!("avx512f")
        && is_x86_feature_detected!("avx512bw")
        && is_x86_feature_detected!("avx512vnni")
    {
        v.push(&AVX512VNNI);
    }
}

#[cfg(target_arch = "aarch64")]
fn push_arch_backends(v: &mut Vec<&'static Kernels>) {
    // NEON is part of the aarch64 baseline.
    v.push(&NEON);
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn push_arch_backends(_v: &mut Vec<&'static Kernels>) {}

/// CPU features relevant to kernel selection that the runtime
/// detected on this host (recorded by the benches next to the chosen
/// backend, so `BENCH_*.json` files are interpretable off-host).
pub fn cpu_features() -> Vec<&'static str> {
    let mut f = Vec::new();
    detect_arch_features(&mut f);
    f
}

#[cfg(target_arch = "x86_64")]
fn detect_arch_features(f: &mut Vec<&'static str>) {
    f.push("sse2");
    if is_x86_feature_detected!("sse4.1") {
        f.push("sse4.1");
    }
    if is_x86_feature_detected!("avx2") {
        f.push("avx2");
    }
    if is_x86_feature_detected!("fma") {
        f.push("fma");
    }
    if is_x86_feature_detected!("avx512f") {
        f.push("avx512f");
    }
    if is_x86_feature_detected!("avx512bw") {
        f.push("avx512bw");
    }
    if is_x86_feature_detected!("avx512vnni") {
        f.push("avx512vnni");
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch_features(f: &mut Vec<&'static str>) {
    f.push("neon");
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch_features(_f: &mut Vec<&'static str>) {}

/// Look a backend up by its `PALLAS_KERNEL` name among the ones
/// available on this host.
pub fn by_name(name: &str) -> Option<&'static Kernels> {
    available().into_iter().find(|k| k.name == name)
}

/// The statically preferred backend: the last (fastest) entry of
/// [`available`].
pub fn detect_best() -> &'static Kernels {
    *available().last().expect("scalar backend always present")
}

/// Calibration hook: install the backend measured fastest so later
/// [`select`] calls (plan builds) use it. The `PALLAS_KERNEL` env
/// override still wins — calibration only replaces the *static*
/// preference with a measured one.
pub fn set_preferred(k: &'static Kernels) {
    PREFERRED.store(k as *const Kernels as *mut Kernels, Ordering::Relaxed);
}

static PREFERRED: AtomicPtr<Kernels> = AtomicPtr::new(std::ptr::null_mut());

/// Serializes tests that mutate the process-global preference
/// (`set_preferred` / `install_fastest_backend`) so concurrent test
/// threads can't interleave a set with another test's assert.
#[cfg(test)]
pub(crate) static PREFERRED_TEST_LOCK: std::sync::Mutex<()> =
    std::sync::Mutex::new(());

fn preferred() -> Option<&'static Kernels> {
    let p = PREFERRED.load(Ordering::Relaxed);
    if p.is_null() {
        None
    } else {
        // Only ever stored from a &'static Kernels in set_preferred.
        Some(unsafe { &*p })
    }
}

static ENV_OVERRIDE: OnceLock<Option<&'static Kernels>> = OnceLock::new();

/// Parse a `PALLAS_KERNEL`-style override value. Empty/absent means
/// "no override"; an unknown or host-unavailable name is a hard error
/// (an override that silently fell back would invalidate calibration
/// runs and the CI matrix leg that forces `scalar`).
pub fn parse_override(val: Option<&str>) -> Option<&'static Kernels> {
    match val {
        None => None,
        Some("") => None,
        Some(s) => match by_name(s) {
            Some(k) => Some(k),
            None => panic!(
                "PALLAS_KERNEL={s:?} is not an available kernel backend \
                 on this host (available: {:?})",
                available().iter().map(|k| k.name).collect::<Vec<_>>()
            ),
        },
    }
}

/// The `PALLAS_KERNEL` env override in force for this process, if
/// any (read once, like [`select`]). Exposed so restore paths (the
/// pipeline's warm state) can *respect* the override instead of
/// silently re-pinning a recorded backend over it — the same
/// contract that makes [`parse_override`] a hard error on unknown
/// names.
pub fn env_override() -> Option<&'static Kernels> {
    *ENV_OVERRIDE.get_or_init(|| {
        parse_override(std::env::var("PALLAS_KERNEL").ok().as_deref())
    })
}

/// Set once the first time [`select`] skips an unavailable calibrated
/// preference, so the warning fires once per process rather than once
/// per plan build.
static PREF_UNAVAILABLE_WARNED: std::sync::atomic::AtomicBool =
    std::sync::atomic::AtomicBool::new(false);

/// The backend a fresh `GemmPlan` uses: `PALLAS_KERNEL` env override
/// (read once per process) → calibration preference → static best.
///
/// A calibrated preference naming a backend the running CPU does not
/// provide (calibration files and warm states travel between hosts)
/// is skipped with a one-shot `stderr` warning instead of an error —
/// only the explicit env override is a hard failure on unavailable
/// names ([`parse_override`]).
pub fn select() -> &'static Kernels {
    if let Some(k) = env_override() {
        return k;
    }
    if let Some(k) = preferred() {
        if available().iter().any(|a| a.name == k.name) {
            return k;
        }
        let best = detect_best();
        if !PREF_UNAVAILABLE_WARNED.swap(true, Ordering::Relaxed) {
            eprintln!(
                "dbfq: calibrated kernel preference {:?} is not \
                 available on this CPU; falling back to {:?}",
                k.name, best.name
            );
        }
        return best;
    }
    detect_best()
}

// ---------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------

/// i32 → f32 widening of a block dot, once per row per K-block. Exact
/// whenever `|v| ≤ 2²⁴` (guaranteed for `bs ≤ I8_EXACT_MAX_BS`); the
/// debug assertion catches the first value past the
/// exactly-representable range on oversized blocks — on every
/// backend, since all of them funnel through this function.
pub fn widen_i32(acci: &[i32], acc: &mut [f32], width: usize) {
    for (o, &v) in acc[..width].iter_mut().zip(acci[..width].iter()) {
        debug_assert!(
            v.unsigned_abs() <= 1 << 24,
            "i8-path block dot {} exceeds the f32-exact range \
             (only bs <= {} is bit-exact; use DataPath::SimF32)",
            v,
            crate::gemm::engine::I8_EXACT_MAX_BS
        );
        *o = v as f32;
    }
}

/// Widen a `rows`-row tile (row `t` at offset `t * bs` in both
/// workspaces) through the backend's `widen` slot — every dot kernel
/// funnels its integer result through its own vtable entry, so a
/// backend that installs a custom widening actually gets it.
fn widen_rows(
    widen: Widen, rows: usize, bs: usize, width: usize, acci: &[i32],
    acc: &mut [f32],
) {
    for t in 0..rows {
        widen(&acci[t * bs..], &mut acc[t * bs..], width);
    }
}

/// Force the vectorized `widen` vtable entries onto the scalar
/// [`widen_i32`] floor when `false` (the `widen_simd_vs_scalar`
/// bench criterion and the widen identity test flip this); defaults
/// to enabled. Mirrors [`set_f32_simd_enabled`].
static WIDEN_SIMD_ENABLED: std::sync::atomic::AtomicBool =
    std::sync::atomic::AtomicBool::new(true);

/// Enable/disable the vectorized i32→f32 widening process-wide.
/// Results are bit-identical either way (hardware `cvt` rounds each
/// lane exactly like `v as f32`); the knob exists so benches can
/// measure the speedup and tests can assert the identity.
pub fn set_widen_simd_enabled(on: bool) {
    WIDEN_SIMD_ENABLED.store(on, Ordering::Relaxed);
}

/// Whether the vectorized widening path is currently enabled.
pub fn widen_simd_enabled() -> bool {
    WIDEN_SIMD_ENABLED.load(Ordering::Relaxed)
}

/// AVX2 widening entry installed in the `avx2`/`avx512vnni` vtables.
/// Debug builds route to the scalar floor so [`widen_i32`]'s
/// overflow guard keeps firing; release builds convert 8 lanes per
/// `vcvtdq2ps` — per-lane correctly-rounded, identical bits to
/// `v as f32` for every i32.
#[cfg(target_arch = "x86_64")]
pub fn widen_i32_avx2(acci: &[i32], acc: &mut [f32], width: usize) {
    if cfg!(debug_assertions) || !widen_simd_enabled() {
        return widen_i32(acci, acc, width);
    }
    // Safety: only installed in vtables `available()` gates on
    // runtime AVX2 (or AVX-512) detection.
    unsafe { x86::widen_avx2(acci, acc, width) }
}

/// NEON widening entry installed in the `neon` vtable; see
/// [`widen_i32_avx2`] for the debug-build and rounding contract.
#[cfg(target_arch = "aarch64")]
pub fn widen_i32_neon(acci: &[i32], acc: &mut [f32], width: usize) {
    if cfg!(debug_assertions) || !widen_simd_enabled() {
        return widen_i32(acci, acc, width);
    }
    // Safety: NEON is baseline on aarch64.
    unsafe { arm::widen_neon(acci, acc, width) }
}

/// Deterministic widening reduction for split-K execution: combine
/// per-split i32 partial dots into the final f32 row, bit-identical
/// for every split count × thread count × backend.
///
/// Each output element is reduced over `parts` through a **fixed
/// pairwise tree whose shape depends only on `parts.len()`**, summing
/// in i64. For integer partials the tree shape is provably irrelevant
/// (integer addition is associative — any order yields the same
/// exact sum), so determinism is unconditional; the fixed shape is
/// the contract a future floating-point-partial variant inherits,
/// where order *would* matter. The single i64→f32 conversion at the
/// root is the same correctly-rounded op as [`widen_i32`], with the
/// same debug-build guard on the f32-exact range.
///
/// The engine's forward/dX/dW shards split **N**, never K, so no
/// execution path reduces today; the hook (and `tests/shard_prop.rs`)
/// pin the contract the first K-split will rely on.
pub fn widen_reduce_i32(
    parts: &[&[i32]], acc: &mut [f32], width: usize,
) {
    assert!(
        !parts.is_empty(),
        "widen_reduce_i32 needs at least one partial"
    );
    fn tree(parts: &[&[i32]], j: usize) -> i64 {
        match parts.len() {
            1 => parts[0][j] as i64,
            n => {
                let mid = n.div_ceil(2);
                tree(&parts[..mid], j) + tree(&parts[mid..], j)
            }
        }
    }
    for (j, o) in acc[..width].iter_mut().enumerate() {
        let s = tree(parts, j);
        debug_assert!(
            s.unsigned_abs() <= 1 << 24,
            "reduced block dot {} exceeds the f32-exact range \
             (only bs <= {} is bit-exact; use DataPath::SimF32)",
            s,
            crate::gemm::engine::I8_EXACT_MAX_BS
        );
        *o = s as f32;
    }
}

// ---------------------------------------------------------------------
// Scalar backend (portable floor; K 4-unrolled like the seed kernels)
// ---------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn dot_i8_scalar(
    qa: &[i8], a_stride: usize, r: usize, k0: usize, bs: usize,
    panel: &[i8], width: usize, acci: &mut [i32], acc: &mut [f32],
) {
    acci[..width].fill(0);
    let arow = &qa[r * a_stride + k0..r * a_stride + k0 + bs];
    let kk = bs & !3;
    for k in (0..kk).step_by(4) {
        let a0 = arow[k] as i32;
        let a1 = arow[k + 1] as i32;
        let a2 = arow[k + 2] as i32;
        let a3 = arow[k + 3] as i32;
        let b0 = &panel[(k0 + k) * width..][..width];
        let b1 = &panel[(k0 + k + 1) * width..][..width];
        let b2 = &panel[(k0 + k + 2) * width..][..width];
        let b3 = &panel[(k0 + k + 3) * width..][..width];
        for j in 0..width {
            acci[j] += a0 * b0[j] as i32
                + a1 * b1[j] as i32
                + a2 * b2[j] as i32
                + a3 * b3[j] as i32;
        }
    }
    for k in kk..bs {
        // No zero-code skip: see the module-level convention note.
        let av = arow[k] as i32;
        let brow = &panel[(k0 + k) * width..][..width];
        for j in 0..width {
            acci[j] += av * brow[j] as i32;
        }
    }
    (SCALAR.widen)(acci, acc, width);
}

#[allow(clippy::too_many_arguments)]
fn dot2_i8_scalar(
    qa: &[i8], a_stride: usize, r: usize, k0: usize, bs: usize,
    panel: &[i8], width: usize, acci: &mut [i32], acc: &mut [f32],
) {
    let (acci0, acci1t) = acci.split_at_mut(bs);
    let acci1 = &mut acci1t[..bs];
    acci0[..width].fill(0);
    acci1[..width].fill(0);
    let arow0 = &qa[r * a_stride + k0..r * a_stride + k0 + bs];
    let arow1 = &qa[(r + 1) * a_stride + k0..(r + 1) * a_stride + k0 + bs];
    let kk = bs & !3;
    for k in (0..kk).step_by(4) {
        let a00 = arow0[k] as i32;
        let a01 = arow0[k + 1] as i32;
        let a02 = arow0[k + 2] as i32;
        let a03 = arow0[k + 3] as i32;
        let a10 = arow1[k] as i32;
        let a11 = arow1[k + 1] as i32;
        let a12 = arow1[k + 2] as i32;
        let a13 = arow1[k + 3] as i32;
        let b0 = &panel[(k0 + k) * width..][..width];
        let b1 = &panel[(k0 + k + 1) * width..][..width];
        let b2 = &panel[(k0 + k + 2) * width..][..width];
        let b3 = &panel[(k0 + k + 3) * width..][..width];
        for j in 0..width {
            let v0 = b0[j] as i32;
            let v1 = b1[j] as i32;
            let v2 = b2[j] as i32;
            let v3 = b3[j] as i32;
            acci0[j] += a00 * v0 + a01 * v1 + a02 * v2 + a03 * v3;
            acci1[j] += a10 * v0 + a11 * v1 + a12 * v2 + a13 * v3;
        }
    }
    for k in kk..bs {
        let brow = &panel[(k0 + k) * width..][..width];
        let av0 = arow0[k] as i32;
        let av1 = arow1[k] as i32;
        for j in 0..width {
            acci0[j] += av0 * brow[j] as i32;
            acci1[j] += av1 * brow[j] as i32;
        }
    }
    widen_rows(SCALAR.widen, 2, bs, width, acci, acc);
}

/// Scalar 4-row tile = two 2-row tiles (no wider register file to
/// exploit; keeps the scalar op sequence identical to the paired
/// kernels it replaces).
#[allow(clippy::too_many_arguments)]
fn dot4_i8_scalar(
    qa: &[i8], a_stride: usize, r: usize, k0: usize, bs: usize,
    panel: &[i8], width: usize, acci: &mut [i32], acc: &mut [f32],
) {
    let (acci01, acci23) = acci.split_at_mut(2 * bs);
    let (acc01, acc23) = acc.split_at_mut(2 * bs);
    dot2_i8_scalar(qa, a_stride, r, k0, bs, panel, width, acci01, acc01);
    dot2_i8_scalar(qa, a_stride, r + 2, k0, bs, panel, width, acci23, acc23);
}

// ---------------------------------------------------------------------
// INT4 (nibble-packed) kernels. The scalar backend is the mandatory
// portable floor: it sign-extends each nibble in place. The SIMD
// backends reuse their i8 machinery: the packed K-block is unpacked
// once into a thread-local i8 scratch (amortized over the whole row
// tile × column width) and the backend's own `dot*_i8` runs on it —
// exact integer arithmetic both ways, so every backend produces the
// identical i32 block dot, and the shared `widen` slot the identical
// f32. Codes are in [-7, 7] (|a·b| ≤ 127·7 = 889 even with i8
// residual codes on the A side), far inside every intermediate bound
// the i8 scheme already proves.
// ---------------------------------------------------------------------

/// Sign-extend the `j`-th code of a nibble-packed row (`brow` holds
/// `width.div_ceil(2)` bytes; low nibble = even column).
#[inline(always)]
fn nibble_at(brow: &[u8], j: usize) -> i8 {
    let b = brow[j >> 1];
    if j & 1 == 0 {
        ((b << 4) as i8) >> 4
    } else {
        (b as i8) >> 4
    }
}

#[allow(clippy::too_many_arguments)]
fn dot_i4_scalar(
    qa: &[i8], a_stride: usize, r: usize, k0: usize, bs: usize,
    panel: &[u8], width: usize, acci: &mut [i32], acc: &mut [f32],
) {
    acci[..width].fill(0);
    let rw = width.div_ceil(2);
    let arow = &qa[r * a_stride + k0..r * a_stride + k0 + bs];
    for (k, &a) in arow.iter().enumerate() {
        // No zero-code skip (module-level convention).
        let av = a as i32;
        let brow = &panel[(k0 + k) * rw..][..rw];
        for j in 0..width {
            acci[j] += av * nibble_at(brow, j) as i32;
        }
    }
    (SCALAR.widen)(acci, acc, width);
}

/// Scalar 2-row i4 tile = two 1-row tiles (the floor optimizes for
/// clarity; the unpack-delegating SIMD entries own the fast path).
#[allow(clippy::too_many_arguments)]
fn dot2_i4_scalar(
    qa: &[i8], a_stride: usize, r: usize, k0: usize, bs: usize,
    panel: &[u8], width: usize, acci: &mut [i32], acc: &mut [f32],
) {
    let (acci0, acci1) = acci.split_at_mut(bs);
    let (acc0, acc1) = acc.split_at_mut(bs);
    dot_i4_scalar(qa, a_stride, r, k0, bs, panel, width, acci0, acc0);
    dot_i4_scalar(qa, a_stride, r + 1, k0, bs, panel, width, acci1, acc1);
}

#[allow(clippy::too_many_arguments)]
fn dot4_i4_scalar(
    qa: &[i8], a_stride: usize, r: usize, k0: usize, bs: usize,
    panel: &[u8], width: usize, acci: &mut [i32], acc: &mut [f32],
) {
    let (acci01, acci23) = acci.split_at_mut(2 * bs);
    let (acc01, acc23) = acc.split_at_mut(2 * bs);
    dot2_i4_scalar(qa, a_stride, r, k0, bs, panel, width, acci01, acc01);
    dot2_i4_scalar(qa, a_stride, r + 2, k0, bs, panel, width, acci23, acc23);
}

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
std::thread_local! {
    /// Per-thread i8 scratch the SIMD i4 entries unpack nibble panels
    /// into. Deliberately separate from the engine's workspace
    /// thread-local — the unpack happens *inside* a kernel call, while
    /// the engine workspace is already mutably borrowed.
    static I4_UNPACK: std::cell::RefCell<Vec<i8>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Unpack rows `k0..k0+bs` of a nibble panel into `out` at the plain
/// i8 panel layout (`out[(k0+k)*width + j]`), so a delegated `DotI8`
/// call with the **same** `k0` reads exactly the decoded codes. Rows
/// below `k0` are left untouched (never read by the delegate).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn unpack_i4_rows(
    panel: &[u8], k0: usize, bs: usize, width: usize, out: &mut Vec<i8>,
) {
    let rw = width.div_ceil(2);
    let need = (k0 + bs) * width;
    if out.len() < need {
        out.resize(need, 0);
    }
    for k in 0..bs {
        let src = &panel[(k0 + k) * rw..][..rw];
        let dst = &mut out[(k0 + k) * width..][..width];
        let even = width & !1;
        for j in (0..even).step_by(2) {
            let b = src[j >> 1];
            dst[j] = (b << 4) as i8 >> 4;
            dst[j + 1] = (b as i8) >> 4;
        }
        if even < width {
            dst[even] = (src[even >> 1] << 4) as i8 >> 4;
        }
    }
}

/// Generate an i4 vtable entry that unpacks to i8 scratch and
/// delegates to the named i8 kernel of the same backend.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
macro_rules! unpack_i4_entry {
    ($name:ident, $delegate:path) => {
        #[allow(clippy::too_many_arguments)]
        fn $name(
            qa: &[i8], a_stride: usize, r: usize, k0: usize, bs: usize,
            panel: &[u8], width: usize, acci: &mut [i32],
            acc: &mut [f32],
        ) {
            I4_UNPACK.with(|ws| {
                let mut ws = ws.borrow_mut();
                unpack_i4_rows(panel, k0, bs, width, &mut ws);
                $delegate(qa, a_stride, r, k0, bs, &ws, width, acci, acc);
            });
        }
    };
}

#[cfg(target_arch = "x86_64")]
unpack_i4_entry!(dot_i4_sse2, x86::dot_i8_sse2);
#[cfg(target_arch = "x86_64")]
unpack_i4_entry!(dot2_i4_sse2, x86::dot2_i8_sse2);
#[cfg(target_arch = "x86_64")]
unpack_i4_entry!(dot4_i4_sse2, x86::dot4_i8_sse2);
#[cfg(target_arch = "x86_64")]
unpack_i4_entry!(dot_i4_avx2, x86::dot_i8_avx2);
#[cfg(target_arch = "x86_64")]
unpack_i4_entry!(dot2_i4_avx2, x86::dot2_i8_avx2);
#[cfg(target_arch = "x86_64")]
unpack_i4_entry!(dot4_i4_avx2, x86::dot4_i8_avx2);
#[cfg(target_arch = "x86_64")]
unpack_i4_entry!(dot_i4_avx512vnni, x86::dot_i8_avx512vnni);
#[cfg(target_arch = "x86_64")]
unpack_i4_entry!(dot2_i4_avx512vnni, x86::dot2_i8_avx512vnni);
#[cfg(target_arch = "x86_64")]
unpack_i4_entry!(dot4_i4_avx512vnni, x86::dot4_i8_avx512vnni);
#[cfg(target_arch = "aarch64")]
unpack_i4_entry!(dot_i4_neon, arm::dot_i8_neon);
#[cfg(target_arch = "aarch64")]
unpack_i4_entry!(dot2_i4_neon, arm::dot2_i8_neon);
#[cfg(target_arch = "aarch64")]
unpack_i4_entry!(dot4_i4_neon, arm::dot4_i8_neon);

// ---------------------------------------------------------------------
// Shared f32 kernels — the v2 op-order contract (see module docs):
// per-lane sequential FMA in ascending K, no zero-code skip. The
// [`fma4_into`]/[`fma1_into`] primitives dispatch to the widest FMA
// unit detected at runtime; every lane's operation sequence is the
// same on every path, so SIMD and scalar produce identical bits.
// ---------------------------------------------------------------------

/// Force the f32 kernels onto the scalar `mul_add` path when `false`
/// (the `f32_simd_vs_scalar` bench criterion and the SIMD≡scalar
/// bitwise tests flip this); defaults to enabled.
static F32_SIMD_ENABLED: std::sync::atomic::AtomicBool =
    std::sync::atomic::AtomicBool::new(true);

/// Enable/disable the vectorized f32 FMA path process-wide. Results
/// are bit-identical either way (that is the v2 contract); the knob
/// exists so benches can measure the speedup and tests can assert the
/// identity.
pub fn set_f32_simd_enabled(on: bool) {
    F32_SIMD_ENABLED.store(on, Ordering::Relaxed);
}

/// Whether the vectorized f32 FMA path is currently enabled.
pub fn f32_simd_enabled() -> bool {
    F32_SIMD_ENABLED.load(Ordering::Relaxed)
}

/// Runtime support for the AVX2+FMA f32 path (AVX2 does **not** imply
/// FMA — they are separate CPUID bits — so both are checked).
#[cfg(target_arch = "x86_64")]
fn f32_fma_supported() -> bool {
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    })
}

/// `acc[j] = fma(a3, b3[j], fma(a2, b2[j], fma(a1, b1[j],
/// fma(a0, b0[j], acc[j]))))` for every lane — four sequential fused
/// steps per lane, the v2 contract's K-unrolled form.
#[inline]
pub(crate) fn fma4_into(
    a: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32],
    acc: &mut [f32],
) {
    #[cfg(target_arch = "x86_64")]
    if f32_simd_enabled() && f32_fma_supported() {
        // Safety: AVX2+FMA runtime-detected just above.
        unsafe { x86::fma4_avx2(a, b0, b1, b2, b3, acc) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if f32_simd_enabled() {
        // Safety: NEON (with FMA) is baseline on aarch64.
        unsafe { arm::fma4_neon(a, b0, b1, b2, b3, acc) };
        return;
    }
    fma4_scalar(a, b0, b1, b2, b3, acc);
}

/// `acc[j] = fma(av, brow[j], acc[j])` for every lane.
#[inline]
pub(crate) fn fma1_into(av: f32, brow: &[f32], acc: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if f32_simd_enabled() && f32_fma_supported() {
        // Safety: AVX2+FMA runtime-detected just above.
        unsafe { x86::fma1_avx2(av, brow, acc) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if f32_simd_enabled() {
        // Safety: NEON (with FMA) is baseline on aarch64.
        unsafe { arm::fma1_neon(av, brow, acc) };
        return;
    }
    fma1_scalar(av, brow, acc);
}

/// Scalar reference for [`fma4_into`] — `f32::mul_add` is a single
/// correctly-rounded IEEE FMA, the same operation the SIMD lanes
/// perform.
#[inline]
fn fma4_scalar(
    a: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32],
    acc: &mut [f32],
) {
    for (j, o) in acc.iter_mut().enumerate() {
        let mut s = *o;
        s = a[0].mul_add(b0[j], s);
        s = a[1].mul_add(b1[j], s);
        s = a[2].mul_add(b2[j], s);
        s = a[3].mul_add(b3[j], s);
        *o = s;
    }
}

/// Scalar reference for [`fma1_into`].
#[inline]
fn fma1_scalar(av: f32, brow: &[f32], acc: &mut [f32]) {
    for (o, &bv) in acc.iter_mut().zip(brow.iter()) {
        *o = av.mul_add(bv, *o);
    }
}

/// One-row f32 block dot against a contiguous B panel:
/// `acc[j] = Σ_k a[r, k0+k] · panel[k0+k, j]` under the v2 op-order
/// contract (per-lane sequential FMA, ascending K, no zero skip).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn panel_dot(
    af: &[f32], a_stride: usize, r: usize, k0: usize, bs: usize,
    panel: &[f32], width: usize, acc: &mut [f32],
) {
    let acc = &mut acc[..width];
    acc.fill(0.0);
    let arow = &af[r * a_stride + k0..r * a_stride + k0 + bs];
    let kk = bs & !3;
    for k in (0..kk).step_by(4) {
        fma4_into(
            [arow[k], arow[k + 1], arow[k + 2], arow[k + 3]],
            &panel[(k0 + k) * width..][..width],
            &panel[(k0 + k + 1) * width..][..width],
            &panel[(k0 + k + 2) * width..][..width],
            &panel[(k0 + k + 3) * width..][..width],
            acc,
        );
    }
    for k in kk..bs {
        fma1_into(arow[k], &panel[(k0 + k) * width..][..width], acc);
    }
}

/// Two-row f32 block dot sharing each loaded B row between adjacent A
/// rows (halves B-panel traffic). Per-row operation order matches
/// [`panel_dot`] exactly, so outputs stay bit-identical.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn panel_dot2(
    af: &[f32], a_stride: usize, r: usize, k0: usize, bs: usize,
    panel: &[f32], width: usize, acc0: &mut [f32], acc1: &mut [f32],
) {
    let acc0 = &mut acc0[..width];
    let acc1 = &mut acc1[..width];
    acc0.fill(0.0);
    acc1.fill(0.0);
    let arow0 = &af[r * a_stride + k0..r * a_stride + k0 + bs];
    let arow1 = &af[(r + 1) * a_stride + k0..(r + 1) * a_stride + k0 + bs];
    let kk = bs & !3;
    for k in (0..kk).step_by(4) {
        let b0 = &panel[(k0 + k) * width..][..width];
        let b1 = &panel[(k0 + k + 1) * width..][..width];
        let b2 = &panel[(k0 + k + 2) * width..][..width];
        let b3 = &panel[(k0 + k + 3) * width..][..width];
        fma4_into(
            [arow0[k], arow0[k + 1], arow0[k + 2], arow0[k + 3]],
            b0, b1, b2, b3, acc0,
        );
        fma4_into(
            [arow1[k], arow1[k + 1], arow1[k + 2], arow1[k + 3]],
            b0, b1, b2, b3, acc1,
        );
    }
    for k in kk..bs {
        let brow = &panel[(k0 + k) * width..][..width];
        fma1_into(arow0[k], brow, acc0);
        fma1_into(arow1[k], brow, acc1);
    }
}

/// Dense two-row kernel sharing each loaded B row; per-row operation
/// order matches `dense::matvec_row` (the single-row kernel, shared
/// with the baseline) exactly — both follow the v2 contract.
#[inline]
fn dense_rows2(
    arow0: &[f32], arow1: &[f32], b: &Mat, crow0: &mut [f32], crow1: &mut [f32],
) {
    let n = b.cols;
    let k = b.rows;
    let crow0 = &mut crow0[..n];
    let crow1 = &mut crow1[..n];
    let kk = k & !3;
    for kb in (0..kk).step_by(4) {
        let b0 = &b.data[kb * n..(kb + 1) * n];
        let b1 = &b.data[(kb + 1) * n..(kb + 2) * n];
        let b2 = &b.data[(kb + 2) * n..(kb + 3) * n];
        let b3 = &b.data[(kb + 3) * n..(kb + 4) * n];
        fma4_into(
            [arow0[kb], arow0[kb + 1], arow0[kb + 2], arow0[kb + 3]],
            b0, b1, b2, b3, crow0,
        );
        fma4_into(
            [arow1[kb], arow1[kb + 1], arow1[kb + 2], arow1[kb + 3]],
            b0, b1, b2, b3, crow1,
        );
    }
    for kb in kk..k {
        let brow = &b.data[kb * n..(kb + 1) * n];
        fma1_into(arow0[kb], brow, crow0);
        fma1_into(arow1[kb], brow, crow1);
    }
}

// ---------------------------------------------------------------------
// Scalar tail shared by the SIMD backends (j past the vector chunks)
// ---------------------------------------------------------------------

/// Finish columns `[j_done, width)` for a `rows`-row tile with plain
/// i32 arithmetic — the same integer, any order.
#[allow(clippy::too_many_arguments)]
fn dot_rows_tail(
    qa: &[i8], a_stride: usize, r: usize, k0: usize, bs: usize,
    panel: &[i8], width: usize, rows: usize, j_done: usize,
    acci: &mut [i32],
) {
    for t in 0..rows {
        let arow = &qa[(r + t) * a_stride + k0..(r + t) * a_stride + k0 + bs];
        for j in j_done..width {
            let mut s = 0i32;
            for (k, &av) in arow.iter().enumerate() {
                s += av as i32 * panel[(k0 + k) * width + j] as i32;
            }
            acci[t * bs + j] = s;
        }
    }
}

// ---------------------------------------------------------------------
// x86_64 backends: SSE2 (baseline) and AVX2 (runtime-detected)
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{dot_rows_tail, widen_rows};
    use core::arch::x86_64::*;

    // Exactness of the SIMD scheme (both ISAs): codes are in
    // [-127, 127], so each i16 product |a·b| ≤ 16129 and the sum of a
    // K-pair of products ≤ 32258 < 2¹⁵ — no i16 overflow — and the
    // sign-extended i32 accumulation is the exact integer dot.

    /// Sign-extend 8 i8 codes at `p` to an i16x8 vector. SSE2 has no
    /// `cvtepi8_epi16` (that is SSE4.1), so build the sign mask with a
    /// compare and interleave.
    ///
    /// Safety: `p .. p+8` must be in bounds.
    #[inline]
    unsafe fn load8_i8_as_i16(p: *const i8) -> __m128i {
        let v = _mm_loadl_epi64(p as *const __m128i);
        let sign = _mm_cmpgt_epi8(_mm_setzero_si128(), v);
        _mm_unpacklo_epi8(v, sign)
    }

    /// Sign-extend an i16x8 product vector and add it into two i32x4
    /// accumulators (lanes 0..4 and 4..8).
    #[inline]
    unsafe fn acc_i16_into_i32(
        lo: __m128i, hi: __m128i, p: __m128i,
    ) -> (__m128i, __m128i) {
        let sign = _mm_cmpgt_epi16(_mm_setzero_si128(), p);
        (
            _mm_add_epi32(lo, _mm_unpacklo_epi16(p, sign)),
            _mm_add_epi32(hi, _mm_unpackhi_epi16(p, sign)),
        )
    }

    /// SSE2 row-tile kernel: 8-column register tiles, K consumed in
    /// pairs so two exact i16 products amortize one widening.
    ///
    /// Safety: caller guarantees the slice geometry of the `DotI8`
    /// contract (`qa` holds rows `r..r+ROWS`, `panel` holds rows
    /// `k0..k0+bs` of `width` codes, `acci.len() ≥ ROWS·bs`). SSE2 is
    /// baseline on x86_64 — no feature check needed.
    #[allow(clippy::too_many_arguments)]
    unsafe fn sse2_dot_rows<const ROWS: usize>(
        qa: &[i8], a_stride: usize, r: usize, k0: usize, bs: usize,
        panel: &[i8], width: usize, acci: &mut [i32],
    ) {
        let arows: [&[i8]; ROWS] = core::array::from_fn(|t| {
            &qa[(r + t) * a_stride + k0..(r + t) * a_stride + k0 + bs]
        });
        let jj = width & !7;
        let kk = bs & !1;
        let mut j = 0usize;
        while j < jj {
            let mut lo = [_mm_setzero_si128(); ROWS];
            let mut hi = [_mm_setzero_si128(); ROWS];
            let mut k = 0usize;
            while k < kk {
                let b0 = load8_i8_as_i16(panel.as_ptr().add((k0 + k) * width + j));
                let b1 =
                    load8_i8_as_i16(panel.as_ptr().add((k0 + k + 1) * width + j));
                for t in 0..ROWS {
                    let a0 = _mm_set1_epi16(arows[t][k] as i16);
                    let a1 = _mm_set1_epi16(arows[t][k + 1] as i16);
                    let p = _mm_add_epi16(
                        _mm_mullo_epi16(a0, b0),
                        _mm_mullo_epi16(a1, b1),
                    );
                    let (l, h) = acc_i16_into_i32(lo[t], hi[t], p);
                    lo[t] = l;
                    hi[t] = h;
                }
                k += 2;
            }
            if k < bs {
                let b0 = load8_i8_as_i16(panel.as_ptr().add((k0 + k) * width + j));
                for t in 0..ROWS {
                    let a0 = _mm_set1_epi16(arows[t][k] as i16);
                    let p = _mm_mullo_epi16(a0, b0);
                    let (l, h) = acc_i16_into_i32(lo[t], hi[t], p);
                    lo[t] = l;
                    hi[t] = h;
                }
            }
            for t in 0..ROWS {
                let dst = acci.as_mut_ptr().add(t * bs + j);
                _mm_storeu_si128(dst as *mut __m128i, lo[t]);
                _mm_storeu_si128(dst.add(4) as *mut __m128i, hi[t]);
            }
            j += 8;
        }
        if j < width {
            dot_rows_tail(qa, a_stride, r, k0, bs, panel, width, ROWS, j, acci);
        }
    }

    /// AVX2 row-tile kernel bodies: 16-column register tiles (two
    /// i32x8 accumulators per row), same exact i16-pair scheme at
    /// twice the lane count. Generated per row count because
    /// `#[target_feature]` + const generics is newer than the
    /// toolchain floor this crate assumes.
    macro_rules! avx2_dot_rows {
        ($name:ident, $rows:literal) => {
            /// Safety: caller guarantees the `DotI8` slice contract
            /// and that AVX2 was runtime-detected.
            #[target_feature(enable = "avx2")]
            #[allow(clippy::too_many_arguments)]
            unsafe fn $name(
                qa: &[i8], a_stride: usize, r: usize, k0: usize,
                bs: usize, panel: &[i8], width: usize,
                acci: &mut [i32],
            ) {
                const ROWS: usize = $rows;
                let arows: [&[i8]; ROWS] = core::array::from_fn(|t| {
                    &qa[(r + t) * a_stride + k0
                        ..(r + t) * a_stride + k0 + bs]
                });
                let jj = width & !15;
                let kk = bs & !1;
                let mut j = 0usize;
                while j < jj {
                    let mut lo = [_mm256_setzero_si256(); ROWS];
                    let mut hi = [_mm256_setzero_si256(); ROWS];
                    let mut k = 0usize;
                    while k < kk {
                        let b0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                            panel.as_ptr().add((k0 + k) * width + j)
                                as *const __m128i,
                        ));
                        let b1 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                            panel.as_ptr().add((k0 + k + 1) * width + j)
                                as *const __m128i,
                        ));
                        for t in 0..ROWS {
                            let a0 = _mm256_set1_epi16(arows[t][k] as i16);
                            let a1 =
                                _mm256_set1_epi16(arows[t][k + 1] as i16);
                            let p = _mm256_add_epi16(
                                _mm256_mullo_epi16(a0, b0),
                                _mm256_mullo_epi16(a1, b1),
                            );
                            lo[t] = _mm256_add_epi32(
                                lo[t],
                                _mm256_cvtepi16_epi32(
                                    _mm256_castsi256_si128(p),
                                ),
                            );
                            hi[t] = _mm256_add_epi32(
                                hi[t],
                                _mm256_cvtepi16_epi32(
                                    _mm256_extracti128_si256::<1>(p),
                                ),
                            );
                        }
                        k += 2;
                    }
                    if k < bs {
                        let b0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                            panel.as_ptr().add((k0 + k) * width + j)
                                as *const __m128i,
                        ));
                        for t in 0..ROWS {
                            let a0 = _mm256_set1_epi16(arows[t][k] as i16);
                            let p = _mm256_mullo_epi16(a0, b0);
                            lo[t] = _mm256_add_epi32(
                                lo[t],
                                _mm256_cvtepi16_epi32(
                                    _mm256_castsi256_si128(p),
                                ),
                            );
                            hi[t] = _mm256_add_epi32(
                                hi[t],
                                _mm256_cvtepi16_epi32(
                                    _mm256_extracti128_si256::<1>(p),
                                ),
                            );
                        }
                    }
                    for t in 0..ROWS {
                        let dst = acci.as_mut_ptr().add(t * bs + j);
                        _mm256_storeu_si256(dst as *mut __m256i, lo[t]);
                        _mm256_storeu_si256(
                            dst.add(8) as *mut __m256i,
                            hi[t],
                        );
                    }
                    j += 16;
                }
                if j < width {
                    dot_rows_tail(
                        qa, a_stride, r, k0, bs, panel, width, ROWS, j,
                        acci,
                    );
                }
            }
        };
    }

    avx2_dot_rows!(avx2_dot_rows1, 1);
    avx2_dot_rows!(avx2_dot_rows2, 2);
    avx2_dot_rows!(avx2_dot_rows4, 4);

    macro_rules! sse2_entry {
        ($name:ident, $rows:literal) => {
            #[allow(clippy::too_many_arguments)]
            pub(super) fn $name(
                qa: &[i8], a_stride: usize, r: usize, k0: usize,
                bs: usize, panel: &[i8], width: usize,
                acci: &mut [i32], acc: &mut [f32],
            ) {
                // Safety: slice geometry is the DotI8 contract; SSE2
                // is baseline on x86_64.
                unsafe {
                    sse2_dot_rows::<$rows>(
                        qa, a_stride, r, k0, bs, panel, width, acci,
                    )
                }
                widen_rows(super::SSE2.widen, $rows, bs, width, acci,
                           acc);
            }
        };
    }

    macro_rules! avx2_entry {
        ($name:ident, $inner:ident, $rows:literal) => {
            #[allow(clippy::too_many_arguments)]
            pub(super) fn $name(
                qa: &[i8], a_stride: usize, r: usize, k0: usize,
                bs: usize, panel: &[i8], width: usize,
                acci: &mut [i32], acc: &mut [f32],
            ) {
                // Safety: slice geometry is the DotI8 contract; the
                // avx2 entries are only reachable through the AVX2
                // vtable, which `available()` gates on runtime
                // detection.
                unsafe {
                    $inner(qa, a_stride, r, k0, bs, panel, width, acci)
                }
                widen_rows(super::AVX2.widen, $rows, bs, width, acci,
                           acc);
            }
        };
    }

    sse2_entry!(dot_i8_sse2, 1);
    sse2_entry!(dot2_i8_sse2, 2);
    sse2_entry!(dot4_i8_sse2, 4);
    avx2_entry!(dot_i8_avx2, avx2_dot_rows1, 1);
    avx2_entry!(dot2_i8_avx2, avx2_dot_rows2, 2);
    avx2_entry!(dot4_i8_avx2, avx2_dot_rows4, 4);

    // -----------------------------------------------------------------
    // AVX-512 VNNI: `VPDPBUSD` consumes four K codes per dword lane
    // per instruction. The instruction wants an *unsigned* left
    // operand, so A codes are offset by +128 into [0, 255]:
    //
    //     Σ_k (a_k + 128) · b_k  =  Σ_k a_k·b_k  +  128 · Σ_k b_k
    //
    // One extra VPDPBUSD against an all-ones unsigned vector
    // accumulates the per-column `Σ b_k` alongside (shared by every A
    // row of the tile), and `acc − (colsum << 7)` restores the exact
    // signed dot. Each u8×i8 product fits i16 (|255·128| = 32640 <
    // 2¹⁵) and VPDPBUSD sums the four products into i32 without
    // intermediate saturation (unlike VPDPBUSDS), so the whole scheme
    // is exact integer arithmetic for any i8 codes, including -128.
    // -----------------------------------------------------------------

    /// Interleave four 16-byte panel rows into one zmm whose dword
    /// lane `j` holds bytes `[r0[j], r1[j], r2[j], r3[j]]` — the
    /// K-group layout VPDPBUSD consumes.
    ///
    /// Safety: caller must have AVX-512F detected (runtime) and pass
    /// rows of ≥ 16 valid bytes.
    #[target_feature(enable = "avx512f")]
    unsafe fn interleave4x16(
        r0: __m128i, r1: __m128i, r2: __m128i, r3: __m128i,
    ) -> __m512i {
        let t0 = _mm_unpacklo_epi8(r0, r1); // cols 0..8: r0,r1 pairs
        let t1 = _mm_unpackhi_epi8(r0, r1); // cols 8..16
        let t2 = _mm_unpacklo_epi8(r2, r3);
        let t3 = _mm_unpackhi_epi8(r2, r3);
        let u0 = _mm_unpacklo_epi16(t0, t2); // cols 0..4: r0..r3 dwords
        let u1 = _mm_unpackhi_epi16(t0, t2); // cols 4..8
        let u2 = _mm_unpacklo_epi16(t1, t3); // cols 8..12
        let u3 = _mm_unpackhi_epi16(t1, t3); // cols 12..16
        let z = _mm512_castsi128_si512(u0);
        let z = _mm512_inserti32x4::<1>(z, u1);
        let z = _mm512_inserti32x4::<2>(z, u2);
        _mm512_inserti32x4::<3>(z, u3)
    }

    /// Pack 4 consecutive offset-A codes (`a + 128`, zero past the
    /// block) into one dword for broadcasting.
    #[inline]
    fn offset_a_dword(arow: &[i8], k: usize, bs: usize) -> i32 {
        let byte = |i: usize| {
            if k + i < bs {
                (arow[k + i] as i16 + 128) as u8
            } else {
                0
            }
        };
        i32::from_le_bytes([byte(0), byte(1), byte(2), byte(3)])
    }

    /// AVX-512 VNNI row-tile kernel bodies: 16-column dword tiles, K
    /// consumed four codes at a time. Generated per row count like
    /// the AVX2 twin.
    macro_rules! avx512vnni_dot_rows {
        ($name:ident, $rows:literal) => {
            /// Safety: caller guarantees the `DotI8` slice contract
            /// and that avx512f+avx512bw+avx512vnni were
            /// runtime-detected.
            #[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
            #[allow(clippy::too_many_arguments)]
            unsafe fn $name(
                qa: &[i8], a_stride: usize, r: usize, k0: usize,
                bs: usize, panel: &[i8], width: usize,
                acci: &mut [i32],
            ) {
                const ROWS: usize = $rows;
                let arows: [&[i8]; ROWS] = core::array::from_fn(|t| {
                    &qa[(r + t) * a_stride + k0
                        ..(r + t) * a_stride + k0 + bs]
                });
                let ones = _mm512_set1_epi8(1);
                let jj = width & !15;
                let kk = bs & !3;
                let mut j = 0usize;
                while j < jj {
                    let mut acc = [_mm512_setzero_si512(); ROWS];
                    let mut colsum = _mm512_setzero_si512();
                    let mut k = 0usize;
                    while k < kk {
                        let b = interleave4x16(
                            _mm_loadu_si128(
                                panel.as_ptr().add((k0 + k) * width + j)
                                    as *const __m128i,
                            ),
                            _mm_loadu_si128(
                                panel
                                    .as_ptr()
                                    .add((k0 + k + 1) * width + j)
                                    as *const __m128i,
                            ),
                            _mm_loadu_si128(
                                panel
                                    .as_ptr()
                                    .add((k0 + k + 2) * width + j)
                                    as *const __m128i,
                            ),
                            _mm_loadu_si128(
                                panel
                                    .as_ptr()
                                    .add((k0 + k + 3) * width + j)
                                    as *const __m128i,
                            ),
                        );
                        colsum = _mm512_dpbusd_epi32(colsum, ones, b);
                        for t in 0..ROWS {
                            let a = _mm512_set1_epi32(offset_a_dword(
                                arows[t], k, bs,
                            ));
                            acc[t] = _mm512_dpbusd_epi32(acc[t], a, b);
                        }
                        k += 4;
                    }
                    if k < bs {
                        // K remainder (1-3 rows): missing rows load as
                        // zero, contributing 0 to both the dot and the
                        // column sum (the offset-A dword zero-pads the
                        // matching bytes).
                        let r0 = _mm_loadu_si128(
                            panel.as_ptr().add((k0 + k) * width + j)
                                as *const __m128i,
                        );
                        let r1 = if k + 1 < bs {
                            _mm_loadu_si128(
                                panel
                                    .as_ptr()
                                    .add((k0 + k + 1) * width + j)
                                    as *const __m128i,
                            )
                        } else {
                            _mm_setzero_si128()
                        };
                        let r2 = if k + 2 < bs {
                            _mm_loadu_si128(
                                panel
                                    .as_ptr()
                                    .add((k0 + k + 2) * width + j)
                                    as *const __m128i,
                            )
                        } else {
                            _mm_setzero_si128()
                        };
                        let r3 = _mm_setzero_si128();
                        let b = interleave4x16(r0, r1, r2, r3);
                        colsum = _mm512_dpbusd_epi32(colsum, ones, b);
                        for t in 0..ROWS {
                            let a = _mm512_set1_epi32(offset_a_dword(
                                arows[t], k, bs,
                            ));
                            acc[t] = _mm512_dpbusd_epi32(acc[t], a, b);
                        }
                    }
                    // acc holds Σ(a+128)·b; subtract 128·Σb per lane.
                    let corr = _mm512_slli_epi32::<7>(colsum);
                    for t in 0..ROWS {
                        _mm512_storeu_si512(
                            acci.as_mut_ptr().add(t * bs + j)
                                as *mut __m512i,
                            _mm512_sub_epi32(acc[t], corr),
                        );
                    }
                    j += 16;
                }
                if j < width {
                    dot_rows_tail(
                        qa, a_stride, r, k0, bs, panel, width, ROWS, j,
                        acci,
                    );
                }
            }
        };
    }

    avx512vnni_dot_rows!(avx512vnni_dot_rows1, 1);
    avx512vnni_dot_rows!(avx512vnni_dot_rows2, 2);
    avx512vnni_dot_rows!(avx512vnni_dot_rows4, 4);

    macro_rules! avx512vnni_entry {
        ($name:ident, $inner:ident, $rows:literal) => {
            #[allow(clippy::too_many_arguments)]
            pub(super) fn $name(
                qa: &[i8], a_stride: usize, r: usize, k0: usize,
                bs: usize, panel: &[i8], width: usize,
                acci: &mut [i32], acc: &mut [f32],
            ) {
                // Safety: slice geometry is the DotI8 contract; the
                // avx512vnni entries are only reachable through the
                // AVX512VNNI vtable, which `available()` gates on
                // runtime detection of all three features.
                unsafe {
                    $inner(qa, a_stride, r, k0, bs, panel, width, acci)
                }
                widen_rows(super::AVX512VNNI.widen, $rows, bs, width,
                           acci, acc);
            }
        };
    }

    avx512vnni_entry!(dot_i8_avx512vnni, avx512vnni_dot_rows1, 1);
    avx512vnni_entry!(dot2_i8_avx512vnni, avx512vnni_dot_rows2, 2);
    avx512vnni_entry!(dot4_i8_avx512vnni, avx512vnni_dot_rows4, 4);

    /// 8-lane i32→f32 widening (`vcvtdq2ps`) with a scalar tail.
    /// Bit-identical to [`super::widen_i32`]: the conversion is a
    /// per-lane correctly-rounded unary op — exactly what `v as f32`
    /// performs — so vector width cannot change any output.
    ///
    /// Safety: caller must have runtime-detected AVX2.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn widen_avx2(
        acci: &[i32], acc: &mut [f32], width: usize,
    ) {
        let mut j = 0usize;
        while j + 8 <= width {
            _mm256_storeu_ps(
                acc.as_mut_ptr().add(j),
                _mm256_cvtepi32_ps(_mm256_loadu_si256(
                    acci.as_ptr().add(j) as *const __m256i,
                )),
            );
            j += 8;
        }
        while j < width {
            acc[j] = acci[j] as f32;
            j += 1;
        }
    }

    // -----------------------------------------------------------------
    // f32 FMA primitives (v2 contract): 8-lane `_mm256_fmadd_ps`
    // bodies with a scalar `mul_add` tail — every lane performs the
    // same sequence of correctly-rounded fused operations as the
    // scalar reference, so results are bit-identical.
    // -----------------------------------------------------------------

    /// Safety: caller must have runtime-detected AVX2 **and** FMA
    /// (separate CPUID bits), and pass `b0..b3` of ≥ `acc.len()`.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn fma4_avx2(
        a: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32],
        acc: &mut [f32],
    ) {
        let n = acc.len();
        let a0 = _mm256_set1_ps(a[0]);
        let a1 = _mm256_set1_ps(a[1]);
        let a2 = _mm256_set1_ps(a[2]);
        let a3 = _mm256_set1_ps(a[3]);
        let mut j = 0usize;
        while j + 8 <= n {
            let mut s = _mm256_loadu_ps(acc.as_ptr().add(j));
            s = _mm256_fmadd_ps(a0, _mm256_loadu_ps(b0.as_ptr().add(j)), s);
            s = _mm256_fmadd_ps(a1, _mm256_loadu_ps(b1.as_ptr().add(j)), s);
            s = _mm256_fmadd_ps(a2, _mm256_loadu_ps(b2.as_ptr().add(j)), s);
            s = _mm256_fmadd_ps(a3, _mm256_loadu_ps(b3.as_ptr().add(j)), s);
            _mm256_storeu_ps(acc.as_mut_ptr().add(j), s);
            j += 8;
        }
        while j < n {
            let mut s = acc[j];
            s = a[0].mul_add(b0[j], s);
            s = a[1].mul_add(b1[j], s);
            s = a[2].mul_add(b2[j], s);
            s = a[3].mul_add(b3[j], s);
            acc[j] = s;
            j += 1;
        }
    }

    /// Safety: see [`fma4_avx2`].
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn fma1_avx2(av: f32, brow: &[f32], acc: &mut [f32]) {
        let n = acc.len();
        let a = _mm256_set1_ps(av);
        let mut j = 0usize;
        while j + 8 <= n {
            let s = _mm256_fmadd_ps(
                a,
                _mm256_loadu_ps(brow.as_ptr().add(j)),
                _mm256_loadu_ps(acc.as_ptr().add(j)),
            );
            _mm256_storeu_ps(acc.as_mut_ptr().add(j), s);
            j += 8;
        }
        while j < n {
            acc[j] = av.mul_add(brow[j], acc[j]);
            j += 1;
        }
    }
}

// ---------------------------------------------------------------------
// aarch64 backend: NEON (baseline)
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{dot_rows_tail, widen_rows};
    use core::arch::aarch64::*;

    /// NEON row-tile kernel: 8-column register tiles; `vmlal_s16`
    /// widens and accumulates in one exact i32 op per 4 lanes. NEON
    /// is baseline on aarch64 — no feature check needed.
    ///
    /// Safety: caller guarantees the slice geometry of the `DotI8`
    /// contract (see the SSE2 twin).
    #[allow(clippy::too_many_arguments)]
    unsafe fn neon_dot_rows<const ROWS: usize>(
        qa: &[i8], a_stride: usize, r: usize, k0: usize, bs: usize,
        panel: &[i8], width: usize, acci: &mut [i32],
    ) {
        let arows: [&[i8]; ROWS] = core::array::from_fn(|t| {
            &qa[(r + t) * a_stride + k0..(r + t) * a_stride + k0 + bs]
        });
        let jj = width & !7;
        let mut j = 0usize;
        while j < jj {
            let mut lo = [vdupq_n_s32(0); ROWS];
            let mut hi = [vdupq_n_s32(0); ROWS];
            for k in 0..bs {
                let b = vmovl_s8(vld1_s8(
                    panel.as_ptr().add((k0 + k) * width + j),
                ));
                let bl = vget_low_s16(b);
                let bh = vget_high_s16(b);
                for t in 0..ROWS {
                    let a = vdup_n_s16(arows[t][k] as i16);
                    lo[t] = vmlal_s16(lo[t], bl, a);
                    hi[t] = vmlal_s16(hi[t], bh, a);
                }
            }
            for t in 0..ROWS {
                let dst = acci.as_mut_ptr().add(t * bs + j);
                vst1q_s32(dst, lo[t]);
                vst1q_s32(dst.add(4), hi[t]);
            }
            j += 8;
        }
        if j < width {
            dot_rows_tail(qa, a_stride, r, k0, bs, panel, width, ROWS, j, acci);
        }
    }

    macro_rules! vtable_entry {
        ($name:ident, $rows:literal) => {
            #[allow(clippy::too_many_arguments)]
            pub(super) fn $name(
                qa: &[i8], a_stride: usize, r: usize, k0: usize,
                bs: usize, panel: &[i8], width: usize,
                acci: &mut [i32], acc: &mut [f32],
            ) {
                // Safety: slice geometry is the DotI8 contract; NEON
                // is unconditionally available on aarch64.
                unsafe {
                    neon_dot_rows::<$rows>(
                        qa, a_stride, r, k0, bs, panel, width, acci,
                    )
                }
                widen_rows(super::NEON.widen, $rows, bs, width, acci,
                           acc);
            }
        };
    }

    vtable_entry!(dot_i8_neon, 1);
    vtable_entry!(dot2_i8_neon, 2);
    vtable_entry!(dot4_i8_neon, 4);

    /// 4-lane i32→f32 widening (`vcvtq_f32_s32`) with a scalar tail —
    /// per-lane correctly-rounded, identical bits to `v as f32`; see
    /// the AVX2 twin.
    ///
    /// Safety: NEON is baseline on aarch64.
    pub(super) unsafe fn widen_neon(
        acci: &[i32], acc: &mut [f32], width: usize,
    ) {
        let mut j = 0usize;
        while j + 4 <= width {
            vst1q_f32(
                acc.as_mut_ptr().add(j),
                vcvtq_f32_s32(vld1q_s32(acci.as_ptr().add(j))),
            );
            j += 4;
        }
        while j < width {
            acc[j] = acci[j] as f32;
            j += 1;
        }
    }

    // -----------------------------------------------------------------
    // f32 FMA primitives (v2 contract): 4-lane `vfmaq_f32` bodies with
    // a scalar `mul_add` tail — same per-lane fused op sequence as the
    // scalar reference, so results are bit-identical.
    // -----------------------------------------------------------------

    /// Safety: caller must pass `b0..b3` of ≥ `acc.len()`. NEON (with
    /// fused FMA) is baseline on aarch64.
    pub(super) unsafe fn fma4_neon(
        a: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32],
        acc: &mut [f32],
    ) {
        let n = acc.len();
        let a0 = vdupq_n_f32(a[0]);
        let a1 = vdupq_n_f32(a[1]);
        let a2 = vdupq_n_f32(a[2]);
        let a3 = vdupq_n_f32(a[3]);
        let mut j = 0usize;
        while j + 4 <= n {
            let mut s = vld1q_f32(acc.as_ptr().add(j));
            s = vfmaq_f32(s, a0, vld1q_f32(b0.as_ptr().add(j)));
            s = vfmaq_f32(s, a1, vld1q_f32(b1.as_ptr().add(j)));
            s = vfmaq_f32(s, a2, vld1q_f32(b2.as_ptr().add(j)));
            s = vfmaq_f32(s, a3, vld1q_f32(b3.as_ptr().add(j)));
            vst1q_f32(acc.as_mut_ptr().add(j), s);
            j += 4;
        }
        while j < n {
            let mut s = acc[j];
            s = a[0].mul_add(b0[j], s);
            s = a[1].mul_add(b1[j], s);
            s = a[2].mul_add(b2[j], s);
            s = a[3].mul_add(b3[j], s);
            acc[j] = s;
            j += 1;
        }
    }

    /// Safety: see [`fma4_neon`].
    pub(super) unsafe fn fma1_neon(av: f32, brow: &[f32], acc: &mut [f32]) {
        let n = acc.len();
        let a = vdupq_n_f32(av);
        let mut j = 0usize;
        while j + 4 <= n {
            let s = vfmaq_f32(
                vld1q_f32(acc.as_ptr().add(j)),
                a,
                vld1q_f32(brow.as_ptr().add(j)),
            );
            vst1q_f32(acc.as_mut_ptr().add(j), s);
            j += 4;
        }
        while j < n {
            acc[j] = av.mul_add(brow[j], acc[j]);
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Exact i64 reference for a `rows`-row block dot.
    #[allow(clippy::too_many_arguments)]
    fn ref_dot(
        qa: &[i8], a_stride: usize, r: usize, k0: usize, bs: usize,
        panel: &[i8], width: usize, rows: usize,
    ) -> Vec<i64> {
        let mut out = vec![0i64; rows * width];
        for t in 0..rows {
            let arow = &qa[(r + t) * a_stride + k0..];
            for j in 0..width {
                let mut s = 0i64;
                for k in 0..bs {
                    s += arow[k] as i64 * panel[(k0 + k) * width + j] as i64;
                }
                out[t * width + j] = s;
            }
        }
        out
    }

    fn rand_i8(n: usize, rng: &mut Pcg64) -> Vec<i8> {
        (0..n)
            .map(|_| ((rng.uniform() * 255.0) as i32 - 127).clamp(-127, 127) as i8)
            .collect()
    }

    #[test]
    fn scalar_always_available_and_selected_from_available() {
        let avail = available();
        assert_eq!(avail[0].name, "scalar");
        let sel = select();
        assert!(avail.iter().any(|k| k.name == sel.name));
        assert_eq!(detect_best().name, avail.last().unwrap().name);
        assert!(by_name("scalar").is_some());
        assert!(by_name("definitely-not-a-backend").is_none());
        assert!(!cpu_features().is_empty() || cfg!(not(any(
            target_arch = "x86_64",
            target_arch = "aarch64"
        ))));
    }

    #[test]
    fn override_parse_rules() {
        assert!(parse_override(None).is_none());
        assert!(parse_override(Some("")).is_none());
        assert_eq!(parse_override(Some("scalar")).unwrap().name, "scalar");
    }

    #[test]
    #[should_panic(expected = "not an available kernel backend")]
    fn override_rejects_unknown_backend() {
        parse_override(Some("vax-11"));
    }

    #[test]
    fn preferred_backend_survives_round_trip() {
        // The preference is process-global: hold the test lock so the
        // costmodel calibration test (same binary) can't interleave
        // its own set_preferred between our set and assert.
        let _g = PREFERRED_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let before = select();
        set_preferred(&SCALAR);
        if std::env::var("PALLAS_KERNEL").map_or(true, |v| v.is_empty()) {
            assert_eq!(select().name, "scalar");
        }
        set_preferred(before);
        assert_eq!(select().name, before.name);
    }

    /// The load-bearing test for the SIMD backends: every available
    /// backend × row tile × awkward (bs, width, k0) geometry must
    /// reproduce the exact i64 dot — including block sizes not
    /// divisible by any vector width and single-column tails.
    #[test]
    fn all_backends_match_i64_reference_on_awkward_shapes() {
        let mut rng = Pcg64::new(0xD07);
        for &bs in &[1usize, 2, 3, 5, 8, 15, 16, 17, 24, 33, 64] {
            for &width in &[1usize, 2, 7, 8, 9, 15, 16, 17, 24, 31, 33] {
                // Engine contract: width ≤ bs (panel width is
                // min(block, cols remainder); acci rows sit bs apart).
                if width > bs {
                    continue;
                }
                for &k0 in &[0usize, bs] {
                    let prows = k0 + bs;
                    let a_stride = prows;
                    let qa = rand_i8(4 * a_stride, &mut rng);
                    let panel = rand_i8(prows * width, &mut rng);
                    let want =
                        ref_dot(&qa, a_stride, 0, k0, bs, &panel, width, 4);
                    for kn in available() {
                        let mut acci = vec![0i32; 4 * bs];
                        let mut acc = vec![0.0f32; 4 * bs];
                        for (rows, dot) in [
                            (1usize, kn.dot_i8),
                            (2, kn.dot2_i8),
                            (4, kn.dot4_i8),
                        ] {
                            acci.fill(i32::MIN);
                            acc.fill(f32::NAN);
                            dot(
                                &qa, a_stride, 0, k0, bs, &panel, width,
                                &mut acci, &mut acc,
                            );
                            for t in 0..rows {
                                for j in 0..width {
                                    let w = want[t * width + j];
                                    assert_eq!(
                                        acci[t * bs + j] as i64,
                                        w,
                                        "{} rows={rows} bs={bs} \
                                         width={width} k0={k0} t={t} j={j}",
                                        kn.name
                                    );
                                    assert_eq!(
                                        acc[t * bs + j],
                                        w as f32,
                                        "{} widen rows={rows} bs={bs} \
                                         width={width}",
                                        kn.name
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn saturated_codes_stay_exact_on_every_backend() {
        // All-(-127/127) codes drive the i16-pair scheme to its
        // extremes (|pair sum| = 32258); the integer result must still
        // be exact on every backend at the widest paper block size.
        for &bs in &[128usize, 256] {
            let width = 16;
            let qa = vec![127i8; 4 * bs];
            let mut panel = vec![-127i8; bs * width];
            for (i, v) in panel.iter_mut().enumerate() {
                if i % 2 == 0 {
                    *v = 127;
                }
            }
            let want = ref_dot(&qa, bs, 0, 0, bs, &panel, width, 4);
            for kn in available() {
                let mut acci = vec![0i32; 4 * bs];
                let mut acc = vec![0.0f32; 4 * bs];
                (kn.dot4_i8)(
                    &qa, bs, 0, 0, bs, &panel, width, &mut acci, &mut acc,
                );
                for t in 0..4 {
                    for j in 0..width {
                        assert_eq!(
                            acci[t * bs + j] as i64,
                            want[t * width + j],
                            "{} bs={bs} t={t} j={j}",
                            kn.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn offset_correction_exact_at_code_extremes() {
        // The VNNI scheme computes Σ(a+128)·b − 128·Σb. Drive the
        // offset and the column-sum correction to their extremes:
        // A at the −128-adjacent end (offset byte 1) and at +127
        // (offset byte 255), B saturated at ±127 so Σb is as large as
        // it gets. Every backend must still reproduce the exact i64
        // dot — including K remainders (bs % 4 ≠ 0) and sub-16 column
        // tails, which exercise the zero-padded interleave rows.
        for &(alo, ahi) in &[(-127i8, -127i8), (127, 127), (-127, 127)] {
            for &bv in &[-127i8, 127] {
                for &bs in &[4usize, 7, 16, 37, 128] {
                    for &width in &[1usize, 4, 15, 16] {
                        if width > bs {
                            continue;
                        }
                        let qa: Vec<i8> = (0..4 * bs)
                            .map(|i| if i % 2 == 0 { alo } else { ahi })
                            .collect();
                        let mut panel = vec![bv; bs * width];
                        for (i, v) in panel.iter_mut().enumerate() {
                            if i % 3 == 0 {
                                *v = -bv;
                            }
                        }
                        let want =
                            ref_dot(&qa, bs, 0, 0, bs, &panel, width, 4);
                        for kn in available() {
                            let mut acci = vec![i32::MIN; 4 * bs];
                            let mut acc = vec![f32::NAN; 4 * bs];
                            (kn.dot4_i8)(
                                &qa, bs, 0, 0, bs, &panel, width,
                                &mut acci, &mut acc,
                            );
                            for t in 0..4 {
                                for j in 0..width {
                                    assert_eq!(
                                        acci[t * bs + j] as i64,
                                        want[t * width + j],
                                        "{} a=({alo},{ahi}) b={bv} \
                                         bs={bs} width={width} t={t} \
                                         j={j}",
                                        kn.name
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn unavailable_preference_falls_back_to_detected() {
        // A calibrated preference can name a backend the running CPU
        // does not provide (warm states travel between hosts): select
        // must fall back to the detected best, not panic — only the
        // env override is a hard error.
        static GHOST: Kernels = Kernels {
            name: "test-unavailable-isa",
            dot_i8: dot_i8_scalar,
            dot2_i8: dot2_i8_scalar,
            dot4_i8: dot4_i8_scalar,
            dot_i4: dot_i4_scalar,
            dot2_i4: dot2_i4_scalar,
            dot4_i4: dot4_i4_scalar,
            dense2: dense_rows2,
            widen: widen_i32,
        };
        let _g = PREFERRED_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let before = select();
        set_preferred(&GHOST);
        if std::env::var("PALLAS_KERNEL").map_or(true, |v| v.is_empty()) {
            assert_eq!(select().name, detect_best().name,
                       "unavailable preference must fall back");
        }
        set_preferred(before);
        assert_eq!(select().name, before.name);
    }

    fn rand_f32(n: usize, rng: &mut Pcg64) -> Vec<f32> {
        (0..n).map(|_| (rng.uniform() as f32 - 0.5) * 4.0).collect()
    }

    #[test]
    fn f32_simd_bit_identical_to_scalar_mul_add() {
        // The v2 contract's load-bearing property: the vectorized FMA
        // path and the scalar mul_add path produce the same bits on
        // arbitrary (non-integer) data, because every lane performs
        // the same sequence of correctly-rounded fused ops.
        let mut rng = Pcg64::new(0xF3A);
        for &(bs, width) in &[(5usize, 3usize), (16, 16), (33, 19),
                              (64, 31)] {
            let af = rand_f32(2 * bs, &mut rng);
            let panel = rand_f32(bs * width, &mut rng);
            let mut simd0 = vec![0.0f32; bs];
            let mut simd1 = vec![0.0f32; bs];
            let mut sc0 = vec![0.0f32; bs];
            let mut sc1 = vec![0.0f32; bs];
            let prev = f32_simd_enabled();
            set_f32_simd_enabled(true);
            panel_dot2(&af, bs, 0, 0, bs, &panel, width, &mut simd0,
                       &mut simd1);
            set_f32_simd_enabled(false);
            panel_dot2(&af, bs, 0, 0, bs, &panel, width, &mut sc0,
                       &mut sc1);
            set_f32_simd_enabled(prev);
            assert_eq!(simd0, sc0, "row0 bs={bs} width={width}");
            assert_eq!(simd1, sc1, "row1 bs={bs} width={width}");
        }
    }

    /// The v1 (seed) f32 op order, kept verbatim for the bridge test:
    /// 4-wide grouped unfused sums with a zero-code skip in the K
    /// remainder.
    #[allow(clippy::too_many_arguments)]
    fn panel_dot_v1(
        af: &[f32], a_stride: usize, r: usize, k0: usize, bs: usize,
        panel: &[f32], width: usize, acc: &mut [f32],
    ) {
        acc[..width].fill(0.0);
        let arow = &af[r * a_stride + k0..r * a_stride + k0 + bs];
        let kk = bs & !3;
        for k in (0..kk).step_by(4) {
            let a0 = arow[k];
            let a1 = arow[k + 1];
            let a2 = arow[k + 2];
            let a3 = arow[k + 3];
            let b0 = &panel[(k0 + k) * width..][..width];
            let b1 = &panel[(k0 + k + 1) * width..][..width];
            let b2 = &panel[(k0 + k + 2) * width..][..width];
            let b3 = &panel[(k0 + k + 3) * width..][..width];
            for j in 0..width {
                acc[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j]
                    + a3 * b3[j];
            }
        }
        for k in kk..bs {
            let av = arow[k];
            if av == 0.0 {
                continue;
            }
            let brow = &panel[(k0 + k) * width..][..width];
            for j in 0..width {
                acc[j] += av * brow[j];
            }
        }
    }

    #[test]
    fn v2_bridge_bounds_drift_from_v1_order() {
        // The re-anchor is a deliberate contract change; this bridge
        // bounds the drift. On arbitrary f32 data the two orders may
        // differ by rounding only (tight relative tolerance); on
        // integer-code-valued data within the 2²⁴ exact range they
        // must agree bit-for-bit — which is why the quantized SimF32 /
        // residual paths did not move under the re-anchor.
        let mut rng = Pcg64::new(0xB21D);
        for &(bs, width) in &[(16usize, 16usize), (33, 19), (64, 32)] {
            let af = rand_f32(bs, &mut rng);
            let panel = rand_f32(bs * width, &mut rng);
            let mut v2 = vec![0.0f32; bs];
            let mut v1 = vec![0.0f32; bs];
            panel_dot(&af, bs, 0, 0, bs, &panel, width, &mut v2);
            panel_dot_v1(&af, bs, 0, 0, bs, &panel, width, &mut v1);
            for j in 0..width {
                let denom = v1[j].abs().max(1.0);
                let rel = (v2[j] - v1[j]).abs() / denom;
                assert!(rel < 1e-5,
                        "drift {rel} at j={j} bs={bs} width={width}");
            }
            // integer-code-valued data: both orders are exact
            let qa = rand_i8(bs, &mut rng);
            let qp = rand_i8(bs * width, &mut rng);
            let afi: Vec<f32> =
                qa.iter().map(|&v| v as f32).collect();
            let pfi: Vec<f32> =
                qp.iter().map(|&v| v as f32).collect();
            panel_dot(&afi, bs, 0, 0, bs, &pfi, width, &mut v2);
            panel_dot_v1(&afi, bs, 0, 0, bs, &pfi, width, &mut v1);
            assert_eq!(&v2[..width], &v1[..width],
                       "integer-exact range bs={bs} width={width}");
        }
    }

    #[test]
    fn widen_simd_bit_identical_to_scalar_on_every_backend() {
        // Every backend's `widen` vtable slot must reproduce the
        // scalar floor bit-for-bit across awkward widths (vector
        // chunks + tails), with the vectorized path both enabled and
        // forced off. Values span the f32-exact range the engine
        // guarantees (|v| ≤ 2²⁴).
        let mut rng = Pcg64::new(0x51D3);
        for &width in &[1usize, 3, 4, 7, 8, 9, 15, 16, 17, 31, 64] {
            let acci: Vec<i32> = (0..width)
                .map(|_| {
                    ((rng.uniform() - 0.5) * 2.0 * ((1 << 24) as f64))
                        as i32
                })
                .collect();
            let mut want = vec![f32::NAN; width];
            widen_i32(&acci, &mut want, width);
            for kn in available() {
                for on in [true, false] {
                    let prev = widen_simd_enabled();
                    set_widen_simd_enabled(on);
                    let mut got = vec![f32::NAN; width];
                    (kn.widen)(&acci, &mut got, width);
                    set_widen_simd_enabled(prev);
                    assert_eq!(
                        got, want,
                        "{} widen width={width} simd={on}",
                        kn.name
                    );
                }
            }
        }
    }

    #[test]
    fn widen_reduce_matches_sequential_i64_sum() {
        // The deterministic tree reduction must equal the plain
        // sequential i64 sum (associativity makes every integer
        // order equal) and must not depend on how the same numbers
        // are partitioned into parts.
        let mut rng = Pcg64::new(0xED0C);
        for &width in &[1usize, 5, 16, 33] {
            for &nparts in &[1usize, 2, 3, 4, 7] {
                let parts: Vec<Vec<i32>> = (0..nparts)
                    .map(|_| {
                        (0..width)
                            .map(|_| {
                                ((rng.uniform() - 0.5) * 65536.0)
                                    as i32
                            })
                            .collect()
                    })
                    .collect();
                let refs: Vec<&[i32]> =
                    parts.iter().map(|p| p.as_slice()).collect();
                let mut got = vec![f32::NAN; width];
                widen_reduce_i32(&refs, &mut got, width);
                for j in 0..width {
                    let want: i64 = parts
                        .iter()
                        .map(|p| p[j] as i64)
                        .sum();
                    assert_eq!(
                        got[j],
                        want as f32,
                        "width={width} nparts={nparts} j={j}"
                    );
                }
            }
        }
        // single part degenerates to widen_i32
        let one = [7i32, -3, 1 << 20];
        let mut got = [f32::NAN; 3];
        let mut want = [f32::NAN; 3];
        widen_reduce_i32(&[&one], &mut got, 3);
        widen_i32(&one, &mut want, 3);
        assert_eq!(got, want);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "exceeds the f32-exact range")]
    fn widen_reduce_guard_fires_past_exactness_bound() {
        // Partials may be individually in range while their sum is
        // not — the root guard must catch that case.
        let a = [1 << 24];
        let b = [1 << 24];
        let mut acc = [0.0f32];
        widen_reduce_i32(&[&a, &b], &mut acc, 1);
    }

    /// Exact i64 reference for a `rows`-row **nibble-panel** block dot
    /// (the kernel-level face of the INT4 oracle).
    #[allow(clippy::too_many_arguments)]
    fn ref_dot_i4(
        qa: &[i8], a_stride: usize, r: usize, k0: usize, bs: usize,
        panel: &[u8], width: usize, rows: usize,
    ) -> Vec<i64> {
        let rw = width.div_ceil(2);
        let mut out = vec![0i64; rows * width];
        for t in 0..rows {
            let arow = &qa[(r + t) * a_stride + k0..];
            for j in 0..width {
                let mut s = 0i64;
                for k in 0..bs {
                    s += arow[k] as i64
                        * nibble_at(&panel[(k0 + k) * rw..][..rw], j)
                            as i64;
                }
                out[t * width + j] = s;
            }
        }
        out
    }

    fn rand_nibble_panel(
        prows: usize, width: usize, rng: &mut Pcg64,
    ) -> Vec<u8> {
        let rw = width.div_ceil(2);
        let mut p = vec![0u8; prows * rw];
        for k in 0..prows {
            for j in 0..width {
                let code =
                    ((rng.uniform() * 15.0) as i32 - 7).clamp(-7, 7) as i8;
                let b = &mut p[k * rw + (j >> 1)];
                if j & 1 == 0 {
                    *b = (*b & 0xF0) | (code as u8 & 0x0F);
                } else {
                    *b = (*b & 0x0F) | ((code as u8 & 0x0F) << 4);
                }
            }
        }
        p
    }

    /// INT4 twin of the i8 load-bearing sweep: every backend × row
    /// tile × awkward geometry — including **odd widths**, where the
    /// final high nibble of each packed row is padding — must
    /// reproduce the exact i64 nibble dot. A runs both as i4-range
    /// codes and as full-range i8 codes (the staged path streams i8
    /// residuals through these kernels).
    #[test]
    fn all_backends_match_i64_nibble_reference() {
        let mut rng = Pcg64::new(0x14D0);
        for &bs in &[1usize, 2, 3, 5, 8, 15, 16, 17, 24, 33, 64] {
            for &width in &[1usize, 2, 5, 7, 8, 9, 15, 16, 17, 31, 33] {
                if width > bs {
                    continue;
                }
                for &k0 in &[0usize, bs] {
                    let prows = k0 + bs;
                    let a_stride = prows;
                    for a_full_range in [false, true] {
                        let qa: Vec<i8> = if a_full_range {
                            rand_i8(4 * a_stride, &mut rng)
                        } else {
                            (0..4 * a_stride)
                                .map(|_| {
                                    ((rng.uniform() * 15.0) as i32 - 7)
                                        .clamp(-7, 7)
                                        as i8
                                })
                                .collect()
                        };
                        let panel =
                            rand_nibble_panel(prows, width, &mut rng);
                        let want = ref_dot_i4(
                            &qa, a_stride, 0, k0, bs, &panel, width, 4,
                        );
                        for kn in available() {
                            let mut acci = vec![i32::MIN; 4 * bs];
                            let mut acc = vec![f32::NAN; 4 * bs];
                            for (rows, dot) in [
                                (1usize, kn.dot_i4),
                                (2, kn.dot2_i4),
                                (4, kn.dot4_i4),
                            ] {
                                acci.fill(i32::MIN);
                                acc.fill(f32::NAN);
                                dot(
                                    &qa, a_stride, 0, k0, bs, &panel,
                                    width, &mut acci, &mut acc,
                                );
                                for t in 0..rows {
                                    for j in 0..width {
                                        let w = want[t * width + j];
                                        assert_eq!(
                                            acci[t * bs + j] as i64,
                                            w,
                                            "{} i4 rows={rows} bs={bs} \
                                             width={width} k0={k0} \
                                             full={a_full_range} t={t} \
                                             j={j}",
                                            kn.name
                                        );
                                        assert_eq!(
                                            acc[t * bs + j],
                                            w as f32,
                                            "{} i4 widen rows={rows} \
                                             bs={bs} width={width}",
                                            kn.name
                                        );
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn i4_saturated_codes_stay_exact_on_every_backend() {
        // All-(±7) nibbles against saturated i8 A codes (the staged
        // residual extreme): |partial| grows as 889·k — still exact
        // i32 integers at the widest paper block size.
        for &bs in &[128usize, 256] {
            let width = 15; // odd: exercises the padding nibble
            let qa = vec![127i8; 4 * bs];
            let rw = width.div_ceil(2);
            let mut panel = vec![0u8; bs * rw];
            for k in 0..bs {
                for j in 0..width {
                    let code = if (k + j) % 2 == 0 { 7i8 } else { -7 };
                    let b = &mut panel[k * rw + (j >> 1)];
                    if j & 1 == 0 {
                        *b = (*b & 0xF0) | (code as u8 & 0x0F);
                    } else {
                        *b = (*b & 0x0F) | ((code as u8 & 0x0F) << 4);
                    }
                }
            }
            let want = ref_dot_i4(&qa, bs, 0, 0, bs, &panel, width, 4);
            for kn in available() {
                let mut acci = vec![0i32; 4 * bs];
                let mut acc = vec![0.0f32; 4 * bs];
                (kn.dot4_i4)(
                    &qa, bs, 0, 0, bs, &panel, width, &mut acci,
                    &mut acc,
                );
                for t in 0..4 {
                    for j in 0..width {
                        assert_eq!(
                            acci[t * bs + j] as i64,
                            want[t * width + j],
                            "{} i4 bs={bs} t={t} j={j}",
                            kn.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn row_tiles_compose() {
        // dot4 ≡ dot2 × 2 ≡ dot1 × 4 on every backend (the engine
        // mixes tile sizes freely at panel tails).
        let mut rng = Pcg64::new(0xC0);
        let (bs, width, stride) = (24usize, 19usize, 29usize);
        let qa = rand_i8(6 * stride, &mut rng);
        let panel = rand_i8(bs * width, &mut rng);
        for kn in available() {
            let mut i4 = vec![0i32; 4 * bs];
            let mut a4 = vec![0.0f32; 4 * bs];
            (kn.dot4_i8)(&qa, stride, 1, 0, bs, &panel, width, &mut i4, &mut a4);
            for t in 0..4 {
                let mut i1 = vec![0i32; bs];
                let mut a1 = vec![0.0f32; bs];
                (kn.dot_i8)(
                    &qa, stride, 1 + t, 0, bs, &panel, width, &mut i1,
                    &mut a1,
                );
                assert_eq!(&i4[t * bs..t * bs + width], &i1[..width],
                           "{} t={t}", kn.name);
            }
            let mut i2 = vec![0i32; 2 * bs];
            let mut a2 = vec![0.0f32; 2 * bs];
            (kn.dot2_i8)(&qa, stride, 3, 0, bs, &panel, width, &mut i2, &mut a2);
            assert_eq!(&i2[..width], &i4[2 * bs..2 * bs + width], "{}", kn.name);
            assert_eq!(&i2[bs..bs + width], &i4[3 * bs..3 * bs + width],
                       "{}", kn.name);
        }
    }
}
