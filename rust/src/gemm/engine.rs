//! # Unified plan/execute GEMM engine
//!
//! One outer loop, three precisions, no duplicated kernels: this module
//! replaces the separate `dense::matmul` / `int8::block_gemm` /
//! `int8::fallback_gemm` triple-loops (retained as `*_baseline` oracles)
//! with a [`GemmPlan`] built once per (operands, shapes, precision) and
//! executed any number of times.
//!
//! ## Plan lifecycle
//!
//! ```text
//!   quantize            plan (once)                 execute (per GEMM)
//!   ─────────►  GemmPlan::new_{dense,int8,fallback} ─► plan.execute()
//!                 │                                      │
//!                 ├─ pack operands (cached on the        ├─ split C into
//!                 │  quant structs, so a second plan     │  disjoint &mut
//!                 │  over the same weights is free):     │  row panels
//!                 │   SimF32: A codes → f32 row-major    ├─ LPT-schedule
//!                 │           B codes → f32 col panels   │  panels by weight
//!                 │   Int8:   A codes = stored i8 rows   └─ per-thread
//!                 │           B codes → i8 col panels       workspace, no
//!                 │           (4x fewer packed bytes)       alloc in hot
//!                 └─ per-row-panel cost weights             loop
//!                    from the fallback u-mask
//! ```
//!
//! ## Data paths and exactness
//!
//! [`DataPath`] selects what the int8 microkernels actually stream:
//!
//! * `SimF32` — the seed-compatible simulation: int8 codes widened to
//!   cached f32 copies, f32 FMA kernels. 4x the operand bytes the
//!   format promises, but bit-equal to int32 accumulation (below).
//! * `Int8` — the true INT8 data flow: i8 row-major A (the stored
//!   codes, zero-copy), i8 column-panel B, and `panel_dot*_i8`
//!   kernels accumulating in **i32**, widened to f32 once per K-block
//!   before the shared per-block scale-FMA.
//! * `Int4` — the precision lattice's bottom rung: i8-stored A codes
//!   in [-7, 7], **nibble-packed** B column panels, `dot*_i4`
//!   kernels. Never auto-selected. [`GemmPlan::new_staged`] runs the
//!   per-block Int4→Int8→f32 ladder on this path: every block's INT4
//!   base, plus an i8 residual through the same nibble panels where
//!   the threshold promotes, plus an exact f32 remainder against B's
//!   f32 code panels where it promotes again — exact within
//!   [`I4_EXACT_MAX_BS`] (the i8-residual × i4-panel bound).
//!
//! Both paths are **bit-identical** to each other and to the
//! `*_baseline` oracles whenever `bs ≤ `[`I8_EXACT_MAX_BS`]: every
//! code product is ≤ 127², so each partial sum of a K-block dot stays
//! ≤ `bs·127² ≤ 2²⁴` — exactly representable in f32 — which makes the
//! f32 kernel's adds exact integer arithmetic and the i32→f32
//! widening lossless. All paper block sizes (32–256) sit far inside
//! the bound; past it the i8 path still runs (i32 cannot overflow
//! before `bs ≈ 1.3e5`) but a debug assertion guards the widening and
//! `new_int8`/`new_fallback` auto-select `SimF32`.
//!
//! Construction packs operands; execution allocates only the output and
//! one small per-thread accumulator. Repeated GEMMs over the same
//! operands (weights across microbatches, bench iterations) skip all
//! conversion and packing — the caches live on [`BlockQuant`] /
//! [`FallbackQuant`] themselves.
//!
//! For cross-step reuse the plan additionally splits into a
//! **cacheable weight half** ([`WeightPlan`]: owned quantized weight
//! + eagerly packed panels + pinned backend) and a **per-call
//! activation half** re-planned against it each microstep —
//! `gemm::pipeline` caches the weight halves across training steps.
//! See `docs/ARCHITECTURE.md` for the full packed-once vs per-call
//! breakdown.
//!
//! ## Packing layout
//!
//! The B operand is repacked column-panel-contiguous ([`PanelPack`]):
//!
//! ```text
//!   row-major B (stride = pcols)        panel pack (stride = width)
//!   ┌────────┬────────┬──────┐          ┌──────────────┐
//!   │ panel0 │ panel1 │ pan2 │          │ panel0 rows  │ contiguous
//!   │  ....  │  ....  │ .... │   ──►    ├──────────────┤
//!   │  ....  │  ....  │ .... │          │ panel1 rows  │ contiguous
//!   └────────┴────────┴──────┘          ├──────────────┤
//!                                       │ panel2 rows  │ contiguous
//!                                       └──────────────┘
//! ```
//!
//! The inner kernel streams one panel linearly (hardware-prefetch
//! friendly, one TLB page run) instead of striding `4·pcols` bytes per
//! K step. A's codes are row-major and already row-panel contiguous, so
//! they are only converted to f32 (cached), not relaid.
//!
//! ## Microkernels, backends, and bit-exactness
//!
//! [`Precision`] selects the inner microkernel behind one shared outer
//! loop (`bj` panels → row tiles → `bk` K-blocks). The microkernels
//! themselves live in [`kernels`](crate::gemm::kernels) behind a
//! [`Kernels`] vtable chosen **once at plan build** — `PALLAS_KERNEL`
//! env override → calibration preference → fastest detected backend
//! (scalar / sse2 / avx2 / avx512vnni / neon);
//! [`with_kernels`](GemmPlan::with_kernels) pins a plan to an
//! explicit backend for tests and calibration.
//!
//! The f32 (SimF32/dense) kernels follow the **v2 op-order contract**
//! (see `gemm::kernels`): per output lane, one fused multiply-add per
//! K step in ascending order, vectorized through shared runtime-
//! dispatched FMA primitives — the same bits on every backend and on
//! the scalar path. The `*_baseline` implementations share the same
//! kernels/contract, so engine outputs stay **bit-identical** to them
//! for every thread count and placement (asserted by
//! `tests/engine_prop.rs`); the per-K-block scale-FMA order is
//! likewise shared. On the quantized paths all operands are integer
//! codes whose block dots stay below 2²⁴, where FP order is
//! irrelevant — which is what made re-anchoring the dense op order
//! (v1 → v2, see `docs/ARCHITECTURE.md`) safe for every oracle here.
//! The i8 kernels accumulate exact integers in i32, so *every*
//! backend (any lane order, any register blocking) produces the same
//! integer and the same widened f32 — bit-identity holds per backend,
//! not just for the scalar floor. The i8 path tiles up to **four** A
//! rows per loaded B row (the SIMD backends keep a rows × 16-column
//! accumulator tile in registers); the SimF32 oracle path keeps the
//! seed's row pairs.
//!
//! ## Scheduling policy
//!
//! Fallback blocks make some C row panels up to `2x` as expensive
//! (Algorithm 1 residual work). The scheduling unit is a *sub-panel*:
//! a run of rows inside one block row (block rows are split ~4-way so
//! even an 8-block-row GEMM yields ~32 schedulable units — enough for
//! LPT to balance when the heavy rows cluster). The plan counts
//! residual blocks per block row from the u-mask, weights each
//! sub-panel `rows · (kb + fallbacks)`, and assigns sub-panels to
//! workers with greedy LPT ([`weighted_buckets`]) instead of
//! contiguous chunking. Under the paper's worst-case *Sequential*
//! placement (Fig 8c) contiguous chunking leaves the trailing workers
//! idle while the leading ones do double work; LPT keeps the makespan
//! within the heaviest single sub-panel. Scheduling never changes
//! results: each row's output depends only on its own deterministic
//! loop order.
//!
//! Output safety: C is split into disjoint `&mut` row-panel slices up
//! front and each worker takes ownership of its panels — no `AtomicPtr`
//! hand-rolling, no aliasing, borrow-checked by construction.
//!
//! ## Sharded execution
//!
//! At `shards > 1` (the `PALLAS_SHARDS` knob, or
//! [`with_shards`](GemmPlan::with_shards)) the column panels split
//! into S contiguous shards, each with its own LPT schedule over a
//! share of the thread budget and a stable worker-affinity base, so a
//! shard's packed panels are touched by the same pool workers every
//! microstep. Each shard owns a disjoint column range of C — the
//! forward/dX/dW GEMMs all shard N, so no inter-shard reduction ever
//! runs; a future K-split would use the deterministic fixed-shape
//! tree reduction in [`kernels::widen_reduce_i32`]. Sharding is
//! bit-neutral: the panel loops are `bj`-outermost and each C element
//! is touched only during its own `bj` iteration, so restricting a
//! worker to a `bj` range preserves every element's exact FP op
//! sequence (asserted across S × threads × backends × paths by
//! `tests/shard_prop.rs`).

use std::cell::RefCell;
use std::sync::{Arc, OnceLock};

use crate::gemm::kernels::{self, panel_dot, panel_dot2, DotI4, DotI8,
                           Kernels};
use crate::quant::{BlockQuant, FallbackQuant, PanelPack, PanelPackI4,
                   PanelPackI8, StagedQuant};
use crate::util::pool::{self, ScopeJob};
use crate::util::threadpool::weighted_buckets;
use crate::util::Mat;

/// Which inner microkernel a plan runs (paper: BF16 baseline, Eq. 1
/// block GEMM, Algorithm 1 fallback GEMM). Deliberately not `Hash`:
/// precision must not become a cache-key dimension — one cached
/// weight half serves both int8 precisions (see `gemm::pipeline`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// f32 reference (the testbed's "BF16 baseline")
    Dense,
    /// per-block INT8 codes, f32 scale accumulation (Eq. 1)
    Int8Block,
    /// INT8 base + conditional INT8 residual per u-mask (Algorithm 1)
    Fallback,
}

/// What the int8-mode microkernels stream (see module docs): the
/// seed-compatible f32 simulation of the codes, or the true i8
/// operands with i32 block accumulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DataPath {
    /// cached f32 copies of the int8 codes, f32 FMA kernels
    SimF32,
    /// i8 operands, i8×i8→i32 kernels, one exact widening per K-block
    Int8,
    /// the precision lattice's lowest rung: nibble-packed i4 B
    /// panels, i8-stored A codes in [-7, 7], `dot*_i4` kernels. Never
    /// auto-selected — opt in per plan, per config, or via
    /// `PALLAS_PATH=int4`. Staged per-block Int4→Int8→f32 fallback
    /// rides this path through [`GemmPlan::new_staged`].
    Int4,
}

/// Largest quantization block size for which the i8 path is bit-exact
/// to the f32 kernels: every K-block partial sum is bounded by
/// `bs · 127²`, which must stay within f32's exact-integer range 2²⁴.
/// `floor(2²⁴ / 127²) = 1040` — all paper block sizes (32–256) qualify.
pub const I8_EXACT_MAX_BS: usize = (1 << 24) / (127 * 127);

/// Largest block size for which the Int4 path is bit-exact. The
/// binding bound comes from the **staged** ladder, whose INT8-tier
/// residual streams i8 codes (≤ 127) against the i4 panels (≤ 7):
/// every partial sum is ≤ `bs · 127 · 7`, which must stay within
/// f32's exact-integer range 2²⁴ → `floor(2²⁴ / 889) = 18872`. A pure
/// i4×i4 GEMM is exact even further (`2²⁴ / 49`), so one bound covers
/// both uses. Far above every paper block size — the i8 bound
/// [`I8_EXACT_MAX_BS`] is always the tighter constraint when both
/// paths run in one model.
pub const I4_EXACT_MAX_BS: usize = (1 << 24) / (127 * 7);

impl DataPath {
    /// Default path for a block size: true i8 inside the exactness
    /// bound, the f32 simulation beyond it. `Int4` is never chosen
    /// automatically — the lattice's bottom rung is opt-in.
    pub fn auto_for(bs: usize) -> DataPath {
        if bs <= I8_EXACT_MAX_BS {
            DataPath::Int8
        } else {
            DataPath::SimF32
        }
    }

    /// Stable serialization tag (warm-state files, reports).
    pub fn tag(&self) -> &'static str {
        match self {
            DataPath::SimF32 => "sim_f32",
            DataPath::Int8 => "int8",
            DataPath::Int4 => "int4",
        }
    }

    /// Inverse of [`tag`](DataPath::tag).
    pub fn from_tag(s: &str) -> Option<DataPath> {
        match s {
            "sim_f32" => Some(DataPath::SimF32),
            "int8" => Some(DataPath::Int8),
            "int4" => Some(DataPath::Int4),
            _ => None,
        }
    }
}

/// Parse a `PALLAS_PATH`-style override. Unset or empty means "no
/// override"; anything else must be a valid [`DataPath::tag`] —
/// mistyping a data path silently falling back to the default would
/// invalidate whole benchmark runs, so an unknown tag is a hard error
/// (same contract as `PALLAS_KERNEL`).
pub fn parse_path_override(val: Option<&str>) -> Option<DataPath> {
    match val {
        None | Some("") => None,
        Some(s) => match DataPath::from_tag(s) {
            Some(p) => Some(p),
            None => panic!(
                "PALLAS_PATH={s:?} is not a data path tag \
                 (expected sim_f32, int8, or int4)"
            ),
        },
    }
}

/// The `PALLAS_PATH` env override, read once per process.
static ENV_PATH: OnceLock<Option<DataPath>> = OnceLock::new();

/// The `PALLAS_PATH` env override, if one is in force (parsed once
/// per process; an unknown tag hard-panics via
/// [`parse_path_override`]). Config constructors consult this so one
/// env var re-paths every plan a test binary builds.
pub fn env_path() -> Option<DataPath> {
    *ENV_PATH.get_or_init(|| {
        parse_path_override(std::env::var("PALLAS_PATH").ok().as_deref())
    })
}

/// The data path pipeline/train configs start from: the `PALLAS_PATH`
/// env override if set, else [`DataPath::Int8`]. Explicit config
/// fields and builder calls still win — this only seeds defaults, so
/// the CI matrix can flip a whole test binary onto the Int4 rung with
/// one env var.
pub fn default_path() -> DataPath {
    env_path().unwrap_or(DataPath::Int8)
}

/// Residual operand of a SimF32 fallback plan.
struct Resid<'a> {
    rf: Arc<Vec<f32>>,
    r_scale: &'a [f32],
    u: &'a [bool],
}

/// Residual operand of an Int8 fallback plan — the stored residual
/// codes themselves, zero-copy.
struct ResidI8<'a> {
    rq: &'a [i8],
    r_scale: &'a [f32],
    u: &'a [bool],
}

/// Staged residual operands of an Int4 lattice plan (borrowed from a
/// [`StagedQuant`]): the INT8-tier residual codes stream through the
/// *same* `dot*_i4` kernels against the same nibble panels (their
/// products stay ≤ 127·7, inside the bound), and the f32-tier raw
/// remainder runs `panel_dot*` against B's f32 code panels — weighted
/// by `sb` alone, since the remainder is already in input units.
struct ResidStaged<'a> {
    rq: &'a [i8],
    r_scale: &'a [f32],
    /// blocks at INT8 tier or above (promote past θ)
    u8m: &'a [bool],
    /// exact f32 remainder `x − deq4 − rq·rs` (padded A layout)
    r2: &'a [f32],
    /// blocks at the f32 tier (promote past κ·θ)
    uf: &'a [bool],
    /// B's f32 code panels for the f32 tier; `None` when no block is
    /// promoted that far (keeps the 4x-bigger cache unbuilt — the
    /// common case)
    bpf: Option<Arc<PanelPack>>,
}

/// Mode-specific packed operands.
enum Kernel<'a> {
    Dense {
        a: &'a Mat,
        b: &'a Mat,
    },
    /// int8 modes, SimF32 data path (f32 copies of the codes)
    Sim {
        af: Arc<Vec<f32>>,
        a_pcols: usize,
        a_scale: &'a [f32],
        bp: Arc<PanelPack>,
        b_scale: &'a [f32],
        resid: Option<Resid<'a>>,
    },
    /// int8 modes, Int8 data path (true i8 operands)
    I8 {
        qa: &'a [i8],
        a_pcols: usize,
        a_scale: &'a [f32],
        bp: Arc<PanelPackI8>,
        b_scale: &'a [f32],
        resid: Option<ResidI8<'a>>,
    },
    /// Int4 data path: i8-stored A codes in [-7, 7], nibble-packed B
    /// panels, `dot*_i4` kernels. With `resid`, the staged
    /// Int4→Int8→f32 ladder of `quant::staged`.
    I4 {
        qa: &'a [i8],
        a_pcols: usize,
        a_scale: &'a [f32],
        bp: Arc<PanelPackI4>,
        b_scale: &'a [f32],
        resid: Option<ResidStaged<'a>>,
    },
}

/// Row-panel height used for scheduling the dense kernel.
const DENSE_PANEL_ROWS: usize = 16;

/// Scheduling-unit height for the int8 kernels: the largest divisor of
/// the block size that splits each block row ~4-way (min 8 rows), so
/// LPT has enough units to balance clustered fallback rows. Must
/// divide `bs` so no unit straddles a block-row (scale) boundary.
fn sched_rows_for(bs: usize) -> usize {
    for d in [4usize, 2] {
        if bs % d == 0 && bs / d >= 8 {
            return bs / d;
        }
    }
    bs
}

/// A prepared GEMM: packed operands + per-sub-panel schedule weights.
/// Build once with one of the `new_*` constructors, run with
/// [`execute`](GemmPlan::execute).
pub struct GemmPlan<'a> {
    mode: Precision,
    path: DataPath,
    /// effective worker count (requested threads clamped to the
    /// sub-panel count at build time)
    eff_threads: usize,
    m: usize,
    n: usize,
    k: usize,
    /// scheduling-unit height in rows (divides `bs` for int8 modes)
    sched_rows: usize,
    /// quantization block size (int8 modes; 0 for dense)
    bs: usize,
    /// K-blocks (int8 modes)
    kb: usize,
    /// N-panels (int8 modes)
    nbk: usize,
    /// per-sub-panel schedule weight (∝ expected cost)
    weights: Vec<f64>,
    /// LPT sub-panel→worker assignment, computed once at build
    /// (weights and thread count are fixed then) and replayed by every
    /// execute — the schedule is part of the plan, not the call
    buckets: Vec<Vec<usize>>,
    /// effective shard count: requested shards clamped to the column
    /// panel count (1 for dense plans — the dense kernel streams
    /// whole B rows, not column panels, so there is nothing to shard)
    shards: usize,
    /// per-shard schedules (empty when `shards == 1`, where the flat
    /// `buckets` path runs unchanged)
    shard_scheds: Vec<ShardSched>,
    kernel: Kernel<'a>,
    /// microkernel backend (selected once at build; see
    /// [`kernels::select`])
    kernels: &'static Kernels,
}

/// One shard of a sharded plan: a contiguous range of column panels,
/// its own LPT bucket assignment over the same sub-panel weights, and
/// the first worker index its jobs are hinted at (stable per plan, so
/// a shard's panels are touched by the same pool workers every
/// microstep — best-effort locality, never a correctness dependence).
struct ShardSched {
    /// first column panel (inclusive)
    bj_lo: usize,
    /// last column panel (exclusive)
    bj_hi: usize,
    /// worker-affinity base: shard jobs are hinted at
    /// `worker_base + bucket_index`
    worker_base: usize,
    /// LPT sub-panel→worker assignment for this shard's thread share
    buckets: Vec<Vec<usize>>,
}

/// Build the per-shard schedules: `nbk` column panels split into
/// `shards` contiguous ranges, `eff_threads` workers split as evenly
/// as possible among shards (each shard gets at least one), and LPT
/// run per shard over the shared sub-panel weights. The weights are
/// column-independent (a sub-panel costs `rows · (kb + fb)` whatever
/// its columns), so the same weight vector drives every shard's LPT.
fn build_shard_scheds(
    weights: &[f64], eff_threads: usize, shards: usize, nbk: usize,
) -> Vec<ShardSched> {
    let base = eff_threads / shards;
    let extra = eff_threads % shards;
    let mut worker_base = 0usize;
    (0..shards)
        .map(|si| {
            let t = (base + usize::from(si < extra))
                .clamp(1, weights.len().max(1));
            let sched = ShardSched {
                bj_lo: si * nbk / shards,
                bj_hi: (si + 1) * nbk / shards,
                worker_base,
                buckets: weighted_buckets(weights, t),
            };
            worker_base += t;
            sched
        })
        .collect()
}

/// Effective worker count and LPT bucket assignment for a weight
/// vector — cached on the plan so `execute`/`execute_into` and
/// `schedule_makespan` never re-run LPT per call.
fn schedule(weights: &[f64], threads: usize)
            -> (usize, Vec<Vec<usize>>) {
    let eff = threads.clamp(1, weights.len().max(1));
    (eff, weighted_buckets(weights, eff))
}

thread_local! {
    /// Per-thread persistent engine workspace (the `acc`/`acci`
    /// accumulator rows), reused across executes so steady-state
    /// GEMMs allocate nothing. The kernels overwrite (never
    /// accumulate into) these rows, so dirty reuse is safe.
    static ENGINE_WS: RefCell<(Vec<f32>, Vec<i32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Borrow the calling thread's persistent accumulator workspace,
/// growing it if this plan needs more than any prior plan did on this
/// thread. Returns the number of buffer growths (0 in steady state)
/// so callers can book them via [`pool::note_ws_allocs`].
fn with_engine_workspace<F>(acc_len: usize, acci_len: usize, f: F)
                            -> u64
where
    F: FnOnce(&mut [f32], &mut [i32]),
{
    ENGINE_WS.with(|ws| {
        let mut ws = ws.borrow_mut();
        let (acc, acci) = &mut *ws;
        let mut grew = 0u64;
        if acc.len() < acc_len {
            if acc.capacity() < acc_len {
                grew += 1;
            }
            acc.resize(acc_len, 0.0);
        }
        if acci.len() < acci_len {
            if acci.capacity() < acci_len {
                grew += 1;
            }
            acci.resize(acci_len, 0);
        }
        f(&mut acc[..acc_len], &mut acci[..acci_len]);
        grew
    })
}

impl<'a> GemmPlan<'a> {
    /// Plan a dense f32 GEMM `C = A·B`.
    pub fn new_dense(a: &'a Mat, b: &'a Mat, threads: usize)
                     -> GemmPlan<'a> {
        assert_eq!(a.cols, b.rows, "inner dims");
        let (m, n, k) = (a.rows, b.cols, a.cols);
        let rbp = m.div_ceil(DENSE_PANEL_ROWS).max(1);
        let weights: Vec<f64> = (0..rbp)
            .map(|ci| {
                let rows = DENSE_PANEL_ROWS
                    .min(m.saturating_sub(ci * DENSE_PANEL_ROWS));
                rows as f64
            })
            .collect();
        let (eff_threads, buckets) = schedule(&weights, threads);
        GemmPlan {
            mode: Precision::Dense,
            path: DataPath::SimF32,
            eff_threads,
            m,
            n,
            k,
            sched_rows: DENSE_PANEL_ROWS,
            bs: 0,
            kb: 0,
            nbk: 0,
            weights,
            buckets,
            shards: 1,
            shard_scheds: Vec::new(),
            kernel: Kernel::Dense { a, b },
            kernels: kernels::select(),
        }
        .with_shards(pool::default_shards())
    }

    /// Plan an INT8 block GEMM (paper Eq. 1) on the default data path
    /// for the block size ([`DataPath::auto_for`] — true i8 within the
    /// exactness bound).
    pub fn new_int8(a: &'a BlockQuant, b: &'a BlockQuant,
                    threads: usize) -> GemmPlan<'a> {
        Self::new_int8_path(a, b, threads, DataPath::auto_for(a.block))
    }

    /// Plan an INT8 block GEMM on an explicit [`DataPath`].
    pub fn new_int8_path(a: &'a BlockQuant, b: &'a BlockQuant,
                         threads: usize, path: DataPath)
                         -> GemmPlan<'a> {
        assert_eq!(a.cols, b.rows, "inner dims");
        assert_eq!(a.block, b.block, "block size");
        let (kb, nbk) = (a.cb(), b.cb());
        let sched = sched_rows_for(a.block);
        let weights: Vec<f64> = (0..a.rows.div_ceil(sched))
            .map(|ci| {
                let rows = sched.min(a.rows - ci * sched);
                (rows * kb) as f64
            })
            .collect();
        let (eff_threads, buckets) = schedule(&weights, threads);
        let kernel = match path {
            DataPath::SimF32 => Kernel::Sim {
                af: a.codes_f32(),
                a_pcols: a.pcols,
                a_scale: &a.scale,
                bp: b.col_panels(),
                b_scale: &b.scale,
                resid: None,
            },
            DataPath::Int8 => Kernel::I8 {
                qa: &a.q,
                a_pcols: a.pcols,
                a_scale: &a.scale,
                bp: b.col_panels_i8(),
                b_scale: &b.scale,
                resid: None,
            },
            // Both operands must carry codes in [-7, 7] (quantize at
            // INT4_LEVELS) — the engine streams the stored i8 A codes
            // as-is and cannot verify the range; out-of-range codes
            // only trip the debug overflow guard once a block dot
            // leaves the exact range.
            DataPath::Int4 => Kernel::I4 {
                qa: &a.q,
                a_pcols: a.pcols,
                a_scale: &a.scale,
                bp: b.col_panels_i4(),
                b_scale: &b.scale,
                resid: None,
            },
        };
        GemmPlan {
            mode: Precision::Int8Block,
            path,
            eff_threads,
            m: a.rows,
            n: b.cols,
            k: a.cols,
            sched_rows: sched,
            bs: a.block,
            kb,
            nbk,
            weights,
            buckets,
            shards: 1,
            shard_scheds: Vec::new(),
            kernel,
            kernels: kernels::select(),
        }
        .with_shards(pool::default_shards())
    }

    /// Plan a mixed-precision fallback GEMM (paper Algorithm 1) on the
    /// default data path for the block size. `u` is the per-block
    /// fallback mask — pass `&fa.u` or a `remap_placement` result.
    pub fn new_fallback(fa: &'a FallbackQuant, b: &'a BlockQuant,
                        u: &'a [bool], threads: usize) -> GemmPlan<'a> {
        Self::new_fallback_path(fa, b, u, threads,
                                DataPath::auto_for(fa.base.block))
    }

    /// Plan a fallback GEMM on an explicit [`DataPath`]. On `Int8` the
    /// residual operand rides the same i8 path as the base codes, so
    /// Algorithm 1's skip-when-`u=0` work stays cheap.
    pub fn new_fallback_path(fa: &'a FallbackQuant, b: &'a BlockQuant,
                             u: &'a [bool], threads: usize,
                             path: DataPath) -> GemmPlan<'a> {
        let a = &fa.base;
        assert_eq!(a.cols, b.rows, "inner dims");
        assert_eq!(a.block, b.block, "block size");
        assert_eq!(u.len(), a.rb() * a.cb(), "u-mask size");
        let (kb, nbk) = (a.cb(), b.cb());
        let sched = sched_rows_for(a.block);
        // Fallback-aware weights: a residual block doubles that
        // K-step's work for every row of its block row (Fig 8c cost
        // model); each sub-panel inherits its block row's cost.
        let weights: Vec<f64> = (0..a.rows.div_ceil(sched))
            .map(|ci| {
                let rows = sched.min(a.rows - ci * sched);
                let bi = ci * sched / a.block;
                let fb = u[bi * kb..(bi + 1) * kb]
                    .iter()
                    .filter(|&&x| x)
                    .count();
                (rows * (kb + fb)) as f64
            })
            .collect();
        let (eff_threads, buckets) = schedule(&weights, threads);
        let kernel = match path {
            DataPath::SimF32 => Kernel::Sim {
                af: a.codes_f32(),
                a_pcols: a.pcols,
                a_scale: &a.scale,
                bp: b.col_panels(),
                b_scale: &b.scale,
                resid: Some(Resid {
                    rf: fa.residual_f32(),
                    r_scale: &fa.rscale,
                    u,
                }),
            },
            DataPath::Int8 => Kernel::I8 {
                qa: &a.q,
                a_pcols: a.pcols,
                a_scale: &a.scale,
                bp: b.col_panels_i8(),
                b_scale: &b.scale,
                resid: Some(ResidI8 {
                    rq: &fa.rq,
                    r_scale: &fa.rscale,
                    u,
                }),
            },
            DataPath::Int4 => panic!(
                "fallback on the Int4 path is the staged ladder: \
                 quantize with quant::staged_quant and plan with \
                 GemmPlan::new_staged / WeightPlan::plan_staged"
            ),
        };
        GemmPlan {
            mode: Precision::Fallback,
            path,
            eff_threads,
            m: a.rows,
            n: b.cols,
            k: a.cols,
            sched_rows: sched,
            bs: a.block,
            kb,
            nbk,
            weights,
            buckets,
            shards: 1,
            shard_scheds: Vec::new(),
            kernel,
            kernels: kernels::select(),
        }
        .with_shards(pool::default_shards())
    }

    /// Plan a staged Int4→Int8→f32 lattice GEMM (Algorithm 1
    /// generalized to three rungs): every block streams its INT4 base
    /// codes; blocks the Algorithm-2 threshold promoted to the INT8
    /// tier add their i8 residual through the *same* nibble panels;
    /// blocks past `κ·θ` additionally add their exact f32 remainder
    /// against B's f32 code panels. All three terms are deterministic
    /// across backends, thread counts, and shards: the two integer
    /// dots are exact within [`I4_EXACT_MAX_BS`], and the f32 term
    /// runs the v2 FMA-contract `panel_dot*` kernels.
    ///
    /// The B operand must carry codes in [-7, 7] (quantized at
    /// `INT4_LEVELS`); the staged A side guarantees its own ranges by
    /// construction.
    pub fn new_staged(sa: &'a StagedQuant, b: &'a BlockQuant,
                      threads: usize) -> GemmPlan<'a> {
        let a = &sa.base;
        assert_eq!(a.cols, b.rows, "inner dims");
        assert_eq!(a.block, b.block, "block size");
        let (kb, nbk) = (a.cb(), b.cb());
        let sched = sched_rows_for(a.block);
        // Lattice-aware weights: each promotion tier adds one more
        // block-dot pass over that K-step for every row of its block
        // row, so an F32-tier block costs ~3x an I4-tier one.
        let weights: Vec<f64> = (0..a.rows.div_ceil(sched))
            .map(|ci| {
                let rows = sched.min(a.rows - ci * sched);
                let bi = ci * sched / a.block;
                let fb: usize = (bi * kb..(bi + 1) * kb)
                    .map(|i| {
                        sa.u8_mask[i] as usize + sa.uf_mask[i] as usize
                    })
                    .sum();
                (rows * (kb + fb)) as f64
            })
            .collect();
        let (eff_threads, buckets) = schedule(&weights, threads);
        // Only build B's 4x-bigger f32 panel cache when some block
        // actually reached the f32 tier this microstep.
        let bpf = if sa.uf_mask.iter().any(|&u| u) {
            Some(b.col_panels())
        } else {
            None
        };
        let kernel = Kernel::I4 {
            qa: &a.q,
            a_pcols: a.pcols,
            a_scale: &a.scale,
            bp: b.col_panels_i4(),
            b_scale: &b.scale,
            resid: Some(ResidStaged {
                rq: &sa.rq,
                r_scale: &sa.rscale,
                u8m: &sa.u8_mask,
                r2: &sa.r2,
                uf: &sa.uf_mask,
                bpf,
            }),
        };
        GemmPlan {
            mode: Precision::Fallback,
            path: DataPath::Int4,
            eff_threads,
            m: a.rows,
            n: b.cols,
            k: a.cols,
            sched_rows: sched,
            bs: a.block,
            kb,
            nbk,
            weights,
            buckets,
            shards: 1,
            shard_scheds: Vec::new(),
            kernel,
            kernels: kernels::select(),
        }
        .with_shards(pool::default_shards())
    }

    /// Pin this plan to an explicit microkernel backend (tests,
    /// calibration, per-backend benches). All backends are
    /// bit-identical on the i8 path, so this only changes speed.
    pub fn with_kernels(mut self, k: &'static Kernels) -> GemmPlan<'a> {
        self.kernels = k;
        self
    }

    /// Re-shard this plan: split its column panels into `shards`
    /// contiguous ranges, each with its own per-shard LPT schedule and
    /// stable worker-affinity base. Constructors default the count
    /// from [`pool::default_shards`] (the `PALLAS_SHARDS` knob);
    /// tests and benches override it here to A/B in-process without
    /// touching the environment.
    ///
    /// The request is clamped to the column-panel count, and dense
    /// plans always stay at 1 (the dense kernel streams whole B rows,
    /// not column panels). Sharding never changes results: each shard
    /// runs the same `bj`-ascending/`bk`-ascending loops over its own
    /// disjoint columns of C, so every output element sees exactly the
    /// FP op sequence of the unsharded plan.
    pub fn with_shards(mut self, shards: usize) -> GemmPlan<'a> {
        let s_eff = match self.mode {
            Precision::Dense => 1,
            _ => shards.max(1).min(self.nbk.max(1)),
        };
        self.shards = s_eff;
        self.shard_scheds = if s_eff <= 1 {
            Vec::new()
        } else {
            build_shard_scheds(&self.weights, self.eff_threads, s_eff,
                               self.nbk)
        };
        self
    }

    /// Effective shard count (after clamping; 1 means the flat
    /// schedule runs unchanged).
    pub fn shard_count(&self) -> usize {
        self.shards
    }

    /// Name of the microkernel backend this plan executes with
    /// (`scalar`, `sse2`, `avx2`, `avx512vnni`, `neon`, ...).
    pub fn kernel_backend(&self) -> &'static str {
        self.kernels.name
    }

    pub fn precision(&self) -> Precision {
        self.mode
    }

    /// The data path this plan's microkernels stream
    /// ([`DataPath::SimF32`] for dense plans).
    pub fn data_path(&self) -> DataPath {
        self.path
    }

    /// (m, n, k) of the planned GEMM.
    pub fn dims(&self) -> (usize, usize, usize) {
        (self.m, self.n, self.k)
    }

    /// Per-sub-panel schedule weights (cost units; exposed for tests
    /// and future cost-model wiring).
    pub fn panel_weights(&self) -> &[f64] {
        &self.weights
    }

    /// Total scheduled work in weight units, and the makespan the LPT
    /// schedule achieves for this plan's thread count — both read from
    /// the schedule cached at build. The ratio is a load-balance
    /// factor; currently consumed by tests only (the cost model uses
    /// measured throughput via `SubstrateCalibration`).
    /// At `shards > 1` the makespan is the max over shards of each
    /// shard's LPT makespan scaled by its panel share — a sub-panel's
    /// weight covers all `nbk` column panels, but a shard only runs
    /// `bj_hi - bj_lo` of them (approximate: panel widths are treated
    /// as uniform, which only the tail panel violates).
    pub fn schedule_makespan(&self) -> (f64, f64) {
        let total: f64 = self.weights.iter().sum();
        let bucket_span = |b: &Vec<usize>| {
            b.iter().map(|&i| self.weights[i]).sum::<f64>()
        };
        let makespan = if self.shards <= 1 {
            self.buckets.iter().map(bucket_span).fold(0.0f64, f64::max)
        } else {
            self.shard_scheds
                .iter()
                .map(|s| {
                    let frac = (s.bj_hi - s.bj_lo) as f64
                        / self.nbk.max(1) as f64;
                    s.buckets.iter().map(bucket_span)
                        .fold(0.0f64, f64::max) * frac
                })
                .fold(0.0f64, f64::max)
        };
        (total, makespan)
    }

    /// Run the plan: allocate C, split it into disjoint row panels,
    /// replay the cached schedule, run the microkernels. Thin wrapper
    /// over [`execute_into`](Self::execute_into) for callers that want
    /// an owned output.
    pub fn execute(&self) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.execute_into(&mut c);
        c
    }

    /// Run the plan into a caller-owned output, reusing `c`'s backing
    /// buffer when its capacity allows — the steady-state path: with a
    /// warm output buffer and warm per-thread workspaces this performs
    /// **zero** heap allocations and (through the pool) zero thread
    /// spawns. Buffer growths (output or workspace) are booked on the
    /// calling thread's [`pool::work_counters`].
    pub fn execute_into(&self, c: &mut Mat) {
        if c.reset_to(self.m, self.n) {
            pool::note_ws_allocs(1);
        }
        if self.m == 0 || self.n == 0 || self.k == 0 {
            return;
        }
        if self.shards > 1 && self.eff_threads > 1 {
            self.execute_sharded(c);
            return;
        }
        // Split C into disjoint &mut sub-panel slices (no AtomicPtr):
        // every sub-panel is `sched_rows * n` long except a shorter
        // tail, which is exactly `chunks_mut` semantics. `sched_rows`
        // divides the block size, so no slice straddles a block row.
        let mut slots: Vec<Option<(usize, &mut [f32])>> = c
            .data
            .chunks_mut(self.sched_rows * self.n)
            .enumerate()
            .map(Some)
            .collect();
        debug_assert_eq!(slots.len(), self.weights.len());
        let (al, il) = (self.acc_len(), self.acci_len());
        if self.eff_threads <= 1 {
            let grew = with_engine_workspace(al, il, |acc, acci| {
                for slot in slots.iter_mut() {
                    let (bi, crows) = slot.take().unwrap();
                    self.run_panel(bi, crows, acc, acci);
                }
            });
            pool::note_ws_allocs(grew);
        } else {
            // Replay the cached LPT assignment: bucket b's panels run
            // on one worker in ascending order, exactly as scheduled
            // at build — placement never changes results, but keeping
            // it fixed makes pool and scoped dispatch trivially
            // bit-identical.
            let mut tasks: Vec<ScopeJob<'_>> =
                Vec::with_capacity(self.buckets.len());
            for bucket in &self.buckets {
                if bucket.is_empty() {
                    continue;
                }
                let mut list = Vec::with_capacity(bucket.len());
                for &bi in bucket {
                    list.push(slots[bi].take().unwrap());
                }
                tasks.push(Box::new(move || {
                    with_engine_workspace(al, il, |acc, acci| {
                        for (bi, crows) in list {
                            self.run_panel(bi, crows, acc, acci);
                        }
                    })
                }));
            }
            pool::note_ws_allocs(pool::run_scoped(tasks));
        }
    }

    /// Sharded execute: each shard owns a contiguous column range of C
    /// (`[bj_lo·bs, bj_hi·bs)`), so every C row is split at the shard
    /// boundaries with chained `split_at_mut` — disjointness stays
    /// borrow-checked, no aliasing, no reduction needed on this path.
    /// One job per (shard, bucket) replays that shard's cached LPT
    /// assignment and is hinted at worker `worker_base + bucket` via
    /// [`pool::run_scoped_hinted`], so a shard's panels are touched by
    /// the same workers every microstep (locality only — results never
    /// depend on placement).
    ///
    /// Bit-identity with the flat path: the panel loops are
    /// `bj`-outermost, and a C element in column panel `bj` is only
    /// touched during iteration `bj` (with `bk` ascending inside), so
    /// restricting a job to a `bj` sub-range changes no element's FP
    /// op sequence.
    fn execute_sharded(&self, c: &mut Mat) {
        let scheds = &self.shard_scheds;
        let ns = scheds.len();
        let (al, il) = (self.acc_len(), self.acci_len());
        // slots[ci][si]: shard si's per-row column segments of
        // sub-panel ci, taken by the (shard, bucket) job that runs it.
        let mut slots: Vec<Vec<Option<Vec<&mut [f32]>>>> =
            Vec::with_capacity(self.weights.len());
        for chunk in c.data.chunks_mut(self.sched_rows * self.n) {
            let mut per_shard: Vec<Vec<&mut [f32]>> =
                (0..ns).map(|_| Vec::new()).collect();
            for row in chunk.chunks_mut(self.n) {
                let mut rest = row;
                let mut col = 0usize;
                for (si, sch) in scheds.iter().enumerate() {
                    let hi = (sch.bj_hi * self.bs).min(self.n);
                    let (seg, r) = rest.split_at_mut(hi - col);
                    per_shard[si].push(seg);
                    col = hi;
                    rest = r;
                }
                debug_assert!(rest.is_empty());
            }
            slots.push(per_shard.into_iter().map(Some).collect());
        }
        debug_assert_eq!(slots.len(), self.weights.len());
        let mut tasks: Vec<(usize, ScopeJob<'_>)> = Vec::new();
        for (si, sch) in scheds.iter().enumerate() {
            let (bj_lo, bj_hi) = (sch.bj_lo, sch.bj_hi);
            for (bix, bucket) in sch.buckets.iter().enumerate() {
                if bucket.is_empty() {
                    continue;
                }
                let mut list = Vec::with_capacity(bucket.len());
                for &ci in bucket {
                    list.push((ci, slots[ci][si].take().unwrap()));
                }
                tasks.push((
                    sch.worker_base + bix,
                    Box::new(move || {
                        with_engine_workspace(al, il, |acc, acci| {
                            for (ci, mut segs) in list {
                                self.run_panel_shard(
                                    ci, bj_lo, bj_hi, &mut segs, acc,
                                    acci,
                                );
                            }
                        })
                    }),
                ));
            }
        }
        pool::note_ws_allocs(pool::run_scoped_hinted(tasks));
    }

    /// Shard-range twin of [`run_panel`](Self::run_panel): compute the
    /// column panels `bj_lo..bj_hi` of sub-panel `ci`. `segs[r]` is
    /// row `r`'s slice of C covering exactly this shard's columns
    /// (local offset of panel `bj` is `(bj - bj_lo) · bs`).
    fn run_panel_shard(&self, ci: usize, bj_lo: usize, bj_hi: usize,
                       segs: &mut [&mut [f32]], acc: &mut [f32],
                       acci: &mut [i32]) {
        let rows = segs.len();
        let r_lo = ci * self.sched_rows;
        let bi = r_lo / self.bs;
        match &self.kernel {
            Kernel::Dense { .. } => {
                unreachable!("dense plans are never sharded")
            }
            Kernel::Sim { af, a_pcols, a_scale, bp, b_scale, resid } => {
                self.run_panel_sim_shard(
                    bi, r_lo, bj_lo, bj_hi, segs, rows, acc, af,
                    *a_pcols, a_scale, bp, b_scale, resid.as_ref(),
                );
            }
            Kernel::I8 { qa, a_pcols, a_scale, bp, b_scale, resid } => {
                self.run_panel_i8_shard(
                    bi, r_lo, bj_lo, bj_hi, segs, rows, acc, acci, qa,
                    *a_pcols, a_scale, bp, b_scale, resid.as_ref(),
                );
            }
            Kernel::I4 { qa, a_pcols, a_scale, bp, b_scale, resid } => {
                self.run_panel_i4_shard(
                    bi, r_lo, bj_lo, bj_hi, segs, rows, acc, acci, qa,
                    *a_pcols, a_scale, bp, b_scale, resid.as_ref(),
                );
            }
        }
    }

    /// [`run_panel_sim`](Self::run_panel_sim) restricted to panels
    /// `bj_lo..bj_hi`, writing through per-row shard segments. Same
    /// loop bodies, same row pairing, same per-`bk` scale-FMA order —
    /// bit-identical per element to the flat path.
    #[allow(clippy::too_many_arguments)]
    fn run_panel_sim_shard(
        &self, bi: usize, r_lo: usize, bj_lo: usize, bj_hi: usize,
        segs: &mut [&mut [f32]], rows: usize, acc: &mut [f32],
        af: &[f32], a_pcols: usize, a_scale: &[f32], bp: &PanelPack,
        b_scale: &[f32], resid: Option<&Resid<'_>>,
    ) {
        let bs = self.bs;
        let (acc0, acc1) = acc.split_at_mut(bs);
        for bj in bj_lo..bj_hi {
            let width = bp.widths[bj];
            let c_lo = (bj - bj_lo) * bs;
            let panel = bp.panel(bj);
            let mut rl = 0usize;
            while rl < rows {
                let pair = rl + 1 < rows;
                if pair {
                    for bk in 0..self.kb {
                        let sa = a_scale[bi * self.kb + bk];
                        let sb = b_scale[bk * self.nbk + bj];
                        panel_dot2(
                            af, a_pcols, r_lo + rl, bk * bs, bs,
                            panel, width, acc0, acc1,
                        );
                        let w = sa * sb;
                        scale_add(&mut segs[rl][c_lo..c_lo + width],
                                  acc0, width, w);
                        scale_add(&mut segs[rl + 1][c_lo..c_lo + width],
                                  acc1, width, w);
                        if let Some(res) = resid {
                            // Algorithm 1 lines 13-16: residual work
                            // really skipped when u = 0.
                            if res.u[bi * self.kb + bk] {
                                let rs = res.r_scale[bi * self.kb + bk];
                                panel_dot2(
                                    &res.rf, a_pcols, r_lo + rl,
                                    bk * bs, bs, panel, width, acc0,
                                    acc1,
                                );
                                let rw = rs * sb;
                                scale_add(
                                    &mut segs[rl][c_lo..c_lo + width],
                                    acc0, width, rw,
                                );
                                scale_add(
                                    &mut segs[rl + 1]
                                        [c_lo..c_lo + width],
                                    acc1, width, rw,
                                );
                            }
                        }
                    }
                    rl += 2;
                } else {
                    for bk in 0..self.kb {
                        let sa = a_scale[bi * self.kb + bk];
                        let sb = b_scale[bk * self.nbk + bj];
                        panel_dot(
                            af, a_pcols, r_lo + rl, bk * bs, bs,
                            panel, width, acc0,
                        );
                        let w = sa * sb;
                        scale_add(&mut segs[rl][c_lo..c_lo + width],
                                  acc0, width, w);
                        if let Some(res) = resid {
                            if res.u[bi * self.kb + bk] {
                                let rs = res.r_scale[bi * self.kb + bk];
                                panel_dot(
                                    &res.rf, a_pcols, r_lo + rl,
                                    bk * bs, bs, panel, width, acc0,
                                );
                                let rw = rs * sb;
                                scale_add(
                                    &mut segs[rl][c_lo..c_lo + width],
                                    acc0, width, rw,
                                );
                            }
                        }
                    }
                    rl += 1;
                }
            }
        }
    }

    /// [`run_panel_i8`](Self::run_panel_i8) restricted to panels
    /// `bj_lo..bj_hi`, writing through per-row shard segments. The
    /// integer block dots are exact, so tiling and sharding cannot
    /// change the widened value; the scale-FMA order per element is
    /// the flat path's.
    #[allow(clippy::too_many_arguments)]
    fn run_panel_i8_shard(
        &self, bi: usize, r_lo: usize, bj_lo: usize, bj_hi: usize,
        segs: &mut [&mut [f32]], rows: usize, acc: &mut [f32],
        acci: &mut [i32], qa: &[i8], a_pcols: usize, a_scale: &[f32],
        bp: &PanelPackI8, b_scale: &[f32],
        resid: Option<&ResidI8<'_>>,
    ) {
        let bs = self.bs;
        let kn = self.kernels;
        for bj in bj_lo..bj_hi {
            let width = bp.widths[bj];
            let c_lo = (bj - bj_lo) * bs;
            let panel = bp.panel(bj);
            let mut rl = 0usize;
            while rl < rows {
                let left = rows - rl;
                let (tile, dot): (usize, DotI8) = if left >= 4 {
                    (4, kn.dot4_i8)
                } else if left >= 2 {
                    (2, kn.dot2_i8)
                } else {
                    (1, kn.dot_i8)
                };
                for bk in 0..self.kb {
                    let sa = a_scale[bi * self.kb + bk];
                    let sb = b_scale[bk * self.nbk + bj];
                    dot(
                        qa, a_pcols, r_lo + rl, bk * bs, bs, panel,
                        width, acci, acc,
                    );
                    let w = sa * sb;
                    for t in 0..tile {
                        let crow =
                            &mut segs[rl + t][c_lo..][..width];
                        scale_add(crow, &acc[t * bs..], width, w);
                    }
                    if let Some(res) = resid {
                        // Algorithm 1 lines 13-16: residual work
                        // really skipped when u = 0.
                        if res.u[bi * self.kb + bk] {
                            let rs = res.r_scale[bi * self.kb + bk];
                            dot(
                                res.rq, a_pcols, r_lo + rl, bk * bs,
                                bs, panel, width, acci, acc,
                            );
                            let rw = rs * sb;
                            for t in 0..tile {
                                let crow = &mut segs[rl + t][c_lo..]
                                    [..width];
                                scale_add(crow, &acc[t * bs..], width,
                                          rw);
                            }
                        }
                    }
                }
                rl += tile;
            }
        }
    }

    /// [`run_panel_i4`](Self::run_panel_i4) restricted to panels
    /// `bj_lo..bj_hi`, writing through per-row shard segments. Same
    /// fixed term order (base / i8 residual / f32 remainder) per
    /// element — bit-identical to the flat path.
    #[allow(clippy::too_many_arguments)]
    fn run_panel_i4_shard(
        &self, bi: usize, r_lo: usize, bj_lo: usize, bj_hi: usize,
        segs: &mut [&mut [f32]], rows: usize, acc: &mut [f32],
        acci: &mut [i32], qa: &[i8], a_pcols: usize, a_scale: &[f32],
        bp: &PanelPackI4, b_scale: &[f32],
        resid: Option<&ResidStaged<'_>>,
    ) {
        let bs = self.bs;
        let kn = self.kernels;
        for bj in bj_lo..bj_hi {
            let width = bp.widths[bj];
            let c_lo = (bj - bj_lo) * bs;
            let panel = bp.panel(bj);
            let fpanel = resid
                .and_then(|r| r.bpf.as_deref())
                .map(|p| p.panel(bj));
            let mut rl = 0usize;
            while rl < rows {
                let left = rows - rl;
                let (tile, dot): (usize, DotI4) = if left >= 4 {
                    (4, kn.dot4_i4)
                } else if left >= 2 {
                    (2, kn.dot2_i4)
                } else {
                    (1, kn.dot_i4)
                };
                for bk in 0..self.kb {
                    let sa = a_scale[bi * self.kb + bk];
                    let sb = b_scale[bk * self.nbk + bj];
                    dot(
                        qa, a_pcols, r_lo + rl, bk * bs, bs, panel,
                        width, acci, acc,
                    );
                    let w = sa * sb;
                    for t in 0..tile {
                        let crow =
                            &mut segs[rl + t][c_lo..][..width];
                        scale_add(crow, &acc[t * bs..], width, w);
                    }
                    if let Some(res) = resid {
                        if res.u8m[bi * self.kb + bk] {
                            let rs = res.r_scale[bi * self.kb + bk];
                            dot(
                                res.rq, a_pcols, r_lo + rl, bk * bs,
                                bs, panel, width, acci, acc,
                            );
                            let rw = rs * sb;
                            for t in 0..tile {
                                let crow = &mut segs[rl + t][c_lo..]
                                    [..width];
                                scale_add(crow, &acc[t * bs..], width,
                                          rw);
                            }
                        }
                        if res.uf[bi * self.kb + bk] {
                            let fp = fpanel.expect(
                                "f32 panels packed when any block \
                                 reaches the f32 tier",
                            );
                            for t in 0..tile {
                                panel_dot(
                                    res.r2, a_pcols, r_lo + rl + t,
                                    bk * bs, bs, fp, width,
                                    &mut acc[..bs],
                                );
                                let crow = &mut segs[rl + t][c_lo..]
                                    [..width];
                                scale_add(crow, &acc[..bs], width, sb);
                            }
                        }
                    }
                }
                rl += tile;
            }
        }
    }

    /// f32 workspace length: four accumulator rows — the i8 backends
    /// tile up to four A rows (row `t` at offset `t·bs`), the SimF32
    /// kernels use the first two, the dense kernel accumulates into C
    /// directly.
    fn acc_len(&self) -> usize {
        match self.mode {
            Precision::Dense => 0,
            _ => 4 * self.bs,
        }
    }

    /// i32 workspace length: the integer paths additionally carry
    /// four integer accumulator rows (widened into the f32 rows once
    /// per K-block).
    fn acci_len(&self) -> usize {
        match &self.kernel {
            Kernel::I8 { .. } | Kernel::I4 { .. } => 4 * self.bs,
            _ => 0,
        }
    }

    /// Compute one C sub-panel. `ci` is the sub-panel (chunk) index;
    /// `crows` is its slice of C (`rows * n` elements, rows =
    /// `sched_rows` except the tail).
    fn run_panel(&self, ci: usize, crows: &mut [f32], acc: &mut [f32],
                 acci: &mut [i32]) {
        let rows = crows.len() / self.n;
        match &self.kernel {
            Kernel::Dense { a, b } => {
                let r_lo = ci * self.sched_rows;
                let mut rl = 0usize;
                while rl < rows {
                    if rl + 1 < rows {
                        let pair = &mut crows[rl * self.n
                                              ..(rl + 2) * self.n];
                        let (c0, c1) = pair.split_at_mut(self.n);
                        (self.kernels.dense2)(
                            a.row(r_lo + rl),
                            a.row(r_lo + rl + 1),
                            b,
                            c0,
                            c1,
                        );
                        rl += 2;
                    } else {
                        let crow = &mut crows[rl * self.n
                                              ..(rl + 1) * self.n];
                        crate::gemm::dense::matvec_row(
                            a.row(r_lo + rl), b, crow);
                        rl += 1;
                    }
                }
            }
            Kernel::Sim { af, a_pcols, a_scale, bp, b_scale, resid } => {
                let r_lo = ci * self.sched_rows;
                // sched_rows divides bs, so the whole sub-panel lies
                // in one block row and shares its scale row.
                let bi = r_lo / self.bs;
                self.run_panel_sim(
                    bi, r_lo, crows, rows, acc, af, *a_pcols, a_scale,
                    bp, b_scale, resid.as_ref(),
                );
            }
            Kernel::I8 { qa, a_pcols, a_scale, bp, b_scale, resid } => {
                let r_lo = ci * self.sched_rows;
                let bi = r_lo / self.bs;
                self.run_panel_i8(
                    bi, r_lo, crows, rows, acc, acci, qa, *a_pcols,
                    a_scale, bp, b_scale, resid.as_ref(),
                );
            }
            Kernel::I4 { qa, a_pcols, a_scale, bp, b_scale, resid } => {
                let r_lo = ci * self.sched_rows;
                let bi = r_lo / self.bs;
                self.run_panel_i4(
                    bi, r_lo, crows, rows, acc, acci, qa, *a_pcols,
                    a_scale, bp, b_scale, resid.as_ref(),
                );
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn run_panel_sim(
        &self, bi: usize, r_lo: usize, crows: &mut [f32], rows: usize,
        acc: &mut [f32], af: &[f32], a_pcols: usize, a_scale: &[f32],
        bp: &PanelPack, b_scale: &[f32], resid: Option<&Resid<'_>>,
    ) {
        let bs = self.bs;
        let (acc0, acc1) = acc.split_at_mut(bs);
        for bj in 0..self.nbk {
            let width = bp.widths[bj];
            let c_lo = bj * bs;
            let panel = bp.panel(bj);
            let mut rl = 0usize;
            while rl < rows {
                let pair = rl + 1 < rows;
                if pair {
                    let rowpair =
                        &mut crows[rl * self.n..(rl + 2) * self.n];
                    let (row0, row1) = rowpair.split_at_mut(self.n);
                    let crow0 = &mut row0[c_lo..c_lo + width];
                    let crow1 = &mut row1[c_lo..c_lo + width];
                    for bk in 0..self.kb {
                        let sa = a_scale[bi * self.kb + bk];
                        let sb = b_scale[bk * self.nbk + bj];
                        panel_dot2(
                            af, a_pcols, r_lo + rl, bk * bs, bs,
                            panel, width, acc0, acc1,
                        );
                        let w = sa * sb;
                        scale_add(crow0, acc0, width, w);
                        scale_add(crow1, acc1, width, w);
                        if let Some(res) = resid {
                            // Algorithm 1 lines 13-16: residual work
                            // really skipped when u = 0.
                            if res.u[bi * self.kb + bk] {
                                let rs = res.r_scale[bi * self.kb + bk];
                                panel_dot2(
                                    &res.rf, a_pcols, r_lo + rl,
                                    bk * bs, bs, panel, width, acc0,
                                    acc1,
                                );
                                let rw = rs * sb;
                                scale_add(crow0, acc0, width, rw);
                                scale_add(crow1, acc1, width, rw);
                            }
                        }
                    }
                    rl += 2;
                } else {
                    let crow = &mut crows[rl * self.n + c_lo
                                          ..rl * self.n + c_lo + width];
                    for bk in 0..self.kb {
                        let sa = a_scale[bi * self.kb + bk];
                        let sb = b_scale[bk * self.nbk + bj];
                        panel_dot(
                            af, a_pcols, r_lo + rl, bk * bs, bs,
                            panel, width, acc0,
                        );
                        let w = sa * sb;
                        scale_add(crow, acc0, width, w);
                        if let Some(res) = resid {
                            if res.u[bi * self.kb + bk] {
                                let rs = res.r_scale[bi * self.kb + bk];
                                panel_dot(
                                    &res.rf, a_pcols, r_lo + rl,
                                    bk * bs, bs, panel, width, acc0,
                                );
                                let rw = rs * sb;
                                scale_add(crow, acc0, width, rw);
                            }
                        }
                    }
                    rl += 1;
                }
            }
        }
    }

    /// Int8-path twin of [`run_panel_sim`](Self::run_panel_sim): same
    /// outer loop and scale-FMA order, but the block dots stream i8
    /// operands through the selected backend's row-tile kernels (up
    /// to 4 A rows per loaded B row) into the i32 workspace and widen
    /// once per K-block — bit-identical output for
    /// `bs ≤ I8_EXACT_MAX_BS` on every backend, because the integer
    /// block dot is exact regardless of lane order or tiling.
    #[allow(clippy::too_many_arguments)]
    fn run_panel_i8(
        &self, bi: usize, r_lo: usize, crows: &mut [f32], rows: usize,
        acc: &mut [f32], acci: &mut [i32], qa: &[i8], a_pcols: usize,
        a_scale: &[f32], bp: &PanelPackI8, b_scale: &[f32],
        resid: Option<&ResidI8<'_>>,
    ) {
        let bs = self.bs;
        let kn = self.kernels;
        for bj in 0..self.nbk {
            let width = bp.widths[bj];
            let c_lo = bj * bs;
            let panel = bp.panel(bj);
            let mut rl = 0usize;
            while rl < rows {
                let left = rows - rl;
                let (tile, dot): (usize, DotI8) = if left >= 4 {
                    (4, kn.dot4_i8)
                } else if left >= 2 {
                    (2, kn.dot2_i8)
                } else {
                    (1, kn.dot_i8)
                };
                for bk in 0..self.kb {
                    let sa = a_scale[bi * self.kb + bk];
                    let sb = b_scale[bk * self.nbk + bj];
                    dot(
                        qa, a_pcols, r_lo + rl, bk * bs, bs, panel,
                        width, acci, acc,
                    );
                    let w = sa * sb;
                    for t in 0..tile {
                        let crow = &mut crows[(rl + t) * self.n + c_lo
                                              ..][..width];
                        scale_add(crow, &acc[t * bs..], width, w);
                    }
                    if let Some(res) = resid {
                        // Algorithm 1 lines 13-16: residual work
                        // really skipped when u = 0.
                        if res.u[bi * self.kb + bk] {
                            let rs = res.r_scale[bi * self.kb + bk];
                            dot(
                                res.rq, a_pcols, r_lo + rl, bk * bs,
                                bs, panel, width, acci, acc,
                            );
                            let rw = rs * sb;
                            for t in 0..tile {
                                let crow =
                                    &mut crows[(rl + t) * self.n + c_lo
                                               ..][..width];
                                scale_add(crow, &acc[t * bs..], width,
                                          rw);
                            }
                        }
                    }
                }
                rl += tile;
            }
        }
    }

    /// Int4-path twin of [`run_panel_i8`](Self::run_panel_i8) running
    /// the staged lattice. Term order per C element and K-block is
    /// fixed — INT4 base dot, then (where `u8m` promotes) the INT8
    /// residual through the *same* `dot*_i4` kernels and nibble
    /// panels, then (where `uf` promotes) the exact f32 remainder via
    /// the v2-contract `panel_dot` against B's f32 code panels,
    /// weighted by `sb` alone. The two integer dots are exact for
    /// `bs ≤ I4_EXACT_MAX_BS` and the f32 term's op order is
    /// backend-invariant, so outputs are bit-identical across
    /// backends, tilings, thread counts, and shards.
    #[allow(clippy::too_many_arguments)]
    fn run_panel_i4(
        &self, bi: usize, r_lo: usize, crows: &mut [f32], rows: usize,
        acc: &mut [f32], acci: &mut [i32], qa: &[i8], a_pcols: usize,
        a_scale: &[f32], bp: &PanelPackI4, b_scale: &[f32],
        resid: Option<&ResidStaged<'_>>,
    ) {
        let bs = self.bs;
        let kn = self.kernels;
        for bj in 0..self.nbk {
            let width = bp.widths[bj];
            let c_lo = bj * bs;
            let panel = bp.panel(bj);
            let fpanel = resid
                .and_then(|r| r.bpf.as_deref())
                .map(|p| p.panel(bj));
            let mut rl = 0usize;
            while rl < rows {
                let left = rows - rl;
                let (tile, dot): (usize, DotI4) = if left >= 4 {
                    (4, kn.dot4_i4)
                } else if left >= 2 {
                    (2, kn.dot2_i4)
                } else {
                    (1, kn.dot_i4)
                };
                for bk in 0..self.kb {
                    let sa = a_scale[bi * self.kb + bk];
                    let sb = b_scale[bk * self.nbk + bj];
                    dot(
                        qa, a_pcols, r_lo + rl, bk * bs, bs, panel,
                        width, acci, acc,
                    );
                    let w = sa * sb;
                    for t in 0..tile {
                        let crow = &mut crows[(rl + t) * self.n + c_lo
                                              ..][..width];
                        scale_add(crow, &acc[t * bs..], width, w);
                    }
                    if let Some(res) = resid {
                        // staged ladder: residual work really skipped
                        // for blocks that stayed at the INT4 tier
                        if res.u8m[bi * self.kb + bk] {
                            let rs = res.r_scale[bi * self.kb + bk];
                            dot(
                                res.rq, a_pcols, r_lo + rl, bk * bs,
                                bs, panel, width, acci, acc,
                            );
                            let rw = rs * sb;
                            for t in 0..tile {
                                let crow =
                                    &mut crows[(rl + t) * self.n + c_lo
                                               ..][..width];
                                scale_add(crow, &acc[t * bs..], width,
                                          rw);
                            }
                        }
                        if res.uf[bi * self.kb + bk] {
                            let fp = fpanel.expect(
                                "f32 panels packed when any block \
                                 reaches the f32 tier",
                            );
                            for t in 0..tile {
                                panel_dot(
                                    res.r2, a_pcols, r_lo + rl + t,
                                    bk * bs, bs, fp, width,
                                    &mut acc[..bs],
                                );
                                let crow =
                                    &mut crows[(rl + t) * self.n + c_lo
                                               ..][..width];
                                scale_add(crow, &acc[..bs], width, sb);
                            }
                        }
                    }
                }
                rl += tile;
            }
        }
    }
}

/// The cacheable **weight half** of a GEMM plan: the B operand's
/// quantized codes, their packed column panels (materialized eagerly
/// at construction), and the microkernel backend pinned for every
/// plan derived from it.
///
/// [`GemmPlan`] borrows both operands, so a plan cannot outlive the
/// activation quant of one training microstep. Splitting the plan
/// separates what is **step-invariant** — weight quantization, panel
/// packing, backend choice — from the **per-call** activation half:
/// a `WeightPlan` is built once (and owned across steps by
/// `gemm::pipeline`'s `PlanCache`), while
/// [`plan_int8`](WeightPlan::plan_int8) /
/// [`plan_fallback`](WeightPlan::plan_fallback) re-plan the
/// activation side against it per microstep with zero packing or
/// conversion work (the cached panels ride through the same `Arc`s).
///
/// Derived plans are **bit-identical** to plans built directly from
/// the same operands: the panel pack is the one cached on the
/// [`BlockQuant`] itself, and the backend pin only selects among
/// bit-identical kernels. `tests/pipeline_prop.rs` asserts this per
/// backend, precision, data path, and thread count. See
/// `docs/ARCHITECTURE.md` for the packed-once vs per-call split.
#[derive(Debug, Clone)]
pub struct WeightPlan {
    qb: Arc<BlockQuant>,
    path: DataPath,
    kernels: &'static Kernels,
    /// requested shard count inherited by every derived plan (each
    /// plan clamps it to its own panel count)
    shards: usize,
}

impl WeightPlan {
    /// Take ownership of `qb` as a cacheable weight operand and pack
    /// its column panels for `path` now, so every later plan build
    /// against this weight does no packing at all.
    pub fn new(qb: Arc<BlockQuant>, path: DataPath) -> WeightPlan {
        match path {
            DataPath::SimF32 => {
                qb.col_panels();
            }
            DataPath::Int8 => {
                qb.col_panels_i8();
            }
            // Only the nibble panels are packed eagerly; the f32
            // panels the staged ladder's f32 tier reads are built
            // lazily by the first plan whose mask actually promotes a
            // block that far (see GemmPlan::new_staged).
            DataPath::Int4 => {
                qb.col_panels_i4();
            }
        }
        WeightPlan {
            qb,
            path,
            kernels: kernels::select(),
            shards: pool::default_shards(),
        }
    }

    /// Pin derived plans to an explicit microkernel backend (default:
    /// whatever [`kernels::select`] chose at construction time).
    pub fn with_kernels(mut self, k: &'static Kernels) -> WeightPlan {
        self.kernels = k;
        self
    }

    /// Shard count every derived plan is built with (default: the
    /// `PALLAS_SHARDS` knob via [`pool::default_shards`]). Sharding
    /// never changes derived-plan results — see
    /// [`GemmPlan::with_shards`].
    pub fn with_shards(mut self, shards: usize) -> WeightPlan {
        self.shards = shards.max(1);
        self
    }

    /// The shard count derived plans inherit (before per-plan
    /// clamping).
    pub fn shard_count(&self) -> usize {
        self.shards
    }

    /// The cached quantized weight operand.
    pub fn weight(&self) -> &BlockQuant {
        &self.qb
    }

    /// The data path the cached panels were packed for.
    pub fn data_path(&self) -> DataPath {
        self.path
    }

    /// Backend every derived plan executes with.
    pub fn kernel_backend(&self) -> &'static str {
        self.kernels.name
    }

    /// (k, n) of the weight operand — GEMM inner dim × output
    /// features.
    pub fn dims(&self) -> (usize, usize) {
        (self.qb.rows, self.qb.cols)
    }

    /// Resident bytes of the cached half: the stored codes + scales
    /// plus the packed column panels for this plan's data path —
    /// what one warm `PlanCache` entry actually keeps alive across
    /// steps (reported by `benches/model_step.rs`).
    pub fn packed_bytes(&self) -> usize {
        let panels = match self.path {
            DataPath::SimF32 => self.qb.col_panels().bytes(),
            DataPath::Int8 => self.qb.col_panels_i8().bytes(),
            // eager footprint only — a lazily built f32-tier panel
            // cache is not counted (it exists only after a microstep
            // promoted a block to the f32 tier)
            DataPath::Int4 => self.qb.col_panels_i4().bytes(),
        };
        self.qb.bytes() + panels
    }

    /// Plan `C = A · W` at `Int8Block` precision against the cached
    /// weight half; only the activation operand is read per call.
    pub fn plan_int8<'p>(&'p self, a: &'p BlockQuant,
                         threads: usize) -> GemmPlan<'p> {
        GemmPlan::new_int8_path(a, self.qb.as_ref(), threads, self.path)
            .with_kernels(self.kernels)
            .with_shards(self.shards)
    }

    /// Plan a fallback GEMM (Algorithm 1) against the cached weight
    /// half. `u` is the activation-side fallback mask (`&fa.u` or a
    /// `remap_placement` result).
    pub fn plan_fallback<'p>(&'p self, fa: &'p FallbackQuant,
                             u: &'p [bool], threads: usize)
                             -> GemmPlan<'p> {
        GemmPlan::new_fallback_path(fa, self.qb.as_ref(), u, threads,
                                    self.path)
            .with_kernels(self.kernels)
            .with_shards(self.shards)
    }

    /// Plan a staged Int4→Int8→f32 lattice GEMM against the cached
    /// weight half (which must have been built for
    /// [`DataPath::Int4`], so the nibble panels are already packed).
    pub fn plan_staged<'p>(&'p self, sa: &'p StagedQuant,
                           threads: usize) -> GemmPlan<'p> {
        GemmPlan::new_staged(sa, self.qb.as_ref(), threads)
            .with_kernels(self.kernels)
            .with_shards(self.shards)
    }
}

/// `crow[j] += acc[j] * w` — the per-K-block scale-FMA of Eq. 1.
#[inline]
fn scale_add(crow: &mut [f32], acc: &[f32], width: usize, w: f32) {
    for (cv, &v) in crow.iter_mut().zip(acc[..width].iter()) {
        *cv += v * w;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::int8::{remap_placement, Placement};
    use crate::quant::{block_quant, fallback_quant, staged_quant,
                       Criterion, Rounding, INT4_LEVELS, INT8_LEVELS};
    use crate::util::rng::Pcg64;

    fn mats(m: usize, k: usize, n: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Pcg64::new(seed);
        (Mat::randn(m, k, 1.0, &mut rng),
         Mat::randn(k, n, 1.0, &mut rng))
    }

    #[test]
    fn plan_reuse_is_deterministic() {
        let (a, b) = mats(48, 33, 40, 3);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        let plan = GemmPlan::new_int8(&qa, &qb, 2);
        assert_eq!(plan.precision(), Precision::Int8Block);
        assert_eq!(plan.dims(), (48, 40, 33));
        let c1 = plan.execute();
        let c2 = plan.execute();
        assert_eq!(c1.data, c2.data);
    }

    #[test]
    fn thread_counts_agree_bitwise() {
        let (a, b) = mats(64, 48, 37, 5);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        let c1 = GemmPlan::new_int8(&qa, &qb, 1).execute();
        for threads in [2, 4, 7] {
            let ct = GemmPlan::new_int8(&qa, &qb, threads).execute();
            assert_eq!(c1.data, ct.data, "threads={threads}");
        }
    }

    #[test]
    fn execute_into_reuses_output_and_workspace() {
        let (a, b) = mats(48, 33, 40, 41);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        // threads=1 executes inline on this thread, so the
        // thread-local workspace counter delta is deterministic.
        let plan = GemmPlan::new_int8(&qa, &qb, 1);
        let oracle = plan.execute();
        let mut c = Mat::zeros(0, 0);
        plan.execute_into(&mut c);
        assert_eq!(c.data, oracle.data);
        // Warm repeat: same bits, zero output/workspace growths.
        let (_, ws0) = pool::work_counters();
        plan.execute_into(&mut c);
        let (_, ws1) = pool::work_counters();
        assert_eq!(c.data, oracle.data);
        assert_eq!(ws1, ws0, "warm execute_into must not allocate");
    }

    #[test]
    fn fallback_weights_reflect_u_mask() {
        let mut rng = Pcg64::new(9);
        let mut a = Mat::randn(64, 64, 1.0, &mut rng);
        for i in 0..12 {
            a.data[i * 97 % a.data.len()] = 300.0;
        }
        let b = Mat::randn(64, 32, 1.0, &mut rng);
        let fa = fallback_quant(&a, 50.0, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        let useq = remap_placement(&fa, Placement::Sequential);
        let plan = GemmPlan::new_fallback(&fa, &qb, &useq, 2);
        let w = plan.panel_weights();
        // Sequential placement packs fallback into leading panels, so
        // the first panel must be the heaviest.
        let total_fb: usize = useq.iter().filter(|&&x| x).count();
        if total_fb > 0 {
            assert!(w[0] > *w.last().unwrap(),
                    "weights {w:?} with {total_fb} fallback blocks");
        }
        // Makespan with LPT must beat (or match) the contiguous-halves
        // split implied by chunked scheduling.
        let (total, makespan) = plan.schedule_makespan();
        assert!(makespan >= total / 2.0 - 1e-9);
        let contiguous: f64 = w[..w.len() / 2].iter().sum();
        assert!(makespan <= contiguous.max(total - contiguous) + 1e-9);
    }

    #[test]
    fn dense_plan_matches_row_kernels() {
        // odd row count exercises the single-row tail path
        let (a, b) = mats(17, 21, 13, 11);
        let c = GemmPlan::new_dense(&a, &b, 2).execute();
        let naive = crate::gemm::dense::matmul_naive(&a, &b);
        let mut max = 0.0f32;
        for (x, y) in c.data.iter().zip(naive.data.iter()) {
            max = max.max((x - y).abs());
        }
        assert!(max < 1e-3, "diff {max}");
    }

    #[test]
    fn empty_dims_yield_zero_matrix() {
        let a = Mat::zeros(0, 8);
        let b = Mat::zeros(8, 4);
        let c = GemmPlan::new_dense(&a, &b, 4).execute();
        assert_eq!((c.rows, c.cols), (0, 4));
    }

    #[test]
    fn data_paths_agree_bitwise() {
        let (a, b) = mats(48, 33, 40, 29);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        let sim = GemmPlan::new_int8_path(&qa, &qb, 2,
                                          DataPath::SimF32);
        let i8p = GemmPlan::new_int8_path(&qa, &qb, 2, DataPath::Int8);
        assert_eq!(sim.data_path(), DataPath::SimF32);
        assert_eq!(i8p.data_path(), DataPath::Int8);
        assert_eq!(sim.execute().data, i8p.execute().data);
        // default constructor picks the i8 path inside the bound
        assert_eq!(GemmPlan::new_int8(&qa, &qb, 2).data_path(),
                   DataPath::Int8);
    }

    #[test]
    fn i8_path_skips_f32_caches() {
        // Memory contract: an Int8-path plan must not materialize the
        // 4x-bigger f32 code caches on either operand; the SimF32
        // oracle path still builds them lazily on demand.
        let (a, b) = mats(48, 32, 32, 31);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        let c_i8 = GemmPlan::new_int8_path(&qa, &qb, 2, DataPath::Int8)
            .execute();
        assert!(!qa.f32_codes_built(), "A f32 codes materialized");
        assert!(!qb.f32_panels_built(), "B f32 panels materialized");
        assert!(qb.i8_panels_built());
        let c_sim =
            GemmPlan::new_int8_path(&qa, &qb, 2, DataPath::SimF32)
                .execute();
        assert_eq!(c_i8.data, c_sim.data);
        assert!(qa.f32_codes_built() && qb.f32_panels_built());
    }

    #[test]
    fn fallback_i8_path_skips_residual_f32() {
        let mut rng = Pcg64::new(37);
        let mut a = Mat::randn(48, 48, 1.0, &mut rng);
        for i in 0..8 {
            a.data[i * 131 % a.data.len()] = 250.0;
        }
        let b = Mat::randn(48, 32, 1.0, &mut rng);
        let fa = fallback_quant(&a, 40.0, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        let c_i8 = GemmPlan::new_fallback_path(&fa, &qb, &fa.u, 2,
                                               DataPath::Int8)
            .execute();
        assert!(!fa.residual_f32_built(),
                "residual f32 copy materialized on the i8 path");
        assert!(!fa.base.f32_codes_built());
        let c_sim = GemmPlan::new_fallback_path(&fa, &qb, &fa.u, 2,
                                                DataPath::SimF32)
            .execute();
        assert_eq!(c_i8.data, c_sim.data);
        assert!(fa.residual_f32_built());
    }

    #[test]
    fn explicit_backends_agree_bitwise_and_report_names() {
        // Every backend available on this host must produce the same
        // bits through the full engine, on both precisions, with a
        // block size that is not a multiple of any vector width and
        // an odd output tail.
        let (a, b) = mats(43, 36, 29, 41);
        let qa = block_quant(&a, 12, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 12, INT8_LEVELS, Rounding::Nearest);
        let backends = crate::gemm::kernels::available();
        let c_scalar = GemmPlan::new_int8_path(&qa, &qb, 2,
                                               DataPath::Int8)
            .with_kernels(&crate::gemm::kernels::SCALAR)
            .execute();
        for &kn in &backends {
            let plan = GemmPlan::new_int8_path(&qa, &qb, 2,
                                               DataPath::Int8)
                .with_kernels(kn);
            assert_eq!(plan.kernel_backend(), kn.name);
            assert_eq!(plan.execute().data, c_scalar.data,
                       "backend {}", kn.name);
        }
        // default selection is one of the available backends
        let dflt = GemmPlan::new_int8(&qa, &qb, 2);
        assert!(backends.iter().any(|k| k.name == dflt.kernel_backend()));
        assert_eq!(dflt.execute().data, c_scalar.data);
    }

    #[test]
    fn four_row_tiles_match_reference_at_tail_counts() {
        // 4-row tiling kicks in for sched panels ≥ 4 rows; row counts
        // 4q+{0..3} exercise every tail tile (4/2/1 mixes).
        for m in [16usize, 17, 18, 19, 21] {
            let (a, b) = mats(m, 32, 20, 100 + m as u64);
            let qa =
                block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
            let qb =
                block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
            let c_i8 =
                GemmPlan::new_int8_path(&qa, &qb, 1, DataPath::Int8)
                    .execute();
            let c_ref =
                crate::gemm::int8::block_gemm_reference(&qa, &qb);
            assert_eq!(c_i8.data, c_ref.data, "m={m}");
        }
    }

    #[test]
    fn exactness_bound_is_tight() {
        // bs · 127² ≤ 2²⁴ exactly at the bound, violated just past it.
        assert_eq!(I8_EXACT_MAX_BS, 1040);
        assert!(I8_EXACT_MAX_BS * 127 * 127 <= 1 << 24);
        assert!((I8_EXACT_MAX_BS + 1) * 127 * 127 > 1 << 24);
        assert_eq!(DataPath::auto_for(I8_EXACT_MAX_BS),
                   DataPath::Int8);
        assert_eq!(DataPath::auto_for(I8_EXACT_MAX_BS + 1),
                   DataPath::SimF32);
    }

    #[test]
    fn i8_exact_at_boundary_block_size() {
        // Adversarial worst case at bs = I8_EXACT_MAX_BS: all codes
        // saturated at ±127, so the block dot hits bs·127² — the
        // largest magnitude the exactness argument must cover. The i8
        // path must agree bitwise with both the f32 simulation and the
        // exact i64 reference.
        let bs = I8_EXACT_MAX_BS;
        let a = Mat::from_vec(2, bs, vec![127.0f32; 2 * bs]);
        let b = Mat::from_vec(bs, 2, vec![127.0f32; 2 * bs]);
        let qa = block_quant(&a, bs, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, bs, INT8_LEVELS, Rounding::Nearest);
        assert!(qa.q[..a.cols].iter().all(|&q| q == 127));
        let c_i8 = GemmPlan::new_int8_path(&qa, &qb, 1, DataPath::Int8)
            .execute();
        let c_sim =
            GemmPlan::new_int8_path(&qa, &qb, 1, DataPath::SimF32)
                .execute();
        let c_ref = crate::gemm::int8::block_gemm_reference(&qa, &qb);
        assert_eq!(c_i8.data, c_sim.data);
        assert_eq!(c_i8.data, c_ref.data);
        // the raw dot really is bs·127², scaled by the one shared
        // per-block scale product — the same FP ops the engine runs
        let dot = (bs * 127 * 127) as f32;
        let w = qa.scale[0] * qb.scale[0];
        assert_eq!(c_i8.data[0], dot * w);
    }

    #[test]
    fn weight_plan_packs_eagerly_and_derives_identical_plans() {
        let (a, w) = mats(40, 32, 48, 51);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let qw = Arc::new(block_quant(&w, 16, INT8_LEVELS,
                                      Rounding::Nearest));
        let wp = WeightPlan::new(qw.clone(), DataPath::Int8);
        // panels packed at construction, on the i8 side only
        assert!(qw.i8_panels_built());
        assert!(!qw.f32_panels_built() && !qw.f32_codes_built());
        assert_eq!(wp.dims(), (32, 48));
        assert_eq!(wp.data_path(), DataPath::Int8);
        assert_eq!(wp.weight().block, 16);
        // derived plan ≡ direct plan, bitwise, at both precisions
        let c_wp = wp.plan_int8(&qa, 2).execute();
        let c_direct =
            GemmPlan::new_int8_path(&qa, qw.as_ref(), 2,
                                    DataPath::Int8)
                .execute();
        assert_eq!(c_wp.data, c_direct.data);
        let fa = fallback_quant(&a, -1.0, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        let f_wp = wp.plan_fallback(&fa, &fa.u, 2).execute();
        let f_direct = GemmPlan::new_fallback_path(
            &fa, qw.as_ref(), &fa.u, 2, DataPath::Int8)
            .execute();
        assert_eq!(f_wp.data, f_direct.data);
        // backend pin survives into derived plans
        let wp_scalar = WeightPlan::new(qw.clone(), DataPath::Int8)
            .with_kernels(&crate::gemm::kernels::SCALAR);
        assert_eq!(wp_scalar.kernel_backend(), "scalar");
        let plan = wp_scalar.plan_int8(&qa, 1);
        assert_eq!(plan.kernel_backend(), "scalar");
        assert_eq!(plan.execute().data, c_wp.data);
    }

    #[test]
    fn sharded_plans_agree_bitwise_with_flat() {
        // Sharding must never change bits: sweep S × threads × paths
        // on a fallback GEMM (residual path included) against the
        // S=1 single-thread oracle. 40 output cols / block 16 → 3
        // column panels, so S=4 also exercises the clamp.
        let mut rng = Pcg64::new(71);
        let mut a = Mat::randn(48, 32, 1.0, &mut rng);
        for i in 0..10 {
            a.data[i * 113 % a.data.len()] = 260.0;
        }
        let b = Mat::randn(32, 40, 1.0, &mut rng);
        let fa = fallback_quant(&a, 40.0, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        for path in [DataPath::Int8, DataPath::SimF32] {
            let oracle = GemmPlan::new_fallback_path(&fa, &qb, &fa.u,
                                                     1, path)
                .with_shards(1)
                .execute();
            for s in [1usize, 2, 3, 4] {
                for threads in [1usize, 2, 4] {
                    let plan = GemmPlan::new_fallback_path(
                        &fa, &qb, &fa.u, threads, path)
                        .with_shards(s);
                    assert!(plan.shard_count() <= 3);
                    assert_eq!(
                        plan.execute().data, oracle.data,
                        "path={path:?} shards={s} threads={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn sharded_int8_plan_matches_exact_reference() {
        let (a, b) = mats(33, 32, 40, 77);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        let c_ref = crate::gemm::int8::block_gemm_reference(&qa, &qb);
        for s in [2usize, 3] {
            let c = GemmPlan::new_int8_path(&qa, &qb, 4,
                                            DataPath::Int8)
                .with_shards(s)
                .execute();
            assert_eq!(c.data, c_ref.data, "shards={s}");
        }
    }

    #[test]
    fn shard_count_clamps_to_panels_and_dense_ignores_it() {
        let (a, b) = mats(32, 32, 40, 83);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        // 40 cols / block 16 → 3 panels: requests past that clamp
        let plan = GemmPlan::new_int8(&qa, &qb, 2).with_shards(8);
        assert_eq!(plan.shard_count(), 3);
        assert_eq!(GemmPlan::new_int8(&qa, &qb, 2).with_shards(0)
                       .shard_count(), 1);
        // dense plans stream whole B rows — nothing to shard
        let dense = GemmPlan::new_dense(&a, &b, 2).with_shards(4);
        assert_eq!(dense.shard_count(), 1);
    }

    #[test]
    fn sharded_makespan_stays_within_flat_total() {
        let mut rng = Pcg64::new(91);
        let mut a = Mat::randn(64, 64, 1.0, &mut rng);
        for i in 0..12 {
            a.data[i * 97 % a.data.len()] = 300.0;
        }
        let b = Mat::randn(64, 32, 1.0, &mut rng);
        let fa = fallback_quant(&a, 50.0, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        let flat = GemmPlan::new_fallback(&fa, &qb, &fa.u, 4)
            .with_shards(1);
        let sharded = GemmPlan::new_fallback(&fa, &qb, &fa.u, 4)
            .with_shards(2);
        let (total_f, mk_f) = flat.schedule_makespan();
        let (total_s, mk_s) = sharded.schedule_makespan();
        assert_eq!(total_f, total_s, "total work is shard-invariant");
        assert!(mk_s > 0.0 && mk_s <= total_s + 1e-9);
        assert!(mk_f > 0.0 && mk_f <= total_f + 1e-9);
    }

    #[test]
    fn weight_plan_shard_config_survives_into_derived_plans() {
        let (a, w) = mats(32, 32, 40, 97);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let qw = Arc::new(block_quant(&w, 16, INT8_LEVELS,
                                      Rounding::Nearest));
        let wp = WeightPlan::new(qw.clone(), DataPath::Int8)
            .with_shards(2);
        assert_eq!(wp.shard_count(), 2);
        let plan = wp.plan_int8(&qa, 4);
        assert_eq!(plan.shard_count(), 2);
        // derived sharded plan ≡ direct flat plan, bitwise
        let c_flat = GemmPlan::new_int8_path(&qa, qw.as_ref(), 1,
                                             DataPath::Int8)
            .with_shards(1)
            .execute();
        assert_eq!(plan.execute().data, c_flat.data);
    }

    #[test]
    fn data_path_tags_roundtrip() {
        for p in [DataPath::SimF32, DataPath::Int8, DataPath::Int4] {
            assert_eq!(DataPath::from_tag(p.tag()), Some(p));
        }
        assert_eq!(DataPath::from_tag("Int8"), None, "tags are stable \
                   lowercase names, not Debug output");
    }

    #[test]
    fn path_override_parses_or_is_absent() {
        assert_eq!(parse_path_override(None), None);
        assert_eq!(parse_path_override(Some("")), None);
        assert_eq!(parse_path_override(Some("sim_f32")),
                   Some(DataPath::SimF32));
        assert_eq!(parse_path_override(Some("int8")),
                   Some(DataPath::Int8));
        assert_eq!(parse_path_override(Some("int4")),
                   Some(DataPath::Int4));
    }

    #[test]
    #[should_panic(expected = "not a data path tag")]
    fn path_override_rejects_unknown_tag() {
        parse_path_override(Some("fp4"));
    }

    #[test]
    fn int4_path_agrees_with_simf32_and_reference() {
        // Both operands quantized at INT4_LEVELS: the nibble path,
        // the f32 simulation of the same codes, and the exact i64
        // reference must agree bitwise, for every thread count.
        let (a, b) = mats(48, 33, 40, 201);
        let qa = block_quant(&a, 16, INT4_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 16, INT4_LEVELS, Rounding::Nearest);
        let c_ref = crate::gemm::int4::int4_gemm_reference(&qa, &qb);
        let c_sim =
            GemmPlan::new_int8_path(&qa, &qb, 2, DataPath::SimF32)
                .execute();
        assert_eq!(c_sim.data, c_ref.data);
        for threads in [1usize, 2, 4] {
            let plan = GemmPlan::new_int8_path(&qa, &qb, threads,
                                               DataPath::Int4);
            assert_eq!(plan.data_path(), DataPath::Int4);
            assert_eq!(plan.precision(), Precision::Int8Block);
            assert_eq!(plan.execute().data, c_ref.data,
                       "threads={threads}");
        }
    }

    #[test]
    fn int4_path_skips_wider_caches() {
        // Memory contract, lattice edition: an Int4 plan packs only
        // the nibble panels — no i8 panels, no f32 codes or panels.
        let (a, b) = mats(32, 32, 32, 203);
        let qa = block_quant(&a, 16, INT4_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 16, INT4_LEVELS, Rounding::Nearest);
        GemmPlan::new_int8_path(&qa, &qb, 2, DataPath::Int4).execute();
        assert!(qb.i4_panels_built());
        assert!(!qb.i8_panels_built(), "i8 panels materialized");
        assert!(!qb.f32_panels_built(), "f32 panels materialized");
        assert!(!qa.f32_codes_built(), "A f32 codes materialized");
    }

    /// Outlier-bearing operands for the staged tests: every tier of
    /// the ladder must be populated at θ = 2.
    fn staged_operands(seed: u64) -> (Mat, Mat) {
        let mut rng = Pcg64::new(seed);
        let mut a = Mat::randn(48, 32, 1.0, &mut rng);
        for i in 0..10 {
            // moderate outliers → INT8 tier
            a.data[(i * 113 + 7) % a.data.len()] = 3.0;
            // extreme outliers → f32 tier (past κ·θ = 8)
            a.data[(i * 131 + 3) % a.data.len()] = 40.0;
        }
        let b = Mat::randn(32, 40, 1.0, &mut rng);
        (a, b)
    }

    #[test]
    fn staged_plan_matches_reference_across_threads_and_shards() {
        let (a, b) = staged_operands(205);
        let sa = staged_quant(&a, 2.0, 16);
        assert!(sa.rate_i8() > 0.0 && sa.rate_f32() > 0.0,
                "ladder not exercised: i8 {} f32 {}",
                sa.rate_i8(), sa.rate_f32());
        let qb = block_quant(&b, 16, INT4_LEVELS, Rounding::Nearest);
        let c_ref =
            crate::gemm::int4::staged_gemm_reference(&sa, &qb);
        for threads in [1usize, 2, 4] {
            for shards in [1usize, 2] {
                let plan = GemmPlan::new_staged(&sa, &qb, threads)
                    .with_shards(shards);
                assert_eq!(plan.precision(), Precision::Fallback);
                assert_eq!(plan.data_path(), DataPath::Int4);
                assert_eq!(
                    plan.execute().data, c_ref.data,
                    "threads={threads} shards={shards}"
                );
            }
        }
    }

    #[test]
    fn staged_backends_agree_bitwise() {
        let (a, b) = staged_operands(207);
        let sa = staged_quant(&a, 2.0, 16);
        let qb = block_quant(&b, 16, INT4_LEVELS, Rounding::Nearest);
        let c_scalar = GemmPlan::new_staged(&sa, &qb, 2)
            .with_kernels(&crate::gemm::kernels::SCALAR)
            .execute();
        for &kn in &crate::gemm::kernels::available() {
            let c = GemmPlan::new_staged(&sa, &qb, 2)
                .with_kernels(kn)
                .execute();
            assert_eq!(c.data, c_scalar.data, "backend {}", kn.name);
        }
    }

    #[test]
    fn staged_plan_defers_f32_panels_until_promoted() {
        // θ = ∞ keeps every block at the INT4 tier: no residual
        // terms, no f32 panel build — and the plan is bit-identical
        // to the pure Int4 plan over the base codes.
        let (a, b) = staged_operands(209);
        let sa = staged_quant(&a, f32::INFINITY, 16);
        assert_eq!(sa.rate_i8(), 0.0);
        let qb = block_quant(&b, 16, INT4_LEVELS, Rounding::Nearest);
        let c = GemmPlan::new_staged(&sa, &qb, 2).execute();
        assert!(!qb.f32_panels_built(),
                "f32 panels built with nothing promoted");
        let c_pure =
            GemmPlan::new_int8_path(&sa.base, &qb, 2, DataPath::Int4)
                .execute();
        assert_eq!(c.data, c_pure.data);
        // θ < 0 promotes everything to the f32 tier: the staged
        // result reproduces the dequantized-A product exactly (base +
        // residual + remainder telescope to x itself).
        let sf = staged_quant(&a, -1.0, 16);
        assert_eq!(sf.rate_f32(), 1.0);
        let cf = GemmPlan::new_staged(&sf, &qb, 2).execute();
        assert!(qb.f32_panels_built());
        let cf_ref =
            crate::gemm::int4::staged_gemm_reference(&sf, &qb);
        assert_eq!(cf.data, cf_ref.data);
    }

    #[test]
    fn staged_weights_reflect_tier_masks() {
        let (a, b) = staged_operands(211);
        let sa = staged_quant(&a, 2.0, 16);
        let qb = block_quant(&b, 16, INT4_LEVELS, Rounding::Nearest);
        let plan = GemmPlan::new_staged(&sa, &qb, 2);
        let w = plan.panel_weights();
        let flat = GemmPlan::new_int8_path(&sa.base, &qb, 2,
                                           DataPath::Int4);
        let promoted: usize = sa.u8_mask.iter()
            .chain(sa.uf_mask.iter())
            .filter(|&&x| x)
            .count();
        assert!(promoted > 0);
        let total: f64 = w.iter().sum();
        let base_total: f64 = flat.panel_weights().iter().sum();
        assert!(total > base_total,
                "promotion must add schedule weight");
    }

    #[test]
    fn weight_plan_plan_staged_matches_direct() {
        let (a, b) = staged_operands(213);
        let sa = staged_quant(&a, 2.0, 16);
        let qw = Arc::new(block_quant(&b, 16, INT4_LEVELS,
                                      Rounding::Nearest));
        let wp = WeightPlan::new(qw.clone(), DataPath::Int4);
        assert!(qw.i4_panels_built(), "nibble panels not eager");
        assert_eq!(wp.packed_bytes(),
                   qw.bytes() + qw.col_panels_i4().bytes());
        let c_wp = wp.plan_staged(&sa, 2).execute();
        let c_direct = GemmPlan::new_staged(&sa, qw.as_ref(), 2)
            .execute();
        assert_eq!(c_wp.data, c_direct.data);
        // plain Int4 derivation shares the same packed panels
        let c_base = wp.plan_int8(&sa.base, 2).execute();
        let c_base_direct = GemmPlan::new_int8_path(
            &sa.base, qw.as_ref(), 2, DataPath::Int4)
            .execute();
        assert_eq!(c_base.data, c_base_direct.data);
    }

    #[test]
    fn i4_exactness_bound_is_tight() {
        // bs · 127 · 7 ≤ 2²⁴ exactly at the bound, violated past it.
        assert_eq!(I4_EXACT_MAX_BS, (1 << 24) / 889);
        assert!(I4_EXACT_MAX_BS * 127 * 7 <= 1 << 24);
        assert!((I4_EXACT_MAX_BS + 1) * 127 * 7 > 1 << 24);
        assert!(I4_EXACT_MAX_BS > I8_EXACT_MAX_BS,
                "i4 products are smaller, so the bound is looser");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "exceeds the f32-exact range")]
    fn i4_overflow_guard_fires_past_exactness_bound() {
        // The engine cannot verify code ranges: stream saturated i8 A
        // codes (the staged residual's worst case) against saturated
        // i4 panels one past the bound — the shared widening guard
        // must catch the lost bits.
        let bs = I4_EXACT_MAX_BS + 1;
        let a = Mat::from_vec(1, bs, vec![127.0f32; bs]);
        let b = Mat::from_vec(bs, 1, vec![7.0f32; bs]);
        let qa = block_quant(&a, bs, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, bs, INT4_LEVELS, Rounding::Nearest);
        assert!(qa.q[..bs].iter().all(|&q| q == 127));
        assert!((0..bs).all(|k| qb.q[k * qb.pcols] == 7));
        GemmPlan::new_int8_path(&qa, &qb, 1, DataPath::Int4).execute();
    }

    #[test]
    fn weight_plan_reports_resident_bytes() {
        let (_, w) = mats(8, 32, 48, 61);
        let qw = Arc::new(block_quant(&w, 16, INT8_LEVELS,
                                      Rounding::Nearest));
        let wp = WeightPlan::new(qw.clone(), DataPath::Int8);
        // codes+scales plus the i8 panel pack, nothing f32-sized
        assert_eq!(wp.packed_bytes(),
                   qw.bytes() + qw.col_panels_i8().bytes());
        assert!(!qw.f32_panels_built());
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "exceeds the f32-exact range")]
    fn i8_overflow_guard_fires_past_exactness_bound() {
        // One past the bound with saturated codes: the widening loses
        // bits and the debug guard must catch it.
        let bs = I8_EXACT_MAX_BS + 1;
        let a = Mat::from_vec(1, bs, vec![127.0f32; bs]);
        let b = Mat::from_vec(bs, 1, vec![127.0f32; bs]);
        let qa = block_quant(&a, bs, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, bs, INT8_LEVELS, Rounding::Nearest);
        // force the i8 path — auto_for would refuse it here
        GemmPlan::new_int8_path(&qa, &qb, 1, DataPath::Int8).execute();
    }
}
