//! # Layer-step pipeline: cached GemmPlans across training steps
//!
//! The paper's 1.57x end-to-end speedup comes from running *whole
//! transformer layers* through the fallback GEMM, not one isolated
//! matmul — and the win evaporates if weight quantization and panel
//! packing are redone per call. This module keeps the step-invariant
//! half of every plan alive across microsteps and steps:
//!
//! ```text
//!   step boundary                 microstep (many per step)
//!   ─────────────                 ─────────────────────────
//!   PlanCache                       per site (qkv, attn_out,
//!    key: (weight id, shape,        mlp_in, mlp_down):
//!         data path, backend)        quantize X (fallback, θ_site)
//!    value: WeightPlan               quantize dY (plain int8)
//!     = q(W) + packed panels   ──►   fwd  Y  = X·W    (cached W)
//!       + pinned backend            bwd  dX = dY·Wᵀ  (cached Wᵀ)
//!    built on miss, owned           bwd  dW = Xᵀ·dY  (fresh: both
//!    across steps, LRU-evicted           operands change per call)
//!                                   record executed fallback rate
//!   RateAccumulator ──────────►   ThresholdController (Alg 2) at
//!    per-site means               the step boundary: θ adapts from
//!                                 real execution
//! ```
//!
//! What is packed **once** (cache hit = zero quantization/packing
//! work): the weight codes, their column panels for the plan's
//! [`DataPath`], and the transposed-weight twin for `dX`. What is
//! rebuilt **per call**: the activation fallback quant, the gradient
//! quant, and the `dW` plan whose operands both change every
//! microstep. `quant::quant_work_counters` makes the split observable
//! — the cache-hit regression tests and `benches/layer_step.rs` lean
//! on it.
//!
//! Bit-identity is non-negotiable: a cached plan must produce
//! byte-identical C to a freshly built one, on every kernel backend
//! and thread count — `tests/pipeline_prop.rs` sweeps exactly that.
//! See `docs/ARCHITECTURE.md` for how this layer sits on the
//! plan/execute engine.

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::{RateAccumulator, ThresholdController};
use crate::gemm::engine::{DataPath, GemmPlan, WeightPlan};
use crate::gemm::kernels::{self, Kernels};
use crate::model::{layer_linears, LinearShape};
use crate::quant::{block_quant_threads, fallback_quant_threads,
                   Criterion, Rounding, INT8_LEVELS};
use crate::util::rng::Pcg64;
use crate::util::threadpool::default_threads;
use crate::util::Mat;

/// Cache key of one weight half: the caller-assigned identity of the
/// weight *tensor*, its GEMM role (inner dim `k` × output features
/// `n`, quantization block), the data path the panels were packed
/// for, and the pinned microkernel backend.
///
/// `weight_id` is what keeps the cache content-correct: shapes alone
/// cannot distinguish two different weight matrices (a square layer
/// makes attn_out/mlp sites shape-identical), so the caller must
/// assign distinct ids to distinct tensors — `LayerStep` uses
/// `2·site + transposed`. The remaining fields exist because one
/// tensor can legitimately be cached several ways (per path and
/// backend) and those variants must not collide.
///
/// GEMM *precision* is deliberately not part of the key: a
/// [`WeightPlan`] is precision-agnostic (the same cached half serves
/// `plan_int8` and `plan_fallback` calls — only the activation side
/// differs), so keying on it would store byte-identical panels twice
/// per tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// caller-assigned identity of the weight tensor (distinct
    /// tensors MUST get distinct ids, or lookups conflate them)
    pub weight_id: u64,
    /// weight rows = GEMM inner dim
    pub k: usize,
    /// weight cols = output features
    pub n: usize,
    /// quantization block size
    pub block: usize,
    /// data path the cached panels were packed for
    pub path: DataPath,
    /// microkernel backend name pinned at build
    pub backend: &'static str,
}

/// Lifetime counters of a [`PlanCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

impl CacheStats {
    /// Hits over lookups (0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.hits + self.misses;
        if lookups == 0 {
            return 0.0;
        }
        self.hits as f64 / lookups as f64
    }
}

/// LRU cache of [`WeightPlan`]s keyed by [`PlanKey`] — owns the
/// packed weight panels across training steps so a microstep's plan
/// build does no weight quantization or packing on a hit.
#[derive(Debug)]
pub struct PlanCache {
    cap: usize,
    tick: u64,
    map: HashMap<PlanKey, (Arc<WeightPlan>, u64)>,
    stats: CacheStats,
}

impl PlanCache {
    /// `capacity` ≥ 1 entries; least-recently-used entries are
    /// evicted when a miss would exceed it.
    pub fn new(capacity: usize) -> PlanCache {
        assert!(capacity >= 1, "cache capacity must be >= 1");
        PlanCache {
            cap: capacity,
            tick: 0,
            map: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Whether `key` is resident (does not touch LRU order or stats).
    pub fn contains(&self, key: &PlanKey) -> bool {
        self.map.contains_key(key)
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Drop every cached entry (stats survive; not counted as
    /// evictions — this is the bench's "uncached" mode, not pressure).
    pub fn clear(&mut self) {
        self.map.clear();
    }

    /// Drop every entry caching the given weight tensor (all roles,
    /// precisions, paths, backends), returning how many were
    /// dropped. Callers MUST invalidate (or switch to a fresh id)
    /// after mutating a weight in place: the cache keys on identity,
    /// not tensor values, so a stale plan would otherwise keep being
    /// served — bit-exact against the *old* weights, with no error.
    /// `LayerStep::set_weight` wires this up for the optimizer-update
    /// path.
    pub fn invalidate_weight(&mut self, weight_id: u64) -> usize {
        let before = self.map.len();
        self.map.retain(|k, _| k.weight_id != weight_id);
        before - self.map.len()
    }

    /// Return the cached weight half for `key`, building (and
    /// inserting) it with `build` on a miss. The built plan is
    /// checked against the key's shape/block/path/backend — asserted
    /// at insert, so a builder mismatching those fields cannot poison
    /// later lookups. (`weight_id` has no witness on the plan and
    /// cannot be checked: keying the *right tensor* under the right
    /// id is the caller's contract — see [`PlanKey`].)
    pub fn get_or_build_with(
        &mut self, key: PlanKey,
        build: impl FnOnce() -> WeightPlan,
    ) -> Arc<WeightPlan> {
        self.tick += 1;
        if let Some((wp, last)) = self.map.get_mut(&key) {
            *last = self.tick;
            self.stats.hits += 1;
            return wp.clone();
        }
        self.stats.misses += 1;
        let wp = Arc::new(build());
        assert_eq!(wp.dims(), (key.k, key.n),
                   "built weight plan shape mismatches cache key");
        assert_eq!(wp.weight().block, key.block, "block size vs key");
        assert_eq!(wp.data_path(), key.path, "data path vs key");
        assert_eq!(wp.kernel_backend(), key.backend, "backend vs key");
        if self.map.len() >= self.cap {
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(k, _)| *k);
            if let Some(victim) = victim {
                self.map.remove(&victim);
                self.stats.evictions += 1;
            }
        }
        self.map.insert(key, (wp.clone(), self.tick));
        self.stats.insertions += 1;
        wp
    }
}

/// Configuration of a [`LayerStep`] driver.
#[derive(Debug, Clone)]
pub struct LayerStepConfig {
    pub d_model: usize,
    pub d_ff: usize,
    /// GLU MLP (doubles `mlp_in`'s output features)
    pub glu: bool,
    /// tokens per microstep (rows of every activation)
    pub tokens: usize,
    /// quantization block size
    pub block: usize,
    pub threads: usize,
    /// data path all plans run ([`DataPath::auto_for`] by default)
    pub path: DataPath,
    /// plan-cache capacity (a layer needs 8 entries: 2 weight halves
    /// × 4 sites; the default leaves headroom for shape churn)
    pub cache_capacity: usize,
}

impl LayerStepConfig {
    pub fn new(d_model: usize, d_ff: usize, tokens: usize,
               block: usize) -> LayerStepConfig {
        LayerStepConfig {
            d_model,
            d_ff,
            glu: true,
            tokens,
            block,
            threads: default_threads(),
            path: DataPath::auto_for(block),
            cache_capacity: 16,
        }
    }
}

/// The three GEMM outputs of one linear site for one microstep.
#[derive(Debug, Clone)]
pub struct SiteOutputs {
    /// forward `Y = X·W` (tokens × n)
    pub y: Mat,
    /// input gradient `dX = dY·Wᵀ` (tokens × k)
    pub dx: Mat,
    /// weight gradient `dW = Xᵀ·dY` (k × n)
    pub dw: Mat,
}

/// Per-site record of one microstep.
#[derive(Debug, Clone)]
pub struct SiteReport {
    pub name: &'static str,
    /// fallback rate the forward GEMM actually executed with
    pub fallback_rate: f64,
    /// useful FLOPs of the site's three GEMMs
    pub flops: f64,
}

/// One microstep's accounting across all sites.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub sites: Vec<SiteReport>,
    /// weight-plan cache lookups that hit during this microstep
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// useful FLOPs of the whole microstep (CAL-FLOPS numerator)
    pub flops: f64,
}

/// Drives the four linear sites of one transformer layer
/// ([`layer_linears`]) through the fallback GEMM engine — forward
/// plus both backward GEMMs per site, per the CAL-FLOPS accounting —
/// re-quantizing only the activation/gradient side per microstep and
/// reusing cached [`WeightPlan`]s for everything weight-shaped.
///
/// Fallback thresholds are per-site and owned by an embedded
/// [`ThresholdController`]; each microstep records the rates the
/// forward GEMMs actually ran with, and
/// [`end_step`](LayerStep::end_step) folds their means back into the
/// controller (Algorithm 2's between-step adjustment).
pub struct LayerStep {
    cfg: LayerStepConfig,
    sites: Vec<LinearShape>,
    /// master weights, one (k × n) matrix per site
    weights: Vec<Mat>,
    cache: PlanCache,
    controller: ThresholdController,
    rates: RateAccumulator,
    kernels: &'static Kernels,
    microsteps: usize,
}

impl LayerStep {
    /// `weights[i]` must be the (k × n) matrix of site `i` in
    /// [`layer_linears`] order (qkv, attn_out, mlp_in, mlp_down).
    pub fn new(cfg: LayerStepConfig, weights: Vec<Mat>) -> LayerStep {
        let sites =
            layer_linears(cfg.d_model, cfg.d_ff, cfg.glu, cfg.tokens);
        assert_eq!(weights.len(), sites.len(), "one weight per site");
        for (w, l) in weights.iter().zip(&sites) {
            assert_eq!((w.rows, w.cols), (l.k, l.n),
                       "weight shape for site {}", l.name);
        }
        let controller =
            ThresholdController::paper_default(sites.len());
        let rates = RateAccumulator::new(sites.len());
        let cache = PlanCache::new(cfg.cache_capacity);
        LayerStep {
            sites,
            weights,
            cache,
            controller,
            rates,
            kernels: kernels::select(),
            microsteps: 0,
            cfg,
        }
    }

    /// Synthetic Gaussian weights (benches, tests).
    pub fn with_random_weights(cfg: LayerStepConfig,
                               seed: u64) -> LayerStep {
        let sites =
            layer_linears(cfg.d_model, cfg.d_ff, cfg.glu, cfg.tokens);
        let mut rng = Pcg64::new(seed);
        let weights = sites
            .iter()
            .map(|l| Mat::randn(l.k, l.n, 0.05, &mut rng))
            .collect();
        LayerStep::new(cfg, weights)
    }

    pub fn sites(&self) -> &[LinearShape] {
        &self.sites
    }

    pub fn config(&self) -> &LayerStepConfig {
        &self.cfg
    }

    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    /// Drop every cached weight plan — each site's next microstep
    /// re-quantizes and repacks both weight halves (the bench's
    /// uncached baseline).
    pub fn clear_cache(&mut self) {
        self.cache.clear();
    }

    pub fn controller(&self) -> &ThresholdController {
        &self.controller
    }

    /// Mutable controller access (pin θ for ablations/benches).
    pub fn controller_mut(&mut self) -> &mut ThresholdController {
        &mut self.controller
    }

    /// Replace site `site`'s master weight (the optimizer-update
    /// path) and invalidate its cached halves — the next microstep
    /// re-quantizes and repacks exactly this site's W and Wᵀ, while
    /// every other site keeps hitting.
    pub fn set_weight(&mut self, site: usize, w: Mat) {
        let l = &self.sites[site];
        assert_eq!((w.rows, w.cols), (l.k, l.n),
                   "weight shape for site {}", l.name);
        self.weights[site] = w;
        self.cache.invalidate_weight(2 * site as u64);
        self.cache.invalidate_weight(2 * site as u64 + 1);
    }

    /// Microsteps run since construction.
    pub fn microsteps(&self) -> usize {
        self.microsteps
    }

    /// Backend every plan of this driver is pinned to.
    pub fn kernel_backend(&self) -> &'static str {
        self.kernels.name
    }

    /// Run one microstep: for every site, quantize the activation
    /// (fallback, at the site's current θ) and the output gradient
    /// (plain int8 — §5.1: dY is not fallback-quantized), then run
    /// fwd / dX / dW through the engine. Weight halves come from the
    /// plan cache; `acts[i]` is (tokens × k), `grads[i]` is
    /// (tokens × n) per site `i`.
    pub fn microstep(&mut self, acts: &[Mat],
                     grads: &[Mat]) -> (Vec<SiteOutputs>, StepReport) {
        assert_eq!(acts.len(), self.sites.len(), "one act per site");
        assert_eq!(grads.len(), self.sites.len(), "one grad per site");
        let (threads, block, path) =
            (self.cfg.threads, self.cfg.block, self.cfg.path);
        let kn = self.kernels;
        let hits0 = self.cache.stats().hits;
        let miss0 = self.cache.stats().misses;
        let sites = &self.sites;
        let weights = &self.weights;
        let cache = &mut self.cache;
        let mut outs = Vec::with_capacity(sites.len());
        let mut site_reports = Vec::with_capacity(sites.len());
        let mut rates = vec![0.0f64; sites.len()];
        for (i, l) in sites.iter().enumerate() {
            let x = &acts[i];
            let dy = &grads[i];
            assert_eq!((x.rows, x.cols), (l.m, l.k),
                       "activation shape for site {}", l.name);
            assert_eq!((dy.rows, dy.cols), (l.m, l.n),
                       "gradient shape for site {}", l.name);
            // per-call half: activation (fallback) + gradient (int8)
            let theta = self.controller.thresholds[i];
            let fx = fallback_quant_threads(x, theta, block,
                                            INT8_LEVELS,
                                            Criterion::AbsMax,
                                            threads);
            let qdy = block_quant_threads(dy, block, INT8_LEVELS,
                                          Rounding::Nearest, threads);
            rates[i] = fx.fallback_rate();
            // cached halves: W for the forward, Wᵀ for dX.
            // weight_id = 2·site + transposed: distinct per tensor,
            // so shape-identical sites can never serve each other's
            // weights.
            let wp = cache.get_or_build_with(
                PlanKey {
                    weight_id: 2 * i as u64,
                    k: l.k,
                    n: l.n,
                    block,
                    path,
                    backend: kn.name,
                },
                || {
                    WeightPlan::new(
                        Arc::new(block_quant_threads(
                            &weights[i], block, INT8_LEVELS,
                            Rounding::Nearest, threads,
                        )),
                        path,
                    )
                    .with_kernels(kn)
                },
            );
            let wpt = cache.get_or_build_with(
                PlanKey {
                    weight_id: 2 * i as u64 + 1,
                    k: l.n,
                    n: l.k,
                    block,
                    path,
                    backend: kn.name,
                },
                || {
                    WeightPlan::new(
                        Arc::new(block_quant_threads(
                            &weights[i].transpose(), block,
                            INT8_LEVELS, Rounding::Nearest, threads,
                        )),
                        path,
                    )
                    .with_kernels(kn)
                },
            );
            let y = wp.plan_fallback(&fx, &fx.u, threads).execute();
            let dx = wpt.plan_int8(&qdy, threads).execute();
            // dW = Xᵀ·dY: both operands change every microstep, so
            // this plan is legitimately fresh (qdy serves as the B
            // operand here and as the A operand of dX above — one
            // quantization, two roles).
            let qxt = block_quant_threads(&x.transpose(), block,
                                          INT8_LEVELS,
                                          Rounding::Nearest, threads);
            let dw =
                GemmPlan::new_int8_path(&qxt, &qdy, threads, path)
                    .with_kernels(kn)
                    .execute();
            outs.push(SiteOutputs { y, dx, dw });
            site_reports.push(SiteReport {
                name: l.name,
                fallback_rate: rates[i],
                flops: l.microstep_flops(),
            });
        }
        self.rates.record(&rates);
        self.microsteps += 1;
        let stats = self.cache.stats();
        let flops = site_reports.iter().map(|s| s.flops).sum();
        let report = StepReport {
            sites: site_reports,
            cache_hits: stats.hits - hits0,
            cache_misses: stats.misses - miss0,
            flops,
        };
        (outs, report)
    }

    /// Step boundary (Algorithm 2): fold the microsteps' mean
    /// executed per-site fallback rates into the threshold controller
    /// and reset the accumulator. Returns the rates that were
    /// applied (empty when no microstep ran since the last call).
    pub fn end_step(&mut self) -> Vec<f32> {
        self.rates.flush_into(&mut self.controller)
    }
}

/// Synthetic per-site activations and output gradients: Gaussian
/// base, with sparse hot channels in the activations (every 97th
/// input feature spikes with probability 0.3 — the §4.1
/// channel-structured outliers) so the fallback path has texture to
/// adapt to. Returns `(acts, grads)` in site order.
pub fn synth_microbatch(sites: &[LinearShape], seed: u64,
                        outlier_mag: f32) -> (Vec<Mat>, Vec<Mat>) {
    let mut rng = Pcg64::new(seed);
    let acts = sites
        .iter()
        .map(|l| {
            let mut x = Mat::randn(l.m, l.k, 1.0, &mut rng);
            for c in (0..l.k).step_by(97) {
                for r in 0..l.m {
                    if rng.uniform() < 0.3 {
                        x.data[r * l.k + c] =
                            outlier_mag * (1.0 + rng.uniform_f32());
                    }
                }
            }
            x
        })
        .collect();
    let grads = sites
        .iter()
        .map(|l| Mat::randn(l.m, l.n, 1.0, &mut rng))
        .collect();
    (acts, grads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{block_gemm_path, fallback_gemm_path};
    use crate::quant::{block_quant, fallback_quant,
                       quant_work_counters, theta_for_rate};

    fn weight_plan(k: usize, n: usize, block: usize,
                   seed: u64) -> WeightPlan {
        let mut rng = Pcg64::new(seed);
        let w = Mat::randn(k, n, 1.0, &mut rng);
        WeightPlan::new(
            Arc::new(block_quant(&w, block, INT8_LEVELS,
                                 Rounding::Nearest)),
            DataPath::Int8,
        )
        .with_kernels(&kernels::SCALAR)
    }

    fn key(id: u64, k: usize, n: usize, block: usize) -> PlanKey {
        PlanKey {
            weight_id: id,
            k,
            n,
            block,
            path: DataPath::Int8,
            backend: "scalar",
        }
    }

    #[test]
    fn cache_hit_returns_shared_plan() {
        let mut cache = PlanCache::new(4);
        let k1 = key(0, 32, 16, 16);
        let a = cache.get_or_build_with(k1, || {
            weight_plan(32, 16, 16, 1)
        });
        let b = cache.get_or_build_with(k1, || {
            panic!("builder must not run on a hit")
        });
        assert!(Arc::ptr_eq(&a, &b));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(cache.len(), 1);
        assert!(cache.contains(&k1));
    }

    #[test]
    fn cache_evicts_least_recently_used_at_capacity() {
        let mut cache = PlanCache::new(2);
        let ka = key(0, 16, 16, 16);
        let kb = key(1, 16, 32, 16);
        let kc = key(2, 16, 48, 16);
        cache.get_or_build_with(ka, || weight_plan(16, 16, 16, 1));
        cache.get_or_build_with(kb, || weight_plan(16, 32, 16, 2));
        // touch `ka` so `kb` is the LRU victim
        cache.get_or_build_with(ka, || unreachable!());
        cache.get_or_build_with(kc, || weight_plan(16, 48, 16, 3));
        assert_eq!(cache.len(), 2);
        assert!(cache.contains(&ka));
        assert!(!cache.contains(&kb), "LRU entry must be evicted");
        assert!(cache.contains(&kc));
        assert_eq!(cache.stats().evictions, 1);
        // the evicted key rebuilds (miss), within capacity again
        cache.get_or_build_with(kb, || weight_plan(16, 32, 16, 2));
        assert_eq!(cache.stats().evictions, 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn cache_keys_distinguish_id_path_and_backend() {
        // Same weight shape, different key dimensions: all coexist.
        let mut cache = PlanCache::new(8);
        let k_a = key(0, 32, 16, 16);
        // distinct weight_id = a *different tensor* of the same
        // shape — must not be conflated with k_a's entry
        let k_other = PlanKey { weight_id: 7, ..k_a };
        let a = cache.get_or_build_with(k_a, || {
            weight_plan(32, 16, 16, 1)
        });
        let w0 = cache.get_or_build_with(k_other, || {
            weight_plan(32, 16, 16, 99)
        });
        assert!(!Arc::ptr_eq(&w0, &a), "ids must not collide");
        let k_sim = PlanKey { path: DataPath::SimF32, ..k_a };
        cache.get_or_build_with(k_sim, || {
            let mut rng = Pcg64::new(1);
            let w = Mat::randn(32, 16, 1.0, &mut rng);
            WeightPlan::new(
                Arc::new(block_quant(&w, 16, INT8_LEVELS,
                                     Rounding::Nearest)),
                DataPath::SimF32,
            )
            .with_kernels(&kernels::SCALAR)
        });
        assert_eq!(cache.len(), 3);
        // a second backend (when the host has one) is a fourth entry
        if let Some(kn) = kernels::available()
            .into_iter()
            .find(|k| k.name != "scalar")
        {
            let k_kn = PlanKey { backend: kn.name, ..k_a };
            let c = cache.get_or_build_with(k_kn, || {
                let mut rng = Pcg64::new(1);
                let w = Mat::randn(32, 16, 1.0, &mut rng);
                WeightPlan::new(
                    Arc::new(block_quant(&w, 16, INT8_LEVELS,
                                         Rounding::Nearest)),
                    DataPath::Int8,
                )
                .with_kernels(kn)
            });
            assert_eq!(c.kernel_backend(), kn.name);
            assert_eq!(cache.len(), 4);
        }
        assert_eq!(cache.stats().hits, 0);
    }

    #[test]
    fn one_cached_entry_serves_both_precisions() {
        // Precision is deliberately NOT in the key: the same cached
        // weight half serves Int8Block and Fallback GEMMs (only the
        // activation side differs), so a mixed-precision caller pays
        // one quantization + one pack per tensor, not two.
        let mut cache = PlanCache::new(4);
        let k1 = key(0, 32, 16, 16);
        let wp = cache.get_or_build_with(k1, || {
            weight_plan(32, 16, 16, 5)
        });
        let again = cache.get_or_build_with(k1, || {
            panic!("second precision must reuse the entry")
        });
        assert!(Arc::ptr_eq(&wp, &again));
        assert_eq!(cache.len(), 1);
        // both precisions execute off the one shared half, and agree
        // with direct engine plans bitwise
        let mut rng = Pcg64::new(31);
        let a = Mat::randn(24, 32, 1.0, &mut rng);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let fa = fallback_quant(&a, -1.0, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        let c_int8 = wp.plan_int8(&qa, 2).execute();
        let c_fb = wp.plan_fallback(&fa, &fa.u, 2).execute();
        let d_int8 = block_gemm_path(&qa, wp.weight(), 2,
                                     DataPath::Int8);
        let d_fb = fallback_gemm_path(&fa, wp.weight(), &fa.u, 2,
                                      DataPath::Int8);
        assert_eq!(c_int8.data, d_int8.data);
        assert_eq!(c_fb.data, d_fb.data);
    }

    #[test]
    #[should_panic(expected = "shape mismatches cache key")]
    fn cache_rejects_mis_keyed_builder() {
        let mut cache = PlanCache::new(2);
        cache.get_or_build_with(key(0, 32, 16, 16),
                                || weight_plan(16, 16, 16, 1));
    }

    fn small_step(threads: usize) -> LayerStep {
        let mut cfg = LayerStepConfig::new(32, 48, 24, 16);
        cfg.glu = false;
        cfg.threads = threads;
        LayerStep::with_random_weights(cfg, 0xD06)
    }

    #[test]
    fn cache_hit_skips_weight_requantization() {
        // Regression via the thread-local work counters: the second
        // microstep must do only per-call quantization (activation,
        // gradient, Xᵀ — 3 per site) and one panel pack (dY as the
        // dW B-operand); the weight halves (2 quants + 2 packs per
        // site) happen exactly once.
        let mut ls = small_step(2);
        let n_sites = ls.sites().len();
        let (acts, grads) = synth_microbatch(ls.sites(), 5, 150.0);
        let (q0, p0) = quant_work_counters();
        let (_, r1) = ls.microstep(&acts, &grads);
        let (q1, p1) = quant_work_counters();
        assert_eq!(r1.cache_misses as usize, 2 * n_sites);
        assert_eq!(r1.cache_hits, 0);
        assert_eq!((q1 - q0) as usize, 5 * n_sites,
                   "cold microstep: 3 per-call + 2 weight quants/site");
        assert_eq!((p1 - p0) as usize, 3 * n_sites,
                   "cold microstep: W, Wᵀ and dY packs per site");
        let (_, r2) = ls.microstep(&acts, &grads);
        let (q2, p2) = quant_work_counters();
        assert_eq!(r2.cache_misses, 0);
        assert_eq!(r2.cache_hits as usize, 2 * n_sites);
        assert_eq!((q2 - q1) as usize, 3 * n_sites,
                   "warm microstep must not re-quantize weights");
        assert_eq!((p2 - p1) as usize, n_sites,
                   "warm microstep packs only the fresh dY operand");
    }

    #[test]
    fn microstep_matches_direct_engine_calls() {
        let mut ls = small_step(1);
        ls.controller_mut().thresholds.fill(20.0);
        let (acts, grads) = synth_microbatch(ls.sites(), 9, 200.0);
        let (outs, rep) = ls.microstep(&acts, &grads);
        assert_eq!(outs.len(), 4);
        assert!(rep.flops > 0.0);
        let path = ls.config().path;
        for (i, l) in ls.sites().iter().enumerate() {
            let w = &ls.weights[i];
            let fx = fallback_quant(&acts[i], 20.0, 16, INT8_LEVELS,
                                    Criterion::AbsMax);
            let qw =
                block_quant(w, 16, INT8_LEVELS, Rounding::Nearest);
            let y = fallback_gemm_path(&fx, &qw, &fx.u, 1, path);
            assert_eq!(outs[i].y.data, y.data, "fwd {}", l.name);
            let qdy = block_quant(&grads[i], 16, INT8_LEVELS,
                                  Rounding::Nearest);
            let qwt = block_quant(&w.transpose(), 16, INT8_LEVELS,
                                  Rounding::Nearest);
            let dx = block_gemm_path(&qdy, &qwt, 1, path);
            assert_eq!(outs[i].dx.data, dx.data, "dX {}", l.name);
            let qxt = block_quant(&acts[i].transpose(), 16,
                                  INT8_LEVELS, Rounding::Nearest);
            let dw = block_gemm_path(&qxt, &qdy, 1, path);
            assert_eq!(outs[i].dw.data, dw.data, "dW {}", l.name);
            assert_eq!((outs[i].y.rows, outs[i].y.cols), (l.m, l.n));
            assert_eq!((outs[i].dx.rows, outs[i].dx.cols),
                       (l.m, l.k));
            assert_eq!((outs[i].dw.rows, outs[i].dw.cols),
                       (l.k, l.n));
        }
    }

    #[test]
    fn set_weight_invalidates_only_that_sites_plans() {
        // Stale-plan regression: after an optimizer update the next
        // microstep must run against the NEW weights (re-quantized),
        // while untouched sites keep hitting the cache.
        let mut ls = small_step(1);
        ls.controller_mut().thresholds.fill(20.0);
        let (acts, grads) = synth_microbatch(ls.sites(), 21, 150.0);
        ls.microstep(&acts, &grads); // warm the cache (8 misses)
        let mut rng = Pcg64::new(777);
        let (k0, n0) =
            (ls.sites()[0].k, ls.sites()[0].n);
        let new_w = Mat::randn(k0, n0, 0.05, &mut rng);
        ls.set_weight(0, new_w.clone());
        assert_eq!(ls.cache().len(), 6, "site 0's two entries dropped");
        let (outs, rep) = ls.microstep(&acts, &grads);
        assert_eq!(rep.cache_misses, 2, "only site 0 rebuilds");
        assert_eq!(rep.cache_hits, 6);
        // site 0's forward now matches a fresh run on the new weight
        let fx = fallback_quant(&acts[0], 20.0, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        let qw = block_quant(&new_w, 16, INT8_LEVELS,
                             Rounding::Nearest);
        let y = fallback_gemm_path(&fx, &qw, &fx.u, 1,
                                   ls.config().path);
        assert_eq!(outs[0].y.data, y.data,
                   "stale plan served after set_weight");
    }

    #[test]
    fn end_step_feeds_executed_rates_into_controller() {
        let mut ls = small_step(2);
        // θ below every block metric -> full fallback -> rates ≈ 1,
        // far above r_max, so Algorithm 2 must raise every θ.
        ls.controller_mut().thresholds.fill(1e-3);
        let (acts, grads) = synth_microbatch(ls.sites(), 3, 150.0);
        let (_, rep) = ls.microstep(&acts, &grads);
        assert!(rep.sites.iter().all(|s| s.fallback_rate > 0.9));
        let applied = ls.end_step();
        assert_eq!(applied.len(), 4);
        assert!(applied.iter().all(|&r| r > 0.9));
        assert!(ls.controller().thresholds.iter().all(|&t| t > 1e-3));
        assert_eq!(ls.controller().n_up, 4);
        // nothing recorded since -> end_step is a no-op
        let before = ls.controller().thresholds.clone();
        assert!(ls.end_step().is_empty());
        assert_eq!(ls.controller().thresholds, before);
    }

    #[test]
    fn theta_probe_pins_moderate_rates() {
        // Wiring check for the bench's probe pattern: pin each site's
        // θ from an offline metric sweep, then observe the executed
        // rate near the target.
        let mut ls = small_step(2);
        let (acts, grads) = synth_microbatch(ls.sites(), 11, 200.0);
        let thetas: Vec<f32> = acts
            .iter()
            .map(|x| {
                let probe = fallback_quant(x, f32::INFINITY, 16,
                                           INT8_LEVELS,
                                           Criterion::AbsMax);
                theta_for_rate(&probe.metric, 0.25)
            })
            .collect();
        ls.controller_mut().thresholds.copy_from_slice(&thetas);
        let (_, rep) = ls.microstep(&acts, &grads);
        for s in &rep.sites {
            assert!(s.fallback_rate < 0.8,
                    "site {} rate {}", s.name, s.fallback_rate);
        }
    }
}
