//! # Layer-step pipeline: cached GemmPlans across training steps
//!
//! The paper's 1.57x end-to-end speedup comes from running *whole
//! transformer layers* through the fallback GEMM, not one isolated
//! matmul — and the win evaporates if weight quantization and panel
//! packing are redone per call. This module keeps the step-invariant
//! half of every plan alive across microsteps and steps:
//!
//! ```text
//!   step boundary                 microstep (many per step)
//!   ─────────────                 ─────────────────────────
//!   PlanCache                       per site (qkv, attn_out,
//!    key: (weight id, shape,        mlp_gate, mlp_up, mlp_down,
//!         data path, backend)       lm_head):
//!    value: WeightPlan                quantize X (fallback, θ_site)
//!    value: WeightPlan               quantize dY (int8, stochastic
//!     = q(W) + packed panels   ──►     rounding — unbiased grads)
//!       + pinned backend            fwd  Y  = X·W    (cached W)
//!    built on miss, owned           bwd  dX = dY·Wᵀ  (cached Wᵀ)
//!    across steps, LRU-evicted      bwd  dW = Xᵀ·dY  (fresh plan;
//!                                       Xᵀ = permuted forward quant)
//!                                   record executed fallback rates
//!   RateAccumulator ──────────►   ThresholdController (Alg 2) at
//!    per-site means               the step boundary: θ adapts from
//!                                 real execution
//! ```
//!
//! Two gradient-path rules this module pins down (both were bugs
//! once, both are regression-tested):
//!
//! * **dY is stochastically rounded.** Nearest rounding makes the
//!   quantization error of every gradient element point the same way
//!   on every microstep — a *bias* that accumulates across an
//!   optimizer step ("Training Transformers with 4-bit Integers"
//!   makes unbiasedness the core correctness lever). The pipeline
//!   draws from the per-block SR streams of `quant::block`
//!   (thread-count-invariant) with a seed derived deterministically
//!   from ([`LayerStepConfig::sr_seed`], microstep, site) via
//!   [`grad_sr_seed`], so runs stay reproducible bit-for-bit.
//! * **dW keeps X's outlier handling.** `dW = Xᵀ·dY` consumes the
//!   same outlier-bearing activation as the forward; quantizing Xᵀ
//!   with plain nearest INT8 silently drops the per-block fallback
//!   exactly where the paper (and Jetfire) say it matters. Xᵀ rides
//!   the fallback path at the site's θ — and because AbsMax is
//!   symmetric under block transposition, its quantization is
//!   obtained by *permuting* the forward's
//!   ([`FallbackQuant::transposed`](crate::quant::FallbackQuant::transposed)),
//!   bit-identical to re-running
//!   Algorithm 1 on xᵀ at zero quantization cost. The executed
//!   backward rate is reported per site
//!   ([`SiteReport::bwd_fallback_rate`]).
//!
//! [`ModelStep`] scales the same loop from one layer to a whole
//! N-layer model + LM head sharing **one** `PlanCache`, and adds
//! warm-state persistence (calibration + cache-warming metadata as
//! JSON) so a fresh process starts at steady-state hit rate — see
//! its docs.
//!
//! What is packed **once** (cache hit = zero quantization/packing
//! work): the weight codes, their column panels for the plan's
//! [`DataPath`], and the transposed-weight twin for `dX`. What is
//! rebuilt **per call**: the activation fallback quant (whose
//! permutation also serves as dW's Xᵀ operand — two quantization
//! passes per site per microstep, not three), the gradient quant,
//! and the `dW` plan whose operands both change every microstep.
//! `quant::quant_work_counters` makes the split observable
//! — the cache-hit regression tests and `benches/layer_step.rs` lean
//! on it.
//!
//! Bit-identity is non-negotiable: a cached plan must produce
//! byte-identical C to a freshly built one, on every kernel backend
//! and thread count — `tests/pipeline_prop.rs` sweeps exactly that.
//! See `docs/ARCHITECTURE.md` for how this layer sits on the
//! plan/execute engine.

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::{RateAccumulator, ThresholdController};
use crate::costmodel::SubstrateCalibration;
use crate::gemm::engine::{env_path, DataPath, GemmPlan, WeightPlan};
use crate::gemm::kernels::{self, Kernels};
use crate::model::{layer_linears, model_linears, sites_per_layer,
                   LinearShape};
use crate::quant::{block_quant_threads, fallback_quant_threads,
                   staged_quant_threads, Criterion, FallbackQuant,
                   Rounding, StagedQuant, INT4_LEVELS, INT8_LEVELS};
use crate::util::json::{obj, Json};
use crate::util::pool::default_shards;
use crate::util::rng::{Pcg64, SplitMix64};
use crate::util::threadpool::default_threads;
use crate::util::Mat;

/// Default base seed of the gradient stochastic-rounding streams
/// (override via [`LayerStepConfig::sr_seed`] /
/// [`ModelStepConfig::sr_seed`]).
pub const GRAD_SR_SEED: u64 = 0xD1A5_0C57_0CA5_71C0;

/// Deterministic SR seed for one gradient quantization: mixes the
/// driver's base seed with the microstep index and the site index, so
/// every (microstep, site) draws from an independent stream — fresh
/// randomness each microstep (the unbiasedness argument needs
/// independent draws) while staying bit-reproducible and, via the
/// per-block streams underneath, thread-count-invariant.
pub fn grad_sr_seed(base: u64, microstep: usize, site: usize) -> u64 {
    let mut sm = SplitMix64(
        base ^ (microstep as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (site as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9),
    );
    sm.next()
}

/// Per-layer SR stream base of a [`ModelStep`]: layer `layer` of a
/// model seeded `base` quantizes its gradients exactly like a
/// standalone [`LayerStep`] whose `sr_seed` is this value (layer
/// index `layers` — one past the last — is the LM head's stream).
/// The ModelStep-vs-composed-LayerSteps bit-identity tests lean on
/// this being a public, stable derivation.
pub fn layer_sr_seed(base: u64, layer: usize) -> u64 {
    let mut sm = SplitMix64(
        base ^ (layer as u64).wrapping_mul(0x94D0_49BB_1331_11EB),
    );
    sm.next()
}

/// Cache key of one weight half: the caller-assigned identity of the
/// weight *tensor*, its GEMM role (inner dim `k` × output features
/// `n`, quantization block), the data path the panels were packed
/// for, and the pinned microkernel backend.
///
/// `weight_id` is what keeps the cache content-correct: shapes alone
/// cannot distinguish two different weight matrices (a square layer
/// makes attn_out/mlp sites shape-identical), so the caller must
/// assign distinct ids to distinct tensors — `LayerStep` uses
/// `2·site + transposed`. The remaining fields exist because one
/// tensor can legitimately be cached several ways (per path and
/// backend) and those variants must not collide.
///
/// GEMM *precision* is deliberately not part of the key: a
/// [`WeightPlan`] is precision-agnostic (the same cached half serves
/// `plan_int8` and `plan_fallback` calls — only the activation side
/// differs), so keying on it would store byte-identical panels twice
/// per tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PlanKey {
    /// caller-assigned identity of the weight tensor (distinct
    /// tensors MUST get distinct ids, or lookups conflate them)
    pub weight_id: u64,
    /// weight rows = GEMM inner dim
    pub k: usize,
    /// weight cols = output features
    pub n: usize,
    /// quantization block size
    pub block: usize,
    /// data path the cached panels were packed for
    pub path: DataPath,
    /// microkernel backend name pinned at build
    pub backend: &'static str,
    /// shard count derived plans are built with (`PALLAS_SHARDS`):
    /// sharding is bit-neutral, but the per-shard LPT schedules and
    /// worker-affinity bases differ, so plans cached under one shard
    /// config must not serve another
    pub shards: usize,
}

/// Lifetime counters of a [`PlanCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

impl CacheStats {
    /// Hits over lookups (0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.hits + self.misses;
        if lookups == 0 {
            return 0.0;
        }
        self.hits as f64 / lookups as f64
    }

    /// Counter deltas since an earlier snapshot — windowed
    /// statistics. Lifetime counters make
    /// [`thrashing`](CacheStats::thrashing) blind to thrash that
    /// begins *after* a long healthy phase (the accumulated hit rate
    /// stays high long after every new lookup starts missing), so
    /// monitors of dynamic pressure should snapshot `stats()`
    /// periodically and evaluate `stats().since(&snapshot)`.
    pub fn since(&self, start: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - start.hits,
            misses: self.misses - start.misses,
            insertions: self.insertions - start.insertions,
            evictions: self.evictions - start.evictions,
        }
    }

    /// Thrash detector: the cache is evicting about as fast as it
    /// inserts while hits stay rare — the signature of a working set
    /// larger than capacity, where every lookup misses, rebuilds the
    /// plan (full weight re-quantization + packing), and evicts an
    /// entry that will be needed again momentarily. This state is
    /// *silent* otherwise — results stay correct, only all the
    /// caching work is wasted — which is why [`LayerStep`] and
    /// [`ModelStep`] additionally validate capacity against their
    /// working set at construction. Evaluates the counters it is
    /// called on: apply to [`since`](CacheStats::since) deltas to
    /// detect thrash that starts after a warm phase.
    pub fn thrashing(&self) -> bool {
        self.misses > 0
            && 2 * self.evictions >= self.insertions
            && self.hit_rate() < 0.5
    }
}

/// LRU cache of [`WeightPlan`]s keyed by [`PlanKey`] — owns the
/// packed weight panels across training steps so a microstep's plan
/// build does no weight quantization or packing on a hit.
#[derive(Debug)]
pub struct PlanCache {
    cap: usize,
    tick: u64,
    map: HashMap<PlanKey, (Arc<WeightPlan>, u64)>,
    stats: CacheStats,
}

impl PlanCache {
    /// `capacity` ≥ 1 entries; least-recently-used entries are
    /// evicted when a miss would exceed it.
    pub fn new(capacity: usize) -> PlanCache {
        assert!(capacity >= 1, "cache capacity must be >= 1");
        PlanCache {
            cap: capacity,
            tick: 0,
            map: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Whether `key` is resident (does not touch LRU order or stats).
    pub fn contains(&self, key: &PlanKey) -> bool {
        self.map.contains_key(key)
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Resident keys, sorted — the cache-warming metadata of a
    /// warm-state file ([`ModelStep::warm_state`]).
    pub fn keys(&self) -> Vec<PlanKey> {
        let mut v: Vec<PlanKey> = self.map.keys().copied().collect();
        v.sort();
        v
    }

    /// Peek at a resident entry without touching LRU order or stats
    /// (introspection: resident-bytes accounting, tests).
    pub fn peek(&self, key: &PlanKey) -> Option<Arc<WeightPlan>> {
        self.map.get(key).map(|(wp, _)| wp.clone())
    }

    /// Drop every cached entry (stats survive; not counted as
    /// evictions — this is the bench's "uncached" mode, not pressure).
    pub fn clear(&mut self) {
        self.map.clear();
    }

    /// Drop every entry caching the given weight tensor (all roles,
    /// precisions, paths, backends), returning how many were
    /// dropped. Callers MUST invalidate (or switch to a fresh id)
    /// after mutating a weight in place: the cache keys on identity,
    /// not tensor values, so a stale plan would otherwise keep being
    /// served — bit-exact against the *old* weights, with no error.
    /// `LayerStep::set_weight` wires this up for the optimizer-update
    /// path.
    pub fn invalidate_weight(&mut self, weight_id: u64) -> usize {
        let before = self.map.len();
        self.map.retain(|k, _| k.weight_id != weight_id);
        before - self.map.len()
    }

    /// Return the cached weight half for `key`, building (and
    /// inserting) it with `build` on a miss. The built plan is
    /// checked against the key's shape/block/path/backend — asserted
    /// at insert, so a builder mismatching those fields cannot poison
    /// later lookups. (`weight_id` has no witness on the plan and
    /// cannot be checked: keying the *right tensor* under the right
    /// id is the caller's contract — see [`PlanKey`].)
    pub fn get_or_build_with(
        &mut self, key: PlanKey,
        build: impl FnOnce() -> WeightPlan,
    ) -> Arc<WeightPlan> {
        self.tick += 1;
        if let Some((wp, last)) = self.map.get_mut(&key) {
            *last = self.tick;
            self.stats.hits += 1;
            return wp.clone();
        }
        self.stats.misses += 1;
        let wp = Arc::new(build());
        assert_eq!(wp.dims(), (key.k, key.n),
                   "built weight plan shape mismatches cache key");
        assert_eq!(wp.weight().block, key.block, "block size vs key");
        assert_eq!(wp.data_path(), key.path, "data path vs key");
        assert_eq!(wp.kernel_backend(), key.backend, "backend vs key");
        assert_eq!(wp.shard_count(), key.shards, "shard count vs key");
        if self.map.len() >= self.cap {
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(k, _)| *k);
            if let Some(victim) = victim {
                self.map.remove(&victim);
                self.stats.evictions += 1;
            }
        }
        self.map.insert(key, (wp.clone(), self.tick));
        self.stats.insertions += 1;
        wp
    }
}

/// Configuration of a [`LayerStep`] driver.
#[derive(Debug, Clone)]
pub struct LayerStepConfig {
    pub d_model: usize,
    pub d_ff: usize,
    /// GLU MLP: splits the MLP input projection into the `mlp_gate`
    /// and `mlp_up` sites (see
    /// [`sites_per_layer`](crate::model::sites_per_layer))
    pub glu: bool,
    /// tokens per microstep (rows of every activation)
    pub tokens: usize,
    /// quantization block size
    pub block: usize,
    pub threads: usize,
    /// data path all plans run (the `PALLAS_PATH` override when set,
    /// else [`DataPath::auto_for`])
    pub path: DataPath,
    /// opt-in outlier telemetry: when set, every microstep attaches
    /// the per-block AbsMax histogram of each site's forward
    /// activation to its [`SiteReport`] ([`metric_histogram`])
    pub telemetry: bool,
    /// plan-cache capacity (a layer needs 2 weight halves ×
    /// [`sites_per_layer`] entries — 8 plain, 10 under `glu`; the
    /// default leaves headroom for shape churn).
    /// Validated at construction: below the working set the cache
    /// would silently thrash every microstep.
    pub cache_capacity: usize,
    /// base seed of the gradient stochastic-rounding streams (see
    /// [`grad_sr_seed`]); two drivers with equal seeds, weights, and
    /// inputs produce bit-identical gradients
    pub sr_seed: u64,
    /// shard count every plan is built with (default: the
    /// `PALLAS_SHARDS` knob) — bit-neutral, see
    /// [`GemmPlan::with_shards`]
    pub shards: usize,
}

impl LayerStepConfig {
    pub fn new(d_model: usize, d_ff: usize, tokens: usize,
               block: usize) -> LayerStepConfig {
        LayerStepConfig {
            d_model,
            d_ff,
            glu: true,
            tokens,
            block,
            threads: default_threads(),
            path: env_path()
                .unwrap_or_else(|| DataPath::auto_for(block)),
            telemetry: false,
            cache_capacity: 16,
            sr_seed: GRAD_SR_SEED,
            shards: default_shards(),
        }
    }
}

/// The three GEMM outputs of one linear site for one microstep.
#[derive(Debug, Clone)]
pub struct SiteOutputs {
    /// forward `Y = X·W` (tokens × n)
    pub y: Mat,
    /// input gradient `dX = dY·Wᵀ` (tokens × k)
    pub dx: Mat,
    /// weight gradient `dW = Xᵀ·dY` (k × n)
    pub dw: Mat,
}

impl SiteOutputs {
    /// Empty (capacity-less) output slot; the engine's
    /// `execute_into` grows each matrix on first use and reuses the
    /// buffers on every microstep after (the drivers' site arena).
    pub fn empty() -> SiteOutputs {
        SiteOutputs {
            y: Mat::zeros(0, 0),
            dx: Mat::zeros(0, 0),
            dw: Mat::zeros(0, 0),
        }
    }
}

/// Per-site record of one microstep.
#[derive(Debug, Clone)]
pub struct SiteReport {
    pub name: &'static str,
    /// fallback rate the forward GEMM actually executed with: blocks
    /// promoted past the path's base precision (two-level blocks on
    /// the binary Int8 fallback, tier ≥ I8 on the staged Int4 ladder)
    pub fallback_rate: f64,
    /// fallback rate the backward `dW` GEMM executed with (Xᵀ on the
    /// fallback path at the same θ — block decisions are the
    /// transpose of the forward's)
    pub bwd_fallback_rate: f64,
    /// f32-tier rate of the forward GEMM (staged Int4 ladder only;
    /// always 0 on the binary-fallback paths, which have no third
    /// rung)
    pub fallback_rate_f32: f64,
    /// f32-tier rate of the backward `dW` GEMM
    pub bwd_fallback_rate_f32: f64,
    /// per-block AbsMax histogram of the forward activation
    /// ([`metric_histogram`]) — present when the driver's `telemetry`
    /// config flag is on, `None` otherwise (zero cost when off)
    pub outlier_hist: Option<Vec<u64>>,
    /// weight-plan cache lookups this site hit / missed (2 lookups
    /// per site per microstep: W and Wᵀ) — lets multi-layer drivers
    /// report per-layer hit rates
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// useful FLOPs of the site's three GEMMs
    pub flops: f64,
}

/// One microstep's accounting across all sites.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub sites: Vec<SiteReport>,
    /// weight-plan cache lookups that hit during this microstep
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// useful FLOPs of the whole microstep (CAL-FLOPS numerator)
    pub flops: f64,
}

/// Quantization levels of the per-call (activation/gradient) and
/// weight grids on `path`: nibble codes on the Int4 rung — the
/// weight panels are nibble-packed and the gradient operand streams
/// through the `dot*_i4` kernels — i8 codes everywhere else.
fn levels_for(path: DataPath) -> f32 {
    match path {
        DataPath::Int4 => INT4_LEVELS,
        _ => INT8_LEVELS,
    }
}

/// Bin count of the outlier-telemetry histograms: power-of-two
/// magnitude buckets, bin `i` counting blocks whose AbsMax has
/// `floor(log2) = i − 8` (so bin 0 collects everything at or below
/// 2⁻⁸ and bin 15 everything at or above 2⁷).
pub const OUTLIER_HIST_BINS: usize = 16;

/// Histogram of per-block AbsMax magnitudes over
/// [`OUTLIER_HIST_BINS`] fixed power-of-two bins — the opt-in
/// outlier telemetry every site attaches to its [`SiteReport`] when
/// the driver's `telemetry` flag is set. Binning reads the f32
/// exponent field directly (no float `log`), so the histogram is
/// bit-deterministic across platforms and libm versions.
pub fn metric_histogram(metric: &[f32]) -> Vec<u64> {
    let mut h = vec![0u64; OUTLIER_HIST_BINS];
    for &m in metric {
        let e = if m > 0.0 {
            ((m.to_bits() >> 23) & 0xff) as i32 - 127
        } else {
            i32::MIN // all-zero blocks land in the bottom bin
        };
        let bin = e.saturating_add(8)
            .clamp(0, OUTLIER_HIST_BINS as i32 - 1);
        h[bin as usize] += 1;
    }
    h
}

/// The forward's activation quantization on whichever lattice rung
/// the plan runs: Algorithm 1's two-level quant (SimF32/Int8 paths)
/// or the staged Int4→Int8→f32 ladder (Int4 path). The backward
/// consumes it twice — its permutation is dW's Xᵀ operand — so the
/// variants share one lifecycle.
enum ActQuant {
    Fallback(FallbackQuant),
    Staged(StagedQuant),
}

impl ActQuant {
    /// Executed fallback rate the Algorithm-2 controller sees: the
    /// fraction of blocks promoted past the path's base precision
    /// (two-level blocks on the binary fallback, tier ≥ I8 on the
    /// staged ladder — same band semantics either way).
    fn fallback_rate(&self) -> f64 {
        match self {
            ActQuant::Fallback(f) => f.fallback_rate(),
            ActQuant::Staged(s) => s.rate_i8(),
        }
    }

    /// Fraction of blocks promoted to the f32 tier (0 off the staged
    /// ladder — the binary fallback has no third rung).
    fn f32_rate(&self) -> f64 {
        match self {
            ActQuant::Fallback(_) => 0.0,
            ActQuant::Staged(s) => s.rate_f32(),
        }
    }

    /// Per-block AbsMax selection metric — the outlier-telemetry
    /// histogram source.
    fn metric(&self) -> &[f32] {
        match self {
            ActQuant::Fallback(f) => &f.metric,
            ActQuant::Staged(s) => &s.metric,
        }
    }
}

/// Build the cacheable weight half of one site: quantize the master
/// weight (or its transpose, for the `dX` role) with nearest rounding
/// at the path's levels ([`levels_for`] — nibble codes on Int4) and
/// eagerly pack its column panels for `path`. Shared by the
/// microstep miss path and the warm-state prewarm so both produce
/// byte-identical plans.
fn build_weight_plan(w: &Mat, transposed: bool, block: usize,
                     threads: usize, path: DataPath,
                     kn: &'static Kernels, shards: usize)
                     -> WeightPlan {
    let levels = levels_for(path);
    let q = if transposed {
        block_quant_threads(&w.transpose(), block, levels,
                            Rounding::Nearest, threads)
    } else {
        block_quant_threads(w, block, levels, Rounding::Nearest,
                            threads)
    };
    WeightPlan::new(Arc::new(q), path)
        .with_kernels(kn)
        .with_shards(shards)
}

/// Forward half of one site's microstep: quantize the activation at
/// the site's θ — the binary fallback quant on the SimF32/Int8 paths,
/// the staged Int4→Int8→f32 ladder on Int4 (both nearest-rounded;
/// the forward has no bias accumulation hazard) — look up or build
/// the cached W half, and execute `Y = X·W` into the caller's slot.
/// Returns the activation quantization — the backward half consumes
/// it twice (its permutation is dW's Xᵀ operand).
///
/// `id_base` is `2 · global site index`: the cache keys of this
/// site's W and Wᵀ halves are `id_base` and `id_base + 1`.
#[allow(clippy::too_many_arguments)]
fn run_site_forward(
    l: &LinearShape, w: &Mat, x: &Mat, theta: f32, id_base: u64,
    block: usize, threads: usize, path: DataPath,
    kn: &'static Kernels, shards: usize, cache: &mut PlanCache,
    out: &mut SiteOutputs,
) -> ActQuant {
    assert_eq!((x.rows, x.cols), (l.m, l.k),
               "activation shape for site {}", l.name);
    let wp = cache.get_or_build_with(
        PlanKey {
            weight_id: id_base,
            k: l.k,
            n: l.n,
            block,
            path,
            backend: kn.name,
            shards,
        },
        || build_weight_plan(w, false, block, threads, path, kn,
                             shards),
    );
    match path {
        DataPath::Int4 => {
            let sx = staged_quant_threads(x, theta, block, threads);
            wp.plan_staged(&sx, threads).execute_into(&mut out.y);
            ActQuant::Staged(sx)
        }
        _ => {
            let fx = fallback_quant_threads(x, theta, block,
                                            INT8_LEVELS,
                                            Criterion::AbsMax,
                                            threads);
            wp.plan_fallback(&fx, &fx.u, threads)
                .execute_into(&mut out.y);
            ActQuant::Fallback(fx)
        }
    }
}

/// Backward half of one site's microstep: quantize dY at the path's
/// levels with stochastic rounding (nearest would bias every element
/// of dW and dX the same way each microstep), execute `dX = dY·Wᵀ`
/// through the cached Wᵀ half, and `dW = Xᵀ·dY` through a
/// legitimately fresh plan (both operands change every microstep;
/// qdy serves as the A operand of dX and the B operand of dW — one
/// quantization, two roles). Xᵀ's quantization is the *permutation*
/// of the forward's: under AbsMax every per-block quantity (absmax,
/// scales, nearest codes, the tier decisions at θ) is symmetric
/// under transposition, so `transposed()` is bit-identical to
/// re-running Algorithm 1 — or the staged ladder — on xᵀ. The
/// outlier blocks the forward protected stay protected in the weight
/// gradient, at zero extra quantization cost
/// (`dw_routes_transposed_activation_through_fallback` pins the
/// identity against a fresh re-quantization). Returns the executed
/// backward (fallback, f32-tier) rates.
#[allow(clippy::too_many_arguments)]
fn run_site_backward(
    l: &LinearShape, w: &Mat, fx: &ActQuant, dy: &Mat,
    sr: Rounding, id_base: u64, block: usize, threads: usize,
    path: DataPath, kn: &'static Kernels, shards: usize,
    cache: &mut PlanCache, out: &mut SiteOutputs,
) -> (f64, f64) {
    assert_eq!((dy.rows, dy.cols), (l.m, l.n),
               "gradient shape for site {}", l.name);
    let qdy = block_quant_threads(dy, block, levels_for(path), sr,
                                  threads);
    let wpt = cache.get_or_build_with(
        PlanKey {
            weight_id: id_base + 1,
            k: l.n,
            n: l.k,
            block,
            path,
            backend: kn.name,
            shards,
        },
        || build_weight_plan(w, true, block, threads, path, kn,
                             shards),
    );
    wpt.plan_int8(&qdy, threads).execute_into(&mut out.dx);
    match fx {
        ActQuant::Fallback(f) => {
            let fxt = f.transposed();
            GemmPlan::new_fallback_path(&fxt, &qdy, &fxt.u, threads,
                                        path)
                .with_kernels(kn)
                .with_shards(shards)
                .execute_into(&mut out.dw);
            (fxt.fallback_rate(), 0.0)
        }
        ActQuant::Staged(s) => {
            let sxt = s.transposed();
            GemmPlan::new_staged(&sxt, &qdy, threads)
                .with_kernels(kn)
                .with_shards(shards)
                .execute_into(&mut out.dw);
            (sxt.rate_i8(), sxt.rate_f32())
        }
    }
}

/// One site's three GEMMs for one microstep — the shared core of
/// [`LayerStep::microstep`] and [`ModelStep::microstep`] (factored
/// out so multi-layer drivers are bit-identical to composed
/// single-layer ones by construction), now itself the composition of
/// [`run_site_forward`] and [`run_site_backward`] so the sequential
/// split API ([`ModelStep::forward_site`] /
/// [`ModelStep::backward_site`]) is bit-identical to the batch
/// microstep by the same argument. Writes the outputs into the
/// caller's reusable `out` slot (warm buffers are reused in place —
/// the engine's `execute_into` steady state) and returns the
/// executed forward and backward fallback rates.
/// The per-tier rates (and optional telemetry histogram) one site
/// executed with during one microstep.
struct SiteRates {
    fwd: f64,
    fwd_f32: f64,
    bwd: f64,
    bwd_f32: f64,
    hist: Option<Vec<u64>>,
}

#[allow(clippy::too_many_arguments)]
fn run_site(
    l: &LinearShape, w: &Mat, x: &Mat, dy: &Mat, theta: f32,
    sr: Rounding, id_base: u64, block: usize, threads: usize,
    path: DataPath, kn: &'static Kernels, shards: usize,
    telemetry: bool, cache: &mut PlanCache, out: &mut SiteOutputs,
) -> SiteRates {
    let fx = run_site_forward(l, w, x, theta, id_base, block, threads,
                              path, kn, shards, cache, out);
    let hist = telemetry.then(|| metric_histogram(fx.metric()));
    let (bwd, bwd_f32) = run_site_backward(
        l, w, &fx, dy, sr, id_base, block, threads, path, kn, shards,
        cache, out);
    SiteRates {
        fwd: fx.fallback_rate(),
        fwd_f32: fx.f32_rate(),
        bwd,
        bwd_f32,
        hist,
    }
}

/// Cache-free reference computation of one site's three GEMMs —
/// exactly [`LayerStep`]/[`ModelStep`]'s per-site math (it runs the
/// same private site runner against a throwaway cache). The
/// composition checks in `tests/model_step_prop.rs` and
/// `benches/model_step.rs` use it as the LM-head reference when
/// comparing a [`ModelStep`] against composed per-layer drivers; the
/// *independence* of the underlying math is pinned elsewhere (the
/// direct-engine and exact-i64-oracle tests), so sharing one body
/// here is deduplication, not circular testing.
#[allow(clippy::too_many_arguments)]
pub fn site_reference(
    l: &LinearShape, w: &Mat, x: &Mat, dy: &Mat, theta: f32,
    sr: Rounding, block: usize, threads: usize, path: DataPath,
    kn: &'static Kernels,
) -> SiteOutputs {
    let mut cache = PlanCache::new(2);
    let mut out = SiteOutputs::empty();
    run_site(l, w, x, dy, theta, sr, 0, block, threads, path, kn,
             default_shards(), false, &mut cache, &mut out);
    out
}

/// Shared microstep core of [`LayerStep`] and [`ModelStep`]: run
/// every site through [`run_site`] with its θ and gradient rounding
/// (`weight_id = 2·site + transposed`, so shape-identical sites can
/// never serve each other's weights), assemble the per-site and
/// per-microstep accounting, and record the executed forward rates
/// into the accumulator. One body for both drivers is what makes
/// "ModelStep ≡ composed LayerSteps" hold by construction — only the
/// per-site `Rounding` derivation differs between the callers.
///
/// `arena` is the driver's site-keyed output store: slot `i` holds
/// site `i`'s three output matrices and is rewritten in place each
/// microstep, so warm buffers are reused instead of reallocated.
#[allow(clippy::too_many_arguments)]
fn drive_microstep(
    sites: &[LinearShape], weights: &[Mat], thresholds: &[f32],
    rounds: &[Rounding], acts: &[Mat], grads: &[Mat], block: usize,
    threads: usize, path: DataPath, kn: &'static Kernels,
    shards: usize, telemetry: bool, cache: &mut PlanCache,
    rates: &mut RateAccumulator, arena: &mut Vec<SiteOutputs>,
) -> StepReport {
    assert_eq!(acts.len(), sites.len(), "one act per site");
    assert_eq!(grads.len(), sites.len(), "one grad per site");
    arena.truncate(sites.len());
    while arena.len() < sites.len() {
        arena.push(SiteOutputs::empty());
    }
    let start = cache.stats();
    let mut site_reports = Vec::with_capacity(sites.len());
    let mut executed = vec![0.0f64; sites.len()];
    for (i, l) in sites.iter().enumerate() {
        let s0 = cache.stats();
        let r = run_site(
            l, &weights[i], &acts[i], &grads[i], thresholds[i],
            rounds[i], 2 * i as u64, block, threads, path, kn, shards,
            telemetry, cache, &mut arena[i],
        );
        let s1 = cache.stats();
        executed[i] = r.fwd;
        site_reports.push(SiteReport {
            name: l.name,
            fallback_rate: r.fwd,
            bwd_fallback_rate: r.bwd,
            fallback_rate_f32: r.fwd_f32,
            bwd_fallback_rate_f32: r.bwd_f32,
            outlier_hist: r.hist,
            cache_hits: s1.hits - s0.hits,
            cache_misses: s1.misses - s0.misses,
            flops: l.microstep_flops(),
        });
    }
    rates.record(&executed);
    let end = cache.stats();
    let flops = site_reports.iter().map(|s| s.flops).sum();
    StepReport {
        sites: site_reports,
        cache_hits: end.hits - start.hits,
        cache_misses: end.misses - start.misses,
        flops,
    }
}

/// Drives the four linear sites of one transformer layer
/// ([`layer_linears`]) through the fallback GEMM engine — forward
/// plus both backward GEMMs per site, per the CAL-FLOPS accounting —
/// re-quantizing only the activation/gradient side per microstep and
/// reusing cached [`WeightPlan`]s for everything weight-shaped.
///
/// Fallback thresholds are per-site and owned by an embedded
/// [`ThresholdController`]; each microstep records the rates the
/// forward GEMMs actually ran with, and
/// [`end_step`](LayerStep::end_step) folds their means back into the
/// controller (Algorithm 2's between-step adjustment).
pub struct LayerStep {
    cfg: LayerStepConfig,
    sites: Vec<LinearShape>,
    /// master weights, one (k × n) matrix per site
    weights: Vec<Mat>,
    cache: PlanCache,
    controller: ThresholdController,
    rates: RateAccumulator,
    kernels: &'static Kernels,
    microsteps: usize,
    /// site-keyed output arena, reused across microsteps (see
    /// [`microstep_in_place`](LayerStep::microstep_in_place))
    arena: Vec<SiteOutputs>,
}

impl LayerStep {
    /// `weights[i]` must be the (k × n) matrix of site `i` in
    /// [`layer_linears`] order (qkv, attn_out, then mlp_in/mlp_down
    /// plain or mlp_gate/mlp_up/mlp_down under `glu`).
    ///
    /// Panics when `cfg.cache_capacity` is below the layer's working
    /// set of `2 × sites` weight halves: an undersized cache would
    /// not fail — it would silently thrash, re-quantizing and
    /// repacking every weight every microstep with a 0% hit rate
    /// (see [`CacheStats::thrashing`]).
    pub fn new(cfg: LayerStepConfig, weights: Vec<Mat>) -> LayerStep {
        let sites =
            layer_linears(cfg.d_model, cfg.d_ff, cfg.glu, cfg.tokens);
        let working_set = 2 * sites.len();
        assert!(
            cfg.cache_capacity >= working_set,
            "plan-cache capacity {} is below the layer's working set \
             of {working_set} (2 weight halves x {} sites): every \
             microstep would silently thrash",
            cfg.cache_capacity,
            sites.len()
        );
        assert_eq!(weights.len(), sites.len(), "one weight per site");
        for (w, l) in weights.iter().zip(&sites) {
            assert_eq!((w.rows, w.cols), (l.k, l.n),
                       "weight shape for site {}", l.name);
        }
        let controller =
            ThresholdController::paper_default(sites.len());
        let rates = RateAccumulator::new(sites.len());
        let cache = PlanCache::new(cfg.cache_capacity);
        LayerStep {
            sites,
            weights,
            cache,
            controller,
            rates,
            kernels: kernels::select(),
            microsteps: 0,
            arena: Vec::new(),
            cfg,
        }
    }

    /// Synthetic Gaussian weights (benches, tests).
    pub fn with_random_weights(cfg: LayerStepConfig,
                               seed: u64) -> LayerStep {
        let sites =
            layer_linears(cfg.d_model, cfg.d_ff, cfg.glu, cfg.tokens);
        let mut rng = Pcg64::new(seed);
        let weights = sites
            .iter()
            .map(|l| Mat::randn(l.k, l.n, 0.05, &mut rng))
            .collect();
        LayerStep::new(cfg, weights)
    }

    pub fn sites(&self) -> &[LinearShape] {
        &self.sites
    }

    pub fn config(&self) -> &LayerStepConfig {
        &self.cfg
    }

    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    /// Drop every cached weight plan — each site's next microstep
    /// re-quantizes and repacks both weight halves (the bench's
    /// uncached baseline).
    pub fn clear_cache(&mut self) {
        self.cache.clear();
    }

    pub fn controller(&self) -> &ThresholdController {
        &self.controller
    }

    /// Mutable controller access (pin θ for ablations/benches).
    pub fn controller_mut(&mut self) -> &mut ThresholdController {
        &mut self.controller
    }

    /// Replace site `site`'s master weight (the optimizer-update
    /// path) and invalidate its cached halves — the next microstep
    /// re-quantizes and repacks exactly this site's W and Wᵀ, while
    /// every other site keeps hitting.
    pub fn set_weight(&mut self, site: usize, w: Mat) {
        let l = &self.sites[site];
        assert_eq!((w.rows, w.cols), (l.k, l.n),
                   "weight shape for site {}", l.name);
        self.weights[site] = w;
        self.cache.invalidate_weight(2 * site as u64);
        self.cache.invalidate_weight(2 * site as u64 + 1);
    }

    /// Microsteps run since construction.
    pub fn microsteps(&self) -> usize {
        self.microsteps
    }

    /// Backend every plan of this driver is pinned to.
    pub fn kernel_backend(&self) -> &'static str {
        self.kernels.name
    }

    /// Pin every plan this driver builds to an explicit microkernel
    /// backend (tests, per-backend benches). Call before the first
    /// microstep: cached entries are keyed by backend, so re-pinning
    /// later makes every site miss once and rebuild.
    pub fn with_kernels(mut self, k: &'static Kernels) -> LayerStep {
        self.kernels = k;
        self
    }

    /// Run one microstep: for every site, quantize the activation
    /// (fallback, at the site's current θ) and the output gradient
    /// (int8 with per-block stochastic rounding — §5.1: dY is not
    /// fallback-quantized, and nearest rounding would bias it), then
    /// run fwd / dX / dW through the engine (`dW`'s Xᵀ operand rides
    /// the fallback path at the same θ). Weight halves come from the
    /// plan cache; `acts[i]` is (tokens × k), `grads[i]` is
    /// (tokens × n) per site `i`.
    pub fn microstep(&mut self, acts: &[Mat],
                     grads: &[Mat]) -> (Vec<SiteOutputs>, StepReport) {
        let report = self.microstep_in_place(acts, grads);
        (std::mem::take(&mut self.arena), report)
    }

    /// [`microstep`](LayerStep::microstep) without handing the
    /// outputs over: results land in the driver's site-keyed arena
    /// (read via [`outputs`](LayerStep::outputs)) and their buffers
    /// are reused on the next call — the zero-allocation steady-state
    /// path.
    pub fn microstep_in_place(&mut self, acts: &[Mat],
                              grads: &[Mat]) -> StepReport {
        let rounds: Vec<Rounding> = (0..self.sites.len())
            .map(|i| Rounding::Stochastic(grad_sr_seed(
                self.cfg.sr_seed, self.microsteps, i)))
            .collect();
        let report = drive_microstep(
            &self.sites, &self.weights, &self.controller.thresholds,
            &rounds, acts, grads, self.cfg.block, self.cfg.threads,
            self.cfg.path, self.kernels, self.cfg.shards,
            self.cfg.telemetry, &mut self.cache, &mut self.rates,
            &mut self.arena,
        );
        self.microsteps += 1;
        report
    }

    /// The last microstep's per-site outputs (empty before the first
    /// [`microstep_in_place`](LayerStep::microstep_in_place), and
    /// after any [`microstep`](LayerStep::microstep) — that variant
    /// moves the arena out to the caller).
    pub fn outputs(&self) -> &[SiteOutputs] {
        &self.arena
    }

    /// Step boundary (Algorithm 2): fold the microsteps' mean
    /// executed per-site fallback rates into the threshold controller
    /// and reset the accumulator. Returns the rates that were
    /// applied (empty when no microstep ran since the last call).
    pub fn end_step(&mut self) -> Vec<f32> {
        self.rates.flush_into(&mut self.controller)
    }
}

/// Configuration of a [`ModelStep`] driver.
#[derive(Debug, Clone)]
pub struct ModelStepConfig {
    /// transformer layers ([`sites_per_layer`] linear sites each)
    pub layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
    /// GLU MLP: splits the MLP input projection into the `mlp_gate`
    /// and `mlp_up` sites — 5 sites per layer instead of 4, each
    /// with its own Algorithm-2 threshold
    pub glu: bool,
    /// LM-head output features — the (d_model × vocab) head weight is
    /// the multi-shape pressure case of the shared plan cache
    pub vocab: usize,
    /// tokens per microstep (rows of every activation)
    pub tokens: usize,
    /// quantization block size
    pub block: usize,
    pub threads: usize,
    /// data path all plans run (the `PALLAS_PATH` override when set,
    /// else [`DataPath::auto_for`])
    pub path: DataPath,
    /// opt-in outlier telemetry (see [`LayerStepConfig::telemetry`])
    pub telemetry: bool,
    /// shared plan-cache capacity; validated ≥
    /// [`working_set`](ModelStepConfig::working_set) at construction
    /// (defaults to exactly that)
    pub cache_capacity: usize,
    /// base seed of the gradient SR streams; layer `l` draws from
    /// [`layer_sr_seed`]`(sr_seed, l)` so each layer matches a
    /// standalone [`LayerStep`] seeded that way
    pub sr_seed: u64,
    /// shard count every plan is built with (default: the
    /// `PALLAS_SHARDS` knob) — bit-neutral, see
    /// [`GemmPlan::with_shards`]
    pub shards: usize,
}

impl ModelStepConfig {
    pub fn new(layers: usize, d_model: usize, d_ff: usize,
               vocab: usize, tokens: usize,
               block: usize) -> ModelStepConfig {
        assert!(layers >= 1, "at least one transformer layer");
        let mut cfg = ModelStepConfig {
            layers,
            d_model,
            d_ff,
            glu: true,
            vocab,
            tokens,
            block,
            threads: default_threads(),
            path: env_path()
                .unwrap_or_else(|| DataPath::auto_for(block)),
            telemetry: false,
            cache_capacity: 0,
            sr_seed: GRAD_SR_SEED,
            shards: default_shards(),
        };
        cfg.cache_capacity = cfg.working_set();
        cfg
    }

    /// Linear sites of the whole model: [`sites_per_layer`] per layer
    /// (4, or 5 with the GLU gate/up split) + the LM head.
    pub fn n_sites(&self) -> usize {
        sites_per_layer(self.glu) * self.layers + 1
    }

    /// Plan-cache working set: 2 weight halves (W, Wᵀ) per site.
    pub fn working_set(&self) -> usize {
        2 * self.n_sites()
    }

    /// The [`LayerStepConfig`] a standalone driver of layer `layer`
    /// would need to reproduce this model's behavior bit-for-bit
    /// (same shapes, path, threads, and — through [`layer_sr_seed`]
    /// — the same gradient SR streams). The composed-LayerSteps
    /// bit-identity tests and bench build their references with this.
    pub fn layer_config(&self, layer: usize) -> LayerStepConfig {
        assert!(layer < self.layers, "layer {layer} of {}", self.layers);
        let mut c = LayerStepConfig::new(self.d_model, self.d_ff,
                                         self.tokens, self.block);
        c.glu = self.glu;
        c.threads = self.threads;
        c.path = self.path;
        c.telemetry = self.telemetry;
        c.sr_seed = layer_sr_seed(self.sr_seed, layer);
        c.shards = self.shards;
        c
    }
}

/// Version tag of the warm-state JSON format. v2 added the top-level
/// `format` record (the precision lattice rung the cached plans were
/// packed for); v1 files predate the lattice and are rejected with a
/// dedicated error — their plan keys cannot name a format, so a
/// silent restore could serve i8-packed panels to an Int4 run.
const WARM_STATE_VERSION: f64 = 2.0;
const WARM_STATE_KIND: &str = "dbfq_model_step_warm_state";

/// Drives every linear site of an N-layer transformer + LM head
/// through the fallback GEMM engine with **one** shared [`PlanCache`]
/// — the whole-model scaling of [`LayerStep`] that the paper's 1.57x
/// end-to-end number implicitly assumes. Weight ids are namespaced by
/// global site index (`2·site + transposed`), so layers never
/// conflate even when shape-identical, and the (d_model × vocab)
/// LM-head plans exercise real multi-shape pressure in the same
/// cache. One [`ThresholdController`] holds a θ per site
/// ([`sites_per_layer`]`·layers + 1` — the GLU gate and up
/// projections each get their own) and one [`RateAccumulator`] per
/// model step feeds it executed rates at
/// [`end_step`](ModelStep::end_step).
///
/// Per site the microstep math is [`LayerStep`]'s, by construction
/// (both call the same private site runner): layer `l` of a
/// `ModelStep` is bit-identical to a standalone `LayerStep` built
/// from [`ModelStepConfig::layer_config`]`(l)` with the same weights
/// and thresholds — property-tested per backend and thread count.
///
/// ## Warm state
///
/// [`warm_state`](ModelStep::warm_state) serializes what a fresh
/// process needs to *start* at steady state instead of re-walking the
/// cold transient: the adapted θ vector (full Algorithm 2 controller
/// state), the microstep counter (so gradient SR streams continue
/// rather than repeat), the pinned backend, the resident plan keys,
/// and optionally a measured [`SubstrateCalibration`]. Restoring with
/// [`from_warm_state`](ModelStep::from_warm_state) re-quantizes the
/// weight halves from the passed master weights (codes are *not*
/// serialized — they are derived data) and prewarms the cache, so the
/// first microstep of the new process already hits on every lookup
/// and its outputs are bit-identical to the ones the saved process
/// would have produced next.
pub struct ModelStep {
    cfg: ModelStepConfig,
    sites: Vec<LinearShape>,
    /// master weights, one (k × n) matrix per global site
    weights: Vec<Mat>,
    cache: PlanCache,
    controller: ThresholdController,
    rates: RateAccumulator,
    kernels: &'static Kernels,
    microsteps: usize,
    /// site-keyed output arena, reused across microsteps (see
    /// [`microstep_in_place`](ModelStep::microstep_in_place))
    arena: Vec<SiteOutputs>,
    /// in-flight split-microstep state, one slot per site (see
    /// [`forward_site`](ModelStep::forward_site))
    pending: Vec<Option<PendingSite>>,
}

/// Split-microstep bookkeeping for one site between its
/// [`forward_site`](ModelStep::forward_site) and the end of the
/// microstep: the forward's activation quantization (consumed by the
/// backward — its permutation is dW's Xᵀ operand) plus the per-site
/// accounting the batch path would have collected in one go.
struct PendingSite {
    fx: ActQuant,
    fwd_rate: f64,
    fwd_f32_rate: f64,
    bwd_rate: f64,
    bwd_f32_rate: f64,
    hist: Option<Vec<u64>>,
    hits: u64,
    misses: u64,
    bwd_done: bool,
}

impl ModelStep {
    /// `weights[s]` must be the (k × n) matrix of global site `s` in
    /// [`model_linears`] order (layer 0's qkv…mlp_down, …, LM head
    /// last). Panics when `cfg.cache_capacity` is below the working
    /// set (see [`LayerStep::new`] — same silent-thrash hazard, 4
    /// layers' worth bigger).
    pub fn new(cfg: ModelStepConfig, weights: Vec<Mat>) -> ModelStep {
        let sites = model_linears(cfg.layers, cfg.d_model, cfg.d_ff,
                                  cfg.glu, cfg.vocab, cfg.tokens);
        let working_set = 2 * sites.len();
        assert!(
            cfg.cache_capacity >= working_set,
            "plan-cache capacity {} is below the model's working set \
             of {working_set} (2 weight halves x {} sites across {} \
             layers + LM head): every microstep would silently thrash",
            cfg.cache_capacity,
            sites.len(),
            cfg.layers
        );
        assert_eq!(weights.len(), sites.len(), "one weight per site");
        for (s, (w, l)) in weights.iter().zip(&sites).enumerate() {
            assert_eq!((w.rows, w.cols), (l.k, l.n),
                       "weight shape for site {s} ({})", l.name);
        }
        let controller =
            ThresholdController::paper_default(sites.len());
        let rates = RateAccumulator::new(sites.len());
        let cache = PlanCache::new(cfg.cache_capacity);
        let pending = sites.iter().map(|_| None).collect();
        ModelStep {
            sites,
            weights,
            cache,
            controller,
            rates,
            kernels: kernels::select(),
            microsteps: 0,
            arena: Vec::new(),
            pending,
            cfg,
        }
    }

    /// Synthetic Gaussian weights (benches, tests).
    pub fn with_random_weights(cfg: ModelStepConfig,
                               seed: u64) -> ModelStep {
        let sites = model_linears(cfg.layers, cfg.d_model, cfg.d_ff,
                                  cfg.glu, cfg.vocab, cfg.tokens);
        let mut rng = Pcg64::new(seed);
        let weights = sites
            .iter()
            .map(|l| Mat::randn(l.k, l.n, 0.05, &mut rng))
            .collect();
        ModelStep::new(cfg, weights)
    }

    /// Pin every plan this driver builds to an explicit microkernel
    /// backend (tests, per-backend benches). Call before the first
    /// microstep — cached entries are keyed by backend.
    pub fn with_kernels(mut self, k: &'static Kernels) -> ModelStep {
        self.kernels = k;
        self
    }

    /// Global site list (layer-major, LM head last).
    pub fn sites(&self) -> &[LinearShape] {
        &self.sites
    }

    pub fn config(&self) -> &ModelStepConfig {
        &self.cfg
    }

    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    /// Drop every cached weight plan (the bench's cold baseline).
    pub fn clear_cache(&mut self) {
        self.cache.clear();
    }

    pub fn controller(&self) -> &ThresholdController {
        &self.controller
    }

    pub fn controller_mut(&mut self) -> &mut ThresholdController {
        &mut self.controller
    }

    /// Microsteps run since construction — or, after a warm-state
    /// restore, since the *saved process's* construction: the counter
    /// rides the warm state so gradient SR streams continue instead
    /// of repeating.
    pub fn microsteps(&self) -> usize {
        self.microsteps
    }

    /// Backend every plan of this driver is pinned to.
    pub fn kernel_backend(&self) -> &'static str {
        self.kernels.name
    }

    /// Replace global site `site`'s master weight (optimizer-update
    /// path) and invalidate its two cached halves; every other site
    /// keeps hitting.
    ///
    /// Panics if a split microstep is in flight: mutating a weight
    /// between a site's [`forward_site`](ModelStep::forward_site) and
    /// [`backward_site`](ModelStep::backward_site) would run the
    /// backward GEMMs against a different W than the forward —
    /// silent gradient corruption, not a supported cadence.
    pub fn set_weight(&mut self, site: usize, w: Mat) {
        assert!(
            !self.split_in_flight(),
            "set_weight during a split microstep: finish_microstep \
             first"
        );
        let l = &self.sites[site];
        assert_eq!((w.rows, w.cols), (l.k, l.n),
                   "weight shape for site {}", l.name);
        self.weights[site] = w;
        self.cache.invalidate_weight(2 * site as u64);
        self.cache.invalidate_weight(2 * site as u64 + 1);
    }

    /// Whether any site has run [`forward_site`](
    /// ModelStep::forward_site) without the enclosing microstep being
    /// closed by [`finish_microstep`](ModelStep::finish_microstep).
    fn split_in_flight(&self) -> bool {
        self.pending.iter().any(|p| p.is_some())
    }

    /// The gradient SR rounding of global site `s` at microstep `t`:
    /// layer-namespaced so layer `l` matches a standalone
    /// [`LayerStep`] seeded [`layer_sr_seed`]`(sr_seed, l)` (the LM
    /// head is "layer" `layers`, site 0 of its stream). The per-layer
    /// stride is [`sites_per_layer`] — 5 under the GLU gate/up split.
    fn site_rounding(&self, s: usize, t: usize) -> Rounding {
        let spl = sites_per_layer(self.cfg.glu);
        let (layer, local) = if s < spl * self.cfg.layers {
            (s / spl, s % spl)
        } else {
            (self.cfg.layers, 0)
        };
        Rounding::Stochastic(grad_sr_seed(
            layer_sr_seed(self.cfg.sr_seed, layer), t, local))
    }

    /// Run one microstep over every site of the model — same per-site
    /// math as [`LayerStep::microstep`], one shared cache. `acts[s]`
    /// is (tokens × k), `grads[s]` is (tokens × n) per global site
    /// `s`.
    pub fn microstep(&mut self, acts: &[Mat],
                     grads: &[Mat]) -> (Vec<SiteOutputs>, StepReport) {
        let report = self.microstep_in_place(acts, grads);
        (std::mem::take(&mut self.arena), report)
    }

    /// [`microstep`](ModelStep::microstep) without handing the
    /// outputs over: results land in the driver's site-keyed arena
    /// (read via [`outputs`](ModelStep::outputs)) and their buffers
    /// are reused on the next call. With a warm plan cache this is
    /// the zero-allocation steady-state path: no thread spawns, no
    /// engine workspace growth, no output allocation (pinned by
    /// `tests/pool_prop.rs` via [`crate::util::pool::work_counters`]).
    pub fn microstep_in_place(&mut self, acts: &[Mat],
                              grads: &[Mat]) -> StepReport {
        assert!(
            !self.split_in_flight(),
            "batch microstep during a split microstep: \
             finish_microstep first"
        );
        let rounds: Vec<Rounding> = (0..self.sites.len())
            .map(|s| self.site_rounding(s, self.microsteps))
            .collect();
        let report = drive_microstep(
            &self.sites, &self.weights, &self.controller.thresholds,
            &rounds, acts, grads, self.cfg.block, self.cfg.threads,
            self.cfg.path, self.kernels, self.cfg.shards,
            self.cfg.telemetry, &mut self.cache, &mut self.rates,
            &mut self.arena,
        );
        self.microsteps += 1;
        report
    }

    /// The last microstep's per-site outputs (empty before the first
    /// [`microstep_in_place`](ModelStep::microstep_in_place), and
    /// after any [`microstep`](ModelStep::microstep) — that variant
    /// moves the arena out to the caller).
    pub fn outputs(&self) -> &[SiteOutputs] {
        &self.arena
    }

    /// Sequential forward of one site inside a **split microstep** —
    /// the training-loop cadence, where site `s+1`'s activation is
    /// computed *from* site `s`'s output and the batch
    /// [`microstep`](ModelStep::microstep) (all activations known up
    /// front) cannot be used. Runs exactly the batch path's forward
    /// half ([`run_site_forward`]) against the shared cache and
    /// returns a copy of `Y = X·W` (the arena keeps the original —
    /// [`outputs`](ModelStep::outputs) — so warm buffers are still
    /// reused in place).
    ///
    /// Protocol: call `forward_site` once per site (any order), then
    /// [`backward_site`](ModelStep::backward_site) once per site (any
    /// order — training uses reverse), then
    /// [`finish_microstep`](ModelStep::finish_microstep). Gradient SR
    /// streams are derived from (microstep, site), not call order, so
    /// a split microstep is bit-identical to the batch microstep over
    /// the same tensors — `split_microstep_matches_batch_microstep`
    /// pins it.
    pub fn forward_site(&mut self, site: usize, x: &Mat) -> Mat {
        assert!(site < self.sites.len(), "unknown site {site}");
        assert!(
            self.pending[site].is_none(),
            "forward_site called twice for site {site} in one \
             microstep"
        );
        self.arena.truncate(self.sites.len());
        while self.arena.len() < self.sites.len() {
            self.arena.push(SiteOutputs::empty());
        }
        let theta = self.controller.thresholds[site];
        let s0 = self.cache.stats();
        let l = &self.sites[site];
        let fx = run_site_forward(
            l, &self.weights[site], x, theta, 2 * site as u64,
            self.cfg.block, self.cfg.threads, self.cfg.path,
            self.kernels, self.cfg.shards, &mut self.cache,
            &mut self.arena[site],
        );
        let s1 = self.cache.stats();
        let fwd_rate = fx.fallback_rate();
        let fwd_f32_rate = fx.f32_rate();
        let hist = self.cfg.telemetry
            .then(|| metric_histogram(fx.metric()));
        self.pending[site] = Some(PendingSite {
            fx,
            fwd_rate,
            fwd_f32_rate,
            bwd_rate: 0.0,
            bwd_f32_rate: 0.0,
            hist,
            hits: s1.hits - s0.hits,
            misses: s1.misses - s0.misses,
            bwd_done: false,
        });
        self.arena[site].y.clone()
    }

    /// Sequential backward of one site inside a split microstep: runs
    /// exactly the batch path's backward half ([`run_site_backward`])
    /// — `dX = dY·Wᵀ` through the cached Wᵀ half, `dW = Xᵀ·dY`
    /// against the permutation of the forward's activation
    /// quantization — and returns a copy of `dX` (the chained
    /// upstream gradient; `dW` stays in the arena for the optimizer
    /// to read via [`outputs`](ModelStep::outputs)). The gradient SR
    /// stream is the site's (microstep, site) stream regardless of
    /// call order. Panics without a prior
    /// [`forward_site`](ModelStep::forward_site) for this site.
    pub fn backward_site(&mut self, site: usize, dy: &Mat) -> Mat {
        assert!(site < self.sites.len(), "unknown site {site}");
        let sr = self.site_rounding(site, self.microsteps);
        let s0 = self.cache.stats();
        let l = &self.sites[site];
        let p = self.pending[site].as_mut().unwrap_or_else(|| {
            panic!("backward_site without forward_site for site \
                    {site}")
        });
        assert!(
            !p.bwd_done,
            "backward_site called twice for site {site} in one \
             microstep"
        );
        let (bwd_rate, bwd_f32_rate) = run_site_backward(
            l, &self.weights[site], &p.fx, dy, sr, 2 * site as u64,
            self.cfg.block, self.cfg.threads, self.cfg.path,
            self.kernels, self.cfg.shards, &mut self.cache,
            &mut self.arena[site],
        );
        let s1 = self.cache.stats();
        p.bwd_rate = bwd_rate;
        p.bwd_f32_rate = bwd_f32_rate;
        p.bwd_done = true;
        p.hits += s1.hits - s0.hits;
        p.misses += s1.misses - s0.misses;
        self.arena[site].dx.clone()
    }

    /// Close a split microstep: assert every site ran its forward and
    /// backward, assemble the same [`StepReport`] the batch
    /// [`microstep`](ModelStep::microstep) would have produced,
    /// record the executed forward rates into the Algorithm 2
    /// accumulator, and advance the microstep counter (the SR-stream
    /// clock). After this call [`set_weight`](ModelStep::set_weight)
    /// is legal again and the next microstep — split or batch —
    /// begins fresh.
    pub fn finish_microstep(&mut self) -> StepReport {
        let mut site_reports = Vec::with_capacity(self.sites.len());
        let mut executed = vec![0.0f64; self.sites.len()];
        let mut hits = 0u64;
        let mut misses = 0u64;
        for (i, l) in self.sites.iter().enumerate() {
            let p = self.pending[i].take().unwrap_or_else(|| {
                panic!("finish_microstep: site {i} ({}) never ran \
                        forward_site", l.name)
            });
            assert!(
                p.bwd_done,
                "finish_microstep: site {i} ({}) never ran \
                 backward_site",
                l.name
            );
            executed[i] = p.fwd_rate;
            hits += p.hits;
            misses += p.misses;
            site_reports.push(SiteReport {
                name: l.name,
                fallback_rate: p.fwd_rate,
                bwd_fallback_rate: p.bwd_rate,
                fallback_rate_f32: p.fwd_f32_rate,
                bwd_fallback_rate_f32: p.bwd_f32_rate,
                outlier_hist: p.hist,
                cache_hits: p.hits,
                cache_misses: p.misses,
                flops: l.microstep_flops(),
            });
        }
        self.rates.record(&executed);
        self.microsteps += 1;
        let flops = site_reports.iter().map(|s| s.flops).sum();
        StepReport {
            sites: site_reports,
            cache_hits: hits,
            cache_misses: misses,
            flops,
        }
    }

    /// Step boundary (Algorithm 2): fold the microsteps' mean
    /// executed per-site fallback rates into the threshold controller
    /// and reset the accumulator — one update per model step across
    /// all [`sites_per_layer`]`·layers + 1` sites. Returns the
    /// applied rates (empty when no microstep ran since the last
    /// call).
    pub fn end_step(&mut self) -> Vec<f32> {
        self.rates.flush_into(&mut self.controller)
    }

    /// Serialize the warm state: config fingerprint, pinned backend,
    /// microstep counter, full controller state, resident plan keys,
    /// and (optionally) a measured calibration. Master weights are
    /// *not* serialized — the quantized halves are derived data that
    /// [`from_warm_state`](ModelStep::from_warm_state) rebuilds from
    /// the weights the caller passes in.
    pub fn warm_state(&self,
                      cal: Option<&SubstrateCalibration>) -> Json {
        let keys = Json::Arr(
            self.cache
                .keys()
                .iter()
                .map(|k| obj(vec![
                    ("weight_id", Json::Num(k.weight_id as f64)),
                    ("k", Json::Num(k.k as f64)),
                    ("n", Json::Num(k.n as f64)),
                    ("block", Json::Num(k.block as f64)),
                    ("path", Json::Str(k.path.tag().into())),
                    ("backend", Json::Str(k.backend.into())),
                    ("shards", Json::Num(k.shards as f64)),
                ]))
                .collect(),
        );
        obj(vec![
            ("kind", Json::Str(WARM_STATE_KIND.into())),
            ("version", Json::Num(WARM_STATE_VERSION)),
            // the precision-lattice rung every cached plan was packed
            // for — validated before anything else config-shaped on
            // restore, with its own loud error path
            ("format", Json::Str(self.cfg.path.tag().into())),
            ("config", obj(vec![
                ("layers", Json::Num(self.cfg.layers as f64)),
                ("d_model", Json::Num(self.cfg.d_model as f64)),
                ("d_ff", Json::Num(self.cfg.d_ff as f64)),
                ("glu", Json::Bool(self.cfg.glu)),
                ("vocab", Json::Num(self.cfg.vocab as f64)),
                ("tokens", Json::Num(self.cfg.tokens as f64)),
                ("block", Json::Num(self.cfg.block as f64)),
                ("path", Json::Str(self.cfg.path.tag().into())),
                // u64 exceeds the exact-f64 integer range: hex string
                ("sr_seed",
                 Json::Str(format!("{:016x}", self.cfg.sr_seed))),
                ("shards", Json::Num(self.cfg.shards as f64)),
            ])),
            ("backend", Json::Str(self.kernels.name.into())),
            ("microsteps", Json::Num(self.microsteps as f64)),
            ("controller", self.controller.to_json()),
            ("plan_keys", keys),
            ("calibration", match cal {
                Some(c) => c.to_json(),
                None => Json::Null,
            }),
        ])
    }

    /// [`warm_state`](ModelStep::warm_state) straight to a file.
    pub fn save_warm_state(&self, path: &str,
                           cal: Option<&SubstrateCalibration>)
                           -> Result<(), String> {
        self.warm_state(cal).to_file(path)
    }

    /// Rebuild a driver from a warm-state JSON and the master
    /// weights: validates the config fingerprint (restoring against
    /// a different model is an error, not silent corruption),
    /// restores the controller (θ vector + Algorithm 2 counters) and
    /// the microstep counter, re-pins the recorded backend when this
    /// host has it (a `PALLAS_KERNEL` override always wins, and a
    /// host without the backend falls back to normal selection),
    /// and **prewarms** the cache — both weight halves of every site
    /// are quantized and packed up front, so the first microstep
    /// hits on all `2 × sites` lookups and is bit-identical to the
    /// microstep the saved process would have run next. Returns the
    /// embedded calibration alongside, when one was saved.
    pub fn from_warm_state(cfg: ModelStepConfig, weights: Vec<Mat>,
                           state: &Json)
                           -> Result<(ModelStep,
                                      Option<SubstrateCalibration>),
                                     String> {
        if state.get("kind").and_then(|v| v.as_str())
            != Some(WARM_STATE_KIND)
        {
            return Err("warm state: wrong or missing 'kind'".into());
        }
        match state.get("version").and_then(|v| v.as_f64()) {
            Some(v) if v == WARM_STATE_VERSION => {}
            Some(v) if v < WARM_STATE_VERSION => {
                return Err(format!(
                    "warm state: version {v} is a pre-lattice \
                     snapshot (no precision-format record); re-save \
                     the warm state with this build"
                ));
            }
            _ => {
                return Err("warm state: unsupported version".into());
            }
        }
        // The precision format is validated before the config
        // fingerprint so a lattice mismatch gets its dedicated
        // error: the plan keys embed the format, and every prewarmed
        // entry would miss (or worse, i8 panels would be rebuilt for
        // an Int4 run) if it restored silently.
        let fmt = match state.get("format").and_then(|v| v.as_str()) {
            None => {
                return Err(
                    "warm state: missing 'format' — a pre-lattice \
                     snapshot cannot be restored; re-save the warm \
                     state with this build"
                        .into(),
                );
            }
            Some(s) => DataPath::from_tag(s).ok_or_else(|| {
                format!("warm state: unknown precision format {s:?}")
            })?,
        };
        if fmt != cfg.path {
            return Err(format!(
                "warm state: recorded precision format '{}' differs \
                 from the live config's '{}' (set PALLAS_PATH to \
                 match or re-save the warm state)",
                fmt.tag(),
                cfg.path.tag()
            ));
        }
        let sc = state
            .get("config")
            .ok_or("warm state: missing 'config'")?;
        let field = |k: &str| {
            sc.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| format!("warm state: missing '{k}'"))
        };
        let saved_seed = sc
            .get("sr_seed")
            .and_then(|v| v.as_str())
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or("warm state: missing 'sr_seed'")?;
        let saved_path = sc
            .get("path")
            .and_then(|v| v.as_str())
            .and_then(DataPath::from_tag)
            .ok_or("warm state: missing 'path'")?;
        let fingerprint_ok = field("layers")? == cfg.layers
            && field("d_model")? == cfg.d_model
            && field("d_ff")? == cfg.d_ff
            && sc.get("glu").and_then(|v| v.as_bool())
                == Some(cfg.glu)
            && field("vocab")? == cfg.vocab
            && field("tokens")? == cfg.tokens
            && field("block")? == cfg.block
            && saved_path == cfg.path
            && saved_seed == cfg.sr_seed;
        if !fingerprint_ok {
            return Err(format!(
                "warm state: config fingerprint mismatch (saved for \
                 a different model than layers={} d_model={} d_ff={} \
                 glu={} vocab={} tokens={} block={} path={} \
                 sr_seed={:016x})",
                cfg.layers, cfg.d_model, cfg.d_ff, cfg.glu, cfg.vocab,
                cfg.tokens, cfg.block, cfg.path.tag(), cfg.sr_seed
            ));
        }
        // Shard config mismatch is rejected loudly, mirroring the
        // backend re-pin rules: sharding is bit-neutral, but the plan
        // keys embed it, so a silent mismatch would make every prewarm
        // entry miss on the first microstep — the exact silent-thrash
        // hazard warm state exists to prevent. Files from before the
        // field existed restored at shards = 1.
        let saved_shards = match sc.get("shards") {
            None => 1,
            Some(v) => v.as_usize().ok_or(
                "warm state: malformed 'shards'")?,
        };
        if saved_shards != cfg.shards {
            return Err(format!(
                "warm state: recorded shard count {saved_shards} \
                 differs from the live config's {} (set PALLAS_SHARDS \
                 to match or re-save the warm state)",
                cfg.shards
            ));
        }
        let controller = ThresholdController::from_json(
            state
                .get("controller")
                .ok_or("warm state: missing 'controller'")?,
        )?;
        let microsteps = state
            .get("microsteps")
            .and_then(|v| v.as_usize())
            .ok_or("warm state: missing 'microsteps'")?;
        let mut ms = ModelStep::new(cfg, weights);
        if controller.thresholds.len() != ms.sites.len() {
            return Err(format!(
                "warm state: {} thresholds for {} sites",
                controller.thresholds.len(),
                ms.sites.len()
            ));
        }
        ms.controller = controller;
        ms.microsteps = microsteps;
        // Re-pin the recorded backend when this host has it — unless
        // a PALLAS_KERNEL override is in force, which always wins: a
        // restore that silently out-pinned the override would
        // invalidate scalar-forced CI legs and calibration runs (the
        // exact hazard `kernels::parse_override` hard-errors to
        // prevent). All backends are bit-identical, so this only
        // affects speed, never results.
        if kernels::env_override().is_none() {
            if let Some(k) = state
                .get("backend")
                .and_then(|v| v.as_str())
                .and_then(kernels::by_name)
            {
                ms.kernels = k;
            }
        }
        // Validate the recorded keys against this model's expected
        // working set (backend is advisory — a cross-host restore
        // legitimately re-pins), then prewarm every site.
        if let Some(keys) = state.get("plan_keys").and_then(|v| v.as_arr())
        {
            for kj in keys {
                let id = kj
                    .get("weight_id")
                    .and_then(|v| v.as_usize())
                    .ok_or("warm state: bad plan key")?;
                let site = id / 2;
                if site >= ms.sites.len() {
                    return Err(format!(
                        "warm state: plan key for unknown site {site}"
                    ));
                }
                let l = &ms.sites[site];
                let (ek, en) = if id % 2 == 0 {
                    (l.k, l.n)
                } else {
                    (l.n, l.k)
                };
                let (k, n, block) = (
                    kj.get("k").and_then(|v| v.as_usize()),
                    kj.get("n").and_then(|v| v.as_usize()),
                    kj.get("block").and_then(|v| v.as_usize()),
                );
                if (k, n, block)
                    != (Some(ek), Some(en), Some(ms.cfg.block))
                {
                    return Err(format!(
                        "warm state: plan key shape mismatch for \
                         site {site} ({})",
                        l.name
                    ));
                }
            }
        }
        // Parse the embedded calibration before the prewarm: every
        // validation fails fast, and the expensive full-model
        // quantization/packing only runs once the whole file is known
        // good.
        let cal = match state.get("calibration") {
            None | Some(Json::Null) => None,
            Some(j) => Some(SubstrateCalibration::from_json(j)?),
        };
        ms.prewarm();
        Ok((ms, cal))
    }

    /// Quantize and pack both weight halves of every site into the
    /// cache (misses now so the microsteps only hit).
    fn prewarm(&mut self) {
        let (threads, block, path, shards) =
            (self.cfg.threads, self.cfg.block, self.cfg.path,
             self.cfg.shards);
        let kn = self.kernels;
        let weights = &self.weights;
        let cache = &mut self.cache;
        for (s, l) in self.sites.iter().enumerate() {
            for transposed in [false, true] {
                let (k, n) = if transposed {
                    (l.n, l.k)
                } else {
                    (l.k, l.n)
                };
                cache.get_or_build_with(
                    PlanKey {
                        weight_id: 2 * s as u64 + transposed as u64,
                        k,
                        n,
                        block,
                        path,
                        backend: kn.name,
                        shards,
                    },
                    || build_weight_plan(&weights[s], transposed,
                                         block, threads, path, kn,
                                         shards),
                );
            }
        }
    }
}

/// Synthetic per-site activations and output gradients: Gaussian
/// base, with sparse hot channels in the activations (every 97th
/// input feature spikes with probability 0.3 — the §4.1
/// channel-structured outliers) so the fallback path has texture to
/// adapt to. Returns `(acts, grads)` in site order.
pub fn synth_microbatch(sites: &[LinearShape], seed: u64,
                        outlier_mag: f32) -> (Vec<Mat>, Vec<Mat>) {
    let mut rng = Pcg64::new(seed);
    let acts = sites
        .iter()
        .map(|l| {
            let mut x = Mat::randn(l.m, l.k, 1.0, &mut rng);
            for c in (0..l.k).step_by(97) {
                for r in 0..l.m {
                    if rng.uniform() < 0.3 {
                        x.data[r * l.k + c] =
                            outlier_mag * (1.0 + rng.uniform_f32());
                    }
                }
            }
            x
        })
        .collect();
    let grads = sites
        .iter()
        .map(|l| Mat::randn(l.m, l.n, 1.0, &mut rng))
        .collect();
    (acts, grads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{block_gemm_path, fallback_gemm_path};
    use crate::quant::{block_quant, fallback_quant,
                       quant_work_counters, theta_for_rate};

    fn weight_plan(k: usize, n: usize, block: usize,
                   seed: u64) -> WeightPlan {
        let mut rng = Pcg64::new(seed);
        let w = Mat::randn(k, n, 1.0, &mut rng);
        WeightPlan::new(
            Arc::new(block_quant(&w, block, INT8_LEVELS,
                                 Rounding::Nearest)),
            DataPath::Int8,
        )
        .with_kernels(&kernels::SCALAR)
        .with_shards(1)
    }

    fn key(id: u64, k: usize, n: usize, block: usize) -> PlanKey {
        PlanKey {
            weight_id: id,
            k,
            n,
            block,
            path: DataPath::Int8,
            backend: "scalar",
            shards: 1,
        }
    }

    #[test]
    fn cache_hit_returns_shared_plan() {
        let mut cache = PlanCache::new(4);
        let k1 = key(0, 32, 16, 16);
        let a = cache.get_or_build_with(k1, || {
            weight_plan(32, 16, 16, 1)
        });
        let b = cache.get_or_build_with(k1, || {
            panic!("builder must not run on a hit")
        });
        assert!(Arc::ptr_eq(&a, &b));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(cache.len(), 1);
        assert!(cache.contains(&k1));
    }

    #[test]
    fn cache_evicts_least_recently_used_at_capacity() {
        let mut cache = PlanCache::new(2);
        let ka = key(0, 16, 16, 16);
        let kb = key(1, 16, 32, 16);
        let kc = key(2, 16, 48, 16);
        cache.get_or_build_with(ka, || weight_plan(16, 16, 16, 1));
        cache.get_or_build_with(kb, || weight_plan(16, 32, 16, 2));
        // touch `ka` so `kb` is the LRU victim
        cache.get_or_build_with(ka, || unreachable!());
        cache.get_or_build_with(kc, || weight_plan(16, 48, 16, 3));
        assert_eq!(cache.len(), 2);
        assert!(cache.contains(&ka));
        assert!(!cache.contains(&kb), "LRU entry must be evicted");
        assert!(cache.contains(&kc));
        assert_eq!(cache.stats().evictions, 1);
        // the evicted key rebuilds (miss), within capacity again
        cache.get_or_build_with(kb, || weight_plan(16, 32, 16, 2));
        assert_eq!(cache.stats().evictions, 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn cache_keys_distinguish_id_path_and_backend() {
        // Same weight shape, different key dimensions: all coexist.
        let mut cache = PlanCache::new(8);
        let k_a = key(0, 32, 16, 16);
        // distinct weight_id = a *different tensor* of the same
        // shape — must not be conflated with k_a's entry
        let k_other = PlanKey { weight_id: 7, ..k_a };
        let a = cache.get_or_build_with(k_a, || {
            weight_plan(32, 16, 16, 1)
        });
        let w0 = cache.get_or_build_with(k_other, || {
            weight_plan(32, 16, 16, 99)
        });
        assert!(!Arc::ptr_eq(&w0, &a), "ids must not collide");
        let k_sim = PlanKey { path: DataPath::SimF32, ..k_a };
        cache.get_or_build_with(k_sim, || {
            let mut rng = Pcg64::new(1);
            let w = Mat::randn(32, 16, 1.0, &mut rng);
            WeightPlan::new(
                Arc::new(block_quant(&w, 16, INT8_LEVELS,
                                     Rounding::Nearest)),
                DataPath::SimF32,
            )
            .with_kernels(&kernels::SCALAR)
            .with_shards(1)
        });
        assert_eq!(cache.len(), 3);
        // a second backend (when the host has one) is a fourth entry
        if let Some(kn) = kernels::available()
            .into_iter()
            .find(|k| k.name != "scalar")
        {
            let k_kn = PlanKey { backend: kn.name, ..k_a };
            let c = cache.get_or_build_with(k_kn, || {
                let mut rng = Pcg64::new(1);
                let w = Mat::randn(32, 16, 1.0, &mut rng);
                WeightPlan::new(
                    Arc::new(block_quant(&w, 16, INT8_LEVELS,
                                         Rounding::Nearest)),
                    DataPath::Int8,
                )
                .with_kernels(kn)
                .with_shards(1)
            });
            assert_eq!(c.kernel_backend(), kn.name);
            assert_eq!(cache.len(), 4);
        }
        assert_eq!(cache.stats().hits, 0);
    }

    #[test]
    fn one_cached_entry_serves_both_precisions() {
        // Precision is deliberately NOT in the key: the same cached
        // weight half serves Int8Block and Fallback GEMMs (only the
        // activation side differs), so a mixed-precision caller pays
        // one quantization + one pack per tensor, not two.
        let mut cache = PlanCache::new(4);
        let k1 = key(0, 32, 16, 16);
        let wp = cache.get_or_build_with(k1, || {
            weight_plan(32, 16, 16, 5)
        });
        let again = cache.get_or_build_with(k1, || {
            panic!("second precision must reuse the entry")
        });
        assert!(Arc::ptr_eq(&wp, &again));
        assert_eq!(cache.len(), 1);
        // both precisions execute off the one shared half, and agree
        // with direct engine plans bitwise
        let mut rng = Pcg64::new(31);
        let a = Mat::randn(24, 32, 1.0, &mut rng);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let fa = fallback_quant(&a, -1.0, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        let c_int8 = wp.plan_int8(&qa, 2).execute();
        let c_fb = wp.plan_fallback(&fa, &fa.u, 2).execute();
        let d_int8 = block_gemm_path(&qa, wp.weight(), 2,
                                     DataPath::Int8);
        let d_fb = fallback_gemm_path(&fa, wp.weight(), &fa.u, 2,
                                      DataPath::Int8);
        assert_eq!(c_int8.data, d_int8.data);
        assert_eq!(c_fb.data, d_fb.data);
    }

    #[test]
    #[should_panic(expected = "shape mismatches cache key")]
    fn cache_rejects_mis_keyed_builder() {
        let mut cache = PlanCache::new(2);
        cache.get_or_build_with(key(0, 32, 16, 16),
                                || weight_plan(16, 16, 16, 1));
    }

    fn small_step(threads: usize) -> LayerStep {
        let mut cfg = LayerStepConfig::new(32, 48, 24, 16);
        cfg.glu = false;
        cfg.threads = threads;
        LayerStep::with_random_weights(cfg, 0xD06)
    }

    #[test]
    fn cache_hit_skips_weight_requantization() {
        // Regression via the thread-local work counters: the second
        // microstep must do only per-call quantization (activation +
        // gradient — 2 per site; dW's Xᵀ is a permutation of the
        // activation quant, not a pass) and one panel pack (dY as
        // the dW B-operand); the weight halves (2 quants + 2 packs
        // per site) happen exactly once.
        let mut ls = small_step(2);
        let n_sites = ls.sites().len();
        let (acts, grads) = synth_microbatch(ls.sites(), 5, 150.0);
        let (q0, p0) = quant_work_counters();
        let (_, r1) = ls.microstep(&acts, &grads);
        let (q1, p1) = quant_work_counters();
        assert_eq!(r1.cache_misses as usize, 2 * n_sites);
        assert_eq!(r1.cache_hits, 0);
        assert_eq!((q1 - q0) as usize, 4 * n_sites,
                   "cold microstep: 2 per-call + 2 weight quants/site");
        assert_eq!((p1 - p0) as usize, 3 * n_sites,
                   "cold microstep: W, Wᵀ and dY packs per site");
        let (_, r2) = ls.microstep(&acts, &grads);
        let (q2, p2) = quant_work_counters();
        assert_eq!(r2.cache_misses, 0);
        assert_eq!(r2.cache_hits as usize, 2 * n_sites);
        assert_eq!((q2 - q1) as usize, 2 * n_sites,
                   "warm microstep must not re-quantize weights");
        assert_eq!((p2 - p1) as usize, n_sites,
                   "warm microstep packs only the fresh dY operand");
    }

    #[test]
    fn microstep_matches_direct_engine_calls() {
        let mut ls = small_step(1);
        ls.controller_mut().thresholds.fill(20.0);
        let (acts, grads) = synth_microbatch(ls.sites(), 9, 200.0);
        let sr_base = ls.config().sr_seed;
        let (outs, rep) = ls.microstep(&acts, &grads);
        assert_eq!(outs.len(), 4);
        assert!(rep.flops > 0.0);
        let path = ls.config().path;
        for (i, l) in ls.sites().iter().enumerate() {
            let w = &ls.weights[i];
            let fx = fallback_quant(&acts[i], 20.0, 16, INT8_LEVELS,
                                    Criterion::AbsMax);
            let qw =
                block_quant(w, 16, INT8_LEVELS, Rounding::Nearest);
            let y = fallback_gemm_path(&fx, &qw, &fx.u, 1, path);
            assert_eq!(outs[i].y.data, y.data, "fwd {}", l.name);
            // dY rides the (microstep, site)-seeded SR stream
            let qdy = block_quant(&grads[i], 16, INT8_LEVELS,
                                  Rounding::Stochastic(grad_sr_seed(
                                      sr_base, 0, i)));
            let qwt = block_quant(&w.transpose(), 16, INT8_LEVELS,
                                  Rounding::Nearest);
            let dx = block_gemm_path(&qdy, &qwt, 1, path);
            assert_eq!(outs[i].dx.data, dx.data, "dX {}", l.name);
            // dW's Xᵀ operand rides the fallback path at the same θ
            let fxt = fallback_quant(&acts[i].transpose(), 20.0, 16,
                                     INT8_LEVELS, Criterion::AbsMax);
            let dw = fallback_gemm_path(&fxt, &qdy, &fxt.u, 1, path);
            assert_eq!(outs[i].dw.data, dw.data, "dW {}", l.name);
            assert_eq!((outs[i].y.rows, outs[i].y.cols), (l.m, l.n));
            assert_eq!((outs[i].dx.rows, outs[i].dx.cols),
                       (l.m, l.k));
            assert_eq!((outs[i].dw.rows, outs[i].dw.cols),
                       (l.k, l.n));
        }
    }

    #[test]
    fn microstep_in_place_matches_owned_variant() {
        // The arena path must be bit-identical to the owned variant
        // even when its warm buffers are being rewritten in place.
        let mut a = small_step(2);
        let mut b = small_step(2);
        let (acts, grads) = synth_microbatch(a.sites(), 13, 150.0);
        for step in 0..3 {
            let (outs, ra) = a.microstep(&acts, &grads);
            let rb = b.microstep_in_place(&acts, &grads);
            let held = b.outputs();
            assert_eq!(outs.len(), held.len());
            for (o, h) in outs.iter().zip(held) {
                assert_eq!(o.y.data, h.y.data, "y step {step}");
                assert_eq!(o.dx.data, h.dx.data, "dx step {step}");
                assert_eq!(o.dw.data, h.dw.data, "dw step {step}");
            }
            assert_eq!(ra.cache_misses, rb.cache_misses);
            assert_eq!(ra.cache_hits, rb.cache_hits);
        }
        // the owned variant moves the arena out to the caller
        assert!(a.outputs().is_empty());
    }

    #[test]
    fn set_weight_invalidates_only_that_sites_plans() {
        // Stale-plan regression: after an optimizer update the next
        // microstep must run against the NEW weights (re-quantized),
        // while untouched sites keep hitting the cache.
        let mut ls = small_step(1);
        ls.controller_mut().thresholds.fill(20.0);
        let (acts, grads) = synth_microbatch(ls.sites(), 21, 150.0);
        ls.microstep(&acts, &grads); // warm the cache (8 misses)
        let mut rng = Pcg64::new(777);
        let (k0, n0) =
            (ls.sites()[0].k, ls.sites()[0].n);
        let new_w = Mat::randn(k0, n0, 0.05, &mut rng);
        ls.set_weight(0, new_w.clone());
        assert_eq!(ls.cache().len(), 6, "site 0's two entries dropped");
        let (outs, rep) = ls.microstep(&acts, &grads);
        assert_eq!(rep.cache_misses, 2, "only site 0 rebuilds");
        assert_eq!(rep.cache_hits, 6);
        // site 0's forward now matches a fresh run on the new weight
        let fx = fallback_quant(&acts[0], 20.0, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        let qw = block_quant(&new_w, 16, INT8_LEVELS,
                             Rounding::Nearest);
        let y = fallback_gemm_path(&fx, &qw, &fx.u, 1,
                                   ls.config().path);
        assert_eq!(outs[0].y.data, y.data,
                   "stale plan served after set_weight");
    }

    #[test]
    fn end_step_feeds_executed_rates_into_controller() {
        let mut ls = small_step(2);
        // θ below every block metric -> full fallback -> rates ≈ 1,
        // far above r_max, so Algorithm 2 must raise every θ.
        ls.controller_mut().thresholds.fill(1e-3);
        let (acts, grads) = synth_microbatch(ls.sites(), 3, 150.0);
        let (_, rep) = ls.microstep(&acts, &grads);
        assert!(rep.sites.iter().all(|s| s.fallback_rate > 0.9));
        let applied = ls.end_step();
        assert_eq!(applied.len(), 4);
        assert!(applied.iter().all(|&r| r > 0.9));
        assert!(ls.controller().thresholds.iter().all(|&t| t > 1e-3));
        assert_eq!(ls.controller().n_up, 4);
        // nothing recorded since -> end_step is a no-op
        let before = ls.controller().thresholds.clone();
        assert!(ls.end_step().is_empty());
        assert_eq!(ls.controller().thresholds, before);
    }

    #[test]
    fn undersized_cache_thrashes_with_zero_hits() {
        // Pins the previously-silent failure mode: a cache smaller
        // than the working set keeps producing correct results while
        // every single lookup misses — the only signal is the stats.
        let mut cache = PlanCache::new(2);
        let keys: Vec<PlanKey> =
            (0..4).map(|i| key(i, 16, 16, 16)).collect();
        for _round in 0..3 {
            for k in &keys {
                cache.get_or_build_with(*k, || {
                    weight_plan(16, 16, 16, k.weight_id)
                });
            }
        }
        let s = cache.stats();
        assert_eq!(s.hits, 0, "working set 4 > capacity 2: no lookup \
                   can ever hit");
        assert_eq!(s.misses, 12);
        assert_eq!(s.insertions, 12);
        assert_eq!(s.evictions, 10);
        assert!(s.thrashing(), "stats {s:?} must flag thrash");
        // healthy control: capacity that fits the working set
        let mut ok = PlanCache::new(4);
        for _round in 0..3 {
            for k in &keys {
                ok.get_or_build_with(*k, || {
                    weight_plan(16, 16, 16, k.weight_id)
                });
            }
        }
        let s = ok.stats();
        assert_eq!(s.hits, 8);
        assert_eq!(s.evictions, 0);
        assert!(!s.thrashing(), "stats {s:?} must not flag thrash");
    }

    #[test]
    fn windowed_stats_catch_thrash_after_a_warm_phase() {
        // Lifetime counters hide thrash that starts late: after a
        // long healthy phase the accumulated hit rate stays high
        // even once every new lookup misses. `since` deltas are the
        // windowed remedy.
        let mut cache = PlanCache::new(4);
        let warm: Vec<PlanKey> =
            (0..4).map(|i| key(i, 16, 16, 16)).collect();
        for _round in 0..10 {
            for k in &warm {
                cache.get_or_build_with(*k, || {
                    weight_plan(16, 16, 16, k.weight_id)
                });
            }
        }
        let snapshot = cache.stats();
        assert!(!snapshot.thrashing());
        // the working set changes and outgrows capacity (e.g. new
        // shapes → new weight ids): cyclic access over 6 fresh keys
        // on a 4-entry LRU misses every time
        let grown: Vec<PlanKey> =
            (10..16).map(|i| key(i, 16, 16, 16)).collect();
        for _round in 0..3 {
            for k in &grown {
                cache.get_or_build_with(*k, || {
                    weight_plan(16, 16, 16, k.weight_id)
                });
            }
        }
        let lifetime = cache.stats();
        assert!(!lifetime.thrashing(),
                "lifetime counters are blind to late-onset thrash \
                 ({lifetime:?})");
        let window = lifetime.since(&snapshot);
        assert_eq!(window.hits, 0);
        assert_eq!(window.misses, 18);
        assert!(window.thrashing(),
                "windowed stats must flag it ({window:?})");
    }

    #[test]
    #[should_panic(expected = "below the layer's working set")]
    fn layer_step_rejects_undersized_cache() {
        let mut cfg = LayerStepConfig::new(32, 48, 24, 16);
        cfg.cache_capacity = 7; // working set is 8
        LayerStep::with_random_weights(cfg, 1);
    }

    #[test]
    #[should_panic(expected = "below the model's working set")]
    fn model_step_rejects_undersized_cache() {
        let mut cfg = ModelStepConfig::new(2, 32, 48, 64, 16, 16);
        cfg.cache_capacity = cfg.working_set() - 1;
        ModelStep::with_random_weights(cfg, 1);
    }

    #[test]
    fn grad_quantization_is_unbiased_under_sr_biased_under_nearest() {
        // dY designed so nearest rounding is maximally biased: one
        // 127.0 anchor pins the block scale to exactly 1.0, every
        // other entry sits at 0.3 — nearest sends them all to 0
        // (per-entry bias −0.3), stochastic rounding draws 1 with
        // probability 0.3 (unbiased).
        let mut dy = Mat::zeros(16, 16);
        dy.data.fill(0.3);
        dy.data[0] = 127.0;
        let qn = block_quant(&dy, 16, INT8_LEVELS, Rounding::Nearest);
        assert_eq!(qn.scale[0], 1.0);
        let dn = qn.dequant();
        let mean_err_nearest = dy
            .data
            .iter()
            .zip(&dn.data)
            .map(|(x, q)| (q - x) as f64)
            .sum::<f64>()
            / dy.data.len() as f64;
        assert!(mean_err_nearest.abs() > 0.25,
                "nearest must be visibly biased here, got \
                 {mean_err_nearest}");

        // The same dY through the *gradient path of the pipeline*:
        // site 1 (attn_out, the square site) gets W = I, which
        // quantizes exactly, so dX = dequant(q(dY)) element-wise and
        // the mean of dX over many microsteps estimates E[q(dY)].
        // The microstep-seeded SR streams must drive that mean to dY
        // itself.
        let mut cfg = LayerStepConfig::new(16, 16, 16, 16);
        cfg.glu = false;
        cfg.threads = 2;
        let sites = layer_linears(16, 16, false, 16);
        let mut rng = Pcg64::new(0xB1A5);
        let weights: Vec<Mat> = sites
            .iter()
            .enumerate()
            .map(|(i, l)| {
                if i == 1 {
                    Mat::from_fn(l.k, l.n, |r, c| {
                        if r == c { 1.0 } else { 0.0 }
                    })
                } else {
                    Mat::randn(l.k, l.n, 0.05, &mut rng)
                }
            })
            .collect();
        let mut ls = LayerStep::new(cfg, weights);
        ls.controller_mut().thresholds.fill(f32::INFINITY);
        let acts: Vec<Mat> = sites
            .iter()
            .map(|l| Mat::randn(l.m, l.k, 1.0, &mut rng))
            .collect();
        let grads: Vec<Mat> = sites
            .iter()
            .enumerate()
            .map(|(i, l)| {
                if i == 1 {
                    dy.clone()
                } else {
                    Mat::randn(l.m, l.n, 1.0, &mut rng)
                }
            })
            .collect();
        let trials = 300usize;
        let mut acc = vec![0.0f64; 256];
        let mut first: Option<Vec<f32>> = None;
        let mut saw_fresh_draws = false;
        for _ in 0..trials {
            let (outs, _) = ls.microstep(&acts, &grads);
            match &first {
                None => first = Some(outs[1].dx.data.clone()),
                Some(f) => {
                    saw_fresh_draws |= *f != outs[1].dx.data;
                }
            }
            for (a, v) in acc.iter_mut().zip(&outs[1].dx.data) {
                *a += *v as f64;
            }
        }
        assert!(saw_fresh_draws,
                "SR must draw fresh per microstep, not repeat one");
        for (i, (a, v)) in acc.iter().zip(&dy.data).enumerate() {
            if i == 0 {
                continue; // the exact 127.0 anchor
            }
            let mean = a / trials as f64;
            let err = (mean - *v as f64).abs();
            assert!(err < 0.2,
                    "dY[{i}]: SR mean {mean} vs {v} (|bias| {err} — \
                     nearest would sit at 0.3)");
        }
    }

    #[test]
    fn dw_routes_transposed_activation_through_fallback() {
        // The dW bugfix: Xᵀ must carry X's per-block outlier
        // handling. Exact i64 oracle + u-mask transposition check +
        // the reported backward rate. The oracle quantizes xᵀ from
        // scratch, so this also pins `fx.transposed()` (the
        // pipeline's permuted reuse) against a fresh Algorithm 1 run.
        let mut ls = small_step(1);
        let (acts, grads) = synth_microbatch(ls.sites(), 33, 250.0);
        // θ from a probe at a moderate rate so fallback is active
        let thetas: Vec<f32> = acts
            .iter()
            .map(|x| {
                let probe = fallback_quant(x, f32::INFINITY, 16,
                                           INT8_LEVELS,
                                           Criterion::AbsMax);
                theta_for_rate(&probe.metric, 0.3)
            })
            .collect();
        ls.controller_mut().thresholds.copy_from_slice(&thetas);
        let sr_base = ls.config().sr_seed;
        let (outs, rep) = ls.microstep(&acts, &grads);
        let mut any_bwd_fallback = false;
        for (i, l) in ls.sites().iter().enumerate() {
            let fx = fallback_quant(&acts[i], thetas[i], 16,
                                    INT8_LEVELS, Criterion::AbsMax);
            let fxt = fallback_quant(&acts[i].transpose(), thetas[i],
                                     16, INT8_LEVELS,
                                     Criterion::AbsMax);
            // AbsMax is symmetric under block transposition, so the
            // backward reuses exactly the forward's block decisions
            let (rb, cb) = (fx.base.rb(), fx.base.cb());
            for bi in 0..cb {
                for bj in 0..rb {
                    assert_eq!(fxt.u[bi * rb + bj],
                               fx.u[bj * cb + bi],
                               "u-mask transposition {} ({bi},{bj})",
                               l.name);
                }
            }
            // exact i64 fallback oracle for dW
            let qdy = block_quant(&grads[i], 16, INT8_LEVELS,
                                  Rounding::Stochastic(grad_sr_seed(
                                      sr_base, 0, i)));
            let oracle = crate::gemm::int8::fallback_gemm_reference(
                &fxt, &qdy, &fxt.u);
            assert_eq!(outs[i].dw.data, oracle.data,
                       "dW vs i64 oracle at {}", l.name);
            // executed backward rate is reported per site
            let want = fxt.fallback_rate();
            assert!((rep.sites[i].bwd_fallback_rate - want).abs()
                        < 1e-12,
                    "bwd rate report at {}", l.name);
            any_bwd_fallback |= want > 0.0;
            // per-site cache accounting: 2 lookups each, all cold
            assert_eq!((rep.sites[i].cache_hits,
                        rep.sites[i].cache_misses), (0, 2));
        }
        assert!(any_bwd_fallback,
                "probe θ at rate 0.3 must trigger backward fallback");
    }

    fn small_model(threads: usize) -> ModelStep {
        // 2 layers + head; vocab ≠ every other output dim so the head
        // exercises a genuinely different shape in the shared cache
        let mut cfg = ModelStepConfig::new(2, 32, 48, 80, 24, 16);
        cfg.glu = false;
        cfg.threads = threads;
        ModelStep::with_random_weights(cfg, 0x0D31)
    }

    #[test]
    fn model_step_shares_one_cache_across_layers_and_head() {
        let mut ms = small_model(2);
        let n_sites = ms.sites().len();
        assert_eq!(n_sites, 9);
        assert_eq!(ms.sites().last().unwrap().name, "lm_head");
        let (acts, grads) = synth_microbatch(ms.sites(), 17, 150.0);
        let (outs, r1) = ms.microstep(&acts, &grads);
        assert_eq!(outs.len(), n_sites);
        assert_eq!(r1.cache_misses as usize, 2 * n_sites);
        assert_eq!(r1.cache_hits, 0);
        assert_eq!(ms.cache().len(), 2 * n_sites,
                   "all sites resident in the one shared cache");
        let (_, r2) = ms.microstep(&acts, &grads);
        assert_eq!(r2.cache_misses, 0);
        assert_eq!(r2.cache_hits as usize, 2 * n_sites);
        // per-site accounting rolls up to per-layer hit rates of 1.0
        for (s, sr) in r2.sites.iter().enumerate() {
            assert_eq!((sr.cache_hits, sr.cache_misses), (2, 0),
                       "site {s}");
        }
        assert!(!ms.cache().stats().thrashing());
        // rates flow per-site into one controller at the step boundary
        ms.controller_mut().thresholds.fill(1e-3);
        ms.microstep(&acts, &grads);
        let applied = ms.end_step();
        assert_eq!(applied.len(), n_sites);
        assert!(ms.controller().n_up > 0);
    }

    #[test]
    fn split_microstep_matches_batch_microstep() {
        // The training loop feeds sites sequentially (forward in
        // site order, backward in reverse — layer l+1's activation
        // depends on layer l's output); the batch microstep sees all
        // tensors at once. Same tensors in → byte-identical outputs,
        // accounting, SR streams, and controller evolution: the SR
        // seed is derived from (microstep, site), never call order.
        let mut a = small_model(2);
        let mut b = small_model(2);
        let n = a.sites().len();
        for step in 0..3u64 {
            let (acts, grads) =
                synth_microbatch(a.sites(), 100 + step, 150.0);
            let ra = a.microstep_in_place(&acts, &grads);
            for s in 0..n {
                let y = b.forward_site(s, &acts[s]);
                assert_eq!(y.data, a.outputs()[s].y.data,
                           "fwd site {s} step {step}");
            }
            for s in (0..n).rev() {
                let dx = b.backward_site(s, &grads[s]);
                assert_eq!(dx.data, a.outputs()[s].dx.data,
                           "bwd site {s} step {step}");
            }
            let rb = b.finish_microstep();
            assert_eq!(ra.cache_hits, rb.cache_hits);
            assert_eq!(ra.cache_misses, rb.cache_misses);
            for s in 0..n {
                assert_eq!(a.outputs()[s].dw.data,
                           b.outputs()[s].dw.data,
                           "dw site {s} step {step}");
                assert_eq!(ra.sites[s].fallback_rate.to_bits(),
                           rb.sites[s].fallback_rate.to_bits());
                assert_eq!(ra.sites[s].bwd_fallback_rate.to_bits(),
                           rb.sites[s].bwd_fallback_rate.to_bits());
            }
            assert_eq!(a.end_step(), b.end_step());
            assert_eq!(a.controller().thresholds,
                       b.controller().thresholds);
        }
        assert_eq!(a.microsteps(), b.microsteps());
    }

    #[test]
    #[should_panic(expected = "backward_site without forward_site")]
    fn split_backward_without_forward_panics() {
        let mut ms = small_model(1);
        let (_, grads) = synth_microbatch(ms.sites(), 1, 150.0);
        ms.backward_site(0, &grads[0]);
    }

    #[test]
    #[should_panic(expected = "set_weight during a split microstep")]
    fn split_set_weight_mid_microstep_panics() {
        let mut ms = small_model(1);
        let (acts, _) = synth_microbatch(ms.sites(), 2, 150.0);
        ms.forward_site(0, &acts[0]);
        let (k, n) = (ms.sites()[0].k, ms.sites()[0].n);
        ms.set_weight(0, Mat::zeros(k, n));
    }

    #[test]
    fn model_step_set_weight_invalidates_only_that_site() {
        let mut ms = small_model(1);
        let n_sites = ms.sites().len();
        let (acts, grads) = synth_microbatch(ms.sites(), 19, 150.0);
        ms.microstep(&acts, &grads);
        // mutate the LM head weight (the multi-shape entry)
        let head = n_sites - 1;
        let (k, n) = (ms.sites()[head].k, ms.sites()[head].n);
        let mut rng = Pcg64::new(3);
        ms.set_weight(head, Mat::randn(k, n, 0.05, &mut rng));
        assert_eq!(ms.cache().len(), 2 * n_sites - 2);
        let (_, rep) = ms.microstep(&acts, &grads);
        assert_eq!(rep.cache_misses, 2, "only the head rebuilds");
        assert_eq!(rep.cache_hits as usize, 2 * (n_sites - 1));
    }

    #[test]
    fn warm_state_validates_fingerprint_and_prewarms() {
        let mut ms = small_model(1);
        let (acts, grads) = synth_microbatch(ms.sites(), 23, 150.0);
        ms.microstep(&acts, &grads);
        let state = ms.warm_state(None);
        // the serialized text is valid JSON and round-trips
        let parsed = Json::parse(&state.to_string()).unwrap();
        assert_eq!(parsed, state);
        // restore: cache prewarmed, so the very first microstep hits
        // on every lookup
        let (mut ms2, cal) = ModelStep::from_warm_state(
            ms.config().clone(), ms.weights.clone(), &parsed)
            .unwrap();
        assert!(cal.is_none());
        assert_eq!(ms2.microsteps(), 1, "counter rides the state");
        assert_eq!(ms2.cache().len(), 2 * ms.sites().len());
        let (_, rep) = ms2.microstep(&acts, &grads);
        assert_eq!(rep.cache_misses, 0,
                   "restored process must start at steady state");
        assert_eq!(rep.cache_hits as usize, 2 * ms.sites().len());
        // a different model's config must be rejected loudly
        let mut other = ms.config().clone();
        other.d_model = 64;
        let err = ModelStep::from_warm_state(
            other, ms.weights.clone(), &parsed)
            .unwrap_err();
        assert!(err.contains("fingerprint"), "{err}");
        // garbage input errors instead of panicking
        assert!(ModelStep::from_warm_state(
            ms.config().clone(), ms.weights.clone(), &Json::Null)
            .is_err());
    }

    #[test]
    fn warm_state_rejects_shard_count_mismatch() {
        // Satellite: a snapshot saved under one shard config must not
        // silently restore under another — the plan keys embed the
        // shard count, so every prewarmed entry would miss.
        let mut ms = small_model(1);
        let (acts, grads) = synth_microbatch(ms.sites(), 29, 150.0);
        ms.microstep(&acts, &grads);
        let state = ms.warm_state(None);
        let mut other = ms.config().clone();
        other.shards = ms.config().shards + 1;
        let err = ModelStep::from_warm_state(
            other, ms.weights.clone(), &state)
            .unwrap_err();
        assert!(err.contains("shard"), "{err}");
        // matching shard config restores fine (covered in depth by
        // warm_state_validates_fingerprint_and_prewarms); a pre-shard
        // file (no 'shards' field) restores only at shards = 1
        let mut cfg1 = ms.config().clone();
        cfg1.shards = 1;
        let mut legacy = ModelStep::new(cfg1.clone(),
                                        ms.weights.clone())
            .warm_state(None);
        if let Json::Obj(fields) = &mut legacy {
            if let Some(Json::Obj(cf)) = fields.get_mut("config") {
                cf.remove("shards");
            }
        }
        let restored = ModelStep::from_warm_state(
            cfg1, ms.weights.clone(), &legacy);
        assert!(restored.is_ok(),
                "missing 'shards' must default to 1: {:?}",
                restored.err());
    }

    #[test]
    fn theta_probe_pins_moderate_rates() {
        // Wiring check for the bench's probe pattern: pin each site's
        // θ from an offline metric sweep, then observe the executed
        // rate near the target.
        let mut ls = small_step(2);
        let (acts, grads) = synth_microbatch(ls.sites(), 11, 200.0);
        let thetas: Vec<f32> = acts
            .iter()
            .map(|x| {
                let probe = fallback_quant(x, f32::INFINITY, 16,
                                           INT8_LEVELS,
                                           Criterion::AbsMax);
                theta_for_rate(&probe.metric, 0.25)
            })
            .collect();
        ls.controller_mut().thresholds.copy_from_slice(&thetas);
        let (_, rep) = ls.microstep(&acts, &grads);
        for s in &rep.sites {
            assert!(s.fallback_rate < 0.8,
                    "site {} rate {}", s.name, s.fallback_rate);
        }
    }

    #[test]
    fn int4_microstep_matches_i64_oracles() {
        // The lattice path end-to-end: forward on the staged
        // Int4→Int8→f32 ladder, dX on pure nibble codes, dW on the
        // transposed ladder — each bit-identical to the exact i64
        // references in `gemm::int4` (bs = 16 is far inside
        // `I4_EXACT_MAX_BS`).
        use crate::gemm::{int4_gemm_reference, staged_gemm_reference};
        use crate::quant::staged_quant;
        for threads in [1usize, 2] {
            let mut cfg = LayerStepConfig::new(32, 48, 24, 16);
            cfg.glu = false;
            cfg.threads = threads;
            cfg.path = DataPath::Int4;
            let mut ls = LayerStep::with_random_weights(cfg, 0xD06);
            let theta = 2.0f32;
            ls.controller_mut().thresholds.fill(theta);
            let (acts, grads) = synth_microbatch(ls.sites(), 9, 200.0);
            let sr_base = ls.config().sr_seed;
            let (outs, rep) = ls.microstep(&acts, &grads);
            let mut any_promoted = false;
            for (i, l) in ls.sites().iter().enumerate() {
                let w = &ls.weights[i];
                let sx = staged_quant(&acts[i], theta, 16);
                let qw = block_quant(w, 16, INT4_LEVELS,
                                     Rounding::Nearest);
                let y = staged_gemm_reference(&sx, &qw);
                assert_eq!(outs[i].y.data, y.data,
                           "fwd {} t{threads}", l.name);
                // dY rides the (microstep, site)-seeded SR stream,
                // quantized at the lattice's nibble levels
                let qdy = block_quant(
                    &grads[i], 16, INT4_LEVELS,
                    Rounding::Stochastic(grad_sr_seed(sr_base, 0, i)));
                let qwt = block_quant(&w.transpose(), 16, INT4_LEVELS,
                                      Rounding::Nearest);
                let dx = int4_gemm_reference(&qdy, &qwt);
                assert_eq!(outs[i].dx.data, dx.data,
                           "dX {} t{threads}", l.name);
                // dW's Xᵀ operand is the transposed staged ladder
                let sxt = sx.transposed();
                let dw = staged_gemm_reference(&sxt, &qdy);
                assert_eq!(outs[i].dw.data, dw.data,
                           "dW {} t{threads}", l.name);
                // per-tier rates surface on the report
                assert_eq!(rep.sites[i].fallback_rate.to_bits(),
                           sx.rate_i8().to_bits(), "rate {}", l.name);
                assert_eq!(rep.sites[i].fallback_rate_f32.to_bits(),
                           sx.rate_f32().to_bits(),
                           "f32 rate {}", l.name);
                assert_eq!(rep.sites[i].bwd_fallback_rate.to_bits(),
                           sxt.rate_i8().to_bits(),
                           "bwd rate {}", l.name);
                any_promoted |= sx.rate_i8() > 0.0;
            }
            assert!(any_promoted,
                    "outlier batch must promote some blocks past Int4");
        }
    }

    #[test]
    fn telemetry_attaches_outlier_histograms() {
        let mut cfg = LayerStepConfig::new(32, 48, 24, 16);
        cfg.glu = false;
        cfg.telemetry = true;
        let mut ls = LayerStep::with_random_weights(cfg, 0xD06);
        let (acts, grads) = synth_microbatch(ls.sites(), 7, 150.0);
        let (_, rep) = ls.microstep(&acts, &grads);
        for (i, s) in rep.sites.iter().enumerate() {
            let h = s.outlier_hist.as_ref()
                .expect("telemetry on => histogram attached");
            assert_eq!(h.len(), OUTLIER_HIST_BINS);
            // one count per activation block, whatever the tier
            let blocks = fallback_quant(&acts[i], f32::INFINITY, 16,
                                        INT8_LEVELS, Criterion::AbsMax)
                .metric
                .len();
            assert_eq!(h.iter().sum::<u64>() as usize, blocks,
                       "site {i}");
        }
        // off by default: the reports carry no histograms
        let mut off = small_step(1);
        let (acts, grads) = synth_microbatch(off.sites(), 7, 150.0);
        let (_, rep) = off.microstep(&acts, &grads);
        assert!(rep.sites.iter().all(|s| s.outlier_hist.is_none()));
        // binning anchors: pure f32-exponent bins, bit-deterministic
        let h = metric_histogram(&[0.0, 0.5, 1.0, 3.0, 1e30]);
        assert_eq!(h.iter().sum::<u64>(), 5);
        assert_eq!((h[0], h[7], h[8], h[9], h[15]), (1, 1, 1, 1, 1));
    }

    #[test]
    fn glu_model_runs_five_sites_per_layer() {
        let mut cfg = ModelStepConfig::new(2, 32, 48, 80, 24, 16);
        cfg.glu = true;
        assert_eq!(cfg.n_sites(), 11);
        let mut ms = ModelStep::with_random_weights(cfg, 0x610);
        let names: Vec<&str> =
            ms.sites().iter().map(|l| l.name).collect();
        assert_eq!(&names[..5],
                   &["qkv", "attn_out", "mlp_gate", "mlp_up",
                     "mlp_down"]);
        assert_eq!(names[10], "lm_head");
        let (acts, grads) = synth_microbatch(ms.sites(), 31, 150.0);
        let (outs, rep) = ms.microstep(&acts, &grads);
        assert_eq!(outs.len(), 11);
        assert_eq!(rep.cache_misses as usize, 2 * 11);
        assert_eq!(ms.cache().len(), 2 * 11,
                   "gate and up share a shape but not a weight id");
        // warm state round-trips the 5-site fingerprint and prewarms
        let state = ms.warm_state(None);
        let (mut ms2, _) = ModelStep::from_warm_state(
            ms.config().clone(), ms.weights.clone(), &state)
            .unwrap();
        let (_, r2) = ms2.microstep(&acts, &grads);
        assert_eq!(r2.cache_misses, 0);
        assert_eq!(r2.cache_hits as usize, 2 * 11);
        // a plain-MLP config must not restore a GLU snapshot
        let mut plain = ms.config().clone();
        plain.glu = false;
        let err = ModelStep::from_warm_state(
            plain,
            ms.weights[..9].to_vec(),
            &state)
            .unwrap_err();
        assert!(err.contains("fingerprint"), "{err}");
    }

    #[test]
    fn warm_state_rejects_format_mismatch_and_pre_lattice_files() {
        // Satellite: the precision-format record. A snapshot from a
        // different rung of the lattice, an unknown tag, or a
        // pre-lattice file (no record / old version) must all fail
        // loudly instead of silently restoring onto the wrong path.
        let mut ms = small_model(1);
        let (acts, grads) = synth_microbatch(ms.sites(), 37, 150.0);
        ms.microstep(&acts, &grads);
        let state = ms.warm_state(None);
        let cfg = ms.config().clone();
        let restore = |st: &Json| {
            ModelStep::from_warm_state(cfg.clone(),
                                       ms.weights.clone(), st)
        };
        // recorded under a different precision format
        let other = if cfg.path == DataPath::Int4 { "int8" }
                    else { "int4" };
        let mut wrong = state.clone();
        if let Json::Obj(f) = &mut wrong {
            f.insert("format".into(), Json::Str(other.into()));
        }
        let err = restore(&wrong).unwrap_err();
        assert!(err.contains("precision format")
                && err.contains("PALLAS_PATH"), "{err}");
        // an unrecognized tag is a corrupt file, not a default
        let mut junk = state.clone();
        if let Json::Obj(f) = &mut junk {
            f.insert("format".into(), Json::Str("int2".into()));
        }
        let err = restore(&junk).unwrap_err();
        assert!(err.contains("unknown precision format"), "{err}");
        // pre-lattice snapshot: the record is missing entirely
        let mut missing = state.clone();
        if let Json::Obj(f) = &mut missing {
            f.remove("format");
        }
        let err = restore(&missing).unwrap_err();
        assert!(err.contains("pre-lattice"), "{err}");
        // pre-lattice snapshot: old version number
        let mut old = state.clone();
        if let Json::Obj(f) = &mut old {
            f.insert("version".into(), Json::Num(1.0));
        }
        let err = restore(&old).unwrap_err();
        assert!(err.contains("pre-lattice"), "{err}");
        // the untouched snapshot still restores
        assert!(restore(&state).is_ok());
    }
}
