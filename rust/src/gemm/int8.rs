//! INT8 block-quantized GEMM (paper Eq. 1) and fallback GEMM
//! (Algorithm 1) on the CPU substrate.
//!
//! Semantics match the L1 Pallas kernel exactly: int8 codes multiply
//! into an **int32 accumulator inside a block** (the TensorCore/MXU
//! path), and blocks are combined with per-block scale products in a
//! **f32 accumulator across K** (the paper's FP32 accumulator).
//!
//! Unlike the JAX graph (static shapes force masked residuals), this
//! implementation *really* skips non-fallback residual blocks — the
//! conditional work the paper's kernel performs — so its measured
//! throughput exhibits the true cost structure: dequant overhead
//! ∝ 1/block-size (Fig 1b) and fallback overhead ∝ fallback rate
//! (Fig 8c).
//!
//! `block_gemm` / `fallback_gemm` are thin wrappers over the
//! plan/execute engine (`gemm::engine`); the pre-engine kernels are
//! retained verbatim as [`block_gemm_baseline`] /
//! [`fallback_gemm_baseline`] — the before/after comparison points of
//! `benches/gemm_engine.rs` and the bit-identity oracles of
//! `tests/engine_prop.rs`.

use crate::gemm::engine::{DataPath, GemmPlan};
use crate::quant::{BlockQuant, FallbackQuant};
use crate::util::threadpool::parallel_chunks;
use crate::util::Mat;

/// Convert int8 codes to f32 once per GEMM call (baseline path only;
/// the engine uses the cached views on the quant structs). Products and
/// in-block sums of int8 codes stay below 2^24, so the f32 inner kernel
/// is *bit-exact* to int32 accumulation while vectorizing an order of
/// magnitude better on CPUs without int8 dot ISA.
fn codes_to_f32(q: &[i8]) -> Vec<f32> {
    q.iter().map(|&v| v as f32).collect()
}

/// inner f32 panel: acc[j] = sum_k a[r, k0+k] * b[k0+k, c0+j], under
/// the v2 f32 op-order contract (per-lane sequential FMA over
/// ascending K — see `gemm::kernels`). All inputs here are integer
/// codes whose block dots stay below 2²⁴, where FMA order is
/// irrelevant, so this is bit-identical to the v1 seed order *and*
/// vectorizes — the bridge test below pins that.
#[inline]
#[allow(clippy::too_many_arguments)]
fn block_row_dot_f32(
    af: &[f32], a_stride: usize, r: usize, k0: usize, bs: usize,
    bf: &[f32], b_stride: usize, c0: usize, width: usize,
    acc: &mut [f32],
) {
    let acc = &mut acc[..width];
    acc.fill(0.0);
    let arow = &af[r * a_stride + k0..r * a_stride + k0 + bs];
    let kk = bs & !3;
    for k in (0..kk).step_by(4) {
        crate::gemm::kernels::fma4_into(
            [arow[k], arow[k + 1], arow[k + 2], arow[k + 3]],
            &bf[(k0 + k) * b_stride + c0..][..width],
            &bf[(k0 + k + 1) * b_stride + c0..][..width],
            &bf[(k0 + k + 2) * b_stride + c0..][..width],
            &bf[(k0 + k + 3) * b_stride + c0..][..width],
            acc,
        );
    }
    for k in kk..bs {
        crate::gemm::kernels::fma1_into(
            arow[k], &bf[(k0 + k) * b_stride + c0..][..width], acc,
        );
    }
}

/// C = deq(A) * deq(B) with per-block INT8 codes (paper Eq. 1).
/// `a` blocks are (M x K), `b` blocks are (K x N); both must share the
/// same block size. Plans and executes through the engine on the
/// default data path (true i8 within the exactness bound); output is
/// bit-identical to [`block_gemm_baseline`] for every thread count.
pub fn block_gemm(a: &BlockQuant, b: &BlockQuant, threads: usize) -> Mat {
    GemmPlan::new_int8(a, b, threads).execute()
}

/// [`block_gemm`] on an explicit [`DataPath`] (SimF32 keeps the f32
/// simulation; Int8 forces the i8 operands + i32 kernels).
pub fn block_gemm_path(a: &BlockQuant, b: &BlockQuant, threads: usize,
                       path: DataPath) -> Mat {
    GemmPlan::new_int8_path(a, b, threads, path).execute()
}

/// Retained seed implementation (pre-engine): per-call code conversion,
/// strided B access, contiguous row-panel chunking, raw-pointer output
/// rows. Kept as the honest baseline the engine is measured against —
/// do not "improve" it.
pub fn block_gemm_baseline(a: &BlockQuant, b: &BlockQuant,
                           threads: usize) -> Mat {
    assert_eq!(a.cols, b.rows, "inner dims");
    assert_eq!(a.block, b.block, "block size");
    let bs = a.block;
    let (m, n) = (a.rows, b.cols);
    let (kb, nbk) = (a.cb(), b.cb());
    let mut c = Mat::zeros(m, n);
    let cptr = std::sync::atomic::AtomicPtr::new(c.data.as_mut_ptr());
    let af = codes_to_f32(&a.q);
    let bf = codes_to_f32(&b.q);

    parallel_chunks(a.rb(), threads, |p0, p1| {
        let craw = cptr.load(std::sync::atomic::Ordering::Relaxed);
        let mut acc = vec![0.0f32; bs];
        for bi in p0..p1 {
            let r_lo = bi * bs;
            let r_hi = ((bi + 1) * bs).min(m);
            for bj in 0..nbk {
                let c_lo = bj * bs;
                let c_hi = ((bj + 1) * bs).min(n);
                let width = c_hi - c_lo;
                for r in r_lo..r_hi {
                    // SAFETY: threads own disjoint row panels of C.
                    let crow = unsafe {
                        std::slice::from_raw_parts_mut(
                            craw.add(r * n + c_lo), width)
                    };
                    for bk in 0..kb {
                        let sa = a.scale[bi * kb + bk];
                        let sb = b.scale[bk * nbk + bj];
                        block_row_dot_f32(
                            &af, a.pcols, r, bk * bs, bs,
                            &bf, b.pcols, c_lo, width, &mut acc,
                        );
                        let w = sa * sb;
                        for (cv, &v) in crow.iter_mut()
                            .zip(acc[..width].iter())
                        {
                            *cv += v * w;
                        }
                    }
                }
            }
        }
    });
    c
}

/// inner i8 x i8 -> i32 panel: acc[j] = sum_k qa[r, k0+k] * qb[k0+k, c0+j]
/// (exact-int32 reference semantics; the hot path uses the bit-equal
/// f32 kernel above — kept for tests/documentation)
#[allow(dead_code)]
#[inline]
fn accumulate_block_row(
    qa: &[i8], a_stride: usize, r: usize, k0: usize, bs: usize,
    qb: &[i8], b_stride: usize, c0: usize, width: usize,
    acc: &mut [i32],
) {
    acc.fill(0);
    let arow = &qa[r * a_stride + k0..r * a_stride + k0 + bs];
    for (k, &av) in arow.iter().enumerate() {
        if av == 0 {
            continue; // padding rows/zero codes contribute nothing
        }
        let av = av as i32;
        let brow = &qb[(k0 + k) * b_stride + c0
                       ..(k0 + k) * b_stride + c0 + width];
        for (j, &bv) in brow.iter().enumerate() {
            acc[j] += av * bv as i32;
        }
    }
}

/// How fallback A-blocks are laid out — the scheduling scenarios of
/// Fig 8c ("random versus sequential block selection (worst case)").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// whatever the data produced (threshold decisions)
    Natural,
    /// uniformly shuffled u-mask at the same rate
    Random(u64),
    /// fallback blocks packed into the leading block rows (worst-case
    /// load imbalance: some C panels do 2x work)
    Sequential,
}

/// Remap the u-mask of `fq` according to the placement scenario,
/// preserving the overall fallback rate.
pub fn remap_placement(fq: &FallbackQuant, placement: Placement) -> Vec<bool> {
    let n = fq.u.len();
    let count = fq.u.iter().filter(|&&b| b).count();
    match placement {
        Placement::Natural => fq.u.clone(),
        Placement::Random(seed) => {
            let mut rng = crate::util::rng::Pcg64::new(seed);
            let mut u = vec![false; n];
            for i in rng.sample_indices(n, count) {
                u[i] = true;
            }
            u
        }
        Placement::Sequential => {
            let mut u = vec![false; n];
            for x in u.iter_mut().take(count) {
                *x = true;
            }
            u
        }
    }
}

/// Mixed-precision fallback GEMM (Algorithm 1): residual blocks of A are
/// loaded and multiplied **only when u(i,k) = 1**. Plans and executes
/// through the engine (fallback-aware scheduling); output is
/// bit-identical to [`fallback_gemm_baseline`] for every thread count
/// and placement.
pub fn fallback_gemm(fa: &FallbackQuant, b: &BlockQuant, u: &[bool],
                     threads: usize) -> Mat {
    GemmPlan::new_fallback(fa, b, u, threads).execute()
}

/// [`fallback_gemm`] on an explicit [`DataPath`].
pub fn fallback_gemm_path(fa: &FallbackQuant, b: &BlockQuant,
                          u: &[bool], threads: usize, path: DataPath)
                          -> Mat {
    GemmPlan::new_fallback_path(fa, b, u, threads, path).execute()
}

/// Retained seed implementation (pre-engine) of the fallback GEMM; see
/// [`block_gemm_baseline`]. Row panels are chunked contiguously, so
/// Sequential placement concentrates the residual work on the first
/// worker — the imbalance the engine's weighted schedule removes.
pub fn fallback_gemm_baseline(fa: &FallbackQuant, b: &BlockQuant,
                              u: &[bool], threads: usize) -> Mat {
    let a = &fa.base;
    assert_eq!(a.cols, b.rows);
    assert_eq!(a.block, b.block);
    assert_eq!(u.len(), a.rb() * a.cb());
    let bs = a.block;
    let (m, n) = (a.rows, b.cols);
    let (kb, nbk) = (a.cb(), b.cb());
    let mut c = Mat::zeros(m, n);
    let cptr = std::sync::atomic::AtomicPtr::new(c.data.as_mut_ptr());
    let af = codes_to_f32(&a.q);
    let rf = codes_to_f32(&fa.rq);
    let bf = codes_to_f32(&b.q);

    parallel_chunks(a.rb(), threads, |p0, p1| {
        let craw = cptr.load(std::sync::atomic::Ordering::Relaxed);
        let mut acc = vec![0.0f32; bs];
        for bi in p0..p1 {
            let r_lo = bi * bs;
            let r_hi = ((bi + 1) * bs).min(m);
            for bj in 0..nbk {
                let c_lo = bj * bs;
                let c_hi = ((bj + 1) * bs).min(n);
                let width = c_hi - c_lo;
                for r in r_lo..r_hi {
                    let crow = unsafe {
                        std::slice::from_raw_parts_mut(
                            craw.add(r * n + c_lo), width)
                    };
                    for bk in 0..kb {
                        let sa = a.scale[bi * kb + bk];
                        let sb = b.scale[bk * nbk + bj];
                        block_row_dot_f32(
                            &af, a.pcols, r, bk * bs, bs,
                            &bf, b.pcols, c_lo, width, &mut acc,
                        );
                        let w = sa * sb;
                        for (cv, &v) in
                            crow.iter_mut().zip(acc[..width].iter())
                        {
                            *cv += v * w;
                        }
                        // Algorithm 1 lines 13-16: conditional residual —
                        // really skipped when u = 0 (the measured cost of
                        // fallback is proportional to the rate).
                        if u[bi * kb + bk] {
                            let rs = fa.rscale[bi * kb + bk];
                            block_row_dot_f32(
                                &rf, a.pcols, r, bk * bs, bs,
                                &bf, b.pcols, c_lo, width, &mut acc,
                            );
                            let rw = rs * sb;
                            for (cv, &v) in
                                crow.iter_mut().zip(acc[..width].iter())
                            {
                                *cv += v * rw;
                            }
                        }
                    }
                }
            }
        }
    });
    c
}

/// Reference implementation through dequantized f32 matmul + per-block
/// int math — used by tests to pin down the exact semantics.
pub fn block_gemm_reference(a: &BlockQuant, b: &BlockQuant) -> Mat {
    let bs = a.block;
    let (m, n) = (a.rows, b.cols);
    let kb = a.cb();
    let nbk = b.cb();
    let mut c = Mat::zeros(m, n);
    for r in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for bk in 0..kb {
                let mut i32acc = 0i64;
                for k in bk * bs..((bk + 1) * bs).min(a.cols) {
                    i32acc += a.q[r * a.pcols + k] as i64
                        * b.q[k * b.pcols + j] as i64;
                }
                acc += i32acc as f32
                    * (a.scale[(r / bs) * kb + bk]
                       * b.scale[bk * nbk + j / bs]);
            }
            c.data[r * n + j] = acc;
        }
    }
    c
}

/// Exact-integer reference for the fallback GEMM (Algorithm 1): i64
/// block dots widened once per K-block, then the same per-block
/// scale-FMA order as the engine (base add, then conditional residual
/// add). Bit-identical to the engine — on either data path — and to
/// [`fallback_gemm_baseline`] whenever the block size is within
/// `engine::I8_EXACT_MAX_BS`.
pub fn fallback_gemm_reference(fa: &FallbackQuant, b: &BlockQuant,
                               u: &[bool]) -> Mat {
    let a = &fa.base;
    let bs = a.block;
    let (m, n) = (a.rows, b.cols);
    let kb = a.cb();
    let nbk = b.cb();
    let mut c = Mat::zeros(m, n);
    for r in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for bk in 0..kb {
                let mut base_i = 0i64;
                let mut res_i = 0i64;
                for k in bk * bs..((bk + 1) * bs).min(a.cols) {
                    let bq = b.q[k * b.pcols + j] as i64;
                    base_i += a.q[r * a.pcols + k] as i64 * bq;
                    res_i += fa.rq[r * a.pcols + k] as i64 * bq;
                }
                let bi = (r / bs) * kb + bk;
                let sb = b.scale[bk * nbk + j / bs];
                acc += base_i as f32 * (a.scale[bi] * sb);
                if u[bi] {
                    acc += res_i as f32 * (fa.rscale[bi] * sb);
                }
            }
            c.data[r * n + j] = acc;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{block_quant, fallback_quant, Criterion, Rounding,
                       INT8_LEVELS};
    use crate::quant::metrics::rel_err;
    use crate::util::rng::Pcg64;
    use crate::util::testing::max_abs_diff;
    use crate::util::Mat;

    fn mats(m: usize, k: usize, n: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Pcg64::new(seed);
        (Mat::randn(m, k, 1.0, &mut rng), Mat::randn(k, n, 1.0, &mut rng))
    }

    #[test]
    fn matches_reference_impl() {
        for (m, k, n) in [(16, 16, 16), (32, 48, 16), (40, 33, 25)] {
            let (a, b) = mats(m, k, n, 42 + m as u64);
            let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
            let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
            let c1 = block_gemm(&qa, &qb, 1);
            let c2 = block_gemm_reference(&qa, &qb);
            assert!(max_abs_diff(&c1.data, &c2.data) < 1e-3);
        }
    }

    #[test]
    fn approximates_exact_gemm() {
        let (a, b) = mats(64, 64, 64, 7);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        let c = block_gemm(&qa, &qb, 1);
        let exact = crate::gemm::dense::matmul(&a, &b, 1);
        assert!(rel_err(&c.data, &exact.data) < 0.02);
    }

    #[test]
    fn parallel_matches_serial() {
        let (a, b) = mats(64, 48, 32, 9);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        assert_eq!(block_gemm(&qa, &qb, 1).data,
                   block_gemm(&qa, &qb, 4).data);
    }

    #[test]
    fn wrapper_bit_identical_to_baseline() {
        let (a, b) = mats(40, 33, 25, 21);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        for threads in [1, 2, 4] {
            assert_eq!(block_gemm(&qa, &qb, threads).data,
                       block_gemm_baseline(&qa, &qb, threads).data,
                       "threads={threads}");
        }
    }

    #[test]
    fn fallback_wrapper_bit_identical_to_baseline() {
        let mut rng = Pcg64::new(23);
        let mut a = Mat::randn(48, 48, 1.0, &mut rng);
        for _ in 0..8 {
            let i = rng.below(a.data.len());
            a.data[i] = 200.0;
        }
        let b = Mat::randn(48, 33, 1.0, &mut rng);
        let fa = fallback_quant(&a, 30.0, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        for placement in [Placement::Natural, Placement::Random(5),
                          Placement::Sequential] {
            let u = remap_placement(&fa, placement);
            for threads in [1, 2, 4] {
                assert_eq!(
                    fallback_gemm(&fa, &qb, &u, threads).data,
                    fallback_gemm_baseline(&fa, &qb, &u, threads).data,
                    "{placement:?} threads={threads}"
                );
            }
        }
    }

    /// The v1 (seed) inner panel, retained verbatim as the bridge
    /// oracle for the v2 re-anchor.
    #[allow(clippy::too_many_arguments)]
    fn block_row_dot_f32_v1(
        af: &[f32], a_stride: usize, r: usize, k0: usize, bs: usize,
        bf: &[f32], b_stride: usize, c0: usize, width: usize,
        acc: &mut [f32],
    ) {
        acc[..width].fill(0.0);
        let arow = &af[r * a_stride + k0..r * a_stride + k0 + bs];
        let kk = bs & !3;
        for k in (0..kk).step_by(4) {
            let a0 = arow[k];
            let a1 = arow[k + 1];
            let a2 = arow[k + 2];
            let a3 = arow[k + 3];
            let b0 = &bf[(k0 + k) * b_stride + c0..][..width];
            let b1 = &bf[(k0 + k + 1) * b_stride + c0..][..width];
            let b2 = &bf[(k0 + k + 2) * b_stride + c0..][..width];
            let b3 = &bf[(k0 + k + 3) * b_stride + c0..][..width];
            for j in 0..width {
                acc[j] +=
                    a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
        }
        for k in kk..bs {
            let av = arow[k];
            if av == 0.0 {
                continue;
            }
            let brow = &bf[(k0 + k) * b_stride + c0..][..width];
            for j in 0..width {
                acc[j] += av * brow[j];
            }
        }
    }

    #[test]
    fn v2_order_bit_identical_to_v1_on_integer_codes() {
        // On the quantized paths every operand is an integer code and
        // every partial sum stays below 2²⁴, so the v2 re-anchor must
        // not move a single bit relative to the seed order — the
        // strongest possible bridge statement for this file.
        let mut rng = Pcg64::new(0x1B);
        for &(bs, width, c0) in
            &[(16usize, 16usize, 0usize), (17, 9, 16), (64, 16, 32)]
        {
            let b_stride = c0 + width + 3;
            let af: Vec<f32> = (0..2 * bs)
                .map(|_| ((rng.uniform() * 255.0) as i32 - 127)
                     .clamp(-127, 127) as f32)
                .collect();
            let bf: Vec<f32> = (0..bs * b_stride)
                .map(|_| ((rng.uniform() * 255.0) as i32 - 127)
                     .clamp(-127, 127) as f32)
                .collect();
            let mut v2 = vec![0.0f32; bs];
            let mut v1 = vec![0.0f32; bs];
            block_row_dot_f32(&af, bs, 1, 0, bs, &bf, b_stride, c0,
                              width, &mut v2);
            block_row_dot_f32_v1(&af, bs, 1, 0, bs, &bf, b_stride, c0,
                                 width, &mut v1);
            assert_eq!(&v2[..width], &v1[..width],
                       "bs={bs} width={width} c0={c0}");
        }
    }

    #[test]
    fn references_bit_identical_to_engine() {
        // With bs ≤ I8_EXACT_MAX_BS the exact-i64 oracles, the seed
        // baselines, and both engine data paths all agree bitwise.
        let (a, b) = mats(40, 33, 25, 77);
        let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        let c_ref = block_gemm_reference(&qa, &qb);
        for path in [DataPath::SimF32, DataPath::Int8] {
            assert_eq!(block_gemm_path(&qa, &qb, 2, path).data,
                       c_ref.data, "{path:?}");
        }
        let mut rng = Pcg64::new(78);
        let mut af = Mat::randn(48, 48, 1.0, &mut rng);
        for _ in 0..8 {
            let i = rng.below(af.data.len());
            af.data[i] = 220.0;
        }
        let bf = Mat::randn(48, 17, 1.0, &mut rng);
        let fa = fallback_quant(&af, 30.0, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        let qbf = block_quant(&bf, 16, INT8_LEVELS, Rounding::Nearest);
        let f_ref = fallback_gemm_reference(&fa, &qbf, &fa.u);
        for path in [DataPath::SimF32, DataPath::Int8] {
            assert_eq!(fallback_gemm_path(&fa, &qbf, &fa.u, 2, path)
                           .data,
                       f_ref.data, "{path:?}");
        }
        assert_eq!(fallback_gemm_baseline(&fa, &qbf, &fa.u, 1).data,
                   f_ref.data);
    }

    #[test]
    fn fallback_gemm_more_accurate() {
        let mut rng = Pcg64::new(11);
        let mut a = Mat::randn(64, 64, 1.0, &mut rng);
        for _ in 0..10 {
            let i = rng.below(a.data.len());
            a.data[i] = 250.0;
        }
        let b = Mat::randn(64, 48, 1.0, &mut rng);
        let exact = crate::gemm::dense::matmul(&a, &b, 1);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        let fa = fallback_quant(&a, -1.0, 16, INT8_LEVELS, Criterion::AbsMax);
        let c_fb = fallback_gemm(&fa, &qb, &fa.u, 1);
        let c_plain = block_gemm(&fa.base, &qb, 1);
        let e_fb = rel_err(&c_fb.data, &exact.data);
        let e_plain = rel_err(&c_plain.data, &exact.data);
        assert!(e_fb < e_plain * 0.5, "fb {e_fb} plain {e_plain}");
    }

    #[test]
    fn fallback_with_no_u_equals_block_gemm() {
        let (a, b) = mats(48, 32, 32, 13);
        let fa = fallback_quant(&a, f32::INFINITY, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
        let c1 = fallback_gemm(&fa, &qb, &fa.u, 1);
        let c2 = block_gemm(&fa.base, &qb, 1);
        assert_eq!(c1.data, c2.data);
    }

    #[test]
    fn placement_preserves_rate() {
        let mut rng = Pcg64::new(17);
        let mut a = Mat::randn(128, 128, 1.0, &mut rng);
        for _ in 0..20 {
            let i = rng.below(a.data.len());
            a.data[i] = 300.0;
        }
        let fa = fallback_quant(&a, 50.0, 16, INT8_LEVELS,
                                Criterion::AbsMax);
        let count = fa.u.iter().filter(|&&x| x).count();
        for p in [Placement::Random(3), Placement::Sequential] {
            let u = remap_placement(&fa, p);
            assert_eq!(u.iter().filter(|&&x| x).count(), count);
        }
    }

    #[test]
    fn prop_block_gemm_matches_reference() {
        crate::util::testing::forall("gemm-vs-ref", 15, |g| {
            let m = 16 * g.usize_in(1, 2);
            let k = 16 * g.usize_in(1, 3);
            let n = 16 * g.usize_in(1, 2);
            let a = Mat::from_vec(m, k, g.vec_outliers(m * k, 1.0, 4, 80.0));
            let b = Mat::from_vec(k, n, g.vec_normal(k * n, 1.0));
            let qa = block_quant(&a, 16, INT8_LEVELS, Rounding::Nearest);
            let qb = block_quant(&b, 16, INT8_LEVELS, Rounding::Nearest);
            let c1 = block_gemm(&qa, &qb, 2);
            let c2 = block_gemm_reference(&qa, &qb);
            let d = max_abs_diff(&c1.data, &c2.data);
            crate::prop_assert!(d < 1e-2, "diff {d} at ({m},{k},{n})");
            Ok(())
        });
    }
}
