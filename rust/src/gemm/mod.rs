//! CPU blocked-GEMM substrate: f32 reference, INT8 block GEMM (Eq. 1),
//! and the fallback GEMM (Algorithm 1) with real conditional skipping.
//!
//! All three precisions now run through the unified plan/execute
//! engine in [`engine`] (packed operands, reusable workspaces,
//! fallback-aware scheduling); the historical free functions remain as
//! thin wrappers, and the pre-engine kernels are retained as
//! `*_baseline` oracles/benchmark baselines. The int8 modes default to
//! the true i8 data path ([`DataPath::Int8`]: i8 panel packs, i32
//! block accumulation — bit-identical to the f32 simulation for all
//! paper block sizes); `*_path` wrappers expose the knob. The
//! precision lattice adds an opt-in [`DataPath::Int4`] bottom rung
//! (nibble panels, `dot*_i4` kernels) with a staged per-block
//! Int4→Int8→f32 fallback ladder ([`GemmPlan::new_staged`] over
//! `quant::staged_quant`), exact against the i64 references in
//! [`int4`] within [`I4_EXACT_MAX_BS`].
//!
//! ## Microkernel backends
//!
//! The engine's inner loops live in [`kernels`] behind a
//! [`Kernels`] vtable — the CPU stand-in for the int8-dot tensor-core
//! units the paper's 1.57x speedup rides on. Backends: `scalar`
//! (portable floor, the seed's 4-unrolled loops), `sse2` / `avx2`
//! (x86_64, exact i16-pair multiplies widened to i32), `avx512vnni`
//! (x86_64, `VPDPBUSD` dword dot tiles with the unsigned-A offset
//! trick), and `neon` (aarch64 `vmlal_s16`). Selection happens once
//! per plan build: `PALLAS_KERNEL=scalar|sse2|avx2|avx512vnni|neon`
//! env override → the backend calibration measured fastest
//! (`SubstrateCalibration::install_fastest_backend`) → the fastest
//! detected one. Integer accumulation makes every backend
//! bit-identical to the scalar floor, the f32 simulation, the
//! `*_baseline` oracles, and the exact i64 references for
//! `bs ≤ I8_EXACT_MAX_BS` — `tests/engine_prop.rs` asserts this per
//! backend. The f32 kernels follow the v2 op-order contract
//! (per-lane sequential FMA, vectorized AVX2/NEON with a bit-equal
//! scalar floor — see `kernels`). To add a backend (AMX next), follow
//! the recipe in `docs/ARCHITECTURE.md` § "Adding a kernel backend":
//! implement the three `DotI8` row tiles, register the static in
//! `available()`, and the test/bench sweeps pick it up automatically.
//!
//! ## Layer-step pipeline
//!
//! [`pipeline`] lifts the engine from one GEMM to one *training
//! step*: a [`PlanCache`] owns the cacheable weight halves
//! ([`WeightPlan`]: quantized weights + packed panels + pinned
//! backend) across steps, and [`LayerStep`] drives the four linear
//! sites of a transformer layer (fwd + both bwd GEMMs each) against
//! them, re-quantizing only the activation/gradient side per
//! microstep (dY with unbiased stochastic rounding, dW's Xᵀ on the
//! fallback path at the site's θ) and feeding executed fallback
//! rates back into the Algorithm 2 threshold controller.
//! [`ModelStep`] scales that to N layers + LM head sharing one
//! cache, with JSON warm-state persistence so a fresh process starts
//! at steady-state hit rate. `benches/layer_step.rs` and
//! `benches/model_step.rs` track the cached / cold / warm-restored
//! gains.
//!
//! ## Sharded execution
//!
//! Plans can additionally slice their packed column panels into S
//! contiguous shards (`PALLAS_SHARDS`, or
//! [`WeightPlan::with_shards`] / `GemmPlan::with_shards`): LPT
//! scheduling runs per shard over the shared row-chunk costs, each
//! shard's buckets are hinted onto a stable subset of pool workers
//! (locality only — correctness never depends on placement), and
//! because every shard owns a disjoint column range of C the output
//! is bitwise identical to the flat engine for every
//! S × thread-count × backend combination (`tests/shard_prop.rs`
//! sweeps this). See `docs/ARCHITECTURE.md` § "Sharded execution".
//!
//! These kernels give *measured* cost structure on this testbed (group
//! size vs dequant overhead, fallback rate vs extra work, placement vs
//! load balance); `costmodel` projects the same structure onto the
//! paper's GPUs. The full architecture tour (plan lifecycle, data
//! paths, backend vtable, plan cache) lives in `docs/ARCHITECTURE.md`.

pub mod dense;
pub mod engine;
pub mod int4;
pub mod int8;
pub mod kernels;
pub mod pipeline;

pub use dense::{matmul, matmul_baseline, matmul_naive};
pub use engine::{default_path, env_path, parse_path_override,
                 DataPath, GemmPlan, Precision, WeightPlan,
                 I4_EXACT_MAX_BS, I8_EXACT_MAX_BS};
pub use kernels::{cpu_features, Kernels};
pub use int4::{int4_gemm_reference, staged_gemm_reference};
pub use int8::{block_gemm, block_gemm_baseline, block_gemm_path,
               block_gemm_reference, fallback_gemm,
               fallback_gemm_baseline, fallback_gemm_path,
               fallback_gemm_reference, remap_placement, Placement};
pub use pipeline::{grad_sr_seed, layer_sr_seed, metric_histogram,
                   site_reference, synth_microbatch, CacheStats,
                   LayerStep, LayerStepConfig, ModelStep,
                   ModelStepConfig, PlanCache, PlanKey, SiteOutputs,
                   SiteReport, StepReport, GRAD_SR_SEED,
                   OUTLIER_HIST_BINS};

use crate::quant::{block_quant, fallback_quant, Criterion, Rounding,
                   INT8_LEVELS};
use crate::util::Mat;

/// One-call quantized matmul (both operands RTN INT8, shared block
/// size). Quantizes per call — for repeated GEMMs over stable
/// operands build a [`GemmPlan`] (or cache a [`WeightPlan`]) instead.
///
/// ```
/// use dbfq::gemm::{matmul, quantized_matmul};
/// use dbfq::util::rng::Pcg64;
/// use dbfq::util::Mat;
///
/// let mut rng = Pcg64::new(7);
/// let a = Mat::randn(32, 48, 1.0, &mut rng);
/// let b = Mat::randn(48, 24, 1.0, &mut rng);
/// let c = quantized_matmul(&a, &b, 16, 2);
/// assert_eq!((c.rows, c.cols), (32, 24));
/// // per-block INT8 stays close to the exact product
/// let exact = matmul(&a, &b, 2);
/// let err = dbfq::quant::metrics::rel_err(&c.data, &exact.data);
/// assert!(err < 0.05, "rel err {err}");
/// ```
pub fn quantized_matmul(a: &Mat, b: &Mat, block: usize,
                        threads: usize) -> Mat {
    let qa = block_quant(a, block, INT8_LEVELS, Rounding::Nearest);
    let qb = block_quant(b, block, INT8_LEVELS, Rounding::Nearest);
    block_gemm(&qa, &qb, threads)
}

/// One-call fallback matmul; returns (C, fallback_rate). The A
/// operand gets the two-level representation of paper §4.3 wherever
/// its block metric exceeds `theta` (Algorithm 1 skips the residual
/// work elsewhere).
///
/// ```
/// use dbfq::gemm::{fallback_matmul, matmul, quantized_matmul};
/// use dbfq::util::rng::Pcg64;
/// use dbfq::util::Mat;
///
/// let mut rng = Pcg64::new(3);
/// let mut a = Mat::randn(32, 32, 1.0, &mut rng);
/// a.data[5] = 400.0; // an outlier plain INT8 would smear
/// let b = Mat::randn(32, 32, 1.0, &mut rng);
///
/// // theta = -1 puts every block on the two-level representation
/// let (c, rate) = fallback_matmul(&a, &b, -1.0, 16, 1);
/// assert!((rate - 1.0).abs() < 1e-12);
///
/// // fallback beats plain block quantization near the outlier
/// let exact = matmul(&a, &b, 1);
/// let plain = quantized_matmul(&a, &b, 16, 1);
/// let rel = dbfq::quant::metrics::rel_err;
/// assert!(rel(&c.data, &exact.data)
///         < rel(&plain.data, &exact.data));
/// ```
pub fn fallback_matmul(a: &Mat, b: &Mat, theta: f32, block: usize,
                       threads: usize) -> (Mat, f64) {
    let fa = fallback_quant(a, theta, block, INT8_LEVELS, Criterion::AbsMax);
    let qb = block_quant(b, block, INT8_LEVELS, Rounding::Nearest);
    let rate = fa.fallback_rate();
    (int8::fallback_gemm(&fa, &qb, &fa.u, threads), rate)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::metrics::rel_err;
    use crate::util::rng::Pcg64;

    #[test]
    fn convenience_wrappers() {
        let mut rng = Pcg64::new(5);
        let a = Mat::randn(32, 32, 1.0, &mut rng);
        let b = Mat::randn(32, 32, 1.0, &mut rng);
        let exact = matmul(&a, &b, 1);
        let c = quantized_matmul(&a, &b, 16, 1);
        assert!(rel_err(&c.data, &exact.data) < 0.02);
        let (cf, rate) = fallback_matmul(&a, &b, -1.0, 16, 1);
        assert!((rate - 1.0).abs() < 1e-12);
        assert!(rel_err(&cf.data, &exact.data)
                < rel_err(&c.data, &exact.data));
    }
}
