//! GPU roofline cost model — projects the CPU-measured kernel structure
//! onto the paper's GPUs (RTX 4090/3090, L20, A800).
//!
//! The testbed has no CUDA hardware (DESIGN.md §Substitutions), so the
//! *measured* axis of every kernel claim comes from the Rust CPU GEMM
//! substrate, while this model reproduces the paper's absolute numbers:
//! Fig 1b (throughput vs group size), Table 3 (layer speedups), Fig 8c /
//! Fig 9 (fallback GEMM throughput, random vs sequential placement).
//!
//! Model per GEMM: t = max(t_mma, t_mem) + t_dequant, where
//!   t_mma     = 2·M·N·K · (1 + fallback_extra) / peak_int8
//!   t_dequant = c_deq · M·N · ceil(K/Kg) / peak_cuda  (FP32 scale-FMA
//!               per C element per K-group — the Eq. 1 accumulation)
//!   t_mem     = bytes(A, B, C, fallback A residuals) / bw
//! Worst-case (sequential) placement adds an LPT makespan penalty over
//! the SM grid.

/// Hardware description (dense peak numbers, no sparsity).
#[derive(Debug, Clone)]
pub struct Gpu {
    pub name: &'static str,
    /// INT8 tensor-core peak, Tops
    pub int8_tops: f64,
    /// BF16 tensor-core peak, Tflops
    pub bf16_tflops: f64,
    /// FP32 CUDA-core peak, Tflops (dequant/accumulate path)
    pub cuda_tflops: f64,
    /// memory bandwidth, GB/s
    pub mem_bw_gbs: f64,
    /// number of SMs (scheduling granularity)
    pub sms: usize,
}

/// The four GPUs of the paper's evaluation (§6.3, Appendix B).
pub fn rtx4090() -> Gpu {
    Gpu { name: "RTX4090", int8_tops: 660.6, bf16_tflops: 165.2,
          cuda_tflops: 82.6, mem_bw_gbs: 1008.0, sms: 128 }
}

pub fn rtx3090() -> Gpu {
    Gpu { name: "RTX3090", int8_tops: 284.0, bf16_tflops: 71.0,
          cuda_tflops: 35.6, mem_bw_gbs: 936.0, sms: 82 }
}

pub fn l20() -> Gpu {
    Gpu { name: "L20", int8_tops: 239.0, bf16_tflops: 119.5,
          cuda_tflops: 59.8, mem_bw_gbs: 864.0, sms: 92 }
}

pub fn a800() -> Gpu {
    Gpu { name: "A800", int8_tops: 624.0, bf16_tflops: 312.0,
          cuda_tflops: 19.5, mem_bw_gbs: 2039.0, sms: 108 }
}

pub fn all_gpus() -> Vec<Gpu> {
    vec![rtx4090(), rtx3090(), l20(), a800()]
}

/// Tensor-core utilization ceiling for well-tuned kernels (empirically
/// ~70-80% of peak for INT8 GEMM at these sizes; calibrated so the
/// 4090 curve passes through the paper's 425 Tops @ Kg=128 and
/// ~270 Tops @ Kg=32 (Fig 1b).
const MMA_EFF: f64 = 0.78;
/// dequant cost in CUDA-core flops per C element per K-group step
/// (scale product + FMA into the f32 accumulator).
const DEQ_FLOPS: f64 = 8.0;

impl Gpu {
    /// Seconds for a BF16 GEMM of (m, n, k).
    pub fn bf16_gemm_secs(&self, m: usize, n: usize, k: usize) -> f64 {
        let work = 2.0 * m as f64 * n as f64 * k as f64;
        let t_mma = work / (self.bf16_tflops * 1e12 * MMA_EFF);
        let bytes = 2.0
            * (m as f64 * k as f64 + k as f64 * n as f64
               + m as f64 * n as f64);
        t_mma.max(bytes / (self.mem_bw_gbs * 1e9))
    }

    /// Seconds for an INT8 block-quantized GEMM (Eq. 1) with group size
    /// `kg` and fallback rate `rate` (0 for plain block GEMM).
    pub fn int8_gemm_secs(&self, m: usize, n: usize, k: usize, kg: usize,
                          rate: f64) -> f64 {
        let (mf, nf, kf) = (m as f64, n as f64, k as f64);
        let work = 2.0 * mf * nf * kf * (1.0 + rate);
        let t_mma = work / (self.int8_tops * 1e12 * MMA_EFF);
        let kgroups = (k as f64 / kg as f64).ceil();
        // Residual blocks dequant-accumulate too.
        let t_deq = DEQ_FLOPS * mf * nf * kgroups * (1.0 + rate)
            / (self.cuda_tflops * 1e12);
        let bytes = mf * kf * (1.0 + rate) + kf * nf + 4.0 * mf * nf;
        let t_mem = bytes / (self.mem_bw_gbs * 1e9);
        t_mma.max(t_mem) + t_deq
    }

    /// Throughput in Tops for the INT8 GEMM above (useful work 2MNK,
    /// like the paper's y-axes — fallback overhead lowers it).
    pub fn int8_gemm_tops(&self, m: usize, n: usize, k: usize, kg: usize,
                          rate: f64) -> f64 {
        2.0 * m as f64 * n as f64 * k as f64
            / self.int8_gemm_secs(m, n, k, kg, rate) / 1e12
    }

    /// Sequential (worst-case) placement: fallback blocks concentrate in
    /// the leading A block-rows, so the corresponding C row-panels carry
    /// (1 + rate_in_row) x work. GPUs rasterize C tiles in a static
    /// row-major order across SMs; we simulate that schedule exactly
    /// (each tile's cost = 1 + fallback-fraction of its A row) and take
    /// the max-SM makespan. Small GEMMs suffer most — too few light
    /// tiles to hide the heavy wave (paper Fig 8c).
    pub fn int8_gemm_tops_worst(&self, m: usize, n: usize, k: usize,
                                kg: usize, rate: f64) -> f64 {
        let even = self.int8_gemm_secs(m, n, k, kg, rate);
        let tiles_m = m.div_ceil(kg);
        let tiles_n = n.div_ceil(kg);
        // sequential: all fallback K-blocks packed into leading rows
        let total_fb = rate * (tiles_m * tiles_n) as f64; // row-units
        let mut row_cost = vec![1.0f64; tiles_m];
        let mut left = total_fb * tiles_n as f64; // tile-units of extra
        for rc in row_cost.iter_mut() {
            let add = left.min(tiles_n as f64);
            *rc += add / tiles_n as f64;
            left -= add;
            if left <= 0.0 {
                break;
            }
        }
        // static row-major rasterization across SMs
        let mut sm_load = vec![0.0f64; self.sms];
        let mut idx = 0usize;
        for r in 0..tiles_m {
            for _ in 0..tiles_n {
                sm_load[idx % self.sms] += row_cost[r];
                idx += 1;
            }
        }
        let makespan = sm_load.iter().cloned().fold(0.0, f64::max);
        let ideal: f64 = sm_load.iter().sum::<f64>() / self.sms as f64;
        // tail-wave quantization: even ideal schedules pay ceil() waves
        let skew = (makespan / ideal).max(1.0);
        2.0 * m as f64 * n as f64 * k as f64 / (even * skew) / 1e12
    }

    /// One transformer layer's GEMM time (fwd or fwd+bwd), hidden `d`,
    /// GLU off (the paper's Table 3 uses a GPT-2 layer), `tokens` rows.
    pub fn layer_secs(&self, d: usize, tokens: usize, int8: bool,
                      kg: usize, rate: f64, backward: bool) -> f64 {
        let shapes = [
            (tokens, 3 * d, d),  // qkv
            (tokens, d, d),      // attn out
            (tokens, 4 * d, d),  // mlp up (GPT-2: 4d)
            (tokens, d, 4 * d),  // mlp down
        ];
        let mut t = 0.0;
        for (m, n, k) in shapes {
            let fwd = if int8 {
                self.int8_gemm_secs(m, n, k, kg, rate)
            } else {
                self.bf16_gemm_secs(m, n, k)
            };
            t += fwd;
            if backward {
                // dX (m,k,n) + dW (n,k,m): same MNK volume each. dY is
                // not fallback-quantized (§5.1) -> rate only in fwd.
                let bwd = if int8 {
                    self.int8_gemm_secs(m, k, n, kg, 0.0)
                        + self.int8_gemm_secs(n, k, m, kg, 0.0)
                } else {
                    self.bf16_gemm_secs(m, k, n)
                        + self.bf16_gemm_secs(n, k, m)
                };
                t += bwd;
            }
        }
        // attention stays BF16 in all methods
        let attn = 2.0 * self.bf16_gemm_secs(tokens, tokens, d);
        t + attn * if backward { 3.0 } else { 1.0 }
    }
}

/// Measured per-mode throughput of the CPU GEMM engine — the
/// *measured* axis the roofline projections are anchored to.
///
/// Where the model above uses ad-hoc constants for the fallback compute
/// factor (`1 + rate`), this struct carries the engine's actually
/// measured dense / int8 / fallback throughput on the current testbed
/// and exposes the measured fallback-overhead slope for projections.
/// The int8 numbers are taken on **both data paths**: the true-i8
/// kernels (`int8_gops`, the deployed path) and the f32 simulation
/// (`int8_sim_gops`, the seed-compatible oracle) — their ratio is the
/// substrate's measured analogue of the paper's INT8:BF16 gain.
///
/// The i8 path is additionally swept across **every microkernel
/// backend** available on the host (`per_backend`); because all
/// backends are bit-identical, the fastest measured one can be
/// installed as the process-wide default
/// ([`install_fastest_backend`](SubstrateCalibration::install_fastest_backend))
/// — calibration, not a static preference table, then decides what
/// later plans run, unless a `PALLAS_KERNEL` override pins it.
/// Produced by [`SubstrateCalibration::measure`] (used by
/// `benches/gemm_engine.rs`) or built directly from recorded numbers.
#[derive(Debug, Clone)]
pub struct SubstrateCalibration {
    /// (m, n, k) of the calibration GEMM
    pub dims: (usize, usize, usize),
    pub block: usize,
    pub threads: usize,
    /// measured engine throughput, Gops (useful work 2·M·N·K)
    pub dense_gops: f64,
    /// Int8Block on the true-i8 data path
    pub int8_gops: f64,
    /// Int8Block on the SimF32 (f32-code) data path
    pub int8_sim_gops: f64,
    /// (achieved fallback rate, Gops) samples on the i8 path,
    /// ascending in rate
    pub fallback: Vec<(f64, f64)>,
    /// microkernel backend used for the headline `int8_gops` /
    /// `fallback` numbers (the plan default at measure time)
    pub backend: &'static str,
    /// i8-path Gops per available kernel backend, in `available()`
    /// order (scalar first)
    pub per_backend: Vec<(&'static str, f64)>,
}

impl SubstrateCalibration {
    /// Run the engine on synthetic operands and record per-mode
    /// throughput. Cheap at small `dim` (used in tests); the bench uses
    /// larger sizes for the tracked numbers.
    pub fn measure(dim: usize, block: usize, threads: usize)
                   -> SubstrateCalibration {
        use crate::gemm::engine::{DataPath, GemmPlan};
        use crate::quant::{block_quant, fallback_quant, theta_for_rate,
                           Criterion, Rounding, INT8_LEVELS};
        use crate::util::bench::{bench, gops};
        use crate::util::rng::Pcg64;
        use crate::util::Mat;

        let mut rng = Pcg64::new(0xCA11B);
        let a = Mat::randn(dim, dim, 1.0, &mut rng);
        let b = Mat::randn(dim, dim, 1.0, &mut rng);
        let target_ms = 40;

        let dense_plan = GemmPlan::new_dense(&a, &b, threads);
        let s = bench(|| {
            std::hint::black_box(dense_plan.execute());
        }, target_ms);
        let dense_gops = gops(dim, dim, dim, s.median_secs());

        let qa = block_quant(&a, block, INT8_LEVELS, Rounding::Nearest);
        let qb = block_quant(&b, block, INT8_LEVELS, Rounding::Nearest);
        // One sweep covers every backend including the selected
        // default — the headline `int8_gops` is read out of the sweep
        // rather than re-measured (select() always returns a member
        // of available()).
        let backend = crate::gemm::kernels::select().name;
        let mut per_backend = Vec::new();
        let mut int8_gops = 0.0;
        for kn in crate::gemm::kernels::available() {
            let plan =
                GemmPlan::new_int8_path(&qa, &qb, threads,
                                        DataPath::Int8)
                    .with_kernels(kn);
            let s = bench(|| {
                std::hint::black_box(plan.execute());
            }, target_ms);
            let g = gops(dim, dim, dim, s.median_secs());
            if kn.name == backend {
                int8_gops = g;
            }
            per_backend.push((kn.name, g));
        }
        let sim_plan = GemmPlan::new_int8_path(&qa, &qb, threads,
                                               DataPath::SimF32);
        let s = bench(|| {
            std::hint::black_box(sim_plan.execute());
        }, target_ms);
        let int8_sim_gops = gops(dim, dim, dim, s.median_secs());

        let probe = fallback_quant(&a, f32::INFINITY, block, INT8_LEVELS,
                                   Criterion::AbsMax);
        let mut fallback = Vec::new();
        for rate in [0.0f64, 0.25] {
            let theta = theta_for_rate(&probe.metric, rate);
            let fa = fallback_quant(&a, theta, block, INT8_LEVELS,
                                    Criterion::AbsMax);
            let plan = GemmPlan::new_fallback_path(
                &fa, &qb, &fa.u, threads, DataPath::Int8);
            let s = bench(|| {
                std::hint::black_box(plan.execute());
            }, target_ms);
            fallback.push((fa.fallback_rate(),
                           gops(dim, dim, dim, s.median_secs())));
        }

        SubstrateCalibration {
            dims: (dim, dim, dim),
            block,
            threads,
            dense_gops,
            int8_gops,
            int8_sim_gops,
            fallback,
            backend,
            per_backend,
        }
    }

    /// The kernel backend with the highest measured i8-path
    /// throughput, with its Gops. `None` only if `per_backend` was
    /// left empty on a hand-built calibration.
    pub fn fastest_backend(&self) -> Option<(&'static str, f64)> {
        self.per_backend
            .iter()
            .copied()
            .max_by(|a, b| a.1.total_cmp(&b.1))
    }

    /// Install the fastest *measured* backend as the process-wide
    /// default for subsequent plan builds (`kernels::set_preferred`).
    /// A `PALLAS_KERNEL` env override still takes precedence — this
    /// only replaces the static detection-order preference with the
    /// calibrated one. Returns the installed name, or `None` when
    /// `per_backend` is empty or the name no longer resolves.
    pub fn install_fastest_backend(&self) -> Option<&'static str> {
        let (name, _) = self.fastest_backend()?;
        let k = crate::gemm::kernels::by_name(name)?;
        crate::gemm::kernels::set_preferred(k);
        Some(name)
    }

    /// Measured slope of fallback overhead vs rate: extra time per unit
    /// rate relative to the rate-0 kernel, clamped at 0 (paper Fig 8c:
    /// overhead ∝ rate). Falls back to the model's implicit slope of
    /// 1.0 when fewer than two samples exist.
    pub fn fallback_overhead_per_rate(&self) -> f64 {
        let (first, last) = match (self.fallback.first(),
                                   self.fallback.last()) {
            (Some(&f), Some(&l)) if l.0 > f.0 => (f, l),
            _ => return 1.0,
        };
        // gops ∝ 1/time: time ratio = gops_lo / gops_hi
        let time_ratio = first.1 / last.1;
        ((time_ratio - 1.0) / (last.0 - first.0)).max(0.0)
    }

    /// Measured int8:dense throughput ratio on the substrate.
    pub fn int8_speedup(&self) -> f64 {
        self.int8_gops / self.dense_gops
    }

    /// Measured speedup of the true-i8 data path over the f32
    /// simulation — the substrate's INT8-data-flow gain (the claim
    /// behind the paper's Fig 8c / Table 3 speedups).
    pub fn datapath_speedup(&self) -> f64 {
        self.int8_gops / self.int8_sim_gops
    }

    /// GPU projection consuming the *measured* fallback slope instead
    /// of the ad-hoc `(1 + rate)` compute factor of
    /// [`Gpu::int8_gemm_secs`].
    pub fn projected_int8_secs(&self, gpu: &Gpu, m: usize, n: usize,
                               k: usize, kg: usize, rate: f64) -> f64 {
        let base = gpu.int8_gemm_secs(m, n, k, kg, 0.0);
        base * (1.0 + rate * self.fallback_overhead_per_rate())
    }

    /// Projected GPU seconds for one transformer-layer *microstep* —
    /// the four linear sites of [`crate::model::layer_linears`], each
    /// running forward + `dX` + `dW` (the layer-step pipeline's GEMM
    /// set). The forward **and `dW`** carry the fallback rate through
    /// the measured slope — `dW`'s Xᵀ operand rides the fallback path
    /// at the site's θ, and its u-mask is exactly the forward's
    /// transpose, so both execute at the same rate. `dX` runs plain
    /// INT8 (§5.1: dY is not fallback-quantized). Group size is the
    /// calibration block.
    pub fn projected_layer_step_secs(&self, gpu: &Gpu, d_model: usize,
                                     d_ff: usize, glu: bool,
                                     tokens: usize,
                                     rate: f64) -> f64 {
        let kg = self.block;
        crate::model::layer_linears(d_model, d_ff, glu, tokens)
            .iter()
            .map(|l| {
                self.projected_int8_secs(gpu, l.m, l.n, l.k, kg, rate)
                    + gpu.int8_gemm_secs(l.m, l.k, l.n, kg, 0.0)
                    + self.projected_int8_secs(gpu, l.k, l.n, l.m,
                                               kg, rate)
            })
            .sum()
    }

    /// Projected GPU seconds for one *whole-model* microstep: `layers`
    /// transformer layers ([`projected_layer_step_secs`]) plus the LM
    /// head's three GEMMs (`tokens × vocab × d_model`) — the GEMM set
    /// `gemm::pipeline::ModelStep` drives. Like the layer projection,
    /// the forward and `dW` GEMMs carry the fallback rate through the
    /// measured slope and `dX` runs plain INT8.
    ///
    /// [`projected_layer_step_secs`]: SubstrateCalibration::projected_layer_step_secs
    #[allow(clippy::too_many_arguments)]
    pub fn projected_model_step_secs(&self, gpu: &Gpu, layers: usize,
                                     d_model: usize, d_ff: usize,
                                     glu: bool, vocab: usize,
                                     tokens: usize, rate: f64) -> f64 {
        let kg = self.block;
        let h = crate::model::lm_head_linear(d_model, vocab, tokens);
        layers as f64
            * self.projected_layer_step_secs(gpu, d_model, d_ff, glu,
                                             tokens, rate)
            + self.projected_int8_secs(gpu, h.m, h.n, h.k, kg, rate)
            + gpu.int8_gemm_secs(h.m, h.k, h.n, kg, 0.0)
            + self.projected_int8_secs(gpu, h.k, h.n, h.m, kg, rate)
    }

    /// CPU-substrate estimate for the same whole-model microstep
    /// (layers × [`substrate_layer_step_secs`] + the LM head), from
    /// the measured i8-path throughput and fallback slope.
    /// `benches/model_step.rs` compares its measured pipeline time
    /// against this.
    ///
    /// [`substrate_layer_step_secs`]: SubstrateCalibration::substrate_layer_step_secs
    #[allow(clippy::too_many_arguments)]
    pub fn substrate_model_step_secs(&self, layers: usize,
                                     d_model: usize, d_ff: usize,
                                     glu: bool, vocab: usize,
                                     tokens: usize, rate: f64) -> f64 {
        let slope = self.fallback_overhead_per_rate();
        let flops_per_sec = self.int8_gops.max(1e-12) * 1e9;
        let h = crate::model::lm_head_linear(d_model, vocab, tokens);
        let fwd = h.flops();
        layers as f64
            * self.substrate_layer_step_secs(d_model, d_ff, glu,
                                             tokens, rate)
            + (2.0 * fwd * (1.0 + rate * slope) + fwd) / flops_per_sec
    }

    /// CPU-substrate estimate of one full optimizer **training
    /// step**: `accum` gradient-accumulation microsteps
    /// ([`substrate_model_step_secs`]) plus the optimizer's
    /// elementwise update over every quantized-site parameter
    /// ([`crate::model::model_param_count`]) priced at the measured
    /// dense-f32 throughput — `opt_flops_per_param` is the update
    /// rule's per-parameter op count
    /// ([`crate::train::Optimizer::flops_per_param`]). This is the
    /// cost model's first end-to-end ground-truth hook:
    /// `benches/train_loop.rs` reports its measured per-step seconds
    /// next to this projection.
    ///
    /// [`substrate_model_step_secs`]: SubstrateCalibration::substrate_model_step_secs
    #[allow(clippy::too_many_arguments)]
    pub fn substrate_train_step_secs(&self, layers: usize,
                                     d_model: usize, d_ff: usize,
                                     glu: bool, vocab: usize,
                                     tokens: usize, rate: f64,
                                     accum: usize,
                                     opt_flops_per_param: f64) -> f64 {
        let params = crate::model::model_param_count(
            layers, d_model, d_ff, glu, vocab) as f64;
        let dense_per_sec = self.dense_gops.max(1e-12) * 1e9;
        accum as f64
            * self.substrate_model_step_secs(layers, d_model, d_ff,
                                             glu, vocab, tokens, rate)
            + params * opt_flops_per_param / dense_per_sec
    }

    /// Serialize the measured numbers (warm-state files, reports) so a
    /// fresh process can consume calibrated projections — and install
    /// the calibrated backend — without re-measuring.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        obj(vec![
            ("dims", Json::Arr(vec![
                Json::Num(self.dims.0 as f64),
                Json::Num(self.dims.1 as f64),
                Json::Num(self.dims.2 as f64),
            ])),
            ("block", Json::Num(self.block as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("dense_gops", Json::Num(self.dense_gops)),
            ("int8_gops", Json::Num(self.int8_gops)),
            ("int8_sim_gops", Json::Num(self.int8_sim_gops)),
            ("fallback", Json::Arr(
                self.fallback
                    .iter()
                    .map(|&(rate, gops)| obj(vec![
                        ("rate", Json::Num(rate)),
                        ("gops", Json::Num(gops)),
                    ]))
                    .collect(),
            )),
            ("backend", Json::Str(self.backend.into())),
            ("per_backend", Json::Arr(
                self.per_backend
                    .iter()
                    .map(|&(name, gops)| obj(vec![
                        ("name", Json::Str(name.into())),
                        ("gops", Json::Num(gops)),
                    ]))
                    .collect(),
            )),
        ])
    }

    /// Restore a calibration serialized by
    /// [`to_json`](SubstrateCalibration::to_json). Backend names
    /// resolve against the kernel backends *available on this host*:
    /// a name this host cannot run (e.g. `"avx2"` restored on
    /// aarch64) falls back to `"scalar"` for the headline label and
    /// is dropped from `per_backend` — the throughput numbers
    /// themselves survive untouched.
    pub fn from_json(j: &crate::util::json::Json)
                     -> Result<SubstrateCalibration, String> {
        use crate::util::json::Json;
        let num = |k: &str| {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("calibration: missing '{k}'"))
        };
        let dims = j
            .get("dims")
            .and_then(|v| v.to_f64_vec())
            .filter(|v| v.len() == 3)
            .ok_or("calibration: missing 'dims'")?;
        let fallback = j
            .get("fallback")
            .and_then(|v| v.as_arr())
            .ok_or("calibration: missing 'fallback'")?
            .iter()
            .map(|s| {
                let rate = s.get("rate").and_then(|v| v.as_f64());
                let gops = s.get("gops").and_then(|v| v.as_f64());
                match (rate, gops) {
                    (Some(r), Some(g)) => Ok((r, g)),
                    _ => Err("calibration: bad fallback sample".into()),
                }
            })
            .collect::<Result<Vec<_>, String>>()?;
        // The keys are required (a file missing them is malformed,
        // not cross-host); only *names this host cannot run* degrade
        // — headline label to "scalar", unresolvable sweep entries
        // dropped.
        let backend = j
            .get("backend")
            .and_then(|v| v.as_str())
            .ok_or("calibration: missing 'backend'")?;
        let backend = static_backend_name(backend).unwrap_or("scalar");
        let per_backend = j
            .get("per_backend")
            .and_then(|v| v.as_arr())
            .ok_or("calibration: missing 'per_backend'")?
            .iter()
            .filter_map(|s| {
                let name = s
                    .get("name")
                    .and_then(|v| v.as_str())
                    .and_then(static_backend_name)?;
                let gops = s.get("gops").and_then(|v| v.as_f64())?;
                Some((name, gops))
            })
            .collect();
        Ok(SubstrateCalibration {
            dims: (dims[0] as usize, dims[1] as usize,
                   dims[2] as usize),
            block: num("block")? as usize,
            threads: num("threads")? as usize,
            dense_gops: num("dense_gops")?,
            int8_gops: num("int8_gops")?,
            int8_sim_gops: num("int8_sim_gops")?,
            fallback,
            backend,
            per_backend,
        })
    }

    /// Estimated CPU-substrate seconds for the same microstep, from
    /// the measured i8-path throughput and fallback slope: each
    /// site's forward **and `dW`** pay `1 + rate·slope` (`dW`'s Xᵀ
    /// operand executes Algorithm 1 at the forward's rate — its
    /// u-mask is the forward's transpose), while `dX` moves the same
    /// M·N·K at rate 0. The layer-step bench compares its measured
    /// cached-pipeline time against this.
    pub fn substrate_layer_step_secs(&self, d_model: usize,
                                     d_ff: usize, glu: bool,
                                     tokens: usize,
                                     rate: f64) -> f64 {
        let slope = self.fallback_overhead_per_rate();
        let flops_per_sec = self.int8_gops.max(1e-12) * 1e9;
        crate::model::layer_linears(d_model, d_ff, glu, tokens)
            .iter()
            .map(|l| {
                let fwd = l.flops();
                (2.0 * fwd * (1.0 + rate * slope) + fwd)
                    / flops_per_sec
            })
            .sum()
    }
}

/// Map a deserialized backend name onto the matching host-available
/// `&'static str` (the calibration struct stores static names). Names
/// of backends this host cannot run resolve to `None`.
fn static_backend_name(s: &str) -> Option<&'static str> {
    crate::gemm::kernels::available()
        .into_iter()
        .map(|k| k.name)
        .find(|&n| n == s)
}

/// Projected LPT makespan (in weight units) of a per-row-sub-panel
/// weight vector under the engine's sharded scheduling policy.
/// `weights` are the fallback-weighted row-chunk costs (what
/// `GemmPlan::panel_weights` exposes); `panels` is the number of
/// column panels the shards slice (`nbk`). The thread budget is split
/// round-robin across `shards` (each shard keeping at least one
/// thread), `weighted_buckets` runs per shard over the shared
/// row-chunk costs, each shard's bucket maximum is scaled by its
/// contiguous share of the column panels, and the projection is the
/// max over shards. `shards <= 1` reduces exactly to the flat LPT
/// makespan — same clamping, same tie-breaks — so this is a strict
/// generalization of the unsharded projection.
///
/// This mirrors `GemmPlan::schedule_makespan` without needing packed
/// operands, so the cost model can ask "does sharding this layer's
/// panel set cost schedule balance?" before any plan is built.
pub fn sharded_makespan(weights: &[f64], threads: usize,
                        shards: usize, panels: usize) -> f64 {
    use crate::util::threadpool::weighted_buckets;
    let bucket_span = |b: &Vec<usize>| {
        b.iter().map(|&i| weights[i]).sum::<f64>()
    };
    let shards = shards.max(1).min(panels.max(1));
    if shards <= 1 {
        return weighted_buckets(weights, threads)
            .iter()
            .map(bucket_span)
            .fold(0.0f64, f64::max);
    }
    let eff = threads.clamp(1, weights.len().max(1));
    let base = eff / shards;
    let extra = eff % shards;
    (0..shards)
        .map(|si| {
            let t = (base + usize::from(si < extra))
                .clamp(1, weights.len().max(1));
            let lo = si * panels / shards;
            let hi = (si + 1) * panels / shards;
            let frac = (hi - lo) as f64 / panels.max(1) as f64;
            weighted_buckets(weights, t)
                .iter()
                .map(bucket_span)
                .fold(0.0f64, f64::max)
                * frac
        })
        .fold(0.0f64, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharded_makespan_s1_matches_flat_lpt() {
        let w = [1.0, 260.0, 2.0, 260.0, 1.5, 3.0];
        for threads in [1usize, 2, 4, 8] {
            let flat = crate::util::threadpool::weighted_buckets(&w, threads)
                .iter()
                .map(|b| b.iter().map(|&i| w[i]).sum::<f64>())
                .fold(0.0f64, f64::max);
            let s1 = sharded_makespan(&w, threads, 1, 4);
            assert_eq!(s1.to_bits(), flat.to_bits(),
                       "S=1 must be the flat projection (threads={threads})");
        }
    }

    #[test]
    fn sharded_makespan_is_bounded_and_clamps() {
        let w = [1.0, 260.0, 2.0, 260.0, 1.5, 3.0];
        let total: f64 = w.iter().sum();
        for threads in [1usize, 2, 4] {
            for shards in [1usize, 2, 3, 4, 16] {
                let m = sharded_makespan(&w, threads, shards, 4);
                assert!(m > 0.0 && m <= total + 1e-9,
                        "makespan {m} outside (0, {total}] at \
                         threads={threads} shards={shards}");
            }
        }
        // Uniform row chunks, 2 shards x 2 threads each: every shard
        // splits the 4 chunks over 2 buckets (span 4.0) and covers
        // half the column panels -> projection total/4.
        let u = [2.0; 4];
        let m = sharded_makespan(&u, 4, 2, 2);
        assert!((m - 2.0).abs() < 1e-12, "expected 8.0/4, got {m}");
        // zero panels / zero chunks never divide by zero
        assert_eq!(sharded_makespan(&[], 4, 3, 0), 0.0);
        assert_eq!(sharded_makespan(&[], 4, 3, 4), 0.0);
    }

    #[test]
    fn int8_faster_than_bf16_at_large_sizes() {
        let g = rtx4090();
        let t8 = g.int8_gemm_secs(4096, 4096, 4096, 128, 0.0);
        let t16 = g.bf16_gemm_secs(4096, 4096, 4096);
        assert!(t8 < t16, "int8 {t8} vs bf16 {t16}");
        // ratio should be ~2-4x
        let ratio = t16 / t8;
        assert!(ratio > 1.8 && ratio < 4.5, "ratio {ratio}");
    }

    #[test]
    fn fig1b_shape_small_groups_slower() {
        // Fig 1b: 32-group INT8 GEMM ~38% slower than 128-group on 4090.
        let g = rtx4090();
        let tops32 = g.int8_gemm_tops(4096, 4096, 4096, 32, 0.0);
        let tops128 = g.int8_gemm_tops(4096, 4096, 4096, 128, 0.0);
        assert!(tops32 < tops128);
        let drop = 1.0 - tops32 / tops128;
        assert!(drop > 0.2 && drop < 0.55, "drop {drop}");
        // paper: ~270 Tops at 32, ~425 at 128 — shape check with slack
        assert!(tops128 > 350.0 && tops128 < 520.0, "t128 {tops128}");
        assert!(tops32 > 180.0 && tops32 < 350.0, "t32 {tops32}");
    }

    #[test]
    fn fallback_overhead_proportional_to_rate() {
        let g = rtx4090();
        let t0 = g.int8_gemm_secs(4096, 4096, 4096, 128, 0.0);
        let t20 = g.int8_gemm_secs(4096, 4096, 4096, 128, 0.2);
        let t40 = g.int8_gemm_secs(4096, 4096, 4096, 128, 0.4);
        assert!(t20 > t0 && t40 > t20);
        let o20 = t20 / t0 - 1.0;
        assert!(o20 > 0.1 && o20 < 0.3, "overhead {o20}");
    }

    #[test]
    fn a800_gains_least() {
        // Appendix B: A800's 2x int8:bf16 ratio + weak CUDA cores.
        let speedup = |g: &Gpu| {
            g.bf16_gemm_secs(4096, 4096, 4096)
                / g.int8_gemm_secs(4096, 4096, 4096, 128, 0.2)
        };
        let s4090 = speedup(&rtx4090());
        let s3090 = speedup(&rtx3090());
        let sa800 = speedup(&a800());
        assert!(s3090 > sa800, "3090 {s3090} vs a800 {sa800}");
        assert!(s4090 > sa800);
    }

    #[test]
    fn worst_case_placement_never_faster() {
        let g = rtx4090();
        for rate in [0.0, 0.1, 0.3] {
            let even = g.int8_gemm_tops(2048, 2048, 2048, 128, rate);
            let worst =
                g.int8_gemm_tops_worst(2048, 2048, 2048, 128, rate);
            assert!(worst <= even + 1e-9, "rate {rate}");
        }
    }

    #[test]
    fn substrate_calibration_measures_and_projects() {
        // install_fastest_backend mutates the process-global kernel
        // preference — serialize with the other test that touches it.
        let _g = crate::gemm::kernels::PREFERRED_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let cal = SubstrateCalibration::measure(96, 16, 1);
        assert!(cal.dense_gops > 0.0);
        assert!(cal.int8_gops > 0.0);
        assert!(cal.int8_sim_gops > 0.0);
        assert!(cal.datapath_speedup() > 0.0);
        // every host backend was swept and the fastest is installable
        let avail = crate::gemm::kernels::available();
        assert_eq!(cal.per_backend.len(), avail.len());
        assert!(cal.per_backend.iter().all(|&(_, g)| g > 0.0));
        assert!(avail.iter().any(|k| k.name == cal.backend));
        let (fast, fast_gops) = cal.fastest_backend().unwrap();
        assert!(cal.per_backend.iter().all(|&(_, g)| g <= fast_gops));
        assert_eq!(cal.install_fastest_backend(), Some(fast));
        // restore the static preference so later tests in this
        // process are unaffected (results are bit-identical anyway)
        crate::gemm::kernels::set_preferred(
            crate::gemm::kernels::detect_best());
        assert_eq!(cal.fallback.len(), 2);
        assert!(cal.fallback.iter().all(|&(_, g)| g > 0.0));
        // achieved rates bracket the request reasonably
        assert!(cal.fallback[0].0 < 0.05);
        assert!(cal.fallback[1].0 > 0.1);
        // slope is clamped non-negative, so projections are monotone
        let slope = cal.fallback_overhead_per_rate();
        assert!(slope >= 0.0, "slope {slope}");
        let g = rtx4090();
        let t0 = cal.projected_int8_secs(&g, 1024, 1024, 1024, 128, 0.0);
        let t3 = cal.projected_int8_secs(&g, 1024, 1024, 1024, 128, 0.3);
        assert!(t3 >= t0);
        assert!(cal.int8_speedup() > 0.0);
    }

    #[test]
    fn layer_step_projection_scales_and_orders() {
        // Hand-built calibration: slope = (10/8 - 1) / 0.25 = 1.0.
        let cal = SubstrateCalibration {
            dims: (256, 256, 256),
            block: 128,
            threads: 4,
            dense_gops: 5.0,
            int8_gops: 10.0,
            int8_sim_gops: 6.0,
            fallback: vec![(0.0, 10.0), (0.25, 8.0)],
            backend: "scalar",
            per_backend: vec![("scalar", 10.0)],
        };
        assert!((cal.fallback_overhead_per_rate() - 1.0).abs() < 1e-9);
        let g = rtx4090();
        let t0 = cal
            .projected_layer_step_secs(&g, 2048, 8192, false, 4096,
                                       0.0);
        let t2 = cal
            .projected_layer_step_secs(&g, 2048, 8192, false, 4096,
                                       0.2);
        assert!(t0 > 0.0);
        assert!(t2 > t0, "fallback rate must cost time");
        // more tokens -> more time, superlinear never required
        let t_big = cal
            .projected_layer_step_secs(&g, 2048, 8192, false, 8192,
                                       0.1);
        let t_small = cal
            .projected_layer_step_secs(&g, 2048, 8192, false, 4096,
                                       0.1);
        assert!(t_big > t_small);
        // substrate estimate at rate 0 is exactly step-flops / Gops
        let s0 = cal
            .substrate_layer_step_secs(2048, 8192, false, 4096, 0.0);
        let flops: f64 =
            crate::model::layer_linears(2048, 8192, false, 4096)
                .iter()
                .map(|l| l.microstep_flops())
                .sum();
        let expect = flops / (10.0 * 1e9);
        assert!((s0 - expect).abs() / expect < 1e-9,
                "s0 {s0} vs {expect}");
        let s2 = cal
            .substrate_layer_step_secs(2048, 8192, false, 4096, 0.2);
        assert!(s2 > s0);
    }

    fn hand_cal() -> SubstrateCalibration {
        SubstrateCalibration {
            dims: (256, 256, 256),
            block: 128,
            threads: 4,
            dense_gops: 5.0,
            int8_gops: 10.0,
            int8_sim_gops: 6.0,
            fallback: vec![(0.0, 10.0), (0.25, 8.0)],
            backend: "scalar",
            per_backend: vec![("scalar", 10.0)],
        }
    }

    #[test]
    fn model_step_projection_composes_layers_and_head() {
        let cal = hand_cal();
        let g = rtx4090();
        let layer = cal
            .projected_layer_step_secs(&g, 1024, 4096, false, 2048,
                                       0.1);
        let one = cal
            .projected_model_step_secs(&g, 1, 1024, 4096, false,
                                       32000, 2048, 0.1);
        let four = cal
            .projected_model_step_secs(&g, 4, 1024, 4096, false,
                                       32000, 2048, 0.1);
        // head adds time on top of the layer stack, layers compose
        // linearly
        assert!(one > layer);
        let head = one - layer;
        assert!((four - (4.0 * layer + head)).abs() / four < 1e-9);
        // substrate estimate: whole-model flops over measured Gops at
        // rate 0
        let s = cal.substrate_model_step_secs(3, 1024, 4096, false,
                                              32000, 2048, 0.0);
        let flops: f64 = crate::model::model_linears(
            3, 1024, 4096, false, 32000, 2048)
            .iter()
            .map(|l| l.microstep_flops())
            .sum();
        let expect = flops / (10.0 * 1e9);
        assert!((s - expect).abs() / expect < 1e-9, "{s} vs {expect}");
        // fallback rate costs time in both projections
        assert!(cal.substrate_model_step_secs(3, 1024, 4096, false,
                                              32000, 2048, 0.2) > s);
    }

    #[test]
    fn train_step_projection_adds_optimizer_cost() {
        let cal = hand_cal();
        let micro = cal.substrate_model_step_secs(3, 1024, 4096,
                                                  false, 32000, 2048,
                                                  0.0);
        let one = cal.substrate_train_step_secs(3, 1024, 4096, false,
                                                32000, 2048, 0.0, 1,
                                                12.0);
        // optimizer update rides on top of the microstep...
        assert!(one > micro);
        let params = crate::model::model_param_count(3, 1024, 4096,
                                                     false, 32000)
            as f64;
        let expect = micro + params * 12.0 / (5.0 * 1e9);
        assert!((one - expect).abs() / expect < 1e-9);
        // ...and accumulation microsteps compose linearly while the
        // update is paid once per step
        let four = cal.substrate_train_step_secs(3, 1024, 4096, false,
                                                 32000, 2048, 0.0, 4,
                                                 12.0);
        assert!((four - (4.0 * micro + (one - micro))).abs() / four
                < 1e-9);
    }

    #[test]
    fn calibration_json_roundtrip() {
        let cal = hand_cal();
        let j = cal.to_json();
        let text = j.to_string();
        let r = SubstrateCalibration::from_json(
            &crate::util::json::Json::parse(&text).unwrap())
            .unwrap();
        assert_eq!(r.dims, cal.dims);
        assert_eq!((r.block, r.threads), (cal.block, cal.threads));
        assert_eq!(r.dense_gops, cal.dense_gops);
        assert_eq!(r.int8_gops, cal.int8_gops);
        assert_eq!(r.int8_sim_gops, cal.int8_sim_gops);
        assert_eq!(r.fallback, cal.fallback);
        assert_eq!(r.backend, "scalar");
        assert_eq!(r.per_backend, cal.per_backend);
        // a backend name this host can't run degrades gracefully
        let mut alien = cal.clone();
        alien.backend = "no-such-isa";
        alien.per_backend = vec![("no-such-isa", 3.0)];
        let r2 = SubstrateCalibration::from_json(
            &crate::util::json::Json::parse(&alien.to_json()
                .to_string()).unwrap())
            .unwrap();
        assert_eq!(r2.backend, "scalar");
        assert!(r2.per_backend.is_empty());
        // malformed input errors
        assert!(SubstrateCalibration::from_json(
            &crate::util::json::Json::Null).is_err());
    }

    #[test]
    fn layer_speedup_grows_with_hidden() {
        // Table 3: overall speedup grows 1.31 -> 1.92 from 1024 to 4096.
        let g = rtx4090();
        let speed = |d: usize| {
            g.layer_secs(d, 2048, false, 128, 0.0, true)
                / g.layer_secs(d, 2048, true, 128, 0.2, true)
        };
        let s1k = speed(1024);
        let s4k = speed(4096);
        assert!(s4k > s1k, "{s1k} -> {s4k}");
        assert!(s1k > 1.0 && s4k < 3.0);
    }
}
