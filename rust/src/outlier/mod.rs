//! Activation-outlier synthesis and analysis (paper §4.1, Table 1,
//! Fig 2, Fig 4a).
//!
//! The paper measures Llama-3.1-8B / Qwen-2.5-7B activations on
//! WikiText. Without those weights (DESIGN.md §Substitutions) we model
//! the *generative structure* their analysis establishes:
//!   P1 GLU activations have much larger outliers (multiplicative gate),
//!   P2 occasional outliers appear outside outlier tokens/channels,
//!   P3 outliers are sparse even inside outlier channels.
//! The generator composes channel-, token-, and occasional components
//! through an optional GLU gate; the analysis half computes the paper's
//! token/channel/other statistics (Table 1) and fallback-block maps
//! (Fig 4a). The same analysis functions run on *real* activations
//! captured from in-repo trained models via the `act_*` artifacts.

use crate::util::rng::Pcg64;
use crate::util::Mat;

/// Parameters for the synthetic activation generator, calibrated so a
/// GLU-on configuration reproduces the magnitude bands of Table 1.
#[derive(Debug, Clone)]
pub struct ActivationModel {
    pub tokens: usize,
    pub channels: usize,
    /// fraction of channels that are "outlier channels"
    pub channel_frac: f64,
    /// typical magnitude of channel outliers (pre-GLU)
    pub channel_mag: f32,
    /// fraction of tokens that are "outlier tokens" (BOS-like)
    pub token_frac: f64,
    pub token_mag: f32,
    /// occasional outliers per 10k elements (P2)
    pub occasional_per_10k: f64,
    pub occasional_mag: f32,
    /// sparsity of hits inside an outlier channel (P3)
    pub hit_prob: f64,
    /// apply the multiplicative GLU gate (squares magnitudes)
    pub glu: bool,
}

impl ActivationModel {
    /// Calibrated to a Qwen-2.5-like DownProj input (Table 1 row 2).
    pub fn glu_llm(tokens: usize, channels: usize) -> ActivationModel {
        ActivationModel {
            tokens,
            channels,
            channel_frac: 0.004,
            channel_mag: 22.0,
            token_frac: 0.01,
            token_mag: 70.0,
            occasional_per_10k: 2.0,
            occasional_mag: 60.0,
            hit_prob: 0.08,
            glu: true,
        }
    }

    /// GPT-2-style (no GLU): additive outliers only, order-50 magnitude.
    pub fn non_glu_llm(tokens: usize, channels: usize) -> ActivationModel {
        ActivationModel {
            tokens,
            channels,
            channel_frac: 0.02,
            channel_mag: 16.0,
            token_frac: 0.01,
            token_mag: 45.0,
            occasional_per_10k: 0.4,
            occasional_mag: 10.0,
            hit_prob: 0.5,
            glu: false,
        }
    }

    /// Generate one activation matrix (tokens x channels).
    pub fn sample(&self, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let (t, c) = (self.tokens, self.channels);
        let n_oc = ((c as f64 * self.channel_frac).ceil() as usize).max(1);
        let n_ot = ((t as f64 * self.token_frac).ceil() as usize).max(1);
        let out_ch = rng.sample_indices(c, n_oc);
        let out_tok = rng.sample_indices(t, n_ot);
        // Heavy-tailed per-channel magnitudes: a handful of channels
        // dominate (what makes Fig 4a's fallback map column-striped).
        let mut ch_mag = vec![0.0f32; c];
        let mut is_oc = vec![false; c];
        for (rank, &i) in out_ch.iter().enumerate() {
            is_oc[i] = true;
            ch_mag[i] = self.channel_mag
                * (1.0 + 3.0 / (1.0 + rank as f32));
        }
        let mut is_ot = vec![false; t];
        for &i in &out_tok {
            is_ot[i] = true;
        }

        let gate_of = |x1: f32| {
            // SiLU gate value
            x1 / (1.0 + (-x1).exp())
        };

        let mut m = Mat::zeros(t, c);
        for r in 0..t {
            for ch in 0..c {
                // base components of the two GLU inputs
                let mut x1 = rng.normal_f32() * 1.2;
                let mut x2 = rng.normal_f32() * 1.2;
                if is_oc[ch] && rng.uniform() < self.hit_prob {
                    // sparse hits inside outlier channels (P3)
                    x1 += ch_mag[ch] * (0.4 + rng.uniform_f32());
                    x2 += ch_mag[ch] * 0.5 * (0.4 + rng.uniform_f32());
                }
                if is_ot[r] {
                    x2 += self.token_mag * 0.1 * rng.normal_f32().abs()
                        + self.token_mag * 0.05;
                }
                // occasional anywhere (P2)
                if rng.uniform()
                    < self.occasional_per_10k / 10_000.0
                {
                    x1 += self.occasional_mag * (0.5 + rng.uniform_f32());
                    x2 += self.occasional_mag
                        * 0.3
                        * (0.5 + rng.uniform_f32());
                }
                let v = if self.glu {
                    gate_of(x1) * x2
                } else {
                    // additive-only activation (GELU-ish body)
                    x1 + 0.3 * x2
                };
                m.data[r * c + ch] = v;
            }
        }
        m
    }
}

/// Table 1 statistics: max |value| within outlier tokens (top 5% by
/// L1-norm), within outlier channels (excluding outlier tokens), and
/// everywhere else ("Others").
#[derive(Debug, Clone)]
pub struct OutlierStats {
    pub token_wise: f32,
    pub channel_wise: f32,
    pub others: f32,
    pub sparsity_99: f64,
}

pub fn outlier_stats(x: &Mat) -> OutlierStats {
    let (t, c) = (x.rows, x.cols);
    // L1 norms
    let mut tok_l1 = vec![0.0f64; t];
    let mut ch_l1 = vec![0.0f64; c];
    for r in 0..t {
        for ch in 0..c {
            let a = x.at(r, ch).abs() as f64;
            tok_l1[r] += a;
            ch_l1[ch] += a;
        }
    }
    let top5 = |l1: &[f64]| {
        let mut idx: Vec<usize> = (0..l1.len()).collect();
        idx.sort_by(|&a, &b| l1[b].partial_cmp(&l1[a]).unwrap());
        let k = (l1.len() as f64 * 0.05).ceil() as usize;
        let mut mark = vec![false; l1.len()];
        for &i in idx.iter().take(k.max(1)) {
            mark[i] = true;
        }
        mark
    };
    let ot = top5(&tok_l1);
    let oc = top5(&ch_l1);

    let mut token_wise = 0.0f32;
    let mut channel_wise = 0.0f32;
    let mut others = 0.0f32;
    for r in 0..t {
        for ch in 0..c {
            let a = x.at(r, ch).abs();
            if ot[r] {
                token_wise = token_wise.max(a);
            } else if oc[ch] {
                channel_wise = channel_wise.max(a);
            } else {
                others = others.max(a);
            }
        }
    }
    // sparsity: fraction of elements below 1% of the global max (P3)
    let gmax = x.abs_max();
    let small = x
        .data
        .iter()
        .filter(|v| v.abs() < 0.01 * gmax)
        .count();
    OutlierStats {
        token_wise,
        channel_wise,
        others,
        sparsity_99: small as f64 / x.data.len() as f64,
    }
}

/// Fig 4a: per-block fallback indicator map at a target rate.
pub fn fallback_map(x: &Mat, block: usize, rate: f64) -> (Vec<bool>,
                                                          usize, usize) {
    let fq = crate::quant::fallback_quant(
        x, f32::INFINITY, block, crate::quant::INT8_LEVELS,
        crate::quant::Criterion::AbsMax);
    let theta = crate::quant::theta_for_rate(&fq.metric, rate);
    let u: Vec<bool> = fq.metric.iter().map(|&m| m > theta).collect();
    (u, fq.base.rb(), fq.base.cb())
}

/// Column-structure score of a fallback map: fraction of fallback blocks
/// living in the top-`k` fallback columns. High = channel-wise pattern
/// (what Fig 4a shows); low = scattered.
pub fn column_concentration(u: &[bool], rb: usize, cb: usize,
                            k: usize) -> f64 {
    let total: usize = u.iter().filter(|&&b| b).count();
    if total == 0 {
        return 0.0;
    }
    let mut per_col = vec![0usize; cb];
    for r in 0..rb {
        for c in 0..cb {
            if u[r * cb + c] {
                per_col[c] += 1;
            }
        }
    }
    per_col.sort_unstable_by(|a, b| b.cmp(a));
    per_col.iter().take(k).sum::<usize>() as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glu_outliers_much_larger_than_non_glu() {
        // Table 1 (P1): GLU maxima are several hundred; non-GLU < ~130.
        let glu = ActivationModel::glu_llm(512, 1024).sample(1);
        let non = ActivationModel::non_glu_llm(512, 1024).sample(2);
        let sg = outlier_stats(&glu);
        let sn = outlier_stats(&non);
        let gmax = sg.token_wise.max(sg.channel_wise).max(sg.others);
        let nmax = sn.token_wise.max(sn.channel_wise).max(sn.others);
        assert!(gmax > 3.0 * nmax, "glu {gmax} vs non {nmax}");
        assert!(gmax > 200.0, "glu max {gmax}");
        assert!(nmax < 150.0, "non-glu max {nmax}");
    }

    #[test]
    fn occasional_outliers_outside_structure() {
        // P2: "Others" magnitude comparable to channel-wise outliers.
        let glu = ActivationModel::glu_llm(1024, 2048).sample(3);
        let s = outlier_stats(&glu);
        assert!(s.others > 0.3 * s.channel_wise,
                "others {} channel {}", s.others, s.channel_wise);
    }

    #[test]
    fn activations_are_sparse() {
        // P3: overwhelming majority of entries tiny vs the max.
        let glu = ActivationModel::glu_llm(512, 1024).sample(4);
        let s = outlier_stats(&glu);
        assert!(s.sparsity_99 > 0.95, "sparsity {}", s.sparsity_99);
    }

    #[test]
    fn fallback_map_rate_and_structure() {
        let glu = ActivationModel::glu_llm(512, 1024).sample(5);
        let (u, rb, cb) = fallback_map(&glu, 128, 0.2);
        let rate =
            u.iter().filter(|&&b| b).count() as f64 / u.len() as f64;
        assert!((rate - 0.2).abs() < 0.1, "rate {rate}");
        // channel-wise pattern: top-2 columns hold a large share
        let conc = column_concentration(&u, rb, cb, 2);
        assert!(conc > 0.3, "concentration {conc}");
    }

    #[test]
    fn deterministic_sampling() {
        let a = ActivationModel::glu_llm(64, 128).sample(9);
        let b = ActivationModel::glu_llm(64, 128).sample(9);
        assert_eq!(a.data, b.data);
    }
}
