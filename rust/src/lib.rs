//! # DBFQ — Dynamic Block-Level Fallback Quantization
//!
//! Production-grade reproduction of *"Accurate INT8 Training Through
//! Dynamic Block-Level Fallback"* (CS.LG 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels for fallback
//!   quantization and the mixed-precision GEMM of Algorithm 1.
//! * **L2** (`python/compile/`): a GLU transformer with quantized
//!   linear layers, AOT-lowered to HLO-text artifacts.
//! * **L3** (this crate): the training framework — PJRT runtime,
//!   delay-threshold coordinator (Algorithm 2), data pipeline, the CPU
//!   INT8 GEMM substrate, GPU roofline cost model, and the benchmark
//!   harness regenerating every table/figure of the paper.
//!
//! See `DESIGN.md` for the per-experiment index and `EXPERIMENTS.md`
//! for paper-vs-measured results.
//!
//! ## Where to start reading
//!
//! * `docs/ARCHITECTURE.md` — the CPU GEMM substrate end to end:
//!   the plan/execute engine ([`gemm::engine`]), the two data paths
//!   (`SimF32` simulation vs true `Int8`), the microkernel backend
//!   vtable and its selection order ([`gemm::kernels`]), and the
//!   layer-step plan cache/pipeline ([`gemm::pipeline`]) with the
//!   packed-once vs per-call breakdown. The "adding a kernel
//!   backend" recipe (AVX-512 VNNI next) lives there too.
//! * `docs/BENCHMARKS.md` — the schema of every `BENCH_*.json` the
//!   bench binaries emit, plus the `BENCH_SMOKE` / `DBFQ_BENCH_STEPS`
//!   knobs.
//! * [`gemm::quantized_matmul`] / [`gemm::fallback_matmul`] — the
//!   two-line entry points (doctested) if you just want a quantized
//!   GEMM.

pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod gemm;
pub mod model;
pub mod outlier;
pub mod quant;
pub mod runtime;
pub mod train;
pub mod util;
