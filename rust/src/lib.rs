//! # DBFQ — Dynamic Block-Level Fallback Quantization
//!
//! Production-grade reproduction of *"Accurate INT8 Training Through
//! Dynamic Block-Level Fallback"* (CS.LG 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels for fallback
//!   quantization and the mixed-precision GEMM of Algorithm 1.
//! * **L2** (`python/compile/`): a GLU transformer with quantized
//!   linear layers, AOT-lowered to HLO-text artifacts.
//! * **L3** (this crate): the training framework — PJRT runtime,
//!   delay-threshold coordinator (Algorithm 2), data pipeline, the CPU
//!   INT8 GEMM substrate, GPU roofline cost model, and the benchmark
//!   harness regenerating every table/figure of the paper.
//!
//! See `DESIGN.md` for the per-experiment index and `EXPERIMENTS.md`
//! for paper-vs-measured results.

pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod gemm;
pub mod model;
pub mod outlier;
pub mod quant;
pub mod runtime;
pub mod util;
