//! Ablation (Appendix D design choices): delay-threshold controller
//! dynamics — adjustment factor α and band width vs settling time and
//! rate stability, on a drifting synthetic absmax distribution.
//!
//! Not a paper figure; regenerates the design rationale for α = 1.3
//! and [0.1, 0.3] that §6 Setup states without ablation.

#[path = "common.rs"]
mod common;

use dbfq::coordinator::ThresholdController;
use dbfq::util::bench::Table;
use dbfq::util::rng::Pcg64;

/// Simulated plant: block absmaxes drawn lognormally with a drifting
/// location (training dynamics); rate(θ) = P[absmax > θ].
struct Plant {
    rng: Pcg64,
    loc: f64,
}

impl Plant {
    fn rate(&mut self, theta: f32, step: usize) -> f32 {
        // drift: outliers grow early in training, then stabilize
        self.loc = 0.5 + 1.5 * (step as f64 / 100.0).min(1.0);
        let n = 2048;
        let mut over = 0;
        for _ in 0..n {
            let a = (self.rng.normal() * 1.1 + self.loc).exp();
            if a as f32 > theta {
                over += 1;
            }
        }
        over as f32 / n as f32
    }
}

fn main() {
    common::banner("Ablation — delay-threshold controller (Alg 2)",
                   "Appendix D: α=1.3, band [0.1,0.3]");
    let mut t = Table::new(&["alpha", "band", "settle steps",
                             "in-band %", "mean |rate-0.2|"]);
    for alpha in [1.05f32, 1.3, 2.0] {
        for (lo, hi) in [(0.1f64, 0.3f64), (0.18, 0.22), (0.05, 0.5)] {
            let mut c = ThresholdController::new(1, 1000.0, lo, hi, alpha);
            let mut plant = Plant { rng: Pcg64::new(7), loc: 0.5 };
            let mut settle = None;
            let mut in_band = 0usize;
            let mut dev = 0.0f64;
            let steps = 250;
            for s in 0..steps {
                let r = plant.rate(c.thresholds[0], s);
                c.update(&[r]);
                let r_now = plant.rate(c.thresholds[0], s);
                if (lo..=hi).contains(&(r_now as f64)) {
                    in_band += 1;
                    settle.get_or_insert(s);
                }
                dev += (r_now as f64 - 0.2).abs();
            }
            t.row(&[
                format!("{alpha}"),
                format!("[{lo},{hi}]"),
                settle.map_or("never".into(), |s| s.to_string()),
                format!("{:.0}%", 100.0 * in_band as f64 / steps as f64),
                format!("{:.3}", dev / steps as f64),
            ]);
        }
    }
    t.print();
    println!("\ndesign rationale: α=1.3 settles in a few steps and \
              tracks drift; α=1.05 is sluggish under drift, α=2.0 \
              oscillates around narrow bands; the paper's [0.1,0.3] \
              band balances tracking and rate stability.");
}
