//! Table 4 (Appendix E): information leakage through quantization
//! scales. A model trained with fine-grained (32x32) block quantization
//! can read next-token information out of the AbsMax statistics —
//! its training loss looks great, BF16 and no-leakage evals don't.
//!
//! Evaluations per trained method:
//!   BF16                — evaluate at full precision
//!   Quant               — quantized eval, whole sequence at once
//!                         (scales see the future -> leakage possible)
//!   Quant (no leakage)  — per-token prefix evaluation: position t is
//!                         scored with all activations masked beyond t

#[path = "common.rs"]
mod common;

use dbfq::coordinator::QScalars;
use dbfq::data::Corpus;
use dbfq::model::Method;
use dbfq::runtime::Value;
use dbfq::util::bench::Table;

fn main() {
    common::banner("Table 4 — leakage-controlled validation PPL",
                   "Table 4, Appendix E");
    let rt = common::runtime();
    let steps = common::bench_steps(60);
    let prof = rt.profile("tiny").unwrap().clone();
    let corpus = Corpus::synthetic(100_000, prof.vocab, 321);
    let eval_tokens: Vec<i32> = corpus.eval_batches(1, prof.seq_len, 1)
        .remove(0);

    let qs = QScalars::default().to_vec();
    let theta_off = vec![f32::INFINITY; prof.n_sites];

    let mut t = Table::new(&["trained-as", "BF16", "Quant",
                             "Quant(no leakage)", "leak gap"]);
    for method in [Method::Bf16, Method::Jetfire, Method::Block,
                   Method::Fallback] {
        let tr = common::trained(&rt, "tiny", method, steps, 13);
        // Fallback disabled at eval (paper: "disable fallback ... for
        // fair comparison").
        let eval_with = |artifact: &str| -> f64 {
            let out = rt
                .call(
                    artifact,
                    &[
                        Value::vec_f32(tr.params.clone()),
                        Value::mat_i32(
                            eval_tokens[..(prof.seq_len + 1)
                                        * 1.min(prof.batch)]
                                .to_vec(),
                            1,
                            prof.seq_len + 1,
                        ),
                        Value::vec_f32(theta_off.clone()),
                        Value::vec_f32(qs.clone()),
                    ],
                )
                .unwrap();
            let per = out[1].as_f32().unwrap();
            (per.iter().map(|&l| l as f64).sum::<f64>()
                / per.len() as f64)
                .exp()
        };
        // BF16 eval needs a batch-shaped input; reuse evalp trick: the
        // eval_tiny_bf16 artifact takes (batch, seq+1); replicate rows.
        let eval_full = |artifact: &str| -> f64 {
            let mut toks = Vec::new();
            for _ in 0..prof.batch {
                toks.extend_from_slice(&eval_tokens);
            }
            let out = rt
                .call(
                    artifact,
                    &[
                        Value::vec_f32(tr.params.clone()),
                        Value::mat_i32(toks, prof.batch,
                                       prof.seq_len + 1),
                        Value::vec_f32(theta_off.clone()),
                        Value::vec_f32(qs.clone()),
                    ],
                )
                .unwrap();
            (out[0].scalar().unwrap() as f64).exp()
        };

        let ppl_bf16 = eval_full("eval_tiny_bf16");
        let quant_art = format!("eval_tiny_{}",
                                if method == Method::Bf16 {
                                    "block".to_string()
                                } else {
                                    method.tag().to_string()
                                });
        let ppl_quant = eval_full(&quant_art);

        // no-leakage: per-token prefix eval through evalp_*
        let evalp_art = format!("evalp_tiny_{}",
                                if method == Method::Bf16 {
                                    "block".to_string()
                                } else {
                                    method.tag().to_string()
                                });
        let mut tot = 0.0f64;
        let mut cnt = 0usize;
        for tpos in 1..prof.seq_len {
            let out = rt
                .call(
                    &evalp_art,
                    &[
                        Value::vec_f32(tr.params.clone()),
                        Value::mat_i32(eval_tokens.clone(), 1,
                                       prof.seq_len + 1),
                        Value::vec_f32(theta_off.clone()),
                        Value::vec_f32(qs.clone()),
                        Value::scalar_i32(tpos as i32),
                    ],
                )
                .unwrap();
            let per = out[1].as_f32().unwrap();
            tot += per[tpos - 1] as f64; // loss of predicting token tpos
            cnt += 1;
        }
        let ppl_noleak = (tot / cnt as f64).exp();
        let _ = eval_with; // (kept for clarity; eval_full used instead)
        t.row(&[
            method.tag().into(),
            format!("{ppl_bf16:.3}"),
            format!("{ppl_quant:.3}"),
            format!("{ppl_noleak:.3}"),
            format!("{:+.3}", ppl_noleak - ppl_quant),
        ]);
    }
    t.print();
    println!("\npaper shape: Jetfire's Quant PPL beats its BF16/no-leak \
              PPL (AbsMax leaks future tokens); Ours is consistent \
              across all three evals (fallback defeats the leak)");
}
