//! Fig 3(c): gradient cosine similarity across fallback criteria
//! (AbsMax / L1 / L1-Rel) and fallback rates — the §4.4 selection study.

#[path = "common.rs"]
mod common;

use dbfq::coordinator::QScalars;
use dbfq::util::bench::Table;

fn main() {
    common::banner("Fig 3c — grad CosSim by fallback criterion x rate",
                   "Fig 3(c), §4.4: AbsMax ≈ L1 > L1-Rel");
    let rt = common::runtime();
    let probe = common::Probe::new(&rt, "probe", 3);
    let gref = probe.reference_grads();

    let criteria: [(&str, [f32; 3]); 3] = [
        ("AbsMax", [1.0, 0.0, 0.0]),
        ("L1", [0.0, 1.0, 0.0]),
        ("L1-Rel", [0.0, 0.0, 1.0]),
    ];
    let rates = [0.0f64, 0.05, 0.1, 0.2, 0.4];

    let mut t = Table::new(&["criterion", "rate", "achieved", "CosSim"]);
    for (name, crit) in criteria {
        // deterministic rounding isolates the criterion's effect on X
        // (SR noise otherwise floors the cosine for all criteria alike)
        let qs = QScalars { crit, sr_dy: 0.0, sr_ctx: 0.0,
                            ..QScalars::default() };
        for &rate in &rates {
            let theta = if rate == 0.0 {
                f32::INFINITY
            } else {
                probe.theta_for_rate(&qs, rate)
            };
            let (_, g, r) = probe.grads(&qs, theta, 1);
            let achieved = r.iter().map(|&x| x as f64).sum::<f64>()
                / r.len() as f64;
            t.row(&[
                name.into(),
                format!("{rate:.2}"),
                format!("{achieved:.3}"),
                format!("{:.5}", common::cos(&g, &gref)),
            ]);
        }
    }
    t.print();
    println!("\npaper shape: CosSim rises with rate; AbsMax and L1 \
              track each other, L1-Rel lags (relative error ignores \
              outlier magnitude). AbsMax is free from step 1 -> chosen.");
}
