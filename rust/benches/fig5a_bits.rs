//! Fig 5(a): gradient fidelity per precision-lattice rung — the
//! GEMM sites of one GLU transformer layer run through the *real*
//! engine data paths (`SimF32` / `Int8` / `Int4`, with and without
//! block-level fallback) instead of simulated bit-widths, with SR on
//! ∇Y throughout (§5.1). The paper shape this reproduces: plain INT4
//! visibly hurts the gradient, the staged Int4→Int8→f32 ladder on
//! the outlier blocks recovers it, and INT8 (± binary fallback)
//! stays near-exact.

#[path = "common.rs"]
mod common;

use dbfq::gemm::{grad_sr_seed, kernels, matmul, site_reference,
                 synth_microbatch, DataPath, GRAD_SR_SEED};
use dbfq::model::layer_linears;
use dbfq::quant::Rounding;
use dbfq::util::bench::Table;
use dbfq::util::rng::Pcg64;
use dbfq::util::Mat;

const BLOCK: usize = 16;
const THREADS: usize = 2;
const TOKENS: usize = 64;

fn main() {
    common::banner(
        "Fig 5a — gradient CosSim per lattice rung",
        "Fig 5(a), §5.1: activation outliers dominate the gradient \
         error at low bits; dynamic block-level fallback recovers it");
    let kn = kernels::select();
    let sites = layer_linears(32, 64, true, TOKENS);
    // outlier-bearing activations/gradients (the GLU gate site is
    // where the paper's extreme outliers live)
    let (acts, grads) = synth_microbatch(&sites, 5, 200.0);
    let weights: Vec<Mat> = sites
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let mut rng = Pcg64::new(0xF16_5A ^ (i as u64) << 11);
            Mat::randn(l.k, l.n, 0.05, &mut rng)
        })
        .collect();
    // exact dense references, concatenated across sites
    let mut dw_ref = Vec::new();
    let mut dx_ref = Vec::new();
    for (i, _) in sites.iter().enumerate() {
        dw_ref.extend_from_slice(
            &matmul(&acts[i].transpose(), &grads[i], THREADS).data);
        dx_ref.extend_from_slice(
            &matmul(&grads[i], &weights[i].transpose(), THREADS)
                .data);
    }

    // θ = ∞ pins every block on the rung's base precision; θ = 8
    // promotes the planted outlier blocks (binary fallback on the
    // i8 rungs, the staged I8/f32 tiers on Int4 — the outliers
    // exceed θ·κ and land on the exact-f32 tier).
    let cases: [(DataPath, f32, &str); 5] = [
        (DataPath::SimF32, f32::INFINITY, "sim_f32"),
        (DataPath::Int8, f32::INFINITY, "int8, no fallback"),
        (DataPath::Int8, 8.0, "int8 + fallback"),
        (DataPath::Int4, f32::INFINITY, "int4, no ladder"),
        (DataPath::Int4, 8.0, "int4 + staged ladder"),
    ];
    let mut t = Table::new(&["data path", "θ (X)", "CosSim dW",
                             "CosSim dX"]);
    for (path, theta, label) in cases {
        let mut dw = Vec::new();
        let mut dx = Vec::new();
        for (i, l) in sites.iter().enumerate() {
            let sr = Rounding::Stochastic(
                grad_sr_seed(GRAD_SR_SEED, 0, i));
            let out = site_reference(l, &weights[i], &acts[i],
                                     &grads[i], theta, sr, BLOCK,
                                     THREADS, path, kn);
            dw.extend_from_slice(&out.dw.data);
            dx.extend_from_slice(&out.dx.data);
        }
        t.row(&[
            label.into(),
            if theta.is_infinite() { "∞".into() }
            else { format!("{theta}") },
            format!("{:.5}", common::cos(&dw, &dw_ref)),
            format!("{:.5}", common::cos(&dx, &dx_ref)),
        ]);
    }
    t.print();
    println!("\npaper shape: with SR on ∇Y everywhere, the int4 rung \
              without fallback loses the most gradient fidelity (the \
              outlier blocks smear whole quantization groups), the \
              staged ladder recovers nearly all of it by promoting \
              only the hot blocks, and both int8 rows stay \
              near-exact — the block-level-fallback motivation of \
              Fig 5(a) on real engine data paths. dX rides plain \
              base quantization of dY per §5.1 (SR, no fallback), so \
              its column moves with the rung's bit-width alone.");
}
