//! Fig 5(a): gradient cosine when X / W / ∇Y are quantized to various
//! bit-widths individually — showing X dominates the gradient error
//! (with SR on ∇Y), which motivates fallback on X only.

#[path = "common.rs"]
mod common;

use dbfq::coordinator::QScalars;
use dbfq::util::bench::Table;

fn main() {
    common::banner("Fig 5a — per-tensor bit-width grad CosSim",
                   "Fig 5(a), §5.1: X's quantization error dominates \
                    when ∇Y uses stochastic rounding");
    let rt = common::runtime();
    let probe = common::Probe::new(&rt, "probe", 5);
    let gref = probe.reference_grads();

    let mut t = Table::new(&["tensor", "bits", "CosSim"]);
    for bits in [4u32, 6, 8] {
        for (name, which) in [("X", 0usize), ("W", 1), ("dY", 2)] {
            let mut qs = QScalars::lossless();
            qs.sr_dy = 1.0; // paper default: SR on gradients
            let lv = (1u32 << (bits - 1)) as f32 - 1.0;
            match which {
                0 => qs.levels_x = lv,
                1 => qs.levels_w = lv,
                _ => qs.levels_dy = lv,
            }
            let (_, g, _) = probe.grads(&qs, f32::INFINITY, 1);
            t.row(&[
                name.into(),
                bits.to_string(),
                format!("{:.5}", common::cos(&g, &gref)),
            ]);
        }
    }
    t.print();
    println!("\npaper shape: with SR on ∇Y, X's (or, here, the \
              outlier-carrying tensor's) deterministic quantization \
              error dominates at low bits while SR keeps ∇Y unbiased. \
              NOTE: this testbed injects outliers via weight rows (no \
              trillion-token training run), so W shares X's burden; in \
              the paper the outliers live in activations only.");
}
