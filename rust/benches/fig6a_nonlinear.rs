//! Fig 6(a): PPL impact of quantizing Linear inputs vs Non-Linear
//! inputs at various bit-widths — non-linear layers are the fragile
//! ones (they cannot average errors over a K-dim accumulation).

#[path = "common.rs"]
mod common;

use dbfq::coordinator::QScalars;
use dbfq::data::Corpus;
use dbfq::model::Method;
use dbfq::runtime::Value;
use dbfq::util::bench::Table;

fn main() {
    common::banner("Fig 6a — linear vs non-linear input quantization",
                   "Fig 6(a), §5.2: non-linear layers are far more \
                    sensitive per bit");
    let rt = common::runtime();
    let steps = common::bench_steps(60);
    let tr = common::trained(&rt, "small", Method::Bf16, steps, 11);
    let prof = rt.profile("small").unwrap().clone();
    let corpus = Corpus::synthetic(100_000, prof.vocab, 99);
    let batches = corpus.eval_batches(prof.batch, prof.seq_len, 3);

    let eval = |qs: &QScalars| -> f64 {
        let mut tot = 0.0;
        for b in &batches {
            let out = rt
                .call(
                    "eval_small_fallback",
                    &[
                        Value::vec_f32(tr.params.clone()),
                        Value::mat_i32(b.clone(), prof.batch,
                                       prof.seq_len + 1),
                        Value::vec_f32(vec![f32::INFINITY;
                                            prof.n_sites]),
                        Value::vec_f32(qs.to_vec()),
                    ],
                )
                .unwrap();
            tot += out[0].scalar().unwrap() as f64;
        }
        (tot / batches.len() as f64).exp()
    };

    let base = eval(&QScalars::lossless());
    println!("lossless PPL: {base:.3}\n");
    let mut t = Table::new(&["bits", "linear-only ΔPPL",
                             "non-linear-only ΔPPL"]);
    for bits in [4u32, 6, 8, 10] {
        let mut lin = QScalars::lossless();
        lin.levels_x = (1u32 << (bits - 1)) as f32 - 1.0;
        lin.levels_w = lin.levels_x;
        let mut nl = QScalars::lossless();
        nl.nl_in_bits = bits as f32; // forward-path non-linear inputs
        t.row(&[
            bits.to_string(),
            format!("{:+.3}", eval(&lin) - base),
            format!("{:+.3}", eval(&nl) - base),
        ]);
    }
    t.print();
    println!("\nnote: at this testbed's training budget (tens of \
              steps) eval-PPL deltas are noise-dominated and low-bit \
              quantization can even act as a regularizer; the robust \
              reproduction of Fig 6a's sensitivity ordering is the \
              gradient-side sweep (fig7a_ctx_bits: non-linear context \
              bits dominate norm-weight gradient fidelity) plus the \
              test_jetfire_int8_dataflow_degrades_nonlinear_grads \
              pytest. Paper shape: low-bit hurts non-linear paths far \
              more per bit.");
}
