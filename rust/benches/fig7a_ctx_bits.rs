//! Fig 7(a): gradient cosine (whole model + norm-weight subset) vs the
//! bit-width of the 1x128 group-quantized non-linear contexts — the
//! INT10 choice (§5.2).

#[path = "common.rs"]
mod common;

use dbfq::coordinator::QScalars;
use dbfq::util::bench::Table;

fn main() {
    common::banner("Fig 7a — grad CosSim vs non-linear context bits",
                   "Fig 7(a), §5.2: 10-bit contexts are near-lossless");
    let rt = common::runtime();
    let probe = common::Probe::new(&rt, "probe", 9);
    let gref = probe.reference_grads();

    // norm-gamma parameter slices from the manifest layout
    let prof = rt.profile("probe").unwrap().clone();
    let norm_ranges: Vec<(usize, usize)> = prof
        .param_layout
        .iter()
        .filter(|l| l.name.contains("ln"))
        .map(|l| (l.offset, l.offset + l.size))
        .collect();
    let norm_slice = |g: &[f32]| -> Vec<f32> {
        norm_ranges
            .iter()
            .flat_map(|&(a, b)| g[a..b].to_vec())
            .collect()
    };
    let gref_norm = norm_slice(&gref);

    let mut t = Table::new(&["ctx bits", "model CosSim", "norm-w CosSim"]);
    for bits in [4.0f32, 6.0, 8.0, 10.0, 12.0] {
        let mut qs = QScalars::lossless();
        qs.ctx_bits = bits;
        let (_, g, _) = probe.grads(&qs, f32::INFINITY, 1);
        t.row(&[
            format!("{bits:.0}"),
            format!("{:.6}", common::cos(&g, &gref)),
            format!("{:.6}", common::cos(&norm_slice(&g), &gref_norm)),
        ]);
    }
    t.print();
    println!("\npaper shape: monotone in bits; >=10 bits ≈ 1.0 for both \
              (norm weights are the sensitive ones) -> INT10 contexts \
              at 5/8 of BF16 memory");
}
