//! Fig 4(a): spatial distribution of fallback blocks in a DownProj
//! input at 20% overall rate — channel-wise stripes + occasional
//! scattered blocks.

#[path = "common.rs"]
mod common;

use dbfq::outlier::{column_concentration, fallback_map, ActivationModel};
use dbfq::util::bench::Table;

fn main() {
    common::banner("Fig 4a — fallback block map @ 20% rate",
                   "Fig 4(a), §4.4: dynamic fallback covers occasional \
                    outliers while preserving per-channel ones");
    let act = ActivationModel::glu_llm(1024, 2048).sample(21);
    let (u, rb, cb) = fallback_map(&act, 128, 0.2);
    println!("map ({rb} x {cb} blocks, '#' = fallback):");
    for r in 0..rb {
        let row: String = (0..cb)
            .map(|c| if u[r * cb + c] { '#' } else { '.' })
            .collect();
        println!("  {row}");
    }
    let mut t = Table::new(&["metric", "value"]);
    let rate = u.iter().filter(|&&b| b).count() as f64 / u.len() as f64;
    t.row(&["achieved rate".into(), format!("{rate:.3}")]);
    for k in [1usize, 2, 4] {
        t.row(&[
            format!("share in top-{k} columns"),
            format!("{:.2}", column_concentration(&u, rb, cb, k)),
        ]);
    }
    // scattered blocks = fallback blocks outside the top-2 columns
    let scattered = 1.0 - column_concentration(&u, rb, cb, 2);
    t.row(&["scattered (occasional) share".into(),
            format!("{scattered:.2}")]);
    t.print();
    println!("\npaper shape: strong column structure (channel outliers) \
              plus a scattered remainder (occasional outliers, P2)");
}
