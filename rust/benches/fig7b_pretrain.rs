//! Fig 7(b): pretraining + validation loss curves for BF16 / Block /
//! Jetfire / Fallback on identical data order.
//!
//! If `runs/pretrain_small_0.jsonl` exists (from the pretrain_e2e
//! example) its curves are summarized; otherwise a short 4-way run on
//! the tiny profile regenerates the figure's shape directly.

#[path = "common.rs"]
mod common;

use dbfq::coordinator::TrainConfig;
use dbfq::data::Corpus;
use dbfq::model::Method;
use dbfq::util::bench::Table;
use dbfq::util::json::Json;
use dbfq::util::rng::Pcg64;

fn main() {
    common::banner("Fig 7b — pretrain/val loss curves per method",
                   "Fig 7(b), §6.2: Ours overlaps BF16; Jetfire \
                    deviates early");
    // summarize prior long runs if present
    if let Ok(text) = std::fs::read_to_string("runs/pretrain_small_0.jsonl")
    {
        println!("(found runs/pretrain_small_0.jsonl — summarizing)");
        let mut t = Table::new(&["run", "step", "train", "val"]);
        for line in text.lines() {
            if let Ok(j) = Json::parse(line) {
                if j.get("val_loss").is_some() {
                    t.row(&[
                        j.req("run").as_str().unwrap_or("?").into(),
                        format!("{}", j.req("step").as_f64().unwrap()),
                        format!("{:.4}", j.req("loss").as_f64().unwrap()),
                        format!("{:.4}",
                                j.req("val_loss").as_f64().unwrap()),
                    ]);
                }
            }
        }
        t.print();
    }

    // fresh 4-way comparison on tiny
    let rt = common::runtime();
    let steps = common::bench_steps(80);
    let prof = rt.profile("tiny").unwrap().clone();
    let corpus = Corpus::synthetic(200_000, prof.vocab, 1234);
    let eval_batches = corpus.eval_batches(prof.batch, prof.seq_len, 4);

    let mut t = Table::new(&["method", "step", "train", "val"]);
    for method in Method::all() {
        let mut cfg = TrainConfig::new("tiny", method, 0, steps);
        cfg.lr.peak = 1e-3;
        let mut tr = dbfq::coordinator::Trainer::new(&rt, cfg).unwrap();
        let mut rng = Pcg64::new(42); // identical data order per method
        for s in 0..steps {
            let toks =
                corpus.sample_batch(prof.batch, prof.seq_len, &mut rng);
            let st = tr.step_on(&toks).unwrap();
            if (s + 1) % (steps / 4).max(1) == 0 {
                let vl = tr.eval_on(&eval_batches).unwrap();
                t.row(&[
                    method.tag().into(),
                    st.step.to_string(),
                    format!("{:.4}", st.loss),
                    format!("{vl:.4}"),
                ]);
            }
        }
    }
    t.print();
    println!("\npaper shape: fallback val-curve tracks bf16; jetfire's \
              int8 non-linear dataflow lags (and in the paper *leaks* — \
              see table4_leakage)");
}
