//! Fig 8(a): finetune loss across seeds — Block's instability vs
//! Fallback's robustness on the GSM8K-like task.

#[path = "common.rs"]
mod common;

use dbfq::coordinator::TrainConfig;
use dbfq::data::Task;
use dbfq::model::Method;
use dbfq::util::bench::Table;
use dbfq::util::rng::Pcg64;

fn main() {
    common::banner("Fig 8a — finetune stability across seeds",
                   "Fig 8(a), §6.1: Block diverges on some seeds; Ours \
                    converges on all");
    let rt = common::runtime();
    let steps = common::bench_steps(60);
    let prof = rt.profile("tiny").unwrap().clone();
    let task = Task::Arithmetic;

    let mut t = Table::new(&["method", "seed", "final-loss", "max-loss",
                             "diverged?"]);
    for method in [Method::Block, Method::Fallback] {
        for seed in 0..3u64 {
            let mut cfg = TrainConfig::new("tiny", method, seed, steps);
            // finetune-style aggressive LR stresses stability (the
            // paper's GSM8K failure mode)
            cfg.lr.peak = 3e-3;
            let mut tr =
                dbfq::coordinator::Trainer::new(&rt, cfg).unwrap();
            let mut rng = Pcg64::new(seed ^ 0xF1E7);
            let mut max_loss = 0.0f64;
            let mut final_loss = 0.0f64;
            for _ in 0..steps {
                let (toks, _) = task.batch(prof.batch, prof.seq_len,
                                           prof.vocab, &mut rng);
                let st = tr.step_on(&toks).unwrap();
                max_loss = max_loss.max(st.loss);
                final_loss = st.loss;
            }
            let first = tr.history[0].loss;
            let diverged = !final_loss.is_finite()
                || final_loss > first * 1.05
                || max_loss > first * 2.0;
            t.row(&[
                method.tag().into(),
                seed.to_string(),
                format!("{final_loss:.4}"),
                format!("{max_loss:.4}"),
                if diverged { "YES".into() } else { "no".into() },
            ]);
        }
    }
    t.print();
    println!("\npaper shape: Ours' final losses cluster tightly across \
              seeds; Block shows higher variance / spikes at small \
              scale (full divergence needs the paper's 1.5B model)");
}
