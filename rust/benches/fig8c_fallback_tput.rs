//! Fig 8(c): Fallback GEMM kernel throughput — random vs sequential
//! (worst-case) fallback block placement.

#[path = "common.rs"]
mod common;

use dbfq::costmodel::rtx4090;
use dbfq::gemm::{self, Placement};
use dbfq::quant::{self, Criterion, Rounding, INT8_LEVELS};
use dbfq::util::bench::{bench, gops, Table};
use dbfq::util::rng::Pcg64;
use dbfq::util::Mat;

fn main() {
    common::banner("Fig 8c — fallback GEMM throughput vs rate/placement",
                   "Fig 8(c), §6.3; also Appendix B");

    // CPU-measured: real conditional skipping, both placements.
    let dim = 768usize;
    let block = 128;
    let mut rng = Pcg64::new(3);
    let mut a = Mat::randn(dim, dim, 1.0, &mut rng);
    // channel-structured outliers so Natural placement is column-wise
    for c in 0..dim {
        if c % 97 == 0 {
            for r in 0..dim {
                if rng.uniform() < 0.3 {
                    a.data[r * dim + c] = 200.0 * (1.0 + rng.uniform_f32());
                }
            }
        }
    }
    let b = Mat::randn(dim, dim, 1.0, &mut rng);
    let qb = quant::block_quant(&b, block, INT8_LEVELS, Rounding::Nearest);
    let probe = quant::fallback_quant(&a, f32::INFINITY, block,
                                      INT8_LEVELS, Criterion::AbsMax);

    let mut t = Table::new(&["rate", "placement", "Gops(cpu)",
                             "overhead"]);
    let mut base_gops = 0.0;
    for rate in [0.0, 0.1, 0.2, 0.4] {
        let theta = quant::theta_for_rate(&probe.metric, rate);
        let fa = quant::fallback_quant(&a, theta, block, INT8_LEVELS,
                                       Criterion::AbsMax);
        for placement in [Placement::Random(9), Placement::Sequential] {
            let u = gemm::remap_placement(&fa, placement);
            let s = bench(|| {
                std::hint::black_box(gemm::fallback_gemm(&fa, &qb, &u, 1));
            }, 250);
            let g = gops(dim, dim, dim, s.median_secs());
            if rate == 0.0 && placement == Placement::Random(9) {
                base_gops = g;
            }
            t.row(&[
                format!("{:.2}", fa.fallback_rate()),
                format!("{placement:?}"),
                format!("{g:.2}"),
                format!("{:+.1}%", 100.0 * (base_gops / g - 1.0)),
            ]);
        }
    }
    t.print();
    println!("(CPU is a single worker: placements match in time; the \
              paper's imbalance effect is modeled below)");

    // 4090 roofline with SM-level makespan skew.
    let g4090 = rtx4090();
    let mut t2 = Table::new(&["dim", "rate", "random(Tops)",
                              "sequential(Tops)"]);
    for dim in [2048usize, 4096, 8192] {
        for rate in [0.1, 0.2, 0.3] {
            t2.row(&[
                dim.to_string(),
                format!("{rate:.1}"),
                format!("{:.0}",
                        g4090.int8_gemm_tops(dim, dim, dim, 128, rate)),
                format!("{:.0}",
                        g4090.int8_gemm_tops_worst(dim, dim, dim, 128,
                                                   rate)),
            ]);
        }
    }
    println!("\nRTX4090 roofline (paper: small GEMM suffers most from \
              sequential placement):");
    t2.print();
}
