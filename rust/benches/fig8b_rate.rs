//! Fig 8(b): training under *constant* fallback rates — convergence is
//! achievable at 2.5% and stable at 10% (§6.1 ablation).

#[path = "common.rs"]
mod common;

use dbfq::coordinator::TrainConfig;
use dbfq::data::Task;
use dbfq::model::Method;
use dbfq::util::bench::Table;
use dbfq::util::rng::Pcg64;

fn main() {
    common::banner("Fig 8b — loss vs constant fallback rate",
                   "Fig 8(b), §6.1: converges from 2.5% fallback on");
    let rt = common::runtime();
    let steps = common::bench_steps(60);
    let prof = rt.profile("tiny").unwrap().clone();
    let task = Task::Arithmetic;

    let mut t = Table::new(&["target rate", "mean achieved",
                             "final-loss"]);
    for rate in [0.0f64, 0.025, 0.05, 0.1, 0.2] {
        let mut cfg =
            TrainConfig::new("tiny", Method::Fallback, 1, steps);
        cfg.lr.peak = 3e-3;
        // pin the band to the target: Alg 2 holds the rate ~constant
        cfg.r_min = (rate - 0.01).max(0.0);
        cfg.r_max = rate + 0.01;
        cfg.alpha = 1.1;
        if rate == 0.0 {
            cfg.freeze_thresholds = true;
        }
        let mut tr = dbfq::coordinator::Trainer::new(&rt, cfg).unwrap();
        if rate == 0.0 {
            tr.set_thresholds(f32::INFINITY);
        }
        let mut rng = Pcg64::new(0xF1E7);
        let mut final_loss = 0.0;
        let mut rate_acc = 0.0;
        for _ in 0..steps {
            let (toks, _) = task.batch(prof.batch, prof.seq_len,
                                       prof.vocab, &mut rng);
            let st = tr.step_on(&toks).unwrap();
            final_loss = st.loss;
            rate_acc += st.mean_fallback_rate;
        }
        t.row(&[
            format!("{rate:.3}"),
            format!("{:.3}", rate_acc / steps as f64),
            format!("{final_loss:.4}"),
        ]);
    }
    t.print();
    println!("\npaper shape: final loss improves sharply from 0% -> \
              2.5% and saturates by ~10% — a little fallback buys most \
              of the accuracy");
}
