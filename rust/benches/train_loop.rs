//! End-to-end training loop: optimizer + data loader + loss over the
//! quantized substrate, at CPU toy scale.
//!
//! Four phases:
//!
//! * `pretrain` — Fig-7b-style trend: the same synthetic-corpus run
//!   on the quantized engine (`Int8` + dynamic fallback) and on the
//!   exact dense-f32 reference; loss curves, held-out eval loss
//!   before/after, and the final-loss gap between the two. Also
//!   times the quantized step and compares it against the cost
//!   model's `substrate_train_step_secs` projection (measured
//!   calibration + the optimizer's per-param flops).
//! * `finetune` — Table-2-style trend: fresh runs on the arithmetic
//!   task, quantized vs exact, scored by `answer_span_loss` on a
//!   held-out batch before and after training.
//! * `checkpoint` — save at the midpoint, restore through JSON text,
//!   run the remainder, and record whether the resumed loss curve is
//!   bit-identical to the uninterrupted one.
//! * `glu` — the SwiGLU surrogate (5 quantized sites per layer) on
//!   the live data path (`PALLAS_PATH` selects the lattice rung)
//!   with outlier telemetry on: loss curve, per-tier fallback
//!   rates, and the summed per-block activation-magnitude
//!   histogram.
//!
//! Emits `BENCH_train_loop.json` (schema in `docs/BENCHMARKS.md`).
//! Set `BENCH_SMOKE=1` for a seconds-long CI smoke run;
//! `DBFQ_BENCH_STEPS=N` overrides the pretrain step count.

use std::time::Instant;

use dbfq::coordinator::LrSchedule;
use dbfq::costmodel::SubstrateCalibration;
use dbfq::data::{answer_span_loss, Corpus, Task};
use dbfq::train::{Loader, TokenBatch, TrainLoop, TrainLoopConfig};
use dbfq::util::json::{arr_f64, obj, Json};

const VOCAB: usize = 64;
const SEQ: usize = 8;

fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

fn base_cfg(steps: usize, exact: bool) -> TrainLoopConfig {
    let mut cfg = TrainLoopConfig::new(1, 32, 48, VOCAB, 4, SEQ, 16);
    cfg.lr = LrSchedule { peak: 5e-3, warmup: 10, total: steps };
    cfg.exact = exact;
    cfg
}

/// Mean held-out loss over deterministic non-overlapping windows.
fn eval_corpus_loss(tl: &TrainLoop, corpus: &Corpus) -> f64 {
    let batches = corpus.eval_batches(4, SEQ, 4);
    let mut sum = 0.0;
    for b in &batches {
        let tb = TokenBatch {
            tokens: b.clone(),
            batch: 4,
            seq: SEQ,
            spans: None,
        };
        sum += tl.eval_loss(&tb);
    }
    sum / batches.len() as f64
}

/// Answer-span loss on a held-out finetune batch (stream position
/// far past anything training touches).
fn eval_span_loss(tl: &TrainLoop, loader: &Loader) -> f64 {
    let tb = loader.batch_at(1 << 20);
    let per_token = tl.eval_per_token(&tb);
    answer_span_loss(&per_token, tb.batch, tb.seq,
                     tb.spans.as_ref().unwrap())
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let steps = std::env::var("DBFQ_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(if smoke { 40 } else { 200 });
    let ft_steps = if smoke { 25 } else { 120 };

    println!("\n================================================");
    println!(
        "train loop: 1 layer d=32 ff=48 vocab={VOCAB} batch=4 \
         seq={SEQ} block=16; pretrain {steps} steps, finetune \
         {ft_steps} steps"
    );
    println!("================================================");

    // -- pretrain: quantized vs exact --------------------------------
    let corpus = Corpus::synthetic(2000, VOCAB, 13);
    let pretrain_run = |exact: bool| {
        let cfg = base_cfg(steps, exact);
        let loader =
            Loader::pretrain(corpus.clone(), 4, SEQ, 71);
        let mut tl = TrainLoop::new(cfg, loader);
        let eval0 = eval_corpus_loss(&tl, &corpus);
        let mut losses = Vec::with_capacity(steps);
        let mut rates = Vec::with_capacity(steps);
        let mut step_ms = Vec::with_capacity(steps);
        for _ in 0..steps {
            let t = Instant::now();
            let st = tl.step_once();
            step_ms.push(t.elapsed().as_secs_f64() * 1e3);
            losses.push(st.loss);
            rates.push(st.fallback_rate);
        }
        let eval1 = eval_corpus_loss(&tl, &corpus);
        (tl, losses, rates, step_ms, eval0, eval1)
    };
    let (q_tl, q_losses, q_rates, q_step_ms, q_eval0, q_eval1) =
        pretrain_run(false);
    let (_e_tl, e_losses, _, _, e_eval0, e_eval1) =
        pretrain_run(true);
    let tail = |v: &[f64]| -> f64 {
        let n = v.len().min(10);
        v[v.len() - n..].iter().sum::<f64>() / n as f64
    };
    let head = |v: &[f64]| -> f64 {
        let n = v.len().min(10);
        v[..n].iter().sum::<f64>() / n as f64
    };
    let (q_first, q_last) = (head(&q_losses), tail(&q_losses));
    let (e_first, e_last) = (head(&e_losses), tail(&e_losses));
    let final_gap = (q_last - e_last).abs();
    let mean_rate =
        q_rates.iter().sum::<f64>() / q_rates.len().max(1) as f64;
    println!(
        "pretrain quantized: train {q_first:.3} -> {q_last:.3}, \
         eval {q_eval0:.3} -> {q_eval1:.3}, mean fallback rate \
         {mean_rate:.3}"
    );
    println!(
        "pretrain exact:     train {e_first:.3} -> {e_last:.3}, \
         eval {e_eval0:.3} -> {e_eval1:.3}; final-loss gap \
         {final_gap:.3}"
    );

    // Step-time projection from a measured calibration: GEMM
    // substrate estimate + optimizer elementwise cost.
    let cfg = q_tl.config();
    let cal_dim = if smoke { 96 } else { 256 };
    let cal = SubstrateCalibration::measure(
        cal_dim, cfg.block.min(cal_dim), cfg.threads);
    let proj_ms = cal.substrate_train_step_secs(
        cfg.layers, cfg.d_model, cfg.d_ff, false, cfg.vocab,
        cfg.tokens(), mean_rate, cfg.accum,
        q_tl.optimizer().flops_per_param()) * 1e3;
    let measured_ms = median(&q_step_ms);
    println!(
        "step time: measured {measured_ms:.2} ms vs substrate \
         projection {proj_ms:.2} ms"
    );

    // -- finetune: answer-span loss before/after ---------------------
    let finetune_run = |exact: bool| {
        let mut cfg = base_cfg(ft_steps, exact);
        cfg.seq = 16;
        cfg.lr = LrSchedule { peak: 3e-3, warmup: 5,
                              total: ft_steps };
        let loader =
            Loader::finetune(Task::Arithmetic, VOCAB, 4, 16, 77);
        let mut tl = TrainLoop::new(cfg, loader);
        let before = eval_span_loss(&tl, tl.loader());
        let losses: Vec<f64> = tl
            .run(ft_steps)
            .iter()
            .map(|s| s.loss)
            .collect();
        let after = eval_span_loss(&tl, tl.loader());
        (losses, before, after)
    };
    let (qf_losses, qf_before, qf_after) = finetune_run(false);
    let (ef_losses, ef_before, ef_after) = finetune_run(true);
    println!(
        "finetune span loss: quantized {qf_before:.3} -> \
         {qf_after:.3}, exact {ef_before:.3} -> {ef_after:.3}"
    );

    // -- checkpoint: mid-run save/restore bit-identity ---------------
    let ck_steps = if smoke { 12 } else { 30 };
    let half = ck_steps / 2;
    let ck_cfg = || base_cfg(ck_steps, false);
    let ck_loader =
        || Loader::pretrain(corpus.clone(), 4, SEQ, 99);
    let mut straight = TrainLoop::new(ck_cfg(), ck_loader());
    let full: Vec<u64> = straight
        .run(ck_steps)
        .iter()
        .map(|s| s.loss.to_bits())
        .collect();
    let mut first = TrainLoop::new(ck_cfg(), ck_loader());
    let mut rejoined: Vec<u64> = first
        .run(half)
        .iter()
        .map(|s| s.loss.to_bits())
        .collect();
    let state_text = first.checkpoint().to_string();
    let parsed = Json::parse(&state_text)
        .expect("checkpoint must serialize to valid JSON");
    let mut resumed =
        TrainLoop::from_checkpoint(ck_cfg(), ck_loader(), &parsed)
            .expect("checkpoint restore");
    rejoined.extend(
        resumed
            .run(ck_steps - half)
            .iter()
            .map(|s| s.loss.to_bits()),
    );
    let ck_identical = rejoined == full;
    assert!(ck_identical,
            "resumed run must be bit-identical to the \
             uninterrupted one");
    println!(
        "checkpoint: {half}+{} steps bit-identical to {ck_steps} \
         straight ({} byte state)",
        ck_steps - half,
        state_text.len()
    );

    // -- GLU surrogate + lattice telemetry ----------------------------
    // The SwiGLU model (5 quantized sites per layer) on the live
    // data path with outlier telemetry on: per-tier fallback rates
    // and the per-block activation-magnitude histogram, summed over
    // the run.
    let glu_steps = if smoke { 10 } else { 40 };
    let mut glu_cfg = base_cfg(glu_steps, false);
    glu_cfg.glu = true;
    glu_cfg.telemetry = true;
    let mut glu_tl = TrainLoop::new(
        glu_cfg, Loader::pretrain(corpus.clone(), 4, SEQ, 171));
    let glu_stats = glu_tl.run(glu_steps);
    let glu_losses: Vec<f64> =
        glu_stats.iter().map(|s| s.loss).collect();
    let glu_rate = glu_stats.iter()
        .map(|s| s.fallback_rate)
        .sum::<f64>() / glu_steps as f64;
    let glu_rate_f32 = glu_stats.iter()
        .map(|s| s.fallback_rate_f32)
        .sum::<f64>() / glu_steps as f64;
    let mut glu_hist: Vec<u64> = Vec::new();
    for s in &glu_stats {
        if let Some(h) = &s.outlier_hist {
            if glu_hist.is_empty() {
                glu_hist = vec![0; h.len()];
            }
            for (a, &v) in glu_hist.iter_mut().zip(h) {
                *a += v;
            }
        }
    }
    let (glu_first, glu_last) =
        (head(&glu_losses), tail(&glu_losses));
    println!(
        "glu pretrain ({} path): train {glu_first:.3} -> \
         {glu_last:.3}, tier rates i8+={glu_rate:.3} \
         f32={glu_rate_f32:.3}, {} histogram counts",
        glu_tl.config().path.tag(),
        glu_hist.iter().sum::<u64>()
    );

    // -- report -------------------------------------------------------
    let report = obj(vec![
        ("bench", Json::Str("train_loop".into())),
        ("smoke", Json::Bool(smoke)),
        ("config", obj(vec![
            ("layers", Json::Num(cfg.layers as f64)),
            ("d_model", Json::Num(cfg.d_model as f64)),
            ("d_ff", Json::Num(cfg.d_ff as f64)),
            ("vocab", Json::Num(cfg.vocab as f64)),
            ("batch", Json::Num(cfg.batch as f64)),
            ("seq", Json::Num(cfg.seq as f64)),
            ("block", Json::Num(cfg.block as f64)),
            ("threads", Json::Num(cfg.threads as f64)),
            ("path", Json::Str(cfg.path.tag().into())),
            ("accum", Json::Num(cfg.accum as f64)),
            ("steps", Json::Num(steps as f64)),
            ("optimizer",
             Json::Str(q_tl.optimizer().name().into())),
            ("kernel_backend",
             Json::Str(q_tl.model()
                 .map(|m| m.kernel_backend())
                 .unwrap_or("exact")
                 .into())),
        ])),
        ("pretrain", obj(vec![
            ("quantized", obj(vec![
                ("loss", arr_f64(&q_losses)),
                ("train_first", Json::Num(q_first)),
                ("train_last", Json::Num(q_last)),
                ("eval_before", Json::Num(q_eval0)),
                ("eval_after", Json::Num(q_eval1)),
                ("mean_fallback_rate", Json::Num(mean_rate)),
            ])),
            ("exact", obj(vec![
                ("loss", arr_f64(&e_losses)),
                ("train_first", Json::Num(e_first)),
                ("train_last", Json::Num(e_last)),
                ("eval_before", Json::Num(e_eval0)),
                ("eval_after", Json::Num(e_eval1)),
            ])),
            ("final_loss_gap", Json::Num(final_gap)),
            ("step_ms_median", Json::Num(measured_ms)),
            ("projected_step_ms", Json::Num(proj_ms)),
        ])),
        ("finetune", obj(vec![
            ("task", Json::Str("arithmetic".into())),
            ("steps", Json::Num(ft_steps as f64)),
            ("quantized", obj(vec![
                ("loss", arr_f64(&qf_losses)),
                ("span_loss_before", Json::Num(qf_before)),
                ("span_loss_after", Json::Num(qf_after)),
            ])),
            ("exact", obj(vec![
                ("loss", arr_f64(&ef_losses)),
                ("span_loss_before", Json::Num(ef_before)),
                ("span_loss_after", Json::Num(ef_after)),
            ])),
        ])),
        ("checkpoint", obj(vec![
            ("steps", Json::Num(ck_steps as f64)),
            ("split_at", Json::Num(half as f64)),
            ("state_bytes", Json::Num(state_text.len() as f64)),
            ("bit_identical", Json::Bool(ck_identical)),
        ])),
        ("glu", obj(vec![
            ("steps", Json::Num(glu_steps as f64)),
            ("path",
             Json::Str(glu_tl.config().path.tag().into())),
            ("loss", arr_f64(&glu_losses)),
            ("train_first", Json::Num(glu_first)),
            ("train_last", Json::Num(glu_last)),
            // per-tier executed promotion rates: the binary
            // fallback rate on Int8/SimF32, tier >= Int8 and the
            // f32 remainder on the Int4 lattice
            ("tier_rates", obj(vec![
                ("i8_or_fallback", Json::Num(glu_rate)),
                ("f32", Json::Num(glu_rate_f32)),
            ])),
            // per-block AbsMax histogram, f32-exponent bins
            // (bin b = exponent b - 8), summed over the run
            ("outlier_histogram", Json::Arr(
                glu_hist.iter()
                    .map(|&v| Json::Num(v as f64))
                    .collect())),
        ])),
        ("criteria", obj(vec![
            // Both engines must actually learn…
            ("quantized_train_delta",
             Json::Num(q_first - q_last)),
            ("exact_train_delta", Json::Num(e_first - e_last)),
            // …and land near each other (Fig-7b trend; exactly 0 is
            // not expected — SR quantization noise is real).
            ("final_loss_gap", Json::Num(final_gap)),
            ("finetune_span_delta_quantized",
             Json::Num(qf_before - qf_after)),
            ("finetune_span_delta_exact",
             Json::Num(ef_before - ef_after)),
            ("glu_train_delta",
             Json::Num(glu_first - glu_last)),
            ("checkpoint_bit_identical",
             Json::Bool(ck_identical)),
        ])),
    ]);
    report
        .to_file("BENCH_train_loop.json")
        .expect("write BENCH_train_loop.json");
    println!("\nwrote BENCH_train_loop.json");
}
