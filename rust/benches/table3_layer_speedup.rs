//! Table 3: GPT-2 transformer-layer speedup vs BF16 at hidden sizes
//! 1024/2048/4096 — forward / backward / overall, Jetfire (32-group)
//! vs Ours (128-group + 20% fallback).

#[path = "common.rs"]
mod common;

use dbfq::costmodel::rtx4090;
use dbfq::gemm;
use dbfq::quant::{block_quant, Rounding, INT8_LEVELS};
use dbfq::util::bench::{bench, Table};
use dbfq::util::rng::Pcg64;
use dbfq::util::Mat;

fn main() {
    common::banner("Table 3 — layer speedup vs hidden size",
                   "Table 3, §6.3: ours 1.31x/1.73x/1.92x overall at \
                    1024/2048/4096");
    let g = rtx4090();
    let tokens = 2048; // 2 x 1024 (paper: microbatch 2, seq 1024)

    let mut t = Table::new(&["hidden", "method", "fwd", "bwd",
                             "overall"]);
    for hidden in [1024usize, 2048, 4096] {
        let bf_f = g.layer_secs(hidden, tokens, false, 128, 0.0, false);
        let bf_fb = g.layer_secs(hidden, tokens, false, 128, 0.0, true);
        let bf_b = bf_fb - bf_f;
        for (name, kg, rate) in [("Jetfire", 32usize, 0.0),
                                 ("Ours", 128, 0.2)] {
            let q_f = g.layer_secs(hidden, tokens, true, kg, rate, false);
            let q_fb = g.layer_secs(hidden, tokens, true, kg, rate, true);
            let q_b = q_fb - q_f;
            t.row(&[
                hidden.to_string(),
                name.into(),
                format!("{:.2}", bf_f / q_f),
                format!("{:.2}", bf_b / q_b),
                format!("{:.2}", bf_fb / q_fb),
            ]);
        }
    }
    println!("modeled on RTX4090 roofline:");
    t.print();

    // CPU-measured miniature of the same structure (hidden scaled down):
    // one layer's 4 GEMMs, f32 vs int8-128 vs int8-32.
    println!("\nCPU-measured layer GEMM bundle (hidden=256, tokens=256):");
    let hidden = 256usize;
    let toks = 256usize;
    let mut rng = Pcg64::new(5);
    let shapes = [(toks, 3 * hidden, hidden), (toks, hidden, hidden),
                  (toks, 4 * hidden, hidden), (toks, hidden, 4 * hidden)];
    let mut t2 = Table::new(&["variant", "secs", "speedup"]);
    let mats: Vec<(Mat, Mat)> = shapes
        .iter()
        .map(|&(m, n, k)| {
            (Mat::randn(m, k, 1.0, &mut rng),
             Mat::randn(k, n, 1.0, &mut rng))
        })
        .collect();
    let s_f32 = bench(|| {
        for (a, b) in &mats {
            std::hint::black_box(gemm::matmul(a, b, 1));
        }
    }, 400).median_secs();
    t2.row(&["f32 (bf16 stand-in)".into(), format!("{s_f32:.4}"),
             "1.00".into()]);
    for group in [32usize, 128] {
        let quants: Vec<_> = mats
            .iter()
            .map(|(a, b)| {
                (block_quant(a, group, INT8_LEVELS, Rounding::Nearest),
                 block_quant(b, group, INT8_LEVELS, Rounding::Nearest))
            })
            .collect();
        let s = bench(|| {
            for (qa, qb) in &quants {
                std::hint::black_box(gemm::block_gemm(qa, qb, 1));
            }
        }, 400).median_secs();
        t2.row(&[format!("int8 group={group}"), format!("{s:.4}"),
                 format!("{:.2}", s_f32 / s)]);
    }
    t2.print();
    println!("\npaper shape: larger groups win; speedup grows with \
              hidden size (bwd benefits most at 4096)");
}
