//! Fig 4(b): eval perplexity vs quantization block size (32..256),
//! naive block quantization vs 20% AbsMax fallback — the argument that
//! fallback lets a 128-block kernel match a 32-block kernel's accuracy.

#[path = "common.rs"]
mod common;

use dbfq::coordinator::QScalars;
use dbfq::data::Corpus;
use dbfq::model::Method;
use dbfq::runtime::Value;
use dbfq::util::bench::Table;

fn main() {
    common::banner("Fig 4b — PPL vs block size, naive vs fallback",
                   "Fig 4(b), §4.5: fallback flattens the block-size \
                    degradation");
    let rt = common::runtime();
    let steps = common::bench_steps(60);
    // a briefly-trained small model so activations have structure
    let tr = common::trained(&rt, "small", Method::Bf16, steps, 11);
    let prof = rt.profile("small").unwrap().clone();
    let corpus = Corpus::synthetic(100_000, prof.vocab, 99);
    let batches = corpus.eval_batches(prof.batch, prof.seq_len, 3);

    let eval = |artifact: &str, theta: f32| -> f64 {
        let mut tot = 0.0;
        for b in &batches {
            let out = rt
                .call(
                    artifact,
                    &[
                        Value::vec_f32(tr.params.clone()),
                        Value::mat_i32(b.clone(), prof.batch,
                                       prof.seq_len + 1),
                        Value::vec_f32(vec![theta; prof.n_sites]),
                        Value::vec_f32(QScalars::default().to_vec()),
                    ],
                )
                .unwrap();
            tot += out[0].scalar().unwrap() as f64;
        }
        (tot / batches.len() as f64).exp()
    };

    // theta tuned per block size for ~20% rate via the rates output
    let theta_for = |artifact: &str, target: f64| -> f32 {
        let (mut lo, mut hi) = (0.0f32, 64.0f32);
        for _ in 0..14 {
            let mid = 0.5 * (lo + hi);
            let out = rt
                .call(
                    artifact,
                    &[
                        Value::vec_f32(tr.params.clone()),
                        Value::mat_i32(batches[0].clone(), prof.batch,
                                       prof.seq_len + 1),
                        Value::vec_f32(vec![mid; prof.n_sites]),
                        Value::vec_f32(QScalars::default().to_vec()),
                    ],
                )
                .unwrap();
            let rates = out[2].as_f32().unwrap();
            let rate = rates.iter().map(|&r| r as f64).sum::<f64>()
                / rates.len() as f64;
            if rate > target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    };

    let bf16 = eval("eval_small_bf16", f32::INFINITY);
    println!("BF16 reference PPL: {bf16:.3}\n");
    let mut t = Table::new(&["block", "naive PPL", "fallback20% PPL",
                             "naive gap", "fb gap"]);
    for bs in [32usize, 64, 128, 256] {
        let naive = eval(&format!("eval_small_block_bs{bs}"),
                         f32::INFINITY);
        let art = format!("eval_small_fallback_bs{bs}");
        let theta = theta_for(&art, 0.2);
        let fb = eval(&art, theta);
        t.row(&[
            bs.to_string(),
            format!("{naive:.3}"),
            format!("{fb:.3}"),
            format!("{:+.3}", naive - bf16),
            format!("{:+.3}", fb - bf16),
        ]);
    }
    t.print();
    println!("\npaper shape: naive PPL degrades as block grows; \
              fallback's gap stays near-flat, so block=128 + fallback \
              ≈ block=32 accuracy with far better kernel throughput");
}
